//! Quickstart: the whole Auto-SpMV pipeline on a handful of matrices.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! 1. generate corpus matrices (the SuiteSparse stand-in);
//! 2. sweep them through the GPU simulator to build a training dataset;
//! 3. train the compile-time and run-time optimizers;
//! 4. ask both modes for a plan on an unseen matrix.

use auto_spmv::coordinator::{CompileTimeOptimizer, OverheadModel, RunTimeOptimizer};
use auto_spmv::dataset::{build, BuildOptions};
use auto_spmv::features::extract_csr;
use auto_spmv::gen;
use auto_spmv::gpusim::Objective;
use auto_spmv::report::{fmt_g, Table};

fn main() -> anyhow::Result<()> {
    // --- 2. dataset: 10 training matrices, both GPU profiles -----------
    let train_names: Vec<String> = [
        "rim", "bcsstk32", "cant", "parabolic_fem", "consph",
        "wiki-talk-temporal", "amazon0601", "crankseg_1", "pwtk", "human_gene2",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    println!("building dataset over {} matrices...", train_names.len());
    let ds = build(&BuildOptions { only: Some(train_names), ..Default::default() });
    println!("dataset: {} records", ds.len());

    // --- 3. train both optimizers for two objectives -------------------
    let overhead = OverheadModel::train_on_corpus(1, Some("eu-2005"));
    for obj in [Objective::Latency, Objective::EnergyEff] {
        let compile = CompileTimeOptimizer::train(&ds, obj);
        let runtime = RunTimeOptimizer::train(&ds, obj, OverheadModel::train_on_corpus(1, Some("eu-2005")));

        // --- 4. plan for an UNSEEN matrix (eu-2005, web graph) ---------
        let entry = gen::by_name("eu-2005").unwrap();
        let coo = entry.generate(1);
        let csr = auto_spmv::sparse::convert::coo_to_csr(&coo);
        let f = extract_csr(&csr);
        let choice = compile.predict(&f, "GTX1650m-Turing");
        let decision = runtime.decide(&coo, 10_000);

        let mut t = Table::new(
            &format!("Auto-SpMV plan for unseen eu-2005 ({})", obj.name()),
            &["knob", "choice"],
        );
        t.row(vec!["TB size".into(), choice.tb_size.to_string()]);
        t.row(vec!["maxrregcount".into(), choice.maxrregcount.to_string()]);
        t.row(vec!["memory config".into(), choice.mem.name().into()]);
        t.row(vec!["sparse format".into(), decision.predicted_format.to_string()]);
        t.row(vec!["convert?".into(), decision.convert.to_string()]);
        t.row(vec!["est. overhead (s)".into(), fmt_g(decision.overhead.total())]);
        println!("{}", t.render());
    }
    let _ = overhead;
    println!("quickstart OK");
    Ok(())
}
