//! Conjugate-gradient solver on the serving pool's session API — the
//! paper's motivating workload (§7.5: "iterative solvers such as the
//! Preconditioned Conjugate Gradient method" amortize the run-time
//! optimization overhead).
//!
//! ```bash
//! make artifacts && cargo run --release --example cg_solver
//! ```
//!
//! Builds an SPD banded system A x = b, registers A with the pool, and
//! drives two iterative phases through ONE [`Session`]:
//!
//! 1. a **spectral-bound estimate** via pure chained power steps — the
//!    session hot path, where the vector never crosses the host
//!    boundary between iterations;
//! 2. the **CG loop** via the `write`/`step`/`read` escape hatches —
//!    CG updates `p` on the host every iteration, so each A·p pays the
//!    same two vector marshals as a per-request product. The printed
//!    ledger keeps that honest: sessions elide round-trips only on
//!    purely chained segments;
//! 3. a **SymGS-preconditioned CG** rerun: each iteration applies the
//!    symmetric Gauss–Seidel smoother z = M⁻¹ r as an in-session
//!    [`Session::symgs_step`] — a solve-kind step on the same pinned
//!    conversion, attributed under `kind=symgs` — and should cut the
//!    iteration count of phase 2.
//!
//! [`Session`]: auto_spmv::serve::Session
//! [`Session::symgs_step`]: auto_spmv::serve::Session::symgs_step

use auto_spmv::coordinator::overhead::OverheadModel;
use auto_spmv::coordinator::RunTimeOptimizer;
use auto_spmv::dataset::{build, BuildOptions};
use auto_spmv::gen::Rng;
use auto_spmv::gpusim::Objective;
use auto_spmv::runtime::default_artifacts_dir;
use auto_spmv::serve::{BackendSpec, Pool, PoolConfig, PoolStats};
use auto_spmv::sparse::convert::{coo_to_csr, ConvertParams};
use auto_spmv::sparse::{Coo, SpMv};
use std::sync::Arc;

/// SPD, diagonally dominant banded matrix (a 1-D Poisson-like stencil
/// with random off-diagonals) sized to fit the 256-row artifact bucket.
fn spd_system(n: usize, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    let band = 3usize;
    // symmetric off-diagonals
    let mut offs: Vec<(usize, usize, f32)> = Vec::new();
    for i in 0..n {
        for d in 1..=band {
            if i + d < n && rng.f64() < 0.7 {
                offs.push((i, i + d, -(rng.f64() as f32) * 0.4));
            }
        }
    }
    let mut diag = vec![1.0f32; n];
    for &(i, j, v) in &offs {
        coo.push(i, j, v);
        coo.push(j, i, v);
        diag[i] += v.abs() + 0.1;
        diag[j] += v.abs() + 0.1;
    }
    for (i, d) in diag.into_iter().enumerate() {
        coo.push(i, i, d);
    }
    coo
}

fn main() -> anyhow::Result<()> {
    let n = 250;
    let coo = spd_system(n, 42);
    let csr = coo_to_csr(&coo);
    println!("SPD system: n = {n}, nnz = {}", csr.vals.len());

    // router trained on a few corpus matrices
    let ds = build(&BuildOptions {
        only: Some(vec!["rim".into(), "bcsstk32".into(), "parabolic_fem".into()]),
        both_archs: false,
        ..Default::default()
    });
    let router =
        RunTimeOptimizer::train(&ds, Objective::Latency, OverheadModel::train_on_corpus(1, None));

    let artifacts = default_artifacts_dir();
    let backend = if artifacts.join("manifest.tsv").exists() {
        println!("backend: PJRT AOT kernels ({artifacts:?})");
        BackendSpec::Pjrt(artifacts)
    } else {
        println!("backend: native (run `make artifacts` for the PJRT path)");
        BackendSpec::Native
    };
    let pool = Pool::start(
        Arc::new(router),
        backend,
        PoolConfig {
            workers: 1,
            convert: ConvertParams { bell_bh: 8, bell_bw: 8, sell_h: 8 },
            ..PoolConfig::default()
        },
    );

    // many CG iterations expected -> the router may convert
    let fmt = pool.register(0, coo, 10_000)?;
    println!("router picked format: {fmt}");
    let session = pool.open_session(0)?;
    let bytes = |a: &PoolStats, b: &PoolStats| b.marshalled_bytes - a.marshalled_bytes;

    // --- phase 1: lambda_max bound via pure chained power steps --------
    // The session hot path: one write in, `power_steps` device-chained
    // iterations, one read out.
    let power_steps = 30u64;
    let before = pool.stats()?;
    session.write(vec![1.0f32; n])?;
    session.power_step_n(power_steps)?;
    let u = session.read()?;
    let after = pool.stats()?;
    let au = csr.spmv_alloc(&u);
    let lambda_max: f32 = u.iter().zip(&au).map(|(a, b)| a * b).sum();
    let power_bytes = bytes(&before, &after);
    println!(
        "spectral bound: lambda_max ~= {lambda_max:.4} after {power_steps} chained steps, \
         {power_bytes} B marshalled ({:.0} B/step vs {} per-request), {} round-trips elided",
        power_bytes as f64 / power_steps as f64,
        8 * n,
        after.round_trips_elided - before.round_trips_elided,
    );
    assert!(
        power_bytes as f64 * 10.0 <= (8 * n) as f64 * power_steps as f64,
        "chained power steps must elide >= 90% of per-request marshalling"
    );

    // --- phase 2: conjugate gradient via the escape hatches ------------
    let b: Vec<f32> = (0..n).map(|i| ((i % 11) as f32 - 5.0) * 0.3).collect();
    let mut x = vec![0.0f32; n];
    let mut r = b.clone();
    let mut p = r.clone();
    let mut rs_old: f32 = r.iter().map(|v| v * v).sum();
    let mut products = 0u32;
    let before = pool.stats()?;
    let t0 = std::time::Instant::now();
    for it in 0..400 {
        // A*p through the pinned session: write(p) -> step -> read
        session.write(p.clone())?;
        session.step()?;
        let ap = session.read()?;
        products += 1;
        let pap: f32 = p.iter().zip(&ap).map(|(a, b)| a * b).sum();
        let alpha = rs_old / pap;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new: f32 = r.iter().map(|v| v * v).sum();
        if rs_new.sqrt() < 1e-5 {
            println!("converged after {} iterations", it + 1);
            break;
        }
        let beta = rs_new / rs_old;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs_old = rs_new;
    }
    let dt = t0.elapsed();
    let after = pool.stats()?;
    let cg_bytes = bytes(&before, &after);

    // verify against a native residual
    let ax = csr.spmv_alloc(&x);
    let resid: f32 = ax.iter().zip(&b).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
    println!(
        "CG done: {products} SpMV products in {:.3}s ({:.2} ms/product), final residual {resid:.2e}",
        dt.as_secs_f64(),
        1e3 * dt.as_secs_f64() / products as f64
    );
    println!(
        "CG ledger: {cg_bytes} B marshalled ({:.0} B/product) — host-side p-updates make \
         every A*p a write/read pair, the same traffic as per-request serving; only the \
         chained phase above elides round-trips",
        cg_bytes as f64 / products as f64
    );
    assert!(resid < 1e-3, "CG must converge");
    let plain_iters = products;

    // --- phase 3: SymGS-preconditioned CG through the same session ----
    // Each iteration makes two session trips: A*p (a product step) and
    // z = M^-1 r (a symgs solve step on the pinned conversion).
    let mut x = vec![0.0f32; n];
    let mut r = b.clone();
    let apply = |vec: &[f32], op: &dyn Fn() -> anyhow::Result<()>| -> anyhow::Result<Vec<f32>> {
        session.write(vec.to_vec())?;
        op()?;
        session.read()
    };
    let mut z = apply(&r, &|| session.symgs_step())?;
    let mut p = z.clone();
    let mut rz_old: f32 = r.iter().zip(&z).map(|(a, b)| a * b).sum();
    let mut pcg_iters = 0u32;
    for it in 0..400 {
        let ap = apply(&p, &|| session.step())?;
        pcg_iters += 1;
        let pap: f32 = p.iter().zip(&ap).map(|(a, b)| a * b).sum();
        let alpha = rz_old / pap;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs: f32 = r.iter().map(|v| v * v).sum();
        if rs.sqrt() < 1e-5 {
            println!("preconditioned CG converged after {} iterations", it + 1);
            break;
        }
        z = apply(&r, &|| session.symgs_step())?;
        let rz_new: f32 = r.iter().zip(&z).map(|(a, b)| a * b).sum();
        let beta = rz_new / rz_old;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
        rz_old = rz_new;
    }
    let ax = csr.spmv_alloc(&x);
    let pcg_resid: f32 = ax.iter().zip(&b).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
    println!(
        "SymGS-PCG: {pcg_iters} iterations vs {plain_iters} unpreconditioned, \
         final residual {pcg_resid:.2e}"
    );
    assert!(pcg_resid < 1e-3, "preconditioned CG must converge");
    assert!(
        pcg_iters <= plain_iters,
        "a SymGS smoother must not slow CG down on a diagonally dominant system"
    );
    drop(session);
    let stats = pool.stats()?;
    println!(
        "pool: {} requests ({} session steps), conversions {}, {} B marshalled total",
        stats.requests, stats.session_steps, stats.conversions, stats.marshalled_bytes
    );
    println!("cg_solver OK");
    Ok(())
}
