//! Conjugate-gradient solver driven through the Auto-SpMV service —
//! the paper's motivating workload (§7.5: "iterative solvers such as the
//! Preconditioned Conjugate Gradient method" amortize the run-time
//! optimization overhead).
//!
//! ```bash
//! make artifacts && cargo run --release --example cg_solver
//! ```
//!
//! Builds an SPD banded system A x = b, registers A with the serving
//! loop (router picks the format; conversion is amortized over the CG
//! iterations), and solves with every SpMV product dispatched through
//! the service — over PJRT AOT kernels when artifacts are present.

use auto_spmv::coordinator::overhead::OverheadModel;
use auto_spmv::coordinator::service::{BackendSpec, Service};
use auto_spmv::coordinator::RunTimeOptimizer;
use auto_spmv::dataset::{build, BuildOptions};
use auto_spmv::gen::Rng;
use auto_spmv::gpusim::Objective;
use auto_spmv::runtime::default_artifacts_dir;
use auto_spmv::sparse::convert::{coo_to_csr, csr_to_coo, ConvertParams};
use auto_spmv::sparse::{Coo, SpMv};

/// SPD, diagonally dominant banded matrix (a 1-D Poisson-like stencil
/// with random off-diagonals) sized to fit the 256-row artifact bucket.
fn spd_system(n: usize, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    let band = 3usize;
    // symmetric off-diagonals
    let mut offs: Vec<(usize, usize, f32)> = Vec::new();
    for i in 0..n {
        for d in 1..=band {
            if i + d < n && rng.f64() < 0.7 {
                offs.push((i, i + d, -(rng.f64() as f32) * 0.4));
            }
        }
    }
    let mut diag = vec![1.0f32; n];
    for &(i, j, v) in &offs {
        coo.push(i, j, v);
        coo.push(j, i, v);
        diag[i] += v.abs() + 0.1;
        diag[j] += v.abs() + 0.1;
    }
    for (i, d) in diag.into_iter().enumerate() {
        coo.push(i, i, d);
    }
    coo
}

fn main() -> anyhow::Result<()> {
    let n = 250;
    let coo = spd_system(n, 42);
    let csr = coo_to_csr(&coo);
    println!("SPD system: n = {n}, nnz = {}", csr.vals.len());

    // router trained on a few corpus matrices
    let ds = build(&BuildOptions {
        only: Some(vec!["rim".into(), "bcsstk32".into(), "parabolic_fem".into()]),
        both_archs: false,
        ..Default::default()
    });
    let router = RunTimeOptimizer::train(
        &ds,
        Objective::Latency,
        OverheadModel::train_on_corpus(1, None),
    );

    let artifacts = default_artifacts_dir();
    let backend = if artifacts.join("manifest.tsv").exists() {
        println!("backend: PJRT AOT kernels ({artifacts:?})");
        BackendSpec::Pjrt(artifacts)
    } else {
        println!("backend: native (run `make artifacts` for the PJRT path)");
        BackendSpec::Native
    };
    let svc = Service::start(router, backend, ConvertParams { bell_bh: 8, bell_bw: 8, sell_h: 8 });

    // many CG iterations expected -> the router may convert
    let fmt = svc.register(0, csr_to_coo(&csr), 10_000)?;
    println!("router picked format: {fmt}");

    // --- conjugate gradient, every A*p through the service -------------
    let b: Vec<f32> = (0..n).map(|i| ((i % 11) as f32 - 5.0) * 0.3).collect();
    let mut x = vec![0.0f32; n];
    let mut r = b.clone();
    let mut p = r.clone();
    let mut rs_old: f32 = r.iter().map(|v| v * v).sum();
    let mut products = 0u32;
    let t0 = std::time::Instant::now();
    for it in 0..400 {
        let ap = svc.product(0, p.clone())?.y;
        products += 1;
        let pap: f32 = p.iter().zip(&ap).map(|(a, b)| a * b).sum();
        let alpha = rs_old / pap;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new: f32 = r.iter().map(|v| v * v).sum();
        if rs_new.sqrt() < 1e-5 {
            println!("converged after {} iterations", it + 1);
            break;
        }
        let beta = rs_new / rs_old;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs_old = rs_new;
    }
    let dt = t0.elapsed();

    // verify against a native residual
    let ax = csr.spmv_alloc(&x);
    let resid: f32 = ax.iter().zip(&b).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
    println!(
        "CG done: {products} SpMV products in {:.3}s ({:.2} ms/product), final residual {resid:.2e}",
        dt.as_secs_f64(),
        1e3 * dt.as_secs_f64() / products as f64
    );
    assert!(resid < 1e-3, "CG must converge");
    let stats = svc.stats()?;
    println!("service: {} requests, conversions {}", stats.requests, stats.conversions);
    println!("cg_solver OK");
    Ok(())
}
