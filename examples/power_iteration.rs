//! Power iteration through the fused L2 power-step artifact — shows a
//! whole solver step (SpMV + norm + scale) compiled into ONE HLO module
//! and driven from Rust (the paper's eigenvalue-problem motivation, §1).
//!
//! ```bash
//! make artifacts && cargo run --release --example power_iteration
//! ```

use auto_spmv::gen::Rng;
use auto_spmv::runtime::{default_artifacts_dir, Engine};
use auto_spmv::sparse::convert::{coo_to_csr, csr_to_ell};
use auto_spmv::sparse::{Coo, SpMv};

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("no artifacts at {dir:?}; run `make artifacts` first");
        return Ok(());
    }
    let mut engine = Engine::new(&dir)?;
    println!("PJRT platform: {}", engine.platform());

    // symmetric banded matrix, 240 rows (fits the 256-row power bucket;
    // width must stay within the bucket's 16)
    let n = 240;
    let mut rng = Rng::new(9);
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, 2.0 + (i % 5) as f32 * 0.1);
        for d in 1..=3usize {
            if i + d < n {
                let v = 0.4 / d as f32 + 0.05 * rng.val();
                coo.push(i, i + d, v);
                coo.push(i + d, i, v);
            }
        }
    }
    let csr = coo_to_csr(&coo);
    let ell = csr_to_ell(&csr);
    println!("matrix: n = {n}, nnz = {}, ELL width = {}", csr.vals.len(), ell.width);

    // --- power iteration: every step ONE fused PJRT execution ----------
    let mut x = vec![1.0f32; n];
    let nrm0: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
    for v in &mut x {
        *v /= nrm0;
    }
    let mut lambda_est = 0.0f32;
    let t0 = std::time::Instant::now();
    let steps = 60;
    for _ in 0..steps {
        let y = engine.power_step(&ell, &x)?;
        // Rayleigh quotient estimate before normalization uses Ax = y * ||Ax||;
        // recompute via native product for the eigenvalue readout
        let ax = csr.spmv_alloc(&x);
        lambda_est = x.iter().zip(&ax).map(|(a, b)| a * b).sum();
        x = y;
    }
    let dt = t0.elapsed();

    // validate: residual ||A x - lambda x|| should be small
    let ax = csr.spmv_alloc(&x);
    let resid: f32 = ax
        .iter()
        .zip(&x)
        .map(|(a, v)| (a - lambda_est * v) * (a - lambda_est * v))
        .sum::<f32>()
        .sqrt();
    println!(
        "power iteration: {steps} fused steps in {:.3}s ({:.2} ms/step)",
        dt.as_secs_f64(),
        1e3 * dt.as_secs_f64() / steps as f64
    );
    println!("dominant eigenvalue ~= {lambda_est:.4}, residual {resid:.2e}");
    assert!(resid < 5e-2, "power iteration must converge toward an eigenpair");
    println!("power_iteration OK");
    Ok(())
}
