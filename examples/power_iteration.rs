//! Power iteration through a device-resident serving session — the
//! eigenvalue-problem motivation (paper §1) on the PR 6 hot path.
//!
//! The same solver runs twice against one serving pool:
//!
//! 1. **per-request**: every step submits `x` and receives `y` through
//!    the pool's queue — two vector marshals per iteration;
//! 2. **session**: [`Session::power_step_n`] keeps the vector resident
//!    across steps (device-side on PJRT via the fused x' = Ax/||Ax||
//!    artifact, host-side reuse on native), so the only marshals are
//!    the initial `write` and the final `read`.
//!
//! The printout is the marshalled-bytes-per-iteration ledger before and
//! after — the round-trip traffic a chained solver stops paying.
//!
//! ```bash
//! make artifacts && cargo run --release --example power_iteration
//! ```

use auto_spmv::coordinator::overhead::OverheadModel;
use auto_spmv::coordinator::RunTimeOptimizer;
use auto_spmv::dataset::{build, BuildOptions};
use auto_spmv::gen::Rng;
use auto_spmv::gpusim::Objective;
use auto_spmv::runtime::default_artifacts_dir;
use auto_spmv::serve::{BackendSpec, Pool, PoolConfig, PoolStats};
use auto_spmv::sparse::convert::coo_to_csr;
use auto_spmv::sparse::{Coo, SpMv};
use std::sync::Arc;

fn normalize(v: &mut [f32]) {
    let norm = v.iter().map(|a| a * a).sum::<f32>().sqrt();
    for a in v {
        *a /= norm;
    }
}

/// Rayleigh quotient and eigenpair residual of a unit vector.
fn eigen_readout(csr: &auto_spmv::sparse::Csr, x: &[f32]) -> (f32, f32) {
    let ax = csr.spmv_alloc(x);
    let lambda: f32 = x.iter().zip(&ax).map(|(a, b)| a * b).sum();
    let resid = ax
        .iter()
        .zip(x)
        .map(|(a, v)| (a - lambda * v) * (a - lambda * v))
        .sum::<f32>()
        .sqrt();
    (lambda, resid)
}

fn main() -> anyhow::Result<()> {
    // symmetric banded matrix, 240 rows (fits the 256-row power bucket;
    // width must stay within the bucket's 16)
    let n = 240;
    let mut rng = Rng::new(9);
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, 2.0 + (i % 5) as f32 * 0.1);
        for d in 1..=3usize {
            if i + d < n {
                let v = 0.4 / d as f32 + 0.05 * rng.val();
                coo.push(i, i + d, v);
                coo.push(i + d, i, v);
            }
        }
    }
    let csr = coo_to_csr(&coo);
    println!("matrix: n = {n}, nnz = {}", csr.vals.len());

    // router trained on a few corpus matrices
    let ds = build(&BuildOptions {
        only: Some(vec!["rim".into(), "bcsstk32".into(), "parabolic_fem".into()]),
        both_archs: false,
        ..Default::default()
    });
    let router =
        RunTimeOptimizer::train(&ds, Objective::Latency, OverheadModel::train_on_corpus(1, None));
    let artifacts = default_artifacts_dir();
    let backend = if artifacts.join("manifest.tsv").exists() {
        println!("backend: PJRT AOT kernels ({artifacts:?})");
        BackendSpec::Pjrt(artifacts)
    } else {
        println!("backend: native (run `make artifacts` for the fused PJRT path)");
        BackendSpec::Native
    };
    let pool = Pool::start(
        Arc::new(router),
        backend,
        PoolConfig { workers: 1, ..PoolConfig::default() },
    );
    let fmt = pool.register(0, coo, 10_000)?;
    println!("router picked format: {fmt}");

    let steps = 60usize;
    let mut x0 = vec![1.0f32; n];
    normalize(&mut x0);
    let bytes = |a: &PoolStats, b: &PoolStats| b.marshalled_bytes - a.marshalled_bytes;

    // --- BEFORE: per-request path, x in and y out every iteration ------
    let before = pool.stats()?;
    let mut x = x0.clone();
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        x = pool.product(0, x)?.y;
        normalize(&mut x);
    }
    let dt_req = t0.elapsed();
    let after = pool.stats()?;
    let req_bytes = bytes(&before, &after);
    let (lambda_req, resid_req) = eigen_readout(&csr, &x);

    // --- AFTER: session path, the vector never leaves the backend ------
    let before = pool.stats()?;
    let session = pool.open_session(0)?;
    session.write(x0)?;
    let t0 = std::time::Instant::now();
    session.power_step_n(steps as u64)?;
    let y = session.read()?;
    let dt_sess = t0.elapsed();
    let after = pool.stats()?;
    let sess_bytes = bytes(&before, &after);
    let (lambda_sess, resid_sess) = eigen_readout(&csr, &y);
    drop(session);

    println!(
        "per-request: {steps} steps in {:.3}s, {req_bytes} B marshalled ({:.0} B/step)",
        dt_req.as_secs_f64(),
        req_bytes as f64 / steps as f64
    );
    println!(
        "session:     {steps} steps in {:.3}s, {sess_bytes} B marshalled ({:.0} B/step), \
         {} round-trips elided",
        dt_sess.as_secs_f64(),
        sess_bytes as f64 / steps as f64,
        after.round_trips_elided - before.round_trips_elided,
    );
    println!(
        "marshalled bytes/iteration: {:.0}x fewer on the session path",
        req_bytes as f64 / sess_bytes.max(1) as f64
    );
    println!(
        "dominant eigenvalue ~= {lambda_sess:.4} (per-request {lambda_req:.4}), \
         residual {resid_sess:.2e}"
    );
    assert!(resid_req < 5e-2, "per-request power iteration must converge");
    assert!(resid_sess < 5e-2, "session power iteration must converge");
    assert!(
        (lambda_req - lambda_sess).abs() < 1e-3 * lambda_req.abs().max(1.0),
        "both paths must agree on the eigenvalue"
    );
    assert!(
        (req_bytes as f64) >= 10.0 * sess_bytes as f64,
        "the session path must elide >= 90% of marshalled bytes per iteration"
    );
    println!("power_iteration OK");
    Ok(())
}
