//! End-to-end serving driver (DESIGN.md: the repo's mandated E2E
//! validation) — exercises all layers together:
//!
//!   corpus generators (L3) -> feature extraction (L3) -> GPU-simulator
//!   dataset + trained router (L3) -> run-time format decisions (L3) ->
//!   sharded serving pool with request coalescing (L3) -> AOT-compiled
//!   Pallas SpMV kernels (L1/L2) through PJRT (native fallback) ->
//!   batched request stream with latency/energy/throughput report.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_requests
//! ```
//!
//! The measured run is recorded in EXPERIMENTS.md §End-to-end.

use auto_spmv::coordinator::overhead::OverheadModel;
use auto_spmv::coordinator::RunTimeOptimizer;
use auto_spmv::dataset::{build, BuildOptions};
use auto_spmv::gen::{patterns, Rng};
use auto_spmv::gpusim::{turing_gtx1650m, Objective};
use auto_spmv::online::{Online, OnlineConfig, Trainer};
use auto_spmv::report::Table;
use auto_spmv::runtime::default_artifacts_dir;
use auto_spmv::serve::{BackendSpec, Pool, PoolConfig};
use auto_spmv::sparse::convert::{coo_to_csr, ConvertParams};
use auto_spmv::sparse::{Coo, SpMv};
use std::sync::Arc;
use std::time::Duration;

/// Workload: a mixed fleet of small matrices (each fits an AOT bucket)
/// with distinct structures, so the router exercises several formats.
fn fleet() -> Vec<(&'static str, Coo)> {
    let mut rng = Rng::new(0xE2E);
    vec![
        ("banded-A", patterns::banded(&mut rng, 240, 10, 5.0)),
        ("banded-B", patterns::banded(&mut rng, 1000, 16, 6.0)),
        ("scattered", patterns::uniform(&mut rng, 250, 250, 5.0)),
        ("powerlaw", patterns::powerlaw(&mut rng, 1000, 1000, 2.0, 4.0, 60)),
        ("blocky", patterns::blocks(&mut rng, 248, 8, 8, 1.6, 3, 0.9)),
        // perfectly regular stencil: the structure class whose
        // energy-efficiency winner is ELL in the training corpus
        ("stencil", patterns::diagonals(&mut rng, 1000, &[-24, 0, 24, -48, 48, -72, 72], 0.98)),
    ]
}

fn main() -> anyhow::Result<()> {
    // --- train the router over the corpus sweep -------------------------
    println!("training router (dataset sweep over the full 30-matrix corpus)...");
    let ds = build(&BuildOptions::default());
    // energy efficiency: the objective where format choice matters most
    // (paper §7.2: CSR is already latency-optimal, but loses up to 99.7%
    // energy efficiency on skewed/banded matrices)
    let objective = Objective::EnergyEff;
    let overhead = OverheadModel::train_on_corpus(1, None);
    let router = Arc::new(RunTimeOptimizer::train(&ds, objective, overhead.clone()));

    // --- closed loop: explore a sliver of traffic, retrain periodically --
    // The fleet below is synthetic (not the training corpus), so the
    // online loop can only improve on the offline router's guesses.
    let online = Online::start(
        OnlineConfig {
            explore_rate: 0.08,
            retrain_every: 192,
            seed: 0xE2E,
            // refits run off-thread so the latency table below measures
            // serving, not retraining
            background: true,
            ..OnlineConfig::default()
        },
        router,
        objective,
        Some(Trainer::new(ds.clone(), objective, overhead, turing_gtx1650m().name)),
    );

    // --- backend: PJRT over the AOT artifacts ---------------------------
    let artifacts = default_artifacts_dir();
    let pjrt = artifacts.join("manifest.tsv").exists();
    let backend = if pjrt {
        BackendSpec::Pjrt(artifacts.clone())
    } else {
        eprintln!("WARNING: no artifacts at {artifacts:?}; falling back to native");
        BackendSpec::Native
    };
    let pool = Pool::start_adaptive(
        online,
        backend,
        PoolConfig {
            workers: 2,
            batch_window: Duration::from_micros(150),
            convert: ConvertParams { bell_bh: 8, bell_bw: 8, sell_h: 8 },
            ..PoolConfig::default()
        },
    );

    // --- register the fleet ---------------------------------------------
    let fleet = fleet();
    let mut dims = Vec::new();
    let mut formats = Vec::new();
    for (id, (name, coo)) in fleet.iter().enumerate() {
        dims.push((coo.n_cols, coo_to_csr(coo)));
        let fmt = pool.register(id as u64, coo.clone(), 500_000)?;
        formats.push(fmt);
        println!("  registered {name:>10} ({} rows) -> {fmt}", coo.n_rows);
    }

    // --- request stream ---------------------------------------------------
    // Pipelined in bursts of 8: concurrent requests for the same matrix
    // coalesce into one spmv_batch dispatch on its shard.
    let n_requests = 504usize;
    let burst = 8usize;
    let mut lat_us: Vec<f64> = Vec::with_capacity(n_requests);
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    let mut checked = 0usize;
    let mut r = 0usize;
    while r < n_requests {
        let mut pending = Vec::with_capacity(burst);
        for _ in 0..burst.min(n_requests - r) {
            let id = rng.below(fleet.len());
            let (n_cols, _) = &dims[id];
            let x: Vec<f32> =
                (0..*n_cols).map(|i| ((i + r) % 9) as f32 * 0.25 - 1.0).collect();
            pending.push((id, x.clone(), pool.product_async(id as u64, x)?));
            r += 1;
        }
        for (id, x, rx) in pending {
            let resp = rx.recv().map_err(|_| anyhow::anyhow!("pool dropped request"))??;
            lat_us.push(resp.service_time.as_secs_f64() * 1e6);
            // spot-check numerics against native on a sample of requests
            if lat_us.len() % 97 == 0 {
                let want = dims[id].1.spmv_alloc(&x);
                for (a, b) in resp.y.iter().zip(&want) {
                    assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "numeric mismatch");
                }
                checked += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // --- report -------------------------------------------------------------
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat_us[(p / 100.0 * (lat_us.len() - 1) as f64).round() as usize];
    let stats = pool.stats()?;
    // `backend_summary` is what the shards ACTUALLY built — a pool that
    // requested PJRT but failed engine init reports native here.
    let mut t = Table::new(
        &format!(
            "End-to-end serving ({} backend, {} workers, {} requests, {} matrices)",
            stats.backend_summary(),
            stats.workers,
            n_requests,
            fleet.len()
        ),
        &["metric", "value"],
    );
    t.row(vec!["throughput (req/s)".into(), format!("{:.1}", n_requests as f64 / wall)]);
    t.row(vec!["latency p50 (us)".into(), format!("{:.1}", pct(50.0))]);
    t.row(vec!["latency p90 (us)".into(), format!("{:.1}", pct(90.0))]);
    t.row(vec!["latency p99 (us)".into(), format!("{:.1}", pct(99.0))]);
    t.row(vec!["max (us)".into(), format!("{:.1}", lat_us[lat_us.len() - 1])]);
    t.row(vec!["dispatches".into(), stats.dispatches.to_string()]);
    t.row(vec![
        "launches (per request)".into(),
        format!("{} ({:.2})", stats.launches, stats.launches_per_request()),
    ]);
    t.row(vec![
        "coalesced batches (max size)".into(),
        format!("{} ({})", stats.coalesced_batches, stats.max_batch),
    ]);
    t.row(vec!["conversions".into(), stats.conversions.to_string()]);
    t.row(vec!["modeled energy (J)".into(), format!("{:.3e}", stats.total_energy_j)]);
    t.row(vec!["numeric spot-checks".into(), checked.to_string()]);
    t.row(vec![
        "formats at registration".into(),
        formats.iter().map(|f| f.to_string()).collect::<Vec<_>>().join(","),
    ]);
    t.row(vec![
        "router version (retrains)".into(),
        format!("v{} ({})", stats.router_version, stats.retrains),
    ]);
    t.row(vec![
        "explored requests / migrations".into(),
        format!("{} / {}", stats.explored_requests, stats.migrations),
    ]);
    t.row(vec![
        "knob migrations / UCB routes".into(),
        format!("{} / {}", stats.knob_migrations, stats.ucb_routes),
    ]);
    t.row(vec![
        "drift".into(),
        stats.drift.map_or("off".to_string(), |d| d.to_string()),
    ]);
    t.emit("e2e_serving");

    // per-matrix telemetry: the §6.3 energy objective at serve time,
    // plus the routing-decision mix (explored arms starred)
    let quant = |q: Option<f64>| q.map_or("-".to_string(), |v| format!("{v:.1}"));
    let mut pm = Table::new(
        "Per-matrix telemetry (energy modeled on the Turing profile)",
        &[
            "matrix", "format", "knobs", "requests", "p50 (us)", "p99 (us)", "energy (J)",
            "decisions",
        ],
    );
    for m in &stats.per_matrix {
        let name = fleet.get(m.id as usize).map_or("?", |(n, _)| *n);
        pm.row(vec![
            name.into(),
            m.format.map_or("?".to_string(), |f| f.to_string()),
            m.knobs.map_or("?".to_string(), |k| k.to_string()),
            m.requests.to_string(),
            quant(m.p50_us),
            quant(m.p99_us),
            format!("{:.3e}", m.energy_j),
            m.decisions(),
        ]);
    }
    pm.emit("e2e_serving_telemetry");
    println!("serve_requests OK");
    Ok(())
}
