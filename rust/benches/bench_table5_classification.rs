//! Table 5 — classification accuracy/F1 of the AutoML-tuned decision
//! tree predicting the best TB size / maxrregcount / memory config for
//! each objective (80/20 split), plus the format target used by the
//! run-time mode.

#[path = "common.rs"]
mod common;

use auto_spmv::automl::tuner::{tune_family, Family};
use auto_spmv::dataset::labels::{self, Target};
use auto_spmv::gpusim::Objective;
use auto_spmv::ml::metrics::{accuracy, f1_macro};
use auto_spmv::ml::split::{take, take_x, train_test_indices};
use auto_spmv::ml::Classifier;
use auto_spmv::report::Table;

fn main() {
    let ds = common::full_dataset();
    let mut t = Table::new(
        "Table 5 — tuned decision tree, accuracy / F1 (%) per objective",
        &["target", "latency", "energy", "avg_power", "energy_eff"],
    );
    for target in Target::ALL {
        let mut cells = vec![target.name().to_string()];
        for obj in Objective::ALL {
            let ex = labels::examples(&ds, obj);
            let (x, y) = labels::to_xy(&ex, target);
            let (tr, te) = train_test_indices(x.len(), 0.2, 0x7AB5);
            let tuned = tune_family(Family::DecisionTree, &take_x(&x, &tr), &take(&y, &tr), 10, 5);
            let pred = tuned.model.predict(&take_x(&x, &te));
            let truth = take(&y, &te);
            cells.push(format!(
                "{:.0}/{:.0}",
                100.0 * accuracy(&truth, &pred),
                100.0 * f1_macro(&truth, &pred, target.n_classes())
            ));
        }
        t.row(cells);
    }
    t.emit("table5_classification");
    println!("paper shape: high accuracy across targets (Table 5 reports 100% acc)");
}
