//! Fig. 11 — regression models estimating each objective: R^2 and MSE on
//! a 20% held-out split for the paper's six regressor families
//! (Bayesian ridge, lasso, LARS, decision tree, random forest, MLP).
//!
//! The estimation task is the paper's: given (sparsity features,
//! configuration), predict the objective value of one run — trained over
//! the full sweep records (the "large training dataset" the paper
//! credits for its R^2 > 0.99). Targets regress in log space (objectives
//! span decades); metrics are reported in that space.

#[path = "common.rs"]
mod common;

use auto_spmv::dataset::labels::arch_feature;
use auto_spmv::gpusim::Objective;
use auto_spmv::ml::forest::RandomForestRegressor;
use auto_spmv::ml::linear::{BayesianRidge, Lars, Lasso};
use auto_spmv::ml::metrics::{mse, r2};
use auto_spmv::ml::mlp::MlpRegressor;
use auto_spmv::ml::scaler::StandardScaler;
use auto_spmv::ml::split::{take, take_x, train_test_indices};
use auto_spmv::ml::tree::DecisionTreeRegressor;
use auto_spmv::ml::Regressor;
use auto_spmv::report::{fmt_g, Table};

fn main() {
    let ds = common::full_dataset();
    // one training row per sweep record: features + config encoding
    let mut x_all: Vec<Vec<f64>> = Vec::with_capacity(ds.len());
    for r in &ds.records {
        let mut f = r.features.to_scaled_vec();
        f.push(arch_feature(&r.arch));
        f.push(r.config.format.class_id() as f64);
        f.push((r.config.tb_size as f64).log2());
        f.push((r.config.maxrregcount as f64).log2());
        f.push(r.config.mem.class_id() as f64);
        x_all.push(f);
    }
    // subsample for the slow learners' budget (1 core): every 3rd record
    let idx: Vec<usize> = (0..x_all.len()).step_by(3).collect();

    for obj in Objective::ALL {
        let y_all: Vec<f64> = ds
            .records
            .iter()
            .map(|r| obj.value(&r.m).max(1e-12).ln())
            .collect();
        let x: Vec<Vec<f64>> = idx.iter().map(|&i| x_all[i].clone()).collect();
        let y: Vec<f64> = idx.iter().map(|&i| y_all[i]).collect();
        let (tr, te) = train_test_indices(x.len(), 0.2, 0xF16);
        let (sc, xt) = StandardScaler::fit_transform(&take_x(&x, &tr));
        let xv = sc.transform(&take_x(&x, &te));
        let (yt, yv) = (take(&y, &tr), take(&y, &te));

        let mut models: Vec<(&str, Box<dyn Regressor>)> = vec![
            ("Bayesian Ridge", Box::new(BayesianRidge::default())),
            ("Lasso", Box::new(Lasso { alpha: 0.01, epochs: 200, ..Default::default() })),
            ("LARS", Box::new(Lars::default())),
            ("Decision Tree", Box::new(DecisionTreeRegressor::default())),
            (
                "Random Forest",
                Box::new(RandomForestRegressor { n_estimators: 20, ..Default::default() }),
            ),
            (
                "MLP",
                Box::new(MlpRegressor {
                    hidden: vec![64, 64],
                    epochs: 12,
                    lr: 1e-3,
                    ..Default::default()
                }),
            ),
        ];
        let mut t = Table::new(
            &format!(
                "Fig. 11 ({}) — per-run objective estimation ({} train rows, log-space)",
                obj.name(),
                xt.len()
            ),
            &["model", "R^2", "MSE"],
        );
        let mut best = ("", f64::NEG_INFINITY);
        for (name, model) in models.iter_mut() {
            model.fit(&xt, &yt);
            let pred = model.predict(&xv);
            let r = r2(&yv, &pred);
            let m = mse(&yv, &pred);
            if r > best.1 {
                best = (name, r);
            }
            t.row(vec![name.to_string(), format!("{r:.4}"), fmt_g(m)]);
        }
        t.emit(&format!("fig11_regression_{}", obj.name()));
        println!(
            "{}: best = {} (R^2 {:.4}); paper shape: tree ensembles dominate with R^2 > 0.99\n",
            obj.name(),
            best.0,
            best.1
        );
    }
}
