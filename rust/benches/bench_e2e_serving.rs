//! End-to-end serving benchmark over the PJRT runtime: request
//! throughput/latency through the full stack (router -> conversion ->
//! AOT Pallas kernels), per format. Falls back to the native backend
//! when artifacts are missing.

use auto_spmv::gen::{patterns, Rng};
use auto_spmv::report::{bench, Table};
use auto_spmv::runtime::{default_artifacts_dir, Engine};
use auto_spmv::sparse::convert::{self, ConvertParams};
use auto_spmv::sparse::{Format, SpMv};

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        println!("SKIP: no artifacts at {dir:?} (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::new(&dir).expect("engine");
    let mut rng = Rng::new(0xBE);
    let coo = patterns::banded(&mut rng, 1000, 16, 6.0);
    let csr = convert::coo_to_csr(&coo);
    let x: Vec<f32> = (0..csr.n_cols).map(|i| (i % 7) as f32 * 0.3).collect();

    let mut t = Table::new(
        "E2E — per-format PJRT SpMV latency (1000-row banded, warm cache)",
        &["format", "mean (us)", "min (us)", "native (us)"],
    );
    let params = ConvertParams { bell_bh: 8, bell_bw: 8, sell_h: 8 };
    let native = bench(3, 50, || {
        std::hint::black_box(csr.spmv_alloc(&x));
    });
    for fmt in Format::ALL {
        let m = convert::convert(&csr, fmt, params);
        // warm: compile + first run
        engine.spmv(&m, &x, None).expect("spmv");
        let timing = bench(2, 30, || {
            std::hint::black_box(engine.spmv(&m, &x, None).unwrap());
        });
        t.row(vec![
            fmt.to_string(),
            format!("{:.1}", timing.mean_s * 1e6),
            format!("{:.1}", timing.min_s * 1e6),
            format!("{:.1}", native.mean_s * 1e6),
        ]);
    }
    t.emit("e2e_serving_bench");
    println!("executions {}, cached executables {}", engine.exec_count, engine.cached());
}
