//! End-to-end serving benchmark.
//!
//! Part 1 (artifacts only): per-format PJRT SpMV latency through the
//! full stack (router -> conversion -> AOT Pallas kernels).
//!
//! Part 2 (always runs — the native backend needs no artifacts):
//! serving throughput of the sharded pool at 1/2/4 workers with request
//! coalescing on vs off, plus the coalescing evidence: dispatches vs
//! requests and the largest spmv_batch executed.
//!
//! Part 3 (always runs): the closed loop under workload drift — a
//! router trained on a biased corpus slice serves a drifted synthetic
//! fleet, frozen vs adaptive (joint (format, knob) exploration +
//! retraining + hot-swap); reports mean modeled energy per request and
//! the router version, then ASSERTS the adaptation converged: with
//! exploration annealed to zero, the adaptive pool's incremental
//! energy per request must not exceed the frozen pool's. The adaptive
//! pool's Prometheus exposition and control-plane event journal are
//! dumped as `reports/METRICS.prom` / `reports/EVENTS.json` — the
//! observability artifacts the CI bench-smoke job lints and uploads.
//!
//! Part 2d (always runs): the solver chain — direct SpTRSV/SymGS
//! requests checked bit-for-bit against the native sweeps, then a
//! SymGS-preconditioned CG loop through one session; the per-kind
//! request/launch attribution and the solve_exec/session_step stage
//! counts are exact and gated by `tools/bench_gate.py`.
//!
//! Part 4 (always runs): request-lifecycle stage decomposition — the
//! stage histograms must partition end-to-end latency EXACTLY (the
//! shard derives both from the same boundary instants), with
//! deterministic per-stage counts gated by `tools/bench_gate.py`.
//!
//! Part 5 (always runs): tracing overhead — the same sequential
//! workload with `PoolConfig::tracing` off vs on, interleaved
//! best-of-5; ASSERTS the instrumented path stays within 3% of the
//! untraced one (wall-clock, so reported but never baseline-gated).
//!
//! Modes: `--smoke` (or env `AUTOSPMV_BENCH_SMOKE=1`) runs a bounded
//! quick configuration for CI — same assertions, smaller request
//! counts. Every table is also emitted as `reports/BENCH_*.json` so
//! the CI job can upload the perf trajectory per PR.

use auto_spmv::gen::{patterns, Rng, Zipf};
use auto_spmv::gpusim::{turing_gtx1650m, Objective};
use auto_spmv::obs::{SloConfig, SloSpec};
use auto_spmv::online::{Online, OnlineConfig, Trainer};
use auto_spmv::report::{bench, Table};
use auto_spmv::runtime::{default_artifacts_dir, Engine};
use auto_spmv::serve::{BackendSpec, Pool, PoolConfig, ScaleOutConfig};
use auto_spmv::sparse::convert::{self, ConvertParams};
use auto_spmv::sparse::{Coo, Format, SpMv};
use auto_spmv::testutil::toy_setup;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn pjrt_format_latency(dir: &std::path::Path) {
    let mut engine = match Engine::new(dir) {
        Ok(e) => e,
        Err(e) => {
            println!("SKIP per-format PJRT table: engine init failed: {e:#}");
            return;
        }
    };
    let mut rng = Rng::new(0xBE);
    let coo = patterns::banded(&mut rng, 1000, 16, 6.0);
    let csr = convert::coo_to_csr(&coo);
    let x: Vec<f32> = (0..csr.n_cols).map(|i| (i % 7) as f32 * 0.3).collect();

    let mut t = Table::new(
        "E2E — per-format PJRT SpMV latency (1000-row banded, warm cache)",
        &["format", "mean (us)", "min (us)", "native (us)"],
    );
    let params = ConvertParams { bell_bh: 8, bell_bw: 8, sell_h: 8 };
    let native = bench(3, 50, || {
        std::hint::black_box(csr.spmv_alloc(&x));
    });
    for fmt in Format::ALL {
        let m = convert::convert(&csr, fmt, params);
        // warm: compile + first run
        engine.spmv(&m, &x, None).expect("spmv");
        let timing = bench(2, 30, || {
            std::hint::black_box(engine.spmv(&m, &x, None).unwrap());
        });
        t.row(vec![
            fmt.to_string(),
            format!("{:.1}", timing.mean_s * 1e6),
            format!("{:.1}", timing.min_s * 1e6),
            format!("{:.1}", native.mean_s * 1e6),
        ]);
    }
    t.emit("e2e_serving_bench");
    println!("executions {}, cached executables {}", engine.exec_count, engine.cached());
}

/// Fire `n_requests` pipelined requests at a pool; returns req/s and
/// the pool's final stats (which also record the backend each shard
/// ACTUALLY built, so rows are never mislabeled after a PJRT->native
/// fallback).
fn drive(pool: &Pool, mats: &[(u64, usize)], n_requests: usize) -> (f64, auto_spmv::serve::PoolStats) {
    let burst = 16usize;
    let mut rng = Rng::new(0xD1);
    let t0 = Instant::now();
    let mut sent = 0usize;
    while sent < n_requests {
        let mut pending = Vec::with_capacity(burst);
        for _ in 0..burst.min(n_requests - sent) {
            let (id, n_cols) = mats[rng.below(mats.len())];
            let x = vec![0.5f32; n_cols];
            pending.push(pool.product_async(id, x).expect("submit"));
            sent += 1;
        }
        for rx in pending {
            rx.recv().expect("pool alive").expect("product ok");
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = pool.stats().expect("stats");
    (n_requests as f64 / wall, stats)
}

/// Bounded quick mode for CI (`--smoke` flag or AUTOSPMV_BENCH_SMOKE=1).
fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("AUTOSPMV_BENCH_SMOKE").is_ok_and(|v| v == "1")
}

fn main() {
    let smoke = smoke_mode();
    if smoke {
        println!("bench_e2e_serving: --smoke (bounded CI configuration)");
    }
    let dir = default_artifacts_dir();
    let have_artifacts = dir.join("manifest.tsv").exists();
    if have_artifacts && !smoke {
        pjrt_format_latency(&dir);
    } else if !have_artifacts {
        println!("no artifacts at {dir:?}: skipping the PJRT table, benching the native backend");
    }

    // --- throughput of the sharded pool (native or PJRT backend) --------
    let router = Arc::new(auto_spmv::testutil::toy_router(
        &["rim", "eu-2005", "shar_te2-b3"],
        Objective::EnergyEff,
    ));
    let backend = if have_artifacts {
        BackendSpec::Pjrt(dir.clone())
    } else {
        BackendSpec::Native
    };

    let mut rng = Rng::new(0xE2);
    let fleet: Vec<Coo> = vec![
        patterns::banded(&mut rng, 1000, 16, 6.0),
        patterns::uniform(&mut rng, 500, 500, 5.0),
        patterns::diagonals(&mut rng, 800, &[-8, 0, 8], 0.95),
    ];
    let n_requests = if smoke { 160usize } else { 480usize };
    let worker_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };

    let mut t = Table::new(
        &format!(
            "E2E — pool throughput ({} backend requested, {} requests, {} matrices)",
            backend.name(),
            n_requests,
            fleet.len()
        ),
        &["workers", "batching", "backend", "req/s", "dispatches", "max batch", "coalesced req %"],
    );
    for &workers in worker_counts {
        for batching in [false, true] {
            let pool = Pool::start(
                router.clone(),
                backend.clone(),
                PoolConfig {
                    workers,
                    // off: every request is its own dispatch; on: drain
                    // the queue + a short admission window
                    max_batch: if batching { 32 } else { 1 },
                    batch_window: if batching {
                        Duration::from_micros(200)
                    } else {
                        Duration::ZERO
                    },
                    ..PoolConfig::default()
                },
            );
            let mut mats = Vec::new();
            for (id, coo) in fleet.iter().enumerate() {
                pool.register(id as u64, coo.clone(), 100_000).expect("register");
                mats.push((id as u64, coo.n_cols));
            }
            let (rps, stats) = drive(&pool, &mats, n_requests);
            let share = if stats.requests == 0 {
                0.0
            } else {
                stats.batched_requests as f64 / stats.requests as f64
            };
            t.row(vec![
                workers.to_string(),
                if batching { "on".into() } else { "off".to_string() },
                stats.backend_summary(),
                format!("{rps:.0}"),
                stats.dispatches.to_string(),
                stats.max_batch.to_string(),
                format!("{:.0}", 100.0 * share),
            ]);
            if batching {
                assert!(
                    stats.dispatches < n_requests as u64,
                    "coalescing must serve multiple requests per SpMM dispatch \
                     ({} dispatches for {n_requests} requests)",
                    stats.dispatches
                );
            }
        }
    }
    t.emit("e2e_serving_throughput");
    t.emit_json("e2e_serving_throughput");

    batch_width_sweep(&backend, smoke);
    iterative_session_sweep(&backend, smoke);
    solver_chain();
    stage_decomposition();
    tracing_overhead(smoke);
    slo_breach_e2e();
    zipf_scaleout_sweep();
    adaptation_under_drift(smoke);
    println!("bench_e2e_serving OK");
}

/// Part 7 — Zipf scale-out sweep: 8 matrices served under a heavily
/// skewed popularity distribution (exact Zipf, alpha 3: rank 1 draws
/// ~84% of traffic), frozen hash partition vs the scale-out control
/// plane (hot-matrix replication + least-loaded routing). Every
/// response is checked bit-for-bit against a precomputed native
/// reference, so replica divergence fails the bench, and no request
/// may be dropped. The scale-out configuration runs TWICE and its
/// control-plane journal key sequence must replay verbatim; the
/// control ledger (requests/sheds/replications/replicas — exact
/// counts, mode-independent, never wall-clock) is gated by
/// `tools/bench_gate.py`. The >= 2x throughput assertion needs real
/// parallelism and only engages on >= 4 cores; the ratio is always
/// reported.
fn zipf_scaleout_sweep() {
    const WORKERS: usize = 3;
    const WARMUP: usize = 128;
    const TIMED: usize = 1600;
    const ROUNDS: usize = 3;
    const BURST: usize = 16;
    let router = Arc::new(auto_spmv::testutil::toy_router(&["rim"], Objective::EnergyEff));
    let mut rng = Rng::new(0x21F5);
    let fleet: Vec<Coo> =
        (0..8).map(|i| patterns::banded(&mut rng, 1200 + 200 * i, 32, 24.0)).collect();
    // one fixed input + native reference per matrix: the per-response
    // check is an equality over precomputed vectors, not new SpMV work
    let refs: Vec<(Arc<[f32]>, Vec<f32>)> = fleet
        .iter()
        .map(|coo| {
            let csr = convert::coo_to_csr(coo);
            let x: Arc<[f32]> =
                (0..csr.n_cols).map(|i| ((i * 7 + 3) % 11) as f32 * 0.25 - 1.0).collect();
            let y = csr.spmv_alloc(&x);
            (x, y)
        })
        .collect();
    let zipf = Zipf::new(fleet.len(), 3.0);

    // Serve the identical seeded request sequence through one pool:
    // a warmup segment (replication settles at the first control
    // window), then ROUNDS timed segments, best (min) wall per pool.
    let run = |scaleout: Option<ScaleOutConfig>| {
        let pool = Pool::start(
            router.clone(),
            BackendSpec::Native,
            PoolConfig { workers: WORKERS, scaleout, ..PoolConfig::default() },
        );
        for (id, coo) in fleet.iter().enumerate() {
            pool.register(id as u64, coo.clone(), 1_000_000).expect("register");
        }
        let mut draws = Rng::new(0x21AF);
        let mut serve = |n: usize| {
            let mut sent = 0usize;
            while sent < n {
                let burst = BURST.min(n - sent);
                let pending: Vec<_> = (0..burst)
                    .map(|_| {
                        let id = zipf.sample(&mut draws) - 1;
                        let rx =
                            pool.product_async(id as u64, refs[id].0.clone()).expect("submit");
                        (id, rx)
                    })
                    .collect();
                for (id, rx) in pending {
                    let resp = rx.recv().expect("pool alive").expect("product ok");
                    assert_eq!(resp.y, refs[id].1, "replica divergence on matrix {id}");
                }
                sent += burst;
            }
        };
        serve(WARMUP);
        let mut best = f64::INFINITY;
        for _ in 0..ROUNDS {
            let t0 = Instant::now();
            serve(TIMED);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        let stats = pool.stats().expect("stats");
        let keys: Vec<String> = pool.events().iter().map(|e| e.kind.key()).collect();
        (best, stats, keys)
    };

    let (base_a, base_stats, base_keys) = run(None);
    let (scale_a, s_stats, keys1) = run(Some(ScaleOutConfig::default()));
    let (base_b, _, _) = run(None);
    let (scale_b, s_stats2, keys2) = run(Some(ScaleOutConfig::default()));
    let total = (WARMUP + ROUNDS * TIMED) as u64;

    assert_eq!(base_stats.requests, total, "hash pool must serve every request");
    assert!(base_keys.is_empty(), "hash pool must journal no control events: {base_keys:?}");
    assert_eq!(keys1, keys2, "control decisions must replay identically run to run");
    // splitmix64 homes matrix 0 on shard 0 of 3; its ~84% share
    // crosses the replication threshold at the first window boundary
    assert_eq!(
        keys1,
        vec![
            "replicate matrix=0 shard=1 replicas=2 at=64".to_string(),
            "replicate matrix=0 shard=2 replicas=3 at=64".to_string(),
            "reroute matrix=0 owners=3 at=64".to_string(),
        ],
    );
    assert_eq!(s_stats.requests, total, "every admitted request must be served");
    assert_eq!(s_stats.sheds, 0, "no SLO configured: admission control stays disarmed");
    assert_eq!((s_stats.replications, s_stats.unreplications, s_stats.replicas), (2, 0, 2));
    assert_eq!(s_stats2.events_total, s_stats.events_total);

    let mut t = Table::new(
        "E2E — Zipf scale-out sweep: control-plane ledger (8 matrices, alpha 3, 3 workers)",
        &["metric", "value"],
    );
    for (metric, value) in [
        ("requests", s_stats.requests),
        ("sheds", s_stats.sheds),
        ("replications", s_stats.replications),
        ("unreplications", s_stats.unreplications),
        ("replicas", s_stats.replicas),
        ("control_events", s_stats.events_total),
    ] {
        t.row(vec![metric.to_string(), value.to_string()]);
    }
    t.emit("e2e_zipf_scaleout");
    t.emit_json("e2e_zipf_scaleout");

    let base_rps = TIMED as f64 / base_a.min(base_b);
    let scale_rps = TIMED as f64 / scale_a.min(scale_b);
    let ratio = scale_rps / base_rps;
    let mut t = Table::new(
        "E2E — Zipf scale-out sweep: throughput vs the frozen hash partition (wall-clock)",
        &["pool", "req/s", "speedup"],
    );
    t.row(vec!["hash".to_string(), format!("{base_rps:.0}"), "1.00".to_string()]);
    t.row(vec!["scale-out".to_string(), format!("{scale_rps:.0}"), format!("{ratio:.2}")]);
    t.emit("e2e_zipf_throughput");
    t.emit_json("e2e_zipf_throughput");

    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    if cores >= 4 {
        assert!(
            ratio >= 2.0,
            "scale-out must at least double Zipf throughput over the frozen hash \
             partition (hash {base_rps:.0} req/s, scale-out {scale_rps:.0} req/s, \
             {ratio:.2}x)"
        );
    } else {
        println!(
            "NOTE: {cores} cores < 4 — {ratio:.2}x speedup reported without the >=2x assertion"
        );
    }
}

/// Part 6 — deterministic SLO breach episode: a frozen single-worker
/// pool with a deadline-miss SLO serves three phases — clean,
/// all-missing (zero deadlines miss at any machine speed), clean again
/// — and the engine must alert exactly once, freeze the breach window
/// into the flight recorder, and recover after the hysteresis. The p99
/// target is set unreachably high so the breach is driven purely by
/// the request-counted miss budget; the whole run executes TWICE and
/// the journal key sequences must match verbatim. Per-arm attribution
/// rides along: every request lands on the one registered matrix's
/// joint arm, so the arm ledger must account for all 224 requests. The
/// counts are mode-independent and gated by `tools/bench_gate.py`.
fn slo_breach_e2e() {
    let run = || {
        let router = Arc::new(auto_spmv::testutil::toy_router(&["rim"], Objective::EnergyEff));
        let mut rng = Rng::new(0x510);
        let coo = patterns::banded(&mut rng, 1000, 16, 6.0);
        let n_cols = coo.n_cols;
        let pool = Pool::start(
            router,
            BackendSpec::Native,
            PoolConfig {
                workers: 1,
                slo: Some(SloConfig {
                    spec: SloSpec {
                        p99_target: Duration::from_secs(3600),
                        deadline_miss_budget: 0.25,
                    },
                    overrides: Vec::new(),
                    fast_window: 32,
                    recovery_evals: 2,
                    flight_cap: 32,
                }),
                ..PoolConfig::default()
            },
        );
        pool.register(1, coo, 1_000_000).expect("register");
        let x = vec![0.5f32; n_cols];
        let hour = Duration::from_secs(3600);
        for _ in 0..64 {
            pool.product_with_deadline(1, x.clone(), hour).expect("product");
        }
        for _ in 0..64 {
            pool.product_with_deadline(1, x.clone(), Duration::ZERO).expect("product");
        }
        for _ in 0..96 {
            pool.product_with_deadline(1, x.clone(), hour).expect("product");
        }
        let stats = pool.stats().expect("stats");
        let keys: Vec<String> = pool.events().iter().map(|e| e.kind.key()).collect();
        let flight = pool.flight_records();
        (stats, keys, flight)
    };

    let (stats, keys, flight) = run();
    let (_, keys2, _) = run();
    assert_eq!(keys, keys2, "the SLO episode must replay identically run to run");
    assert_eq!(
        keys,
        vec![
            "slo_alert scope=pool at=96 signal=miss_budget missed=32/32".to_string(),
            "slo_recovered scope=pool at=192".to_string(),
        ],
    );
    let slo = stats.slo.as_ref().expect("slo snapshot");
    assert_eq!((slo.alerts, slo.recoveries, slo.evals), (1, 1, 7));
    assert_eq!(slo.status.name(), "ok", "the episode must end recovered");
    assert_eq!(flight.len(), 32, "the breach capture must hold the full ring");
    assert!(flight.iter().all(|r| r.deadline_missed), "the captured window IS the breach");
    let arm_requests: u64 = stats.arm_profiles.iter().map(|p| p.requests).sum();
    assert_eq!(arm_requests, 224, "arm attribution must account for every request");

    let mut t = Table::new(
        "E2E — deterministic SLO breach episode (miss-budget driven, 1 worker, native)",
        &["metric", "value"],
    );
    for (metric, value) in [
        ("slo_alerts", slo.alerts),
        ("slo_recoveries", slo.recoveries),
        ("slo_evals", slo.evals),
        ("flight_records", flight.len() as u64),
        ("deadline_tagged", stats.deadline_tagged),
        ("deadline_misses", stats.deadline_misses),
        ("arm_requests", arm_requests),
    ] {
        t.row(vec![metric.to_string(), value.to_string()]);
    }
    t.emit("e2e_slo_breach");
    t.emit_json("e2e_slo_breach");
}

/// Part 2c — iterative-session sweep: a chained solver (each product's
/// y is the next x) served per-request vs through a device-resident
/// [`auto_spmv::serve::Session`], at growing chain lengths. Launches
/// per request stay EQUAL — the session saves marshalling, not kernel
/// work — so the column to watch is marshalled bytes per iteration:
/// 8n/iter on the per-request path vs 8n total (one write + one read)
/// across the whole session chain.
fn iterative_session_sweep(backend: &BackendSpec, smoke: bool) {
    let router = Arc::new(auto_spmv::testutil::toy_router(&["rim"], Objective::EnergyEff));
    let mut rng = Rng::new(0x5E55);
    let coo = patterns::banded(&mut rng, 1000, 16, 6.0);
    let n = coo.n_cols;
    let x0: Vec<f32> = (0..n).map(|i| (i % 7) as f32 * 0.3 - 0.9).collect();
    let native = matches!(backend, BackendSpec::Native);

    let mut t = Table::new(
        "E2E — iterative-session sweep: per-request vs device-resident session (1 worker)",
        &["chain k", "path", "req/s", "launches/req", "B/iter", "RT elided", "bytes ratio"],
    );
    let chains: &[usize] = if smoke { &[16, 64] } else { &[16, 64, 256] };
    for &k in chains {
        // per-request: every iteration submits x and marshals y back out
        let pool =
            Pool::start(router.clone(), backend.clone(), PoolConfig { workers: 1, ..PoolConfig::default() });
        pool.register(1, coo.clone(), 1_000_000).expect("register");
        let t0 = Instant::now();
        let mut x = x0.clone();
        for _ in 0..k {
            x = pool.product(1, x).expect("product").y;
        }
        let wall_req = t0.elapsed().as_secs_f64();
        let s_req = pool.stats().expect("stats");
        assert_eq!(s_req.launches, k as u64, "sequential products pay one launch each");
        let req_b_per_iter = s_req.marshalled_bytes as f64 / k as f64;
        t.row(vec![
            k.to_string(),
            "per-request".to_string(),
            format!("{:.0}", k as f64 / wall_req),
            format!("{:.2}", s_req.launches_per_request()),
            format!("{req_b_per_iter:.0}"),
            "0".to_string(),
            "1.0".to_string(),
        ]);

        // session: one write in, k chained steps, one read out
        let pool =
            Pool::start(router.clone(), backend.clone(), PoolConfig { workers: 1, ..PoolConfig::default() });
        pool.register(1, coo.clone(), 1_000_000).expect("register");
        let session = pool.open_session(1).expect("open_session");
        let t0 = Instant::now();
        session.write(x0.clone()).expect("write");
        session.step_n(k as u64).expect("step_n");
        let y = session.read().expect("read");
        let wall_sess = t0.elapsed().as_secs_f64();
        let s_sess = pool.stats().expect("stats");
        assert_eq!(s_sess.requests, k as u64, "each session step counts as a request");
        assert_eq!(
            s_sess.launches, k as u64,
            "equal launches/request: the session elides marshalling, not kernels"
        );
        if native {
            assert_eq!(y, x, "session chain must be bit-identical to the per-request chain");
        }
        let sess_b_per_iter = s_sess.marshalled_bytes as f64 / k as f64;
        let ratio = req_b_per_iter / sess_b_per_iter.max(f64::MIN_POSITIVE);
        t.row(vec![
            k.to_string(),
            "session".to_string(),
            format!("{:.0}", k as f64 / wall_sess),
            format!("{:.2}", s_sess.launches_per_request()),
            format!("{sess_b_per_iter:.0}"),
            s_sess.round_trips_elided.to_string(),
            format!("{ratio:.1}"),
        ]);
        if s_sess.round_trips_elided == k as u64 {
            // the PR 6 acceptance criterion: >= 90% of marshalled bytes
            // per iteration elided at equal launches/request
            assert!(
                ratio >= 10.0,
                "k={k}: session path must elide >= 90% of marshalled bytes/iteration \
                 ({req_b_per_iter:.0} B/iter per-request vs {sess_b_per_iter:.0} B/iter session)"
            );
        } else {
            // no silent caps: a non-square artifact bucket bounces the
            // chain through the host, and the ledger says so
            println!(
                "NOTE k={k}: only {}/{k} steps chained device-side (artifact bucket \
                 bounce) — bytes ratio {ratio:.1} reported without the >=10x assertion",
                s_sess.round_trips_elided
            );
        }
    }
    t.emit("e2e_iterative_session");
    t.emit_json("e2e_iterative_session");
}

/// SPD, diagonally dominant banded matrix (symmetric random
/// off-diagonals under a strictly dominant diagonal) — the system the
/// solver-chain part runs CG with SymGS smoothing on.
fn spd_system(n: usize, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    let mut offs: Vec<(usize, usize, f32)> = Vec::new();
    for i in 0..n {
        for d in 1..=3usize {
            if i + d < n && rng.f64() < 0.7 {
                offs.push((i, i + d, -(rng.f64() as f32) * 0.4));
            }
        }
    }
    let mut diag = vec![1.0f32; n];
    for &(i, j, v) in &offs {
        coo.push(i, j, v);
        coo.push(j, i, v);
        diag[i] += v.abs() + 0.1;
        diag[j] += v.abs() + 0.1;
    }
    for (i, d) in diag.into_iter().enumerate() {
        coo.push(i, i, d);
    }
    coo
}

/// Part 2d — solver chain: all three kernel classes served through one
/// pool. Direct SpMV / SpTRSV(lower, upper) / SymGS requests ride the
/// request path (each solve checked bit-for-bit against the native
/// sweep), then a SymGS-preconditioned CG loop runs through a single
/// device-resident session — each iteration one chained A·p product
/// step plus one z = M⁻¹ r solve step, a fixed iteration count so the
/// ledger never depends on a convergence test. The whole ledger is
/// deterministic: sequential native dispatch pays exactly one launch
/// per request, so the per-kind request/launch attribution, the
/// solve_exec / session_step stage counts, and the session-step tally
/// are exact counts gated by `tools/bench_gate.py` (mode-independent —
/// the chain is small enough to run identically under --smoke).
fn solver_chain() {
    const DIRECT: usize = 12; // requests per kind-variant bundle
    const PCG_ITERS: usize = 16;
    let router = Arc::new(auto_spmv::testutil::toy_router(&["rim"], Objective::EnergyEff));
    let n = 200usize;
    let coo = spd_system(n, 0x501C);
    let csr = convert::coo_to_csr(&coo);
    let pool = Pool::start(
        router,
        BackendSpec::Native,
        PoolConfig { workers: 1, ..PoolConfig::default() },
    );
    pool.register(1, coo, 1_000_000).expect("register");

    // direct requests: every response checked against the native
    // reference, so a format conversion that breaks solve bit-identity
    // fails the bench, not a downstream consumer
    for r in 0..DIRECT {
        let b: Vec<f32> = (0..n).map(|i| ((i * 5 + r) % 13) as f32 * 0.25 - 1.5).collect();
        assert_eq!(pool.product(1, b.clone()).expect("product").y, csr.spmv_alloc(&b));
        assert_eq!(
            pool.sptrsv(1, b.clone(), true).expect("sptrsv").y,
            csr.sptrsv(&b, true).expect("native sptrsv"),
            "lower solve must match the native sweep bit-for-bit"
        );
        assert_eq!(
            pool.sptrsv(1, b.clone(), false).expect("sptrsv").y,
            csr.sptrsv(&b, false).expect("native sptrsv")
        );
        let mut want = vec![0.0f32; n];
        csr.symgs_sweep(&b, &mut want).expect("native symgs");
        assert_eq!(pool.symgs(1, b).expect("symgs").y, want);
    }

    // SymGS-preconditioned CG through one session: write/step/read per
    // operator application (CG updates p host-side every iteration)
    let b: Vec<f32> = (0..n).map(|i| ((i % 11) as f32 - 5.0) * 0.3).collect();
    let session = pool.open_session(1).expect("open_session");
    let apply = |v: &[f32], op: &dyn Fn() -> anyhow::Result<()>| -> Vec<f32> {
        session.write(v.to_vec()).expect("session write");
        op().expect("session step");
        session.read().expect("session read")
    };
    let mut x = vec![0.0f32; n];
    let mut r = b.clone();
    let mut z = apply(&r, &|| session.symgs_step());
    let mut p = z.clone();
    let mut rz_old: f32 = r.iter().zip(&z).map(|(a, b)| a * b).sum();
    for _ in 0..PCG_ITERS {
        let ap = apply(&p, &|| session.step());
        let pap: f32 = p.iter().zip(&ap).map(|(a, b)| a * b).sum();
        let alpha = rz_old / pap;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        z = apply(&r, &|| session.symgs_step());
        let rz_new: f32 = r.iter().zip(&z).map(|(a, b)| a * b).sum();
        let beta = rz_new / rz_old;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
        rz_old = rz_new;
    }
    let ax = csr.spmv_alloc(&x);
    let rel = ax.iter().zip(&b).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt()
        / b.iter().map(|v| v * v).sum::<f32>().sqrt();
    assert!(
        rel < 1e-2,
        "{PCG_ITERS} SymGS-PCG iterations must cut the relative residual below 1e-2 \
         on a diagonally dominant SPD system (got {rel:.2e})"
    );
    drop(session);

    let stats = pool.stats().expect("stats");
    let total = (4 * DIRECT + 2 * PCG_ITERS + 1) as u64;
    assert_eq!(stats.requests, total, "every direct request and session step is a request");
    assert_eq!(stats.launches, total, "sequential native dispatch: one launch per request");
    assert_eq!(stats.session_steps, (2 * PCG_ITERS + 1) as u64);
    let kind_requests = |kind: &str| -> u64 {
        stats.arm_profiles.iter().filter(|p| p.kind == kind).map(|p| p.requests).sum()
    };
    let (spmv_req, tri_req, gs_req) =
        (kind_requests("spmv"), kind_requests("sptrsv"), kind_requests("symgs"));
    assert_eq!(
        (spmv_req, tri_req, gs_req),
        ((DIRECT + PCG_ITERS) as u64, (2 * DIRECT) as u64, (DIRECT + PCG_ITERS + 1) as u64),
        "per-kind arm attribution must account for every request exactly"
    );
    let count_of = |name: &str| {
        stats.stage_stats.iter().find(|s| s.stage.name() == name).map_or(0, |s| s.hist.count)
    };
    assert_eq!(count_of("solve_exec"), (3 * DIRECT) as u64, "direct solves land in solve_exec");
    assert_eq!(count_of("session_step"), stats.session_steps);

    let mut t = Table::new(
        "E2E — solver chain: SymGS-preconditioned CG via one session + direct solve \
         requests (1 worker, native)",
        &["metric", "value"],
    );
    for (metric, value) in [
        ("requests", stats.requests),
        ("launches", stats.launches),
        ("session_steps", stats.session_steps),
        ("spmv_requests", spmv_req),
        ("sptrsv_requests", tri_req),
        ("symgs_requests", gs_req),
        ("solve_exec_stage", count_of("solve_exec")),
        ("session_step_stage", count_of("session_step")),
        // byte ledger: reported for the trajectory, not baseline-gated
        ("marshalled_bytes", stats.marshalled_bytes),
        ("elided_bytes", stats.elided_bytes),
    ] {
        t.row(vec![metric.to_string(), value.to_string()]);
    }
    t.emit("e2e_solver_chain");
    t.emit_json("e2e_solver_chain");
}

/// Part 4 — stage decomposition: a fixed sequential workload (96
/// products + one 32-step session, 1 worker, native backend) whose
/// stage ledger is fully deterministic: every trace must sum exactly
/// to its response's service time, the pool-wide stage histograms must
/// partition total service time exactly (coverage 100%), and the
/// per-stage counts are pinned against the committed baseline by
/// `tools/bench_gate.py`. The counts are mode-independent — the ledger
/// is cheap — so the smoke-written baseline holds for full runs too.
fn stage_decomposition() {
    let router = Arc::new(auto_spmv::testutil::toy_router(&["rim"], Objective::EnergyEff));
    let mut rng = Rng::new(0x57A6E);
    let coo = patterns::banded(&mut rng, 1000, 16, 6.0);
    let n = coo.n_cols;
    const PRODUCTS: usize = 96;
    const STEPS: u64 = 32;

    let pool = Pool::start(
        router,
        BackendSpec::Native,
        PoolConfig { workers: 1, ..PoolConfig::default() },
    );
    pool.register(1, coo, 1_000_000).expect("register");
    for r in 0..PRODUCTS {
        let x: Vec<f32> = (0..n).map(|i| ((i * 3 + r) % 7) as f32 * 0.5).collect();
        let resp = pool.product(1, x).expect("product");
        let trace = resp.trace.expect("tracing is on by default");
        assert_eq!(
            trace.total(),
            resp.service_time,
            "per-request stages must sum exactly to the end-to-end service time"
        );
    }
    let session = pool.open_session(1).expect("open_session");
    session.write(vec![0.5f32; n]).expect("write");
    session.step_n(STEPS).expect("step_n");
    drop(session);

    let stats = pool.stats().expect("stats");
    assert_eq!(stats.requests, PRODUCTS as u64 + STEPS);
    assert_eq!(
        stats.stage_total(),
        stats.total_service(),
        "stage histograms must partition total service time exactly"
    );
    let coverage = stats.stage_coverage();
    assert!((coverage - 1.0).abs() < 1e-9, "stage coverage must be 1.0, got {coverage}");
    let count_of = |name: &str| {
        stats.stage_stats.iter().find(|s| s.stage.name() == name).map_or(0, |s| s.hist.count)
    };
    // native sequential products ride the one-matrix-walk SpMM path
    assert_eq!(count_of("spmm_exec"), PRODUCTS as u64);
    assert_eq!(count_of("exec"), 0);
    assert_eq!(count_of("session_step"), STEPS);
    assert_eq!(count_of("queue_wait"), PRODUCTS as u64);

    let total_ns = stats.total_service().as_nanos() as f64;
    let mut t = Table::new(
        "E2E — stage decomposition: where request latency goes (1 worker, native, tracing on)",
        &["stage", "count", "mean (us)", "p99 (us)", "share %", "coverage %"],
    );
    for s in &stats.stage_stats {
        t.row(vec![
            s.stage.to_string(),
            s.hist.count.to_string(),
            format!("{:.1}", s.hist.mean_us()),
            s.hist.tail_quantile_us(0.99).map_or("-".to_string(), |q| format!("{q:.1}")),
            format!("{:.1}", 100.0 * s.hist.sum_ns as f64 / total_ns),
            // only the `all` row carries the gated coverage — per-stage
            // shares are wall-clock-shaped and must not enter the gate
            "-".to_string(),
        ]);
    }
    t.row(vec![
        "all".to_string(),
        stats.requests.to_string(),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
        format!("{:.6}", 100.0 * coverage),
    ]);
    t.emit("e2e_stage_decomposition");
    t.emit_json("e2e_stage_decomposition");
}

/// Part 5 — tracing overhead: identical sequential workloads through a
/// pool with stage tracing off vs on, interleaved over 5 rounds so
/// machine-load drift hits both arms alike, best (min) wall time per
/// arm. The instrumented hot path adds only duration arithmetic and
/// relaxed atomic adds, so it must stay within 3% — asserted here but
/// never baseline-gated (wall-clock flakes on loaded runners).
fn tracing_overhead(smoke: bool) {
    let router = Arc::new(auto_spmv::testutil::toy_router(&["rim"], Objective::EnergyEff));
    let mut rng = Rng::new(0x0B4D);
    let coo = patterns::banded(&mut rng, 1000, 16, 6.0);
    let n_cols = coo.n_cols;
    let n_requests = if smoke { 1024usize } else { 4096 };
    const ROUNDS: usize = 5;

    let run = |tracing: bool| -> f64 {
        let pool = Pool::start(
            router.clone(),
            BackendSpec::Native,
            PoolConfig { workers: 1, tracing, ..PoolConfig::default() },
        );
        pool.register(1, coo.clone(), 1_000_000).expect("register");
        let x = vec![0.5f32; n_cols];
        for _ in 0..32 {
            pool.product(1, x.clone()).expect("warmup product");
        }
        let t0 = Instant::now();
        for _ in 0..n_requests {
            pool.product(1, x.clone()).expect("product");
        }
        t0.elapsed().as_secs_f64()
    };

    let mut best = [f64::INFINITY; 2];
    for _ in 0..ROUNDS {
        best[0] = best[0].min(run(false));
        best[1] = best[1].min(run(true));
    }
    let overhead = best[1] / best[0] - 1.0;
    let mut t = Table::new(
        "E2E — stage-tracing overhead: sequential native products, best of 5 interleaved runs",
        &["tracing", "best ns/req", "overhead %"],
    );
    t.row(vec![
        "off".to_string(),
        format!("{:.0}", best[0] * 1e9 / n_requests as f64),
        "-".to_string(),
    ]);
    t.row(vec![
        "on".to_string(),
        format!("{:.0}", best[1] * 1e9 / n_requests as f64),
        format!("{:.2}", 100.0 * overhead),
    ]);
    // emit before asserting so a failure still leaves the evidence
    t.emit("e2e_tracing_overhead");
    t.emit_json("e2e_tracing_overhead");
    assert!(
        overhead < 0.03,
        "stage tracing must cost < 3% end to end (best-of-{ROUNDS}: \
         off {:.3} ms, on {:.3} ms, overhead {:.2}%)",
        best[0] * 1e3,
        best[1] * 1e3,
        100.0 * overhead
    );
}

/// Part 2b — batch-width sweep: the same burst workload dispatched
/// per-vector (max_batch 1: every request pays its own launch) vs
/// through the SpMM batch path, at growing burst widths. The columns to
/// watch are launches/request (1.00 per-vector; 1/k when coalescing
/// captures the burst) and the throughput ratio.
fn batch_width_sweep(backend: &BackendSpec, smoke: bool) {
    let router = Arc::new(auto_spmv::testutil::toy_router(&["rim"], Objective::EnergyEff));
    let mut rng = Rng::new(0xBA7C4);
    let coo = patterns::banded(&mut rng, 1000, 16, 6.0);
    let n_cols = coo.n_cols;

    let mut t = Table::new(
        "E2E — batch-width sweep: per-vector vs SpMM dispatch (1 worker)",
        &["burst k", "dispatch", "req/s", "dispatches", "launches", "launches/req"],
    );
    let widths: &[usize] = if smoke { &[1, 4, 8] } else { &[1, 2, 4, 8, 16] };
    for &k in widths {
        for spmm in [false, true] {
            let pool = Pool::start(
                router.clone(),
                backend.clone(),
                PoolConfig {
                    workers: 1,
                    max_batch: if spmm { k } else { 1 },
                    // generous window: the whole burst is in flight, so
                    // collection ends at max_batch, not the deadline
                    batch_window: if spmm && k > 1 {
                        Duration::from_millis(20)
                    } else {
                        Duration::ZERO
                    },
                    ..PoolConfig::default()
                },
            );
            pool.register(1, coo.clone(), 100_000).expect("register");
            let n_requests = 32 * k;
            let t0 = Instant::now();
            for _ in 0..32 {
                // one burst of k pipelined requests
                let pending: Vec<_> = (0..k)
                    .map(|r| {
                        let x: Vec<f32> =
                            (0..n_cols).map(|i| ((i * 3 + r) % 7) as f32 * 0.5).collect();
                        pool.product_async(1, x).expect("submit")
                    })
                    .collect();
                for rx in pending {
                    rx.recv().expect("pool alive").expect("product ok");
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let stats = pool.stats().expect("stats");
            assert_eq!(stats.requests, n_requests as u64);
            t.row(vec![
                k.to_string(),
                if spmm { "spmm".into() } else { "per-vector".to_string() },
                format!("{:.0}", n_requests as f64 / wall),
                stats.dispatches.to_string(),
                stats.launches.to_string(),
                format!("{:.2}", stats.launches_per_request()),
            ]);
            if !spmm {
                assert_eq!(
                    stats.launches, stats.requests,
                    "per-vector dispatch pays one launch per request"
                );
            }
            if spmm && k >= 4 {
                // the acceptance criterion: coalescing + SpMM dispatch
                // drives launches-per-request below 1
                assert!(
                    stats.launches < stats.requests,
                    "k={k}: SpMM dispatch must amortize launches \
                     ({} launches / {} requests)",
                    stats.launches,
                    stats.requests
                );
                assert!(stats.launches_per_request() < 1.0);
            }
        }
    }
    t.emit("e2e_batch_width_sweep");
    t.emit_json("e2e_batch_width_sweep");
}

/// Serve `n` requests strictly sequentially (one dispatch per request,
/// round-robin over the fleet): unlike [`drive`], the dispatch
/// structure — and therefore the bandit's one-draw-per-dispatch RNG
/// schedule and every observation's weight — does not depend on
/// wall-clock coalescing, so the adaptation trajectory is identical on
/// a loaded CI runner. Returns total modeled energy delta per request.
fn serve_sequential(pool: &Pool, mats: &[(u64, usize)], n: usize) -> f64 {
    let before = pool.stats().expect("stats").total_energy_j;
    for r in 0..n {
        let (id, n_cols) = mats[r % mats.len()];
        let x = vec![0.5f32; n_cols];
        pool.product(id, x).expect("product ok");
    }
    (pool.stats().expect("stats").total_energy_j - before) / n as f64
}

/// Part 3 — closed-loop adaptation: the same drifted fleet served by a
/// frozen router vs the joint (format, knob) online loop (explore 20%,
/// retrain every 64 requests, deterministic seed, single worker, and
/// strictly SEQUENTIAL requests so the whole trajectory is
/// reproducible). After the adaptation run, exploration is annealed to
/// zero and both pools serve an identical measurement workload:
/// convergence is ASSERTED as the adaptive pool's incremental modeled
/// energy per request not exceeding the frozen pool's.
fn adaptation_under_drift(smoke: bool) {
    let objective = Objective::Energy;
    // Bias the offline view: train on power-law web graphs only, then
    // serve banded/stencil matrices (the drifted population).
    let (router, ds, overhead) = toy_setup(&["eu-2005", "wiki-talk-temporal"], objective);
    let router = Arc::new(router);
    let mut rng = Rng::new(0xD21F7);
    let fleet: Vec<Coo> = vec![
        patterns::diagonals(&mut rng, 1000, &[-24, 0, 24, -48, 48], 0.98),
        patterns::banded(&mut rng, 800, 12, 6.0),
    ];
    let n_requests = if smoke { 256usize } else { 512usize };
    let measure = if smoke { 48usize } else { 96usize };
    let cfg = PoolConfig { workers: 1, ..PoolConfig::default() };

    let frozen = Pool::start(router.clone(), BackendSpec::Native, cfg.clone());
    let online = Online::start(
        OnlineConfig {
            explore_rate: 0.2,
            retrain_every: 64,
            seed: 0xD21F7,
            ..OnlineConfig::default() // joint_knobs defaults ON
        },
        router.clone(),
        objective,
        Some(Trainer::new(ds.clone(), objective, overhead.clone(), turing_gtx1650m().name)),
    );
    // the adaptive pool also carries a deliberately lax SLO
    // (unreachable targets, nothing ever alerts) so the METRICS.prom
    // dump below exercises the spmv_slo_* families for the CI lint
    let adaptive = Pool::start_adaptive(
        online.clone(),
        BackendSpec::Native,
        PoolConfig {
            slo: Some(SloConfig::new(SloSpec {
                p99_target: Duration::from_secs(3600),
                deadline_miss_budget: 1.0,
            })),
            ..cfg
        },
    );

    let mut t = Table::new(
        "E2E — closed-loop adaptation under drift (modeled energy objective)",
        &[
            "pool", "router", "retrains", "fmt migr", "knob migr", "explored",
            "mean energy/req (J)",
        ],
    );
    let mut mats = Vec::new();
    for (id, coo) in fleet.iter().enumerate() {
        frozen.register(id as u64, coo.clone(), 1_000_000_000).expect("register");
        adaptive.register(id as u64, coo.clone(), 1_000_000_000).expect("register");
        mats.push((id as u64, coo.n_cols));
    }
    for (label, pool) in [("frozen", &frozen), ("adaptive", &adaptive)] {
        serve_sequential(pool, &mats, n_requests);
        let stats = pool.stats().expect("stats");
        assert_eq!(stats.requests, n_requests as u64, "no request may be dropped");
        t.row(vec![
            label.to_string(),
            format!("v{}", stats.router_version),
            stats.retrains.to_string(),
            stats.migrations.to_string(),
            stats.knob_migrations.to_string(),
            stats.explored_requests.to_string(),
            format!("{:.3e}", stats.total_energy_j / stats.requests as f64),
        ]);
        if label == "adaptive" {
            assert!(stats.router_version > 1, "retraining must hot-swap at this cadence");
            assert!(stats.explored_requests > 0, "exploration must route some traffic");
        }
    }

    // Convergence assertion: steady-state (explore 0) energy per
    // request, identical sequential workload on both pools.
    online.set_explore_rate(0.0);
    let f_mean = serve_sequential(&frozen, &mats, measure);
    let a_mean = serve_sequential(&adaptive, &mats, measure);
    t.row(vec![
        "steady-state".to_string(),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
        "0".to_string(),
        format!("frozen {f_mean:.3e} / adaptive {a_mean:.3e}"),
    ]);
    assert!(
        a_mean <= f_mean * 1.001,
        "the drift-adaptation loop must converge: adaptive steady-state energy \
         {a_mean:.3e} J/req exceeds frozen {f_mean:.3e} J/req"
    );
    t.emit("e2e_adaptation");
    t.emit_json("e2e_adaptation");

    // Observability artifacts: the adaptive pool has lived through
    // retrains, hot-swaps, and migrations, so its Prometheus
    // exposition and control-plane journal are the richest dump this
    // bench produces. The CI bench-smoke job lints the exposition with
    // `tools/metrics_lint.py` and uploads both files.
    let metrics = adaptive.metrics_text().expect("metrics_text");
    assert!(metrics.contains("# TYPE spmv_requests_total counter"));
    assert!(metrics.contains("# TYPE spmv_slo_status gauge"));
    assert!(metrics.contains("# TYPE spmv_arm_requests_total counter"));
    let events = adaptive.events_json();
    assert!(
        events.contains("\"kind\":\"hot_swap\"") && events.contains("\"kind\":\"retrain\""),
        "the drift run must have journaled its retrain -> hot-swap chain"
    );
    let dir = std::path::Path::new("reports");
    if std::fs::create_dir_all(dir).is_ok() {
        std::fs::write(dir.join("METRICS.prom"), &metrics).expect("write METRICS.prom");
        std::fs::write(dir.join("EVENTS.json"), &events).expect("write EVENTS.json");
        println!(
            "wrote reports/METRICS.prom ({} B) and reports/EVENTS.json ({} events)",
            metrics.len(),
            adaptive.events().len()
        );
    }
}
