//! Shared support for the paper-reproduction benches: builds the full
//! 30-matrix x 2-GPU dataset once and caches it as TSV under reports/
//! so each bench binary (a separate process) reuses it.

use auto_spmv::dataset::{self, store, BuildOptions, Dataset};
use std::path::Path;

pub const DATASET_CACHE: &str = "reports/dataset_full.tsv";

/// Full-corpus dataset, cached across bench processes.
pub fn full_dataset() -> Dataset {
    let path = Path::new(DATASET_CACHE);
    if path.exists() {
        if let Ok(ds) = store::load(path) {
            if !ds.is_empty() {
                return ds;
            }
        }
    }
    let ds = dataset::build(&BuildOptions::default());
    std::fs::create_dir_all("reports").ok();
    store::save(&ds, path).ok();
    ds
}

/// Pretty percentage.
pub fn pct(v: f64) -> String {
    format!("{v:.1}%")
}

#[allow(dead_code)]
fn main() {} // never used; this file is included via #[path]
