//! Fig. 4 — per-knob ablation on `eu-2005`: improvement over the default
//! configuration when optimizing each configuration parameter ALONE
//! (TB size / maxrregcount / memory config with everything else at the
//! default; sparse format with default compile params).

#[path = "common.rs"]
mod common;

use auto_spmv::gpusim::{KernelConfig, MemConfig, Objective, MAXRREGCOUNT, TB_SIZES};
use auto_spmv::report::Table;
use auto_spmv::sparse::Format;

fn main() {
    let ds = common::full_dataset();
    for arch in ["GTX1650m-Turing", "GTX1080-Pascal"] {
        run_arch(&ds, arch);
    }
    println!("paper shape: every knob contributes; compile knobs matter, not just format");
    println!("note: maxrregcount is inert on Turing by construction (64K regs / 1024");
    println!("threads = 64 regs/thread at full occupancy) and binds on Pascal (2048 threads).");
}

fn run_arch(ds: &auto_spmv::dataset::Dataset, arch: &str) {
    let slice = ds.slice("eu-2005", arch);
    let value = |cfg: &KernelConfig, obj: Objective| -> f64 {
        obj.value(&slice.iter().find(|r| r.config == *cfg).expect("cfg in sweep").m)
    };
    let default = KernelConfig::default_baseline();

    let mut t = Table::new(
        &format!("Fig. 4 — eu-2005 on {arch}: improvement from each knob alone (%)"),
        &["knob", "latency", "energy", "avg_power", "energy_eff"],
    );

    type Sweep = Box<dyn Fn(&mut KernelConfig, usize)>;
    let knobs: Vec<(&str, usize, Sweep)> = vec![
        ("TB size", TB_SIZES.len(), Box::new(|c, i| c.tb_size = TB_SIZES[i])),
        ("maxrregcount", MAXRREGCOUNT.len(), Box::new(|c, i| c.maxrregcount = MAXRREGCOUNT[i])),
        ("memory config", MemConfig::ALL.len(), Box::new(|c, i| c.mem = MemConfig::ALL[i])),
        ("sparse format", Format::ALL.len(), Box::new(|c, i| c.format = Format::ALL[i])),
    ];

    for (name, n, set) in &knobs {
        let mut cells = vec![name.to_string()];
        for obj in Objective::ALL {
            let base = value(&default, obj);
            let mut best = base;
            for i in 0..*n {
                let mut cfg = default;
                set(&mut cfg, i);
                let v = value(&cfg, obj);
                if obj.better(v, best) {
                    best = v;
                }
            }
            let imp = if obj.minimize() {
                (base - best) / base * 100.0
            } else {
                (best - base) / base * 100.0
            };
            cells.push(common::pct(imp));
        }
        t.row(cells);
    }
    t.emit(&format!("fig4_ablation_{arch}"));
}
