//! Fig. 3 — Auto-SpMV vs the default configuration (CSR + default
//! compile parameters) on the `consph` matrix, all four objectives,
//! normalized to Auto-SpMV (higher is better for the default bar being
//! below 1.0).

#[path = "common.rs"]
mod common;

use auto_spmv::dataset::labels;
use auto_spmv::gpusim::Objective;
use auto_spmv::report::{fmt_g, Table};

fn main() {
    let ds = common::full_dataset();
    let mut t = Table::new(
        "Fig. 3 — consph: default config vs Auto-SpMV (normalized to Auto-SpMV)",
        &["objective", "auto_spmv", "default", "default/auto (norm)", "auto gain"],
    );
    for obj in Objective::ALL {
        let ex = labels::examples(&ds, obj);
        let e = ex
            .iter()
            .find(|e| e.matrix == "consph" && e.arch.contains("Turing"))
            .expect("consph present");
        // Auto-SpMV tunes BOTH format and compile params: take the best of
        // compile-tuned CSR and the best format (the paper's full pipeline)
        let auto = if obj.better(e.best_format_value, e.best_compile) {
            e.best_format_value
        } else {
            e.best_compile
        };
        let norm = if obj.minimize() { auto / e.default_value } else { e.default_value / auto };
        let gain = if obj.minimize() {
            e.default_value / auto
        } else {
            auto / e.default_value
        };
        t.row(vec![
            obj.name().into(),
            fmt_g(auto),
            fmt_g(e.default_value),
            format!("{norm:.3}"),
            format!("{gain:.2}x"),
        ]);
    }
    t.emit("fig3_motivation");
    println!("paper shape: default normalized bars < 1.0 on every objective");
}
