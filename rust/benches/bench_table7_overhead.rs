//! Table 7 — optimization overhead of the run-time mode per corpus
//! matrix (ascending nnz): measured f_latency (feature extraction) and
//! c_latency (conversion to the predicted format), plus the ~constant
//! o+p latency of model inference (§7.5).
//!
//! Absolute numbers are CPU- and scale-dependent (the paper measures
//! paper-scale matrices on their Python/NumPy pipeline; we measure the
//! Rust pipeline at corpus scale — pass --full-scale via
//! AUTO_SPMV_SCALE=8 to approach paper sizes); the SHAPE to match is
//! overhead growing ~linearly with nnz and dominated by f+c.

#[path = "common.rs"]
mod common;

use auto_spmv::coordinator::overhead::{measure_overhead, OverheadModel};
use auto_spmv::gen;
use auto_spmv::report::{fmt_g, Table};
use auto_spmv::sparse::Format;

fn main() {
    let scale: usize = std::env::var("AUTO_SPMV_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let mut rows: Vec<(String, f64, f64, f64)> = gen::corpus()
        .iter()
        .map(|e| {
            let s = measure_overhead(e, scale, Format::Ell);
            (e.name.to_string(), s.nnz, s.f_latency_s, s.c_latency_s)
        })
        .collect();
    rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

    let mut t = Table::new(
        &format!("Table 7 — run-time optimization overhead (scale {scale}, seconds)"),
        &["matrix", "nnz", "f_latency", "c_latency", "f+c"],
    );
    for (name, nnz, f, c) in &rows {
        t.row(vec![
            name.clone(),
            format!("{}", *nnz as u64),
            fmt_g(*f),
            fmt_g(*c),
            fmt_g(f + c),
        ]);
    }
    t.emit("table7_overhead");

    // o_latency + p_latency: constant, model-inference scale
    let model = OverheadModel::train_on_corpus(scale, None);
    let (_, o_lat) = model.predict_timed(1e4, 1e6);
    println!("o+p latency (model inference): {:.3} ms — constant, as in §7.5", o_lat * 1e3);

    // linearity check (the paper's key claim: overhead ~ nnz)
    let first = &rows[0];
    let last = &rows[rows.len() - 1];
    println!(
        "overhead growth: nnz x{:.0} -> f+c x{:.1} (paper shape: ~linear in nnz)",
        last.1 / first.1,
        (last.2 + last.3) / (first.2 + first.3).max(1e-12)
    );
}
