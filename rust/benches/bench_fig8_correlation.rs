//! Fig. 8 — Pearson correlation (%) between the eight sparsity features
//! over the corpus (paper shape: low mutual correlation, except the
//! definitionally-linked dispersion features).

use auto_spmv::features::{extract_csr, FEATURE_NAMES};
use auto_spmv::gen;
use auto_spmv::report::Table;

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va == 0.0 || vb == 0.0 {
        0.0
    } else {
        cov / (va.sqrt() * vb.sqrt())
    }
}

fn main() {
    let feats: Vec<Vec<f64>> = gen::corpus()
        .iter()
        .map(|e| extract_csr(&e.generate_csr(1)).to_vec())
        .collect();
    let cols: Vec<Vec<f64>> = (0..8)
        .map(|j| feats.iter().map(|f| f[j]).collect())
        .collect();

    let header: Vec<&str> = std::iter::once("feature").chain(FEATURE_NAMES).collect();
    let mut t = Table::new("Fig. 8 — Pearson correlation (%) of sparsity features", &header);
    let mut offdiag = Vec::new();
    for i in 0..8 {
        let mut cells = vec![FEATURE_NAMES[i].to_string()];
        for j in 0..8 {
            let r = pearson(&cols[i], &cols[j]) * 100.0;
            if i != j && !((i, j) == (3, 7) || (i, j) == (7, 3)) {
                offdiag.push(r.abs());
            }
            cells.push(format!("{r:.0}"));
        }
        t.row(cells);
    }
    t.emit("fig8_correlation");
    let mean = offdiag.iter().sum::<f64>() / offdiag.len() as f64;
    println!("mean |off-diagonal| correlation (excl. Var/Std pair): {mean:.1}%");
    println!("paper shape: low correlation -> features carry independent signal");
}
