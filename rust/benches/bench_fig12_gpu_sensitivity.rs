//! Fig. 12 — GPU sensitivity: configurations predicted by the
//! Turing-trained classifier, evaluated against the measured optimum on
//! the Pascal profile for the paper's six cross-check matrices
//! (amazon0601, crankseg_2, bcsstk32, x104, il2010, Chevron3).

#[path = "common.rs"]
mod common;

use auto_spmv::coordinator::CompileTimeOptimizer;
use auto_spmv::dataset::Dataset;
use auto_spmv::features::extract_csr;
use auto_spmv::gen::{self, GPU_SENSITIVITY_SET};
use auto_spmv::gpusim::Objective;
use auto_spmv::report::Table;
use auto_spmv::sparse::Format;

fn main() {
    let ds = common::full_dataset();
    let turing = Dataset {
        records: ds.records.iter().filter(|r| r.arch.contains("Turing")).cloned().collect(),
    };
    for obj in [Objective::Latency, Objective::EnergyEff] {
        let opt = CompileTimeOptimizer::train(&turing, obj);
        let mut t = Table::new(
            &format!(
                "Fig. 12 ({}) — Turing-trained predictions measured on Pascal (normalized to optimum)",
                obj.name()
            ),
            &["matrix", "predicted cfg", "pred/optimal", "loss"],
        );
        let mut worst: f64 = 0.0;
        for name in GPU_SENSITIVITY_SET {
            let f = extract_csr(&gen::by_name(name).unwrap().generate_csr(1));
            let choice = opt.predict(&f, "GTX1650m-Turing");
            let slice = ds.slice(name, "GTX1080-Pascal");
            let chosen = slice
                .iter()
                .find(|r| r.config == choice.to_config())
                .expect("config in sweep");
            let best = slice
                .iter()
                .filter(|r| r.config.format == Format::Csr)
                .map(|r| obj.value(&r.m))
                .reduce(|a, b| if obj.better(a, b) { a } else { b })
                .unwrap();
            let chosen_v = obj.value(&chosen.m);
            let ratio = if obj.minimize() { best / chosen_v } else { chosen_v / best };
            let loss = (1.0 - ratio) * 100.0;
            worst = worst.max(loss);
            t.row(vec![
                name.into(),
                choice.to_config().to_string(),
                format!("{ratio:.3}"),
                common::pct(loss),
            ]);
        }
        t.emit(&format!("fig12_sensitivity_{}", obj.name()));
        println!("{}: worst cross-GPU loss {:.1}% (paper: up to ~2% on real boards)\n",
                 obj.name(), worst);
    }
}
