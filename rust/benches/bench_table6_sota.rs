//! Table 6 — Auto-SpMV (AutoML-tuned decision tree) vs state-of-the-art
//! baselines: BestSF's single SVM [78], the bagged-trees classifier of
//! [74], and a CNN-proxy for [32] — all on the format-selection task for
//! the execution-time and energy objectives.

#[path = "common.rs"]
mod common;

use auto_spmv::automl::tuner::tune_all;
use auto_spmv::dataset::labels::{self, Target};
use auto_spmv::gpusim::Objective;
use auto_spmv::ml::baselines;
use auto_spmv::ml::metrics::accuracy;
use auto_spmv::ml::scaler::StandardScaler;
use auto_spmv::ml::split::{take, take_x, train_test_indices};
use auto_spmv::ml::Classifier;
use auto_spmv::report::Table;

fn main() {
    let ds = common::full_dataset();
    let mut t = Table::new(
        "Table 6 — classification accuracy vs state-of-the-art (format selection)",
        &["model", "acc (latency)", "acc (energy)"],
    );
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for obj in [Objective::Latency, Objective::Energy] {
        let ex = labels::examples(&ds, obj);
        let (x, y) = labels::to_xy(&ex, Target::Format);
        let (tr, te) = train_test_indices(x.len(), 0.2, 0x7AB6);
        let (sc, xt) = StandardScaler::fit_transform(&take_x(&x, &tr));
        let xv = sc.transform(&take_x(&x, &te));
        let (yt, yv) = (take(&y, &tr), take(&y, &te));

        // baselines (fixed hyperparameters, no AutoML — the comparison point)
        for (name, mut model) in baselines::all(&xt) {
            model.fit(&xt, &yt);
            let acc = accuracy(&yv, &model.predict(&xv));
            match rows.iter_mut().find(|(n, _)| n == name) {
                Some((_, v)) => v.push(acc),
                None => rows.push((name.to_string(), vec![acc])),
            }
        }
        // Auto-SpMV: tune all six families with TPE, deploy the best
        // (§5.4: "fine-tunes six different learning models ... then we
        // report the best classification results")
        let tuned = tune_all(&xt, &yt, 10, 6);
        let best = &tuned[0];
        eprintln!("  [{}] Auto-SpMV winner: {}", obj.name(), best.family.name());
        let acc = accuracy(&yv, &best.model.predict(&xv));
        match rows.iter_mut().find(|(n, _)| n == "Auto-SpMV (best tuned)") {
            Some((_, v)) => v.push(acc),
            None => rows.push(("Auto-SpMV (best tuned)".into(), vec![acc])),
        }
    }
    for (name, accs) in &rows {
        t.row(vec![
            name.clone(),
            format!("{:.0}%", 100.0 * accs[0]),
            format!("{:.0}%", 100.0 * accs.get(1).copied().unwrap_or(f64::NAN)),
        ]);
    }
    t.emit("table6_sota");
    println!("paper shape: Auto-SpMV's tuned model >= every fixed-hyperparameter baseline");
}
