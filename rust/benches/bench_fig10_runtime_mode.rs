//! Fig. 10 — run-time optimization mode: per-matrix improvement of the
//! best sparse format (at optimal compile parameters, the paper's fair
//! comparison) over CSR at optimal compile parameters.

#[path = "common.rs"]
mod common;

use auto_spmv::dataset::labels;
use auto_spmv::gpusim::Objective;
use auto_spmv::report::Table;
use auto_spmv::sparse::Format;

fn main() {
    let ds = common::full_dataset();
    for obj in Objective::ALL {
        let ex = labels::examples(&ds, obj);
        let mut t = Table::new(
            &format!("Fig. 10 ({}) — run-time mode: best format vs tuned CSR", obj.name()),
            &["matrix", "best format", "improvement"],
        );
        let mut max: f64 = 0.0;
        let mut nonzero = 0usize;
        let mut count = 0usize;
        for e in ex.iter().filter(|e| e.arch.contains("Turing")) {
            let imp = if obj.minimize() {
                (e.best_compile - e.best_format_value) / e.best_compile * 100.0
            } else {
                (e.best_format_value - e.best_compile) / e.best_compile * 100.0
            };
            let fmt = Format::from_class_id(e.format_class).unwrap();
            if imp > 0.5 {
                nonzero += 1;
            }
            max = max.max(imp);
            count += 1;
            t.row(vec![e.matrix.clone(), fmt.to_string(), common::pct(imp)]);
        }
        t.emit(&format!("fig10_runtime_{}", obj.name()));
        println!(
            "{}: max improvement {:.1}%, matrices improved {nonzero}/{count} \
             (paper: lat/energy ~0 [CSR optimal], avg_power up to 34.6%, eff up to 99.7%)\n",
            obj.name(),
            max
        );
    }
}
