//! Fig. 6 — accuracy of the run-time overhead estimators: predicted vs
//! measured f_latency (feature extraction) and c_latency (conversion),
//! leave-one-out over the corpus.

#[path = "common.rs"]
mod common;

use auto_spmv::coordinator::overhead::{measure_overhead, OverheadModel, OverheadSample};
use auto_spmv::gen;
use auto_spmv::report::{fmt_g, Table};
use auto_spmv::sparse::Format;

fn main() {
    // measure every corpus matrix once (the ground truth of Fig. 6)
    let entries = gen::corpus();
    let samples: Vec<(String, OverheadSample)> = entries
        .iter()
        .map(|e| (e.name.to_string(), measure_overhead(e, 1, Format::Ell)))
        .collect();

    let mut t = Table::new(
        "Fig. 6 — overhead estimation (leave-one-out): predicted vs measured (ms)",
        &["matrix", "f_meas", "f_pred", "c_meas", "c_pred"],
    );
    let mut err_f = 0.0;
    let mut err_c = 0.0;
    for (name, s) in &samples {
        let train: Vec<OverheadSample> = samples
            .iter()
            .filter(|(n, _)| n != name)
            .map(|(_, s)| *s)
            .collect();
        let model = OverheadModel::train(&train);
        let est = model.predict(s.n, s.nnz);
        err_f += (est.f_latency_s - s.f_latency_s).abs() / s.f_latency_s.max(1e-9);
        err_c += (est.c_latency_s - s.c_latency_s).abs() / s.c_latency_s.max(1e-9);
        t.row(vec![
            name.clone(),
            fmt_g(s.f_latency_s * 1e3),
            fmt_g(est.f_latency_s * 1e3),
            fmt_g(s.c_latency_s * 1e3),
            fmt_g(est.c_latency_s * 1e3),
        ]);
    }
    t.emit("fig6_overhead_model");
    println!(
        "mean relative error: f_latency {:.1}%, c_latency {:.1}% (paper shape: accurate tracking)",
        100.0 * err_f / samples.len() as f64,
        100.0 * err_c / samples.len() as f64
    );
}
