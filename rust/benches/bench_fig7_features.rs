//! Fig. 7 — distribution of the eight sparsity features over the corpus,
//! sorted by ascending nnz (the matched-coverage check for the
//! SuiteSparse stand-in).

use auto_spmv::features::{extract_csr, FEATURE_NAMES};
use auto_spmv::gen;
use auto_spmv::report::{fmt_g, Table};

fn main() {
    let mut rows: Vec<(String, Vec<f64>)> = gen::corpus()
        .iter()
        .map(|e| {
            let f = extract_csr(&e.generate_csr(1));
            (e.name.to_string(), f.to_vec())
        })
        .collect();
    rows.sort_by(|a, b| a.1[1].partial_cmp(&b.1[1]).unwrap()); // by nnz

    let header: Vec<&str> =
        std::iter::once("matrix").chain(FEATURE_NAMES.iter().copied()).collect();
    let mut t = Table::new("Fig. 7 — sparsity features (ascending nnz)", &header);
    for (name, f) in &rows {
        let mut cells = vec![name.clone()];
        cells.extend(f.iter().map(|v| fmt_g(*v)));
        t.row(cells);
    }
    t.emit("fig7_features");

    // coverage summary (paper: "wide range of sparsity features")
    for (j, name) in FEATURE_NAMES.iter().enumerate() {
        let vals: Vec<f64> = rows.iter().map(|r| r.1[j]).collect();
        let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        println!("{name:>10}: {} .. {} (x{:.0} range)", fmt_g(min), fmt_g(max),
                 if min > 0.0 { max / min } else { f64::NAN });
    }
}
