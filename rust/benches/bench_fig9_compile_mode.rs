//! Fig. 9 — compile-time optimization mode: per-matrix improvement over
//! the default parameters (CSR format), all four objectives, with the
//! best/worst-TB whiskers the paper draws (the programmer-controlled
//! parameter band).

#[path = "common.rs"]
mod common;

use auto_spmv::dataset::labels;
use auto_spmv::gpusim::{KernelConfig, Objective, TB_SIZES};
use auto_spmv::report::Table;
use auto_spmv::sparse::Format;

fn main() {
    let ds = common::full_dataset();
    for obj in Objective::ALL {
        let ex = labels::examples(&ds, obj);
        let mut t = Table::new(
            &format!("Fig. 9 ({}) — compile-time mode improvement over default CSR", obj.name()),
            &["matrix", "improvement", "best-TB band", "worst-TB band"],
        );
        let mut sum = 0.0;
        let mut max: f64 = 0.0;
        let mut count = 0usize;
        for e in ex.iter().filter(|e| e.arch.contains("Turing")) {
            let imp = if obj.minimize() {
                (e.default_value - e.best_compile) / e.default_value * 100.0
            } else {
                (e.best_compile - e.default_value) / e.default_value * 100.0
            };
            // whiskers: optimize regs+mem per TB size, report band over TB
            let slice = ds.slice(&e.matrix, &e.arch);
            let mut band: Vec<f64> = Vec::new();
            for &tb in &TB_SIZES {
                let best_at_tb = slice
                    .iter()
                    .filter(|r| r.config.format == Format::Csr && r.config.tb_size == tb)
                    .map(|r| obj.value(&r.m))
                    .reduce(|a, b| if obj.better(a, b) { a } else { b })
                    .unwrap();
                let rel = if obj.minimize() {
                    (e.default_value - best_at_tb) / e.default_value * 100.0
                } else {
                    (best_at_tb - e.default_value) / e.default_value * 100.0
                };
                band.push(rel);
            }
            let hi = band.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let lo = band.iter().cloned().fold(f64::INFINITY, f64::min);
            sum += imp;
            max = max.max(imp);
            count += 1;
            t.row(vec![
                e.matrix.clone(),
                common::pct(imp),
                common::pct(hi),
                common::pct(lo),
            ]);
        }
        t.emit(&format!("fig9_compile_{}", obj.name()));
        println!(
            "{}: mean {:.1}%, max {:.1}%  (paper: up to 51.9/52/33.2/53% for lat/en/pow/eff)\n",
            obj.name(),
            sum / count as f64,
            max
        );
        let _ = KernelConfig::default_baseline();
    }
}
