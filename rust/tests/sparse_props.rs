//! Property tests over the sparse substrate (testutil::proputil is the
//! offline proptest stand-in — see Cargo.toml).
//!
//! Invariants:
//!  * every format conversion preserves the SpMV product;
//!  * conversion round trips preserve CSR exactly;
//!  * batched products (`spmm`, plus its `spmv_batch` alias) are
//!    bit-identical to independent `spmv_alloc` calls, for every format
//!    and ragged batch widths (the serving pool's coalescing
//!    correctness contract);
//!  * kernel marshalling (padded bucket arrays) preserves the product;
//!  * feature extraction is format-independent;
//!  * routing/labeling invariants (best <= default under each objective).

use auto_spmv::features;
use auto_spmv::gen::Rng;
use auto_spmv::sparse::convert::{self, AnyFormat, ConvertParams};
use auto_spmv::sparse::{Coo, Dense, Format, SpMv};
use auto_spmv::testutil::{arb_coo, arb_x, assert_prop};

fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} != {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol * y.abs().max(1.0) {
            return Err(format!("row {i}: {x} != {y}"));
        }
    }
    Ok(())
}

#[test]
fn prop_all_conversions_preserve_spmv() {
    assert_prop("conversions preserve spmv", 0xC0, 60, 256, |rng, size| {
        let coo = arb_coo(rng, size);
        let x = arb_x(rng, coo.n_cols);
        let csr = convert::coo_to_csr(&coo);
        let want = csr.spmv_alloc(&x);
        for fmt in Format::ALL {
            for params in [
                ConvertParams { bell_bh: 2, bell_bw: 2, sell_h: 2 },
                ConvertParams { bell_bh: 4, bell_bw: 8, sell_h: 8 },
                ConvertParams::default(),
            ] {
                let m = convert::convert(&csr, fmt, params);
                let got = m.as_spmv().spmv_alloc(&x);
                close(&got, &want, 1e-4).map_err(|e| format!("{fmt} {params:?}: {e}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_roundtrips_preserve_csr() {
    assert_prop("round trips preserve csr", 0xC1, 60, 256, |rng, size| {
        let coo = arb_coo(rng, size);
        let csr = convert::coo_to_csr(&coo);
        // note: generators may produce duplicates; densified comparison
        let dense = convert::csr_to_dense(&csr);
        let back_ell = convert::csr_to_dense(&convert::ell_to_csr(&convert::csr_to_ell(&csr)));
        if back_ell.data != dense.data {
            return Err("ELL round trip changed the dense realization".into());
        }
        let back_sell =
            convert::csr_to_dense(&convert::sell_to_csr(&convert::csr_to_sell(&csr, 3)));
        if back_sell.data != dense.data {
            return Err("SELL round trip changed the dense realization".into());
        }
        let back_bell =
            convert::csr_to_dense(&convert::bell_to_csr(&convert::csr_to_bell(&csr, 3, 5)));
        if back_bell.data != dense.data {
            return Err("BELL round trip changed the dense realization".into());
        }
        Ok(())
    });
}

#[test]
fn prop_spmm_matches_independent_products_bit_for_bit() {
    // Every format overrides `spmm` with a one-matrix-walk batch kernel;
    // the contract is bit-identity per vector, for ragged batch widths
    // (k = 1 up to past the serving pool's common bucket sizes).
    assert_prop("spmm == k x spmv_alloc", 0xC6, 50, 200, |rng, size| {
        let coo = arb_coo(rng, size);
        let csr = convert::coo_to_csr(&coo);
        let k = match size % 4 {
            0 => 1,             // degenerate batch
            1 => 3,             // under any bucket
            2 => 8,             // a common bucket width
            _ => 9,             // bucket + 1 (the chunking edge)
        };
        let xs: Vec<Vec<f32>> = (0..k).map(|_| arb_x(rng, coo.n_cols)).collect();
        let views: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        for fmt in Format::ALL {
            for params in [
                ConvertParams { bell_bh: 2, bell_bw: 2, sell_h: 2 },
                ConvertParams::default(),
            ] {
                let m = convert::convert(&csr, fmt, params);
                let batch = m.as_spmv().spmm(&views);
                if batch.len() != k {
                    return Err(format!("{fmt}: batch len {} != {k}", batch.len()));
                }
                for (j, x) in xs.iter().enumerate() {
                    let want = m.as_spmv().spmv_alloc(x);
                    // bit-identical, not merely close: the serving pool
                    // relies on batched and unbatched dispatch being
                    // interchangeable
                    if batch[j] != want {
                        return Err(format!("{fmt} {params:?}: vector {j} differs"));
                    }
                }
                // the legacy alias must keep routing through spmm
                if m.as_spmv().spmv_batch(&views) != batch {
                    return Err(format!("{fmt}: spmv_batch alias diverged from spmm"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kernel_marshalling_preserves_product() {
    assert_prop("kernel marshalling preserves product", 0xC2, 40, 128, |rng, size| {
        let coo = arb_coo(rng, size);
        let x = arb_x(rng, coo.n_cols);
        let csr = convert::coo_to_csr(&coo);
        let want = csr.spmv_alloc(&x);

        // ELL bucket marshalling: compute from the padded arrays directly
        let ell = convert::csr_to_ell(&csr);
        let rows_pad = (csr.n_rows + 7).div_ceil(8) * 8;
        let width_pad = ell.width + 3;
        let (vals, cols) = ell.to_kernel(rows_pad, width_pad);
        let mut got = vec![0.0f32; csr.n_rows];
        for (r, g) in got.iter_mut().enumerate() {
            for s in 0..width_pad {
                *g += vals[r * width_pad + s] * x[cols[r * width_pad + s] as usize];
            }
        }
        close(&got, &want, 1e-4).map_err(|e| format!("ELL marshalling: {e}"))?;

        // CSR COO-expansion marshalling
        let nnz_pad = csr.vals.len() + 5;
        let (v, r, c) = csr.to_kernel_coo(nnz_pad);
        let mut got2 = vec![0.0f32; csr.n_rows];
        for k in 0..nnz_pad {
            got2[r[k] as usize] += v[k] * x[c[k] as usize];
        }
        close(&got2, &want, 1e-4).map_err(|e| format!("CSR marshalling: {e}"))
    });
}

/// Square, diagonally dominant system with a guaranteed nonzero
/// diagonal and NO duplicate (row, col) entries. Duplicates matter
/// here: `Coo::for_each_in_row` visits each stored entry separately
/// while `coo_to_csr` merges duplicates, so a duplicate-free generator
/// is what lets the solve bit-identity contract cover COO itself.
fn arb_solvable(rng: &mut Rng, size: usize) -> Coo {
    let n = (size % 24) + 1;
    let mut off: std::collections::BTreeMap<(usize, usize), f32> =
        std::collections::BTreeMap::new();
    for _ in 0..rng.below(3 * n + 1) {
        let i = rng.below(n);
        let j = rng.below(n);
        if i != j {
            off.insert((i, j), rng.val());
        }
    }
    // diag[i] > sum_j |a_ij| keeps both triangular solves and the
    // Gauss-Seidel sweep well conditioned for the residual oracles
    let mut diag = vec![1.0f32; n];
    for (&(i, _), v) in &off {
        diag[i] += v.abs();
    }
    let mut coo = Coo::new(n, n);
    for ((i, j), v) in off {
        coo.push(i, j, v);
    }
    for (i, d) in diag.into_iter().enumerate() {
        coo.push(i, i, d);
    }
    coo
}

/// Scipy-free SymGS reference: forward then backward pass over the
/// dense realization, f64 accumulators — independent of the
/// `for_each_in_row` traversal the trait's provided method uses.
fn symgs_oracle(d: &Dense, b: &[f32]) -> Vec<f32> {
    let n = d.n_rows;
    let mut x = vec![0.0f32; n];
    for pass in 0..2 {
        for step in 0..n {
            let i = if pass == 0 { step } else { n - 1 - step };
            let mut acc = b[i] as f64;
            for c in 0..n {
                if c != i {
                    acc -= d.data[i * n + c] as f64 * x[c] as f64;
                }
            }
            x[i] = (acc / d.data[i * n + i] as f64) as f32;
        }
    }
    x
}

#[test]
fn prop_solves_bit_identical_across_formats() {
    // SpTRSV (both triangles) and SymGS gather rows via
    // `for_each_in_row` and sort by column, so every format — the four
    // convertible ones, COO, and the dense realization — must produce
    // the SAME BITS. The serving pool relies on this: artifact
    // selection may pick any cached form for a solve-kind job.
    assert_prop("solves are bit-identical across formats", 0xD0, 50, 96, |rng, size| {
        let coo = arb_solvable(rng, size);
        let csr = convert::coo_to_csr(&coo);
        let dense = convert::csr_to_dense(&csr);
        let b = arb_x(rng, csr.n_rows);
        let want_lo = dense.sptrsv(&b, true).map_err(|e| e.to_string())?;
        let want_up = dense.sptrsv(&b, false).map_err(|e| e.to_string())?;
        let mut want_gs = vec![0.0f32; csr.n_rows];
        dense.symgs_sweep(&b, &mut want_gs).map_err(|e| e.to_string())?;

        let check = |m: &dyn SpMv, tag: &str| -> Result<(), String> {
            let lo = m.sptrsv(&b, true).map_err(|e| format!("{tag} lower: {e}"))?;
            if lo != want_lo {
                return Err(format!("{tag}: lower solve differs from dense oracle"));
            }
            let up = m.sptrsv(&b, false).map_err(|e| format!("{tag} upper: {e}"))?;
            if up != want_up {
                return Err(format!("{tag}: upper solve differs from dense oracle"));
            }
            let mut gs = vec![0.0f32; b.len()];
            m.symgs_sweep(&b, &mut gs).map_err(|e| format!("{tag} symgs: {e}"))?;
            if gs != want_gs {
                return Err(format!("{tag}: symgs sweep differs from dense oracle"));
            }
            Ok(())
        };
        check(&coo, "coo")?;
        for fmt in Format::ALL {
            for params in [
                ConvertParams { bell_bh: 2, bell_bw: 2, sell_h: 2 },
                ConvertParams::default(),
            ] {
                let m = convert::convert(&csr, fmt, params);
                check(m.as_spmv(), &format!("{fmt} {params:?}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_triangular_solves_satisfy_their_triangle() {
    // Independent oracle: substitute the solution back. T x must
    // reproduce b where T is the solved triangle INCLUDING the
    // diagonal — stored entries on the wrong side are ignored
    // (HPCG-style full-matrix solve), which this residual pins.
    assert_prop("sptrsv residual vanishes", 0xD1, 50, 96, |rng, size| {
        let coo = arb_solvable(rng, size);
        let csr = convert::coo_to_csr(&coo);
        let dense = convert::csr_to_dense(&csr);
        let n = csr.n_rows;
        let b = arb_x(rng, n);
        for lower in [true, false] {
            let x = csr.sptrsv(&b, lower).map_err(|e| e.to_string())?;
            let mut tb = vec![0.0f32; n];
            for (i, t) in tb.iter_mut().enumerate() {
                let mut acc = 0.0f64;
                for c in 0..n {
                    let in_tri = if lower { c <= i } else { c >= i };
                    if in_tri {
                        acc += dense.data[i * n + c] as f64 * x[c] as f64;
                    }
                }
                *t = acc as f32;
            }
            close(&tb, &b, 1e-3)
                .map_err(|e| format!("lower={lower}: T x != b: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_symgs_matches_dense_reference_sweep() {
    assert_prop("symgs == dense f64 reference", 0xD2, 50, 96, |rng, size| {
        let coo = arb_solvable(rng, size);
        let csr = convert::coo_to_csr(&coo);
        let dense = convert::csr_to_dense(&csr);
        let b = arb_x(rng, csr.n_rows);
        let mut got = vec![0.0f32; csr.n_rows];
        csr.symgs_sweep(&b, &mut got).map_err(|e| e.to_string())?;
        let want = symgs_oracle(&dense, &b);
        close(&got, &want, 1e-3)
    });
}

#[test]
fn prop_singular_diagonal_errors_on_every_format() {
    // Drop one row's diagonal: every format's solve paths must refuse
    // with the singular-system error naming that row — padding entries
    // (value 0.0) must never fake a pivot.
    assert_prop("missing diagonal is singular everywhere", 0xD3, 40, 96, |rng, size| {
        let good = arb_solvable(rng, size);
        let n = good.n_rows;
        let k = rng.below(n);
        let mut coo = Coo::new(n, n);
        for i in 0..good.len() {
            if !(good.rows[i] as usize == k && good.cols[i] as usize == k) {
                coo.push(good.rows[i] as usize, good.cols[i] as usize, good.vals[i]);
            }
        }
        let csr = convert::coo_to_csr(&coo);
        let b = arb_x(rng, n);
        let expect = format!("singular system: row {k}");
        let check = |m: &dyn SpMv, tag: &str| -> Result<(), String> {
            for (what, res) in [
                ("sptrsv lower", m.sptrsv(&b, true)),
                ("sptrsv upper", m.sptrsv(&b, false)),
                ("symgs", {
                    let mut x = vec![0.0f32; n];
                    m.symgs_sweep(&b, &mut x).map(|()| x)
                }),
            ] {
                match res {
                    Ok(_) => return Err(format!("{tag} {what}: singular solve succeeded")),
                    Err(e) if !e.to_string().contains(&expect) => {
                        return Err(format!("{tag} {what}: wrong error: {e}"));
                    }
                    Err(_) => {}
                }
            }
            Ok(())
        };
        check(&coo, "coo")?;
        check(&convert::csr_to_dense(&csr), "dense")?;
        for fmt in Format::ALL {
            let m = convert::convert(&csr, fmt, ConvertParams::default());
            check(m.as_spmv(), &format!("{fmt}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_features_format_independent() {
    assert_prop("features are format independent", 0xC3, 60, 256, |rng, size| {
        let coo = arb_coo(rng, size);
        let csr = convert::coo_to_csr(&coo);
        let f_coo = features::extract_coo(&coo);
        let f_csr = features::extract_csr(&csr);
        if f_coo != f_csr {
            return Err(format!("{f_coo:?} != {f_csr:?}"));
        }
        // consistency identities
        if (f_coo.std_nnz * f_coo.std_nnz - f_coo.var_nnz).abs() > 1e-9 {
            return Err("std^2 != var".into());
        }
        if f_coo.ell_ratio > 1.0 + 1e-12 {
            return Err("ELL ratio > 1".into());
        }
        Ok(())
    });
}

#[test]
fn prop_storage_accounting_consistent() {
    use auto_spmv::sparse::Storage;
    assert_prop("storage accounting", 0xC4, 60, 256, |rng, size| {
        let coo = arb_coo(rng, size);
        let csr = convert::coo_to_csr(&coo);
        for fmt in Format::ALL {
            let m = convert::convert(&csr, fmt, ConvertParams { bell_bh: 2, bell_bw: 2, sell_h: 2 });
            let (stored, nnz) = match &m {
                AnyFormat::Csr(a) => (a.stored_entries(), a.nnz()),
                AnyFormat::Ell(a) => (a.stored_entries(), a.nnz()),
                AnyFormat::Bell(a) => (a.stored_entries(), a.nnz()),
                AnyFormat::Sell(a) => (a.stored_entries(), a.nnz()),
            };
            if stored < nnz {
                return Err(format!("{fmt}: stored {stored} < nnz {nnz}"));
            }
            if m.storage_bytes() == 0 && nnz > 0 {
                return Err(format!("{fmt}: zero storage with nnz {nnz}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_objectives_positive_and_consistent() {
    use auto_spmv::gpusim::{
        measure, profile, turing_gtx1650m, KernelConfig, MemConfig,
    };
    assert_prop("simulator objectives", 0xC5, 25, 200, |rng, size| {
        let coo = arb_coo(rng, size + 8);
        if coo.is_empty() {
            return Ok(());
        }
        let csr = convert::coo_to_csr(&coo);
        let arch = turing_gtx1650m();
        for fmt in Format::ALL {
            let prof = profile(&csr, fmt, ConvertParams { bell_bh: 2, bell_bw: 2, sell_h: 2 });
            let cfg = KernelConfig {
                format: fmt,
                tb_size: [64u32, 256, 1024][size % 3],
                maxrregcount: [16u32, 64][size % 2],
                mem: MemConfig::ALL[size % 3],
            };
            let m = measure(&arch, &prof, &cfg);
            if !(m.latency_s > 0.0 && m.energy_j > 0.0 && m.avg_power_w > 0.0) {
                return Err(format!("{fmt}: non-positive objectives {m:?}"));
            }
            if ((m.energy_j / m.latency_s) - m.avg_power_w).abs() > 1e-6 * m.avg_power_w {
                return Err(format!("{fmt}: E != P*t"));
            }
        }
        Ok(())
    });
}
