//! Property tests over the sparse substrate (testutil::proputil is the
//! offline proptest stand-in — see Cargo.toml).
//!
//! Invariants:
//!  * every format conversion preserves the SpMV product;
//!  * conversion round trips preserve CSR exactly;
//!  * batched products (`spmm`, plus its `spmv_batch` alias) are
//!    bit-identical to independent `spmv_alloc` calls, for every format
//!    and ragged batch widths (the serving pool's coalescing
//!    correctness contract);
//!  * kernel marshalling (padded bucket arrays) preserves the product;
//!  * feature extraction is format-independent;
//!  * routing/labeling invariants (best <= default under each objective).

use auto_spmv::features;
use auto_spmv::sparse::convert::{self, AnyFormat, ConvertParams};
use auto_spmv::sparse::{Format, SpMv};
use auto_spmv::testutil::{arb_coo, arb_x, assert_prop};

fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} != {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol * y.abs().max(1.0) {
            return Err(format!("row {i}: {x} != {y}"));
        }
    }
    Ok(())
}

#[test]
fn prop_all_conversions_preserve_spmv() {
    assert_prop("conversions preserve spmv", 0xC0, 60, 256, |rng, size| {
        let coo = arb_coo(rng, size);
        let x = arb_x(rng, coo.n_cols);
        let csr = convert::coo_to_csr(&coo);
        let want = csr.spmv_alloc(&x);
        for fmt in Format::ALL {
            for params in [
                ConvertParams { bell_bh: 2, bell_bw: 2, sell_h: 2 },
                ConvertParams { bell_bh: 4, bell_bw: 8, sell_h: 8 },
                ConvertParams::default(),
            ] {
                let m = convert::convert(&csr, fmt, params);
                let got = m.as_spmv().spmv_alloc(&x);
                close(&got, &want, 1e-4).map_err(|e| format!("{fmt} {params:?}: {e}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_roundtrips_preserve_csr() {
    assert_prop("round trips preserve csr", 0xC1, 60, 256, |rng, size| {
        let coo = arb_coo(rng, size);
        let csr = convert::coo_to_csr(&coo);
        // note: generators may produce duplicates; densified comparison
        let dense = convert::csr_to_dense(&csr);
        let back_ell = convert::csr_to_dense(&convert::ell_to_csr(&convert::csr_to_ell(&csr)));
        if back_ell.data != dense.data {
            return Err("ELL round trip changed the dense realization".into());
        }
        let back_sell =
            convert::csr_to_dense(&convert::sell_to_csr(&convert::csr_to_sell(&csr, 3)));
        if back_sell.data != dense.data {
            return Err("SELL round trip changed the dense realization".into());
        }
        let back_bell =
            convert::csr_to_dense(&convert::bell_to_csr(&convert::csr_to_bell(&csr, 3, 5)));
        if back_bell.data != dense.data {
            return Err("BELL round trip changed the dense realization".into());
        }
        Ok(())
    });
}

#[test]
fn prop_spmm_matches_independent_products_bit_for_bit() {
    // Every format overrides `spmm` with a one-matrix-walk batch kernel;
    // the contract is bit-identity per vector, for ragged batch widths
    // (k = 1 up to past the serving pool's common bucket sizes).
    assert_prop("spmm == k x spmv_alloc", 0xC6, 50, 200, |rng, size| {
        let coo = arb_coo(rng, size);
        let csr = convert::coo_to_csr(&coo);
        let k = match size % 4 {
            0 => 1,             // degenerate batch
            1 => 3,             // under any bucket
            2 => 8,             // a common bucket width
            _ => 9,             // bucket + 1 (the chunking edge)
        };
        let xs: Vec<Vec<f32>> = (0..k).map(|_| arb_x(rng, coo.n_cols)).collect();
        let views: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        for fmt in Format::ALL {
            for params in [
                ConvertParams { bell_bh: 2, bell_bw: 2, sell_h: 2 },
                ConvertParams::default(),
            ] {
                let m = convert::convert(&csr, fmt, params);
                let batch = m.as_spmv().spmm(&views);
                if batch.len() != k {
                    return Err(format!("{fmt}: batch len {} != {k}", batch.len()));
                }
                for (j, x) in xs.iter().enumerate() {
                    let want = m.as_spmv().spmv_alloc(x);
                    // bit-identical, not merely close: the serving pool
                    // relies on batched and unbatched dispatch being
                    // interchangeable
                    if batch[j] != want {
                        return Err(format!("{fmt} {params:?}: vector {j} differs"));
                    }
                }
                // the legacy alias must keep routing through spmm
                if m.as_spmv().spmv_batch(&views) != batch {
                    return Err(format!("{fmt}: spmv_batch alias diverged from spmm"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kernel_marshalling_preserves_product() {
    assert_prop("kernel marshalling preserves product", 0xC2, 40, 128, |rng, size| {
        let coo = arb_coo(rng, size);
        let x = arb_x(rng, coo.n_cols);
        let csr = convert::coo_to_csr(&coo);
        let want = csr.spmv_alloc(&x);

        // ELL bucket marshalling: compute from the padded arrays directly
        let ell = convert::csr_to_ell(&csr);
        let rows_pad = (csr.n_rows + 7).div_ceil(8) * 8;
        let width_pad = ell.width + 3;
        let (vals, cols) = ell.to_kernel(rows_pad, width_pad);
        let mut got = vec![0.0f32; csr.n_rows];
        for (r, g) in got.iter_mut().enumerate() {
            for s in 0..width_pad {
                *g += vals[r * width_pad + s] * x[cols[r * width_pad + s] as usize];
            }
        }
        close(&got, &want, 1e-4).map_err(|e| format!("ELL marshalling: {e}"))?;

        // CSR COO-expansion marshalling
        let nnz_pad = csr.vals.len() + 5;
        let (v, r, c) = csr.to_kernel_coo(nnz_pad);
        let mut got2 = vec![0.0f32; csr.n_rows];
        for k in 0..nnz_pad {
            got2[r[k] as usize] += v[k] * x[c[k] as usize];
        }
        close(&got2, &want, 1e-4).map_err(|e| format!("CSR marshalling: {e}"))
    });
}

#[test]
fn prop_features_format_independent() {
    assert_prop("features are format independent", 0xC3, 60, 256, |rng, size| {
        let coo = arb_coo(rng, size);
        let csr = convert::coo_to_csr(&coo);
        let f_coo = features::extract_coo(&coo);
        let f_csr = features::extract_csr(&csr);
        if f_coo != f_csr {
            return Err(format!("{f_coo:?} != {f_csr:?}"));
        }
        // consistency identities
        if (f_coo.std_nnz * f_coo.std_nnz - f_coo.var_nnz).abs() > 1e-9 {
            return Err("std^2 != var".into());
        }
        if f_coo.ell_ratio > 1.0 + 1e-12 {
            return Err("ELL ratio > 1".into());
        }
        Ok(())
    });
}

#[test]
fn prop_storage_accounting_consistent() {
    use auto_spmv::sparse::Storage;
    assert_prop("storage accounting", 0xC4, 60, 256, |rng, size| {
        let coo = arb_coo(rng, size);
        let csr = convert::coo_to_csr(&coo);
        for fmt in Format::ALL {
            let m = convert::convert(&csr, fmt, ConvertParams { bell_bh: 2, bell_bw: 2, sell_h: 2 });
            let (stored, nnz) = match &m {
                AnyFormat::Csr(a) => (a.stored_entries(), a.nnz()),
                AnyFormat::Ell(a) => (a.stored_entries(), a.nnz()),
                AnyFormat::Bell(a) => (a.stored_entries(), a.nnz()),
                AnyFormat::Sell(a) => (a.stored_entries(), a.nnz()),
            };
            if stored < nnz {
                return Err(format!("{fmt}: stored {stored} < nnz {nnz}"));
            }
            if m.storage_bytes() == 0 && nnz > 0 {
                return Err(format!("{fmt}: zero storage with nnz {nnz}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_objectives_positive_and_consistent() {
    use auto_spmv::gpusim::{
        measure, profile, turing_gtx1650m, KernelConfig, MemConfig,
    };
    assert_prop("simulator objectives", 0xC5, 25, 200, |rng, size| {
        let coo = arb_coo(rng, size + 8);
        if coo.is_empty() {
            return Ok(());
        }
        let csr = convert::coo_to_csr(&coo);
        let arch = turing_gtx1650m();
        for fmt in Format::ALL {
            let prof = profile(&csr, fmt, ConvertParams { bell_bh: 2, bell_bw: 2, sell_h: 2 });
            let cfg = KernelConfig {
                format: fmt,
                tb_size: [64u32, 256, 1024][size % 3],
                maxrregcount: [16u32, 64][size % 2],
                mem: MemConfig::ALL[size % 3],
            };
            let m = measure(&arch, &prof, &cfg);
            if !(m.latency_s > 0.0 && m.energy_j > 0.0 && m.avg_power_w > 0.0) {
                return Err(format!("{fmt}: non-positive objectives {m:?}"));
            }
            if ((m.energy_j / m.latency_s) - m.avg_power_w).abs() > 1e-6 * m.avg_power_w {
                return Err(format!("{fmt}: E != P*t"));
            }
        }
        Ok(())
    });
}
