//! Integration tests for the closed-loop online subsystem
//! (`online` + `serve`): frozen-router equivalence, deterministic
//! drift convergence with hot-swap, and in-flight swap safety.

use auto_spmv::coordinator::{CompileChoice, KnobPolicy, RunTimeOptimizer};
use auto_spmv::dataset::labels;
use auto_spmv::features;
use auto_spmv::gen::{patterns, Rng};
use auto_spmv::gpusim::{profile, simulate, turing_gtx1650m, Objective};
use auto_spmv::obs::{Event, EventKind, SwapTrigger, DEFAULT_JOURNAL_CAP};
use auto_spmv::online::{bandit, observer, DriftConfig, Online, OnlineConfig, Policy, Trainer};
use auto_spmv::serve::{BackendSpec, Pool, PoolConfig, PoolStats, Response};
use auto_spmv::sparse::convert::{self, coo_to_csr, AnyFormat, ConvertParams};
use auto_spmv::sparse::{Coo, Csr, Format, SpMv};
use auto_spmv::testutil::{assert_prop, toy_setup};
use std::sync::Arc;
use std::time::Duration;

/// Deterministic input vector.
fn input(n: usize, salt: usize) -> Vec<f32> {
    (0..n).map(|i| ((i * 5 + salt * 11) % 13) as f32 * 0.5 - 3.0).collect()
}

fn single_worker_cfg() -> PoolConfig {
    PoolConfig { workers: 1, ..PoolConfig::default() }
}

/// One reference realization per format, converted with the pool's own
/// parameters — so every response can be checked bit-identically
/// against a single-product run of the format it actually executed in
/// (formats differ in float association, so a cross-format comparison
/// gets a tolerance instead).
struct FormatRefs {
    csr: Csr,
    by_format: Vec<AnyFormat>,
}

impl FormatRefs {
    fn new(coo: &Coo, params: ConvertParams) -> FormatRefs {
        let csr = coo_to_csr(coo);
        let by_format =
            Format::ALL.iter().map(|f| convert::convert(&csr, *f, params)).collect();
        FormatRefs { csr, by_format }
    }

    /// Panics when `resp` was dropped into the wrong numbers: exact
    /// against the executed format, close against the CSR baseline.
    fn check(&self, resp: &Response, x: &[f32], label: &str) {
        let want = self.by_format[resp.format_used.class_id()].as_spmv().spmv_alloc(x);
        assert_eq!(resp.y, want, "{label}: not bit-identical to its own format's product");
        let base = self.csr.spmv_alloc(x);
        for (a, b) in resp.y.iter().zip(&base) {
            assert!(
                (a - b).abs() <= 1e-3 * b.abs().max(1.0),
                "{label}: diverges from the CSR baseline ({a} vs {b})"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Property: with explore-rate 0 and no retraining, an adaptive pool's
// decisions and outputs are bit-identical to today's frozen-router
// behavior.
// ---------------------------------------------------------------------
#[test]
fn adaptive_pool_at_rate_zero_is_bit_identical_to_frozen_pool() {
    let router = Arc::new(toy_setup(&["rim", "eu-2005", "shar_te2-b3"], Objective::EnergyEff).0);
    assert_prop("rate-0 == frozen", 0xF0, 6, 400, |rng, size| {
        // a random structured matrix per case
        let n = 32 + size % 200;
        let coo = match size % 3 {
            0 => patterns::banded(rng, n, 4 + size % 8, 4.0),
            1 => patterns::uniform(rng, n, n, 3.0),
            _ => patterns::powerlaw(rng, n, n, 2.0, 3.0, 24),
        };
        let frozen = Pool::start(router.clone(), BackendSpec::Native, single_worker_cfg());
        let online = Online::start(
            OnlineConfig { explore_rate: 0.0, retrain_every: 0, ..OnlineConfig::default() },
            router.clone(),
            Objective::EnergyEff,
            None,
        );
        let adaptive = Pool::start_adaptive(online, BackendSpec::Native, single_worker_cfg());

        let f1 = frozen.register(1, coo.clone(), 10_000).map_err(|e| e.to_string())?;
        let f2 = adaptive.register(1, coo.clone(), 10_000).map_err(|e| e.to_string())?;
        if f1 != f2 {
            return Err(format!("registration formats diverge: {f1} vs {f2}"));
        }
        for r in 0..4 {
            let x = input(coo.n_cols, r);
            let a = frozen.product(1, x.clone()).map_err(|e| e.to_string())?;
            let b = adaptive.product(1, x).map_err(|e| e.to_string())?;
            if a.y != b.y {
                return Err(format!("request {r}: outputs diverge"));
            }
            if a.format_used != b.format_used {
                return Err(format!("request {r}: formats diverge"));
            }
        }
        let sa = adaptive.stats().map_err(|e| e.to_string())?;
        if sa.router_version != 1 || sa.explored_requests != 0 || sa.retrains != 0 {
            return Err(format!(
                "rate-0 pool must stay frozen: v{} explored {} retrains {}",
                sa.router_version, sa.explored_requests, sa.retrains
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// The acceptance end-to-end: a seeded drifted workload served through a
// pool with exploration + retraining converges the router to the
// better format within a bounded number of retrain rounds, ends with a
// higher router version and a measurably lower mean modeled objective
// than the frozen baseline, and drops or corrupts zero requests across
// the hot-swaps.
// ---------------------------------------------------------------------

/// A router that always predicts CSR: the §5.3 tree trained on
/// single-class (forced-CSR) labels — the deterministic stand-in for
/// "the offline corpus never covered this structure class".
fn stale_csr_router(
    ds: &auto_spmv::dataset::Dataset,
    objective: Objective,
    overhead: auto_spmv::coordinator::OverheadModel,
) -> RunTimeOptimizer {
    let mut ex = labels::examples(ds, objective);
    for e in &mut ex {
        e.format_class = Format::Csr.class_id();
    }
    RunTimeOptimizer::train_on_examples(ds, &ex, objective, overhead)
}

/// Modeled energy per product for each format at the serving knobs —
/// the ground truth the closed loop should converge to.
fn modeled_energy_per_format(coo: &Coo, convert: ConvertParams) -> [f64; 4] {
    let csr = coo_to_csr(coo);
    let arch = turing_gtx1650m();
    std::array::from_fn(|class| {
        let fmt = Format::from_class_id(class).unwrap();
        let prof = profile(&csr, fmt, convert);
        simulate(&arch, &prof, &observer::model_config(fmt)).0.energy_j
    })
}

#[test]
fn drifted_workload_converges_and_beats_frozen_router() {
    let objective = Objective::Energy;
    // Offline view: two power-law web graphs. Drifted traffic: a
    // regular stencil — pick, among candidates, the one where the
    // gpusim ground truth most favors a non-CSR format, so the test is
    // robust to model tweaks.
    let (_, ds, overhead) = toy_setup(&["eu-2005", "wiki-talk-temporal"], objective);
    let convert = PoolConfig::default().convert;
    let mut rng = Rng::new(0x0D12F7);
    let candidates: Vec<Coo> = vec![
        patterns::diagonals(&mut rng, 1000, &[-24, 0, 24, -48, 48, -72, 72], 0.98),
        patterns::banded(&mut rng, 900, 10, 6.0),
        patterns::diagonals(&mut rng, 700, &[-1, 0, 1, -32, 32], 0.99),
        patterns::blocks(&mut rng, 960, 8, 8, 1.6, 3, 0.95),
        patterns::diagonals(&mut rng, 1200, &[0, 1, -1, 64, -64, 128, -128, 256, -256], 0.97),
    ];
    let (coo, energies, best_fmt) = candidates
        .into_iter()
        .map(|c| {
            let e = modeled_energy_per_format(&c, convert);
            let best = Format::ALL
                .into_iter()
                .min_by(|a, b| e[a.class_id()].total_cmp(&e[b.class_id()]))
                .unwrap();
            (c, e, best)
        })
        .min_by(|(_, ea, ba), (_, eb, bb)| {
            let gap = |e: &[f64; 4], b: &Format| e[b.class_id()] / e[Format::Csr.class_id()];
            gap(ea, ba).total_cmp(&gap(eb, bb))
        })
        .unwrap();
    let e_csr = energies[Format::Csr.class_id()];
    let e_best = energies[best_fmt.class_id()];
    assert!(
        best_fmt != Format::Csr && e_best < 0.98 * e_csr,
        "test premise: the gpusim ground truth must favor a non-CSR format by >= 2% \
         on at least one candidate (got best {best_fmt} at {e_best:.3e} vs CSR {e_csr:.3e})"
    );

    let stale = Arc::new(stale_csr_router(&ds, objective, overhead.clone()));
    let refs = FormatRefs::new(&coo, convert);
    let hint = 1_000_000_000_000u64; // a long-lived iterative workload

    // Frozen baseline.
    let frozen = Pool::start(stale.clone(), BackendSpec::Native, single_worker_cfg());
    frozen.register(0, coo.clone(), hint).unwrap();

    // Closed loop: inline retraining (deterministic), single worker.
    // joint_knobs OFF: this test pins the PR 2/3 format-only
    // convergence contract; the joint loop has its own e2e below.
    let online = Online::start(
        OnlineConfig {
            explore_rate: 0.25,
            retrain_every: 48,
            seed: 0x5EED,
            background: false,
            joint_knobs: false,
            ..OnlineConfig::default()
        },
        stale.clone(),
        objective,
        Some(Trainer::new(ds.clone(), objective, overhead, turing_gtx1650m().name)),
    );
    let adaptive = Pool::start_adaptive(online.clone(), BackendSpec::Native, single_worker_cfg());
    let registered = adaptive.register(0, coo.clone(), hint).unwrap();
    assert_eq!(registered, Format::Csr, "the stale router must start every matrix at CSR");

    // Convergence phase: rounds of sequential requests; every response
    // is checked bit-identical against the native CSR reference, so a
    // corrupted product anywhere (including across hot-swaps) fails.
    const ROUND: usize = 48;
    const MAX_ROUNDS: usize = 8;
    let mut served = 0usize;
    let mut converged_after = None;
    for round in 0..MAX_ROUNDS {
        for r in 0..ROUND {
            let x = input(coo.n_cols, served + r);
            let resp = adaptive.product(0, x.clone()).expect("no request may be dropped");
            refs.check(&resp, &x, &format!("convergence request {}", served + r));
        }
        served += ROUND;
        let stats = adaptive.stats().unwrap();
        if stats.per_matrix[0].format == Some(best_fmt) {
            converged_after = Some(round + 1);
            break;
        }
    }
    let stats = adaptive.stats().unwrap();
    let rounds = converged_after.unwrap_or_else(|| {
        panic!(
            "router must converge to {best_fmt} within {MAX_ROUNDS} rounds \
             (stats: v{}, retrains {}, migrations {}, format {:?}, arms {:?})",
            stats.router_version,
            stats.retrains,
            stats.migrations,
            stats.per_matrix[0].format,
            online.arms(&features::extract_coo(&coo)),
        )
    });
    println!("converged to {best_fmt} after {rounds} round(s), router v{}", stats.router_version);
    assert!(stats.router_version >= 2, "convergence implies at least one hot-swap");
    assert!(stats.retrains >= 1);
    assert!(stats.migrations >= 1, "the registered matrix must have migrated");
    assert!(stats.explored_requests > 0, "exploration produced the counterfactual labels");

    // Measurement phase: anneal exploration to zero (the steady-state
    // serving posture) and compare mean modeled objective per request.
    online.set_explore_rate(0.0);
    let frozen_before = frozen.stats().unwrap();
    let adaptive_before = adaptive.stats().unwrap();
    const MEASURE: usize = 64;
    for r in 0..MEASURE {
        let x = input(coo.n_cols, 100_000 + r);
        let a = adaptive.product(0, x.clone()).expect("adaptive pool serves");
        let f = frozen.product(0, x.clone()).expect("frozen pool serves");
        refs.check(&a, &x, &format!("adaptive measurement request {r}"));
        refs.check(&f, &x, &format!("frozen measurement request {r}"));
    }
    let frozen_after = frozen.stats().unwrap();
    let adaptive_after = adaptive.stats().unwrap();
    let mean = |before: &auto_spmv::serve::PoolStats, after: &auto_spmv::serve::PoolStats| {
        (after.total_energy_j - before.total_energy_j) / MEASURE as f64
    };
    let frozen_mean = mean(&frozen_before, &frozen_after);
    let adaptive_mean = mean(&adaptive_before, &adaptive_after);
    println!(
        "mean modeled energy/request: frozen {frozen_mean:.3e} J, adaptive {adaptive_mean:.3e} J"
    );
    assert!(
        adaptive_mean < 0.995 * frozen_mean,
        "the converged router must measurably beat the frozen baseline \
         (adaptive {adaptive_mean:.3e} vs frozen {frozen_mean:.3e})"
    );
    // and the converged pool's decisions all ride the better format now
    let m = &adaptive_after.per_matrix[0];
    let new_chosen = m.chosen_by_format[best_fmt.class_id()];
    assert!(new_chosen >= MEASURE as u64, "steady-state traffic must ride {best_fmt}");
}

// ---------------------------------------------------------------------
// The joint (format, knob) acceptance end-to-end: a workload whose
// modeled-best compile knob differs from the serving default, served
// through the joint closed loop, converges to the modeled-best knob of
// its serving format within bounded rounds (knob migration on
// hot-swap), beats the format-only loop's steady-state energy, covers
// the UCB exploration path, and drops/corrupts zero requests.
// ---------------------------------------------------------------------

/// Modeled energy per (format, quantized knob arm) at the serving
/// conversion parameters — the joint ground-truth grid.
fn joint_energy_grid(coo: &Coo, convert: ConvertParams) -> Vec<[f64; bandit::N_KNOBS]> {
    let csr = coo_to_csr(coo);
    let arch = turing_gtx1650m();
    Format::ALL
        .iter()
        .map(|fmt| {
            let prof = profile(&csr, *fmt, convert);
            std::array::from_fn(|a| {
                let cfg = bandit::knob_arm(a).config_for(*fmt);
                simulate(&arch, &prof, &cfg).0.energy_j
            })
        })
        .collect()
}

#[test]
fn joint_knob_migration_converges_and_beats_format_only_router() {
    let objective = Objective::Energy;
    let (_, ds, overhead) = toy_setup(&["eu-2005", "wiki-talk-temporal"], objective);
    let convert = PoolConfig::default().convert;
    let default_arm = bandit::knob_index(CompileChoice::serving_default());

    // Candidates sized so the default TB (256) underfills the SMs: the
    // modeled-best knob then differs from the default for EVERY format
    // (grid-fill starvation, gpusim §4 obs. 1). Pick the one with the
    // largest joint-vs-(format-only-at-default) gap.
    let mut rng = Rng::new(0x701);
    let candidates: Vec<Coo> = vec![
        patterns::diagonals(&mut rng, 1000, &[-24, 0, 24, -48, 48, -72, 72], 0.98),
        patterns::banded(&mut rng, 1200, 24, 14.0),
        patterns::diagonals(&mut rng, 900, &[0, 1, -1, 32, -32, 64, -64], 0.99),
    ];
    let (coo, grid) = candidates
        .into_iter()
        .map(|c| {
            let g = joint_energy_grid(&c, convert);
            (c, g)
        })
        .min_by(|(_, ga), (_, gb)| {
            let gap = |g: &Vec<[f64; bandit::N_KNOBS]>| {
                let joint_best =
                    g.iter().flat_map(|r| r.iter()).fold(f64::INFINITY, |a, b| a.min(*b));
                let fo_best = g.iter().map(|r| r[default_arm]).fold(f64::INFINITY, f64::min);
                joint_best / fo_best
            };
            gap(ga).total_cmp(&gap(gb))
        })
        .unwrap();
    let joint_best = grid.iter().flat_map(|r| r.iter()).fold(f64::INFINITY, |a, b| a.min(*b));
    let format_only_best = grid.iter().map(|r| r[default_arm]).fold(f64::INFINITY, f64::min);
    assert!(
        joint_best < 0.99 * format_only_best,
        "test premise: some (format, knob) pair must beat every format at the default \
         knobs by >= 1% (joint {joint_best:.3e} vs format-only {format_only_best:.3e})"
    );
    for (fi, row) in grid.iter().enumerate() {
        let best = row.iter().fold(f64::INFINITY, |a, b| a.min(*b));
        assert!(
            best < row[default_arm] * 0.999,
            "test premise: format {fi}: the modeled-best knob must differ from the default"
        );
    }

    let stale = Arc::new(stale_csr_router(&ds, objective, overhead.clone()));
    let refs = FormatRefs::new(&coo, convert);
    let hint = 1_000_000_000_000u64;

    // Two adaptive pools over identical workloads: the joint loop and
    // the PR 2/3 format-only loop (its own seed-identical schedule).
    let mk_online = |joint: bool| {
        Online::start(
            OnlineConfig {
                explore_rate: 0.5,
                retrain_every: 48,
                seed: 0x70B5,
                background: false,
                joint_knobs: joint,
                ucb_floor: 1,
                ..OnlineConfig::default()
            },
            stale.clone(),
            objective,
            Some(Trainer::new(ds.clone(), objective, overhead.clone(), turing_gtx1650m().name)),
        )
    };
    let joint_online = mk_online(true);
    let joint_pool =
        Pool::start_adaptive(joint_online.clone(), BackendSpec::Native, single_worker_cfg());
    let fo_online = mk_online(false);
    let fo_pool = Pool::start_adaptive(fo_online.clone(), BackendSpec::Native, single_worker_cfg());
    assert_eq!(joint_pool.register(0, coo.clone(), hint).unwrap(), Format::Csr);
    assert_eq!(fo_pool.register(0, coo.clone(), hint).unwrap(), Format::Csr);

    // Convergence: rounds of sequential requests on both pools; every
    // response is checked bit-identical against its executed format's
    // native reference, so a corrupted product anywhere — including
    // across knob hot-swaps — fails.
    const ROUND: usize = 48;
    const MAX_ROUNDS: usize = 10;
    let mut served = 0usize;
    let mut converged_after = None;
    for round in 0..MAX_ROUNDS {
        for r in 0..ROUND {
            let x = input(coo.n_cols, served + r);
            let a = joint_pool.product(0, x.clone()).expect("no request may be dropped");
            refs.check(&a, &x, &format!("joint request {}", served + r));
            let b = fo_pool.product(0, x.clone()).expect("no request may be dropped");
            refs.check(&b, &x, &format!("format-only request {}", served + r));
        }
        served += ROUND;
        let round_stats = joint_pool.stats().unwrap();
        let m = &round_stats.per_matrix[0];
        if let (Some(fmt), Some(knobs)) = (m.format, m.knobs) {
            let row = &grid[fmt.class_id()];
            let row_best = row.iter().fold(f64::INFINITY, |a, b| a.min(*b));
            let served_arm = bandit::knob_index(knobs);
            // converged once the serving knob is the modeled-best arm
            // of the serving format (ties tolerated) and is no longer
            // the default arm
            if served_arm != default_arm && row[served_arm] <= row_best * 1.001 {
                converged_after = Some(round + 1);
                break;
            }
        }
    }
    let stats = joint_pool.stats().unwrap();
    let rounds = converged_after.unwrap_or_else(|| {
        panic!(
            "joint loop must converge to the modeled-best knob within {MAX_ROUNDS} rounds \
             (v{}, retrains {}, fmt migrations {}, knob migrations {}, serving {:?} @ {:?})",
            stats.router_version,
            stats.retrains,
            stats.migrations,
            stats.knob_migrations,
            stats.per_matrix[0].format,
            stats.per_matrix[0].knobs,
        )
    });
    println!(
        "joint loop converged in {rounds} round(s): {:?} @ {:?}, v{}, {} knob migrations",
        stats.per_matrix[0].format,
        stats.per_matrix[0].knobs,
        stats.router_version,
        stats.knob_migrations
    );
    assert!(stats.router_version >= 2, "convergence implies at least one hot-swap");
    assert!(stats.knob_migrations >= 1, "the registered matrix must have knob-migrated");
    assert!(
        joint_online.ucb_routes() > 0,
        "with ucb_floor 1 and a full arm sweep, the UCB scorer must have engaged"
    );

    // Steady state: anneal exploration on both loops, serve the same
    // measurement workload, compare modeled energy per request.
    joint_online.set_explore_rate(0.0);
    fo_online.set_explore_rate(0.0);
    const MEASURE: usize = 64;
    let joint_before = joint_pool.stats().unwrap();
    let fo_before = fo_pool.stats().unwrap();
    for r in 0..MEASURE {
        let x = input(coo.n_cols, 200_000 + r);
        let a = joint_pool.product(0, x.clone()).expect("joint pool serves");
        let b = fo_pool.product(0, x.clone()).expect("format-only pool serves");
        refs.check(&a, &x, &format!("joint measurement request {r}"));
        refs.check(&b, &x, &format!("format-only measurement request {r}"));
    }
    let joint_after = joint_pool.stats().unwrap();
    let fo_after = fo_pool.stats().unwrap();
    let mean = |b: &auto_spmv::serve::PoolStats, a: &auto_spmv::serve::PoolStats| {
        (a.total_energy_j - b.total_energy_j) / MEASURE as f64
    };
    let joint_mean = mean(&joint_before, &joint_after);
    let fo_mean = mean(&fo_before, &fo_after);
    println!(
        "steady-state energy/request: joint {joint_mean:.3e} J, format-only {fo_mean:.3e} J"
    );
    assert!(
        joint_mean < fo_mean * 0.999,
        "the joint decision must beat the format-only router's mean energy \
         (joint {joint_mean:.3e} vs format-only {fo_mean:.3e})"
    );
    assert_eq!(
        joint_after.requests, fo_after.requests,
        "both pools served every request"
    );
}

// ---------------------------------------------------------------------
// Knob-swap safety: in-flight pipelined requests complete with
// bit-identical results across a JOINT policy upgrade that migrates
// only the compile knobs (format unchanged).
// ---------------------------------------------------------------------
#[test]
fn inflight_requests_survive_knob_hot_swap_bit_identically() {
    let objective = Objective::EnergyEff;
    let (router, ds, _) = toy_setup(&["rim", "eu-2005", "shar_te2-b3"], objective);
    let router = Arc::new(router);
    let pool = Pool::start(
        router.clone(),
        BackendSpec::Native,
        PoolConfig { workers: 2, batch_window: Duration::from_micros(100), ..Default::default() },
    );
    let names = ["rim", "eu-2005", "shar_te2-b3"];
    let mats: Vec<Coo> =
        names.iter().map(|n| auto_spmv::gen::by_name(n).unwrap().generate(1)).collect();
    let refs: Vec<FormatRefs> =
        mats.iter().map(|coo| FormatRefs::new(coo, PoolConfig::default().convert)).collect();
    for (id, coo) in mats.iter().enumerate() {
        pool.register(id as u64, coo.clone(), 10_000).unwrap();
    }

    // A knob policy that forces a NON-default choice for every format,
    // paired with the SAME router: the swap migrates knobs only.
    let forced = CompileChoice {
        tb_size: 64,
        maxrregcount: 32,
        mem: auto_spmv::gpusim::MemConfig::PreferL1,
    };
    let ex: Vec<(Format, auto_spmv::dataset::labels::Example)> = Format::ALL
        .iter()
        .map(|f| {
            let feats = ds.records[0].features.to_scaled_vec();
            let mut fv = feats;
            fv.push(0.0);
            (
                *f,
                auto_spmv::coordinator::compile_time::knob_example(
                    "forced",
                    "GTX1650m-Turing",
                    fv,
                    &forced.config_for(*f),
                    1.0,
                ),
            )
        })
        .collect();
    let knobs = Arc::new(KnobPolicy::train(objective, "GTX1650m-Turing", &ex));

    // pipeline a burst, install the joint policy while it is in
    // flight, then pipeline a second burst
    let mut pending = Vec::new();
    for r in 0..32 {
        let id = r % mats.len();
        let x = input(mats[id].n_cols, r);
        pending.push((id, x.clone(), pool.product_async(id as u64, x).unwrap()));
    }
    let v = pool.router().install_policy(Arc::new(Policy::joint(router.clone(), knobs)));
    assert_eq!(v, 2);
    for r in 32..64 {
        let id = r % mats.len();
        let x = input(mats[id].n_cols, r);
        pending.push((id, x.clone(), pool.product_async(id as u64, x).unwrap()));
    }
    let mut completed = 0;
    for (id, x, rx) in pending {
        let resp = rx.recv().expect("pool alive").expect("request must not be dropped");
        refs[id].check(&resp, &x, "in-flight request across knob hot-swap");
        completed += 1;
    }
    assert_eq!(completed, 64);
    let stats = pool.stats().unwrap();
    assert_eq!(stats.router_version, 2);
    assert_eq!(stats.requests, 64);
    assert_eq!(
        stats.migrations, 0,
        "same router, same format decisions: no format migration"
    );
    assert_eq!(
        stats.knob_migrations as usize,
        mats.len(),
        "every registered matrix must have re-decided its knobs"
    );
    for m in &stats.per_matrix {
        assert_eq!(m.knobs, Some(forced), "the forced knob policy must be serving");
    }
}

// ---------------------------------------------------------------------
// Hot-swap safety: in-flight pipelined requests complete with
// bit-identical results across a router upgrade.
// ---------------------------------------------------------------------
#[test]
fn inflight_requests_survive_hot_swap_bit_identically() {
    let (router_a, _, _) = toy_setup(&["rim", "eu-2005", "shar_te2-b3"], Objective::EnergyEff);
    let pool = Pool::start(
        Arc::new(router_a),
        BackendSpec::Native,
        PoolConfig { workers: 2, batch_window: Duration::from_micros(100), ..Default::default() },
    );
    let names = ["rim", "eu-2005", "shar_te2-b3"];
    let mats: Vec<Coo> =
        names.iter().map(|n| auto_spmv::gen::by_name(n).unwrap().generate(1)).collect();
    let refs: Vec<FormatRefs> =
        mats.iter().map(|coo| FormatRefs::new(coo, PoolConfig::default().convert)).collect();
    for (id, coo) in mats.iter().enumerate() {
        pool.register(id as u64, coo.clone(), 10_000).unwrap();
    }

    // pipeline a burst, install the new router while it is in flight,
    // then pipeline a second burst
    let mut pending = Vec::new();
    for r in 0..32 {
        let id = r % mats.len();
        let x = input(mats[id].n_cols, r);
        pending.push((id, x.clone(), pool.product_async(id as u64, x).unwrap()));
    }
    let v = pool.router().install(Arc::new(toy_setup(&names, Objective::Latency).0));
    assert_eq!(v, 2);
    for r in 32..64 {
        let id = r % mats.len();
        let x = input(mats[id].n_cols, r);
        pending.push((id, x.clone(), pool.product_async(id as u64, x).unwrap()));
    }
    let mut completed = 0;
    for (id, x, rx) in pending {
        let resp = rx.recv().expect("pool alive").expect("request must not be dropped");
        refs[id].check(&resp, &x, "in-flight request across hot-swap");
        completed += 1;
    }
    assert_eq!(completed, 64);
    let stats = pool.stats().unwrap();
    assert_eq!(stats.router_version, 2);
    assert_eq!(stats.requests, 64);
}

// ---------------------------------------------------------------------
// Session × hot-swap: a drift-triggered migration while a session
// iterates must DEFER to the session boundary. The pinned decision and
// its converted form stay untouched (bit-identical to a frozen pool's
// session under cache-eviction pressure), and the deferred migration
// lands when the last session on the matrix closes. DESIGN.md §9.
// ---------------------------------------------------------------------
#[test]
fn mid_session_hot_swap_defers_and_lands_at_session_close() {
    let objective = Objective::Energy;
    let (_, ds, overhead) = toy_setup(&["eu-2005", "wiki-talk-temporal"], objective);
    let convert = PoolConfig::default().convert;
    let mut rng = Rng::new(0x0D12F7);
    // The drifted-workload candidate most favoring a non-CSR format.
    let candidates: Vec<Coo> = vec![
        patterns::diagonals(&mut rng, 1000, &[-24, 0, 24, -48, 48, -72, 72], 0.98),
        patterns::banded(&mut rng, 900, 10, 6.0),
        patterns::diagonals(&mut rng, 700, &[-1, 0, 1, -32, 32], 0.99),
        patterns::diagonals(&mut rng, 1200, &[0, 1, -1, 64, -64, 128, -128, 256, -256], 0.97),
    ];
    let (coo, best_fmt) = candidates
        .into_iter()
        .map(|c| {
            let e = modeled_energy_per_format(&c, convert);
            let best = Format::ALL
                .into_iter()
                .min_by(|a, b| e[a.class_id()].total_cmp(&e[b.class_id()]))
                .unwrap();
            let gap = e[best.class_id()] / e[Format::Csr.class_id()];
            (c, best, gap)
        })
        .min_by(|(_, _, ga), (_, _, gb)| ga.total_cmp(gb))
        .map(|(c, b, _)| (c, b))
        .unwrap();
    assert_ne!(best_fmt, Format::Csr, "test premise: drift must favor a non-CSR format");

    let stale = Arc::new(stale_csr_router(&ds, objective, overhead.clone()));
    let refs = FormatRefs::new(&coo, convert);
    let hint = 1_000_000_000_000u64;

    // Frozen reference pool: its session can never migrate.
    let frozen = Pool::start(stale.clone(), BackendSpec::Native, single_worker_cfg());
    frozen.register(0, coo.clone(), hint).unwrap();
    let online = Online::start(
        OnlineConfig {
            explore_rate: 0.25,
            retrain_every: 48,
            seed: 0x5EED,
            background: false,
            joint_knobs: false,
            ..OnlineConfig::default()
        },
        stale.clone(),
        objective,
        Some(Trainer::new(ds.clone(), objective, overhead, turing_gtx1650m().name)),
    );
    // Tiny cache: probe registrations + per-request traffic keep
    // thrashing it, so the session's pinned conversion only survives
    // through its owning handle — the eviction-protection contract.
    let adaptive = Pool::start_adaptive(
        online.clone(),
        BackendSpec::Native,
        PoolConfig { workers: 1, cache_capacity: 2, ..PoolConfig::default() },
    );
    assert_eq!(adaptive.register(0, coo.clone(), hint).unwrap(), Format::Csr);

    // Both sessions pin the decision in force at open time: CSR.
    let sess_a = adaptive.open_session(0).unwrap();
    let sess_f = frozen.open_session(0).unwrap();
    let x0 = input(coo.n_cols, 7);
    sess_a.write(x0.clone()).unwrap();
    sess_f.write(x0.clone()).unwrap();

    // Convergence phase: per-request traffic drives exploration and
    // retraining while the sessions iterate. A probe registration per
    // round exposes the CURRENT router's decision for this structure
    // (the pinned matrix's own registry entry is frozen by deferral).
    const ROUND: usize = 48;
    const MAX_ROUNDS: usize = 8;
    let mut converged = false;
    for round in 0..MAX_ROUNDS {
        for r in 0..ROUND {
            let x = input(coo.n_cols, round * ROUND + r);
            let resp = adaptive.product(0, x.clone()).expect("no request may be dropped");
            refs.check(&resp, &x, &format!("per-request traffic round {round} req {r}"));
        }
        sess_a.step_n(4).expect("session must keep stepping across retrains");
        sess_f.step_n(4).unwrap();
        let probe = adaptive.register(100 + round as u64, coo.clone(), hint).unwrap();
        if probe == best_fmt {
            converged = true;
            break;
        }
    }
    let stats = adaptive.stats().unwrap();
    assert!(
        converged,
        "router must converge to {best_fmt} within {MAX_ROUNDS} rounds \
         (v{}, retrains {})",
        stats.router_version, stats.retrains
    );
    assert!(stats.router_version >= 2, "convergence implies a hot-swap happened mid-session");
    assert!(stats.evictions > 0, "premise: the tiny cache must have thrashed: {stats:?}");
    assert_eq!(stats.active_sessions, 1, "frozen pool's session is not in these stats");
    // THE deferral contract: the swap landed, the registry re-decided —
    // but the session-pinned matrix kept its open-time decision.
    assert_eq!(
        stats.per_matrix[0].format,
        Some(Format::Csr),
        "migration must defer while a session is open on the matrix"
    );

    // The adaptive session's chain must be bit-identical to the frozen
    // pool's: same pinned format, same conversion, untouched by the
    // swap or by eviction pressure.
    let ya = sess_a.read().unwrap();
    let yf = sess_f.read().unwrap();
    assert_eq!(ya, yf, "session chain across a hot-swap must match the frozen pool's");

    // Session close is the boundary: the deferred migration lands.
    let migrations_before = stats.migrations;
    drop(sess_a);
    let stats = adaptive.stats().unwrap();
    assert_eq!(stats.active_sessions, 0);
    assert_eq!(
        stats.per_matrix[0].format,
        Some(best_fmt),
        "the deferred migration must land when the last session closes"
    );
    assert!(stats.migrations > migrations_before, "landing must count as a migration");

    // And post-migration per-request traffic serves correctly.
    for r in 0..4 {
        let x = input(coo.n_cols, 900_000 + r);
        let resp = adaptive.product(0, x.clone()).unwrap();
        refs.check(&resp, &x, &format!("post-migration request {r}"));
    }
}

// ---------------------------------------------------------------------
// Observability: the control-plane journal records a drift-triggered
// adaptation as the causal chain drift -> retrain(drift) ->
// hot_swap(drift) -> migration, in sequence order; its per-kind counts
// agree with the pool counters; and a second identically seeded run
// produces the identical deterministic key sequence (wall-clock fields
// excluded by design). DESIGN.md §10.2.
// ---------------------------------------------------------------------

/// One seeded drift scenario: a pool warmed on a power-law reference
/// population whose traffic then shifts to a stencil the stale router
/// mis-serves. The request schedule is FIXED (no data-dependent early
/// exit), so two runs make identical decisions end to end.
fn drift_scenario() -> (Vec<Event>, PoolStats) {
    let objective = Objective::Energy;
    let (_, ds, overhead) = toy_setup(&["eu-2005", "wiki-talk-temporal"], objective);
    let convert = PoolConfig::default().convert;
    let mut rng = Rng::new(0x0D12F7);
    // Reference population: a power-law graph like the offline corpus.
    let reference = patterns::powerlaw(&mut rng, 600, 600, 2.0, 3.0, 24);
    // Drifted population: among stencil candidates, the one the gpusim
    // ground truth most favors away from CSR (robust to model tweaks,
    // same selection as the convergence e2e above).
    let candidates: Vec<Coo> = vec![
        patterns::diagonals(&mut rng, 1000, &[-24, 0, 24, -48, 48, -72, 72], 0.98),
        patterns::banded(&mut rng, 900, 10, 6.0),
        patterns::diagonals(&mut rng, 1200, &[0, 1, -1, 64, -64, 128, -128, 256, -256], 0.97),
    ];
    let (drifted, best_fmt) = candidates
        .into_iter()
        .map(|c| {
            let e = modeled_energy_per_format(&c, convert);
            let best = Format::ALL
                .into_iter()
                .min_by(|a, b| e[a.class_id()].total_cmp(&e[b.class_id()]))
                .unwrap();
            let gap = e[best.class_id()] / e[Format::Csr.class_id()];
            (c, best, gap)
        })
        .min_by(|(_, _, ga), (_, _, gb)| ga.total_cmp(gb))
        .map(|(c, b, _)| (c, b))
        .unwrap();
    assert_ne!(best_fmt, Format::Csr, "test premise: drift must favor a non-CSR format");

    let stale = Arc::new(stale_csr_router(&ds, objective, overhead.clone()));
    let online = Online::start(
        OnlineConfig {
            explore_rate: 0.25,
            retrain_every: 48,
            seed: 0x5EED,
            background: false,
            joint_knobs: false,
            // small windows so the population shift trips the detector
            // well before the 48-request cadence would fire
            drift: DriftConfig { window: 16, threshold: 4.0 },
            ..OnlineConfig::default()
        },
        stale,
        objective,
        Some(Trainer::new(ds.clone(), objective, overhead, turing_gtx1650m().name)),
    );
    let pool = Pool::start_adaptive(online, BackendSpec::Native, single_worker_cfg());
    let hint = 1_000_000_000_000u64;
    pool.register(0, reference.clone(), hint).unwrap();
    pool.register(1, drifted.clone(), hint).unwrap();

    // Phase 1: reference traffic fills the detector's reference window.
    for r in 0..16 {
        let x = input(reference.n_cols, r);
        pool.product(0, x).expect("reference traffic");
    }
    // Phase 2: the population shifts. The 16th drifted request fills
    // the current window and fires the rising edge (an early retrain at
    // ~32 observations, before the cadence); the rest of the fixed
    // schedule lets cadence retrains converge the router so a
    // migration lands.
    for r in 0..336 {
        let x = input(drifted.n_cols, 1000 + r);
        pool.product(1, x).expect("drifted traffic");
    }
    let stats = pool.stats().expect("stats");
    (pool.events(), stats)
}

#[test]
fn journal_records_the_drift_causal_chain_deterministically() {
    let (events, stats) = drift_scenario();

    // Dense, ordered, nothing dropped at this volume.
    assert!(events.len() < DEFAULT_JOURNAL_CAP, "scenario must stay under the ring cap");
    assert_eq!(stats.events_dropped, 0);
    assert_eq!(stats.events_total, events.len() as u64);
    for (i, e) in events.iter().enumerate() {
        assert_eq!(e.seq, i as u64, "seq must be dense and in ring order");
    }

    // The journal's per-kind counts agree with the counters.
    let count = |name: &str| events.iter().filter(|e| e.kind.name() == name).count() as u64;
    assert_eq!(count("hot_swap"), stats.router_version - 1);
    assert_eq!(count("retrain"), stats.retrains);
    // joint_knobs off: every migration event is a format migration
    assert_eq!(count("migration"), stats.migrations);
    assert!(count("explored") > 0, "exploration at 25% must journal counterfactuals");
    assert_eq!(count("session_open") + count("session_close"), 0, "no sessions in this run");

    // The causal chain, in sequence order.
    let drift_at = events
        .iter()
        .position(|e| matches!(&e.kind, EventKind::Drift { .. }))
        .expect("the population shift must journal a drift event");
    let retrain_at = events
        .iter()
        .position(|e| {
            matches!(&e.kind, EventKind::Retrain { trigger: SwapTrigger::Drift, .. })
        })
        .expect("the drift edge must trigger an early retrain");
    let swap_at = events
        .iter()
        .position(|e| matches!(&e.kind, EventKind::HotSwap { trigger: SwapTrigger::Drift, .. }))
        .expect("the drift retrain must hot-swap the router");
    let migration_at = events
        .iter()
        .position(|e| matches!(&e.kind, EventKind::Migration { .. }))
        .expect("convergence must migrate a registered matrix");
    assert!(
        drift_at < retrain_at && retrain_at < swap_at && swap_at < migration_at,
        "causal order violated: drift@{drift_at} retrain@{retrain_at} \
         hot_swap@{swap_at} migration@{migration_at}"
    );
    let EventKind::HotSwap { version, .. } = events[swap_at].kind else { unreachable!() };
    assert_eq!(version, 2, "the drift-triggered swap must be the first router upgrade");
    // every migration cites the upgrade that re-decided it
    for e in &events {
        if let EventKind::Migration { decided_by, .. } = e.kind {
            assert!(decided_by >= version, "migrations follow from swaps");
        }
    }
    // and the drifted matrix itself moved off the stale CSR decision
    assert!(
        events.iter().any(|e| matches!(
            &e.kind,
            EventKind::Migration { matrix: 1, from, .. } if from.format == Format::Csr
        )),
        "matrix 1 must migrate off the stale CSR decision"
    );

    // Determinism: an identically seeded run yields the identical key
    // sequence (Event::key excludes wall-clock fields by design).
    let (events2, _) = drift_scenario();
    let keys: Vec<String> = events.iter().map(|e| e.kind.key()).collect();
    let keys2: Vec<String> = events2.iter().map(|e| e.kind.key()).collect();
    assert_eq!(keys, keys2, "seeded journal must be run-to-run deterministic");
}
