//! End-to-end pipeline integration: corpus -> dataset -> labels -> tuned
//! models -> both optimization modes, without the PJRT layer (covered in
//! runtime_integration.rs). This is the §5 pipeline exercised as a whole.

use auto_spmv::automl::tuner::{tune_family, Family};
use auto_spmv::coordinator::overhead::{OverheadModel, OverheadSample};
use auto_spmv::coordinator::{CompileTimeOptimizer, RunTimeOptimizer};
use auto_spmv::dataset::labels::{self, Target};
use auto_spmv::dataset::{build, store, BuildOptions};
use auto_spmv::gen;
use auto_spmv::gpusim::{KernelConfig, Objective};
use auto_spmv::ml::metrics::accuracy;
use auto_spmv::ml::Classifier;

fn subset() -> Vec<String> {
    ["rim", "eu-2005", "crankseg_1", "parabolic_fem", "wiki-talk-temporal",
     "consph", "amazon0601", "pkustk04"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

fn toy_overhead() -> OverheadModel {
    let samples: Vec<OverheadSample> = (1..12)
        .map(|k| OverheadSample {
            n: k as f64 * 800.0,
            nnz: k as f64 * 16_000.0,
            f_latency_s: k as f64 * 8e-4,
            c_latency_s: k as f64 * 1.6e-3,
        })
        .collect();
    OverheadModel::train(&samples)
}

#[test]
fn full_pipeline_compile_and_runtime_modes() {
    let ds = build(&BuildOptions { only: Some(subset()), ..Default::default() });
    assert_eq!(ds.len(), 8 * 2 * KernelConfig::sweep_all().len());

    for obj in Objective::ALL {
        let ex = labels::examples(&ds, obj);
        assert_eq!(ex.len(), 16);

        // compile-time mode improves (or matches) the default on every
        // training matrix
        let opt = CompileTimeOptimizer::train_on_examples(&ex, obj);
        for e in &ex {
            let entry = gen::by_name(&e.matrix).unwrap();
            let f = auto_spmv::features::extract_csr(&entry.generate_csr(1));
            let choice = opt.predict(&f, &e.arch);
            let slice = ds.slice(&e.matrix, &e.arch);
            let chosen = slice.iter().find(|r| r.config == choice.to_config()).unwrap();
            let chosen_v = obj.value(&chosen.m);
            // labels canonicalize near-ties within 0.5% (dataset::labels);
            // the predicted config may sit inside that band
            let tol_ok = if obj.minimize() {
                chosen_v <= e.default_value * 1.006
            } else {
                chosen_v >= e.default_value * 0.994
            };
            assert!(
                tol_ok,
                "{} {} {}: predicted config {} loses to default ({} vs {})",
                e.matrix,
                e.arch,
                obj.name(),
                choice.to_config(),
                chosen_v,
                e.default_value,
            );
        }

        // run-time mode: decisions are sane on training matrices
        let rt = RunTimeOptimizer::train(&ds, obj, toy_overhead());
        for name in subset() {
            let coo = gen::by_name(&name).unwrap().generate(1);
            let d = rt.decide(&coo, 1000);
            assert!(d.overhead.total() >= 0.0);
            assert!(d.est_best > 0.0);
        }
    }
}

#[test]
fn tuned_decision_tree_reaches_table5_accuracy_on_train() {
    // the paper reports 100% accuracy (Table 5); on the training split a
    // tuned decision tree must memorize the compile-parameter labels
    let ds = build(&BuildOptions { only: Some(subset()), ..Default::default() });
    let ex = labels::examples(&ds, Objective::Latency);
    for target in [Target::TbSize, Target::MaxRegCount, Target::MemConfig] {
        let (x, y) = labels::to_xy(&ex, target);
        let tuned = tune_family(Family::DecisionTree, &x, &y, 8, 3);
        let acc = accuracy(&y, &tuned.model.predict(&x));
        assert!(acc >= 0.9, "{}: train accuracy {acc}", target.name());
    }
}

#[test]
fn dataset_roundtrip_preserves_trained_behavior() {
    let ds = build(&BuildOptions {
        only: Some(vec!["rim".into(), "consph".into()]),
        both_archs: false,
        ..Default::default()
    });
    let tmp = std::env::temp_dir().join("autospmv_pipeline_ds.tsv");
    store::save(&ds, &tmp).unwrap();
    let back = store::load(&tmp).unwrap();
    std::fs::remove_file(&tmp).ok();

    let a = CompileTimeOptimizer::train(&ds, Objective::Energy);
    let b = CompileTimeOptimizer::train(&back, Objective::Energy);
    let f = auto_spmv::features::extract_csr(&gen::by_name("rim").unwrap().generate_csr(1));
    assert_eq!(a.predict(&f, "GTX1650m-Turing"), b.predict(&f, "GTX1650m-Turing"));
}

#[test]
fn cross_arch_prediction_transfers() {
    // Fig. 12's premise: Turing-trained models predict well for Pascal
    let ds = build(&BuildOptions { only: Some(subset()), ..Default::default() });
    let obj = Objective::Latency;
    // train on Turing records only
    let turing_only = auto_spmv::dataset::Dataset {
        records: ds.records.iter().filter(|r| r.arch.contains("Turing")).cloned().collect(),
    };
    let opt = CompileTimeOptimizer::train(&turing_only, obj);
    // evaluate predicted configs on the Pascal half
    for name in subset() {
        let f = auto_spmv::features::extract_csr(&gen::by_name(&name).unwrap().generate_csr(1));
        // trained on Turing only: the model has never seen the Pascal flag
        let choice = opt.predict(&f, "GTX1650m-Turing");
        let slice = ds.slice(&name, "GTX1080-Pascal");
        let chosen = slice.iter().find(|r| r.config == choice.to_config()).unwrap();
        let best = slice
            .iter()
            .filter(|r| r.config.format == auto_spmv::sparse::Format::Csr)
            .map(|r| r.m.latency_s)
            .fold(f64::INFINITY, f64::min);
        // within 25% of the per-device optimum (paper: ~2% on real GPUs;
        // our two profiles differ more than their two boards did)
        assert!(
            chosen.m.latency_s <= 1.25 * best,
            "{name}: transferred config {} vs best {best}",
            chosen.m.latency_s
        );
    }
}
