//! CLI-level serve tests: spawn the real `auto-spmv` binary and pin
//! its stream contract — stdout is the machine-readable report stream
//! (banner, final ledger, tables, dump confirmations), the in-flight
//! `--stats-every` ticker goes to stderr — plus the SLO / flight
//! recorder surface (`--slo-p99-us`, `--slo-miss-budget`,
//! `--flight-out`).
//!
//! Each test builds a tiny 3-matrix dataset first and hands it to the
//! binary via `--set dataset_path=...`, so the serve run trains its
//! router on that instead of sweeping the full 30-matrix corpus.

use auto_spmv::dataset::{build, store, BuildOptions};
use std::path::PathBuf;
use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_auto-spmv")
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("auto_spmv_cli_{}_{name}", std::process::id()))
}

/// Build + save a dataset over exactly the matrices `serve` registers.
fn small_dataset(tag: &str) -> PathBuf {
    let path = tmp(&format!("{tag}_dataset.tsv"));
    let only = ["shar_te2-b3", "rim", "bcsstk32"].iter().map(|s| s.to_string()).collect();
    let ds = build(&BuildOptions { only: Some(only), ..Default::default() });
    store::save(&ds, &path).expect("save small dataset");
    path
}

#[test]
fn serve_progress_ticker_goes_to_stderr_not_stdout() {
    let ds = small_dataset("ticker");
    let out = Command::new(bin())
        .args([
            "serve",
            "--requests",
            "8",
            "--workers",
            "1",
            "--stats-every",
            "4",
            "--set",
            &format!("dataset_path={}", ds.display()),
        ])
        .output()
        .expect("spawn auto-spmv serve");
    assert!(out.status.success(), "serve failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("[4/8]"), "ticker on stderr: {stderr}");
    assert!(stderr.contains("[8/8]"), "ticker on stderr: {stderr}");
    assert!(!stdout.contains("[4/8]"), "ticker must not pollute stdout: {stdout}");
    assert!(stdout.contains("8 requests in"), "final ledger stays on stdout: {stdout}");
    let _ = std::fs::remove_file(&ds);
}

#[test]
fn serve_slo_flags_surface_status_and_dump_flight_records() {
    let ds = small_dataset("slo");
    let flight = tmp("flight.json");
    let _ = std::fs::remove_file(&flight);
    let out = Command::new(bin())
        .args([
            "serve",
            "--requests",
            "8",
            "--workers",
            "1",
            "--stats-every",
            "4",
            // a one-hour p99 target with a 100% miss budget: the engine
            // runs but never breaches, so the run is deterministic
            "--slo-p99-us",
            "3600000000",
            "--slo-miss-budget",
            "1.0",
            "--flight-out",
            flight.to_str().unwrap(),
            "--set",
            &format!("dataset_path={}", ds.display()),
        ])
        .output()
        .expect("spawn auto-spmv serve");
    assert!(out.status.success(), "serve failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("slo: p99 target 3600000000 us"), "config banner: {stdout}");
    assert!(stdout.contains("slo ok:"), "final SLO summary on stdout: {stdout}");
    assert!(stderr.contains("slo ok:"), "per-tick SLO line on stderr: {stderr}");
    assert!(stdout.contains("wrote flight records"), "{stdout}");
    let json = std::fs::read_to_string(&flight).expect("flight dump written");
    assert!(json.starts_with("[\n"), "{json}");
    assert!(json.contains("\"seq\":"), "live ring dumped without a breach: {json}");
    assert!(json.contains("\"deadline_missed\":false"), "{json}");
    let _ = std::fs::remove_file(&flight);
    let _ = std::fs::remove_file(&ds);
}
