//! Integration tests over the PJRT runtime: load real AOT artifacts,
//! execute every format's kernel, and check numerics against the native
//! Rust SpMV. Requires `make artifacts` (skipped with a notice if the
//! manifest is absent).

use auto_spmv::coordinator::overhead::{OverheadModel, OverheadSample};
use auto_spmv::coordinator::service::{BackendSpec, Service};
use auto_spmv::coordinator::RunTimeOptimizer;
use auto_spmv::dataset::{build, BuildOptions};
use auto_spmv::gen;
use auto_spmv::gpusim::Objective;
use auto_spmv::runtime::{default_artifacts_dir, Engine};
use auto_spmv::sparse::convert::{self, AnyFormat, ConvertParams};
use auto_spmv::sparse::{Format, SpMv};
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = default_artifacts_dir();
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} (run `make artifacts`)");
        None
    }
}

fn assert_close(got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        let scale = b.abs().max(1.0);
        assert!(
            (a - b).abs() <= tol * scale,
            "row {i}: got {a}, want {b} (tol {tol})"
        );
    }
}

/// A small matrix that fits the 256-row buckets.
fn small_csr() -> auto_spmv::sparse::Csr {
    let mut rng = auto_spmv::gen::Rng::new(77);
    let coo = auto_spmv::gen::patterns::banded(&mut rng, 200, 12, 6.0);
    convert::coo_to_csr(&coo)
}

#[test]
fn all_formats_match_native_numerics() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).expect("engine");
    let csr = small_csr();
    let x: Vec<f32> = (0..csr.n_cols).map(|i| ((i % 13) as f32 - 6.0) * 0.25).collect();
    let want = csr.spmv_alloc(&x);

    let params = ConvertParams { bell_bh: 8, bell_bw: 8, sell_h: 8 };
    for fmt in Format::ALL {
        let m = convert::convert(&csr, fmt, params);
        let got = engine
            .spmv(&m, &x, None)
            .unwrap_or_else(|e| panic!("{fmt}: {e:#}"));
        assert_close(&got, &want, 1e-4);
    }
    assert!(engine.exec_count >= 4);
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).expect("engine");
    let csr = small_csr();
    let x = vec![1.0f32; csr.n_cols];
    let m = convert::convert(&csr, Format::Ell, ConvertParams::default());
    engine.spmv(&m, &x, None).unwrap();
    let cached_after_one = engine.cached();
    for _ in 0..5 {
        engine.spmv(&m, &x, None).unwrap();
    }
    assert_eq!(engine.cached(), cached_after_one, "same variant must reuse the cache");
    assert_eq!(engine.exec_count, 6);
}

#[test]
fn knob_choice_selects_different_variants() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).expect("engine");
    let csr = small_csr();
    let x = vec![0.5f32; csr.n_cols];
    let m = convert::convert(&csr, Format::Ell, ConvertParams::default());
    let want = csr.spmv_alloc(&x);
    use auto_spmv::gpusim::MemConfig;
    // different knob mappings still compute the same product
    for choice in [
        (64u32, 16u32, MemConfig::Default),
        (1024, 128, MemConfig::PreferL1),
        (512, 64, MemConfig::PreferShared),
    ] {
        let got = engine.spmv(&m, &x, Some(choice)).unwrap();
        assert_close(&got, &want, 1e-4);
    }
    // at least two distinct executables were compiled for the choices
    assert!(engine.cached() >= 2, "cached {}", engine.cached());
}

#[test]
fn bigger_bucket_used_for_bigger_matrix() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).expect("engine");
    let mut rng = auto_spmv::gen::Rng::new(78);
    let coo = auto_spmv::gen::patterns::banded(&mut rng, 900, 10, 5.0);
    let csr = convert::coo_to_csr(&coo);
    let x: Vec<f32> = (0..csr.n_cols).map(|i| (i % 5) as f32).collect();
    let want = csr.spmv_alloc(&x);
    let m = convert::convert(&csr, Format::Ell, ConvertParams::default());
    let got = engine.spmv(&m, &x, None).expect("900-row matrix fits the 1024 bucket");
    assert_close(&got, &want, 1e-4);
}

#[test]
fn oversized_matrix_is_clean_error() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).expect("engine");
    let mut rng = auto_spmv::gen::Rng::new(79);
    let coo = auto_spmv::gen::patterns::uniform(&mut rng, 2000, 2000, 4.0);
    let csr = convert::coo_to_csr(&coo);
    let x = vec![1.0f32; 2000];
    let m = convert::convert(&csr, Format::Ell, ConvertParams::default());
    let err = engine.spmv(&m, &x, None).unwrap_err();
    assert!(format!("{err:#}").contains("no artifact bucket"));
}

#[test]
fn power_step_normalizes_through_pjrt() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).expect("engine");
    let csr = small_csr();
    let ell = convert::csr_to_ell(&csr);
    let x = vec![1.0f32; csr.n_cols];
    let y = engine.power_step(&ell, &x).expect("power step");
    let norm: f32 = y.iter().map(|v| v * v).sum::<f32>().sqrt();
    // normalized over the padded 256-vector; the truncated part carries
    // the whole mass because padded rows are zero
    assert!((norm - 1.0).abs() < 1e-3, "norm {norm}");
}

#[test]
fn service_end_to_end_over_pjrt() {
    let Some(dir) = artifacts() else { return };
    // tiny router trained on two matrices
    let ds = build(&BuildOptions {
        only: Some(vec!["rim".into(), "bcsstk32".into()]),
        both_archs: false,
        ..Default::default()
    });
    let samples: Vec<OverheadSample> = (1..8)
        .map(|k| OverheadSample {
            n: k as f64 * 500.0,
            nnz: k as f64 * 5_000.0,
            f_latency_s: k as f64 * 1e-3,
            c_latency_s: k as f64 * 1e-3,
        })
        .collect();
    let router = RunTimeOptimizer::train(&ds, Objective::Latency, OverheadModel::train(&samples));
    let svc = Service::start(
        router,
        BackendSpec::Pjrt(dir),
        ConvertParams { bell_bh: 8, bell_bw: 8, sell_h: 8 },
    );

    // serve a small banded matrix (fits the 256 bucket)
    let csr = small_csr();
    let coo = convert::csr_to_coo(&csr);
    svc.register(1, coo, 100).unwrap();
    let x: Vec<f32> = (0..csr.n_cols).map(|i| (i % 3) as f32).collect();
    let want = csr.spmv_alloc(&x);
    let resp = svc.product(1, x).unwrap();
    assert_close(&resp.y, &want, 1e-4);
    let stats = svc.stats().unwrap();
    assert_eq!(stats.requests, 1);
}

#[test]
fn pjrt_matches_native_on_corpus_sample() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).expect("engine");
    // bcsstk32 at scale 1 is 1200 rows -> outside 1024 bucket; use a
    // truncated banded matrix instead from the generator directly
    let mut rng = auto_spmv::gen::Rng::new(80);
    for (i, gen_fn) in [
        // CSR buckets cap padded nnz at 8192; keep densities below that
        auto_spmv::gen::patterns::banded(&mut rng, 1000, 24, 6.0),
        auto_spmv::gen::patterns::uniform(&mut rng, 512, 512, 6.0),
    ]
    .into_iter()
    .enumerate()
    {
        let csr = convert::coo_to_csr(&gen_fn);
        let x: Vec<f32> = (0..csr.n_cols).map(|k| ((k * (i + 2)) % 7) as f32 * 0.5).collect();
        let want = csr.spmv_alloc(&x);
        let got = engine.spmv(&AnyFormat::Csr(csr.clone()), &x, None);
        match got {
            Ok(y) => assert_close(&y, &want, 1e-3),
            Err(e) => {
                // CSR buckets cap nnz at 8192; banded(1000, 24, 8) fits
                panic!("case {i}: {e:#} (nnz {})", csr.vals.len());
            }
        }
    }
    let _ = gen::corpus();
}

#[test]
fn spmm_prepared_matches_per_vector_at_ragged_batch_widths() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).expect("engine");
    let csr = small_csr();
    let m = convert::convert(&csr, Format::Ell, ConvertParams::default());
    let Some(spmm) = engine.prepare_spmm(&m, None).expect("prepare_spmm") else {
        eprintln!("SKIP: no SpMM artifact for ELL (re-run `make artifacts`)");
        return;
    };
    let prep = engine.prepare(&m, None).expect("prepare");
    let bucket = spmm.ncols();
    assert!(bucket > 1, "SpMM artifacts carry a batch bucket > 1");
    // ragged batch widths around the bucket: under, exactly, just over
    for k in [1usize, bucket, bucket + 1] {
        let xs: Vec<Vec<f32>> = (0..k)
            .map(|r| {
                (0..csr.n_cols)
                    .map(|i| ((i * 3 + r * 7) % 11) as f32 * 0.25 - 1.0)
                    .collect()
            })
            .collect();
        let views: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        let exec0 = engine.exec_count;
        let batch = engine.spmm_prepared(&spmm, &views).expect("spmm_prepared");
        let launches = (engine.exec_count - exec0) as usize;
        assert_eq!(
            launches,
            spmm.launches_for(k),
            "k={k}: a coalesced batch executes in one launch per bucket chunk"
        );
        assert_eq!(batch.len(), k);
        for (j, x) in xs.iter().enumerate() {
            let want = engine.run_prepared(&prep, x).expect("run_prepared");
            assert_eq!(
                batch[j], want,
                "k={k} vector {j}: SpMM output must be bit-identical to run_prepared"
            );
        }
    }
}

/// CI manifest-schema gate: the kernel-lowering job generates a
/// manifest with `python -m compile.aot --quick --manifest-only` and
/// points AUTOSPMV_MANIFEST_FIXTURE at it; this test round-trips the
/// emitted rows through the Rust parser so schema drift between the
/// Python emitter and `runtime::artifacts` fails fast. Skipped (with a
/// notice) when the env var is unset — local runs are covered by the
/// artifact-dir tests above.
#[test]
fn python_emitted_manifest_roundtrips_through_the_parser() {
    let Ok(dir) = std::env::var("AUTOSPMV_MANIFEST_FIXTURE") else {
        eprintln!("SKIP: AUTOSPMV_MANIFEST_FIXTURE not set (CI-only schema gate)");
        return;
    };
    let idx = auto_spmv::runtime::ArtifactIndex::load(std::path::Path::new(&dir))
        .expect("CI fixture manifest must parse");
    assert!(!idx.specs.is_empty(), "fixture manifest has no rows");
    use auto_spmv::runtime::artifacts::{Kind, MatrixDims};
    let spmm: Vec<_> = idx.specs.iter().filter(|s| s.kind == Kind::Spmm).collect();
    assert!(!spmm.is_empty(), "the quick inventory must emit kind=spmm rows");
    for s in &spmm {
        assert!(s.ncols() > 1, "{}: spmm rows carry a batch bucket (nc extra)", s.name);
        assert!(s.rows > 0 && s.cols > 0 && s.width > 0, "{}: shape bucket parsed", s.name);
        assert!(
            ["resident", "gather", "streamed"].contains(&s.x_placement.as_str()),
            "{}: knob placement column parsed ({})",
            s.name,
            s.x_placement
        );
    }
    // the knob sweep reaches the spmm inventory: at least two distinct
    // knob triples among same-format spmm rows, and selection
    // knob-breaks between them
    let knob = |s: &auto_spmv::runtime::ArtifactSpec| {
        (s.block_rows, s.chunk_width, s.x_placement.clone())
    };
    let distinct: std::collections::HashSet<_> = spmm.iter().map(|s| knob(*s)).collect();
    assert!(
        distinct.len() >= 2,
        "the spmm inventory must be knob-swept (got one knob point: {distinct:?})"
    );
    let probe = spmm[0];
    let dims = MatrixDims {
        n_rows: probe.rows.min(64),
        n_cols: probe.cols.min(64),
        nnz: 16,
        max_row_len: 2,
        bell_kb: 2,
    };
    let picked = idx
        .select_spmm(probe.fmt, &dims, 2, None)
        .expect("an spmm variant must cover a tiny matrix");
    assert_eq!(picked.kind, Kind::Spmm);

    // the solve kernel classes reach the inventory too: the quick
    // sweep must emit both solve kinds, and per-kind selection must
    // resolve them for a tiny matrix without crossing kinds
    for (kind, label) in [(Kind::Sptrsv, "sptrsv"), (Kind::Symgs, "symgs")] {
        let rows: Vec<_> = idx.specs.iter().filter(|s| s.kind == kind).collect();
        assert!(!rows.is_empty(), "the quick inventory must emit kind={label} rows");
        let probe = rows[0];
        let dims = MatrixDims {
            n_rows: probe.rows.min(64),
            n_cols: probe.cols.min(64),
            nnz: 16,
            max_row_len: 2,
            bell_kb: 2,
        };
        let lower = if kind == Kind::Sptrsv { Some(probe.lower()) } else { None };
        let picked = idx
            .select_solve(kind, probe.fmt, &dims, lower, None)
            .unwrap_or_else(|| panic!("a {label} variant must cover a tiny matrix"));
        assert_eq!(picked.kind, kind);
    }
    // sptrsv rows carry the triangle side as the `lo` extra; the quick
    // inventory emits both sides so upper solves never silently fall
    // back to a lower artifact
    let sides: std::collections::HashSet<bool> = idx
        .specs
        .iter()
        .filter(|s| s.kind == Kind::Sptrsv)
        .map(|s| s.lower())
        .collect();
    assert_eq!(sides.len(), 2, "sptrsv rows must cover both triangle sides");
}
