//! # Auto-SpMV
//!
//! A from-scratch reproduction of *Auto-SpMV: Automated Optimizing SpMV
//! Kernels on GPU* (Ashoury et al., 2023) as a three-layer Rust + JAX +
//! Pallas system. This crate is Layer 3: the framework that extracts
//! sparsity features, builds the training dataset, trains the paper's
//! classifier/regressor zoo, and drives the compile-time and run-time
//! optimization modes — dispatching real AOT-compiled SpMV executables
//! through PJRT on the hot path (`runtime`), with the paper's GPU testbed
//! replaced by an analytical simulator (`gpusim`, see DESIGN.md §1).
//!
//! Module map (DESIGN.md §3 has the full inventory):
//! * [`sparse`]      — COO/CSR/ELL/BELL/SELL types, conversions, CPU SpMV.
//! * [`gen`]         — synthetic matrix generators + the 30-matrix corpus.
//! * [`features`]    — the paper's eight sparsity features (Table 2).
//! * [`gpusim`]      — occupancy / memory / latency / power models for the
//!                     Pascal and Turing profiles (Table 3).
//! * [`ml`]          — decision tree, random forest, nearest centroid,
//!                     SVM, gradient boosting, MLP (+ regressors, metrics).
//! * [`automl`]      — TPE hyperparameter search (the Optuna stand-in).
//! * [`dataset`]     — configuration sweep, record store, labelling.
//! * [`coordinator`] — compile-time optimizer, run-time format router,
//!                     overhead estimator, legacy serving shim.
//! * [`serve`]       — the sharded serving engine: N worker shards
//!                     (matrices partitioned by id hash), request
//!                     coalescing into multi-vector `spmv_batch`
//!                     dispatches, a bounded converted-matrix LRU, and
//!                     per-matrix latency/energy telemetry (DESIGN.md
//!                     §serve).
//! * [`online`]      — closed-loop adaptive routing for the pool:
//!                     observation buffer, exploration bandit, drift
//!                     detector, background retraining, and the
//!                     hot-swappable versioned router (DESIGN.md §6).
//! * [`obs`]         — observability primitives: log2 latency
//!                     histograms, request-lifecycle stage tracing, the
//!                     control-plane event journal, and Prometheus
//!                     text-exposition rendering (DESIGN.md §10).
//! * [`runtime`]     — PJRT client wrapper + artifact manifest/executable
//!                     cache (the only module touching the xla API; the
//!                     offline build aliases it to `runtime::xla_shim`).
//! * [`report`]      — table/figure printers and the bench kit.

// Index-based loops in the sparse kernels intentionally mirror the
// CUDA/Pallas pseudocode they reproduce.
#![allow(clippy::needless_range_loop)]

pub mod automl;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod features;
pub mod gen;
pub mod gpusim;
pub mod ml;
pub mod obs;
pub mod online;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod testutil;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
