//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The offline build environment does not ship the `xla` crate, so this
//! module mirrors the exact API surface `runtime::pjrt` consumes:
//! [`PjRtClient`], [`PjRtLoadedExecutable`], [`HloModuleProto`],
//! [`XlaComputation`], [`PjRtBuffer`], and [`Literal`]. Client
//! construction fails (there is no PJRT plugin to talk to), which the
//! serving layer already treats as "fall back to the native backend";
//! [`Literal`] shape bookkeeping is real, so marshalling helpers and
//! their unit tests behave identically to the real crate — including
//! the SpMM batch path, whose `(ncols, cols)` X literal and
//! `(ncols, rows)` result ride the same `vec1` + `reshape` surface. A
//! future PR
//! that restores the genuine dependency only needs to swap the
//! `use super::xla_shim as xla;` alias in `pjrt.rs`.

use std::borrow::Borrow;

/// Error type mirroring `xla::Error` far enough for `{e:?}` formatting.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

fn unavailable() -> XlaError {
    XlaError("PJRT unavailable: built against the offline xla shim (see runtime::xla_shim)".into())
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the shim.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "xla-shim".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }
}

/// Parsed HLO module (never constructible in the shim).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable())
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }

    /// Project element `i` out of a tuple-shaped buffer WITHOUT leaving
    /// the device (PJRT's `GetTupleElement` surface). The iterative
    /// session path uses this to keep an execution's `y` output
    /// device-resident so it can feed the next execution's `x` input.
    pub fn tuple_element(&self, _i: usize) -> Result<PjRtBuffer, XlaError> {
        Err(unavailable())
    }
}

/// One execution input: a host literal to be transferred, or an
/// already-device-resident buffer passed by identity (zero-copy). The
/// real bindings accept `PjRtBuffer` arguments on the same device
/// without a host round-trip; the shim mirrors that surface so
/// `runtime::pjrt`'s session chaining compiles against both.
pub enum ExecInput<'a> {
    Literal(&'a Literal),
    Buffer(&'a PjRtBuffer),
}

/// Compiled executable handle (never constructible in the shim).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }

    /// Execute with mixed host/device inputs ([`ExecInput`]): literals
    /// are transferred, buffers are consumed in place. This is the
    /// entry point the device-resident session loop chains through.
    pub fn execute_inputs(&self, _args: &[ExecInput]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

/// Element types a [`Literal`] can be built from.
pub trait NativeElement: Copy {}
impl NativeElement for f32 {}
impl NativeElement for i32 {}

/// Host literal. The shim tracks element counts so reshape validation
/// (and the marshalling unit tests built on it) behave like the real
/// crate; payload data is not retained because nothing can execute.
#[derive(Debug, Clone)]
pub struct Literal {
    elems: usize,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeElement>(v: &[T]) -> Literal {
        Literal { elems: v.len() }
    }

    pub fn element_count(&self) -> usize {
        self.elems
    }

    /// Reshape; fails unless the dimension product matches the element
    /// count, exactly as the real bindings do.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.elems {
            return Err(XlaError(format!(
                "reshape: {} elements cannot fill shape {dims:?}",
                self.elems
            )));
        }
        Ok(Literal { elems: self.elems })
    }

    /// Unwrap a 1-tuple result (unreachable in the shim: nothing executes).
    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }

    /// Copy out as a host vector (unreachable in the shim).
    pub fn to_vec<T: NativeElement>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_shim() {
        let e = PjRtClient::cpu().err().expect("shim client must fail");
        assert!(format!("{e:?}").contains("shim"));
    }

    #[test]
    fn reshape_validates_element_count() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.element_count(), 6);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[1, 2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
        let i = Literal::vec1(&[0i32; 8]);
        assert!(i.reshape(&[2, 2, 2]).is_ok());
    }
}
