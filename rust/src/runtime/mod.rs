//! Runtime: PJRT client wrapper + artifact manifest (the hot path's
//! executor). Pattern adapted from /opt/xla-example/load_hlo.
//!
//! Python runs once (`make artifacts`); this module makes the Rust binary
//! self-contained afterwards: HLO text -> XlaComputation -> PJRT compile
//! (cached) -> execute.

pub mod artifacts;
pub mod pjrt;
pub mod xla_shim;

pub use artifacts::{knob_map, spmm_launches, ArtifactIndex, ArtifactSpec, Kind, MatrixDims};
pub use pjrt::{Engine, PreparedPower, PreparedSession, PreparedSpmm, PreparedSpmv, SessionVec};

use std::path::PathBuf;

/// Default artifact directory: `$AUTO_SPMV_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("AUTO_SPMV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
