//! Artifact manifest — the L2 -> L3 interchange contract (DESIGN.md §5).
//!
//! `make artifacts` writes `artifacts/manifest.tsv`, one row per
//! AOT-compiled HLO module; this module parses it and selects the right
//! variant (shape bucket + compile-knob analogues) for a request.

use crate::gpusim::MemConfig;
use crate::sparse::Format;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Kind of compiled graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Spmv,
    /// Multi-vector batch kernel `Y = A X` (X is `(ncols, cols)`); one
    /// launch serves a whole coalesced request group.
    Spmm,
    Power,
    /// Sparse triangular solve `T x = b` (lower/upper per the `lo`
    /// extra: `lo=1` forward/lower, `lo=0` backward/upper).
    Sptrsv,
    /// One symmetric Gauss-Seidel sweep (forward + backward pass).
    Symgs,
}

/// One compiled variant (a parsed manifest row).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: Kind,
    pub fmt: Format,
    /// Shape-bucket rows/cols.
    pub rows: usize,
    pub cols: usize,
    /// ELL/SELL width, BELL block-cols, CSR padded nnz.
    pub width: usize,
    pub block_rows: usize,
    pub chunk_width: usize,
    pub x_placement: String,
    pub extra: HashMap<String, usize>,
    pub path: PathBuf,
}

impl ArtifactSpec {
    /// BELL block height / SELL slice height helpers.
    pub fn bh(&self) -> usize {
        self.extra.get("bh").copied().unwrap_or(8)
    }
    pub fn bw(&self) -> usize {
        self.extra.get("bw").copied().unwrap_or(8)
    }
    pub fn slice_h(&self) -> usize {
        self.extra.get("h").copied().unwrap_or(8)
    }

    /// Batch bucket of an SpMM artifact: input vectors per launch
    /// (`nc` in the manifest extras; 1 for plain SpMV variants).
    pub fn ncols(&self) -> usize {
        self.extra.get("nc").copied().unwrap_or(1).max(1)
    }

    /// Triangle side of an SpTRSV artifact: `lo=1` solves the lower
    /// triangle (forward sweep), `lo=0` the upper. Defaults to lower —
    /// the forward-substitution case every emitter starts from.
    pub fn lower(&self) -> bool {
        self.extra.get("lo").copied().unwrap_or(1) != 0
    }
}

/// Launches needed to cover a `k`-vector batch with a `bucket`-wide SpMM
/// artifact: one launch up to the bucket, chunking only beyond it. The
/// final chunk pads with zero vectors up to the bucket width.
pub fn spmm_launches(k: usize, bucket: usize) -> usize {
    k.div_ceil(bucket.max(1))
}

/// Parsed manifest with variant lookup.
#[derive(Debug, Clone, Default)]
pub struct ArtifactIndex {
    pub specs: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl ArtifactIndex {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("read {manifest:?} — run `make artifacts` first"))?;
        let mut lines = text.lines();
        let header = lines.next().context("empty manifest")?;
        let want = "name\tkind\tfmt\trows\tcols\twidth\tblock_rows\tchunk_width\tx_placement\textra\tpath\tinputs";
        if header != want {
            bail!("manifest header mismatch:\n got {header}\nwant {want}");
        }
        let mut specs = Vec::new();
        for (ln, line) in lines.enumerate() {
            if line.is_empty() {
                continue;
            }
            let c: Vec<&str> = line.split('\t').collect();
            if c.len() != 12 {
                bail!("manifest line {}: expected 12 cols, got {}", ln + 2, c.len());
            }
            let kind = match c[1] {
                "spmv" => Kind::Spmv,
                "spmm" => Kind::Spmm,
                "power" => Kind::Power,
                "sptrsv" => Kind::Sptrsv,
                "symgs" => Kind::Symgs,
                // UNKNOWN kinds are SKIPPED, not errors — same leniency
                // contract as unknown extras below: a newer emitter's
                // inventory must still load on an older runtime, which
                // simply never selects the rows it cannot serve.
                _ => continue,
            };
            let fmt = Format::parse(c[2]).with_context(|| format!("bad format {}", c[2]))?;
            let mut extra = HashMap::new();
            if c[9] != "-" {
                for kv in c[9].split(';') {
                    // UNKNOWN extras are SKIPPED, not errors: manifests
                    // evolve (PR 3-era rows carry no knob sweep; future
                    // emitters may tag rows with extras this parser
                    // predates), and selection then degrades to the
                    // smallest covering bucket instead of refusing to
                    // load the whole inventory. A malformed value on a
                    // key we DO interpret (batch bucket, slice/block
                    // dims) still fails fast — silently defaulting
                    // those would mis-marshal at serve time.
                    let known = |k: &str| ["nc", "h", "bh", "bw", "xseg", "lo"].contains(&k);
                    let Some((k, v)) = kv.split_once('=') else {
                        if known(kv) {
                            bail!("manifest line {}: extra {kv} is missing its value", ln + 2);
                        }
                        continue;
                    };
                    match v.parse() {
                        Ok(v) => {
                            extra.insert(k.to_string(), v);
                        }
                        Err(_) if !known(k) => continue,
                        Err(e) => {
                            bail!("manifest line {}: bad extra {kv}: {e}", ln + 2)
                        }
                    }
                }
            }
            specs.push(ArtifactSpec {
                name: c[0].to_string(),
                kind,
                fmt,
                rows: c[3].parse()?,
                cols: c[4].parse()?,
                width: c[5].parse()?,
                block_rows: c[6].parse()?,
                chunk_width: c[7].parse()?,
                x_placement: c[8].to_string(),
                extra,
                path: dir.join(c[10]),
            });
        }
        Ok(ArtifactIndex { specs, dir: dir.to_path_buf() })
    }

    /// Required storage width of a matrix in a format (what the bucket's
    /// `width` must cover).
    pub fn required_width(fmt: Format, spec_like: &MatrixDims) -> usize {
        match fmt {
            Format::Csr => spec_like.nnz,
            Format::Ell => spec_like.max_row_len,
            Format::Bell => spec_like.bell_kb,
            Format::Sell => spec_like.max_row_len,
        }
    }

    /// Select the smallest enclosing spmv variant for a matrix in `fmt`,
    /// preferring the knob mapping of `choice` (see [`knob_map`]).
    pub fn select(
        &self,
        fmt: Format,
        dims: &MatrixDims,
        choice: Option<(u32, u32, MemConfig)>,
    ) -> Option<&ArtifactSpec> {
        let fits = |s: &&ArtifactSpec| {
            s.kind == Kind::Spmv
                && s.fmt == fmt
                && s.rows >= dims.n_rows
                && s.cols >= dims.n_cols
                && s.width >= Self::required_width(fmt, dims)
        };
        let candidates: Vec<&ArtifactSpec> = self.specs.iter().filter(fits).collect();
        Self::pick_in_smallest_bucket(candidates, choice)
    }

    /// Select the smallest enclosing solve variant (`Kind::Sptrsv` /
    /// `Kind::Symgs`) for a matrix in `fmt`, preferring the knob
    /// mapping of `choice` exactly like SpMV selection. For SpTRSV,
    /// `lower` filters on the artifact's triangle side (`lo` extra);
    /// pass `None` for SymGS (a sweep is side-free). Returns `None`
    /// when the inventory has no fitting row — callers fall back to the
    /// native trait methods (`SpMv::sptrsv` / `SpMv::symgs_sweep`).
    pub fn select_solve(
        &self,
        kind: Kind,
        fmt: Format,
        dims: &MatrixDims,
        lower: Option<bool>,
        choice: Option<(u32, u32, MemConfig)>,
    ) -> Option<&ArtifactSpec> {
        debug_assert!(matches!(kind, Kind::Sptrsv | Kind::Symgs));
        let fits = |s: &&ArtifactSpec| {
            s.kind == kind
                && s.fmt == fmt
                && s.rows >= dims.n_rows
                && s.cols >= dims.n_cols
                && s.width >= Self::required_width(fmt, dims)
                && lower.is_none_or(|lo| s.lower() == lo)
        };
        let candidates: Vec<&ArtifactSpec> = self.specs.iter().filter(fits).collect();
        Self::pick_in_smallest_bucket(candidates, choice)
    }

    /// Select an SpMM (multi-vector) variant for a `k`-vector batch of a
    /// matrix in `fmt`, or `None` when no SpMM artifact fits the shape
    /// (callers fall back to the per-vector prepared path). Within the
    /// smallest enclosing shape bucket the batch bucket is the smallest
    /// `ncols >= k`; when `k` exceeds every compiled bucket the widest
    /// one wins and the caller chunks (see [`spmm_launches`]).
    pub fn select_spmm(
        &self,
        fmt: Format,
        dims: &MatrixDims,
        k: usize,
        choice: Option<(u32, u32, MemConfig)>,
    ) -> Option<&ArtifactSpec> {
        let fits = |s: &&ArtifactSpec| {
            s.kind == Kind::Spmm
                && s.fmt == fmt
                && s.rows >= dims.n_rows
                && s.cols >= dims.n_cols
                && s.width >= Self::required_width(fmt, dims)
        };
        let candidates: Vec<&ArtifactSpec> = self.specs.iter().filter(fits).collect();
        if candidates.is_empty() {
            return None;
        }
        let min_key = candidates
            .iter()
            .map(|s| (s.rows, s.cols, s.width))
            .min()
            .unwrap();
        let in_bucket: Vec<&ArtifactSpec> = candidates
            .into_iter()
            .filter(|s| (s.rows, s.cols, s.width) == min_key)
            .collect();
        // batch bucket: smallest covering ncols, else the widest (chunk)
        let ncols = match in_bucket.iter().map(|s| s.ncols()).filter(|n| *n >= k).min() {
            Some(n) => n,
            None => in_bucket.iter().map(|s| s.ncols()).max().unwrap(),
        };
        let same_ncols: Vec<&ArtifactSpec> =
            in_bucket.into_iter().filter(|s| s.ncols() == ncols).collect();
        Self::knob_break(same_ncols, choice)
    }

    /// Shared tail of variant selection: keep the smallest enclosing
    /// (rows, cols, width) bucket, then apply the knob preference.
    fn pick_in_smallest_bucket<'a>(
        candidates: Vec<&'a ArtifactSpec>,
        choice: Option<(u32, u32, MemConfig)>,
    ) -> Option<&'a ArtifactSpec> {
        if candidates.is_empty() {
            return None;
        }
        // smallest bucket first; among equals prefer the knob match
        let min_key = candidates
            .iter()
            .map(|s| (s.rows, s.cols, s.width))
            .min()
            .unwrap();
        let in_bucket: Vec<&ArtifactSpec> = candidates
            .into_iter()
            .filter(|s| (s.rows, s.cols, s.width) == min_key)
            .collect();
        Self::knob_break(in_bucket, choice)
    }

    fn knob_break<'a>(
        in_bucket: Vec<&'a ArtifactSpec>,
        choice: Option<(u32, u32, MemConfig)>,
    ) -> Option<&'a ArtifactSpec> {
        match choice {
            None => in_bucket.first().copied(),
            Some((tb, regs, mem)) => {
                let (want_br, want_cw, want_place) = knob_map(tb, regs, mem);
                in_bucket
                    .iter()
                    .min_by_key(|s| {
                        let mut cost = 0usize;
                        if s.x_placement != want_place {
                            cost += 4;
                        }
                        cost += s.block_rows.abs_diff(want_br) / 64;
                        cost += s.chunk_width.abs_diff(want_cw);
                        cost
                    })
                    .copied()
            }
        }
    }

    /// The power-step variant list (examples use these).
    pub fn power_specs(&self) -> Vec<&ArtifactSpec> {
        self.specs.iter().filter(|s| s.kind == Kind::Power).collect()
    }
}

/// What the selector needs to know about a concrete matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MatrixDims {
    pub n_rows: usize,
    pub n_cols: usize,
    pub nnz: usize,
    pub max_row_len: usize,
    /// Block-columns per block-row if converted to BELL (8x8).
    pub bell_kb: usize,
}

/// Map the paper's CUDA compile knobs onto the Pallas variant knobs
/// (DESIGN.md §2): TB size -> block_rows, maxrregcount -> chunk_width,
/// memory config -> x placement.
pub fn knob_map(tb_size: u32, maxrregcount: u32, mem: MemConfig) -> (usize, usize, &'static str) {
    let block_rows = if tb_size <= 128 { 64 } else { 256 };
    let chunk_width = if maxrregcount <= 32 { 8 } else { 16 };
    let place = match mem {
        MemConfig::Default => "resident",
        MemConfig::PreferL1 => "gather",
        MemConfig::PreferShared => "streamed",
    };
    (block_rows, chunk_width, place)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, rows: &[&str]) {
        let header = "name\tkind\tfmt\trows\tcols\twidth\tblock_rows\tchunk_width\tx_placement\textra\tpath\tinputs";
        let mut text = String::from(header);
        for r in rows {
            text.push('\n');
            text.push_str(r);
        }
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), text).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("autospmv_art_{tag}"));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn parses_and_selects_smallest_bucket() {
        let d = tmpdir("select");
        write_manifest(
            &d,
            &[
                "e1\tspmv\tell\t256\t256\t16\t64\t8\tresident\t-\te1.hlo.txt\tf32:256x16,i32:256x16,f32:256",
                "e2\tspmv\tell\t1024\t1024\t16\t64\t8\tresident\t-\te2.hlo.txt\tf32:1024x16,i32:1024x16,f32:1024",
            ],
        );
        let idx = ArtifactIndex::load(&d).unwrap();
        assert_eq!(idx.specs.len(), 2);
        let dims = MatrixDims { n_rows: 200, n_cols: 200, nnz: 900, max_row_len: 9, bell_kb: 4 };
        let s = idx.select(Format::Ell, &dims, None).unwrap();
        assert_eq!(s.name, "e1");
        let big = MatrixDims { n_rows: 700, n_cols: 700, nnz: 900, max_row_len: 9, bell_kb: 4 };
        assert_eq!(idx.select(Format::Ell, &big, None).unwrap().name, "e2");
        let too_big =
            MatrixDims { n_rows: 5000, n_cols: 700, nnz: 900, max_row_len: 9, bell_kb: 4 };
        assert!(idx.select(Format::Ell, &too_big, None).is_none());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn knob_preference_breaks_ties() {
        let d = tmpdir("knobs");
        write_manifest(
            &d,
            &[
                "a\tspmv\tell\t256\t256\t16\t64\t8\tresident\t-\ta.hlo\tf32:1",
                "b\tspmv\tell\t256\t256\t16\t64\t8\tgather\t-\tb.hlo\tf32:1",
                "c\tspmv\tell\t256\t256\t16\t64\t16\tresident\t-\tc.hlo\tf32:1",
            ],
        );
        let idx = ArtifactIndex::load(&d).unwrap();
        let dims = MatrixDims { n_rows: 100, n_cols: 100, nnz: 100, max_row_len: 4, bell_kb: 2 };
        let s = idx
            .select(Format::Ell, &dims, Some((64, 16, MemConfig::PreferL1)))
            .unwrap();
        assert_eq!(s.name, "b"); // gather + cw 8
        let s2 = idx
            .select(Format::Ell, &dims, Some((512, 128, MemConfig::Default)))
            .unwrap();
        assert_eq!(s2.name, "c"); // resident + cw 16
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn extra_fields_parse() {
        let d = tmpdir("extra");
        write_manifest(
            &d,
            &["s\tspmv\tsell\t256\t256\t16\t8\t8\tresident\th=32\ts.hlo\tf32:1"],
        );
        let idx = ArtifactIndex::load(&d).unwrap();
        assert_eq!(idx.specs[0].slice_h(), 32);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn rejects_bad_manifest() {
        let d = tmpdir("bad");
        std::fs::create_dir_all(&d).unwrap();
        std::fs::write(d.join("manifest.tsv"), "wrong").unwrap();
        assert!(ArtifactIndex::load(&d).is_err());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn spmm_selection_picks_batch_bucket_and_falls_back() {
        let d = tmpdir("spmm");
        write_manifest(
            &d,
            &[
                "s4\tspmm\tell\t256\t256\t16\t64\t8\tresident\tnc=4\ts4.hlo\tf32:1",
                "s16\tspmm\tell\t256\t256\t16\t64\t8\tresident\tnc=16\ts16.hlo\tf32:1",
                "e1\tspmv\tell\t256\t256\t16\t64\t8\tresident\t-\te1.hlo\tf32:1",
            ],
        );
        let idx = ArtifactIndex::load(&d).unwrap();
        let dims = MatrixDims { n_rows: 200, n_cols: 200, nnz: 900, max_row_len: 9, bell_kb: 4 };
        // k = 1 rides the narrowest covering bucket
        assert_eq!(idx.select_spmm(Format::Ell, &dims, 1, None).unwrap().name, "s4");
        // k = bucket is still one launch of that bucket
        assert_eq!(idx.select_spmm(Format::Ell, &dims, 4, None).unwrap().name, "s4");
        // k = bucket + 1 escalates to the next bucket, not to chunking
        assert_eq!(idx.select_spmm(Format::Ell, &dims, 5, None).unwrap().name, "s16");
        // k beyond every bucket picks the widest and the caller chunks
        let wide = idx.select_spmm(Format::Ell, &dims, 33, None).unwrap();
        assert_eq!(wide.name, "s16");
        assert_eq!(wide.ncols(), 16);
        // no SpMM artifact for this format -> None (per-vector fallback);
        // plain spmv selection never returns an SpMM row
        assert!(idx.select_spmm(Format::Csr, &dims, 4, None).is_none());
        assert_eq!(idx.select(Format::Ell, &dims, None).unwrap().name, "e1");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn spmm_launch_chunking_arithmetic() {
        assert_eq!(spmm_launches(1, 16), 1);
        assert_eq!(spmm_launches(16, 16), 1, "k = bucket is ONE launch");
        assert_eq!(spmm_launches(17, 16), 2, "k = bucket + 1 chunks once");
        assert_eq!(spmm_launches(48, 16), 3);
        assert_eq!(spmm_launches(5, 0), 5, "degenerate bucket degrades to per-vector");
    }

    #[test]
    fn knob_map_covers_space() {
        assert_eq!(knob_map(64, 16, MemConfig::Default), (64, 8, "resident"));
        assert_eq!(knob_map(1024, 128, MemConfig::PreferShared), (256, 16, "streamed"));
        assert_eq!(knob_map(256, 32, MemConfig::PreferL1), (256, 8, "gather"));
    }

    /// Property over the FULL CUDA knob grid: `knob_map` is total
    /// (every sweep point maps to a valid Pallas knob triple), stable
    /// (deterministic), and its aliasing is exactly the documented
    /// quantization — two CUDA points share a Pallas variant iff they
    /// fall in the same (TB <= 128, regs <= 32, mem) class. No point
    /// silently collapses beyond that.
    #[test]
    fn knob_map_is_total_and_aliases_only_documented_classes() {
        use crate::gpusim::{MAXRREGCOUNT, TB_SIZES};
        let grid: Vec<(u32, u32, MemConfig)> = TB_SIZES
            .iter()
            .flat_map(|&tb| {
                MAXRREGCOUNT
                    .iter()
                    .flat_map(move |&r| MemConfig::ALL.iter().map(move |&m| (tb, r, m)))
            })
            .collect();
        assert_eq!(grid.len(), 60, "the §6 sweep is 5 x 4 x 3");
        let class = |(tb, r, m): (u32, u32, MemConfig)| (tb <= 128, r <= 32, m.class_id());
        for &a in &grid {
            let mapped = knob_map(a.0, a.1, a.2);
            // total: valid Pallas knob values only
            assert!([64, 256].contains(&mapped.0), "{a:?} -> {mapped:?}");
            assert!([8, 16].contains(&mapped.1), "{a:?} -> {mapped:?}");
            assert!(["resident", "gather", "streamed"].contains(&mapped.2));
            // stable: same input, same output
            assert_eq!(mapped, knob_map(a.0, a.1, a.2));
            for &b in &grid {
                let same = knob_map(b.0, b.1, b.2) == mapped;
                assert_eq!(
                    same,
                    class(a) == class(b),
                    "{a:?} vs {b:?}: aliasing must match the documented quantization"
                );
            }
        }
    }

    /// Regression (PR 3-era manifests): `kind=spmm` rows without the
    /// knob sweep — and rows carrying extras this parser does not know,
    /// including non-numeric values — must load and degrade to the
    /// PR 3 selection (smallest covering batch bucket), never error.
    #[test]
    fn pr3_era_spmm_manifest_without_knob_extras_degrades_gracefully() {
        let d = tmpdir("pr3compat");
        write_manifest(
            &d,
            &[
                // exactly what PR 3's inventory emitted: resident-only
                "s4\tspmm\tell\t256\t256\t16\t64\t8\tresident\tnc=4\ts4.hlo\tf32:1",
                "s16\tspmm\tell\t256\t256\t16\t64\t8\tresident\tnc=16\ts16.hlo\tf32:1",
                // a future emitter's row with extras we do not know
                "sX\tspmm\tell\t256\t256\t16\t64\t8\tresident\tnc=4;variant=exp;pipeline\tsX.hlo\tf32:1",
            ],
        );
        let idx = ArtifactIndex::load(&d).unwrap();
        assert_eq!(idx.specs.len(), 3, "unknown extras must not reject rows");
        assert_eq!(idx.specs[2].ncols(), 4, "known extras still parse next to unknown ones");
        let dims = MatrixDims { n_rows: 200, n_cols: 200, nnz: 900, max_row_len: 9, bell_kb: 4 };
        // a knob preference that nothing in the inventory satisfies
        // (streamed placement, small TB) degrades to the PR 3 pick
        let choice = Some((64u32, 16u32, MemConfig::PreferShared));
        let s = idx.select_spmm(Format::Ell, &dims, 3, choice).unwrap();
        assert_eq!((s.rows, s.ncols()), (256, 4), "smallest covering bucket wins");
        assert_eq!(idx.select_spmm(Format::Ell, &dims, 9, choice).unwrap().ncols(), 16);
        std::fs::remove_dir_all(&d).ok();
    }

    /// Leniency is for UNKNOWN keys only: a malformed value on a key
    /// this parser interprets (the batch bucket here) must still fail
    /// at load time — defaulting `nc` to 1 would mis-pad X at serve
    /// time.
    #[test]
    fn malformed_known_extra_still_fails_fast() {
        let d = tmpdir("badknown");
        write_manifest(
            &d,
            &["s\tspmm\tell\t256\t256\t16\t64\t8\tresident\tnc=1x6\ts.hlo\tf32:1"],
        );
        let err = ArtifactIndex::load(&d).unwrap_err();
        assert!(format!("{err:#}").contains("bad extra nc=1x6"), "{err:#}");
        std::fs::remove_dir_all(&d).ok();
    }

    /// With a knob-swept SpMM inventory, `select_spmm` knob-breaks
    /// within the batch bucket exactly like SpMV selection does.
    #[test]
    fn spmm_selection_knob_breaks_within_the_batch_bucket() {
        let d = tmpdir("spmmknobs");
        write_manifest(
            &d,
            &[
                "a\tspmm\tell\t256\t256\t16\t64\t8\tresident\tnc=8\ta.hlo\tf32:1",
                "b\tspmm\tell\t256\t256\t16\t64\t8\tgather\tnc=8\tb.hlo\tf32:1",
                "c\tspmm\tell\t256\t256\t16\t256\t16\tresident\tnc=8\tc.hlo\tf32:1",
            ],
        );
        let idx = ArtifactIndex::load(&d).unwrap();
        let dims = MatrixDims { n_rows: 200, n_cols: 200, nnz: 900, max_row_len: 9, bell_kb: 4 };
        // PreferL1 -> gather placement
        let s = idx
            .select_spmm(Format::Ell, &dims, 8, Some((64, 16, MemConfig::PreferL1)))
            .unwrap();
        assert_eq!(s.name, "b");
        // big TB + uncapped regs -> wide resident variant
        let s = idx
            .select_spmm(Format::Ell, &dims, 8, Some((1024, 128, MemConfig::Default)))
            .unwrap();
        assert_eq!(s.name, "c");
        // no preference keeps the first in-bucket variant (PR 3 path)
        assert_eq!(idx.select_spmm(Format::Ell, &dims, 8, None).unwrap().name, "a");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = ArtifactIndex::load(Path::new("/nonexistent_dir_xyz")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    /// PR 5 leniency contract, extended to the kind column: rows whose
    /// `kind` this runtime predates must be skipped — never an error —
    /// while every known kind keeps parsing next to them.
    #[test]
    fn unknown_manifest_kind_is_skipped_not_fatal() {
        let d = tmpdir("unkind");
        write_manifest(
            &d,
            &[
                "e1\tspmv\tell\t256\t256\t16\t64\t8\tresident\t-\te1.hlo\tf32:1",
                "z\tspmsvp\tell\t256\t256\t16\t64\t8\tresident\t-\tz.hlo\tf32:1",
                "t1\tsptrsv\tcsr\t256\t256\t4096\t64\t8\tresident\tlo=1\tt1.hlo\tf32:1",
                "g1\tsymgs\tcsr\t256\t256\t4096\t64\t8\tresident\t-\tg1.hlo\tf32:1",
            ],
        );
        let idx = ArtifactIndex::load(&d).unwrap();
        assert_eq!(idx.specs.len(), 3, "the unknown-kind row is dropped, the rest load");
        assert!(idx.specs.iter().all(|s| s.name != "z"));
        assert!(idx.specs.iter().any(|s| s.kind == Kind::Sptrsv));
        assert!(idx.specs.iter().any(|s| s.kind == Kind::Symgs));
        std::fs::remove_dir_all(&d).ok();
    }

    /// Solve selection: kind-filtered, triangle-side-filtered for
    /// SpTRSV, smallest-bucket + knob-break like SpMV, and `None` (the
    /// native-fallback signal) when nothing fits.
    #[test]
    fn solve_selection_filters_kind_and_triangle_side() {
        let d = tmpdir("solve");
        write_manifest(
            &d,
            &[
                "tl\tsptrsv\tcsr\t256\t256\t4096\t64\t8\tresident\tlo=1\ttl.hlo\tf32:1",
                "tu\tsptrsv\tcsr\t256\t256\t4096\t64\t8\tresident\tlo=0\ttu.hlo\tf32:1",
                "tubig\tsptrsv\tcsr\t1024\t1024\t16384\t64\t8\tresident\tlo=0\ttubig.hlo\tf32:1",
                "g\tsymgs\tcsr\t256\t256\t4096\t64\t8\tresident\t-\tg.hlo\tf32:1",
                "gg\tsymgs\tcsr\t256\t256\t4096\t64\t8\tgather\t-\tgg.hlo\tf32:1",
                "e1\tspmv\tcsr\t256\t256\t4096\t64\t8\tresident\t-\te1.hlo\tf32:1",
            ],
        );
        let idx = ArtifactIndex::load(&d).unwrap();
        assert!(idx.specs.iter().find(|s| s.name == "tl").unwrap().lower());
        assert!(!idx.specs.iter().find(|s| s.name == "tu").unwrap().lower());
        let dims = MatrixDims { n_rows: 200, n_cols: 200, nnz: 900, max_row_len: 9, bell_kb: 4 };
        let lo = idx.select_solve(Kind::Sptrsv, Format::Csr, &dims, Some(true), None).unwrap();
        assert_eq!(lo.name, "tl");
        let up = idx.select_solve(Kind::Sptrsv, Format::Csr, &dims, Some(false), None).unwrap();
        assert_eq!(up.name, "tu", "smallest bucket wins over tubig");
        // knob preference breaks the SymGS placement tie like SpMV's
        let g = idx
            .select_solve(Kind::Symgs, Format::Csr, &dims, None, Some((64, 16, MemConfig::PreferL1)))
            .unwrap();
        assert_eq!(g.name, "gg");
        // solve selection never returns spmv rows, and vice versa
        assert!(idx.select_solve(Kind::Symgs, Format::Ell, &dims, None, None).is_none());
        assert_eq!(idx.select(Format::Csr, &dims, None).unwrap().name, "e1");
        std::fs::remove_dir_all(&d).ok();
    }

    /// Property form of the leniency contract: ARBITRARY unknown kind
    /// tokens (not just one hand-picked typo) are skipped row-by-row,
    /// never an error, and never shadow the known rows beside them.
    #[test]
    fn prop_arbitrary_unknown_kinds_parse_leniently() {
        use crate::testutil::assert_prop;
        const KNOWN: [&str; 5] = ["spmv", "spmm", "power", "sptrsv", "symgs"];
        assert_prop("unknown kinds are skipped, never fatal", 0xA7, 15, 24, |rng, size| {
            const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz_";
            let mut unknown = String::new();
            while unknown.is_empty() || KNOWN.contains(&unknown.as_str()) {
                unknown.clear();
                for _ in 0..(1 + rng.below(8)) {
                    unknown.push(ALPHABET[rng.below(ALPHABET.len())] as char);
                }
            }
            let mut rows: Vec<String> = (0..1 + size % 4)
                .map(|u| {
                    format!(
                        "u{u}\t{unknown}\tell\t256\t256\t16\t64\t8\tresident\t-\tu{u}.hlo\tf32:1"
                    )
                })
                .collect();
            // one known row with an unknown extra key rides along
            rows.push(format!(
                "k0\tspmv\tell\t256\t256\t16\t64\t8\tresident\tzz{}=7\tk0.hlo\tf32:1",
                rng.below(100)
            ));
            let refs: Vec<&str> = rows.iter().map(|s| s.as_str()).collect();
            let d = tmpdir("lenient");
            write_manifest(&d, &refs);
            let idx = ArtifactIndex::load(&d).map_err(|e| format!("load failed: {e:#}"))?;
            std::fs::remove_dir_all(&d).ok();
            if idx.specs.len() != 1 || idx.specs[0].name != "k0" {
                return Err(format!(
                    "kind '{unknown}': expected only k0 to survive, got {:?}",
                    idx.specs.iter().map(|s| &s.name).collect::<Vec<_>>()
                ));
            }
            Ok(())
        });
    }
}
