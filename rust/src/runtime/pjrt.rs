//! PJRT execution engine — the only module touching the `xla` crate.
//!
//! Loads HLO **text** artifacts (see /opt/xla-example/README: serialized
//! protos from jax >= 0.5 are rejected by xla_extension 0.5.1; the text
//! parser reassigns instruction ids), compiles them on the CPU PJRT
//! client once, caches the executables, and marshals sparse matrices into
//! the kernels' padded bucket layouts.

use super::artifacts::{ArtifactIndex, ArtifactSpec, MatrixDims};
// The offline environment has no `xla` crate; the shim mirrors its API
// and fails at client construction (serving then falls back to native).
// Swapping in the real bindings is a one-line change here.
use super::xla_shim as xla;
use crate::gpusim::MemConfig;
use crate::sparse::convert::AnyFormat;
use crate::sparse::{Csr, Format};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// PJRT engine: client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub index: ArtifactIndex,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions performed (metrics).
    pub exec_count: u64,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let index = ArtifactIndex::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine { client, index, cache: HashMap::new(), exec_count: 0 })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) executable for a spec.
    fn executable(&mut self, spec: &ArtifactSpec) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&spec.name) {
            let proto = xla::HloModuleProto::from_text_file(
                spec.path.to_str().context("artifact path utf8")?,
            )
            .map_err(|e| anyhow!("parse {:?}: {e:?}", spec.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", spec.name))?;
            self.cache.insert(spec.name.clone(), exe);
        }
        Ok(self.cache.get(&spec.name).unwrap())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    fn run(&mut self, spec: &ArtifactSpec, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let name = spec.name.clone();
        let exe = self.executable(spec)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True -> 1-tuple
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let v = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec {name}: {e:?}"))?;
        self.exec_count += 1;
        Ok(v)
    }

    /// Measure a matrix's bucket-selection dimensions.
    pub fn dims_of(csr: &Csr) -> MatrixDims {
        MatrixDims {
            n_rows: csr.n_rows,
            n_cols: csr.n_cols,
            nnz: csr.vals.len(),
            max_row_len: csr.max_row_len(),
            bell_kb: {
                // worst-case occupied 8x8 block columns per block row
                let b = crate::sparse::convert::csr_to_bell(csr, 8, 8);
                b.kb
            },
        }
    }

    /// Execute y = A x through the AOT kernel for `matrix`'s format.
    ///
    /// `choice` optionally biases variant selection toward the
    /// compile-knob mapping (DESIGN.md §2). Returns y truncated to the
    /// true row count. One-shot path: for repeated products with the same
    /// matrix use [`Engine::prepare`] + [`Engine::run_prepared`], which
    /// marshal the matrix-side literals once (EXPERIMENTS.md §Perf
    /// iteration 2).
    pub fn spmv(
        &mut self,
        matrix: &AnyFormat,
        x: &[f32],
        choice: Option<(u32, u32, MemConfig)>,
    ) -> Result<Vec<f32>> {
        let prep = self.prepare(matrix, choice)?;
        self.run_prepared(&prep, x)
    }

    /// Marshal a matrix into its artifact bucket once, for repeated
    /// products. The x vector is every kernel's LAST input, so the
    /// matrix-side literals can be cached and reused.
    pub fn prepare(
        &mut self,
        matrix: &AnyFormat,
        choice: Option<(u32, u32, MemConfig)>,
    ) -> Result<PreparedSpmv> {
        let (dims, n_rows, n_cols) = match matrix {
            AnyFormat::Csr(m) => (Self::dims_of(m), m.n_rows, m.n_cols),
            AnyFormat::Ell(m) => (
                MatrixDims {
                    n_rows: m.n_rows,
                    n_cols: m.n_cols,
                    nnz: { use crate::sparse::Storage; m.stored_entries() },
                    max_row_len: m.width,
                    bell_kb: 0,
                },
                m.n_rows,
                m.n_cols,
            ),
            AnyFormat::Bell(m) => (
                MatrixDims {
                    n_rows: m.n_rows,
                    n_cols: m.n_cols,
                    nnz: 0,
                    max_row_len: 0,
                    bell_kb: m.kb,
                },
                m.n_rows,
                m.n_cols,
            ),
            AnyFormat::Sell(m) => (
                MatrixDims {
                    n_rows: m.n_rows,
                    n_cols: m.n_cols,
                    nnz: 0,
                    max_row_len: m.max_slice_width(),
                    bell_kb: 0,
                },
                m.n_rows,
                m.n_cols,
            ),
        };
        let fmt = matrix.format();
        let spec = self
            .index
            .select(fmt, &dims, choice)
            .with_context(|| format!("no artifact bucket fits {fmt} {dims:?}"))?
            .clone();

        let matrix_literals: Vec<xla::Literal> = match matrix {
            AnyFormat::Ell(m) => {
                let (vals, cols) = m.to_kernel(spec.rows, spec.width);
                vec![
                    lit2(&vals, spec.rows, spec.width)?,
                    lit2i(&cols, spec.rows, spec.width)?,
                ]
            }
            AnyFormat::Sell(m) => {
                // re-slice to the artifact's slice height if needed
                let h = spec.slice_h();
                let resliced;
                let mm = if m.h == h {
                    m
                } else {
                    resliced = crate::sparse::convert::csr_to_sell(
                        &crate::sparse::convert::sell_to_csr(m),
                        h,
                    );
                    &resliced
                };
                let ns_pad = spec.rows / h;
                let (vals, cols) = mm.to_kernel(ns_pad, spec.width);
                vec![
                    lit3(&vals, ns_pad, h, spec.width)?,
                    lit3i(&cols, ns_pad, h, spec.width)?,
                ]
            }
            AnyFormat::Bell(m) => {
                if m.bh != spec.bh() || m.bw != spec.bw() {
                    bail!("BELL block {}x{} != artifact {}x{}", m.bh, m.bw, spec.bh(), spec.bw());
                }
                let nb_pad = spec.rows / spec.bh();
                let (data, bcols) = m.to_kernel(nb_pad, spec.width);
                vec![
                    lit4(&data, nb_pad, spec.width, spec.bh(), spec.bw())?,
                    lit2i(&bcols, nb_pad, spec.width)?,
                ]
            }
            AnyFormat::Csr(m) => {
                let (vals, rows, cols) = m.to_kernel_coo(spec.width);
                vec![
                    xla::Literal::vec1(&vals),
                    xla::Literal::vec1(&rows),
                    xla::Literal::vec1(&cols),
                ]
            }
        };
        Ok(PreparedSpmv {
            spec,
            matrix_literals,
            n_rows,
            x_len: n_cols,
        })
    }

    /// Execute a prepared product: only the x literal is built per call.
    pub fn run_prepared(&mut self, prep: &PreparedSpmv, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != prep.x_len {
            bail!("x length {} != n_cols {}", x.len(), prep.x_len);
        }
        let mut xp = x.to_vec();
        xp.resize(prep.spec.cols, 0.0);
        let x_lit = xla::Literal::vec1(&xp);
        let mut inputs: Vec<&xla::Literal> = prep.matrix_literals.iter().collect();
        inputs.push(&x_lit);
        let name = prep.spec.name.clone();
        let exe = self.executable(&prep.spec)?;
        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let mut y = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec {name}: {e:?}"))?;
        self.exec_count += 1;
        y.truncate(prep.n_rows);
        Ok(y)
    }

    /// Execute a prepared matrix against a whole batch of input vectors —
    /// the PJRT side of [`crate::sparse::SpMv::spmv_batch`]. The matrix
    /// literals are marshalled once and the executable is resolved once;
    /// only the x literal varies per vector. (A true multi-column SpMM
    /// artifact is a compile-layer change tracked in ROADMAP.md; this is
    /// the dispatch-side coalescing the serving pool relies on.)
    pub fn spmv_batch_prepared(
        &mut self,
        prep: &PreparedSpmv,
        xs: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>> {
        xs.iter().map(|x| self.run_prepared(prep, x)).collect()
    }

    /// Execute one power-iteration step x' = A x / ||A x|| using a
    /// `power` artifact (ELL resident variant).
    pub fn power_step(&mut self, ell: &crate::sparse::Ell, x: &[f32]) -> Result<Vec<f32>> {
        let spec = self
            .index
            .power_specs()
            .into_iter()
            .find(|s| {
                s.fmt == Format::Ell
                    && s.rows >= ell.n_rows
                    && s.cols >= ell.n_cols
                    && s.width >= ell.width
            })
            .context("no power artifact fits")?
            .clone();
        let (vals, cols) = ell.to_kernel(spec.rows, spec.width);
        let mut xp = x.to_vec();
        xp.resize(spec.cols, 0.0);
        let inputs = vec![
            lit2(&vals, spec.rows, spec.width)?,
            lit2i(&cols, spec.rows, spec.width)?,
            xla::Literal::vec1(&xp),
        ];
        let mut y = self.run(&spec, &inputs)?;
        y.truncate(ell.n_rows);
        Ok(y)
    }
}

/// A matrix marshalled into its artifact bucket: cached literals + the
/// selected variant. Create with [`Engine::prepare`].
pub struct PreparedSpmv {
    spec: ArtifactSpec,
    matrix_literals: Vec<xla::Literal>,
    n_rows: usize,
    x_len: usize,
}

impl PreparedSpmv {
    pub fn variant_name(&self) -> &str {
        &self.spec.name
    }
}

fn lit2(v: &[f32], a: usize, b: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[a as i64, b as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

fn lit2i(v: &[i32], a: usize, b: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[a as i64, b as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

fn lit3(v: &[f32], a: usize, b: usize, c: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[a as i64, b as i64, c as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

fn lit3i(v: &[i32], a: usize, b: usize, c: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[a as i64, b as i64, c as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

fn lit4(v: &[f32], a: usize, b: usize, c: usize, d: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[a as i64, b as i64, c as i64, d as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

// Integration coverage lives in rust/tests/runtime_integration.rs (needs
// `make artifacts`); unit tests here cover the pure helpers.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;

    #[test]
    fn dims_of_reports_structure() {
        let csr = gen::by_name("rim").unwrap().generate_csr(1);
        let d = Engine::dims_of(&csr);
        assert_eq!(d.n_rows, csr.n_rows);
        assert_eq!(d.nnz, csr.vals.len());
        assert!(d.max_row_len >= 1);
        assert!(d.bell_kb >= 1);
    }

    #[test]
    fn literal_helpers_shape_checks() {
        assert!(lit2(&[1.0, 2.0, 3.0, 4.0], 2, 2).is_ok());
        assert!(lit2(&[1.0, 2.0, 3.0], 2, 2).is_err());
        assert!(lit3i(&[0; 8], 2, 2, 2).is_ok());
        assert!(lit4(&[0.0; 16], 2, 2, 2, 2).is_ok());
    }
}
