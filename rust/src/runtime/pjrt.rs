//! PJRT execution engine — the only module touching the `xla` crate.
//!
//! Loads HLO **text** artifacts (see /opt/xla-example/README: serialized
//! protos from jax >= 0.5 are rejected by xla_extension 0.5.1; the text
//! parser reassigns instruction ids), compiles them on the CPU PJRT
//! client once, caches the executables, and marshals sparse matrices into
//! the kernels' padded bucket layouts.

use super::artifacts::{ArtifactIndex, ArtifactSpec, MatrixDims};
// The offline environment has no `xla` crate; the shim mirrors its API
// and fails at client construction (serving then falls back to native).
// Swapping in the real bindings is a one-line change here.
use super::xla_shim as xla;
use crate::gpusim::MemConfig;
use crate::sparse::convert::AnyFormat;
use crate::sparse::{Csr, Format};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

/// PJRT engine: client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub index: ArtifactIndex,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions performed (metrics).
    pub exec_count: u64,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let index = ArtifactIndex::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine { client, index, cache: HashMap::new(), exec_count: 0 })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) executable for a spec.
    fn executable(&mut self, spec: &ArtifactSpec) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&spec.name) {
            let proto = xla::HloModuleProto::from_text_file(
                spec.path.to_str().context("artifact path utf8")?,
            )
            .map_err(|e| anyhow!("parse {:?}: {e:?}", spec.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", spec.name))?;
            self.cache.insert(spec.name.clone(), exe);
        }
        Ok(self.cache.get(&spec.name).unwrap())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    fn run(&mut self, spec: &ArtifactSpec, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let name = spec.name.clone();
        let exe = self.executable(spec)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True -> 1-tuple
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let v = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec {name}: {e:?}"))?;
        self.exec_count += 1;
        Ok(v)
    }

    /// Measure a matrix's bucket-selection dimensions.
    pub fn dims_of(csr: &Csr) -> MatrixDims {
        MatrixDims {
            n_rows: csr.n_rows,
            n_cols: csr.n_cols,
            nnz: csr.vals.len(),
            max_row_len: csr.max_row_len(),
            bell_kb: {
                // worst-case occupied 8x8 block columns per block row
                let b = crate::sparse::convert::csr_to_bell(csr, 8, 8);
                b.kb
            },
        }
    }

    /// Execute y = A x through the AOT kernel for `matrix`'s format.
    ///
    /// `choice` optionally biases variant selection toward the
    /// compile-knob mapping (DESIGN.md §2). Returns y truncated to the
    /// true row count. One-shot path: for repeated products with the same
    /// matrix use [`Engine::prepare`] + [`Engine::run_prepared`], which
    /// marshal the matrix-side literals once (EXPERIMENTS.md §Perf
    /// iteration 2).
    pub fn spmv(
        &mut self,
        matrix: &AnyFormat,
        x: &[f32],
        choice: Option<(u32, u32, MemConfig)>,
    ) -> Result<Vec<f32>> {
        let prep = self.prepare(matrix, choice)?;
        self.run_prepared(&prep, x)
    }

    /// Bucket-selection dims + true (rows, cols) of any concrete format.
    fn shape_of(matrix: &AnyFormat) -> (MatrixDims, usize, usize) {
        match matrix {
            AnyFormat::Csr(m) => (Self::dims_of(m), m.n_rows, m.n_cols),
            AnyFormat::Ell(m) => (
                MatrixDims {
                    n_rows: m.n_rows,
                    n_cols: m.n_cols,
                    nnz: { use crate::sparse::Storage; m.stored_entries() },
                    max_row_len: m.width,
                    bell_kb: 0,
                },
                m.n_rows,
                m.n_cols,
            ),
            AnyFormat::Bell(m) => (
                MatrixDims {
                    n_rows: m.n_rows,
                    n_cols: m.n_cols,
                    nnz: 0,
                    max_row_len: 0,
                    bell_kb: m.kb,
                },
                m.n_rows,
                m.n_cols,
            ),
            AnyFormat::Sell(m) => (
                MatrixDims {
                    n_rows: m.n_rows,
                    n_cols: m.n_cols,
                    nnz: 0,
                    max_row_len: m.max_slice_width(),
                    bell_kb: 0,
                },
                m.n_rows,
                m.n_cols,
            ),
        }
    }

    /// Marshal a matrix into its artifact bucket once, for repeated
    /// products. The x vector is every kernel's LAST input, so the
    /// matrix-side literals can be cached and reused.
    pub fn prepare(
        &mut self,
        matrix: &AnyFormat,
        choice: Option<(u32, u32, MemConfig)>,
    ) -> Result<PreparedSpmv> {
        let (dims, n_rows, n_cols) = Self::shape_of(matrix);
        let fmt = matrix.format();
        let spec = self
            .index
            .select(fmt, &dims, choice)
            .with_context(|| format!("no artifact bucket fits {fmt} {dims:?}"))?
            .clone();
        let matrix_literals = Rc::new(Self::marshal_matrix(matrix, &spec)?);
        Ok(PreparedSpmv {
            spec,
            matrix_literals,
            n_rows,
            x_len: n_cols,
        })
    }

    /// Marshal a matrix into a variant's bucket layout — shared by the
    /// SpMV and SpMM prepare paths (the matrix-side inputs of an SpMM
    /// artifact are identical to its SpMV sibling's; only X changes).
    fn marshal_matrix(matrix: &AnyFormat, spec: &ArtifactSpec) -> Result<Vec<xla::Literal>> {
        let literals = match matrix {
            AnyFormat::Ell(m) => {
                let (vals, cols) = m.to_kernel(spec.rows, spec.width);
                vec![
                    lit2(&vals, spec.rows, spec.width)?,
                    lit2i(&cols, spec.rows, spec.width)?,
                ]
            }
            AnyFormat::Sell(m) => {
                // re-slice to the artifact's slice height if needed
                let h = spec.slice_h();
                let resliced;
                let mm = if m.h == h {
                    m
                } else {
                    resliced = crate::sparse::convert::csr_to_sell(
                        &crate::sparse::convert::sell_to_csr(m),
                        h,
                    );
                    &resliced
                };
                let ns_pad = spec.rows / h;
                let (vals, cols) = mm.to_kernel(ns_pad, spec.width);
                vec![
                    lit3(&vals, ns_pad, h, spec.width)?,
                    lit3i(&cols, ns_pad, h, spec.width)?,
                ]
            }
            AnyFormat::Bell(m) => {
                if m.bh != spec.bh() || m.bw != spec.bw() {
                    bail!("BELL block {}x{} != artifact {}x{}", m.bh, m.bw, spec.bh(), spec.bw());
                }
                let nb_pad = spec.rows / spec.bh();
                let (data, bcols) = m.to_kernel(nb_pad, spec.width);
                vec![
                    lit4(&data, nb_pad, spec.width, spec.bh(), spec.bw())?,
                    lit2i(&bcols, nb_pad, spec.width)?,
                ]
            }
            AnyFormat::Csr(m) => {
                let (vals, rows, cols) = m.to_kernel_coo(spec.width);
                vec![
                    xla::Literal::vec1(&vals),
                    xla::Literal::vec1(&rows),
                    xla::Literal::vec1(&cols),
                ]
            }
        };
        Ok(literals)
    }

    /// Marshal a matrix against its SpMM (multi-vector) artifact, if one
    /// is compiled for the shape. `Ok(None)` means no SpMM variant fits
    /// — callers keep the per-vector prepared path (the seed inventory
    /// predates SpMM, and quick CI artifact sets only cover ELL/CSR).
    pub fn prepare_spmm(
        &mut self,
        matrix: &AnyFormat,
        choice: Option<(u32, u32, MemConfig)>,
    ) -> Result<Option<PreparedSpmm>> {
        self.prepare_spmm_sharing(matrix, choice, None)
    }

    /// Like [`Engine::prepare_spmm`], but when an already-marshalled
    /// per-vector preparation of the SAME matrix lives in an identical
    /// bucket layout, its matrix-side literals are shared instead of
    /// marshalled (and held) a second time — the padded arrays can
    /// dwarf the source matrix, and SpMV/SpMM siblings of one shape
    /// bucket take byte-identical inputs.
    pub fn prepare_spmm_sharing(
        &mut self,
        matrix: &AnyFormat,
        choice: Option<(u32, u32, MemConfig)>,
        share: Option<&PreparedSpmv>,
    ) -> Result<Option<PreparedSpmm>> {
        let (dims, n_rows, n_cols) = Self::shape_of(matrix);
        let fmt = matrix.format();
        // usize::MAX asks for the widest compiled batch bucket: the
        // executable is compiled once, narrow batches zero-pad into it,
        // and only k > bucket chunks (acceptance: one launch per
        // coalesced batch unless k exceeds the largest bucket).
        let Some(spec) = self.index.select_spmm(fmt, &dims, usize::MAX, choice) else {
            return Ok(None);
        };
        let spec = spec.clone();
        let matrix_literals = match share {
            Some(p) if same_matrix_layout(&p.spec, &spec) => Rc::clone(&p.matrix_literals),
            _ => Rc::new(Self::marshal_matrix(matrix, &spec)?),
        };
        Ok(Some(PreparedSpmm {
            spec,
            matrix_literals,
            n_rows,
            x_len: n_cols,
        }))
    }

    /// Execute a prepared SpMM against a whole coalesced batch: ONE
    /// launch per `ncols`-bucket chunk. Each chunk builds a single
    /// `(ncols, cols)` X literal — the k vectors padded to the bucket's
    /// column count, missing batch rows zero-padded — and splits the
    /// `(ncols, rows)` result back into per-vector outputs truncated to
    /// the true row count. Bit-wise the kernel computes each output row
    /// independently, so results match `run_prepared` per vector.
    pub fn spmm_prepared(
        &mut self,
        prep: &PreparedSpmm,
        xs: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        let bucket = prep.ncols();
        let cols = prep.spec.cols;
        let mut out: Vec<Vec<f32>> = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(bucket.max(1)) {
            let mut xp = vec![0.0f32; bucket * cols];
            for (i, x) in chunk.iter().enumerate() {
                if x.len() != prep.x_len {
                    bail!("x length {} != n_cols {}", x.len(), prep.x_len);
                }
                xp[i * cols..i * cols + x.len()].copy_from_slice(x);
            }
            let x_lit = xla::Literal::vec1(&xp)
                .reshape(&[bucket as i64, cols as i64])
                .map_err(|e| anyhow!("reshape X: {e:?}"))?;
            let mut inputs: Vec<&xla::Literal> = prep.matrix_literals.iter().collect();
            inputs.push(&x_lit);
            let name = prep.spec.name.clone();
            let exe = self.executable(&prep.spec)?;
            let result = exe
                .execute::<&xla::Literal>(&inputs)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
            let y_all = result
                .to_tuple1()
                .map_err(|e| anyhow!("untuple {name}: {e:?}"))?
                .to_vec::<f32>()
                .map_err(|e| anyhow!("to_vec {name}: {e:?}"))?;
            self.exec_count += 1;
            // (ncols, rows) row-major -> one padded row vector per input
            out.extend(
                y_all
                    .chunks(prep.spec.rows)
                    .take(chunk.len())
                    .map(|y| y[..prep.n_rows].to_vec()),
            );
        }
        Ok(out)
    }

    /// Execute a prepared product: only the x literal is built per call.
    pub fn run_prepared(&mut self, prep: &PreparedSpmv, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != prep.x_len {
            bail!("x length {} != n_cols {}", x.len(), prep.x_len);
        }
        let mut xp = x.to_vec();
        xp.resize(prep.spec.cols, 0.0);
        let x_lit = xla::Literal::vec1(&xp);
        let mut inputs: Vec<&xla::Literal> = prep.matrix_literals.iter().collect();
        inputs.push(&x_lit);
        let name = prep.spec.name.clone();
        let exe = self.executable(&prep.spec)?;
        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let mut y = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec {name}: {e:?}"))?;
        self.exec_count += 1;
        y.truncate(prep.n_rows);
        Ok(y)
    }

    /// Execute a prepared matrix against a batch of input vectors, one
    /// launch per vector. This is the FALLBACK batch path for shapes
    /// without a compiled SpMM artifact ([`Engine::prepare_spmm`]
    /// returned `None`); when one exists, [`Engine::spmm_prepared`]
    /// serves the whole batch in a single launch per bucket chunk.
    pub fn spmv_batch_prepared(
        &mut self,
        prep: &PreparedSpmv,
        xs: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        xs.iter().map(|x| self.run_prepared(prep, x)).collect()
    }

    /// Marshal an ELL matrix against the fused power-step artifact
    /// (x' = A x / ||A x|| in ONE module), if one fits. `Ok(None)` means
    /// no power variant is compiled for the shape — sessions then serve
    /// normalized steps as a plain product plus a host-side scale.
    pub fn prepare_power(&mut self, ell: &crate::sparse::Ell) -> Result<Option<PreparedPower>> {
        let Some(spec) = self
            .index
            .power_specs()
            .into_iter()
            .find(|s| {
                s.fmt == Format::Ell
                    && s.rows >= ell.n_rows
                    && s.cols >= ell.n_cols
                    && s.width >= ell.width
            })
            .cloned()
        else {
            return Ok(None);
        };
        let (vals, cols) = ell.to_kernel(spec.rows, spec.width);
        let matrix_literals = vec![
            lit2(&vals, spec.rows, spec.width)?,
            lit2i(&cols, spec.rows, spec.width)?,
        ];
        Ok(Some(PreparedPower { spec, matrix_literals, n_rows: ell.n_rows, x_len: ell.n_cols }))
    }

    /// Execute one fused power step against a prepared (once-marshalled)
    /// artifact; only the x literal is built per call.
    pub fn power_step_prepared(&mut self, prep: &PreparedPower, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != prep.x_len {
            bail!("x length {} != n_cols {}", x.len(), prep.x_len);
        }
        let mut xp = x.to_vec();
        xp.resize(prep.spec.cols, 0.0);
        let mut inputs: Vec<xla::Literal> = prep.matrix_literals.clone();
        inputs.push(xla::Literal::vec1(&xp));
        let mut y = self.run(&prep.spec, &inputs)?;
        y.truncate(prep.n_rows);
        Ok(y)
    }

    /// Execute one power-iteration step x' = A x / ||A x|| using a
    /// `power` artifact (ELL resident variant). One-shot path: for
    /// repeated steps use [`Engine::prepare_power`] +
    /// [`Engine::power_step_prepared`] (or a [`PreparedSession`]),
    /// which marshal the matrix literals once.
    pub fn power_step(&mut self, ell: &crate::sparse::Ell, x: &[f32]) -> Result<Vec<f32>> {
        let prep = self.prepare_power(ell)?.context("no power artifact fits")?;
        self.power_step_prepared(&prep, x)
    }

    /// Prepare a device-resident iterative session over a square
    /// matrix: the per-step SpMV preparation, plus the fused power-step
    /// artifact when the matrix is ELL and one fits. Chained steps can
    /// keep the vector on the device only when the artifact's bucket is
    /// square (a step's padded output is then shape-compatible with the
    /// next step's x input); [`Engine::session_step`] reports when it
    /// had to bounce through the host instead.
    pub fn prepare_session(
        &mut self,
        matrix: &AnyFormat,
        choice: Option<(u32, u32, MemConfig)>,
    ) -> Result<PreparedSession> {
        let (_, n_rows, n_cols) = Self::shape_of(matrix);
        if n_rows != n_cols {
            bail!("iterative session requires a square matrix ({n_rows}x{n_cols})");
        }
        let spmv = self.prepare(matrix, choice)?;
        let power = match matrix {
            AnyFormat::Ell(m) => self.prepare_power(m)?,
            _ => None,
        };
        Ok(PreparedSession { spmv, power, n: n_rows })
    }

    /// One session step: y = A x (or the fused x' = A x / ||A x|| when
    /// `normalize` and a power artifact is bound), consuming the
    /// previous vector state and returning the next. A `Device` input
    /// chains by buffer identity — no host round-trip — whenever the
    /// executing artifact's bucket is square; otherwise the state
    /// bounces through the host once and the step reports it. A
    /// `normalize` step without a fused artifact executes the plain
    /// product and normalizes host-side (also a reported bounce).
    pub fn session_step(
        &mut self,
        sess: &PreparedSession,
        state: SessionVec,
        normalize: bool,
    ) -> Result<(SessionVec, bool)> {
        if normalize && sess.power.is_none() {
            // no fused artifact: plain product, then host-side scale
            let (next, _) = self.session_step(sess, state, false)?;
            let mut y = self.session_read(sess, &next)?;
            let norm: f32 = y.iter().map(|v| v * v).sum::<f32>().sqrt();
            for v in &mut y {
                *v /= norm;
            }
            return Ok((SessionVec::Host(y), true));
        }
        let (spec, literals): (&ArtifactSpec, &[xla::Literal]) = if normalize {
            let p = sess.power.as_ref().expect("checked above");
            (&p.spec, &p.matrix_literals)
        } else {
            (&sess.spmv.spec, &sess.spmv.matrix_literals)
        };
        // a chained device buffer has the previous step's padded output
        // shape (spec.rows); it is a valid x input only for a square
        // bucket (rows beyond the true n are zero either way)
        let chains = spec.rows == spec.cols;
        let mut round_trip = false;
        let host; // keeps a bounced/padded host vector alive across execute
        let x_input: xla::ExecInput = match &state {
            SessionVec::Device(buf) if chains => xla::ExecInput::Buffer(buf),
            SessionVec::Device(buf) => {
                round_trip = true;
                let mut v = self.buffer_to_host(buf, sess.n)?;
                v.resize(spec.cols, 0.0);
                host = xla::Literal::vec1(&v);
                xla::ExecInput::Literal(&host)
            }
            SessionVec::Host(v) => {
                if v.len() != sess.n {
                    bail!("session vector length {} != n {}", v.len(), sess.n);
                }
                let mut vp = v.clone();
                vp.resize(spec.cols, 0.0);
                host = xla::Literal::vec1(&vp);
                xla::ExecInput::Literal(&host)
            }
        };
        let mut inputs: Vec<xla::ExecInput> =
            literals.iter().map(xla::ExecInput::Literal).collect();
        inputs.push(x_input);
        let name = spec.name.clone();
        let spec = spec.clone();
        let exe = self.executable(&spec)?;
        let out = exe
            .execute_inputs(&inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?
            .remove(0)
            .remove(0)
            // aot.py lowers with return_tuple=True: project the 1-tuple
            // on device so the y buffer itself can chain
            .tuple_element(0)
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        self.exec_count += 1;
        Ok((SessionVec::Device(out), round_trip))
    }

    /// Copy a session vector out to the host (the session's explicit
    /// `read()` escape hatch, and the bounce path of a non-chainable
    /// step). Truncates to the true dimension.
    pub fn session_read(&mut self, sess: &PreparedSession, state: &SessionVec) -> Result<Vec<f32>> {
        match state {
            SessionVec::Host(v) => Ok(v.clone()),
            SessionVec::Device(buf) => self.buffer_to_host(buf, sess.n),
        }
    }

    fn buffer_to_host(&mut self, buf: &xla::PjRtBuffer, n: usize) -> Result<Vec<f32>> {
        let mut v = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch session vector: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("session vector to_vec: {e:?}"))?;
        v.truncate(n);
        Ok(v)
    }
}

/// Do two variants take byte-identical matrix-side inputs? True when
/// the shape bucket AND every layout-affecting extra (SELL slice
/// height, BELL block dims) agree — the precondition for sharing
/// marshalled literals between an SpMV and an SpMM preparation.
fn same_matrix_layout(a: &ArtifactSpec, b: &ArtifactSpec) -> bool {
    a.fmt == b.fmt
        && a.rows == b.rows
        && a.cols == b.cols
        && a.width == b.width
        && a.slice_h() == b.slice_h()
        && a.bh() == b.bh()
        && a.bw() == b.bw()
}

/// A matrix marshalled into its artifact bucket: cached literals + the
/// selected variant. Create with [`Engine::prepare`]. The literals sit
/// behind an `Rc` so an SpMM sibling preparation can share them
/// ([`Engine::prepare_spmm_sharing`]); nothing here is `Send` anyway —
/// the engine is pinned to its shard thread.
pub struct PreparedSpmv {
    spec: ArtifactSpec,
    matrix_literals: Rc<Vec<xla::Literal>>,
    n_rows: usize,
    x_len: usize,
}

impl PreparedSpmv {
    pub fn variant_name(&self) -> &str {
        &self.spec.name
    }

    /// The Pallas knob triple of the bound variant (block_rows,
    /// chunk_width, x placement) — what a `CompileChoice` preference
    /// actually selected through `knob_map`.
    pub fn variant_knobs(&self) -> (usize, usize, &str) {
        (self.spec.block_rows, self.spec.chunk_width, self.spec.x_placement.as_str())
    }
}

/// A matrix marshalled against its SpMM (multi-vector) artifact: the
/// cached matrix-side literals (possibly shared with the per-vector
/// preparation) plus the batch-bucket variant. Create with
/// [`Engine::prepare_spmm`]; execute with [`Engine::spmm_prepared`].
pub struct PreparedSpmm {
    spec: ArtifactSpec,
    matrix_literals: Rc<Vec<xla::Literal>>,
    n_rows: usize,
    x_len: usize,
}

impl PreparedSpmm {
    pub fn variant_name(&self) -> &str {
        &self.spec.name
    }

    /// The Pallas knob triple of the bound SpMM variant — records
    /// which knob point of the swept inventory this preparation
    /// selected (DESIGN.md §8).
    pub fn variant_knobs(&self) -> (usize, usize, &str) {
        (self.spec.block_rows, self.spec.chunk_width, self.spec.x_placement.as_str())
    }

    /// Batch bucket: vectors consumed per launch.
    pub fn ncols(&self) -> usize {
        self.spec.ncols()
    }

    /// Launches a `k`-vector batch costs on this artifact (1 unless `k`
    /// exceeds the compiled bucket).
    pub fn launches_for(&self, k: usize) -> usize {
        super::artifacts::spmm_launches(k, self.ncols())
    }
}

/// An ELL matrix marshalled ONCE against the fused power-step artifact
/// (x' = A x / ||A x||). Unlike the one-shot [`Engine::power_step`],
/// repeated steps through [`Engine::power_step_prepared`] (or a
/// session) rebuild only the x literal.
pub struct PreparedPower {
    spec: ArtifactSpec,
    matrix_literals: Vec<xla::Literal>,
    n_rows: usize,
    x_len: usize,
}

impl PreparedPower {
    pub fn variant_name(&self) -> &str {
        &self.spec.name
    }
}

/// Vector state of an iterative session: `Host` between explicit
/// writes (and after a bounced step), `Device` after a chained step —
/// the execution's y output buffer held by identity, never copied to
/// the host until `read()`.
pub enum SessionVec {
    Host(Vec<f32>),
    Device(xla::PjRtBuffer),
}

/// A pinned matrix's session preparation: the per-step SpMV literals
/// plus (when the matrix is ELL and the inventory has one) the fused
/// power-step artifact, both marshalled once at session open. Create
/// with [`Engine::prepare_session`]; drive with
/// [`Engine::session_step`] / [`Engine::session_read`].
pub struct PreparedSession {
    spmv: PreparedSpmv,
    power: Option<PreparedPower>,
    /// True (square) dimension: outputs truncate to it, inputs must
    /// match it.
    n: usize,
}

impl PreparedSession {
    pub fn variant_name(&self) -> &str {
        self.spmv.variant_name()
    }

    /// Does a fused power-step artifact back `normalize` steps?
    pub fn has_fused_power(&self) -> bool {
        self.power.is_some()
    }

    pub fn n(&self) -> usize {
        self.n
    }
}

fn lit2(v: &[f32], a: usize, b: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[a as i64, b as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

fn lit2i(v: &[i32], a: usize, b: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[a as i64, b as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

fn lit3(v: &[f32], a: usize, b: usize, c: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[a as i64, b as i64, c as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

fn lit3i(v: &[i32], a: usize, b: usize, c: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[a as i64, b as i64, c as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

fn lit4(v: &[f32], a: usize, b: usize, c: usize, d: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[a as i64, b as i64, c as i64, d as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

// Integration coverage lives in rust/tests/runtime_integration.rs (needs
// `make artifacts`); unit tests here cover the pure helpers.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;

    #[test]
    fn dims_of_reports_structure() {
        let csr = gen::by_name("rim").unwrap().generate_csr(1);
        let d = Engine::dims_of(&csr);
        assert_eq!(d.n_rows, csr.n_rows);
        assert_eq!(d.nnz, csr.vals.len());
        assert!(d.max_row_len >= 1);
        assert!(d.bell_kb >= 1);
    }

    #[test]
    fn prepared_spmm_reports_bucket_and_chunking() {
        let spec = ArtifactSpec {
            name: "spmm_test".into(),
            kind: super::super::artifacts::Kind::Spmm,
            fmt: Format::Ell,
            rows: 256,
            cols: 256,
            width: 16,
            block_rows: 64,
            chunk_width: 8,
            x_placement: "resident".into(),
            extra: HashMap::from([("nc".to_string(), 8usize)]),
            path: std::path::PathBuf::from("spmm_test.hlo.txt"),
        };
        let prep =
            PreparedSpmm { spec, matrix_literals: Rc::new(vec![]), n_rows: 200, x_len: 200 };
        assert_eq!(prep.ncols(), 8);
        assert_eq!(prep.variant_name(), "spmm_test");
        assert_eq!(
            prep.variant_knobs(),
            (64, 8, "resident"),
            "the preparation must record which knob variant it bound"
        );
        assert_eq!(prep.launches_for(1), 1);
        assert_eq!(prep.launches_for(8), 1, "k = bucket stays one launch");
        assert_eq!(prep.launches_for(9), 2, "only k > bucket chunks");
    }

    #[test]
    fn layout_sharing_requires_identical_buckets() {
        let spec = |fmt, rows, extra: &[(&str, usize)]| ArtifactSpec {
            name: "s".into(),
            kind: super::super::artifacts::Kind::Spmm,
            fmt,
            rows,
            cols: 256,
            width: 16,
            block_rows: 64,
            chunk_width: 8,
            x_placement: "resident".into(),
            extra: extra.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            path: std::path::PathBuf::from("s.hlo.txt"),
        };
        let a = spec(Format::Ell, 256, &[]);
        assert!(same_matrix_layout(&a, &spec(Format::Ell, 256, &[("nc", 8)])),
            "the batch bucket does not change the matrix-side layout");
        assert!(!same_matrix_layout(&a, &spec(Format::Ell, 1024, &[])));
        assert!(!same_matrix_layout(&a, &spec(Format::Sell, 256, &[])));
        assert!(!same_matrix_layout(
            &spec(Format::Sell, 256, &[("h", 8)]),
            &spec(Format::Sell, 256, &[("h", 32)])
        ));
    }

    #[test]
    fn literal_helpers_shape_checks() {
        assert!(lit2(&[1.0, 2.0, 3.0, 4.0], 2, 2).is_ok());
        assert!(lit2(&[1.0, 2.0, 3.0], 2, 2).is_err());
        assert!(lit3i(&[0; 8], 2, 2, 2).is_ok());
        assert!(lit4(&[0.0; 16], 2, 2, 2, 2).is_ok());
    }
}
