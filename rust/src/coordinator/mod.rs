//! Layer-3 coordinator — the Auto-SpMV framework proper (paper §5).
//!
//! * [`compile_time`] — §5.2: predict optimal compile parameters
//!   (TB size, maxrregcount, memory config) from sparsity features.
//! * [`run_time`] — §5.3: predict the optimal sparse format, estimate the
//!   conversion overhead, and convert only when the predicted gain
//!   exceeds it.
//! * [`overhead`] — §7.5: regression models for f_latency / c_latency.
//! * [`service`] — legacy single-worker serving API, now a thin shim
//!   over the sharded batching engine in [`crate::serve`].

pub mod compile_time;
pub mod overhead;
pub mod run_time;
pub mod service;

pub use compile_time::{CompileChoice, CompileTimeOptimizer, KnobPolicy};
pub use overhead::OverheadModel;
pub use run_time::{Decision, RunTimeOptimizer};
