//! Compile-time optimization mode (paper §5.2, Fig. 5a):
//! 1. compute sparsity features;
//! 2. predict optimal compile parameters (TB size, maxrregcount, memory
//!    hierarchy config) with per-objective classifiers;
//! 3. compile the CSR kernel with those parameters (here: select the
//!    matching simulator configuration and/or AOT artifact variant).

use crate::dataset::labels::{self, Example, Target};
use crate::dataset::Dataset;
use crate::features::Features;
use crate::gpusim::{KernelConfig, MemConfig, Objective, MAXRREGCOUNT, TB_SIZES};
use crate::ml::tree::DecisionTreeClassifier;
use crate::ml::Classifier;
use crate::sparse::Format;

/// Predicted compile parameters for one objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompileChoice {
    pub tb_size: u32,
    pub maxrregcount: u32,
    pub mem: MemConfig,
}

impl CompileChoice {
    /// Into a full kernel config with the compile-mode's fixed CSR format.
    pub fn to_config(self) -> KernelConfig {
        KernelConfig {
            format: Format::Csr,
            tb_size: self.tb_size,
            maxrregcount: self.maxrregcount,
            mem: self.mem,
        }
    }

    /// The serving default: what a pool runs before any knob policy is
    /// installed (mid TB size, no register-cap pressure, default
    /// carve-out — the PR 2/3 telemetry assumption).
    pub fn serving_default() -> CompileChoice {
        CompileChoice { tb_size: 256, maxrregcount: 64, mem: MemConfig::Default }
    }

    /// Tuple form the artifact selector takes (`ArtifactIndex::select*`).
    pub fn knobs(self) -> (u32, u32, MemConfig) {
        (self.tb_size, self.maxrregcount, self.mem)
    }

    /// Full kernel config at this choice for an arbitrary format (the
    /// joint run-time decision; [`CompileChoice::to_config`] keeps the
    /// compile-mode's fixed-CSR semantics).
    pub fn config_for(self, format: Format) -> KernelConfig {
        KernelConfig {
            format,
            tb_size: self.tb_size,
            maxrregcount: self.maxrregcount,
            mem: self.mem,
        }
    }

    /// The knob slice of a full kernel config (format dropped).
    pub fn from_config(c: &KernelConfig) -> CompileChoice {
        CompileChoice { tb_size: c.tb_size, maxrregcount: c.maxrregcount, mem: c.mem }
    }
}

impl std::fmt::Display for CompileChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tb{}/r{}/{}", self.tb_size, self.maxrregcount, self.mem.name())
    }
}

/// Per-objective compile-parameter predictor (three decision trees, the
/// paper's winning model family — Table 5).
pub struct CompileTimeOptimizer {
    pub objective: Objective,
    tb_model: DecisionTreeClassifier,
    reg_model: DecisionTreeClassifier,
    mem_model: DecisionTreeClassifier,
}

impl CompileTimeOptimizer {
    /// Train on a dataset (one example per matrix x arch).
    pub fn train(ds: &Dataset, objective: Objective) -> Self {
        let ex = labels::examples(ds, objective);
        Self::train_on_examples(&ex, objective)
    }

    /// Train from pre-derived examples (lets callers share label work).
    pub fn train_on_examples(ex: &[Example], objective: Objective) -> Self {
        let fit = |target: Target| {
            let (x, y) = labels::to_xy(ex, target);
            let mut m = DecisionTreeClassifier::default();
            m.fit(&x, &y);
            m
        };
        CompileTimeOptimizer {
            objective,
            tb_model: fit(Target::TbSize),
            reg_model: fit(Target::MaxRegCount),
            mem_model: fit(Target::MemConfig),
        }
    }

    /// Predict the compile parameters for an unseen matrix on a device.
    pub fn predict(&self, f: &Features, arch: &str) -> CompileChoice {
        let mut x = f.to_scaled_vec();
        x.push(crate::dataset::labels::arch_feature(arch));
        let tb = TB_SIZES[self.tb_model.predict_one(&x).min(TB_SIZES.len() - 1)];
        let regs =
            MAXRREGCOUNT[self.reg_model.predict_one(&x).min(MAXRREGCOUNT.len() - 1)];
        let mem = MemConfig::from_class_id(self.mem_model.predict_one(&x))
            .unwrap_or(MemConfig::Default);
        CompileChoice { tb_size: tb, maxrregcount: regs, mem }
    }
}

/// Per-format compile-knob policy: one [`CompileTimeOptimizer`] per
/// sparse format, so the run-time router's format decision can be
/// paired with the knobs that are best *for that format* (the joint
/// (format, knob) decision of DESIGN.md §8). The §5.2 optimizer fixes
/// CSR; this generalizes its label derivation to every format's own
/// sweep slice, and the online trainer refits it from serving evidence.
pub struct KnobPolicy {
    pub objective: Objective,
    /// `Format::ALL` order; `None` when a format had no examples (its
    /// predictions fall back to the serving default).
    by_format: Vec<Option<CompileTimeOptimizer>>,
    /// Deployment profile name (selects the arch indicator feature).
    arch: String,
}

impl KnobPolicy {
    /// Offline per-format knob labels: for each (matrix, arch, format),
    /// the best compile config among that format's sweep records.
    pub fn offline_examples(ds: &Dataset, objective: Objective) -> Vec<(Format, Example)> {
        let mut out = Vec::new();
        for matrix in ds.matrices() {
            for arch in ds.archs() {
                let slice = ds.slice(&matrix, &arch);
                if slice.is_empty() {
                    continue;
                }
                let mut feats = slice[0].features.to_scaled_vec();
                feats.push(labels::arch_feature(&arch));
                for f in Format::ALL {
                    let mut best: Option<(&crate::dataset::Record, f64)> = None;
                    for r in slice.iter().copied().filter(|r| r.config.format == f) {
                        let v = objective.value(&r.m);
                        if best.is_none_or(|(_, bv)| objective.better(v, bv)) {
                            best = Some((r, v));
                        }
                    }
                    let Some((r, v)) = best else { continue };
                    out.push((f, knob_example(&matrix, &arch, feats.clone(), &r.config, v)));
                }
            }
        }
        out
    }

    /// Fit the per-format predictors from `(format, example)` pairs —
    /// offline labels, online labels, or both concatenated.
    pub fn train(objective: Objective, arch: &str, ex: &[(Format, Example)]) -> KnobPolicy {
        let by_format = Format::ALL
            .iter()
            .map(|f| {
                let own: Vec<Example> = ex
                    .iter()
                    .filter(|(ff, _)| ff == f)
                    .map(|(_, e)| e.clone())
                    .collect();
                (!own.is_empty())
                    .then(|| CompileTimeOptimizer::train_on_examples(&own, objective))
            })
            .collect();
        KnobPolicy { objective, by_format, arch: arch.to_string() }
    }

    /// Convenience: offline-only policy for a dataset.
    pub fn train_on_dataset(ds: &Dataset, objective: Objective, arch: &str) -> KnobPolicy {
        Self::train(objective, arch, &Self::offline_examples(ds, objective))
    }

    /// Knob decision for a matrix already routed to `format`.
    pub fn predict(&self, f: &Features, format: Format) -> CompileChoice {
        match &self.by_format[format.class_id()] {
            Some(opt) => opt.predict(f, &self.arch),
            None => CompileChoice::serving_default(),
        }
    }
}

/// Build a knob [`Example`] from an already-scaled feature vector and
/// the winning config. Class lookups are tolerant: a config outside the
/// sweep grid (possible for deserialized online evidence) snaps to the
/// serving-default classes instead of panicking.
pub fn knob_example(
    matrix: &str,
    arch: &str,
    features: Vec<f64>,
    config: &KernelConfig,
    value: f64,
) -> Example {
    let tb_class = TB_SIZES
        .iter()
        .position(|&t| t == config.tb_size)
        .unwrap_or_else(|| KernelConfig::default_baseline().tb_class());
    let reg_class = MAXRREGCOUNT
        .iter()
        .position(|&r| r == config.maxrregcount)
        .unwrap_or_else(|| KernelConfig::default_baseline().reg_class());
    Example {
        matrix: matrix.to_string(),
        arch: arch.to_string(),
        features,
        tb_class,
        reg_class,
        mem_class: config.mem.class_id(),
        format_class: config.format.class_id(),
        best_compile: value,
        best_format_value: value,
        default_value: value,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{build, BuildOptions};
    use crate::features::extract_csr;
    use crate::gen;

    #[test]
    fn trained_optimizer_beats_default_on_seen_matrices() {
        let names = ["rim", "eu-2005", "consph", "crankseg_1", "amazon0601"];
        let ds = build(&BuildOptions {
            only: Some(names.iter().map(|s| s.to_string()).collect()),
            both_archs: false,
            ..Default::default()
        });
        let obj = Objective::Latency;
        let opt = CompileTimeOptimizer::train(&ds, obj);

        for name in names {
            let entry = gen::by_name(name).unwrap();
            let csr = entry.generate_csr(1);
            let f = extract_csr(&csr);
            let choice = opt.predict(&f, "GTX1650m-Turing");
            // find the chosen and default configs in the sweep
            let slice = ds.slice(name, "GTX1650m-Turing");
            let chosen = slice
                .iter()
                .find(|r| r.config == choice.to_config())
                .expect("choice in sweep");
            let default = slice
                .iter()
                .find(|r| r.config == KernelConfig::default_baseline())
                .unwrap();
            assert!(
                chosen.m.latency_s <= default.m.latency_s * 1.0001,
                "{name}: chosen {} > default {}",
                chosen.m.latency_s,
                default.m.latency_s
            );
        }
    }

    #[test]
    fn predicts_valid_choices() {
        let ds = build(&BuildOptions {
            only: Some(vec!["rim".into(), "cant".into()]),
            both_archs: false,
            ..Default::default()
        });
        for obj in Objective::ALL {
            let opt = CompileTimeOptimizer::train(&ds, obj);
            let f = ds.records[0].features;
            let c = opt.predict(&f, "GTX1650m-Turing");
            assert!(TB_SIZES.contains(&c.tb_size));
            assert!(MAXRREGCOUNT.contains(&c.maxrregcount));
        }
    }

    #[test]
    fn choice_helpers_roundtrip() {
        let c = CompileChoice { tb_size: 128, maxrregcount: 32, mem: MemConfig::PreferShared };
        let k = c.config_for(Format::Sell);
        assert_eq!(k.format, Format::Sell);
        assert_eq!(CompileChoice::from_config(&k), c);
        assert_eq!(c.knobs(), (128, 32, MemConfig::PreferShared));
        assert_eq!(c.to_string(), "tb128/r32/prefer_shared");
        let d = CompileChoice::serving_default();
        assert_eq!((d.tb_size, d.maxrregcount, d.mem), (256, 64, MemConfig::Default));
    }

    #[test]
    fn knob_policy_labels_per_format_optima_from_the_sweep() {
        let names = ["rim", "eu-2005", "consph"];
        let ds = build(&BuildOptions {
            only: Some(names.iter().map(|s| s.to_string()).collect()),
            both_archs: false,
            ..Default::default()
        });
        let obj = Objective::Energy;
        let policy = KnobPolicy::train_on_dataset(&ds, obj, "GTX1650m-Turing");
        for name in names {
            let entry = gen::by_name(name).unwrap();
            let f = extract_csr(&entry.generate_csr(1));
            for fmt in Format::ALL {
                let choice = policy.predict(&f, fmt);
                // the predicted config must exist in that format's sweep
                let slice = ds.slice(name, "GTX1650m-Turing");
                let rec = slice
                    .iter()
                    .find(|r| r.config == choice.config_for(fmt))
                    .unwrap_or_else(|| panic!("{name}/{fmt}: {choice} not in sweep"));
                // and a seen matrix's prediction must not lose to the
                // format's default-knob point (trees memorize training
                // labels; ties allowed)
                let default_cfg = CompileChoice::serving_default().config_for(fmt);
                let default = slice.iter().find(|r| r.config == default_cfg).unwrap();
                assert!(
                    obj.value(&rec.m) <= obj.value(&default.m) * 1.0001,
                    "{name}/{fmt}: predicted {choice} worse than the default knobs"
                );
            }
        }
    }

    #[test]
    fn knob_policy_without_examples_falls_back_to_default() {
        let policy = KnobPolicy::train(Objective::Latency, "GTX1650m-Turing", &[]);
        let ds = build(&BuildOptions {
            only: Some(vec!["rim".into()]),
            both_archs: false,
            ..Default::default()
        });
        let f = ds.records[0].features;
        for fmt in Format::ALL {
            assert_eq!(policy.predict(&f, fmt), CompileChoice::serving_default());
        }
    }
}
