//! Compile-time optimization mode (paper §5.2, Fig. 5a):
//! 1. compute sparsity features;
//! 2. predict optimal compile parameters (TB size, maxrregcount, memory
//!    hierarchy config) with per-objective classifiers;
//! 3. compile the CSR kernel with those parameters (here: select the
//!    matching simulator configuration and/or AOT artifact variant).

use crate::dataset::labels::{self, Example, Target};
use crate::dataset::Dataset;
use crate::features::Features;
use crate::gpusim::{KernelConfig, MemConfig, Objective, MAXRREGCOUNT, TB_SIZES};
use crate::ml::tree::DecisionTreeClassifier;
use crate::ml::Classifier;
use crate::sparse::Format;

/// Predicted compile parameters for one objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompileChoice {
    pub tb_size: u32,
    pub maxrregcount: u32,
    pub mem: MemConfig,
}

impl CompileChoice {
    /// Into a full kernel config with the compile-mode's fixed CSR format.
    pub fn to_config(self) -> KernelConfig {
        KernelConfig {
            format: Format::Csr,
            tb_size: self.tb_size,
            maxrregcount: self.maxrregcount,
            mem: self.mem,
        }
    }
}

/// Per-objective compile-parameter predictor (three decision trees, the
/// paper's winning model family — Table 5).
pub struct CompileTimeOptimizer {
    pub objective: Objective,
    tb_model: DecisionTreeClassifier,
    reg_model: DecisionTreeClassifier,
    mem_model: DecisionTreeClassifier,
}

impl CompileTimeOptimizer {
    /// Train on a dataset (one example per matrix x arch).
    pub fn train(ds: &Dataset, objective: Objective) -> Self {
        let ex = labels::examples(ds, objective);
        Self::train_on_examples(&ex, objective)
    }

    /// Train from pre-derived examples (lets callers share label work).
    pub fn train_on_examples(ex: &[Example], objective: Objective) -> Self {
        let fit = |target: Target| {
            let (x, y) = labels::to_xy(ex, target);
            let mut m = DecisionTreeClassifier::default();
            m.fit(&x, &y);
            m
        };
        CompileTimeOptimizer {
            objective,
            tb_model: fit(Target::TbSize),
            reg_model: fit(Target::MaxRegCount),
            mem_model: fit(Target::MemConfig),
        }
    }

    /// Predict the compile parameters for an unseen matrix on a device.
    pub fn predict(&self, f: &Features, arch: &str) -> CompileChoice {
        let mut x = f.to_scaled_vec();
        x.push(crate::dataset::labels::arch_feature(arch));
        let tb = TB_SIZES[self.tb_model.predict_one(&x).min(TB_SIZES.len() - 1)];
        let regs =
            MAXRREGCOUNT[self.reg_model.predict_one(&x).min(MAXRREGCOUNT.len() - 1)];
        let mem = MemConfig::from_class_id(self.mem_model.predict_one(&x))
            .unwrap_or(MemConfig::Default);
        CompileChoice { tb_size: tb, maxrregcount: regs, mem }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{build, BuildOptions};
    use crate::features::extract_csr;
    use crate::gen;

    #[test]
    fn trained_optimizer_beats_default_on_seen_matrices() {
        let names = ["rim", "eu-2005", "consph", "crankseg_1", "amazon0601"];
        let ds = build(&BuildOptions {
            only: Some(names.iter().map(|s| s.to_string()).collect()),
            both_archs: false,
            ..Default::default()
        });
        let obj = Objective::Latency;
        let opt = CompileTimeOptimizer::train(&ds, obj);

        for name in names {
            let entry = gen::by_name(name).unwrap();
            let csr = entry.generate_csr(1);
            let f = extract_csr(&csr);
            let choice = opt.predict(&f, "GTX1650m-Turing");
            // find the chosen and default configs in the sweep
            let slice = ds.slice(name, "GTX1650m-Turing");
            let chosen = slice
                .iter()
                .find(|r| r.config == choice.to_config())
                .expect("choice in sweep");
            let default = slice
                .iter()
                .find(|r| r.config == KernelConfig::default_baseline())
                .unwrap();
            assert!(
                chosen.m.latency_s <= default.m.latency_s * 1.0001,
                "{name}: chosen {} > default {}",
                chosen.m.latency_s,
                default.m.latency_s
            );
        }
    }

    #[test]
    fn predicts_valid_choices() {
        let ds = build(&BuildOptions {
            only: Some(vec!["rim".into(), "cant".into()]),
            both_archs: false,
            ..Default::default()
        });
        for obj in Objective::ALL {
            let opt = CompileTimeOptimizer::train(&ds, obj);
            let f = ds.records[0].features;
            let c = opt.predict(&f, "GTX1650m-Turing");
            assert!(TB_SIZES.contains(&c.tb_size));
            assert!(MAXRREGCOUNT.contains(&c.maxrregcount));
        }
    }
}
