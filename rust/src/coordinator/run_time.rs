//! Run-time optimization mode (paper §5.3, Fig. 5b):
//! 1. compute sparsity features (timed -> f_latency);
//! 2. predict the optimal sparse format for the objective;
//! 3. estimate the optimization overhead (f + c latency);
//! 4. convert only if predicted benefit over the remaining iterations
//!    exceeds the overhead.

use super::overhead::{OverheadEstimate, OverheadModel};
use crate::dataset::labels::{self, Example, Target};
use crate::dataset::Dataset;
use crate::features::{self, Features};
use crate::gpusim::Objective;
use crate::ml::tree::DecisionTreeClassifier;
use crate::ml::{Classifier, Regressor};
use crate::sparse::Coo;
use crate::sparse::Format;

/// Outcome of the run-time decision for one input matrix.
#[derive(Debug, Clone)]
pub struct Decision {
    pub features: Features,
    pub predicted_format: Format,
    /// Predicted per-iteration objective value in the default (CSR) format.
    pub est_default: f64,
    /// Predicted per-iteration objective value in the predicted format.
    pub est_best: f64,
    pub overhead: OverheadEstimate,
    /// Measured f_latency of this call (step 1).
    pub f_latency_s: f64,
    /// Whether conversion is worth it for `iterations` products.
    pub convert: bool,
}

/// Run-time format router.
pub struct RunTimeOptimizer {
    pub objective: Objective,
    /// Architecture indicator of the deployment device (9th feature).
    pub deploy_arch_feature: f64,
    format_model: DecisionTreeClassifier,
    /// Per-format regression of the objective value (drives the benefit
    /// estimate of step 4). Full-depth CART regressors: the paper's
    /// Fig. 11 regression winners are tree models with R^2 > 0.99, i.e.
    /// near-exact recall of the training sweep.
    value_models: Vec<crate::ml::tree::DecisionTreeRegressor>,
    overhead: OverheadModel,
}

impl RunTimeOptimizer {
    pub fn train(ds: &Dataset, objective: Objective, overhead: OverheadModel) -> Self {
        let ex = labels::examples(ds, objective);
        Self::train_on_examples(ds, &ex, objective, overhead)
    }

    pub fn train_on_examples(
        ds: &Dataset,
        ex: &[Example],
        objective: Objective,
        overhead: OverheadModel,
    ) -> Self {
        let (x, y) = labels::to_xy(ex, Target::Format);
        let mut format_model = DecisionTreeClassifier::default();
        format_model.fit(&x, &y);

        // value models: per format, regress the objective at optimal
        // compile parameters (what the router would actually run)
        let mut value_models = Vec::new();
        for f in Format::ALL {
            let mut xs: Vec<Vec<f64>> = Vec::new();
            let mut ys: Vec<f64> = Vec::new();
            for matrix in ds.matrices() {
                for arch in ds.archs() {
                    let slice = ds.slice(&matrix, &arch);
                    let best = slice
                        .iter()
                        .filter(|r| r.config.format == f)
                        .map(|r| objective.value(&r.m))
                        .fold(None, |acc: Option<f64>, v| {
                            Some(match acc {
                                None => v,
                                Some(a) => {
                                    if objective.better(v, a) {
                                        v
                                    } else {
                                        a
                                    }
                                }
                            })
                        });
                    if let (Some(v), Some(r)) = (best, slice.first()) {
                        let mut fv = r.features.to_scaled_vec();
                        fv.push(labels::arch_feature(&arch));
                        xs.push(fv);
                        // regress in log space: objectives span decades
                        ys.push(v.max(1e-12).ln());
                    }
                }
            }
            let mut m = crate::ml::tree::DecisionTreeRegressor::default();
            m.fit(&xs, &ys);
            value_models.push(m);
        }
        RunTimeOptimizer {
            objective,
            deploy_arch_feature: 0.0,
            format_model,
            value_models,
            overhead,
        }
    }

    /// Deploy on a specific device profile (Fig. 12's cross-GPU setting).
    pub fn for_arch(mut self, arch: &str) -> Self {
        self.deploy_arch_feature = labels::arch_feature(arch);
        self
    }

    /// Predicted objective value for a format (log-space model).
    pub fn predict_value(&self, f: &Features, format: Format) -> f64 {
        let mut x = f.to_scaled_vec();
        x.push(self.deploy_arch_feature);
        self.value_models[format.class_id()].predict_one(&x).exp()
    }

    /// The full §5.3 pipeline for one COO input.
    ///
    /// `iterations` is the caller's expected number of SpMV products with
    /// this matrix (iterative solvers run thousands; one-shot callers
    /// pass 1 and will typically skip conversion).
    pub fn decide(&self, coo: &Coo, iterations: u64) -> Decision {
        // step 1: features (timed)
        let (feats, f_dur) = features::extract_timed(coo);
        self.decide_with_features(feats, f_dur, iterations)
    }

    /// Steps 2–4 of §5.3 when the features are already at hand — the
    /// serving pool's re-decision path on a router hot-swap: features
    /// were measured once at registration, so step 1 costs nothing and
    /// callers pass the original `f_latency` (or zero).
    pub fn decide_with_features(
        &self,
        feats: Features,
        f_latency: std::time::Duration,
        iterations: u64,
    ) -> Decision {
        let mut x = feats.to_scaled_vec();
        x.push(self.deploy_arch_feature);

        // step 2: predict the optimal format
        let predicted_format = Format::from_class_id(self.format_model.predict_one(&x))
            .unwrap_or(Format::Csr);

        // step 3: estimate overhead
        let overhead = self.overhead.predict(feats.n, feats.nnz);

        // step 4: benefit vs overhead (benefit counted on latency-like
        // objectives; for maximize objectives the benefit is expressed as
        // saved latency-equivalent via relative improvement)
        let est_default = self.predict_value(&feats, Format::Csr);
        let est_best = self.predict_value(&feats, predicted_format);
        let gain_per_iter = match self.objective {
            Objective::Latency | Objective::Energy => est_default - est_best,
            // power/efficiency: relative improvement credited against the
            // default latency estimate (the paper's benefit proxy)
            Objective::AvgPower | Objective::EnergyEff => {
                let rel = if self.objective.minimize() {
                    (est_default - est_best) / est_default.max(1e-12)
                } else {
                    (est_best - est_default) / est_default.max(1e-12)
                };
                rel * est_default
            }
        };
        let convert = predicted_format != Format::Csr
            && gain_per_iter > 0.0
            && gain_per_iter * iterations as f64 > overhead.total();

        Decision {
            features: feats,
            predicted_format,
            est_default,
            est_best,
            overhead,
            f_latency_s: f_latency.as_secs_f64(),
            convert,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::overhead::OverheadSample;
    use crate::dataset::{build, BuildOptions};
    use crate::gen;

    fn toy_overhead() -> OverheadModel {
        let samples: Vec<OverheadSample> = (1..12)
            .map(|k| OverheadSample {
                n: k as f64 * 1000.0,
                nnz: k as f64 * 20_000.0,
                f_latency_s: k as f64 * 1e-3,
                c_latency_s: k as f64 * 2e-3,
            })
            .collect();
        OverheadModel::train(&samples)
    }

    fn trained(obj: Objective) -> (RunTimeOptimizer, Dataset) {
        let ds = build(&BuildOptions {
            only: Some(
                ["rim", "eu-2005", "crankseg_1", "parabolic_fem", "wiki-talk-temporal"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect(),
            ),
            both_archs: false,
            ..Default::default()
        });
        (RunTimeOptimizer::train(&ds, obj, toy_overhead()), ds)
    }

    #[test]
    fn one_shot_never_converts_when_gain_small() {
        let (opt, _) = trained(Objective::Latency);
        let coo = gen::by_name("rim").unwrap().generate(1);
        let d1 = opt.decide(&coo, 1);
        // overhead is milliseconds; a single microsecond-scale product
        // cannot amortize it
        assert!(!d1.convert, "{d1:?}");
    }

    #[test]
    fn many_iterations_enable_conversion_when_gain_positive() {
        // the decision rule: convert iff predicted_format != CSR AND the
        // value models predict positive gain AND iterations amortize the
        // overhead. Find a training matrix with positive predicted gain
        // and check both sides of the iteration threshold.
        let (opt, _) = trained(Objective::EnergyEff);
        let mut checked = 0;
        for name in ["rim", "eu-2005", "crankseg_1", "parabolic_fem", "wiki-talk-temporal"] {
            let coo = gen::by_name(name).unwrap().generate(1);
            let d_many = opt.decide(&coo, u64::MAX / 2);
            if d_many.predicted_format != Format::Csr
                && opt.objective.better(d_many.est_best, d_many.est_default)
            {
                assert!(d_many.convert, "{name}: huge iteration counts must amortize: {d_many:?}");
                checked += 1;
            }
        }
        assert!(checked > 0, "corpus should contain at least one positive-gain case");
    }

    #[test]
    fn decision_is_internally_consistent() {
        let (opt, _) = trained(Objective::Latency);
        let coo = gen::by_name("eu-2005").unwrap().generate(1);
        let d = opt.decide(&coo, 1000);
        assert!(d.f_latency_s > 0.0);
        assert!(d.overhead.total() >= 0.0);
        if d.convert {
            assert_ne!(d.predicted_format, Format::Csr);
        }
        assert!(d.est_default > 0.0 && d.est_best > 0.0);
    }

    #[test]
    fn predicted_format_matches_training_label_for_seen_matrix() {
        let (opt, ds) = trained(Objective::EnergyEff);
        let ex = labels::examples(&ds, Objective::EnergyEff);
        for e in &ex {
            let entry = gen::by_name(&e.matrix).unwrap();
            let coo = entry.generate(1);
            let d = opt.decide(&coo, 1);
            assert_eq!(
                d.predicted_format.class_id(),
                e.format_class,
                "{}: tree should memorize training labels",
                e.matrix
            );
        }
    }
}
