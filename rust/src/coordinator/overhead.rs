//! Run-time overhead estimation (paper §5.3 step 3 and §7.5, Fig. 6):
//! regression models predicting `f_latency` (feature extraction) and
//! `c_latency` (format conversion) from cheap matrix statistics (n, nnz),
//! trained on measured wall times of this machine's actual extraction /
//! conversion code.

use crate::features;
use crate::gen::{corpus, CorpusEntry};
use crate::ml::linear::BayesianRidge;
use crate::ml::Regressor;
use crate::sparse::convert::{self, ConvertParams};
use crate::sparse::Format;
use std::time::Instant;

/// Measured overheads of one matrix (the ground truth of Table 7).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverheadSample {
    pub n: f64,
    pub nnz: f64,
    pub f_latency_s: f64,
    pub c_latency_s: f64,
}

/// Measure actual extraction + conversion wall time for one matrix.
/// Conversion is measured into `target` (the run-time mode's predicted
/// format); COO -> CSR normalization is counted as part of conversion,
/// as in the paper (SuiteSparse ships COO, §7.5).
pub fn measure_overhead(entry: &CorpusEntry, scale: usize, target: Format) -> OverheadSample {
    let coo = entry.generate(scale);
    // best-of-3: at CI scale single runs are allocator-noise dominated
    let mut f_latency_s = f64::INFINITY;
    let mut c_latency_s = f64::INFINITY;
    let mut feats = None;
    for _ in 0..3 {
        let t0 = Instant::now();
        let f = features::extract_coo(&coo);
        f_latency_s = f_latency_s.min(t0.elapsed().as_secs_f64());
        feats = Some(f);

        let t1 = Instant::now();
        let csr = convert::coo_to_csr(&coo);
        let converted = convert::convert(&csr, target, ConvertParams::default());
        c_latency_s = c_latency_s.min(t1.elapsed().as_secs_f64());
        std::hint::black_box(&converted);
    }
    let f = feats.unwrap();
    OverheadSample { n: f.n, nnz: f.nnz, f_latency_s, c_latency_s }
}

/// The o_latency + p_latency constant of §7.5 (~20 ms on the paper's
/// CPU): model inference + overhead prediction are O(tree depth) here,
/// measured per call by [`OverheadModel::predict_timed`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverheadEstimate {
    pub f_latency_s: f64,
    pub c_latency_s: f64,
}

impl OverheadEstimate {
    pub fn total(&self) -> f64 {
        self.f_latency_s + self.c_latency_s
    }
}

/// Regression models for f/c latency (Bayesian ridge on [n, nnz, n+nnz]).
/// `Clone` so long-lived holders (the online retraining loop re-fits a
/// fresh `RunTimeOptimizer` per round) can hand out copies.
#[derive(Clone)]
pub struct OverheadModel {
    f_model: BayesianRidge,
    c_model: BayesianRidge,
}

fn overhead_features(n: f64, nnz: f64) -> Vec<f64> {
    // log-space power-law fit: latency ~ nnz^a * n^b. Multiplicative
    // residuals keep small matrices (microsecond scale, allocator noise)
    // from being swamped by the large ones.
    vec![n.max(1.0).ln(), nnz.max(1.0).ln()]
}

impl OverheadModel {
    /// Train from measured samples (log-space targets).
    pub fn train(samples: &[OverheadSample]) -> Self {
        let x: Vec<Vec<f64>> =
            samples.iter().map(|s| overhead_features(s.n, s.nnz)).collect();
        let yf: Vec<f64> = samples.iter().map(|s| s.f_latency_s.max(1e-9).ln()).collect();
        let yc: Vec<f64> = samples.iter().map(|s| s.c_latency_s.max(1e-9).ln()).collect();
        let mut f_model = BayesianRidge::default();
        let mut c_model = BayesianRidge::default();
        f_model.fit(&x, &yf);
        c_model.fit(&x, &yc);
        OverheadModel { f_model, c_model }
    }

    /// Train by measuring the whole corpus (leave-one-out callers can
    /// filter `skip`).
    pub fn train_on_corpus(scale: usize, skip: Option<&str>) -> Self {
        let samples: Vec<OverheadSample> = corpus()
            .iter()
            .filter(|e| skip.is_none_or(|s| s != e.name))
            .map(|e| measure_overhead(e, scale, Format::Ell))
            .collect();
        Self::train(&samples)
    }

    pub fn predict(&self, n: f64, nnz: f64) -> OverheadEstimate {
        let x = overhead_features(n, nnz);
        OverheadEstimate {
            f_latency_s: self.f_model.predict_one(&x).exp(),
            c_latency_s: self.c_model.predict_one(&x).exp(),
        }
    }

    /// Predict and report the prediction's own wall time (the paper's
    /// o_latency — constant and tiny).
    pub fn predict_timed(&self, n: f64, nnz: f64) -> (OverheadEstimate, f64) {
        let t0 = Instant::now();
        let e = self.predict(n, nnz);
        (e, t0.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;

    #[test]
    fn measured_overhead_scales_with_nnz() {
        let small = measure_overhead(&gen::by_name("rim").unwrap(), 1, Format::Ell);
        let large = measure_overhead(&gen::by_name("eu-2005").unwrap(), 1, Format::Ell);
        assert!(large.nnz > 5.0 * small.nnz);
        // wall time is noisy at CI scale; require a weak ordering only
        assert!(large.f_latency_s + large.c_latency_s > 0.0);
        assert!(small.f_latency_s + small.c_latency_s > 0.0);
    }

    #[test]
    fn model_predicts_monotone_in_nnz() {
        // synthetic perfectly-linear samples: the model must recover them
        let samples: Vec<OverheadSample> = (1..20)
            .map(|k| {
                let n = (k * 1000) as f64;
                let nnz = (k * 20_000) as f64;
                OverheadSample {
                    n,
                    nnz,
                    f_latency_s: 1e-8 * nnz + 2e-8 * n,
                    c_latency_s: 3e-8 * nnz,
                }
            })
            .collect();
        let m = OverheadModel::train(&samples);
        let small = m.predict(2000.0, 40_000.0);
        let big = m.predict(18_000.0, 360_000.0);
        assert!(big.total() > 5.0 * small.total(), "{small:?} vs {big:?}");
        // relative accuracy on a held-out point
        let want = 1e-8 * 200_000.0 + 2e-8 * 10_000.0;
        let got = m.predict(10_000.0, 200_000.0).f_latency_s;
        assert!((got - want).abs() / want < 0.1, "want {want} got {got}");
    }

    #[test]
    fn predict_timed_returns_fast_o_latency() {
        let samples: Vec<OverheadSample> = (1..10)
            .map(|k| OverheadSample {
                n: k as f64 * 100.0,
                nnz: k as f64 * 1000.0,
                f_latency_s: k as f64 * 1e-5,
                c_latency_s: k as f64 * 2e-5,
            })
            .collect();
        let m = OverheadModel::train(&samples);
        let (_, o_latency) = m.predict_timed(500.0, 5000.0);
        assert!(o_latency < 0.02, "o_latency should be ~constant ms-scale, got {o_latency}");
    }
}
