//! SpMV serving loop — the deployable face of the run-time mode.
//!
//! A dedicated worker thread owns the PJRT [`Engine`] (executables are
//! not shared across threads); clients submit requests over an mpsc
//! channel and receive results on per-request reply channels. The worker
//! routes each request through the trained [`RunTimeOptimizer`], converts
//! the matrix when the overhead model approves (caching the converted
//! form for subsequent products), and dispatches the matching AOT
//! executable.
//!
//! (tokio is not available in the offline build environment — see
//! Cargo.toml; std threads + channels implement the same request loop.)

use super::run_time::RunTimeOptimizer;
use crate::runtime::Engine;
use crate::sparse::convert::{self, AnyFormat, ConvertParams};
use crate::sparse::{Coo, Format, SpMv};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How products are executed. The PJRT client is not `Send`, so the
/// worker thread constructs its own [`Engine`] from this spec.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    /// AOT-compiled kernels through PJRT (the production path).
    Pjrt(std::path::PathBuf),
    /// Native Rust SpMV (testing / environments without artifacts).
    Native,
}

enum Backend {
    Pjrt(Box<Engine>),
    Native,
}

impl BackendSpec {
    fn build(&self) -> Result<Backend> {
        match self {
            BackendSpec::Pjrt(dir) => Ok(Backend::Pjrt(Box::new(Engine::new(dir)?))),
            BackendSpec::Native => Ok(Backend::Native),
        }
    }
}

/// One serving request: a matrix (by registered id) and an input vector.
pub struct Request {
    pub matrix_id: u64,
    pub x: Vec<f32>,
    pub reply: Sender<Result<Response>>,
}

/// Result of one product.
#[derive(Debug, Clone)]
pub struct Response {
    pub y: Vec<f32>,
    pub format_used: Format,
    pub converted: bool,
    pub service_time: Duration,
}

/// Registration message: provide a matrix once, serve many products.
enum Msg {
    Register { id: u64, coo: Coo, iterations_hint: u64, ack: Sender<Result<Format>> },
    Product(Request),
    Stats(Sender<ServiceStats>),
    Shutdown,
}

/// Aggregate serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub conversions: u64,
    pub total_service: Duration,
    pub max_service: Duration,
}

struct Served {
    matrix: AnyFormat,
    format: Format,
    converted: bool,
    /// Matrix-side kernel literals, marshalled once at registration
    /// (EXPERIMENTS.md §Perf iteration 2).
    prepared: Option<crate::runtime::pjrt::PreparedSpmv>,
}

/// Handle to a running service.
pub struct Service {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl Service {
    /// Start the worker thread. `router` decides formats; `backend`
    /// executes products (constructed inside the worker — PJRT handles
    /// are not `Send`).
    pub fn start(router: RunTimeOptimizer, backend: BackendSpec, convert: ConvertParams) -> Service {
        let (tx, rx) = channel::<Msg>();
        let worker = std::thread::spawn(move || {
            let backend = match backend.build() {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("service backend init failed, falling back to native: {e:#}");
                    Backend::Native
                }
            };
            worker_loop(rx, router, backend, convert)
        });
        Service { tx, worker: Some(worker) }
    }

    /// Register a matrix; returns the format the router chose for it.
    pub fn register(&self, id: u64, coo: Coo, iterations_hint: u64) -> Result<Format> {
        let (ack, rx) = channel();
        self.tx
            .send(Msg::Register { id, coo, iterations_hint, ack })
            .map_err(|_| anyhow!("service stopped"))?;
        rx.recv().map_err(|_| anyhow!("service dropped request"))?
    }

    /// Submit a product request; blocks for the response.
    pub fn product(&self, matrix_id: u64, x: Vec<f32>) -> Result<Response> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Product(Request { matrix_id, x, reply }))
            .map_err(|_| anyhow!("service stopped"))?;
        rx.recv().map_err(|_| anyhow!("service dropped request"))?
    }

    /// Submit without waiting; the receiver yields the response later
    /// (lets callers pipeline many requests).
    pub fn product_async(&self, matrix_id: u64, x: Vec<f32>) -> Result<Receiver<Result<Response>>> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Product(Request { matrix_id, x, reply }))
            .map_err(|_| anyhow!("service stopped"))?;
        Ok(rx)
    }

    pub fn stats(&self) -> Result<ServiceStats> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Stats(tx)).map_err(|_| anyhow!("service stopped"))?;
        rx.recv().map_err(|_| anyhow!("service dropped request"))
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    rx: Receiver<Msg>,
    router: RunTimeOptimizer,
    mut backend: Backend,
    params: ConvertParams,
) {
    let mut served: HashMap<u64, Served> = HashMap::new();
    let mut stats = ServiceStats::default();
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Register { id, coo, iterations_hint, ack } => {
                let result = (|| -> Result<Format> {
                    let decision = router.decide(&coo, iterations_hint);
                    let csr = convert::coo_to_csr(&coo);
                    let (fmt, converted) = if decision.convert {
                        (decision.predicted_format, true)
                    } else {
                        (Format::Csr, false)
                    };
                    let matrix = convert::convert(&csr, fmt, params);
                    if converted {
                        stats.conversions += 1;
                    }
                    let prepared = match &mut backend {
                        Backend::Pjrt(engine) => Some(engine.prepare(&matrix, None)?),
                        Backend::Native => None,
                    };
                    served.insert(id, Served { matrix, format: fmt, converted, prepared });
                    Ok(fmt)
                })();
                let _ = ack.send(result);
            }
            Msg::Product(req) => {
                let t0 = Instant::now();
                let result = (|| -> Result<Response> {
                    let s = served
                        .get(&req.matrix_id)
                        .ok_or_else(|| anyhow!("unknown matrix id {}", req.matrix_id))?;
                    let y = match &mut backend {
                        Backend::Pjrt(engine) => match &s.prepared {
                            Some(prep) => engine.run_prepared(prep, &req.x)?,
                            None => engine.spmv(&s.matrix, &req.x, None)?,
                        },
                        Backend::Native => {
                            let m = s.matrix.as_spmv();
                            if req.x.len() != m.n_cols() {
                                return Err(anyhow!(
                                    "x length {} != n_cols {}",
                                    req.x.len(),
                                    m.n_cols()
                                ));
                            }
                            m.spmv_alloc(&req.x)
                        }
                    };
                    let service_time = t0.elapsed();
                    Ok(Response { y, format_used: s.format, converted: s.converted, service_time })
                })();
                if let Ok(r) = &result {
                    stats.requests += 1;
                    stats.total_service += r.service_time;
                    stats.max_service = stats.max_service.max(r.service_time);
                }
                let _ = req.reply.send(result);
            }
            Msg::Stats(tx) => {
                let _ = tx.send(stats.clone());
            }
            Msg::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::overhead::{OverheadModel, OverheadSample};
    use crate::dataset::{build, BuildOptions};
    use crate::gen;
    use crate::gpusim::Objective;

    fn test_service() -> Service {
        let ds = build(&BuildOptions {
            only: Some(vec!["rim".into(), "eu-2005".into()]),
            both_archs: false,
            ..Default::default()
        });
        let samples: Vec<OverheadSample> = (1..10)
            .map(|k| OverheadSample {
                n: k as f64 * 1000.0,
                nnz: k as f64 * 10_000.0,
                f_latency_s: k as f64 * 1e-3,
                c_latency_s: k as f64 * 1e-3,
            })
            .collect();
        let router = RunTimeOptimizer::train(&ds, Objective::Latency, OverheadModel::train(&samples));
        Service::start(router, BackendSpec::Native, ConvertParams::default())
    }

    #[test]
    fn serves_correct_products() {
        let svc = test_service();
        let entry = gen::by_name("rim").unwrap();
        let coo = entry.generate(1);
        let csr = convert::coo_to_csr(&coo);
        svc.register(1, coo, 1).unwrap();
        let x: Vec<f32> = (0..csr.n_cols).map(|i| ((i % 7) as f32) - 3.0).collect();
        let want = csr.spmv_alloc(&x);
        let resp = svc.product(1, x).unwrap();
        assert_eq!(resp.y.len(), want.len());
        for (a, b) in resp.y.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn unknown_matrix_is_error() {
        let svc = test_service();
        let err = svc.product(99, vec![1.0]).unwrap_err();
        assert!(format!("{err}").contains("unknown matrix"));
    }

    #[test]
    fn wrong_x_length_is_error_not_panic() {
        let svc = test_service();
        let coo = gen::by_name("rim").unwrap().generate(1);
        svc.register(7, coo, 1).unwrap();
        assert!(svc.product(7, vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn stats_accumulate() {
        let svc = test_service();
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        svc.register(1, coo, 1).unwrap();
        for _ in 0..5 {
            svc.product(1, vec![1.0; n]).unwrap();
        }
        let s = svc.stats().unwrap();
        assert_eq!(s.requests, 5);
        assert!(s.total_service >= s.max_service);
    }

    #[test]
    fn pipelined_async_requests() {
        let svc = test_service();
        let coo = gen::by_name("eu-2005").unwrap().generate(1);
        let n = coo.n_cols;
        svc.register(2, coo, 100).unwrap();
        let rxs: Vec<_> =
            (0..8).map(|_| svc.product_async(2, vec![0.5; n]).unwrap()).collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }
}
