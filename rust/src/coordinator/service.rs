//! SpMV serving loop — compatibility shim over [`crate::serve::Pool`].
//!
//! The original implementation here was a single worker thread behind
//! one mpsc channel. The serving engine now lives in [`crate::serve`]
//! (sharded workers, request coalescing into SpMM dispatches, a
//! bounded conversion cache, and latency/energy telemetry); this module
//! keeps the old single-worker `Service` API as a thin wrapper — one
//! shard, no admission window, `max_batch = 1`, so requests execute
//! serially exactly as before and results are unchanged. One semantic
//! difference from the legacy loop: `service_time` (and the stats built
//! from it) now measures end-to-end from submission — queue wait
//! included — where the old worker timed execution only, so pipelined
//! callers will see larger, more honest latencies.

use super::run_time::RunTimeOptimizer;
use crate::serve::{Pool, PoolConfig};
use crate::sparse::convert::ConvertParams;
use crate::sparse::{Coo, Format};
use anyhow::Result;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

pub use crate::serve::{BackendSpec, Response};

/// Aggregate serving metrics (legacy shape; [`crate::serve::PoolStats`]
/// is the richer replacement).
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub conversions: u64,
    pub total_service: Duration,
    pub max_service: Duration,
}

/// Handle to a running single-worker service.
pub struct Service {
    pool: Pool,
}

impl Service {
    /// Start a single-shard pool. `router` decides formats; `backend`
    /// executes products (constructed inside the worker — PJRT handles
    /// are not `Send`).
    pub fn start(router: RunTimeOptimizer, backend: BackendSpec, convert: ConvertParams) -> Service {
        let cfg = PoolConfig {
            workers: 1,
            batch_window: Duration::ZERO,
            // legacy behavior: strictly serial dispatch, no coalescing,
            // and an effectively unbounded conversion cache (the old
            // loop never evicted) — large working sets opt into the
            // bounded LRU by using serve::Pool directly.
            max_batch: 1,
            cache_capacity: usize::MAX,
            convert,
            ..PoolConfig::default()
        };
        Service { pool: Pool::start(Arc::new(router), backend, cfg) }
    }

    /// Register a matrix; returns the format the router chose for it.
    pub fn register(&self, id: u64, coo: Coo, iterations_hint: u64) -> Result<Format> {
        self.pool.register(id, coo, iterations_hint)
    }

    /// Submit a product request; blocks for the response.
    pub fn product(&self, matrix_id: u64, x: Vec<f32>) -> Result<Response> {
        self.pool.product(matrix_id, x)
    }

    /// Submit without waiting; the receiver yields the response later
    /// (lets callers pipeline many requests — which is also what lets
    /// the worker coalesce them into one batched dispatch).
    pub fn product_async(&self, matrix_id: u64, x: Vec<f32>) -> Result<Receiver<Result<Response>>> {
        self.pool.product_async(matrix_id, x)
    }

    pub fn stats(&self) -> Result<ServiceStats> {
        let s = self.pool.stats()?;
        Ok(ServiceStats {
            requests: s.requests,
            conversions: s.conversions,
            total_service: s.total_service(),
            max_service: s.max_service(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::gpusim::Objective;
    use crate::sparse::convert;
    use crate::sparse::SpMv;
    use crate::testutil::toy_router;

    fn test_service() -> Service {
        let router = toy_router(&["rim", "eu-2005"], Objective::Latency);
        Service::start(router, BackendSpec::Native, ConvertParams::default())
    }

    #[test]
    fn serves_correct_products() {
        let svc = test_service();
        let entry = gen::by_name("rim").unwrap();
        let coo = entry.generate(1);
        let csr = convert::coo_to_csr(&coo);
        svc.register(1, coo, 1).unwrap();
        let x: Vec<f32> = (0..csr.n_cols).map(|i| ((i % 7) as f32) - 3.0).collect();
        let want = csr.spmv_alloc(&x);
        let resp = svc.product(1, x).unwrap();
        assert_eq!(resp.y.len(), want.len());
        for (a, b) in resp.y.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn unknown_matrix_is_error() {
        let svc = test_service();
        let err = svc.product(99, vec![1.0]).unwrap_err();
        assert!(format!("{err}").contains("unknown matrix"));
    }

    #[test]
    fn wrong_x_length_is_error_not_panic() {
        let svc = test_service();
        let coo = gen::by_name("rim").unwrap().generate(1);
        svc.register(7, coo, 1).unwrap();
        assert!(svc.product(7, vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn stats_accumulate() {
        let svc = test_service();
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        svc.register(1, coo, 1).unwrap();
        for _ in 0..5 {
            svc.product(1, vec![1.0; n]).unwrap();
        }
        let s = svc.stats().unwrap();
        assert_eq!(s.requests, 5);
        assert!(s.total_service >= s.max_service);
    }

    #[test]
    fn pipelined_async_requests() {
        let svc = test_service();
        let coo = gen::by_name("eu-2005").unwrap().generate(1);
        let n = coo.n_cols;
        svc.register(2, coo, 100).unwrap();
        let rxs: Vec<_> =
            (0..8).map(|_| svc.product_async(2, vec![0.5; n]).unwrap()).collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }
}
