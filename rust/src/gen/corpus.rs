//! The 30-matrix benchmark corpus — the SuiteSparse stand-in.
//!
//! One synthetic matrix per matrix in the paper's Table 7, same names,
//! same ascending-nnz order, matched structure class (DESIGN.md §1), with
//! sizes scaled down ~64x so the full 15k-record sweep runs in CI. The
//! `scale` parameter (1 = default CI scale) lets `--full-scale` runs
//! regenerate paper-sized matrices for the Table 7 overhead experiment.

use super::patterns;
use super::rng::Rng;
use crate::sparse::{convert::coo_to_csr, Coo, Csr};

/// Structure class of a corpus matrix (drives generator choice).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Class {
    Banded { half_band: usize, avg: f64 },
    Diagonals { k: usize, spread: usize, density: f64 },
    Uniform { avg: f64 },
    PowerLaw { alpha: f64, avg: f64, max_frac: f64 },
    Blocks { bh: usize, bw: usize, per_brow: f64, band: usize, fill: f64 },
    Bimodal { light: f64, heavy: f64, frac: f64 },
    Clustered { avg: f64, cluster: usize },
}

/// A named corpus entry.
#[derive(Debug, Clone)]
pub struct CorpusEntry {
    /// SuiteSparse name this entry mirrors (Table 7).
    pub name: &'static str,
    /// Base dimension at scale 1.
    pub n: usize,
    pub class: Class,
    pub seed: u64,
}

impl CorpusEntry {
    /// Generate the COO matrix at the given scale multiplier.
    pub fn generate(&self, scale: usize) -> Coo {
        let n = self.n * scale.max(1);
        let mut rng = Rng::new(self.seed);
        match self.class {
            Class::Banded { half_band, avg } => {
                patterns::banded(&mut rng, n, half_band * scale.max(1), avg)
            }
            Class::Diagonals { k, spread, density } => {
                let mut offsets: Vec<i64> = vec![0];
                for i in 1..=(k / 2) {
                    let o = (i * spread * scale.max(1)) as i64;
                    offsets.push(o);
                    offsets.push(-o);
                }
                patterns::diagonals(&mut rng, n, &offsets, density)
            }
            Class::Uniform { avg } => patterns::uniform(&mut rng, n, n, avg),
            Class::PowerLaw { alpha, avg, max_frac } => {
                let max_row = ((n as f64 * max_frac) as usize).max(8);
                patterns::powerlaw(&mut rng, n, n, alpha, avg, max_row)
            }
            Class::Blocks { bh, bw, per_brow, band, fill } => {
                patterns::blocks(&mut rng, n, bh, bw, per_brow, band, fill)
            }
            Class::Bimodal { light, heavy, frac } => {
                patterns::bimodal(&mut rng, n, n, light, heavy, frac)
            }
            Class::Clustered { avg, cluster } => {
                patterns::clustered(&mut rng, n, n, avg, cluster)
            }
        }
    }

    /// Generate directly as CSR (the framework's working format).
    pub fn generate_csr(&self, scale: usize) -> Csr {
        coo_to_csr(&self.generate(scale))
    }
}

/// The 30 corpus matrices, ascending target nnz (paper Table 7 order).
pub fn corpus() -> Vec<CorpusEntry> {
    use Class::*;
    let e = |name, n, class, seed| CorpusEntry { name, n, class, seed };
    vec![
        e("shar_te2-b3", 3200, Uniform { avg: 4.0 }, 101),
        e("rim", 1400, Banded { half_band: 24, avg: 11.0 }, 102),
        e("bcsstk32", 1200, Blocks { bh: 4, bw: 4, per_brow: 3.0, band: 10, fill: 0.9 }, 103),
        e("il2010", 3600, PowerLaw { alpha: 1.6, avg: 5.0, max_frac: 0.02 }, 104),
        e("viscorocks", 1300, Blocks { bh: 4, bw: 4, per_brow: 3.5, band: 8, fill: 0.85 }, 105),
        e("cant", 1600, Banded { half_band: 32, avg: 20.0 }, 106),
        e("parabolic_fem", 5200, Diagonals { k: 7, spread: 18, density: 0.98 }, 107),
        e("pkustk04", 1500, Blocks { bh: 8, bw: 8, per_brow: 2.8, band: 6, fill: 0.92 }, 108),
        e("apache2", 5600, Diagonals { k: 7, spread: 30, density: 0.99 }, 109),
        e("consph", 1700, Blocks { bh: 3, bw: 3, per_brow: 6.0, band: 14, fill: 0.88 }, 110),
        e("wiki-talk-temporal", 8000, PowerLaw { alpha: 2.1, avg: 6.0, max_frac: 0.08 }, 111),
        e("amazon0601", 6400, PowerLaw { alpha: 1.5, avg: 8.0, max_frac: 0.01 }, 112),
        e("Chevron3", 4200, Banded { half_band: 40, avg: 12.5 }, 113),
        e("xenon2", 2500, Banded { half_band: 48, avg: 24.0 }, 114),
        e("x104", 1800, Blocks { bh: 8, bw: 8, per_brow: 5.5, band: 8, fill: 0.95 }, 115),
        e("crankseg_1", 1400, Blocks { bh: 8, bw: 8, per_brow: 9.0, band: 12, fill: 0.93 }, 116),
        e("Si87H76", 1500, Clustered { avg: 57.0, cluster: 48 }, 117),
        e("Hamrle3", 7200, Bimodal { light: 3.0, heavy: 30.0, frac: 0.12 }, 118),
        e("pwtk", 2600, Banded { half_band: 40, avg: 36.0 }, 119),
        e("Chevron4", 6000, Banded { half_band: 44, avg: 16.5 }, 120),
        e("Hardesty1", 5400, Bimodal { light: 8.0, heavy: 44.0, frac: 0.15 }, 121),
        e("rgg_n_2_20_s0", 7000, Uniform { avg: 15.0 }, 122),
        e("crankseg_2", 1600, Blocks { bh: 8, bw: 8, per_brow: 10.5, band: 12, fill: 0.94 }, 123),
        e("CurlCurl_3", 3800, Banded { half_band: 56, avg: 30.0 }, 124),
        e("human_gene2", 1200, Clustered { avg: 118.0, cluster: 64 }, 125),
        e("af_shell6", 3200, Blocks { bh: 5, bw: 5, per_brow: 7.0, band: 10, fill: 0.9 }, 126),
        e("atmosmodm", 9000, Diagonals { k: 7, spread: 42, density: 1.0 }, 127),
        e("kim2", 4400, Banded { half_band: 64, avg: 40.0 }, 128),
        e("test1", 5000, Uniform { avg: 41.0 }, 129),
        e("eu-2005", 6800, PowerLaw { alpha: 1.9, avg: 44.0, max_frac: 0.1 }, 130),
    ]
}

/// Look up a corpus entry by name.
pub fn by_name(name: &str) -> Option<CorpusEntry> {
    corpus().into_iter().find(|e| e.name == name)
}

/// The six matrices re-measured on the Pascal GPU in §7.6 / Fig. 12.
pub const GPU_SENSITIVITY_SET: [&str; 6] =
    ["amazon0601", "crankseg_2", "bcsstk32", "x104", "il2010", "Chevron3"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Storage;

    #[test]
    fn corpus_has_30_unique_names() {
        let c = corpus();
        assert_eq!(c.len(), 30);
        let names: std::collections::HashSet<_> = c.iter().map(|e| e.name).collect();
        assert_eq!(names.len(), 30);
    }

    #[test]
    fn nnz_roughly_ascending() {
        // Table 7 is sorted by nnz; allow local jitter but require a
        // strong global trend (rank correlation > 0.8).
        let c = corpus();
        let nnz: Vec<usize> = c.iter().map(|e| e.generate(1).nnz()).collect();
        let n = nnz.len();
        let mut concordant = 0i64;
        let mut total = 0i64;
        for i in 0..n {
            for j in i + 1..n {
                total += 1;
                if nnz[j] >= nnz[i] {
                    concordant += 1;
                }
            }
        }
        let tau = concordant as f64 / total as f64;
        assert!(tau > 0.8, "corpus should be roughly nnz-ascending, tau {tau}");
    }

    #[test]
    fn sensitivity_set_exists() {
        for name in GPU_SENSITIVITY_SET {
            assert!(by_name(name).is_some(), "{name} missing");
        }
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn generation_is_deterministic() {
        let e = by_name("consph").unwrap();
        assert_eq!(e.generate(1), e.generate(1));
    }

    #[test]
    fn scale_grows_matrix() {
        let e = by_name("rim").unwrap();
        let s1 = e.generate(1);
        let s2 = e.generate(2);
        assert_eq!(s2.n_rows, 2 * s1.n_rows);
        assert!(s2.nnz() > s1.nnz());
    }
}
