//! Synthetic sparsity-pattern generators.
//!
//! Each generator reproduces the row-length distribution and spatial
//! structure of one *class* of SuiteSparse matrix (DESIGN.md §1): which
//! sparse format wins on a matrix is governed by exactly these properties
//! (paper §5.5), so matched structure classes preserve the learning
//! problem. All generators emit sorted, duplicate-free COO.

use super::rng::Rng;
use crate::sparse::Coo;

/// Dedup + sort helper: generators may propose duplicates; SpMV semantics
/// would accumulate them, but SuiteSparse matrices are duplicate-free, so
/// we keep the last value per (row, col).
fn finalize(mut coo: Coo) -> Coo {
    coo.sort();
    let mut out = Coo::with_capacity(coo.n_rows, coo.n_cols, coo.len());
    let mut last: Option<(u32, u32)> = None;
    for i in 0..coo.len() {
        let key = (coo.rows[i], coo.cols[i]);
        if last == Some(key) {
            let n = out.len();
            out.vals[n - 1] = coo.vals[i];
        } else {
            out.push(coo.rows[i] as usize, coo.cols[i] as usize, coo.vals[i]);
            last = Some(key);
        }
    }
    out
}

/// Banded matrix: every row has ~`avg_nnz` entries within `half_band` of
/// the diagonal (FEM / finite-difference stencils: cant, pwtk, xenon2...).
pub fn banded(rng: &mut Rng, n: usize, half_band: usize, avg_nnz: f64) -> Coo {
    let mut coo = Coo::with_capacity(n, n, (n as f64 * avg_nnz) as usize);
    for r in 0..n {
        let k = rng.poisson(avg_nnz).max(1);
        let lo = r.saturating_sub(half_band);
        let hi = (r + half_band).min(n - 1);
        for _ in 0..k {
            let c = rng.range(lo, hi);
            coo.push(r, c, rng.val());
        }
        coo.push(r, r, rng.val()); // diagonal always present
    }
    finalize(coo)
}

/// Fixed diagonals (apache2 / atmosmodm-style stencils): entries exactly
/// on the given offsets, present with probability `density`.
pub fn diagonals(rng: &mut Rng, n: usize, offsets: &[i64], density: f64) -> Coo {
    let mut coo = Coo::with_capacity(n, n, n * offsets.len());
    for r in 0..n {
        for &o in offsets {
            let c = r as i64 + o;
            if c >= 0 && (c as usize) < n && rng.f64() < density {
                coo.push(r, c as usize, rng.val());
            }
        }
    }
    finalize(coo)
}

/// Uniform-random rows with Poisson row lengths (rgg / shar_te-style:
/// regular degree distribution, scattered columns).
pub fn uniform(rng: &mut Rng, n: usize, m: usize, avg_nnz: f64) -> Coo {
    let mut coo = Coo::with_capacity(n, m, (n as f64 * avg_nnz) as usize);
    for r in 0..n {
        let k = rng.poisson(avg_nnz);
        for _ in 0..k {
            coo.push(r, rng.below(m), rng.val());
        }
    }
    finalize(coo)
}

/// Power-law (Zipf) row lengths with preferential column attachment
/// (web/social graphs: eu-2005, wiki-talk, amazon0601). `alpha` controls
/// skew (larger = more skewed); `max_row` caps hub rows.
pub fn powerlaw(rng: &mut Rng, n: usize, m: usize, alpha: f64, avg_nnz: f64, max_row: usize) -> Coo {
    let mut coo = Coo::with_capacity(n, m, (n as f64 * avg_nnz) as usize);
    // calibrate: zipf(z, alpha) has some mean; scale draws to hit avg_nnz
    let probe: f64 = {
        let mut r2 = rng.clone();
        let s: usize = (0..512).map(|_| r2.zipf(max_row, alpha)).sum();
        s as f64 / 512.0
    };
    let scale = (avg_nnz / probe.max(1e-9)).max(0.05);
    for r in 0..n {
        let k = ((rng.zipf(max_row, alpha) as f64 * scale).round() as usize).clamp(1, max_row);
        for _ in 0..k {
            // preferential attachment: columns also zipf-distributed
            let c = (rng.zipf(m, 1.3) - 1).min(m - 1);
            coo.push(r, c, rng.val());
        }
    }
    finalize(coo)
}

/// Block-structured matrix (multi-DOF FEM: crankseg, pkustk, x104):
/// dense `bh x bw` blocks scattered near the diagonal.
pub fn blocks(
    rng: &mut Rng,
    n: usize,
    bh: usize,
    bw: usize,
    blocks_per_brow: f64,
    half_band_blocks: usize,
    block_fill: f64,
) -> Coo {
    let nb = n / bh;
    let nbc = n / bw;
    let mut coo = Coo::with_capacity(n, n, (nb as f64 * blocks_per_brow) as usize * bh * bw);
    for ib in 0..nb {
        let k = rng.poisson(blocks_per_brow).max(1);
        let lo = ib.saturating_sub(half_band_blocks).min(nbc - 1);
        let hi = (ib + half_band_blocks).min(nbc - 1);
        for _ in 0..k {
            let bc = rng.range(lo, hi);
            for i in 0..bh {
                for j in 0..bw {
                    if rng.f64() < block_fill {
                        let (r, c) = (ib * bh + i, bc * bw + j);
                        if r < n && c < n {
                            coo.push(r, c, rng.val());
                        }
                    }
                }
            }
        }
    }
    finalize(coo)
}

/// Bimodal rows (temporal / bipartite-ish: wiki-talk-temporal, Hamrle3):
/// a fraction `heavy_frac` of rows are `heavy_nnz` long, the rest short.
pub fn bimodal(
    rng: &mut Rng,
    n: usize,
    m: usize,
    light_nnz: f64,
    heavy_nnz: f64,
    heavy_frac: f64,
) -> Coo {
    let mut coo = Coo::with_capacity(n, m, (n as f64 * light_nnz) as usize);
    for r in 0..n {
        let lam = if rng.f64() < heavy_frac { heavy_nnz } else { light_nnz };
        let k = rng.poisson(lam);
        for _ in 0..k {
            coo.push(r, rng.below(m), rng.val());
        }
    }
    finalize(coo)
}

/// Dense-ish clustered rows (human_gene2 / Si87H76: high average degree,
/// column locality within clusters).
pub fn clustered(rng: &mut Rng, n: usize, m: usize, avg_nnz: f64, cluster: usize) -> Coo {
    let mut coo = Coo::with_capacity(n, m, (n as f64 * avg_nnz) as usize);
    for r in 0..n {
        let k = rng.poisson(avg_nnz).max(1);
        let center = (r / cluster) * cluster;
        for _ in 0..k {
            let c = if rng.f64() < 0.8 {
                (center + rng.below(cluster.min(m))).min(m - 1)
            } else {
                rng.below(m)
            };
            coo.push(r, c, rng.val());
        }
    }
    finalize(coo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Storage;

    #[test]
    fn banded_stays_in_band() {
        let mut rng = Rng::new(1);
        let a = banded(&mut rng, 200, 10, 5.0);
        for i in 0..a.len() {
            let (r, c) = (a.rows[i] as i64, a.cols[i] as i64);
            assert!((r - c).abs() <= 10, "entry ({r},{c}) outside band");
        }
        assert!(a.nnz() > 200); // at least diagonal
    }

    #[test]
    fn diagonals_exact_offsets() {
        let mut rng = Rng::new(2);
        let a = diagonals(&mut rng, 100, &[-10, 0, 10], 1.0);
        for i in 0..a.len() {
            let d = a.cols[i] as i64 - a.rows[i] as i64;
            assert!(d == -10 || d == 0 || d == 10);
        }
        // full density: every in-range offset present
        assert_eq!(a.len(), 100 + 90 + 90);
    }

    #[test]
    fn uniform_hits_avg() {
        let mut rng = Rng::new(3);
        let a = uniform(&mut rng, 2000, 2000, 8.0);
        let avg = a.len() as f64 / 2000.0;
        assert!((avg - 8.0).abs() < 1.0, "avg {avg}");
    }

    #[test]
    fn powerlaw_is_skewed() {
        let mut rng = Rng::new(4);
        let a = powerlaw(&mut rng, 2000, 2000, 2.0, 8.0, 400);
        let counts = a.row_counts();
        let max = *counts.iter().max().unwrap() as f64;
        let avg = a.len() as f64 / 2000.0;
        assert!(max > 6.0 * avg, "power-law should have hub rows: max {max} avg {avg}");
    }

    #[test]
    fn blocks_are_blocky() {
        let mut rng = Rng::new(5);
        let a = blocks(&mut rng, 256, 8, 8, 3.0, 4, 0.95);
        // high fill within occupied 8x8 blocks => BELL-friendly
        let csr = crate::sparse::convert::coo_to_csr(&a);
        let bell = crate::sparse::convert::csr_to_bell(&csr, 8, 8);
        // occupied blocks are dense, but Poisson slot counts mean ragged
        // kb padding; require clearly better fill than a scattered matrix
        let scattered = uniform(&mut Rng::new(5), 256, 256, a.len() as f64 / 256.0);
        let bell_u = crate::sparse::convert::csr_to_bell(
            &crate::sparse::convert::coo_to_csr(&scattered), 8, 8);
        assert!(
            bell.block_fill_ratio() > 3.0 * bell_u.block_fill_ratio(),
            "blocky fill {} should beat scattered fill {}",
            bell.block_fill_ratio(),
            bell_u.block_fill_ratio()
        );
    }

    #[test]
    fn bimodal_has_two_modes() {
        let mut rng = Rng::new(6);
        let a = bimodal(&mut rng, 3000, 3000, 2.0, 60.0, 0.1);
        let counts = a.row_counts();
        let heavy = counts.iter().filter(|&&c| c > 30).count();
        let light = counts.iter().filter(|&&c| c <= 8).count();
        assert!(heavy > 100, "heavy {heavy}");
        assert!(light > 1500, "light {light}");
    }

    #[test]
    fn generators_deterministic() {
        let a = uniform(&mut Rng::new(9), 100, 100, 4.0);
        let b = uniform(&mut Rng::new(9), 100, 100, 4.0);
        assert_eq!(a, b);
    }

    #[test]
    fn no_duplicates_after_finalize() {
        let mut rng = Rng::new(10);
        let a = clustered(&mut rng, 300, 300, 20.0, 16);
        let mut seen = std::collections::HashSet::new();
        for i in 0..a.len() {
            assert!(seen.insert((a.rows[i], a.cols[i])), "duplicate entry");
        }
    }
}
