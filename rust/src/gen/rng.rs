//! Deterministic RNG (xoshiro256**) — no external crates, fully
//! reproducible corpus generation from fixed seeds.

/// xoshiro256** PRNG. Seeded through splitmix64 so any u64 seed yields a
/// well-mixed state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [-1, 1) excluding exact 0 (sparse values must be
    /// structurally non-zero).
    #[inline]
    pub fn val(&mut self) -> f32 {
        let v = (self.f64() * 2.0 - 1.0) as f32;
        if v == 0.0 { 0.5 } else { v }
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi].
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Poisson(lambda) — inversion for small lambda, normal approx above.
    pub fn poisson(&mut self, lambda: f64) -> usize {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            return (lambda + lambda.sqrt() * self.normal()).round().max(0.0) as usize;
        }
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf-like integer in [1, n] with exponent `alpha` (rejection-free
    /// inverse-CDF approximation — adequate for workload generation).
    /// For an exact distribution (popularity benchmarks asserting on
    /// rank shares) use [`Zipf`].
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        let u = self.f64().max(1e-12);
        if (alpha - 1.0).abs() < 1e-9 {
            let z = (n as f64).ln();
            return ((u * z).exp() as usize).clamp(1, n);
        }
        let e = 1.0 - alpha;
        let z = ((n as f64).powf(e) - 1.0) / e;
        (((u * z * e + 1.0).powf(1.0 / e)) as usize).clamp(1, n)
    }
}

/// Exact Zipf(n, alpha) sampler: `P(rank) ∝ rank^-alpha` over ranks
/// `[1, n]`, sampled by binary-searching a precomputed normalized CDF
/// (O(n) build, O(log n) per draw). Unlike [`Rng::zipf`]'s continuous
/// approximation, rank shares match the theoretical distribution
/// exactly, so popularity sweeps can assert on them.
#[derive(Debug, Clone)]
pub struct Zipf {
    /// `cdf[r-1]` = P(rank <= r), with `cdf[n-1] == 1.0`.
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0, "Zipf needs at least one rank");
        let mut cdf: Vec<f64> = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 1..=n {
            acc += (r as f64).powf(-alpha);
            cdf.push(acc);
        }
        let norm = acc;
        for c in &mut cdf {
            *c /= norm;
        }
        cdf[n - 1] = 1.0;
        Zipf { cdf }
    }

    /// Number of ranks.
    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    /// Draw a rank in [1, n]. Deterministic given the `rng` state.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c <= u) + 1
    }

    /// Theoretical probability of `rank` (1-based).
    pub fn share(&self, rank: usize) -> f64 {
        assert!((1..=self.cdf.len()).contains(&rank));
        if rank == 1 {
            self.cdf[0]
        } else {
            self.cdf[rank - 1] - self.cdf[rank - 2]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(11);
        let lambda = 6.0;
        let n = 5000;
        let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < 0.3, "mean {mean}");
    }

    #[test]
    fn zipf_skews_small() {
        let mut r = Rng::new(13);
        let n = 10000;
        let small = (0..n).filter(|_| r.zipf(1000, 2.0) <= 10).count();
        assert!(small > n / 2, "zipf(2.0) should mostly draw small values, got {small}");
    }

    #[test]
    fn zipf_sampler_is_deterministic_per_seed() {
        let z = Zipf::new(16, 1.2);
        let mut a = Rng::new(99);
        let mut b = Rng::new(99);
        let draws_a: Vec<usize> = (0..256).map(|_| z.sample(&mut a)).collect();
        let draws_b: Vec<usize> = (0..256).map(|_| z.sample(&mut b)).collect();
        assert_eq!(draws_a, draws_b, "same seed must give the same draw sequence");
        assert!(draws_a.iter().all(|&r| (1..=16).contains(&r)));
        let mut c = Rng::new(100);
        let draws_c: Vec<usize> = (0..256).map(|_| z.sample(&mut c)).collect();
        assert_ne!(draws_a, draws_c, "different seeds must diverge");
    }

    #[test]
    fn zipf_sampler_rank1_frequency_matches_theoretical_share() {
        let z = Zipf::new(8, 2.0);
        let mut r = Rng::new(0x51);
        let n = 20000;
        let rank1 = (0..n).filter(|_| z.sample(&mut r) == 1).count();
        let observed = rank1 as f64 / n as f64;
        let expected = z.share(1);
        assert!(expected > 0.6, "alpha=2 over 8 ranks is heavily skewed, got {expected}");
        assert!(
            (observed - expected).abs() < 0.02,
            "rank-1 frequency {observed} vs theoretical {expected}"
        );
        // shares are a probability distribution, monotone in rank
        let shares: Vec<f64> = (1..=8).map(|r| z.share(r)).collect();
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(shares.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn val_never_zero() {
        let mut r = Rng::new(19);
        assert!((0..10000).all(|_| r.val() != 0.0));
    }
}
