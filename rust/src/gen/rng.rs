//! Deterministic RNG (xoshiro256**) — no external crates, fully
//! reproducible corpus generation from fixed seeds.

/// xoshiro256** PRNG. Seeded through splitmix64 so any u64 seed yields a
/// well-mixed state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [-1, 1) excluding exact 0 (sparse values must be
    /// structurally non-zero).
    #[inline]
    pub fn val(&mut self) -> f32 {
        let v = (self.f64() * 2.0 - 1.0) as f32;
        if v == 0.0 { 0.5 } else { v }
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi].
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Poisson(lambda) — inversion for small lambda, normal approx above.
    pub fn poisson(&mut self, lambda: f64) -> usize {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            return (lambda + lambda.sqrt() * self.normal()).round().max(0.0) as usize;
        }
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf-like integer in [1, n] with exponent `alpha` (rejection-free
    /// inverse-CDF approximation — adequate for workload generation).
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        let u = self.f64().max(1e-12);
        if (alpha - 1.0).abs() < 1e-9 {
            let z = (n as f64).ln();
            return ((u * z).exp() as usize).clamp(1, n);
        }
        let e = 1.0 - alpha;
        let z = ((n as f64).powf(e) - 1.0) / e;
        (((u * z * e + 1.0).powf(1.0 / e)) as usize).clamp(1, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(11);
        let lambda = 6.0;
        let n = 5000;
        let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < 0.3, "mean {mean}");
    }

    #[test]
    fn zipf_skews_small() {
        let mut r = Rng::new(13);
        let n = 10000;
        let small = (0..n).filter(|_| r.zipf(1000, 2.0) <= 10).count();
        assert!(small > n / 2, "zipf(2.0) should mostly draw small values, got {small}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn val_never_zero() {
        let mut r = Rng::new(19);
        assert!((0..10000).all(|_| r.val() != 0.0));
    }
}
