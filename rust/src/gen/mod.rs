//! Synthetic sparse-matrix generation: RNG, pattern generators, and the
//! 30-matrix corpus standing in for the paper's SuiteSparse selection
//! (§6.1; substitution rationale in DESIGN.md §1).

pub mod corpus;
pub mod patterns;
pub mod rng;

pub use corpus::{by_name, corpus, CorpusEntry, Class, GPU_SENSITIVITY_SET};
pub use rng::{Rng, Zipf};
