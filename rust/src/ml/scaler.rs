//! Feature standardization (zero mean, unit variance) — fitted on the
//! training split only, applied everywhere (the usual sklearn pipeline).

/// Per-feature standard scaler.
#[derive(Debug, Clone, Default)]
pub struct StandardScaler {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl StandardScaler {
    pub fn fit(x: &[Vec<f64>]) -> Self {
        let n = x.len();
        if n == 0 {
            return Self::default();
        }
        let d = x[0].len();
        let mut mean = vec![0.0; d];
        for row in x {
            for (m, v) in mean.iter_mut().zip(row) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        let mut var = vec![0.0; d];
        for row in x {
            for j in 0..d {
                let dlt = row[j] - mean[j];
                var[j] += dlt * dlt;
            }
        }
        let std = var
            .into_iter()
            .map(|v| {
                let s = (v / n as f64).sqrt();
                if s < 1e-12 { 1.0 } else { s }
            })
            .collect();
        StandardScaler { mean, std }
    }

    pub fn transform_row(&self, row: &[f64]) -> Vec<f64> {
        row.iter()
            .enumerate()
            .map(|(j, v)| (v - self.mean[j]) / self.std[j])
            .collect()
    }

    pub fn transform(&self, x: &[Vec<f64>]) -> Vec<Vec<f64>> {
        x.iter().map(|r| self.transform_row(r)).collect()
    }

    pub fn fit_transform(x: &[Vec<f64>]) -> (Self, Vec<Vec<f64>>) {
        let s = Self::fit(x);
        let t = s.transform(x);
        (s, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standardizes_to_zero_mean_unit_var() {
        let x = vec![vec![1.0, 10.0], vec![2.0, 20.0], vec![3.0, 30.0]];
        let (_, t) = StandardScaler::fit_transform(&x);
        for j in 0..2 {
            let mean: f64 = t.iter().map(|r| r[j]).sum::<f64>() / 3.0;
            let var: f64 = t.iter().map(|r| r[j] * r[j]).sum::<f64>() / 3.0;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn constant_feature_untouched() {
        let x = vec![vec![5.0], vec![5.0]];
        let (s, t) = StandardScaler::fit_transform(&x);
        assert_eq!(s.std[0], 1.0);
        assert_eq!(t[0][0], 0.0);
    }

    #[test]
    fn transform_uses_train_stats() {
        let s = StandardScaler { mean: vec![10.0], std: vec![2.0] };
        assert_eq!(s.transform_row(&[14.0]), vec![2.0]);
    }
}
