//! Evaluation metrics: accuracy and macro-F1 for classification (Table 5),
//! R² and MSE for regression (Fig. 11).

/// Fraction of exact label matches.
pub fn accuracy(y_true: &[usize], y_pred: &[usize]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    if y_true.is_empty() {
        return 0.0;
    }
    let hits = y_true.iter().zip(y_pred).filter(|(a, b)| a == b).count();
    hits as f64 / y_true.len() as f64
}

/// Confusion matrix `c[true][pred]` over `k` classes.
pub fn confusion(y_true: &[usize], y_pred: &[usize], k: usize) -> Vec<Vec<usize>> {
    let mut c = vec![vec![0usize; k]; k];
    for (&t, &p) in y_true.iter().zip(y_pred) {
        c[t][p] += 1;
    }
    c
}

/// Macro-averaged F1 over the classes *present in y_true* (scikit-learn's
/// behaviour with `labels=present`): classes never seen contribute no term.
pub fn f1_macro(y_true: &[usize], y_pred: &[usize], k: usize) -> f64 {
    let c = confusion(y_true, y_pred, k);
    let mut f1_sum = 0.0;
    let mut present = 0usize;
    for cls in 0..k {
        let tp = c[cls][cls] as f64;
        let fn_: f64 = (0..k).filter(|&j| j != cls).map(|j| c[cls][j] as f64).sum();
        let fp: f64 = (0..k).filter(|&j| j != cls).map(|j| c[j][cls] as f64).sum();
        if tp + fn_ == 0.0 {
            continue; // class absent from y_true
        }
        present += 1;
        let denom = 2.0 * tp + fp + fn_;
        if denom > 0.0 {
            f1_sum += 2.0 * tp / denom;
        }
    }
    if present == 0 {
        0.0
    } else {
        f1_sum / present as f64
    }
}

/// Mean squared error.
pub fn mse(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    if y_true.is_empty() {
        return 0.0;
    }
    y_true
        .iter()
        .zip(y_pred)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        / y_true.len() as f64
}

/// Coefficient of determination R².
pub fn r2(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    if y_true.is_empty() {
        return 0.0;
    }
    let mean = y_true.iter().sum::<f64>() / y_true.len() as f64;
    let ss_tot: f64 = y_true.iter().map(|v| (v - mean) * (v - mean)).sum();
    let ss_res: f64 = y_true.iter().zip(y_pred).map(|(a, b)| (a - b) * (a - b)).sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 { 1.0 } else { 0.0 }
    } else {
        1.0 - ss_res / ss_tot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[0, 1, 2, 1], &[0, 1, 1, 1]), 0.75);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn perfect_f1_is_one() {
        let y = [0usize, 1, 2, 0, 1, 2];
        assert!((f1_macro(&y, &y, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn f1_hand_computed_binary() {
        // true: [1,1,0,0], pred: [1,0,0,1]
        // class 1: tp=1 fp=1 fn=1 -> f1 = 2/4 = .5 ; class 0 symmetric
        let f = f1_macro(&[1, 1, 0, 0], &[1, 0, 0, 1], 2);
        assert!((f - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_ignores_absent_classes() {
        // only class 0 present in truth; predicting all 0 is perfect
        let f = f1_macro(&[0, 0, 0], &[0, 0, 0], 4);
        assert!((f - 1.0).abs() < 1e-12);
    }

    #[test]
    fn confusion_layout() {
        let c = confusion(&[0, 1, 1], &[1, 1, 0], 2);
        assert_eq!(c, vec![vec![0, 1], vec![1, 1]]);
    }

    #[test]
    fn mse_and_r2() {
        let t = [1.0, 2.0, 3.0];
        assert_eq!(mse(&t, &t), 0.0);
        assert_eq!(r2(&t, &t), 1.0);
        let mean_pred = [2.0, 2.0, 2.0];
        assert!(r2(&t, &mean_pred).abs() < 1e-12); // predicting mean -> 0
        assert!((mse(&t, &mean_pred) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn r2_constant_truth() {
        assert_eq!(r2(&[5.0, 5.0], &[5.0, 5.0]), 1.0);
        assert_eq!(r2(&[5.0, 5.0], &[4.0, 5.0]), 0.0);
    }
}
