//! Train/test splitting (the paper's 80/20 protocol, §6.4) and K-fold
//! cross-validation indices — seeded and deterministic.

use crate::gen::Rng;

/// Fisher-Yates shuffled index vector.
pub fn shuffled_indices(n: usize, seed: u64) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(seed);
    for i in (1..n).rev() {
        idx.swap(i, rng.below(i + 1));
    }
    idx
}

/// Split indices into (train, test) with `test_frac` in the test side.
pub fn train_test_indices(n: usize, test_frac: f64, seed: u64) -> (Vec<usize>, Vec<usize>) {
    let idx = shuffled_indices(n, seed);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let n_test = n_test.min(n);
    (idx[n_test..].to_vec(), idx[..n_test].to_vec())
}

/// Gather rows of a feature matrix by index.
pub fn take_x(x: &[Vec<f64>], idx: &[usize]) -> Vec<Vec<f64>> {
    idx.iter().map(|&i| x[i].clone()).collect()
}

/// Gather elements of a label/target vector by index.
pub fn take<T: Copy>(y: &[T], idx: &[usize]) -> Vec<T> {
    idx.iter().map(|&i| y[i]).collect()
}

/// K-fold index sets: returns `k` (train, valid) pairs.
pub fn kfold(n: usize, k: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(k >= 2 && n >= k);
    let idx = shuffled_indices(n, seed);
    let mut out = Vec::with_capacity(k);
    for f in 0..k {
        let lo = f * n / k;
        let hi = (f + 1) * n / k;
        let valid: Vec<usize> = idx[lo..hi].to_vec();
        let train: Vec<usize> = idx[..lo].iter().chain(&idx[hi..]).copied().collect();
        out.push((train, valid));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_is_partition() {
        let (tr, te) = train_test_indices(100, 0.2, 7);
        assert_eq!(te.len(), 20);
        assert_eq!(tr.len(), 80);
        let mut all: Vec<usize> = tr.iter().chain(&te).copied().collect();
        all.sort();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(train_test_indices(50, 0.2, 1), train_test_indices(50, 0.2, 1));
        assert_ne!(train_test_indices(50, 0.2, 1).1, train_test_indices(50, 0.2, 2).1);
    }

    #[test]
    fn kfold_covers_everything_once() {
        let folds = kfold(23, 5, 3);
        assert_eq!(folds.len(), 5);
        let mut seen = vec![0usize; 23];
        for (tr, va) in &folds {
            assert_eq!(tr.len() + va.len(), 23);
            for &i in va {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn take_helpers() {
        let x = vec![vec![1.0], vec![2.0], vec![3.0]];
        let y = [10usize, 20, 30];
        assert_eq!(take_x(&x, &[2, 0]), vec![vec![3.0], vec![1.0]]);
        assert_eq!(take(&y, &[1]), vec![20]);
    }
}
