//! CART decision trees — the paper's winning classifier (Table 5) and a
//! Fig. 11 regressor. Supports the Table 1 hyperparameters: criterion
//! (gini / entropy / log_loss) and splitter (best / random), plus
//! max_depth and min_samples_split.

use super::{Classifier, Regressor};
use crate::gen::Rng;

/// Split-quality criterion (log_loss == entropy, as in sklearn).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Criterion {
    Gini,
    Entropy,
    LogLoss,
}

impl Criterion {
    pub const ALL: [Criterion; 3] = [Criterion::Gini, Criterion::Entropy, Criterion::LogLoss];

    pub fn name(self) -> &'static str {
        match self {
            Criterion::Gini => "gini",
            Criterion::Entropy => "entropy",
            Criterion::LogLoss => "log_loss",
        }
    }

    fn impurity(self, counts: &[usize], total: usize) -> f64 {
        if total == 0 {
            return 0.0;
        }
        match self {
            Criterion::Gini => {
                let mut g = 1.0;
                for &c in counts {
                    let p = c as f64 / total as f64;
                    g -= p * p;
                }
                g
            }
            Criterion::Entropy | Criterion::LogLoss => {
                let mut h = 0.0;
                for &c in counts {
                    if c > 0 {
                        let p = c as f64 / total as f64;
                        h -= p * p.log2();
                    }
                }
                h
            }
        }
    }
}

/// Split-point selection strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Splitter {
    /// Scan all thresholds for the impurity-optimal split.
    Best,
    /// sklearn's "random": one uniform threshold per feature, pick the
    /// best feature (extra-trees style).
    Random,
}

#[derive(Debug, Clone)]
pub enum Node {
    Leaf { value: f64, class: usize },
    Split { feature: usize, threshold: f64, left: usize, right: usize },
}

/// Shared tree-growing machinery for both tasks.
#[derive(Debug, Clone)]
pub struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict_leaf(&self, x: &[f64]) -> (&f64, &usize) {
        let mut cur = 0usize;
        loop {
            match &self.nodes[cur] {
                Node::Leaf { value, class } => return (value, class),
                Node::Split { feature, threshold, left, right } => {
                    cur = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    fn depth_from(&self, i: usize) -> usize {
        match &self.nodes[i] {
            Node::Leaf { .. } => 1,
            Node::Split { left, right, .. } => {
                1 + self.depth_from(*left).max(self.depth_from(*right))
            }
        }
    }
}

// ---------------------------------------------------------------------
// Classifier
// ---------------------------------------------------------------------

/// CART classifier.
#[derive(Debug, Clone)]
pub struct DecisionTreeClassifier {
    pub criterion: Criterion,
    pub splitter: Splitter,
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// Features considered per split (None = all) — used by forests.
    pub max_features: Option<usize>,
    pub seed: u64,
    pub tree: Option<Tree>,
    pub n_classes: usize,
}

impl Default for DecisionTreeClassifier {
    fn default() -> Self {
        DecisionTreeClassifier {
            criterion: Criterion::Gini,
            splitter: Splitter::Best,
            max_depth: 13, // paper Table 4: Depth = 13
            min_samples_split: 2,
            max_features: None,
            seed: 0,
            tree: None,
            n_classes: 0,
        }
    }
}

struct ClsContext<'a> {
    x: &'a [Vec<f64>],
    y: &'a [usize],
    k: usize,
    criterion: Criterion,
    splitter: Splitter,
    max_depth: usize,
    min_split: usize,
    max_features: usize,
    rng: Rng,
}

impl DecisionTreeClassifier {
    fn grow(ctx: &mut ClsContext, nodes: &mut Vec<Node>, idx: &mut [usize], depth: usize) -> usize {
        let mut counts = vec![0usize; ctx.k];
        for &i in idx.iter() {
            counts[ctx.y[i]] += 1;
        }
        let majority = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(c, _)| c)
            .unwrap_or(0);
        let node_impurity = ctx.criterion.impurity(&counts, idx.len());
        let pure = counts.iter().filter(|&&c| c > 0).count() <= 1;

        if pure || depth >= ctx.max_depth || idx.len() < ctx.min_split {
            nodes.push(Node::Leaf { value: majority as f64, class: majority });
            return nodes.len() - 1;
        }

        // candidate features
        let d = ctx.x[0].len();
        let mut feats: Vec<usize> = (0..d).collect();
        if ctx.max_features < d {
            for i in 0..ctx.max_features {
                let j = i + ctx.rng.below(d - i);
                feats.swap(i, j);
            }
            feats.truncate(ctx.max_features);
        }

        let mut best: Option<(f64, usize, f64)> = None; // (score, feat, thr)
        let mut vals: Vec<(f64, usize)> = Vec::with_capacity(idx.len());
        for &f in &feats {
            vals.clear();
            vals.extend(idx.iter().map(|&i| (ctx.x[i][f], ctx.y[i])));
            vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            if vals[0].0 == vals[vals.len() - 1].0 {
                continue; // constant feature
            }
            match ctx.splitter {
                Splitter::Best => {
                    let mut left = vec![0usize; ctx.k];
                    let mut right = counts.clone();
                    let total = idx.len();
                    for w in 0..vals.len() - 1 {
                        left[vals[w].1] += 1;
                        right[vals[w].1] -= 1;
                        if vals[w].0 == vals[w + 1].0 {
                            continue;
                        }
                        let nl = w + 1;
                        let nr = total - nl;
                        let score = (nl as f64 * ctx.criterion.impurity(&left, nl)
                            + nr as f64 * ctx.criterion.impurity(&right, nr))
                            / total as f64;
                        let thr = 0.5 * (vals[w].0 + vals[w + 1].0);
                        if best.map_or(true, |(s, _, _)| score < s) {
                            best = Some((score, f, thr));
                        }
                    }
                }
                Splitter::Random => {
                    let (lo, hi) = (vals[0].0, vals[vals.len() - 1].0);
                    let thr = lo + ctx.rng.f64() * (hi - lo);
                    let mut left = vec![0usize; ctx.k];
                    let mut right = vec![0usize; ctx.k];
                    for &(v, c) in &vals {
                        if v <= thr {
                            left[c] += 1;
                        } else {
                            right[c] += 1;
                        }
                    }
                    let (nl, nr) = (left.iter().sum::<usize>(), right.iter().sum::<usize>());
                    if nl == 0 || nr == 0 {
                        continue;
                    }
                    let score = (nl as f64 * ctx.criterion.impurity(&left, nl)
                        + nr as f64 * ctx.criterion.impurity(&right, nr))
                        / idx.len() as f64;
                    if best.map_or(true, |(s, _, _)| score < s) {
                        best = Some((score, f, thr));
                    }
                }
            }
        }

        match best {
            Some((score, f, thr)) if score < node_impurity - 1e-12 => {
                // partition idx in place
                let mut mid = 0usize;
                for i in 0..idx.len() {
                    if ctx.x[idx[i]][f] <= thr {
                        idx.swap(i, mid);
                        mid += 1;
                    }
                }
                if mid == 0 || mid == idx.len() {
                    nodes.push(Node::Leaf { value: majority as f64, class: majority });
                    return nodes.len() - 1;
                }
                let slot = nodes.len();
                nodes.push(Node::Leaf { value: 0.0, class: 0 }); // placeholder
                let (l_idx, r_idx) = idx.split_at_mut(mid);
                let left = Self::grow(ctx, nodes, l_idx, depth + 1);
                let right = Self::grow(ctx, nodes, r_idx, depth + 1);
                nodes[slot] = Node::Split { feature: f, threshold: thr, left, right };
                slot
            }
            _ => {
                nodes.push(Node::Leaf { value: majority as f64, class: majority });
                nodes.len() - 1
            }
        }
    }

    pub fn depth(&self) -> usize {
        self.tree.as_ref().map_or(0, |t| t.depth_from(0))
    }
}

impl Classifier for DecisionTreeClassifier {
    fn fit(&mut self, x: &[Vec<f64>], y: &[usize]) {
        assert!(!x.is_empty());
        self.n_classes = super::n_classes(y);
        let d = x[0].len();
        let mut ctx = ClsContext {
            x,
            y,
            k: self.n_classes,
            criterion: self.criterion,
            splitter: self.splitter,
            max_depth: self.max_depth.max(1),
            min_split: self.min_samples_split.max(2),
            max_features: self.max_features.unwrap_or(d).clamp(1, d),
            rng: Rng::new(self.seed ^ 0xDEC1510),
        };
        let mut nodes = Vec::new();
        let mut idx: Vec<usize> = (0..x.len()).collect();
        Self::grow(&mut ctx, &mut nodes, &mut idx, 0);
        self.tree = Some(Tree { nodes });
    }

    fn predict_one(&self, x: &[f64]) -> usize {
        *self.tree.as_ref().expect("fit first").predict_leaf(x).1
    }
}

// ---------------------------------------------------------------------
// Regressor
// ---------------------------------------------------------------------

/// CART regressor (MSE criterion), used standalone (Fig. 11) and inside
/// random forests / gradient boosting.
#[derive(Debug, Clone)]
pub struct DecisionTreeRegressor {
    pub max_depth: usize,
    pub min_samples_split: usize,
    pub max_features: Option<usize>,
    pub seed: u64,
    pub tree: Option<Tree>,
}

impl Default for DecisionTreeRegressor {
    fn default() -> Self {
        DecisionTreeRegressor {
            max_depth: usize::MAX, // paper Table 4: Depth = None
            min_samples_split: 2,
            max_features: None,
            seed: 0,
            tree: None,
        }
    }
}

struct RegContext<'a> {
    x: &'a [Vec<f64>],
    y: &'a [f64],
    max_depth: usize,
    min_split: usize,
    max_features: usize,
    rng: Rng,
}

impl DecisionTreeRegressor {
    fn grow(ctx: &mut RegContext, nodes: &mut Vec<Node>, idx: &mut [usize], depth: usize) -> usize {
        let n = idx.len() as f64;
        let mean = idx.iter().map(|&i| ctx.y[i]).sum::<f64>() / n;
        let sse: f64 = idx.iter().map(|&i| (ctx.y[i] - mean) * (ctx.y[i] - mean)).sum();

        if sse < 1e-12 || depth >= ctx.max_depth || idx.len() < ctx.min_split {
            nodes.push(Node::Leaf { value: mean, class: 0 });
            return nodes.len() - 1;
        }

        let d = ctx.x[0].len();
        let mut feats: Vec<usize> = (0..d).collect();
        if ctx.max_features < d {
            for i in 0..ctx.max_features {
                let j = i + ctx.rng.below(d - i);
                feats.swap(i, j);
            }
            feats.truncate(ctx.max_features);
        }

        // best split by SSE reduction (prefix-sum scan)
        let mut best: Option<(f64, usize, f64)> = None;
        let mut vals: Vec<(f64, f64)> = Vec::with_capacity(idx.len());
        for &f in &feats {
            vals.clear();
            vals.extend(idx.iter().map(|&i| (ctx.x[i][f], ctx.y[i])));
            vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            if vals[0].0 == vals[vals.len() - 1].0 {
                continue;
            }
            let total_sum: f64 = vals.iter().map(|v| v.1).sum();
            let total_sq: f64 = vals.iter().map(|v| v.1 * v.1).sum();
            let mut lsum = 0.0;
            let mut lsq = 0.0;
            for w in 0..vals.len() - 1 {
                lsum += vals[w].1;
                lsq += vals[w].1 * vals[w].1;
                if vals[w].0 == vals[w + 1].0 {
                    continue;
                }
                let nl = (w + 1) as f64;
                let nr = n - nl;
                let sse_l = lsq - lsum * lsum / nl;
                let sse_r = (total_sq - lsq) - (total_sum - lsum) * (total_sum - lsum) / nr;
                let score = sse_l + sse_r;
                if best.map_or(true, |(s, _, _)| score < s) {
                    best = Some((score, f, 0.5 * (vals[w].0 + vals[w + 1].0)));
                }
            }
        }

        match best {
            Some((score, f, thr)) if score < sse - 1e-12 => {
                let mut mid = 0usize;
                for i in 0..idx.len() {
                    if ctx.x[idx[i]][f] <= thr {
                        idx.swap(i, mid);
                        mid += 1;
                    }
                }
                if mid == 0 || mid == idx.len() {
                    nodes.push(Node::Leaf { value: mean, class: 0 });
                    return nodes.len() - 1;
                }
                let slot = nodes.len();
                nodes.push(Node::Leaf { value: 0.0, class: 0 });
                let (l_idx, r_idx) = idx.split_at_mut(mid);
                let left = Self::grow(ctx, nodes, l_idx, depth + 1);
                let right = Self::grow(ctx, nodes, r_idx, depth + 1);
                nodes[slot] = Node::Split { feature: f, threshold: thr, left, right };
                slot
            }
            _ => {
                nodes.push(Node::Leaf { value: mean, class: 0 });
                nodes.len() - 1
            }
        }
    }
}

impl Regressor for DecisionTreeRegressor {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert!(!x.is_empty());
        let d = x[0].len();
        let mut ctx = RegContext {
            x,
            y,
            max_depth: self.max_depth.max(1),
            min_split: self.min_samples_split.max(2),
            max_features: self.max_features.unwrap_or(d).clamp(1, d),
            rng: Rng::new(self.seed ^ 0x7259),
        };
        let mut nodes = Vec::new();
        let mut idx: Vec<usize> = (0..x.len()).collect();
        Self::grow(&mut ctx, &mut nodes, &mut idx, 0);
        self.tree = Some(Tree { nodes });
    }

    fn predict_one(&self, x: &[f64]) -> f64 {
        *self.tree.as_ref().expect("fit first").predict_leaf(x).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::{accuracy, r2};
    use crate::ml::testdata;

    #[test]
    fn classifier_fits_blobs_perfectly() {
        let (x, y) = testdata::blobs(40, 1);
        let mut t = DecisionTreeClassifier::default();
        t.fit(&x, &y);
        assert!(accuracy(&y, &t.predict(&x)) > 0.98);
    }

    #[test]
    fn classifier_solves_xor() {
        let (x, y) = testdata::xor(50, 2);
        let mut t = DecisionTreeClassifier::default();
        t.fit(&x, &y);
        assert_eq!(accuracy(&y, &t.predict(&x)), 1.0);
    }

    #[test]
    fn depth_limit_respected() {
        let (x, y) = testdata::xor(50, 3);
        let mut t = DecisionTreeClassifier { max_depth: 2, ..Default::default() };
        t.fit(&x, &y);
        assert!(t.depth() <= 3); // root + 2 levels
    }

    #[test]
    fn all_criteria_work() {
        let (x, y) = testdata::blobs(30, 4);
        for c in Criterion::ALL {
            let mut t = DecisionTreeClassifier { criterion: c, ..Default::default() };
            t.fit(&x, &y);
            assert!(accuracy(&y, &t.predict(&x)) > 0.95, "{}", c.name());
        }
    }

    #[test]
    fn random_splitter_still_learns() {
        let (x, y) = testdata::blobs(40, 5);
        let mut t = DecisionTreeClassifier {
            splitter: Splitter::Random,
            max_depth: 12,
            seed: 3,
            ..Default::default()
        };
        t.fit(&x, &y);
        assert!(accuracy(&y, &t.predict(&x)) > 0.9);
    }

    #[test]
    fn regressor_fits_nonlinear() {
        let (x, y) = testdata::friedman(400, 6);
        let mut t = DecisionTreeRegressor::default();
        t.fit(&x, &y);
        assert!(r2(&y, &t.predict(&x)) > 0.95);
    }

    #[test]
    fn regressor_constant_target() {
        let x = vec![vec![1.0], vec![2.0], vec![3.0]];
        let y = vec![5.0, 5.0, 5.0];
        let mut t = DecisionTreeRegressor::default();
        t.fit(&x, &y);
        assert_eq!(t.predict_one(&[9.0]), 5.0);
    }

    #[test]
    fn single_class_predicts_it() {
        let x = vec![vec![0.0], vec![1.0]];
        let y = vec![2usize, 2];
        let mut t = DecisionTreeClassifier::default();
        t.fit(&x, &y);
        assert_eq!(t.predict_one(&[0.5]), 2);
    }
}
