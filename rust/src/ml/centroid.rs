//! Nearest-centroid classifier — Table 1/4 (metric: manhattan /
//! euclidean / minkowski).

use super::Classifier;

/// Distance metric for centroid matching.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Metric {
    Manhattan,
    Euclidean,
    /// Minkowski with exponent p.
    Minkowski(f64),
}

impl Metric {
    pub fn name(self) -> &'static str {
        match self {
            Metric::Manhattan => "manhattan",
            Metric::Euclidean => "euclidean",
            Metric::Minkowski(_) => "minkowski",
        }
    }

    fn dist(self, a: &[f64], b: &[f64]) -> f64 {
        match self {
            Metric::Manhattan => a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum(),
            Metric::Euclidean => a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f64>()
                .sqrt(),
            Metric::Minkowski(p) => a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs().powf(p))
                .sum::<f64>()
                .powf(1.0 / p),
        }
    }
}

/// Nearest-centroid classifier.
#[derive(Debug, Clone)]
pub struct NearestCentroid {
    pub metric: Metric,
    pub centroids: Vec<(usize, Vec<f64>)>,
}

impl Default for NearestCentroid {
    fn default() -> Self {
        // paper Table 4: metric = manhattan
        NearestCentroid { metric: Metric::Manhattan, centroids: Vec::new() }
    }
}

impl Classifier for NearestCentroid {
    fn fit(&mut self, x: &[Vec<f64>], y: &[usize]) {
        assert!(!x.is_empty());
        let k = super::n_classes(y);
        let d = x[0].len();
        let mut sums = vec![vec![0.0f64; d]; k];
        let mut counts = vec![0usize; k];
        for (row, &cls) in x.iter().zip(y) {
            counts[cls] += 1;
            for (s, v) in sums[cls].iter_mut().zip(row) {
                *s += v;
            }
        }
        self.centroids = sums
            .into_iter()
            .zip(counts)
            .enumerate()
            .filter(|(_, (_, c))| *c > 0)
            .map(|(cls, (mut s, c))| {
                for v in &mut s {
                    *v /= c as f64;
                }
                (cls, s)
            })
            .collect();
    }

    fn predict_one(&self, x: &[f64]) -> usize {
        self.centroids
            .iter()
            .min_by(|a, b| {
                self.metric
                    .dist(&a.1, x)
                    .partial_cmp(&self.metric.dist(&b.1, x))
                    .unwrap()
            })
            .map(|(cls, _)| *cls)
            .expect("fit first")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;
    use crate::ml::testdata;

    #[test]
    fn separable_blobs_all_metrics() {
        let (x, y) = testdata::blobs(40, 11);
        for m in [Metric::Manhattan, Metric::Euclidean, Metric::Minkowski(3.0)] {
            let mut c = NearestCentroid { metric: m, ..Default::default() };
            c.fit(&x, &y);
            assert!(accuracy(&y, &c.predict(&x)) > 0.95, "{}", m.name());
        }
    }

    #[test]
    fn fails_on_xor_as_expected() {
        // centroids of XOR classes coincide at the origin: near-chance.
        let (x, y) = testdata::xor(50, 12);
        let mut c = NearestCentroid::default();
        c.fit(&x, &y);
        let acc = accuracy(&y, &c.predict(&x));
        assert!(acc < 0.8, "nearest centroid cannot solve XOR, acc {acc}");
    }

    #[test]
    fn skips_empty_classes() {
        let x = vec![vec![0.0], vec![10.0]];
        let y = vec![0usize, 3]; // classes 1, 2 absent
        let mut c = NearestCentroid::default();
        c.fit(&x, &y);
        assert_eq!(c.predict_one(&[9.0]), 3);
        assert_eq!(c.predict_one(&[1.0]), 0);
    }

    #[test]
    fn metric_math() {
        assert_eq!(Metric::Manhattan.dist(&[0.0, 0.0], &[3.0, 4.0]), 7.0);
        assert_eq!(Metric::Euclidean.dist(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
        let m = Metric::Minkowski(2.0).dist(&[0.0, 0.0], &[3.0, 4.0]);
        assert!((m - 5.0).abs() < 1e-12);
    }
}
