//! Random forests — bagged CART trees with feature subsampling.
//! Paper Table 4: 100 estimators, max depth 15 (classifier) / None
//! (regressor).

use super::tree::{Criterion, DecisionTreeClassifier, DecisionTreeRegressor, Splitter};
use super::{Classifier, Regressor};
use crate::gen::Rng;

fn bootstrap(n: usize, rng: &mut Rng) -> Vec<usize> {
    (0..n).map(|_| rng.below(n)).collect()
}

/// Random-forest classifier (majority vote).
#[derive(Debug, Clone)]
pub struct RandomForestClassifier {
    pub n_estimators: usize,
    pub criterion: Criterion,
    pub max_depth: usize,
    /// Features per split; None = sqrt(d).
    pub max_features: Option<usize>,
    /// Bootstrap resampling on/off (off = bagged-trees baseline uses all rows).
    pub bootstrap: bool,
    pub seed: u64,
    pub trees: Vec<DecisionTreeClassifier>,
    pub n_classes: usize,
}

impl Default for RandomForestClassifier {
    fn default() -> Self {
        RandomForestClassifier {
            n_estimators: 100,
            criterion: Criterion::Gini,
            max_depth: 15, // paper Table 4
            max_features: None,
            bootstrap: true,
            seed: 0,
            trees: Vec::new(),
            n_classes: 0,
        }
    }
}

impl Classifier for RandomForestClassifier {
    fn fit(&mut self, x: &[Vec<f64>], y: &[usize]) {
        assert!(!x.is_empty());
        self.n_classes = super::n_classes(y);
        let d = x[0].len();
        let mf = self.max_features.unwrap_or_else(|| (d as f64).sqrt().ceil() as usize);
        let mut rng = Rng::new(self.seed ^ 0xF0FE57);
        self.trees = (0..self.n_estimators)
            .map(|t| {
                let idx: Vec<usize> = if self.bootstrap {
                    bootstrap(x.len(), &mut rng)
                } else {
                    (0..x.len()).collect()
                };
                let bx: Vec<Vec<f64>> = idx.iter().map(|&i| x[i].clone()).collect();
                let by: Vec<usize> = idx.iter().map(|&i| y[i]).collect();
                let mut tree = DecisionTreeClassifier {
                    criterion: self.criterion,
                    splitter: Splitter::Best,
                    max_depth: self.max_depth,
                    max_features: Some(mf),
                    seed: self.seed.wrapping_add(t as u64 * 7919 + 1),
                    ..Default::default()
                };
                tree.fit(&bx, &by);
                tree
            })
            .collect();
    }

    fn predict_one(&self, x: &[f64]) -> usize {
        let mut votes = vec![0usize; self.n_classes.max(1)];
        for t in &self.trees {
            votes[t.predict_one(x)] += 1;
        }
        votes.iter().enumerate().max_by_key(|(_, &v)| v).map(|(c, _)| c).unwrap_or(0)
    }
}

/// Random-forest regressor (mean of trees).
#[derive(Debug, Clone)]
pub struct RandomForestRegressor {
    pub n_estimators: usize,
    pub max_depth: usize,
    pub max_features: Option<usize>,
    pub seed: u64,
    pub trees: Vec<DecisionTreeRegressor>,
}

impl Default for RandomForestRegressor {
    fn default() -> Self {
        RandomForestRegressor {
            n_estimators: 100,
            max_depth: usize::MAX, // paper Table 4: Depth = None
            max_features: None,
            seed: 0,
            trees: Vec::new(),
        }
    }
}

impl Regressor for RandomForestRegressor {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert!(!x.is_empty());
        let d = x[0].len();
        let mf = self.max_features.unwrap_or_else(|| ((d as f64) / 3.0).ceil() as usize);
        let mut rng = Rng::new(self.seed ^ 0xF02E6);
        self.trees = (0..self.n_estimators)
            .map(|t| {
                let idx = bootstrap(x.len(), &mut rng);
                let bx: Vec<Vec<f64>> = idx.iter().map(|&i| x[i].clone()).collect();
                let by: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
                let mut tree = DecisionTreeRegressor {
                    max_depth: self.max_depth,
                    max_features: Some(mf.max(1)),
                    seed: self.seed.wrapping_add(t as u64 * 6367 + 1),
                    ..Default::default()
                };
                tree.fit(&bx, &by);
                tree
            })
            .collect();
    }

    fn predict_one(&self, x: &[f64]) -> f64 {
        if self.trees.is_empty() {
            return 0.0;
        }
        self.trees.iter().map(|t| t.predict_one(x)).sum::<f64>() / self.trees.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::{accuracy, r2};
    use crate::ml::split::{take, take_x, train_test_indices};
    use crate::ml::testdata;

    #[test]
    fn forest_classifies_blobs_held_out() {
        let (x, y) = testdata::blobs(60, 7);
        let (tr, te) = train_test_indices(x.len(), 0.25, 1);
        let mut f = RandomForestClassifier { n_estimators: 25, ..Default::default() };
        f.fit(&take_x(&x, &tr), &take(&y, &tr));
        let acc = accuracy(&take(&y, &te), &f.predict(&take_x(&x, &te)));
        assert!(acc > 0.95, "acc {acc}");
    }

    #[test]
    fn forest_regresses_friedman_held_out() {
        let (x, y) = testdata::friedman(500, 8);
        let (tr, te) = train_test_indices(x.len(), 0.25, 2);
        let mut f = RandomForestRegressor { n_estimators: 30, ..Default::default() };
        f.fit(&take_x(&x, &tr), &take(&y, &tr));
        let score = r2(&take(&y, &te), &f.predict(&take_x(&x, &te)));
        assert!(score > 0.85, "r2 {score}");
    }

    #[test]
    fn no_bootstrap_mode_works() {
        let (x, y) = testdata::xor(40, 9);
        let mut f = RandomForestClassifier {
            n_estimators: 15,
            bootstrap: false,
            ..Default::default()
        };
        f.fit(&x, &y);
        assert!(accuracy(&y, &f.predict(&x)) > 0.95);
    }

    #[test]
    fn deterministic_per_seed() {
        let (x, y) = testdata::blobs(30, 10);
        let mut a = RandomForestClassifier { n_estimators: 5, seed: 3, ..Default::default() };
        let mut b = RandomForestClassifier { n_estimators: 5, seed: 3, ..Default::default() };
        a.fit(&x, &y);
        b.fit(&x, &y);
        assert_eq!(a.predict(&x), b.predict(&x));
    }
}
