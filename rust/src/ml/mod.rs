//! From-scratch ML library — the scikit-learn stand-in (DESIGN.md §1).
//!
//! Implements the paper's model zoo (Tables 1 & 4): nearest centroid,
//! decision tree, non-linear (kernel) SVM, gradient boosting, random
//! forest and MLP classifiers; Bayesian ridge, lasso, LARS, decision
//! tree, random forest and MLP regressors — plus metrics, splitting,
//! scaling, and the Table 6 baselines.

pub mod baselines;
pub mod boosting;
pub mod centroid;
pub mod forest;
pub mod linalg;
pub mod linear;
pub mod metrics;
pub mod mlp;
pub mod scaler;
pub mod split;
pub mod svm;
pub mod tree;

/// Multi-class classifier interface (labels are dense 0..k).
pub trait Classifier {
    fn fit(&mut self, x: &[Vec<f64>], y: &[usize]);
    fn predict_one(&self, x: &[f64]) -> usize;

    fn predict(&self, x: &[Vec<f64>]) -> Vec<usize> {
        x.iter().map(|r| self.predict_one(r)).collect()
    }
}

/// Scalar regressor interface.
pub trait Regressor {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]);
    fn predict_one(&self, x: &[f64]) -> f64;

    fn predict(&self, x: &[Vec<f64>]) -> Vec<f64> {
        x.iter().map(|r| self.predict_one(r)).collect()
    }
}

/// Number of classes implied by a label vector.
pub fn n_classes(y: &[usize]) -> usize {
    y.iter().copied().max().map_or(0, |m| m + 1)
}

#[cfg(test)]
pub(crate) mod testdata {
    use crate::gen::Rng;

    /// Three Gaussian blobs in 2-D — linearly separable-ish.
    pub fn blobs(n_per: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let centers = [(0.0, 0.0), (4.0, 4.0), (0.0, 5.0)];
        let mut x = Vec::new();
        let mut y = Vec::new();
        for (c, &(cx, cy)) in centers.iter().enumerate() {
            for _ in 0..n_per {
                x.push(vec![cx + 0.6 * rng.normal(), cy + 0.6 * rng.normal()]);
                y.push(c);
            }
        }
        (x, y)
    }

    /// XOR — requires a non-linear decision boundary.
    pub fn xor(n_per: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for q in 0..4usize {
            let (sx, sy) = (if q & 1 == 0 { -1.0 } else { 1.0f64 }, if q & 2 == 0 { -1.0 } else { 1.0f64 });
            for _ in 0..n_per {
                x.push(vec![sx * (1.0 + 0.3 * rng.normal().abs()), sy * (1.0 + 0.3 * rng.normal().abs())]);
                y.push(((q & 1) ^ ((q >> 1) & 1)) as usize);
            }
        }
        (x, y)
    }

    /// y = smooth nonlinear function of 2 features + small noise.
    pub fn friedman(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.f64() * 4.0 - 2.0;
            let b = rng.f64() * 4.0 - 2.0;
            x.push(vec![a, b]);
            y.push((a * 2.0).sin() + 0.5 * b * b + 0.05 * rng.normal());
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn n_classes_from_labels() {
        assert_eq!(super::n_classes(&[0, 2, 1, 2]), 3);
        assert_eq!(super::n_classes(&[]), 0);
    }
}
