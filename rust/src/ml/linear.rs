//! Linear regressors of Table 4: Bayesian ridge, Lasso (coordinate
//! descent), and LARS (forward stepwise with least-squares refits).

use super::linalg::{dot, ridge_solve};
use super::Regressor;

/// Bayesian ridge regression: ridge with evidence-style iterative
/// re-estimation of the precision ratio (alpha/lambda), per sklearn's
/// BayesianRidge (n_iter=300, tol=1e-3 in Table 4).
#[derive(Debug, Clone)]
pub struct BayesianRidge {
    pub n_iter: usize,
    pub tol: f64,
    pub w: Vec<f64>,
    pub b: f64,
}

impl Default for BayesianRidge {
    fn default() -> Self {
        BayesianRidge { n_iter: 300, tol: 1e-3, w: Vec::new(), b: 0.0 }
    }
}

impl Regressor for BayesianRidge {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert!(!x.is_empty());
        let n = x.len() as f64;
        let mut lambda = 1.0; // effective ridge strength
        let (mut w, mut b) = ridge_solve(x, y, lambda);
        for _ in 0..self.n_iter {
            // residual variance and weight norm drive the update
            let sse: f64 = x
                .iter()
                .zip(y)
                .map(|(r, &t)| {
                    let p = dot(&w, r) + b;
                    (p - t) * (p - t)
                })
                .sum();
            let wnorm: f64 = w.iter().map(|v| v * v).sum();
            let noise_var = (sse / n).max(1e-12);
            let weight_var = (wnorm / w.len().max(1) as f64).max(1e-12);
            let new_lambda = (noise_var / weight_var).clamp(1e-8, 1e8);
            if (new_lambda - lambda).abs() / lambda.max(1e-12) < self.tol {
                lambda = new_lambda;
                break;
            }
            lambda = new_lambda;
            let sol = ridge_solve(x, y, lambda);
            w = sol.0;
            b = sol.1;
        }
        let sol = ridge_solve(x, y, lambda);
        self.w = sol.0;
        self.b = sol.1;
    }

    fn predict_one(&self, x: &[f64]) -> f64 {
        dot(&self.w, x) + self.b
    }
}

/// Lasso via cyclic coordinate descent (Table 4: alpha=1.0, 1000 epochs).
#[derive(Debug, Clone)]
pub struct Lasso {
    pub alpha: f64,
    pub epochs: usize,
    pub w: Vec<f64>,
    pub b: f64,
}

impl Default for Lasso {
    fn default() -> Self {
        Lasso { alpha: 1.0, epochs: 1000, w: Vec::new(), b: 0.0 }
    }
}

fn soft_threshold(z: f64, g: f64) -> f64 {
    if z > g {
        z - g
    } else if z < -g {
        z + g
    } else {
        0.0
    }
}

impl Regressor for Lasso {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert!(!x.is_empty());
        let n = x.len();
        let d = x[0].len();
        self.w = vec![0.0; d];
        self.b = y.iter().sum::<f64>() / n as f64;
        // column norms
        let col_sq: Vec<f64> = (0..d)
            .map(|j| x.iter().map(|r| r[j] * r[j]).sum::<f64>())
            .collect();
        let mut resid: Vec<f64> = x
            .iter()
            .zip(y)
            .map(|(r, &t)| t - self.b - dot(&self.w, r))
            .collect();
        for _ in 0..self.epochs {
            let mut max_change = 0.0f64;
            for j in 0..d {
                if col_sq[j] < 1e-12 {
                    continue;
                }
                let wj = self.w[j];
                // rho = x_j . (resid + wj * x_j)
                let rho: f64 =
                    x.iter().zip(&resid).map(|(r, &e)| r[j] * (e + wj * r[j])).sum();
                let new_wj = soft_threshold(rho, self.alpha * n as f64) / col_sq[j];
                if new_wj != wj {
                    let delta = new_wj - wj;
                    for (e, r) in resid.iter_mut().zip(x) {
                        *e -= delta * r[j];
                    }
                    self.w[j] = new_wj;
                    max_change = max_change.max(delta.abs());
                }
            }
            // refit intercept
            let mean_resid = resid.iter().sum::<f64>() / n as f64;
            if mean_resid.abs() > 1e-12 {
                self.b += mean_resid;
                for e in &mut resid {
                    *e -= mean_resid;
                }
            }
            if max_change < 1e-9 {
                break;
            }
        }
    }

    fn predict_one(&self, x: &[f64]) -> f64 {
        dot(&self.w, x) + self.b
    }
}

/// LARS approximated as forward stepwise selection with exact
/// least-squares refits on the active set (Table 4: up to 500 non-zero
/// coefficients — here bounded by the feature count).
#[derive(Debug, Clone)]
pub struct Lars {
    pub max_nonzero: usize,
    pub w: Vec<f64>,
    pub b: f64,
}

impl Default for Lars {
    fn default() -> Self {
        Lars { max_nonzero: 500, w: Vec::new(), b: 0.0 }
    }
}

impl Regressor for Lars {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert!(!x.is_empty());
        let n = x.len();
        let d = x[0].len();
        self.w = vec![0.0; d];
        self.b = y.iter().sum::<f64>() / n as f64;
        let mut active: Vec<usize> = Vec::new();
        let mut resid: Vec<f64> = y.iter().map(|&t| t - self.b).collect();
        for _ in 0..self.max_nonzero.min(d) {
            // most correlated inactive feature
            let mut best: Option<(f64, usize)> = None;
            for j in 0..d {
                if active.contains(&j) {
                    continue;
                }
                let c: f64 = x.iter().zip(&resid).map(|(r, &e)| r[j] * e).sum();
                if best.map_or(true, |(bc, _)| c.abs() > bc) {
                    best = Some((c.abs(), j));
                }
            }
            let Some((corr, j)) = best else { break };
            if corr < 1e-9 {
                break;
            }
            active.push(j);
            // least-squares refit on active set
            let xa: Vec<Vec<f64>> =
                x.iter().map(|r| active.iter().map(|&a| r[a]).collect()).collect();
            let (wa, ba) = ridge_solve(&xa, y, 1e-10);
            self.w = vec![0.0; d];
            for (k, &a) in active.iter().enumerate() {
                self.w[a] = wa[k];
            }
            self.b = ba;
            for (e, (r, &t)) in resid.iter_mut().zip(x.iter().zip(y)) {
                *e = t - self.b - dot(&self.w, r);
            }
        }
    }

    fn predict_one(&self, x: &[f64]) -> f64 {
        dot(&self.w, x) + self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::Rng;
    use crate::ml::metrics::r2;
    use crate::ml::Regressor;

    fn linear_data(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.normal();
            let b = rng.normal();
            let c = rng.normal(); // irrelevant feature
            x.push(vec![a, b, c]);
            y.push(2.0 * a - 1.0 * b + 3.0 + 0.01 * rng.normal());
        }
        (x, y)
    }

    #[test]
    fn bayesian_ridge_recovers_weights() {
        let (x, y) = linear_data(200, 31);
        let mut m = BayesianRidge::default();
        m.fit(&x, &y);
        assert!((m.w[0] - 2.0).abs() < 0.05, "{:?}", m.w);
        assert!((m.w[1] + 1.0).abs() < 0.05);
        assert!(r2(&y, &m.predict(&x)) > 0.99);
    }

    #[test]
    fn lasso_sparsifies_irrelevant_feature() {
        let (x, y) = linear_data(200, 32);
        let mut m = Lasso { alpha: 0.05, ..Default::default() };
        m.fit(&x, &y);
        assert!(m.w[2].abs() < 0.05, "irrelevant weight should shrink: {:?}", m.w);
        assert!(r2(&y, &m.predict(&x)) > 0.95);
    }

    #[test]
    fn strong_lasso_kills_everything() {
        let (x, y) = linear_data(100, 33);
        let mut m = Lasso { alpha: 1e3, ..Default::default() };
        m.fit(&x, &y);
        assert!(m.w.iter().all(|w| w.abs() < 1e-9));
    }

    #[test]
    fn lars_selects_in_correlation_order() {
        let (x, y) = linear_data(200, 34);
        let mut m = Lars { max_nonzero: 2, ..Default::default() };
        m.fit(&x, &y);
        // with 2 slots it should pick features 0 and 1, not 2
        assert!(m.w[2].abs() < 1e-9, "{:?}", m.w);
        assert!(r2(&y, &m.predict(&x)) > 0.99);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
    }
}
