//! Minimal dense linear algebra for the linear models: symmetric solves
//! via Cholesky with ridge jitter. Matrices are small (d <= a few hundred).

/// Row-major square matrix wrapper for solves.
pub fn cholesky_solve(a: &[Vec<f64>], b: &[f64]) -> Option<Vec<f64>> {
    let n = b.len();
    debug_assert_eq!(a.len(), n);
    // decompose a = L L^T
    let mut l = vec![vec![0.0f64; n]; n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i][j];
            for k in 0..j {
                s -= l[i][k] * l[j][k];
            }
            if i == j {
                if s <= 0.0 {
                    return None; // not positive definite
                }
                l[i][j] = s.sqrt();
            } else {
                l[i][j] = s / l[j][j];
            }
        }
    }
    // forward: L z = b
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i][k] * z[k];
        }
        z[i] = s / l[i][i];
    }
    // back: L^T x = z
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for k in i + 1..n {
            s -= l[k][i] * x[k];
        }
        x[i] = s / l[i][i];
    }
    Some(x)
}

/// Solve the ridge normal equations (X^T X + lambda I) w = X^T y with a
/// bias column appended. Returns (weights, bias).
pub fn ridge_solve(x: &[Vec<f64>], y: &[f64], lambda: f64) -> (Vec<f64>, f64) {
    let n = x.len();
    let d = if n == 0 { 0 } else { x[0].len() };
    let dd = d + 1; // + bias
    let mut xtx = vec![vec![0.0f64; dd]; dd];
    let mut xty = vec![0.0f64; dd];
    for (row, &t) in x.iter().zip(y) {
        for i in 0..d {
            for j in 0..=i {
                xtx[i][j] += row[i] * row[j];
            }
            xtx[d][i] += row[i]; // bias x feature
            xty[i] += row[i] * t;
        }
        xtx[d][d] += 1.0;
        xty[d] += t;
    }
    // symmetrize + regularize (bias unregularized)
    for i in 0..dd {
        for j in i + 1..dd {
            xtx[i][j] = xtx[j][i];
        }
    }
    for (i, row) in xtx.iter_mut().enumerate().take(d) {
        row[i] += lambda;
    }
    // jitter until PD
    let mut jitter = 1e-10;
    loop {
        if let Some(sol) = cholesky_solve(&xtx, &xty) {
            let (w, b) = sol.split_at(d);
            return (w.to_vec(), b[0]);
        }
        for i in 0..dd {
            xtx[i][i] += jitter;
        }
        jitter *= 10.0;
        if jitter > 1.0 {
            return (vec![0.0; d], y.iter().sum::<f64>() / n.max(1) as f64);
        }
    }
}

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_solves_spd() {
        // A = [[4,2],[2,3]], b = [10, 9] -> x = [1.5, 2]
        let a = vec![vec![4.0, 2.0], vec![2.0, 3.0]];
        let x = cholesky_solve(&a, &[10.0, 9.0]).unwrap();
        assert!((x[0] - 1.5).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![vec![0.0, 1.0], vec![1.0, 0.0]];
        assert!(cholesky_solve(&a, &[1.0, 1.0]).is_none());
    }

    #[test]
    fn ridge_recovers_linear_function() {
        // y = 3 x0 - 2 x1 + 1
        let x: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![(i % 7) as f64, (i % 5) as f64])
            .collect();
        let y: Vec<f64> = x.iter().map(|r| 3.0 * r[0] - 2.0 * r[1] + 1.0).collect();
        let (w, b) = ridge_solve(&x, &y, 1e-8);
        assert!((w[0] - 3.0).abs() < 1e-5, "{w:?}");
        assert!((w[1] + 2.0).abs() < 1e-5);
        assert!((b - 1.0).abs() < 1e-4);
    }

    #[test]
    fn dot_product() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }
}
