//! Multi-layer perceptron — Table 1 hyperparameters: hidden size
//! {20..200}, depth {1..10}, activation {identity, logistic, tanh, relu};
//! Table 4: ReLU, 5 layers x 100/200 nodes, Adam, lr 1e-3/1e-4.
//!
//! One implementation serves both tasks: softmax + cross-entropy head for
//! classification, linear + MSE head for regression.

use super::{Classifier, Regressor};
use crate::gen::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Identity,
    Logistic,
    Tanh,
    Relu,
}

impl Activation {
    pub const ALL: [Activation; 4] =
        [Activation::Identity, Activation::Logistic, Activation::Tanh, Activation::Relu];

    pub fn name(self) -> &'static str {
        match self {
            Activation::Identity => "identity",
            Activation::Logistic => "logistic",
            Activation::Tanh => "tanh",
            Activation::Relu => "relu",
        }
    }

    fn f(self, x: f64) -> f64 {
        match self {
            Activation::Identity => x,
            Activation::Logistic => 1.0 / (1.0 + (-x).exp()),
            Activation::Tanh => x.tanh(),
            Activation::Relu => x.max(0.0),
        }
    }

    /// Derivative expressed in terms of the activation output `a`.
    fn df(self, a: f64) -> f64 {
        match self {
            Activation::Identity => 1.0,
            Activation::Logistic => a * (1.0 - a),
            Activation::Tanh => 1.0 - a * a,
            Activation::Relu => {
                if a > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// Dense layer with Adam state.
#[derive(Debug, Clone)]
pub struct Layer {
    w: Vec<f64>, // (out, in) row-major
    b: Vec<f64>,
    n_in: usize,
    n_out: usize,
    // Adam moments
    mw: Vec<f64>,
    vw: Vec<f64>,
    mb: Vec<f64>,
    vb: Vec<f64>,
}

impl Layer {
    fn new(n_in: usize, n_out: usize, rng: &mut Rng) -> Self {
        let scale = (2.0 / (n_in + n_out) as f64).sqrt();
        let w = (0..n_in * n_out).map(|_| rng.normal() * scale).collect();
        Layer {
            w,
            b: vec![0.0; n_out],
            n_in,
            n_out,
            mw: vec![0.0; n_in * n_out],
            vw: vec![0.0; n_in * n_out],
            mb: vec![0.0; n_out],
            vb: vec![0.0; n_out],
        }
    }

    fn forward(&self, x: &[f64], out: &mut Vec<f64>) {
        out.clear();
        for o in 0..self.n_out {
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            out.push(self.b[o] + row.iter().zip(x).map(|(a, b)| a * b).sum::<f64>());
        }
    }
}

const BETA1: f64 = 0.9;
const BETA2: f64 = 0.999;
const EPS: f64 = 1e-8;

/// Core network shared by both heads.
#[derive(Debug, Clone)]
pub struct Net {
    layers: Vec<Layer>,
    act: Activation,
    t: u64,
}

impl Net {
    fn new(dims: &[usize], act: Activation, rng: &mut Rng) -> Self {
        let layers = dims.windows(2).map(|w| Layer::new(w[0], w[1], rng)).collect();
        Net { layers, act, t: 0 }
    }

    /// Forward pass keeping activations; hidden layers use `act`, the
    /// final layer is linear (head applied by caller).
    fn forward(&self, x: &[f64]) -> Vec<Vec<f64>> {
        let mut acts = vec![x.to_vec()];
        let mut buf = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            layer.forward(acts.last().unwrap(), &mut buf);
            let mut a = std::mem::take(&mut buf);
            if li + 1 < self.layers.len() {
                for v in &mut a {
                    *v = self.act.f(*v);
                }
            }
            acts.push(a);
        }
        acts
    }

    /// Backprop one sample given output-layer delta; Adam update.
    fn backward(&mut self, acts: &[Vec<f64>], mut delta: Vec<f64>, lr: f64) {
        self.t += 1;
        let bc1 = 1.0 - BETA1.powi(self.t as i32);
        let bc2 = 1.0 - BETA2.powi(self.t as i32);
        for li in (0..self.layers.len()).rev() {
            let input = &acts[li];
            // next delta (before this layer's update)
            let prev_delta: Option<Vec<f64>> = if li > 0 {
                let layer = &self.layers[li];
                let mut pd = vec![0.0; layer.n_in];
                for o in 0..layer.n_out {
                    let row = &layer.w[o * layer.n_in..(o + 1) * layer.n_in];
                    for (p, wv) in pd.iter_mut().zip(row) {
                        *p += wv * delta[o];
                    }
                }
                for (p, a) in pd.iter_mut().zip(&acts[li]) {
                    *p *= self.act.df(*a);
                }
                Some(pd)
            } else {
                None
            };
            let layer = &mut self.layers[li];
            for o in 0..layer.n_out {
                let g_b = delta[o];
                layer.mb[o] = BETA1 * layer.mb[o] + (1.0 - BETA1) * g_b;
                layer.vb[o] = BETA2 * layer.vb[o] + (1.0 - BETA2) * g_b * g_b;
                layer.b[o] -= lr * (layer.mb[o] / bc1) / ((layer.vb[o] / bc2).sqrt() + EPS);
                let base = o * layer.n_in;
                for i in 0..layer.n_in {
                    let g = g_b * input[i];
                    let idx = base + i;
                    layer.mw[idx] = BETA1 * layer.mw[idx] + (1.0 - BETA1) * g;
                    layer.vw[idx] = BETA2 * layer.vw[idx] + (1.0 - BETA2) * g * g;
                    layer.w[idx] -=
                        lr * (layer.mw[idx] / bc1) / ((layer.vw[idx] / bc2).sqrt() + EPS);
                }
            }
            if let Some(pd) = prev_delta {
                delta = pd;
            }
        }
    }
}

fn softmax(z: &[f64]) -> Vec<f64> {
    let m = z.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let e: Vec<f64> = z.iter().map(|v| (v - m).exp()).collect();
    let s: f64 = e.iter().sum();
    e.into_iter().map(|v| v / s).collect()
}

/// MLP classifier (softmax head, cross-entropy loss, Adam).
#[derive(Debug, Clone)]
pub struct MlpClassifier {
    pub hidden: Vec<usize>,
    pub activation: Activation,
    pub epochs: usize,
    pub lr: f64,
    pub seed: u64,
    pub net: Option<Net>,
    pub n_classes: usize,
}

impl Default for MlpClassifier {
    fn default() -> Self {
        // paper Table 4: 5 layers x 100 nodes, ReLU, Adam, lr=1e-3, 200 epochs
        MlpClassifier {
            hidden: vec![100; 5],
            activation: Activation::Relu,
            epochs: 200,
            lr: 1e-3,
            seed: 0,
            net: None,
            n_classes: 0,
        }
    }
}

impl Classifier for MlpClassifier {
    fn fit(&mut self, x: &[Vec<f64>], y: &[usize]) {
        assert!(!x.is_empty());
        self.n_classes = super::n_classes(y).max(2);
        let mut dims = vec![x[0].len()];
        dims.extend_from_slice(&self.hidden);
        dims.push(self.n_classes);
        let mut rng = Rng::new(self.seed ^ 0x313A55);
        let mut net = Net::new(&dims, self.activation, &mut rng);
        let n = x.len();
        for _ in 0..self.epochs {
            for _ in 0..n {
                let i = rng.below(n);
                let acts = net.forward(&x[i]);
                let probs = softmax(acts.last().unwrap());
                let mut delta = probs;
                delta[y[i]] -= 1.0; // dCE/dz
                net.backward(&acts, delta, self.lr);
            }
        }
        self.net = Some(net);
    }

    fn predict_one(&self, x: &[f64]) -> usize {
        let net = self.net.as_ref().expect("fit first");
        let acts = net.forward(x);
        let z = acts.last().unwrap();
        z.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// MLP regressor (linear head, MSE loss, Adam).
#[derive(Debug, Clone)]
pub struct MlpRegressor {
    pub hidden: Vec<usize>,
    pub activation: Activation,
    pub epochs: usize,
    pub lr: f64,
    pub seed: u64,
    pub net: Option<Net>,
}

impl Default for MlpRegressor {
    fn default() -> Self {
        // paper Table 4: 5 layers x 200 nodes, ReLU, Adam, lr=1e-4
        MlpRegressor {
            hidden: vec![200; 5],
            activation: Activation::Relu,
            epochs: 200,
            lr: 1e-4,
            seed: 0,
            net: None,
        }
    }
}

impl Regressor for MlpRegressor {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert!(!x.is_empty());
        let mut dims = vec![x[0].len()];
        dims.extend_from_slice(&self.hidden);
        dims.push(1);
        let mut rng = Rng::new(self.seed ^ 0x313A66);
        let mut net = Net::new(&dims, self.activation, &mut rng);
        let n = x.len();
        for _ in 0..self.epochs {
            for _ in 0..n {
                let i = rng.below(n);
                let acts = net.forward(&x[i]);
                let pred = acts.last().unwrap()[0];
                let delta = vec![pred - y[i]]; // dMSE/2 dz
                net.backward(&acts, delta, self.lr);
            }
        }
        self.net = Some(net);
    }

    fn predict_one(&self, x: &[f64]) -> f64 {
        let net = self.net.as_ref().expect("fit first");
        net.forward(x).last().unwrap()[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::{accuracy, r2};
    use crate::ml::testdata;

    #[test]
    fn mlp_solves_xor() {
        let (x, y) = testdata::xor(40, 15);
        let mut m = MlpClassifier {
            hidden: vec![16, 16],
            epochs: 120,
            lr: 5e-3,
            ..Default::default()
        };
        m.fit(&x, &y);
        let acc = accuracy(&y, &m.predict(&x));
        assert!(acc > 0.95, "xor acc {acc}");
    }

    #[test]
    fn mlp_classifies_blobs() {
        let (x, y) = testdata::blobs(30, 16);
        let mut m = MlpClassifier { hidden: vec![20], epochs: 80, lr: 5e-3, ..Default::default() };
        m.fit(&x, &y);
        assert!(accuracy(&y, &m.predict(&x)) > 0.95);
    }

    #[test]
    fn mlp_regresses() {
        let (x, y) = testdata::friedman(300, 17);
        let mut m = MlpRegressor {
            hidden: vec![32, 32],
            epochs: 150,
            lr: 3e-3,
            ..Default::default()
        };
        m.fit(&x, &y);
        let score = r2(&y, &m.predict(&x));
        assert!(score > 0.9, "r2 {score}");
    }

    #[test]
    fn activations_all_run() {
        let (x, y) = testdata::blobs(15, 18);
        for a in Activation::ALL {
            let mut m = MlpClassifier {
                hidden: vec![12],
                activation: a,
                epochs: 60,
                lr: 5e-3,
                ..Default::default()
            };
            m.fit(&x, &y);
            // identity can only do linear boundaries but blobs are separable
            assert!(accuracy(&y, &m.predict(&x)) > 0.8, "{}", a.name());
        }
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn activation_derivatives_match_definition() {
        for a in Activation::ALL {
            let x = 0.3;
            let fx = a.f(x);
            let eps = 1e-6;
            let num = (a.f(x + eps) - a.f(x - eps)) / (2.0 * eps);
            assert!((a.df(fx) - num).abs() < 1e-5, "{}", a.name());
        }
    }
}
