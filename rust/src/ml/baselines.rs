//! State-of-the-art baselines reproduced for Table 6:
//! * BestSF [78] — a single RBF-SVM trained with default hyperparameters;
//! * Dufrechou et al. [74] — a bagged-trees classifier;
//! * Zhao et al. [32] — a CNN on density images; proxied here by an MLP
//!   over the same sparsity features (the paper's table only compares
//!   accuracy, and this environment's input is the feature vector).

use super::forest::RandomForestClassifier;
use super::mlp::MlpClassifier;
use super::svm::{Kernel, SvmClassifier};
use super::Classifier;

/// BestSF-style single SVM (no AutoML tuning — that is the point of the
/// comparison).
pub fn bestsf_svm(x_train: &[Vec<f64>]) -> SvmClassifier {
    SvmClassifier {
        kernel: Kernel::Rbf { gamma: SvmClassifier::gamma_scale(x_train) },
        c: 1.0,
        epochs: 40,
        seed: 78,
        ..Default::default()
    }
}

/// Bagged-trees classifier: bootstrap aggregation WITHOUT feature
/// subsampling (the distinction from a random forest).
pub fn bagged_trees() -> RandomForestClassifier {
    RandomForestClassifier {
        n_estimators: 50,
        max_features: Some(usize::MAX), // all features at every split
        bootstrap: true,
        seed: 74,
        ..Default::default()
    }
}

/// CNN-proxy: a fixed-architecture MLP with default (untuned) learning
/// hyperparameters.
pub fn cnn_proxy() -> MlpClassifier {
    MlpClassifier {
        hidden: vec![64, 64, 32],
        epochs: 100,
        lr: 1e-3,
        seed: 32,
        ..Default::default()
    }
}

/// Named baseline set for the Table 6 bench.
pub fn all(x_train: &[Vec<f64>]) -> Vec<(&'static str, Box<dyn Classifier>)> {
    vec![
        ("BestSF (SVM)", Box::new(bestsf_svm(x_train))),
        ("Bagged Trees [74]", Box::new(bagged_trees())),
        ("CNN-proxy [32]", Box::new(cnn_proxy())),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;
    use crate::ml::testdata;

    #[test]
    fn baselines_all_learn_blobs() {
        let (x, y) = testdata::blobs(30, 41);
        for (name, mut model) in all(&x) {
            model.fit(&x, &y);
            let acc = accuracy(&y, &model.predict(&x));
            assert!(acc > 0.85, "{name}: {acc}");
        }
    }

    #[test]
    fn bagged_trees_uses_all_features() {
        let b = bagged_trees();
        assert_eq!(b.max_features, Some(usize::MAX));
        assert!(b.bootstrap);
    }
}
