//! Non-linear (kernel) SVM — Table 1 kernels: linear, poly, rbf, sigmoid.
//!
//! Trained as one-vs-rest kernel machines with Pegasos-style subgradient
//! descent on the hinge loss in the kernel expansion (each training point
//! carries a dual-ish coefficient). Equivalent decision family to SMO-
//! trained SVC; chosen for implementation economy and deterministic
//! behaviour. This is also the BestSF baseline model (Table 6).

use super::Classifier;
use crate::gen::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Kernel {
    Linear,
    /// Polynomial (gamma x.y + coef0)^degree.
    Poly { degree: u32, gamma: f64, coef0: f64 },
    /// RBF exp(-gamma ||x-y||^2).
    Rbf { gamma: f64 },
    /// tanh(gamma x.y + coef0).
    Sigmoid { gamma: f64, coef0: f64 },
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Linear => "linear",
            Kernel::Poly { .. } => "poly",
            Kernel::Rbf { .. } => "rbf",
            Kernel::Sigmoid { .. } => "sigmoid",
        }
    }

    fn eval(self, a: &[f64], b: &[f64]) -> f64 {
        let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        match self {
            Kernel::Linear => dot,
            Kernel::Poly { degree, gamma, coef0 } => (gamma * dot + coef0).powi(degree as i32),
            Kernel::Rbf { gamma } => {
                let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
                (-gamma * d2).exp()
            }
            Kernel::Sigmoid { gamma, coef0 } => (gamma * dot + coef0).tanh(),
        }
    }
}

/// One-vs-rest kernel SVM.
#[derive(Debug, Clone)]
pub struct SvmClassifier {
    pub kernel: Kernel,
    /// Regularization strength (sklearn's C; lambda = 1/(C n)).
    pub c: f64,
    pub epochs: usize,
    pub seed: u64,
    pub support: Vec<Vec<f64>>,
    /// alpha[class][support index].
    pub alpha: Vec<Vec<f64>>,
    pub bias: Vec<f64>,
    pub n_classes: usize,
}

impl Default for SvmClassifier {
    fn default() -> Self {
        // paper Table 4: kernel=rbf, C=1.0, gamma=scale
        SvmClassifier {
            kernel: Kernel::Rbf { gamma: 0.5 },
            c: 1.0,
            epochs: 40,
            seed: 0,
            support: Vec::new(),
            alpha: Vec::new(),
            bias: Vec::new(),
            n_classes: 0,
        }
    }
}

impl SvmClassifier {
    /// sklearn's gamma="scale": 1 / (d * Var(X)).
    pub fn gamma_scale(x: &[Vec<f64>]) -> f64 {
        let n = x.len();
        if n == 0 {
            return 1.0;
        }
        let d = x[0].len();
        let mut mean = vec![0.0; d];
        for r in x {
            for (m, v) in mean.iter_mut().zip(r) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        let mut var = 0.0;
        for r in x {
            for j in 0..d {
                var += (r[j] - mean[j]) * (r[j] - mean[j]);
            }
        }
        var /= (n * d) as f64;
        if var < 1e-12 {
            1.0
        } else {
            1.0 / (d as f64 * var)
        }
    }

    fn decision(&self, cls: usize, x: &[f64]) -> f64 {
        let mut s = self.bias[cls];
        for (sv, &a) in self.support.iter().zip(&self.alpha[cls]) {
            if a != 0.0 {
                s += a * self.kernel.eval(sv, x);
            }
        }
        s
    }
}

impl Classifier for SvmClassifier {
    fn fit(&mut self, x: &[Vec<f64>], y: &[usize]) {
        assert!(!x.is_empty());
        let n = x.len();
        self.n_classes = super::n_classes(y);
        self.support = x.to_vec();
        self.alpha = vec![vec![0.0; n]; self.n_classes];
        self.bias = vec![0.0; self.n_classes];

        // precompute kernel matrix (datasets here are small: n <= ~2k)
        let mut k = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let v = self.kernel.eval(&x[i], &x[j]);
                k[i * n + j] = v;
                k[j * n + i] = v;
            }
        }

        let lambda = 1.0 / (self.c * n as f64);
        let mut rng = Rng::new(self.seed ^ 0x5F11);
        for cls in 0..self.n_classes {
            let targets: Vec<f64> =
                y.iter().map(|&c| if c == cls { 1.0 } else { -1.0 }).collect();
            let alpha = &mut self.alpha[cls];
            let bias = &mut self.bias[cls];
            let mut t = 0usize;
            for _ in 0..self.epochs {
                for _ in 0..n {
                    t += 1;
                    let i = rng.below(n);
                    let eta = 1.0 / (lambda * t as f64);
                    // margin of sample i under current expansion
                    let mut m = *bias;
                    for j in 0..n {
                        if alpha[j] != 0.0 {
                            m += alpha[j] * k[j * n + i];
                        }
                    }
                    // decay (regularization applies to all coefficients)
                    let decay = 1.0 - eta * lambda;
                    for a in alpha.iter_mut() {
                        *a *= decay;
                    }
                    if targets[i] * m < 1.0 {
                        alpha[i] += eta * targets[i] / n as f64;
                        *bias += eta * targets[i] * 0.01;
                    }
                }
            }
        }
    }

    fn predict_one(&self, x: &[f64]) -> usize {
        (0..self.n_classes)
            .max_by(|&a, &b| self.decision(a, x).partial_cmp(&self.decision(b, x)).unwrap())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;
    use crate::ml::testdata;

    #[test]
    fn rbf_solves_xor() {
        let (x, y) = testdata::xor(30, 13);
        let mut s = SvmClassifier {
            kernel: Kernel::Rbf { gamma: 1.0 },
            epochs: 60,
            ..Default::default()
        };
        s.fit(&x, &y);
        let acc = accuracy(&y, &s.predict(&x));
        assert!(acc > 0.9, "rbf xor acc {acc}");
    }

    #[test]
    fn linear_separates_blobs() {
        let (x, y) = testdata::blobs(30, 14);
        let mut s = SvmClassifier { kernel: Kernel::Linear, epochs: 60, ..Default::default() };
        s.fit(&x, &y);
        assert!(accuracy(&y, &s.predict(&x)) > 0.9);
    }

    #[test]
    fn kernel_evals() {
        assert_eq!(Kernel::Linear.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        let p = Kernel::Poly { degree: 2, gamma: 1.0, coef0: 1.0 }.eval(&[1.0], &[2.0]);
        assert_eq!(p, 9.0);
        let r = Kernel::Rbf { gamma: 1.0 }.eval(&[0.0], &[0.0]);
        assert_eq!(r, 1.0);
        let s = Kernel::Sigmoid { gamma: 1.0, coef0: 0.0 }.eval(&[1.0], &[0.0]);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn gamma_scale_sane() {
        let x = vec![vec![0.0, 0.0], vec![2.0, 2.0]];
        let g = SvmClassifier::gamma_scale(&x);
        assert!(g > 0.0 && g.is_finite());
        // variance per spec: mean=1, var=1 over all entries -> 1/(2*1)
        assert!((g - 0.5).abs() < 1e-9, "{g}");
    }
}
