//! Gradient boosting — Table 1: {50..200} estimators, lr {0.1, 0.01,
//! 0.001}. One-vs-rest GBDT on the logistic loss (classification) and
//! least-squares GBDT (regression), with shallow CART regressors as the
//! weak learners.

use super::tree::DecisionTreeRegressor;
use super::{Classifier, Regressor};

/// Gradient-boosted trees, one-vs-rest logistic.
#[derive(Debug, Clone)]
pub struct GradientBoostingClassifier {
    pub n_estimators: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
    pub seed: u64,
    /// ensembles[class] = (prior, trees)
    pub ensembles: Vec<(f64, Vec<DecisionTreeRegressor>)>,
    pub n_classes: usize,
}

impl Default for GradientBoostingClassifier {
    fn default() -> Self {
        GradientBoostingClassifier {
            n_estimators: 100,
            learning_rate: 0.1,
            max_depth: 3,
            seed: 0,
            ensembles: Vec::new(),
            n_classes: 0,
        }
    }
}

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

impl GradientBoostingClassifier {
    fn raw_score(&self, cls: usize, x: &[f64]) -> f64 {
        let (prior, trees) = &self.ensembles[cls];
        let mut s = *prior;
        for t in trees {
            s += self.learning_rate * t.predict_one(x);
        }
        s
    }
}

impl Classifier for GradientBoostingClassifier {
    fn fit(&mut self, x: &[Vec<f64>], y: &[usize]) {
        assert!(!x.is_empty());
        self.n_classes = super::n_classes(y);
        self.ensembles.clear();
        let n = x.len();
        for cls in 0..self.n_classes {
            let t: Vec<f64> = y.iter().map(|&c| if c == cls { 1.0 } else { 0.0 }).collect();
            let p0 = (t.iter().sum::<f64>() / n as f64).clamp(1e-6, 1.0 - 1e-6);
            let prior = (p0 / (1.0 - p0)).ln();
            let mut raw = vec![prior; n];
            let mut trees = Vec::with_capacity(self.n_estimators);
            for e in 0..self.n_estimators {
                // negative gradient of logistic loss: t - sigmoid(raw)
                let resid: Vec<f64> =
                    raw.iter().zip(&t).map(|(&r, &ti)| ti - sigmoid(r)).collect();
                let mut tree = DecisionTreeRegressor {
                    max_depth: self.max_depth,
                    seed: self.seed.wrapping_add((cls * 1000 + e) as u64),
                    ..Default::default()
                };
                tree.fit(x, &resid);
                for (r, row) in raw.iter_mut().zip(x) {
                    *r += self.learning_rate * tree.predict_one(row);
                }
                trees.push(tree);
            }
            self.ensembles.push((prior, trees));
        }
    }

    fn predict_one(&self, x: &[f64]) -> usize {
        (0..self.n_classes)
            .max_by(|&a, &b| {
                self.raw_score(a, x).partial_cmp(&self.raw_score(b, x)).unwrap()
            })
            .unwrap_or(0)
    }
}

/// Least-squares gradient boosting (regression).
#[derive(Debug, Clone)]
pub struct GradientBoostingRegressor {
    pub n_estimators: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
    pub seed: u64,
    pub base: f64,
    pub trees: Vec<DecisionTreeRegressor>,
}

impl Default for GradientBoostingRegressor {
    fn default() -> Self {
        GradientBoostingRegressor {
            n_estimators: 100,
            learning_rate: 0.1,
            max_depth: 3,
            seed: 0,
            base: 0.0,
            trees: Vec::new(),
        }
    }
}

impl Regressor for GradientBoostingRegressor {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert!(!x.is_empty());
        self.base = y.iter().sum::<f64>() / y.len() as f64;
        let mut pred = vec![self.base; y.len()];
        self.trees.clear();
        for e in 0..self.n_estimators {
            let resid: Vec<f64> = y.iter().zip(&pred).map(|(t, p)| t - p).collect();
            let mut tree = DecisionTreeRegressor {
                max_depth: self.max_depth,
                seed: self.seed.wrapping_add(e as u64),
                ..Default::default()
            };
            tree.fit(x, &resid);
            for (p, row) in pred.iter_mut().zip(x) {
                *p += self.learning_rate * tree.predict_one(row);
            }
            self.trees.push(tree);
        }
    }

    fn predict_one(&self, x: &[f64]) -> f64 {
        self.base
            + self.learning_rate
                * self.trees.iter().map(|t| t.predict_one(x)).sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::{accuracy, r2};
    use crate::ml::testdata;

    #[test]
    fn gbdt_solves_xor() {
        let (x, y) = testdata::xor(40, 19);
        let mut g = GradientBoostingClassifier { n_estimators: 40, ..Default::default() };
        g.fit(&x, &y);
        assert!(accuracy(&y, &g.predict(&x)) > 0.95);
    }

    #[test]
    fn gbdt_classifies_blobs() {
        let (x, y) = testdata::blobs(30, 20);
        let mut g = GradientBoostingClassifier { n_estimators: 30, ..Default::default() };
        g.fit(&x, &y);
        assert!(accuracy(&y, &g.predict(&x)) > 0.95);
    }

    #[test]
    fn gbdt_regresses() {
        let (x, y) = testdata::friedman(300, 21);
        let mut g = GradientBoostingRegressor { n_estimators: 80, ..Default::default() };
        g.fit(&x, &y);
        let score = r2(&y, &g.predict(&x));
        assert!(score > 0.9, "r2 {score}");
    }

    #[test]
    fn more_estimators_fit_tighter() {
        let (x, y) = testdata::friedman(200, 22);
        let fit_r2 = |n_est: usize| {
            let mut g = GradientBoostingRegressor { n_estimators: n_est, ..Default::default() };
            g.fit(&x, &y);
            r2(&y, &g.predict(&x))
        };
        assert!(fit_r2(60) > fit_r2(5));
    }

    #[test]
    fn tiny_learning_rate_underfits() {
        let (x, y) = testdata::friedman(200, 23);
        let mut g = GradientBoostingRegressor {
            n_estimators: 10,
            learning_rate: 0.001,
            ..Default::default()
        };
        g.fit(&x, &y);
        assert!(r2(&y, &g.predict(&x)) < 0.5);
    }
}
