//! Prometheus text-exposition rendering (plus a report-table twin).
//!
//! Families are registered in insertion order; each renders a
//! `# HELP` / `# TYPE` pair followed by its samples. Log2 histograms
//! ([`HistSnapshot`]) render with cumulative `_bucket{le="..."}` counts
//! whose boundaries are the bucket upper edges (`2^b` ns) in seconds,
//! ending at `le="+Inf"`, then `_sum` (seconds) and `_count` — the
//! standard Prometheus histogram contract, so `histogram_quantile()`
//! works out of the box. The same family list renders a
//! `["metric", "labels", "value"]` [`Table`] for the repo's TSV/JSON
//! report pipeline. `tools/metrics_lint.py` checks the text form in CI.

use super::hist::{HistSnapshot, HIST_BUCKETS};
use crate::report::Table;
use std::fmt::Write as _;

/// Prometheus metric kinds we emit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    fn name(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// `true` iff `name` is a valid Prometheus metric name
/// (`[a-zA-Z_:][a-zA-Z0-9_:]*`).
pub fn valid_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// `true` iff `name` is a valid label name (`[a-zA-Z_][a-zA-Z0-9_]*`).
pub fn valid_label_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

struct Sample {
    /// `""`, `"_bucket"`, `"_sum"`, or `"_count"`.
    suffix: &'static str,
    labels: Vec<(String, String)>,
    value: f64,
}

struct Family {
    name: String,
    help: String,
    kind: MetricKind,
    samples: Vec<Sample>,
}

/// An ordered set of metric families under construction.
#[derive(Default)]
pub struct Metrics {
    families: Vec<Family>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { families: Vec::new() }
    }

    fn family(&mut self, name: &str, help: &str, kind: MetricKind) -> &mut Family {
        assert!(valid_metric_name(name), "invalid metric name {name}");
        if let Some(i) = self.families.iter().position(|f| f.name == name) {
            assert_eq!(self.families[i].kind, kind, "metric {name} re-registered as {kind:?}");
            return &mut self.families[i];
        }
        self.families.push(Family {
            name: name.to_string(),
            help: help.to_string(),
            kind,
            samples: Vec::new(),
        });
        self.families.last_mut().expect("just pushed")
    }

    /// A monotone counter (use `_total` names by convention).
    pub fn counter(&mut self, name: &str, help: &str, value: f64) {
        self.labeled_counter(name, help, &[], value);
    }

    /// A counter sample with labels; repeated calls with the same name
    /// accumulate samples under one family (one `# TYPE` line).
    pub fn labeled_counter(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, String)],
        value: f64,
    ) {
        let labels = own_labels(labels);
        self.family(name, help, MetricKind::Counter).samples.push(Sample {
            suffix: "",
            labels,
            value,
        });
    }

    /// An instantaneous gauge.
    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.labeled_gauge(name, help, &[], value);
    }

    /// A gauge sample with labels; repeated calls with the same name
    /// accumulate samples under one family (one `# TYPE` line).
    pub fn labeled_gauge(&mut self, name: &str, help: &str, labels: &[(&str, String)], value: f64) {
        let labels = own_labels(labels);
        self.family(name, help, MetricKind::Gauge).samples.push(Sample {
            suffix: "",
            labels,
            value,
        });
    }

    /// A log2 histogram as a Prometheus histogram (seconds).
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, String)],
        snap: &HistSnapshot,
    ) {
        let base = own_labels(labels);
        let family = self.family(name, help, MetricKind::Histogram);
        let mut cum = 0u64;
        for b in 0..HIST_BUCKETS {
            cum += snap.counts.get(b).copied().unwrap_or(0);
            let le = if b + 1 == HIST_BUCKETS {
                "+Inf".to_string()
            } else {
                // bucket b's upper edge is 2^b ns, rendered in seconds
                format!("{}", (1u64 << b) as f64 * 1e-9)
            };
            let mut labels = base.clone();
            labels.push(("le".to_string(), le));
            family.samples.push(Sample { suffix: "_bucket", labels, value: cum as f64 });
        }
        family.samples.push(Sample { suffix: "_sum", labels: base.clone(), value: snap.sum_s() });
        family.samples.push(Sample { suffix: "_count", labels: base, value: snap.count as f64 });
    }

    /// Render the Prometheus text-exposition document.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.families {
            let help = f.help.replace('\\', "\\\\").replace('\n', "\\n");
            writeln!(out, "# HELP {} {}", f.name, help).expect("string write");
            writeln!(out, "# TYPE {} {}", f.name, f.kind.name()).expect("string write");
            for s in &f.samples {
                out.push_str(&f.name);
                out.push_str(s.suffix);
                if !s.labels.is_empty() {
                    out.push('{');
                    for (i, (k, v)) in s.labels.iter().enumerate() {
                        debug_assert!(valid_label_name(k), "invalid label name {k}");
                        if i > 0 {
                            out.push(',');
                        }
                        write!(out, "{k}=\"{}\"", escape_label_value(v)).expect("string write");
                    }
                    out.push('}');
                }
                writeln!(out, " {}", s.value).expect("string write");
            }
        }
        out
    }

    /// The same samples as a `["metric", "labels", "value"]` table for
    /// the TSV/JSON report pipeline.
    pub fn to_table(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["metric", "labels", "value"]);
        for f in &self.families {
            for s in &f.samples {
                let labels = if s.labels.is_empty() {
                    "-".to_string()
                } else {
                    s.labels
                        .iter()
                        .map(|(k, v)| format!("{k}={v}"))
                        .collect::<Vec<_>>()
                        .join(",")
                };
                t.row(vec![format!("{}{}", f.name, s.suffix), labels, format!("{}", s.value)]);
            }
        }
        t
    }
}

fn own_labels(labels: &[(&str, String)]) -> Vec<(String, String)> {
    labels.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::Hist;
    use std::time::Duration;

    #[test]
    fn name_validation() {
        assert!(valid_metric_name("autospmv_requests_total"));
        assert!(valid_metric_name("_x:y9"));
        assert!(!valid_metric_name("9lives"));
        assert!(!valid_metric_name("has-dash"));
        assert!(!valid_metric_name(""));
        assert!(valid_label_name("matrix"));
        assert!(!valid_label_name("le:"));
    }

    #[test]
    fn counters_and_gauges_render_with_one_type_line_each() {
        let mut m = Metrics::new();
        m.counter("autospmv_requests_total", "Requests served.", 42.0);
        m.labeled_gauge("autospmv_matrix_requests", "Per-matrix.", &[("matrix", "0".into())], 7.0);
        m.labeled_gauge("autospmv_matrix_requests", "Per-matrix.", &[("matrix", "1".into())], 9.0);
        let text = m.render_text();
        assert!(text.contains("# TYPE autospmv_requests_total counter"), "{text}");
        assert!(text.contains("autospmv_requests_total 42"), "{text}");
        assert_eq!(text.matches("# TYPE autospmv_matrix_requests gauge").count(), 1, "{text}");
        assert!(text.contains("autospmv_matrix_requests{matrix=\"0\"} 7"), "{text}");
        assert!(text.contains("autospmv_matrix_requests{matrix=\"1\"} 9"), "{text}");
    }

    #[test]
    fn labeled_counters_share_one_family() {
        let mut m = Metrics::new();
        m.labeled_counter("arm_total", "Per-arm.", &[("format", "csr".into())], 3.0);
        m.labeled_counter("arm_total", "Per-arm.", &[("format", "ell".into())], 5.0);
        let text = m.render_text();
        assert_eq!(text.matches("# TYPE arm_total counter").count(), 1, "{text}");
        assert!(text.contains("arm_total{format=\"csr\"} 3"), "{text}");
        assert!(text.contains("arm_total{format=\"ell\"} 5"), "{text}");
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_inf() {
        let h = Hist::new();
        h.record(Duration::from_nanos(3)); // bucket 2: [2, 4) ns
        h.record(Duration::from_nanos(100)); // bucket 7: [64, 128) ns
        let mut m = Metrics::new();
        m.histogram("autospmv_stage_seconds", "Stage latency.", &[], &h.snapshot());
        let text = m.render_text();
        assert!(text.contains("# TYPE autospmv_stage_seconds histogram"), "{text}");
        // below bucket 2's edge: 0 observed; at/after: cumulative
        assert!(text.contains("autospmv_stage_seconds_bucket{le=\"0.000000002\"} 0"), "{text}");
        assert!(text.contains("autospmv_stage_seconds_bucket{le=\"0.000000004\"} 1"), "{text}");
        assert!(text.contains("autospmv_stage_seconds_bucket{le=\"0.000000128\"} 2"), "{text}");
        assert!(text.contains("autospmv_stage_seconds_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("autospmv_stage_seconds_count 2"), "{text}");
        // cumulative counts never decrease
        let mut last = 0.0f64;
        for line in text.lines().filter(|l| l.contains("_bucket{")) {
            let v: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "non-monotone bucket line: {line}");
            last = v;
        }
    }

    #[test]
    fn label_values_are_escaped_and_table_twin_matches() {
        let mut m = Metrics::new();
        m.labeled_gauge("g", "Gauge.", &[("name", "a\"b\\c".into())], 1.0);
        let text = m.render_text();
        assert!(text.contains("g{name=\"a\\\"b\\\\c\"} 1"), "{text}");
        let table = m.to_table("metrics");
        let json = table.to_json();
        assert!(json.contains("\"g\""), "{json}");
    }
}
