//! Per-arm cost attribution: request-weighted latency, gpusim-modeled
//! energy, average power, and efficiency keyed by the joint
//! (format, compile-knob) [`JointDecision`] — the paper's four headline
//! metrics, broken down by the arm that actually served the traffic.
//!
//! Shards call [`ArmAttr::record`] on every executed dispatch (a few
//! relaxed atomic adds — attribution is always on and must stay inside
//! the <3% tracing-overhead budget). On each router hot-swap the first
//! shard to notice the new version calls [`ArmAttr::mark_generation`],
//! which rolls a per-arm generation window and journals an
//! [`EventKind::ArmShift`] when an arm's mean modeled energy moved
//! beyond a threshold between generations — the modeled cost is
//! deterministic, so shift events are too.

use super::journal::{EventKind, Journal};
use crate::gpusim::Measurement;
use crate::online::bandit::N_ARMS;
use crate::online::JointDecision;
use crate::sparse::KernelKind;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Attribution cells: one per (kernel kind, joint arm) so SpMV and
/// solve (SpTRSV / SymGS) traffic never share a ledger row.
pub const N_CELLS: usize = KernelKind::N * N_ARMS;

/// Minimum requests an arm must serve inside a generation window before
/// its mean is considered evidence for an `ArmShift`.
pub const SHIFT_MIN_REQUESTS: u64 = 8;

/// Mean-energy ratio band (new/old) treated as "no shift".
const SHIFT_BAND: (f64, f64) = (0.8, 1.25);

/// One arm's totals (all relaxed atomics; power/efficiency are
/// request-weighted sums scaled by 1000 so means stay exact-ish in u64).
#[derive(Default)]
struct ArmCell {
    requests: AtomicU64,
    exec_ns: AtomicU64,
    energy_nj: AtomicU64,
    power_mw: AtomicU64,
    eff_x1000: AtomicU64,
}

/// Generation bookkeeping, touched only on hot-swap (cold path).
struct GenState {
    version: u64,
    /// Per-arm `(requests, energy_nj)` at the start of the current
    /// generation window.
    mark: Vec<(u64, u64)>,
    /// Per-arm mean energy (nJ/request) over the PREVIOUS window.
    prev_mean_nj: Vec<Option<f64>>,
}

/// One row of [`ArmAttr::snapshot`]: an arm that served traffic, with
/// the paper's four metrics attributed to it.
#[derive(Debug, Clone)]
pub struct ArmProfile {
    /// Kernel-kind label (`spmv`/`sptrsv`/`symgs`).
    pub kind: String,
    /// Sparse format name (`csr`/`ell`/...).
    pub format: String,
    /// Compile-knob label (`tb256/r64/default` style).
    pub knobs: String,
    /// Flat joint arm index (within the kind).
    pub arm: usize,
    pub requests: u64,
    /// Request-weighted exec time (seconds).
    pub exec_s: f64,
    /// Total gpusim-modeled energy (joules).
    pub energy_j: f64,
    /// Request-weighted mean power (watts).
    pub mean_power_w: f64,
    /// Request-weighted mean efficiency (MFLOPS/W).
    pub mflops_per_watt: f64,
}

/// Pool-wide per-arm accumulator shared by all shards via `Telemetry`.
pub struct ArmAttr {
    cells: Vec<ArmCell>,
    generation: AtomicU64,
    gen_state: Mutex<GenState>,
}

impl Default for ArmAttr {
    fn default() -> Self {
        ArmAttr::new()
    }
}

impl ArmAttr {
    pub fn new() -> Self {
        ArmAttr {
            cells: (0..N_CELLS).map(|_| ArmCell::default()).collect(),
            generation: AtomicU64::new(1),
            gen_state: Mutex::new(GenState {
                version: 1,
                mark: vec![(0, 0); N_CELLS],
                prev_mean_nj: vec![None; N_CELLS],
            }),
        }
    }

    /// Attribute `requests` served SpMV requests to `d`'s arm — the
    /// product-path shorthand for [`ArmAttr::record_kind`].
    /// `exec_weighted` is the request-weighted exec time (a coalesced
    /// batch of k contributes k * per-product time) and `m` the
    /// gpusim-modeled per-product measurement.
    pub fn record(
        &self,
        d: JointDecision,
        requests: u64,
        exec_weighted: Duration,
        m: &Measurement,
    ) {
        self.record_kind(KernelKind::Spmv, d, requests, exec_weighted, m);
    }

    /// Attribute `requests` served requests of `kind` to `d`'s arm.
    pub fn record_kind(
        &self,
        kind: KernelKind,
        d: JointDecision,
        requests: u64,
        exec_weighted: Duration,
        m: &Measurement,
    ) {
        if requests == 0 {
            return;
        }
        let cell = &self.cells[kind.class_id() * N_ARMS + d.arm_index()];
        cell.requests.fetch_add(requests, Ordering::Relaxed);
        cell.exec_ns.fetch_add(exec_weighted.as_nanos() as u64, Ordering::Relaxed);
        let nj = (m.energy_j * 1e9).round().max(0.0) as u64;
        cell.energy_nj.fetch_add(nj * requests, Ordering::Relaxed);
        let mw = (m.avg_power_w * 1e3).round().max(0.0) as u64;
        cell.power_mw.fetch_add(mw * requests, Ordering::Relaxed);
        let effk = (m.mflops_per_watt * 1e3).round().max(0.0) as u64;
        cell.eff_x1000.fetch_add(effk * requests, Ordering::Relaxed);
    }

    /// Close the current generation window at router `version`,
    /// journaling an `ArmShift` for every arm whose mean modeled energy
    /// moved outside [`SHIFT_BAND`] versus the previous window. Called
    /// by whichever shard observes the hot-swap first; later shards
    /// (and replays of older versions) are no-ops.
    pub fn mark_generation(&self, version: u64, journal: &Journal) {
        let mut st = self.gen_state.lock().expect("arm gen lock");
        if version <= st.version {
            return;
        }
        for (i, cell) in self.cells.iter().enumerate() {
            let req = cell.requests.load(Ordering::Relaxed);
            let nj = cell.energy_nj.load(Ordering::Relaxed);
            let (mreq, mnj) = st.mark[i];
            let (wreq, wnj) = (req - mreq, nj - mnj);
            if wreq >= SHIFT_MIN_REQUESTS {
                let mean = wnj as f64 / wreq as f64;
                if let Some(prev) = st.prev_mean_nj[i] {
                    if prev > 0.0 {
                        let ratio = mean / prev;
                        if !(SHIFT_BAND.0..=SHIFT_BAND.1).contains(&ratio) {
                            // the event names the joint arm; the shift
                            // windows themselves are kind-separated, so
                            // solve traffic can never drag an SpMV
                            // arm's mean across the band
                            journal.emit(EventKind::ArmShift {
                                arm: JointDecision::from_arm(i % N_ARMS),
                                generation: version,
                                ratio_pct: (ratio * 100.0).round() as u64,
                            });
                        }
                    }
                }
                st.prev_mean_nj[i] = Some(mean);
            }
            st.mark[i] = (req, nj);
        }
        st.version = version;
        self.generation.store(version, Ordering::Relaxed);
    }

    /// Router generation the attribution windows are aligned to.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    /// Profiles for every (kind, arm) cell that served at least one
    /// request, in (kind, arm) order (at most [`N_CELLS`] rows —
    /// bounded label cardinality, see `tools/metrics_lint.py`).
    pub fn snapshot(&self) -> Vec<ArmProfile> {
        let mut out = Vec::new();
        for (i, cell) in self.cells.iter().enumerate() {
            let requests = cell.requests.load(Ordering::Relaxed);
            if requests == 0 {
                continue;
            }
            let kind = KernelKind::from_class_id(i / N_ARMS).expect("cell index in range");
            let d = JointDecision::from_arm(i % N_ARMS);
            let rf = requests as f64;
            out.push(ArmProfile {
                kind: kind.name().to_string(),
                format: d.format.to_string(),
                knobs: d.choice.to_string(),
                arm: i % N_ARMS,
                requests,
                exec_s: cell.exec_ns.load(Ordering::Relaxed) as f64 * 1e-9,
                energy_j: cell.energy_nj.load(Ordering::Relaxed) as f64 * 1e-9,
                mean_power_w: cell.power_mw.load(Ordering::Relaxed) as f64 * 1e-3 / rf,
                mflops_per_watt: cell.eff_x1000.load(Ordering::Relaxed) as f64 * 1e-3 / rf,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Format;

    fn meas(energy_j: f64) -> Measurement {
        Measurement { latency_s: 1e-4, energy_j, avg_power_w: 30.0, mflops_per_watt: 250.0 }
    }

    fn arm(format: Format) -> JointDecision {
        JointDecision::format_only(format)
    }

    #[test]
    fn record_accumulates_request_weighted_totals() {
        let attr = ArmAttr::new();
        let d = arm(Format::Csr);
        attr.record(d, 4, Duration::from_micros(400), &meas(2e-6));
        attr.record(d, 2, Duration::from_micros(200), &meas(2e-6));
        attr.record(d, 0, Duration::from_secs(9), &meas(1.0)); // no-op
        let prof = attr.snapshot();
        assert_eq!(prof.len(), 1);
        let p = &prof[0];
        assert_eq!(p.arm, d.arm_index());
        assert_eq!(p.format, "csr");
        assert_eq!(p.knobs, d.choice.to_string());
        assert_eq!(p.requests, 6);
        assert!((p.exec_s - 600e-6).abs() < 1e-9, "{}", p.exec_s);
        assert!((p.energy_j - 12e-6).abs() < 1e-12, "{}", p.energy_j);
        assert!((p.mean_power_w - 30.0).abs() < 1e-9);
        assert!((p.mflops_per_watt - 250.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_orders_by_arm_and_skips_idle_arms() {
        let attr = ArmAttr::new();
        attr.record(arm(Format::Ell), 1, Duration::from_micros(10), &meas(1e-6));
        attr.record(arm(Format::Csr), 1, Duration::from_micros(10), &meas(1e-6));
        let prof = attr.snapshot();
        assert_eq!(prof.len(), 2);
        assert!(prof[0].arm < prof[1].arm, "arm-index order");
        assert!(prof.len() <= N_ARMS);
    }

    #[test]
    fn generation_shift_emits_when_mean_energy_moves() {
        let journal = Journal::new(16);
        let attr = ArmAttr::new();
        let d = arm(Format::Csr);
        assert_eq!(attr.generation(), 1);
        // generation 1 window: 8 requests at 1uJ each
        attr.record(d, 8, Duration::from_micros(80), &meas(1e-6));
        attr.mark_generation(2, &journal);
        assert!(journal.is_empty(), "first window only sets the baseline");
        // generation 2 window: mean doubles -> shift
        attr.record(d, 8, Duration::from_micros(80), &meas(2e-6));
        attr.mark_generation(3, &journal);
        assert_eq!(attr.generation(), 3);
        let keys: Vec<String> = journal.snapshot().iter().map(|e| e.kind.key()).collect();
        assert_eq!(keys.len(), 1, "{keys:?}");
        assert_eq!(keys[0], format!("arm_shift arm={d} gen=v3 ratio=200%"));
    }

    #[test]
    fn small_windows_and_stable_means_stay_silent() {
        let journal = Journal::new(16);
        let attr = ArmAttr::new();
        let d = arm(Format::Bell);
        attr.record(d, 8, Duration::from_micros(80), &meas(1e-6));
        attr.mark_generation(2, &journal);
        // below the evidence floor: no shift even though mean tripled
        attr.record(d, SHIFT_MIN_REQUESTS - 1, Duration::from_micros(70), &meas(3e-6));
        attr.mark_generation(3, &journal);
        assert!(journal.is_empty());
        // inside the band: stable mean stays silent
        attr.record(d, 8, Duration::from_micros(80), &meas(1.1e-6));
        attr.mark_generation(4, &journal);
        assert!(journal.is_empty());
        // replayed/stale versions are no-ops
        attr.mark_generation(4, &journal);
        assert_eq!(attr.generation(), 4);
    }

    #[test]
    fn kinds_attribute_to_separate_cells() {
        let attr = ArmAttr::new();
        let d = arm(Format::Csr);
        attr.record(d, 3, Duration::from_micros(30), &meas(1e-6));
        attr.record_kind(KernelKind::Sptrsv, d, 2, Duration::from_micros(200), &meas(4e-6));
        attr.record_kind(KernelKind::Symgs, d, 1, Duration::from_micros(500), &meas(8e-6));
        let prof = attr.snapshot();
        assert_eq!(prof.len(), 3, "one row per kind, same joint arm");
        let by_kind = |k: &str| prof.iter().find(|p| p.kind == k).unwrap();
        assert_eq!(by_kind("spmv").requests, 3);
        assert_eq!(by_kind("sptrsv").requests, 2);
        assert_eq!(by_kind("symgs").requests, 1);
        for p in &prof {
            assert_eq!(p.arm, d.arm_index(), "arm index stays kind-relative");
            assert_eq!(p.format, "csr");
        }
        // kind-major, arm-minor snapshot order
        assert_eq!(
            prof.iter().map(|p| p.kind.as_str()).collect::<Vec<_>>(),
            vec!["spmv", "sptrsv", "symgs"]
        );
    }
}
