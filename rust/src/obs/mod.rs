//! Observability primitives for the serving pool and the online loop.
//!
//! Six pillars, all allocation-free on the hot path:
//!
//! 1. **Stage tracing** ([`trace`]): every served request decomposes its
//!    end-to-end latency into monotonic stage durations (queue-wait →
//!    batch-wait → convert → exec → reply), recorded into per-stage
//!    log2 histograms ([`hist`]) that sum — exactly, by construction —
//!    to the end-to-end histogram telemetry already keeps.
//! 2. **Control-plane event journal** ([`journal`]): a bounded,
//!    drop-oldest ring of structured events (hot-swap, retrain,
//!    migration, drift, exploration, session lifecycle, SLO
//!    alert/recovery, arm shift) shared by the router and every shard,
//!    so a drift-triggered hot-swap leaves a causal paper trail instead
//!    of three counter bumps.
//! 3. **Metrics export** ([`metrics`]): renders counters, gauges, and
//!    the log2 histograms in Prometheus text-exposition format, plus a
//!    [`crate::report::Table`] twin for TSV/JSON emission.
//! 4. **SLO engine** ([`slo`]): multi-window burn-rate evaluation of a
//!    p99 target and a deadline-miss budget over request-counted
//!    windows, with debounced breach/recovery journal events.
//! 5. **Per-arm attribution** ([`attr`]): the paper's four headline
//!    metrics (latency, energy, power, efficiency) accumulated per
//!    joint (format × compile-knob) arm, with generation windows
//!    aligned to router hot-swaps.
//! 6. **Flight recorder** ([`recorder`]): a bounded per-shard ring of
//!    recent request traces, frozen by the SLO engine at breach time so
//!    the breach context survives for post-mortem.
//!
//! The hot-path cost budget is two `Instant::now()` calls and a handful
//! of relaxed atomic adds per request (gated by `PoolConfig::tracing`;
//! arm attribution is a few more relaxed adds per *dispatch*); the SLO
//! observe path (histogram add + flight-lane push) only runs when the
//! pool has an SLO configured. Journal emission takes a mutex but only
//! on control-plane events, which are rare by design.

pub mod attr;
pub mod hist;
pub mod journal;
pub mod metrics;
pub mod recorder;
pub mod slo;
pub mod trace;

pub use attr::{ArmAttr, ArmProfile};
pub use hist::{Hist, HistSnapshot, HIST_BUCKETS};
pub use journal::{Event, EventKind, Journal, SwapTrigger, DEFAULT_JOURNAL_CAP};
pub use metrics::Metrics;
pub use recorder::{FlightRecord, FlightRecorder, DEFAULT_FLIGHT_CAP};
pub use slo::{SloConfig, SloEngine, SloSnapshot, SloSpec, SloStatus};
pub use trace::{Stage, StageHists, StageStats, Trace, N_STAGES};
