//! Observability primitives for the serving pool and the online loop.
//!
//! Three pillars, all allocation-free on the hot path:
//!
//! 1. **Stage tracing** ([`trace`]): every served request decomposes its
//!    end-to-end latency into monotonic stage durations (queue-wait →
//!    batch-wait → convert → exec → reply), recorded into per-stage
//!    log2 histograms ([`hist`]) that sum — exactly, by construction —
//!    to the end-to-end histogram telemetry already keeps.
//! 2. **Control-plane event journal** ([`journal`]): a bounded,
//!    drop-oldest ring of structured events (hot-swap, retrain,
//!    migration, drift, exploration, session lifecycle) shared by the
//!    router and every shard, so a drift-triggered hot-swap leaves a
//!    causal paper trail instead of three counter bumps.
//! 3. **Metrics export** ([`metrics`]): renders counters, gauges, and
//!    the log2 histograms in Prometheus text-exposition format, plus a
//!    [`crate::report::Table`] twin for TSV/JSON emission.
//!
//! The hot-path cost budget is two `Instant::now()` calls and a handful
//! of relaxed atomic adds per request (gated by `PoolConfig::tracing`);
//! journal emission takes a mutex but only on control-plane events,
//! which are rare by design.

pub mod hist;
pub mod journal;
pub mod metrics;
pub mod trace;

pub use hist::{Hist, HistSnapshot, HIST_BUCKETS};
pub use journal::{Event, EventKind, Journal, SwapTrigger, DEFAULT_JOURNAL_CAP};
pub use metrics::Metrics;
pub use trace::{Stage, StageHists, StageStats, Trace, N_STAGES};
