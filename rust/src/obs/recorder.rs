//! Trace flight recorder: a bounded per-shard ring of the most recent
//! request traces, frozen ("captured") by the SLO engine when a breach
//! fires so the traces AROUND the breach survive for post-mortem.
//!
//! The recorder is off unless the pool runs with an SLO config (the
//! engine owns one); with it on, the per-request cost is one short
//! mutex push into the owning shard's private lane — shards never
//! contend with each other, only with the rare snapshot/capture reader.
//! Each lane holds the last `cap` records; the merged view interleaves
//! lanes by a global sequence number so "the last N requests" reads in
//! admission order even on a multi-shard pool.

use super::trace::Trace;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Default per-shard ring capacity (records, not bytes).
pub const DEFAULT_FLIGHT_CAP: usize = 32;

/// One recorded request: identity, outcome, and its full stage trace.
#[derive(Debug, Clone)]
pub struct FlightRecord {
    /// Global admission order across all lanes (monotone).
    pub seq: u64,
    pub matrix: u64,
    pub shard: usize,
    /// End-to-end service time.
    pub service: Duration,
    /// Whether the request carried a deadline tag and missed it.
    pub deadline_missed: bool,
    /// Stage decomposition (all-zero when pool tracing is off).
    pub trace: Trace,
}

impl FlightRecord {
    /// One-line JSON object (microsecond durations, like the journal).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"seq\":{},\"matrix\":{},\"shard\":{},\"service_us\":{},\
             \"deadline_missed\":{},\"queue_wait_us\":{},\"batch_wait_us\":{},\
             \"convert_us\":{},\"exec_us\":{},\"reply_us\":{}}}",
            self.seq,
            self.matrix,
            self.shard,
            self.service.as_micros(),
            self.deadline_missed,
            self.trace.queue_wait.as_micros(),
            self.trace.batch_wait.as_micros(),
            self.trace.convert.as_micros(),
            self.trace.exec.as_micros(),
            self.trace.reply.as_micros(),
        )
    }
}

/// Bounded per-shard trace rings plus the breach-time capture slot.
pub struct FlightRecorder {
    cap: usize,
    seq: AtomicU64,
    lanes: Vec<Mutex<VecDeque<FlightRecord>>>,
    /// The ring as it looked when the last breach fired.
    captured: Mutex<Vec<FlightRecord>>,
    captures: AtomicU64,
}

impl FlightRecorder {
    pub fn new(lanes: usize, cap: usize) -> Self {
        let cap = cap.max(1);
        FlightRecorder {
            cap,
            seq: AtomicU64::new(0),
            lanes: (0..lanes.max(1)).map(|_| Mutex::new(VecDeque::with_capacity(cap))).collect(),
            captured: Mutex::new(Vec::new()),
            captures: AtomicU64::new(0),
        }
    }

    /// Record one served request into its shard's lane (drop-oldest).
    pub fn push(
        &self,
        shard: usize,
        matrix: u64,
        service: Duration,
        deadline_missed: bool,
        trace: Trace,
    ) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let lane = &self.lanes[shard % self.lanes.len()];
        let mut ring = lane.lock().expect("flight lane lock");
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(FlightRecord { seq, matrix, shard, service, deadline_missed, trace });
    }

    /// The live rings merged across lanes, oldest first (by `seq`).
    pub fn ring(&self) -> Vec<FlightRecord> {
        let mut out = Vec::new();
        for lane in &self.lanes {
            out.extend(lane.lock().expect("flight lane lock").iter().cloned());
        }
        out.sort_unstable_by_key(|r| r.seq);
        out
    }

    /// Freeze the current ring as the breach context (the SLO alert
    /// path calls this); returns the number of records captured.
    pub fn capture(&self) -> usize {
        let snap = self.ring();
        let n = snap.len();
        *self.captured.lock().expect("flight capture lock") = snap;
        self.captures.fetch_add(1, Ordering::Relaxed);
        n
    }

    /// The most recent breach capture (empty if none fired yet).
    pub fn captured(&self) -> Vec<FlightRecord> {
        self.captured.lock().expect("flight capture lock").clone()
    }

    /// Breach captures taken over the recorder's lifetime.
    pub fn captures(&self) -> u64 {
        self.captures.load(Ordering::Relaxed)
    }

    /// Records currently live across all lanes.
    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.lock().expect("flight lane lock").len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Render records as a JSON array (one object per line) — the
    /// serve CLI's `--flight-out` payload.
    pub fn to_json(records: &[FlightRecord]) -> String {
        if records.is_empty() {
            return "[]\n".to_string();
        }
        let mut out = String::from("[\n");
        for (i, r) in records.iter().enumerate() {
            out.push_str("  ");
            out.push_str(&r.to_json());
            if i + 1 < records.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("]\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_n(rec: &FlightRecorder, shard: usize, n: usize) {
        for i in 0..n {
            rec.push(
                shard,
                i as u64,
                Duration::from_micros(10 + i as u64),
                false,
                Trace::default(),
            );
        }
    }

    #[test]
    fn ring_is_bounded_and_drops_oldest() {
        let rec = FlightRecorder::new(1, 4);
        push_n(&rec, 0, 10);
        let ring = rec.ring();
        assert_eq!(ring.len(), 4);
        assert_eq!(rec.len(), 4);
        let seqs: Vec<u64> = ring.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "newest survive, oldest first");
    }

    #[test]
    fn lanes_merge_in_global_admission_order() {
        let rec = FlightRecorder::new(2, 8);
        rec.push(0, 1, Duration::from_micros(5), false, Trace::default());
        rec.push(1, 2, Duration::from_micros(6), true, Trace::default());
        rec.push(0, 3, Duration::from_micros(7), false, Trace::default());
        let ring = rec.ring();
        let seqs: Vec<u64> = ring.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2]);
        assert_eq!(ring[1].matrix, 2);
        assert!(ring[1].deadline_missed);
    }

    #[test]
    fn capture_freezes_the_breach_context() {
        let rec = FlightRecorder::new(1, 4);
        assert!(rec.is_empty());
        assert_eq!(rec.captures(), 0);
        assert!(rec.captured().is_empty());
        push_n(&rec, 0, 4);
        assert_eq!(rec.capture(), 4);
        assert_eq!(rec.captures(), 1);
        // the live ring rolls on; the capture does not
        push_n(&rec, 0, 4);
        let cap = rec.captured();
        assert_eq!(cap.len(), 4);
        assert_eq!(cap[0].seq, 0, "capture holds the breach-time window");
        assert_eq!(rec.ring()[0].seq, 4);
    }

    #[test]
    fn json_renders_one_object_per_record() {
        let rec = FlightRecorder::new(1, 4);
        rec.push(
            0,
            7,
            Duration::from_micros(42),
            true,
            Trace { exec: Duration::from_micros(40), ..Default::default() },
        );
        let json = FlightRecorder::to_json(&rec.ring());
        assert!(json.starts_with("[\n"), "{json}");
        assert!(json.contains("\"matrix\":7"), "{json}");
        assert!(json.contains("\"service_us\":42"), "{json}");
        assert!(json.contains("\"deadline_missed\":true"), "{json}");
        assert!(json.contains("\"exec_us\":40"), "{json}");
        assert_eq!(FlightRecorder::to_json(&[]), "[]\n");
    }
}
