//! SLO engine: windowed p99/deadline-miss tracking with multi-window
//! burn-rate alerting over the serving pool's log2 histograms.
//!
//! An [`SloSpec`] states the promise (p99 target, deadline-miss
//! budget); the engine checks it over TWO windows — a fast window of
//! the last `fast_window` requests and the slow full-history window —
//! and only declares [`SloStatus::Breach`] when BOTH agree, the
//! classic multi-window burn-rate rule: the fast window makes alerts
//! prompt, the slow window keeps one bad batch from paging anyone.
//! Windows are request-counted, not wall-clocked, so a seeded
//! single-worker run evaluates at identical boundaries every time and
//! the `slo_alert`/`slo_recovered` journal keys are deterministic.
//!
//! Evaluation is debounced structurally: one alert per breach episode
//! (no re-alert while breached), and recovery requires
//! `recovery_evals` consecutive clean evaluations — an oscillating
//! workload cannot storm the journal. On the alert edge the engine
//! freezes the [`FlightRecorder`] ring, so the traces around the
//! breach survive for post-mortem (`Pool::flight_records`).
//!
//! The engine itself stays observational — it never sheds or reorders
//! a request. The scale-out control plane (`serve::pool`, DESIGN.md
//! §12) is the actuator: it consults [`SloEngine::status`] to gate
//! admission shedding and [`SloEngine::matrix_status`] to trigger
//! hot-matrix replication, so an engine-less (or healthy) pool is
//! bit-identical to one with no control plane at all.

use super::hist::{quantile_us, Hist, HIST_BUCKETS};
use super::journal::{EventKind, Journal};
use super::recorder::FlightRecorder;
use super::trace::Trace;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// The promise: a p99 service-time target and the fraction of
/// deadline-tagged requests allowed to miss their tag.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Windowed p99 service time must stay at or under this.
    pub p99_target: Duration,
    /// Allowed miss fraction among deadline-tagged requests (the burn
    /// rate is `observed_miss_fraction / budget`; >= 1.0 burns it).
    pub deadline_miss_budget: f64,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec { p99_target: Duration::from_millis(50), deadline_miss_budget: 0.01 }
    }
}

/// Engine configuration: the pool-wide spec, optional per-matrix
/// overrides (each gets its own windows and its own alert scope), and
/// the window/debounce geometry.
#[derive(Debug, Clone)]
pub struct SloConfig {
    pub spec: SloSpec,
    /// Per-matrix overrides: `(matrix_id, spec)`. Each override is
    /// evaluated as its own scope on the same request-count boundaries.
    pub overrides: Vec<(u64, SloSpec)>,
    /// Fast-window width AND evaluation cadence, in requests (the
    /// "1-minute-equivalent" window, expressed in request counts so
    /// seeded runs are deterministic).
    pub fast_window: u64,
    /// Consecutive clean evaluations required before a breached scope
    /// recovers (hysteresis against alert storms).
    pub recovery_evals: u64,
    /// Per-shard flight-recorder ring capacity.
    pub flight_cap: usize,
}

impl SloConfig {
    pub fn new(spec: SloSpec) -> Self {
        SloConfig {
            spec,
            overrides: Vec::new(),
            fast_window: 64,
            recovery_evals: 2,
            flight_cap: super::recorder::DEFAULT_FLIGHT_CAP,
        }
    }
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig::new(SloSpec::default())
    }
}

/// Where a scope stands against its spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloStatus {
    /// Neither window violates the spec.
    Ok,
    /// The fast window violates but the slow window does not (a blip —
    /// watch, don't page).
    Warning,
    /// Both windows violate (or a breach episode has not recovered yet).
    Breach,
}

impl SloStatus {
    pub fn name(self) -> &'static str {
        match self {
            SloStatus::Ok => "ok",
            SloStatus::Warning => "warning",
            SloStatus::Breach => "breach",
        }
    }

    /// Gauge encoding for metrics export (0 / 1 / 2).
    pub fn as_f64(self) -> f64 {
        match self {
            SloStatus::Ok => 0.0,
            SloStatus::Warning => 1.0,
            SloStatus::Breach => 2.0,
        }
    }
}

/// Point-in-time summary of the POOL scope (the headline numbers the
/// CLI line and the metrics families render).
#[derive(Debug, Clone)]
pub struct SloSnapshot {
    /// Worst status across all scopes (breach episodes are sticky
    /// until they recover).
    pub status: SloStatus,
    pub p99_target: Duration,
    pub miss_budget: f64,
    /// Evaluations run (every `fast_window` observed requests).
    pub evals: u64,
    /// Breach episodes alerted (one per episode, debounced).
    pub alerts: u64,
    /// Breach episodes recovered.
    pub recoveries: u64,
    /// Pool-scope burn rates at the last evaluation.
    pub fast_burn: f64,
    pub slow_burn: f64,
    /// Pool-scope windowed p99s at the last evaluation (None below two
    /// samples in the window).
    pub fast_p99_us: Option<f64>,
    pub slow_p99_us: Option<f64>,
    /// Requests observed / deadline-tagged / missed, full history.
    pub observed: u64,
    pub tagged: u64,
    pub missed: u64,
    /// Records in the last breach capture (0 before any breach).
    pub flight_captured: usize,
    /// Breach captures taken.
    pub flight_captures: u64,
}

/// Shared per-scope accumulation (hot path: relaxed atomics only).
struct ScopeState {
    /// `None` = the pool scope; `Some(id)` = a per-matrix override.
    matrix: Option<u64>,
    spec: SloSpec,
    lat: Hist,
    tagged: AtomicU64,
    missed: AtomicU64,
}

/// Per-scope evaluation state (touched only under the eval mutex).
struct ScopeEval {
    /// Histogram bucket counts at the last evaluation boundary — the
    /// fast window is the delta since here.
    mark_counts: Vec<u64>,
    mark_count: u64,
    mark_tagged: u64,
    mark_missed: u64,
    /// In a breach episode (alerted, not yet recovered).
    breached: bool,
    clean_evals: u64,
    status: SloStatus,
    fast_burn: f64,
    slow_burn: f64,
    fast_p99_us: Option<f64>,
    slow_p99_us: Option<f64>,
}

impl ScopeEval {
    fn new() -> Self {
        ScopeEval {
            mark_counts: vec![0; HIST_BUCKETS],
            mark_count: 0,
            mark_tagged: 0,
            mark_missed: 0,
            breached: false,
            clean_evals: 0,
            status: SloStatus::Ok,
            fast_burn: 0.0,
            slow_burn: 0.0,
            fast_p99_us: None,
            slow_p99_us: None,
        }
    }

    /// Breach episodes stay visible until they recover, even if a
    /// single evaluation in between looked clean.
    fn displayed_status(&self) -> SloStatus {
        if self.breached {
            SloStatus::Breach
        } else {
            self.status
        }
    }
}

/// Miss burn rate: observed miss fraction over the budget. Zero misses
/// burn nothing; a non-zero miss against a zero budget burns infinitely.
fn burn_rate(missed: u64, tagged: u64, budget: f64) -> f64 {
    if missed == 0 || tagged == 0 {
        return 0.0;
    }
    let frac = missed as f64 / tagged as f64;
    if budget <= 0.0 {
        f64::INFINITY
    } else {
        frac / budget
    }
}

/// The engine: scopes + windows + the flight recorder, fed by shards
/// via [`SloEngine::observe`] and read by `Pool::stats`.
pub struct SloEngine {
    cfg: SloConfig,
    journal: Arc<Journal>,
    recorder: FlightRecorder,
    scopes: Vec<ScopeState>,
    observed: AtomicU64,
    evals: AtomicU64,
    alerts: AtomicU64,
    recoveries: AtomicU64,
    eval_state: Mutex<Vec<ScopeEval>>,
}

impl SloEngine {
    /// Build the engine for a pool with `shards` workers, emitting
    /// alerts into the pool's shared `journal`.
    pub fn new(cfg: SloConfig, shards: usize, journal: Arc<Journal>) -> Self {
        let mut scopes = vec![ScopeState {
            matrix: None,
            spec: cfg.spec,
            lat: Hist::new(),
            tagged: AtomicU64::new(0),
            missed: AtomicU64::new(0),
        }];
        for &(id, spec) in &cfg.overrides {
            scopes.push(ScopeState {
                matrix: Some(id),
                spec,
                lat: Hist::new(),
                tagged: AtomicU64::new(0),
                missed: AtomicU64::new(0),
            });
        }
        let evals = scopes.iter().map(|_| ScopeEval::new()).collect();
        SloEngine {
            recorder: FlightRecorder::new(shards, cfg.flight_cap),
            cfg,
            journal,
            scopes,
            observed: AtomicU64::new(0),
            evals: AtomicU64::new(0),
            alerts: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
            eval_state: Mutex::new(evals),
        }
    }

    /// Record one served request; every `fast_window`-th observation
    /// runs an evaluation. Shards call this per request when an SLO is
    /// configured — the cost is a histogram add, two or three relaxed
    /// counter adds, and one short flight-lane push.
    pub fn observe(
        &self,
        matrix: u64,
        shard: usize,
        service: Duration,
        tagged: bool,
        missed: bool,
        trace: Option<Trace>,
    ) {
        self.recorder.push(shard, matrix, service, missed, trace.unwrap_or_default());
        for scope in &self.scopes {
            if scope.matrix.is_none_or(|m| m == matrix) {
                scope.lat.record(service);
                if tagged {
                    scope.tagged.fetch_add(1, Ordering::Relaxed);
                    if missed {
                        scope.missed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        let n = self.observed.fetch_add(1, Ordering::Relaxed) + 1;
        if n % self.cfg.fast_window.max(1) == 0 {
            self.evaluate(n);
        }
    }

    /// Evaluate every scope at the request-count boundary `at_requests`.
    fn evaluate(&self, at_requests: u64) {
        let mut state = self.eval_state.lock().expect("slo eval lock");
        self.evals.fetch_add(1, Ordering::Relaxed);
        for (scope, ev) in self.scopes.iter().zip(state.iter_mut()) {
            let snap = scope.lat.snapshot();
            let tagged = scope.tagged.load(Ordering::Relaxed);
            let missed = scope.missed.load(Ordering::Relaxed);
            let fast_total = snap.count - ev.mark_count;
            if fast_total == 0 {
                // no traffic in this scope's window: status unchanged,
                // and an idle scope neither burns nor recovers
                continue;
            }
            let fast_counts: Vec<u64> = snap
                .counts
                .iter()
                .zip(&ev.mark_counts)
                .map(|(cur, mark)| cur - mark)
                .collect();
            let fast_tagged = tagged - ev.mark_tagged;
            let fast_missed = missed - ev.mark_missed;

            // p99 needs at least two samples in the window (same rule
            // as HistSnapshot::tail_quantile_us).
            let target_us = scope.spec.p99_target.as_secs_f64() * 1e6;
            ev.fast_p99_us = if fast_total >= 2 { quantile_us(&fast_counts, 0.99) } else { None };
            ev.slow_p99_us = if snap.count >= 2 { quantile_us(&snap.counts, 0.99) } else { None };
            ev.fast_burn = burn_rate(fast_missed, fast_tagged, scope.spec.deadline_miss_budget);
            ev.slow_burn = burn_rate(missed, tagged, scope.spec.deadline_miss_budget);

            let p99_fast = ev.fast_p99_us.is_some_and(|p| p > target_us);
            let p99_slow = ev.slow_p99_us.is_some_and(|p| p > target_us);
            let miss_fast = ev.fast_burn >= 1.0;
            let miss_slow = ev.slow_burn >= 1.0;
            let p99_viol = p99_fast && p99_slow;
            let miss_viol = miss_fast && miss_slow;
            ev.status = if p99_viol || miss_viol {
                SloStatus::Breach
            } else if p99_fast || miss_fast {
                SloStatus::Warning
            } else {
                SloStatus::Ok
            };

            if ev.status == SloStatus::Breach && !ev.breached {
                // alert edge: one per episode, and freeze the flight
                // ring so the breach-window traces survive
                ev.breached = true;
                ev.clean_evals = 0;
                self.alerts.fetch_add(1, Ordering::Relaxed);
                self.recorder.capture();
                let signal = match (miss_viol, p99_viol) {
                    (true, true) => "p99+miss_budget",
                    (true, false) => "miss_budget",
                    _ => "p99",
                };
                self.journal.emit(EventKind::SloAlert {
                    scope: scope.matrix,
                    at_requests,
                    signal,
                    missed: fast_missed,
                    tagged: fast_tagged,
                });
            } else if ev.breached {
                if ev.status == SloStatus::Ok {
                    ev.clean_evals += 1;
                    if ev.clean_evals >= self.cfg.recovery_evals.max(1) {
                        ev.breached = false;
                        self.recoveries.fetch_add(1, Ordering::Relaxed);
                        self.journal
                            .emit(EventKind::SloRecovered { scope: scope.matrix, at_requests });
                    }
                } else {
                    ev.clean_evals = 0;
                }
            }

            // roll the fast-window mark to this boundary
            ev.mark_counts.copy_from_slice(&snap.counts);
            ev.mark_count = snap.count;
            ev.mark_tagged = tagged;
            ev.mark_missed = missed;
        }
    }

    /// Worst displayed status across all scopes.
    pub fn status(&self) -> SloStatus {
        let state = self.eval_state.lock().expect("slo eval lock");
        state.iter().map(|ev| ev.displayed_status()).max().unwrap_or(SloStatus::Ok)
    }

    /// Displayed status of the per-matrix override scope for `matrix`
    /// (`None` when the matrix has no override — the control plane
    /// treats that as "no per-matrix signal", not "healthy").
    pub fn matrix_status(&self, matrix: u64) -> Option<SloStatus> {
        let state = self.eval_state.lock().expect("slo eval lock");
        self.scopes
            .iter()
            .zip(state.iter())
            .find(|(scope, _)| scope.matrix == Some(matrix))
            .map(|(_, ev)| ev.displayed_status())
    }

    /// The flight recorder the engine freezes on breach.
    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }

    pub fn snapshot(&self) -> SloSnapshot {
        let state = self.eval_state.lock().expect("slo eval lock");
        let status = state.iter().map(|ev| ev.displayed_status()).max().unwrap_or(SloStatus::Ok);
        let pool = &state[0];
        SloSnapshot {
            status,
            p99_target: self.cfg.spec.p99_target,
            miss_budget: self.cfg.spec.deadline_miss_budget,
            evals: self.evals.load(Ordering::Relaxed),
            alerts: self.alerts.load(Ordering::Relaxed),
            recoveries: self.recoveries.load(Ordering::Relaxed),
            fast_burn: pool.fast_burn,
            slow_burn: pool.slow_burn,
            fast_p99_us: pool.fast_p99_us,
            slow_p99_us: pool.slow_p99_us,
            observed: self.observed.load(Ordering::Relaxed),
            tagged: self.scopes[0].tagged.load(Ordering::Relaxed),
            missed: self.scopes[0].missed.load(Ordering::Relaxed),
            flight_captured: self.recorder.captured().len(),
            flight_captures: self.recorder.captures(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(cfg: SloConfig) -> (SloEngine, Arc<Journal>) {
        let journal = Arc::new(Journal::new(64));
        (SloEngine::new(cfg, 1, journal.clone()), journal)
    }

    fn cfg(budget: f64, fast_window: u64) -> SloConfig {
        SloConfig {
            spec: SloSpec { p99_target: Duration::from_secs(3600), deadline_miss_budget: budget },
            fast_window,
            ..SloConfig::default()
        }
    }

    fn drive(e: &SloEngine, n: usize, us: u64, tagged: bool, missed: bool) {
        for _ in 0..n {
            e.observe(1, 0, Duration::from_micros(us), tagged, missed, None);
        }
    }

    fn keys(journal: &Journal) -> Vec<String> {
        journal.snapshot().iter().map(|e| e.kind.key()).collect()
    }

    #[test]
    fn healthy_traffic_stays_ok_and_emits_nothing() {
        let (e, journal) = engine(cfg(0.25, 8));
        drive(&e, 32, 50, true, false);
        let s = e.snapshot();
        assert_eq!(s.status, SloStatus::Ok);
        assert_eq!(s.evals, 4);
        assert_eq!(s.alerts, 0);
        assert_eq!(s.fast_burn, 0.0);
        assert!(journal.is_empty());
        assert_eq!(e.recorder().captures(), 0);
    }

    #[test]
    fn miss_budget_breach_alerts_once_then_recovers_deterministically() {
        let (e, journal) = engine(cfg(0.25, 8));
        drive(&e, 16, 50, true, false); // clean history
        drive(&e, 16, 50, true, true); // every tagged request misses
        let s = e.snapshot();
        assert_eq!(s.status, SloStatus::Breach);
        assert_eq!(s.alerts, 1, "debounce: one alert per episode");
        assert!(s.fast_burn >= 1.0 && s.slow_burn >= 1.0, "{s:?}");
        assert!(e.recorder().captures() == 1 && s.flight_captured > 0);
        // drain: two clean evaluations recover the episode
        drive(&e, 16, 50, true, false);
        let s = e.snapshot();
        assert_eq!(s.status, SloStatus::Ok);
        assert_eq!(s.recoveries, 1);
        assert_eq!(
            keys(&journal),
            vec![
                "slo_alert scope=pool at=24 signal=miss_budget missed=8/8".to_string(),
                "slo_recovered scope=pool at=48".to_string(),
            ],
        );
    }

    #[test]
    fn fast_only_violation_is_a_warning_not_a_breach() {
        let (e, journal) = engine(cfg(0.25, 8));
        // long clean history so the slow window stays under budget
        drive(&e, 64, 50, true, false);
        // one bad fast window: 8/72 tagged missed = 0.11 < 0.25 slow
        drive(&e, 8, 50, true, true);
        let s = e.snapshot();
        assert_eq!(s.status, SloStatus::Warning);
        assert_eq!(s.alerts, 0, "a blip must not page");
        assert!(journal.is_empty());
    }

    #[test]
    fn p99_target_breach_carries_the_p99_signal() {
        let spec = SloSpec { p99_target: Duration::from_micros(100), deadline_miss_budget: 1.0 };
        let (e, journal) = engine(SloConfig { spec, fast_window: 8, ..SloConfig::default() });
        drive(&e, 16, 5_000, false, false); // 5ms >> 100us target, untagged
        let s = e.snapshot();
        assert_eq!(s.status, SloStatus::Breach);
        assert!(s.fast_p99_us.unwrap() > 100.0);
        let k = keys(&journal);
        assert_eq!(k.len(), 1);
        assert!(k[0].contains("signal=p99"), "{k:?}");
    }

    #[test]
    fn per_matrix_override_scopes_alert_independently() {
        let mut c = cfg(1.0, 8); // pool budget so lax it never burns
        c.overrides = vec![(
            7,
            SloSpec { p99_target: Duration::from_secs(3600), deadline_miss_budget: 0.1 },
        )];
        let (e, journal) = engine(c);
        for i in 0..16 {
            // matrix 7 misses every deadline; matrix 1 is healthy
            e.observe(7, 0, Duration::from_micros(80), true, true, None);
            e.observe(1, 0, Duration::from_micros(20), true, false, None);
            let _ = i;
        }
        let s = e.snapshot();
        assert_eq!(s.status, SloStatus::Breach, "worst scope wins");
        let k = keys(&journal);
        assert_eq!(k.len(), 1, "{k:?}");
        assert!(k[0].starts_with("slo_alert scope=matrix7 "), "{k:?}");
    }

    #[test]
    fn matrix_status_reports_override_scopes_only() {
        let mut c = cfg(1.0, 8);
        c.overrides = vec![(
            7,
            SloSpec { p99_target: Duration::from_secs(3600), deadline_miss_budget: 0.1 },
        )];
        let (e, _journal) = engine(c);
        assert_eq!(e.matrix_status(7), Some(SloStatus::Ok));
        assert_eq!(e.matrix_status(1), None, "no override scope, no signal");
        for _ in 0..16 {
            e.observe(7, 0, Duration::from_micros(80), true, true, None);
        }
        assert_eq!(e.matrix_status(7), Some(SloStatus::Breach));
    }

    #[test]
    fn oscillating_breach_does_not_storm_and_recovery_needs_hysteresis() {
        let (e, journal) = engine(cfg(0.25, 8));
        drive(&e, 8, 50, true, true); // breach at first eval
        drive(&e, 8, 50, true, false); // clean eval #1 (no recovery yet)
        drive(&e, 8, 50, true, true); // breach again mid-episode: no new alert
        drive(&e, 8, 50, true, false); // clean eval #1 again
        drive(&e, 8, 50, true, false); // clean eval #2: recovered
        let s = e.snapshot();
        assert_eq!(s.alerts, 1);
        assert_eq!(s.recoveries, 1);
        let names: Vec<&str> =
            journal.snapshot().iter().map(|ev| ev.kind.name()).collect::<Vec<_>>();
        assert_eq!(names, vec!["slo_alert", "slo_recovered"]);
    }
}
