//! Atomic log2-bucketed latency histograms.
//!
//! The bucket math lived in `serve/telemetry.rs` until stage tracing
//! needed the same histogram seven more times; it is now shared here.
//! Bucket `b >= 1` counts nanosecond latencies in `[2^(b-1), 2^b)`;
//! bucket 0 counts exact zeros; bucket 47 tops out above ~39 hours.
//! Quantiles come out of 48 counters instead of an unbounded sample
//! buffer, and recording is a handful of relaxed atomic adds — safe on
//! the shard hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log2 nanosecond buckets.
pub const HIST_BUCKETS: usize = 48;

/// Bucket index for a nanosecond latency.
pub fn bucket_of(ns: u64) -> usize {
    ((64 - ns.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Geometric representative of a bucket, in nanoseconds.
pub fn bucket_rep_ns(b: usize) -> f64 {
    if b == 0 {
        0.0
    } else {
        0.75 * (1u64 << b.min(63)) as f64
    }
}

/// Histogram quantile: the representative value of the bucket holding
/// the `q`-th ranked sample, or `None` on an empty histogram.
pub fn quantile_us(counts: &[u64], q: f64) -> Option<f64> {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return None;
    }
    let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for (b, c) in counts.iter().enumerate() {
        cum += c;
        if cum >= rank {
            return Some(bucket_rep_ns(b) / 1e3);
        }
    }
    Some(bucket_rep_ns(counts.len() - 1) / 1e3)
}

/// A lock-free latency histogram: count + sum + max + log2 buckets,
/// every field a relaxed atomic so shards record without locking.
pub struct Hist {
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Hist {
    pub fn new() -> Self {
        Hist {
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record one observation.
    pub fn record(&self, d: Duration) {
        self.record_n(d, 1);
    }

    /// Record `n` observations of the same duration in one shot — the
    /// batch-shared-stage fast path (a coalesced dispatch's convert and
    /// exec stages cost every rider the same wall time, so one atomic
    /// round covers the whole batch).
    pub fn record_n(&self, d: Duration, n: u64) {
        if n == 0 {
            return;
        }
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.count.fetch_add(n, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns.saturating_mul(n), Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        self.buckets[bucket_of(ns)].fetch_add(n, Ordering::Relaxed);
    }

    /// Consistent-enough copy of the counters (relaxed loads; exact
    /// under quiescence, monotone always).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
            counts: self.buckets.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
        }
    }
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

/// Plain-data copy of a [`Hist`] at one instant.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum_ns: u64,
    pub max_ns: u64,
    /// Per-bucket counts, `HIST_BUCKETS` entries (empty on `Default`).
    pub counts: Vec<u64>,
}

impl HistSnapshot {
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64 / 1e3
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_ns as f64 / 1e3
    }

    /// Accumulated duration in seconds (Prometheus `_sum` convention).
    pub fn sum_s(&self) -> f64 {
        self.sum_ns as f64 * 1e-9
    }

    /// Quantile in microseconds. Bucket representatives can overshoot
    /// the true extremum; clamping keeps `p99 <= max` in every report.
    /// `None` on an empty histogram.
    pub fn quantile_us(&self, q: f64) -> Option<f64> {
        quantile_us(&self.counts, q).map(|v| v.min(self.max_us()))
    }

    /// Tail quantile: `None` below two samples — one observation
    /// supports a median, not a p99.
    pub fn tail_quantile_us(&self, q: f64) -> Option<f64> {
        if self.count >= 2 {
            self.quantile_us(q)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_log2_and_monotone() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        for ns in [1u64, 10, 1000, 1_000_000] {
            let b = bucket_of(ns);
            assert!(ns >= 1u64 << (b - 1) && ns < 1u64 << b, "ns {ns} bucket {b}");
        }
    }

    #[test]
    fn quantile_of_uniform_histogram() {
        let mut counts = vec![0u64; HIST_BUCKETS];
        counts[10] = 50; // all samples in one bucket
        let v = quantile_us(&counts, 0.5).unwrap();
        assert!((v - bucket_rep_ns(10) / 1e3).abs() < 1e-12);
        assert_eq!(quantile_us(&[0u64; HIST_BUCKETS], 0.99), None);
    }

    #[test]
    fn record_accumulates_count_sum_max() {
        let h = Hist::new();
        h.record(Duration::from_micros(10));
        h.record(Duration::from_micros(40));
        let s = h.snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.sum_ns, 50_000);
        assert_eq!(s.max_ns, 40_000);
        assert_eq!(s.counts.iter().sum::<u64>(), 2);
        assert!((s.mean_us() - 25.0).abs() < 1e-12);
        let p50 = s.quantile_us(0.5).unwrap();
        assert!(p50 > 0.0 && p50 <= s.max_us());
    }

    #[test]
    fn record_n_is_n_identical_observations() {
        let a = Hist::new();
        let b = Hist::new();
        a.record_n(Duration::from_micros(7), 5);
        for _ in 0..5 {
            b.record(Duration::from_micros(7));
        }
        assert_eq!(a.snapshot(), b.snapshot());
        a.record_n(Duration::from_secs(1), 0); // no-op
        assert_eq!(a.snapshot().count, 5);
    }

    #[test]
    fn empty_snapshot_has_no_quantiles() {
        let s = Hist::new().snapshot();
        assert!(s.is_empty());
        assert_eq!(s.quantile_us(0.5), None);
        assert_eq!(s.tail_quantile_us(0.99), None);
        assert_eq!(s.mean_us(), 0.0);
    }

    #[test]
    fn quantile_edge_cases_for_slo_windows() {
        // empty: no quantile at any q
        assert_eq!(quantile_us(&[], 0.5), None);
        assert_eq!(quantile_us(&vec![0u64; HIST_BUCKETS], 0.0), None);
        // single sample: every quantile is that sample's bucket
        let mut one = vec![0u64; HIST_BUCKETS];
        one[5] = 1;
        for q in [0.0, 0.5, 0.99, 1.0] {
            let v = quantile_us(&one, q).unwrap();
            assert!((v - bucket_rep_ns(5) / 1e3).abs() < 1e-12, "q={q}");
        }
        // all mass in the LAST bucket (the overflow bucket): quantiles
        // land there and stay finite
        let mut last = vec![0u64; HIST_BUCKETS];
        last[HIST_BUCKETS - 1] = 100;
        let v = quantile_us(&last, 0.99).unwrap();
        assert!((v - bucket_rep_ns(HIST_BUCKETS - 1) / 1e3).abs() < 1e-12);
        assert!(v.is_finite());
    }

    #[test]
    fn quantiles_are_monotone_in_q() {
        // spread mass across several buckets; q(0.5) <= q(0.99) and
        // more generally q is non-decreasing — the property the SLO
        // burn-rate windows lean on
        let mut counts = vec![0u64; HIST_BUCKETS];
        counts[3] = 40;
        counts[9] = 30;
        counts[15] = 20;
        counts[30] = 10;
        let qs = [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0];
        let vals: Vec<f64> = qs.iter().map(|&q| quantile_us(&counts, q).unwrap()).collect();
        for w in vals.windows(2) {
            assert!(w[0] <= w[1], "quantile must be non-decreasing: {vals:?}");
        }
        assert!(quantile_us(&counts, 0.5).unwrap() <= quantile_us(&counts, 0.99).unwrap());
        // and the snapshot path preserves it under the max clamp
        let h = Hist::new();
        for us in [10u64, 20, 40, 80, 5000] {
            h.record(Duration::from_micros(us));
        }
        let s = h.snapshot();
        assert!(s.quantile_us(0.5).unwrap() <= s.quantile_us(0.99).unwrap());
        assert!(s.quantile_us(0.99).unwrap() <= s.max_us());
    }

    #[test]
    fn tail_quantiles_need_two_samples() {
        let h = Hist::new();
        h.record(Duration::from_micros(100));
        let s = h.snapshot();
        assert!(s.quantile_us(0.5).is_some(), "one sample is a median");
        assert_eq!(s.tail_quantile_us(0.99), None);
        h.record(Duration::from_micros(200));
        assert!(h.snapshot().tail_quantile_us(0.99).is_some());
    }
}
