//! Request-lifecycle stage taxonomy and per-stage histograms.
//!
//! A served product's end-to-end latency decomposes into five
//! monotonic stages measured off shared boundary `Instant`s in the
//! shard hot path, so per-request stage durations sum to the recorded
//! service time *exactly* (no double counting, no gaps):
//!
//! ```text
//! enqueued ──queue_wait──► collect_start ──batch_wait──► group_start
//!   ──convert──► conv_done ──exec/spmm_exec──► exec_done ──reply──► now
//! ```
//!
//! `queue_wait` is the time the job sat in the shard channel before a
//! worker picked its batch up; `batch_wait` is time spent inside the
//! coalescing window; `convert` covers routing + conversion-cache
//! resolution; `exec` (or `spmm_exec` when the dispatch ran a true
//! SpMM batch path) is the kernel dispatch; `reply` is result
//! marshalling back to the caller. Iterative-session steps are a
//! single `session_step` stage whose duration *is* their end-to-end
//! latency, preserving the sum-equals-e2e invariant pool-wide.

use super::hist::{Hist, HistSnapshot};
use std::fmt;
use std::time::Duration;

/// Lifecycle stages (label order is rendering order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Enqueue → first message of the batch picked up by a worker.
    QueueWait,
    /// Batch pickup → coalescing window closed (group execution start).
    BatchWait,
    /// Routing, length validation, and conversion-cache resolution.
    Convert,
    /// Kernel dispatch on the per-vector path.
    Exec,
    /// Kernel dispatch through a true SpMM batch path.
    SpmmExec,
    /// Kernel dispatch of a solve (SpTRSV / SymGS) — the sequential
    /// per-vector kernel class, never batched into an SpMM launch.
    SolveExec,
    /// One iterative-session step, end to end.
    SessionStep,
    /// Result marshalling back to the caller.
    Reply,
}

/// Number of stage labels.
pub const N_STAGES: usize = Stage::ALL.len();

impl Stage {
    pub const ALL: [Stage; 8] = [
        Stage::QueueWait,
        Stage::BatchWait,
        Stage::Convert,
        Stage::Exec,
        Stage::SpmmExec,
        Stage::SolveExec,
        Stage::SessionStep,
        Stage::Reply,
    ];

    /// Stable snake_case label (metric label / report key).
    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::BatchWait => "batch_wait",
            Stage::Convert => "convert",
            Stage::Exec => "exec",
            Stage::SpmmExec => "spmm_exec",
            Stage::SolveExec => "solve_exec",
            Stage::SessionStep => "session_step",
            Stage::Reply => "reply",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

impl fmt::Display for Stage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One request's stage decomposition, returned on the `Response` when
/// tracing is enabled. Stage durations sum to `Response::service_time`
/// exactly (shared boundary instants).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Trace {
    pub queue_wait: Duration,
    pub batch_wait: Duration,
    pub convert: Duration,
    pub exec: Duration,
    pub reply: Duration,
}

impl Trace {
    /// Sum of all stages (== the request's service time).
    pub fn total(&self) -> Duration {
        self.queue_wait + self.batch_wait + self.convert + self.exec + self.reply
    }
}

/// Per-stage latency histograms, pool-wide (one [`Hist`] per label).
pub struct StageHists {
    hists: [Hist; N_STAGES],
}

impl StageHists {
    pub fn new() -> Self {
        StageHists { hists: std::array::from_fn(|_| Hist::new()) }
    }

    pub fn record(&self, stage: Stage, d: Duration) {
        self.hists[stage.index()].record(d);
    }

    /// Record a batch-shared stage once for `n` riders.
    pub fn record_n(&self, stage: Stage, d: Duration, n: u64) {
        self.hists[stage.index()].record_n(d, n);
    }

    /// Snapshot every stage, `Stage::ALL` order (empty stages included
    /// so reports are deterministic in shape).
    pub fn snapshot(&self) -> Vec<StageStats> {
        Stage::ALL
            .iter()
            .map(|&stage| StageStats { stage, hist: self.hists[stage.index()].snapshot() })
            .collect()
    }
}

impl Default for StageHists {
    fn default() -> Self {
        Self::new()
    }
}

/// One stage's aggregated latency statistics.
#[derive(Debug, Clone)]
pub struct StageStats {
    pub stage: Stage,
    pub hist: HistSnapshot,
}

impl StageStats {
    pub fn count(&self) -> u64 {
        self.hist.count
    }

    /// Accumulated stage time across all requests.
    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.hist.sum_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_labels_are_unique_snake_case() {
        let mut seen = std::collections::HashSet::new();
        for s in Stage::ALL {
            let name = s.name();
            assert!(seen.insert(name), "duplicate stage label {name}");
            assert!(
                name.chars().all(|c| c.is_ascii_lowercase() || c == '_'),
                "label {name} is not snake_case"
            );
            assert_eq!(format!("{s}"), name);
        }
        assert_eq!(Stage::ALL.len(), N_STAGES);
    }

    #[test]
    fn trace_total_sums_stages() {
        let t = Trace {
            queue_wait: Duration::from_micros(1),
            batch_wait: Duration::from_micros(2),
            convert: Duration::from_micros(3),
            exec: Duration::from_micros(4),
            reply: Duration::from_micros(5),
        };
        assert_eq!(t.total(), Duration::from_micros(15));
        assert_eq!(Trace::default().total(), Duration::ZERO);
    }

    #[test]
    fn stage_hists_snapshot_in_label_order() {
        let h = StageHists::new();
        h.record(Stage::Exec, Duration::from_micros(10));
        h.record_n(Stage::Convert, Duration::from_micros(2), 4);
        let snap = h.snapshot();
        assert_eq!(snap.len(), N_STAGES);
        for (i, s) in snap.iter().enumerate() {
            assert_eq!(s.stage, Stage::ALL[i]);
        }
        let by_stage = |stage: Stage| snap.iter().find(|s| s.stage == stage).unwrap().clone();
        assert_eq!(by_stage(Stage::Exec).count(), 1);
        assert_eq!(by_stage(Stage::Convert).count(), 4);
        assert_eq!(by_stage(Stage::Convert).total(), Duration::from_micros(8));
        assert_eq!(by_stage(Stage::QueueWait).count(), 0);
    }
}
