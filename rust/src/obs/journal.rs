//! Control-plane event journal: a bounded, drop-oldest ring of
//! structured events shared by the router and every shard.
//!
//! Counters say *how many* migrations happened; the journal says which
//! matrix moved where, decided by which router version, and what
//! triggered the swap — the causal chain `drift → retrain → hot-swap →
//! migration` becomes a sequence you can assert on. Under a seeded,
//! single-worker run the event sequence is deterministic: every
//! payload field except wall-clock timestamps derives from the request
//! stream and the seed, and [`Event::key`] renders exactly that
//! deterministic part (timestamps and measured durations excluded) so
//! two identical runs produce identical key sequences.
//!
//! Emission takes a mutex, which is fine because events are
//! control-plane by design (swaps, retrains, migrations, session
//! lifecycle) — never one-per-request. The one near-hot-path event,
//! `Explored`, fires at the bandit's exploration rate (a few percent
//! of dispatches), not per request.

use crate::online::JointDecision;
use crate::report::json_escape;
use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Default ring capacity (events, not bytes).
pub const DEFAULT_JOURNAL_CAP: usize = 1024;

/// What caused a router hot-swap or retrain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapTrigger {
    /// Direct `install` call (tests, operator action).
    Manual,
    /// Periodic retrain cadence (`retrain_every`).
    Cadence,
    /// Drift detector rising edge forced an early retrain.
    Drift,
}

impl SwapTrigger {
    pub fn name(self) -> &'static str {
        match self {
            SwapTrigger::Manual => "manual",
            SwapTrigger::Cadence => "cadence",
            SwapTrigger::Drift => "drift",
        }
    }
}

impl fmt::Display for SwapTrigger {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One structured control-plane event.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// A new policy version went live on the router.
    HotSwap { version: u64, trigger: SwapTrigger },
    /// The trainer refit the optimizer on serving evidence.
    Retrain { examples: usize, duration: Duration, trigger: SwapTrigger },
    /// A shard re-decided a registered matrix after a hot-swap and the
    /// serving (format, knob) decision changed.
    Migration { matrix: u64, from: JointDecision, to: JointDecision, decided_by: u64 },
    /// A hot-swap wanted to migrate a matrix but it was pinned by an
    /// open session; the migration runs at session close.
    DeferredMigration { matrix: u64, to: JointDecision, decided_by: u64 },
    /// The bandit routed a dispatch off-policy to score a
    /// counterfactual arm.
    Explored { matrix: u64, from: JointDecision, to: JointDecision },
    /// The drift detector's rising edge: a feature's serving-window
    /// mean shifted `sigma` standard deviations from the reference.
    Drift { feature: &'static str, sigma: f64 },
    /// An iterative session pinned a matrix.
    SessionOpen { session: u64, matrix: u64 },
    /// A session closed after `steps` chained products.
    SessionClose { session: u64, matrix: u64, steps: u64 },
    /// An SLO scope entered a breach episode (both burn-rate windows
    /// violated). `scope` is `None` for the pool, `Some(id)` for a
    /// per-matrix override; `at_requests` is the request-count
    /// evaluation boundary, so seeded runs alert at identical keys.
    SloAlert {
        scope: Option<u64>,
        at_requests: u64,
        signal: &'static str,
        missed: u64,
        tagged: u64,
    },
    /// A breached SLO scope recovered (`recovery_evals` consecutive
    /// clean evaluations).
    SloRecovered { scope: Option<u64>, at_requests: u64 },
    /// An arm's mean modeled energy moved beyond the shift band between
    /// router generations (`ratio_pct` = new/old mean, percent).
    ArmShift { arm: JointDecision, generation: u64, ratio_pct: u64 },
    /// The control plane registered a hot matrix on an additional
    /// shard; `replicas` is the owning-shard count after the copy and
    /// `at_requests` the admission-count evaluation boundary.
    Replicate { matrix: u64, shard: usize, replicas: usize, at_requests: u64 },
    /// A replicated matrix cooled below the hold threshold; `dropped`
    /// replicas were deregistered and routing reverts to the hash home.
    Unreplicate { matrix: u64, dropped: usize, at_requests: u64 },
    /// Routing-policy change: the matrix now routes to the least-loaded
    /// of `owners` shards instead of its hash home.
    Reroute { matrix: u64, owners: usize, at_requests: u64 },
    /// Admission control rejected a request (`reason` is `overloaded`
    /// or `deadline`); journaled at most once per control window — the
    /// shed *counters* track volume.
    Shed { matrix: u64, reason: &'static str, at_requests: u64 },
}

/// Render an SLO scope for event keys (`pool` or `matrix<N>`).
fn scope_key(scope: &Option<u64>) -> String {
    match scope {
        None => "pool".to_string(),
        Some(id) => format!("matrix{id}"),
    }
}

impl EventKind {
    /// Stable snake_case tag for grouping/filtering.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::HotSwap { .. } => "hot_swap",
            EventKind::Retrain { .. } => "retrain",
            EventKind::Migration { .. } => "migration",
            EventKind::DeferredMigration { .. } => "deferred_migration",
            EventKind::Explored { .. } => "explored",
            EventKind::Drift { .. } => "drift",
            EventKind::SessionOpen { .. } => "session_open",
            EventKind::SessionClose { .. } => "session_close",
            EventKind::SloAlert { .. } => "slo_alert",
            EventKind::SloRecovered { .. } => "slo_recovered",
            EventKind::ArmShift { .. } => "arm_shift",
            EventKind::Replicate { .. } => "replicate",
            EventKind::Unreplicate { .. } => "unreplicate",
            EventKind::Reroute { .. } => "reroute",
            EventKind::Shed { .. } => "shed",
        }
    }

    /// Deterministic rendering: every payload field EXCEPT wall-clock
    /// measurements (retrain duration), so seeded runs can compare key
    /// sequences verbatim. Drift sigma stays in — it derives from
    /// matrix structure features, which are deterministic.
    pub fn key(&self) -> String {
        match self {
            EventKind::HotSwap { version, trigger } => {
                format!("hot_swap v{version} trigger={trigger}")
            }
            EventKind::Retrain { examples, trigger, .. } => {
                format!("retrain examples={examples} trigger={trigger}")
            }
            EventKind::Migration { matrix, from, to, decided_by } => {
                format!("migration matrix={matrix} {from}->{to} by=v{decided_by}")
            }
            EventKind::DeferredMigration { matrix, to, decided_by } => {
                format!("deferred_migration matrix={matrix} ->{to} by=v{decided_by}")
            }
            EventKind::Explored { matrix, from, to } => {
                format!("explored matrix={matrix} {from}->{to}")
            }
            EventKind::Drift { feature, sigma } => {
                format!("drift feature={feature} sigma={sigma:.1}")
            }
            EventKind::SessionOpen { session, matrix } => {
                format!("session_open s={session} matrix={matrix}")
            }
            EventKind::SessionClose { session, matrix, steps } => {
                format!("session_close s={session} matrix={matrix} steps={steps}")
            }
            EventKind::SloAlert { scope, at_requests, signal, missed, tagged } => {
                format!(
                    "slo_alert scope={} at={at_requests} signal={signal} missed={missed}/{tagged}",
                    scope_key(scope)
                )
            }
            EventKind::SloRecovered { scope, at_requests } => {
                format!("slo_recovered scope={} at={at_requests}", scope_key(scope))
            }
            EventKind::ArmShift { arm, generation, ratio_pct } => {
                format!("arm_shift arm={arm} gen=v{generation} ratio={ratio_pct}%")
            }
            EventKind::Replicate { matrix, shard, replicas, at_requests } => {
                format!(
                    "replicate matrix={matrix} shard={shard} replicas={replicas} at={at_requests}"
                )
            }
            EventKind::Unreplicate { matrix, dropped, at_requests } => {
                format!("unreplicate matrix={matrix} dropped={dropped} at={at_requests}")
            }
            EventKind::Reroute { matrix, owners, at_requests } => {
                format!("reroute matrix={matrix} owners={owners} at={at_requests}")
            }
            EventKind::Shed { matrix, reason, at_requests } => {
                format!("shed matrix={matrix} reason={reason} at={at_requests}")
            }
        }
    }
}

impl fmt::Display for EventKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EventKind::Retrain { duration, .. } => {
                write!(f, "{} took={:.1}ms", self.key(), duration.as_secs_f64() * 1e3)
            }
            _ => f.write_str(&self.key()),
        }
    }
}

/// A journal entry: monotone sequence number, time since the journal
/// was created, payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub seq: u64,
    pub at: Duration,
    pub kind: EventKind,
}

impl Event {
    /// One-line JSON object (`seq`, `at_us`, `kind`, `detail`).
    pub fn to_json(&self) -> String {
        // json_escape returns the string WITH surrounding quotes
        format!(
            "{{\"seq\":{},\"at_us\":{},\"kind\":\"{}\",\"detail\":{}}}",
            self.seq,
            self.at.as_micros(),
            self.kind.name(),
            json_escape(&self.kind.to_string())
        )
    }
}

impl fmt::Display for Event {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "#{:<4} +{:>9.3}ms  {}", self.seq, self.at.as_secs_f64() * 1e3, self.kind)
    }
}

/// Bounded drop-oldest event ring. One journal is shared by the router
/// (which creates it), the pool telemetry, and every shard.
pub struct Journal {
    epoch: Instant,
    cap: usize,
    seq: AtomicU64,
    dropped: AtomicU64,
    ring: Mutex<VecDeque<Event>>,
}

impl Journal {
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Journal {
            epoch: Instant::now(),
            cap,
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::with_capacity(cap.min(DEFAULT_JOURNAL_CAP))),
        }
    }

    /// Append an event, evicting the oldest entry at capacity.
    pub fn emit(&self, kind: EventKind) {
        let at = self.epoch.elapsed();
        let mut ring = self.ring.lock().expect("journal lock");
        // seq is assigned under the lock so ring order == seq order
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        if ring.len() == self.cap {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(Event { seq, at, kind });
    }

    /// Events currently retained, oldest first.
    pub fn snapshot(&self) -> Vec<Event> {
        self.ring.lock().expect("journal lock").iter().cloned().collect()
    }

    /// Total events ever emitted (including dropped ones).
    pub fn total(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Events evicted by the capacity bound.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Events currently retained.
    pub fn len(&self) -> usize {
        self.ring.lock().expect("journal lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The retained events as a JSON array (one object per line).
    pub fn to_json(&self) -> String {
        let events = self.snapshot();
        if events.is_empty() {
            return "[]\n".to_string();
        }
        let mut out = String::from("[\n");
        for (i, e) in events.iter().enumerate() {
            out.push_str("  ");
            out.push_str(&e.to_json());
            if i + 1 < events.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("]\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Format;

    fn swap(version: u64) -> EventKind {
        EventKind::HotSwap { version, trigger: SwapTrigger::Manual }
    }

    #[test]
    fn empty_journal_snapshot() {
        let j = Journal::new(8);
        assert!(j.is_empty());
        assert_eq!(j.snapshot(), Vec::new());
        assert_eq!(j.total(), 0);
        assert_eq!(j.dropped(), 0);
        assert_eq!(j.to_json(), "[]\n");
    }

    #[test]
    fn bounded_ring_drops_oldest_at_capacity() {
        let j = Journal::new(4);
        for v in 0..10 {
            j.emit(swap(v));
        }
        let events = j.snapshot();
        assert_eq!(events.len(), 4);
        assert_eq!(j.len(), 4);
        assert_eq!(j.total(), 10);
        assert_eq!(j.dropped(), 6);
        // the four NEWEST survive, oldest first, seq contiguous
        let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        for (e, v) in events.iter().zip(6u64..) {
            assert_eq!(e.kind, swap(v));
        }
    }

    #[test]
    fn keys_render_payload_without_wall_clock() {
        let retrain = EventKind::Retrain {
            examples: 96,
            duration: Duration::from_millis(12),
            trigger: SwapTrigger::Drift,
        };
        assert_eq!(retrain.key(), "retrain examples=96 trigger=drift");
        assert!(!retrain.key().contains("12"), "duration must stay out of the key");
        assert!(retrain.to_string().contains("took="));

        let d = JointDecision::format_only(Format::Csr);
        let to = JointDecision::format_only(Format::Ell);
        let m = EventKind::Migration { matrix: 3, from: d, to, decided_by: 2 };
        assert_eq!(m.name(), "migration");
        assert!(m.key().starts_with("migration matrix=3 "), "{}", m.key());
        assert!(m.key().ends_with(" by=v2"), "{}", m.key());
        assert_eq!(
            EventKind::Drift { feature: "avg_nnz", sigma: 5.25 }.key(),
            "drift feature=avg_nnz sigma=5.2"
        );
    }

    #[test]
    fn slo_and_arm_shift_keys_are_deterministic() {
        let alert = EventKind::SloAlert {
            scope: None,
            at_requests: 96,
            signal: "miss_budget",
            missed: 32,
            tagged: 32,
        };
        assert_eq!(alert.name(), "slo_alert");
        assert_eq!(alert.key(), "slo_alert scope=pool at=96 signal=miss_budget missed=32/32");
        assert_eq!(
            EventKind::SloAlert {
                scope: Some(7),
                at_requests: 64,
                signal: "p99",
                missed: 0,
                tagged: 0
            }
            .key(),
            "slo_alert scope=matrix7 at=64 signal=p99 missed=0/0"
        );
        assert_eq!(
            EventKind::SloRecovered { scope: None, at_requests: 192 }.key(),
            "slo_recovered scope=pool at=192"
        );
        let arm = JointDecision::format_only(Format::Csr);
        let shift = EventKind::ArmShift { arm, generation: 3, ratio_pct: 200 };
        assert_eq!(shift.name(), "arm_shift");
        assert_eq!(shift.key(), format!("arm_shift arm={arm} gen=v3 ratio=200%"));
    }

    #[test]
    fn control_plane_keys_are_deterministic() {
        let r = EventKind::Replicate { matrix: 5, shard: 2, replicas: 3, at_requests: 128 };
        assert_eq!(r.name(), "replicate");
        assert_eq!(r.key(), "replicate matrix=5 shard=2 replicas=3 at=128");
        let u = EventKind::Unreplicate { matrix: 5, dropped: 2, at_requests: 256 };
        assert_eq!(u.name(), "unreplicate");
        assert_eq!(u.key(), "unreplicate matrix=5 dropped=2 at=256");
        let rr = EventKind::Reroute { matrix: 5, owners: 3, at_requests: 128 };
        assert_eq!(rr.name(), "reroute");
        assert_eq!(rr.key(), "reroute matrix=5 owners=3 at=128");
        let s = EventKind::Shed { matrix: 9, reason: "deadline", at_requests: 130 };
        assert_eq!(s.name(), "shed");
        assert_eq!(s.key(), "shed matrix=9 reason=deadline at=130");
        // no wall-clock field in any control-plane key
        for k in [r.key(), u.key(), rr.key(), s.key()] {
            assert!(!k.contains("ms") && !k.contains("us"), "{k}");
        }
    }

    #[test]
    fn json_is_one_object_per_event_with_escaped_detail() {
        let j = Journal::new(8);
        j.emit(EventKind::SessionOpen { session: 1, matrix: 2 });
        j.emit(EventKind::SessionClose { session: 1, matrix: 2, steps: 5 });
        let json = j.to_json();
        assert!(json.starts_with("[\n"), "{json}");
        assert!(json.trim_end().ends_with(']'), "{json}");
        assert!(json.contains("\"seq\":0"), "{json}");
        assert!(json.contains("\"kind\":\"session_close\""), "{json}");
        assert!(json.contains("steps=5"), "{json}");
        assert_eq!(json.matches("{\"seq\"").count(), 2);
    }

    #[test]
    fn seq_is_monotone_in_ring_order_under_concurrent_emit() {
        use std::sync::Arc;
        let j = Arc::new(Journal::new(64));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let j = j.clone();
                std::thread::spawn(move || {
                    for v in 0..16 {
                        j.emit(swap(v));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let events = j.snapshot();
        assert_eq!(events.len(), 64);
        assert_eq!(j.total(), 64);
        assert!(events.windows(2).all(|w| w[0].seq < w[1].seq), "seq must be ring-ordered");
    }
}
