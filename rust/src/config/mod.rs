//! Configuration system: a small `key = value` file format (TOML subset;
//! the toml crate is not in the offline mirror) with CLI `--key value`
//! overrides, resolved into the typed [`AppConfig`] that every CLI
//! subcommand and example consumes.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Raw parsed key/value pairs.
#[derive(Debug, Clone, Default)]
pub struct RawConfig {
    pub values: BTreeMap<String, String>,
}

impl RawConfig {
    /// Parse `key = value` lines; `#` comments and blank lines ignored.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with('[') {
                continue; // section headers tolerated for TOML compatibility
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", ln + 1))?;
            let v = v.trim().trim_matches('"');
            values.insert(k.trim().to_string(), v.to_string());
        }
        Ok(RawConfig { values })
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}: bad integer {v}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}: bad integer {v}")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.values.get(key).map(String::as_str) {
            None => Ok(default),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(v) => bail!("{key}: bad bool {v}"),
        }
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.values.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

/// Typed application configuration.
#[derive(Debug, Clone)]
pub struct AppConfig {
    /// Corpus scale multiplier.
    pub scale: usize,
    /// Sweep both GPU profiles.
    pub both_archs: bool,
    /// Global RNG seed.
    pub seed: u64,
    /// AutoML trials per model family.
    pub automl_trials: usize,
    /// Artifact directory for the PJRT runtime.
    pub artifacts_dir: PathBuf,
    /// Dataset TSV path.
    pub dataset_path: PathBuf,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig {
            scale: 1,
            both_archs: true,
            seed: 0xA5BD,
            automl_trials: 12,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            dataset_path: PathBuf::from("reports/dataset.tsv"),
        }
    }
}

impl AppConfig {
    pub fn from_raw(raw: &RawConfig) -> Result<Self> {
        let d = AppConfig::default();
        Ok(AppConfig {
            scale: raw.get_usize("scale", d.scale)?,
            both_archs: raw.get_bool("both_archs", d.both_archs)?,
            seed: raw.get_u64("seed", d.seed)?,
            automl_trials: raw.get_usize("automl_trials", d.automl_trials)?,
            artifacts_dir: PathBuf::from(
                raw.get_str("artifacts_dir", d.artifacts_dir.to_str().unwrap()),
            ),
            dataset_path: PathBuf::from(
                raw.get_str("dataset_path", d.dataset_path.to_str().unwrap()),
            ),
        })
    }

    /// Load `auto-spmv.toml` if present, then apply `--key value` pairs.
    pub fn resolve(file: Option<&Path>, overrides: &[(String, String)]) -> Result<Self> {
        let mut raw = match file {
            Some(p) => RawConfig::load(p)?,
            None => {
                let default = Path::new("auto-spmv.toml");
                if default.exists() {
                    RawConfig::load(default)?
                } else {
                    RawConfig::default()
                }
            }
        };
        for (k, v) in overrides {
            raw.set(k, v);
        }
        Self::from_raw(&raw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_kv_with_comments() {
        let raw = RawConfig::parse("# c\nscale = 2\n[section]\nseed = \"7\"\n").unwrap();
        assert_eq!(raw.get_usize("scale", 1).unwrap(), 2);
        assert_eq!(raw.get_u64("seed", 0).unwrap(), 7);
        assert_eq!(raw.get_usize("missing", 9).unwrap(), 9);
    }

    #[test]
    fn rejects_garbage() {
        assert!(RawConfig::parse("no equals sign").is_err());
        let raw = RawConfig::parse("x = abc").unwrap();
        assert!(raw.get_usize("x", 0).is_err());
        assert!(raw.get_bool("x", false).is_err());
    }

    #[test]
    fn typed_config_with_overrides() {
        let cfg = AppConfig::resolve(
            None,
            &[("scale".into(), "3".into()), ("both_archs".into(), "false".into())],
        )
        .unwrap();
        assert_eq!(cfg.scale, 3);
        assert!(!cfg.both_archs);
        assert_eq!(cfg.automl_trials, AppConfig::default().automl_trials);
    }

    #[test]
    fn bool_forms() {
        let raw = RawConfig::parse("a = 1\nb = false\n").unwrap();
        assert!(raw.get_bool("a", false).unwrap());
        assert!(!raw.get_bool("b", true).unwrap());
    }
}
