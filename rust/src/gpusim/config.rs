//! Kernel configuration — the optimization variables of the paper (§4):
//! thread-block size, `maxrregcount`, memory-hierarchy configuration
//! (compile-time), and sparse format (run-time).

use crate::sparse::Format;

/// L1/shared carve-out choice (§4 observation 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MemConfig {
    /// Compiler default split.
    Default,
    /// Maximize L1 cache (helps irregular x gathers, e.g. CSR).
    PreferL1,
    /// Maximize shared memory (helps staged/tiled kernels, e.g. BELL).
    PreferShared,
}

impl MemConfig {
    pub const ALL: [MemConfig; 3] = [MemConfig::Default, MemConfig::PreferL1, MemConfig::PreferShared];

    pub fn name(self) -> &'static str {
        match self {
            MemConfig::Default => "default",
            MemConfig::PreferL1 => "prefer_l1",
            MemConfig::PreferShared => "prefer_shared",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "default" => Some(MemConfig::Default),
            "prefer_l1" => Some(MemConfig::PreferL1),
            "prefer_shared" => Some(MemConfig::PreferShared),
            _ => None,
        }
    }

    /// Stable class id (ML label).
    pub fn class_id(self) -> usize {
        match self {
            MemConfig::Default => 0,
            MemConfig::PreferL1 => 1,
            MemConfig::PreferShared => 2,
        }
    }

    pub fn from_class_id(id: usize) -> Option<Self> {
        Self::ALL.get(id).copied()
    }
}

/// The paper's sweep values (§6: >15k configuration records).
pub const TB_SIZES: [u32; 5] = [64, 128, 256, 512, 1024];
pub const MAXRREGCOUNT: [u32; 4] = [16, 32, 64, 128];

/// One point in the configuration space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KernelConfig {
    pub format: Format,
    /// Threads per block.
    pub tb_size: u32,
    /// Register cap per thread (nvcc --maxrregcount).
    pub maxrregcount: u32,
    pub mem: MemConfig,
}

impl KernelConfig {
    /// The paper's default baseline: CSR + compiler defaults (§3.1/§7.1).
    /// TB size 1024 is the naive maximize-occupancy choice programmers
    /// default to; registers are uncapped; carve-out untouched.
    pub fn default_baseline() -> Self {
        KernelConfig {
            format: Format::Csr,
            tb_size: 1024,
            maxrregcount: 128, // "no cap" within sweep range
            mem: MemConfig::Default,
        }
    }

    /// Full compile-parameter sweep for one format.
    pub fn sweep_compile(format: Format) -> Vec<KernelConfig> {
        let mut out = Vec::with_capacity(TB_SIZES.len() * MAXRREGCOUNT.len() * MemConfig::ALL.len());
        for &tb_size in &TB_SIZES {
            for &maxrregcount in &MAXRREGCOUNT {
                for &mem in &MemConfig::ALL {
                    out.push(KernelConfig { format, tb_size, maxrregcount, mem });
                }
            }
        }
        out
    }

    /// Full sweep over all formats — one matrix's share of the dataset.
    pub fn sweep_all() -> Vec<KernelConfig> {
        Format::ALL.iter().flat_map(|&f| Self::sweep_compile(f)).collect()
    }

    /// Class ids for the three compile-parameter classification targets
    /// (Table 5 columns): TB size, maxrregcount, memory config.
    pub fn tb_class(&self) -> usize {
        TB_SIZES.iter().position(|&t| t == self.tb_size).expect("tb in sweep")
    }

    pub fn reg_class(&self) -> usize {
        MAXRREGCOUNT.iter().position(|&r| r == self.maxrregcount).expect("regs in sweep")
    }
}

impl std::fmt::Display for KernelConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}/tb{}/r{}/{}",
            self.format, self.tb_size, self.maxrregcount, self.mem.name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_sizes() {
        assert_eq!(KernelConfig::sweep_compile(Format::Csr).len(), 5 * 4 * 3);
        assert_eq!(KernelConfig::sweep_all().len(), 4 * 5 * 4 * 3);
    }

    #[test]
    fn class_ids_roundtrip() {
        for (i, &m) in MemConfig::ALL.iter().enumerate() {
            assert_eq!(m.class_id(), i);
            assert_eq!(MemConfig::from_class_id(i), Some(m));
            assert_eq!(MemConfig::parse(m.name()), Some(m));
        }
        let c = KernelConfig { format: Format::Ell, tb_size: 512, maxrregcount: 32, mem: MemConfig::PreferL1 };
        assert_eq!(c.tb_class(), 3);
        assert_eq!(c.reg_class(), 1);
    }

    #[test]
    fn default_baseline_is_csr() {
        let d = KernelConfig::default_baseline();
        assert_eq!(d.format, Format::Csr);
        assert_eq!(d.mem, MemConfig::Default);
    }

    #[test]
    fn display_format() {
        let c = KernelConfig::default_baseline();
        assert_eq!(c.to_string(), "csr/tb1024/r128/default");
    }
}
