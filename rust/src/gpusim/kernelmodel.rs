//! Per-format kernel workload characterization.
//!
//! For a given matrix this derives, per sparse format, the quantities the
//! execution model needs: executed FLOPs (padding included — ELL's waste,
//! §5.5 observation 4), streamed matrix bytes, gather counts, warp-level
//! load imbalance (CSR's weakness, §2.3), SIMT divergence, the kernel's
//! natural register demand, and its shared-memory staging footprint.

use super::memory::{reuse_curve, ReuseCurve};
use crate::sparse::convert::{self, ConvertParams};
use crate::sparse::{Csr, Format, Storage};

/// Workload profile of one (matrix, format) pair — architecture- and
/// configuration-independent; the config is applied by `exec`.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelProfile {
    pub format: Format,
    /// Useful FLOPs: 2 * nnz (the MFLOPS numerator, §6.3).
    pub flops_useful: u64,
    /// FLOPs actually executed, incl. zero padding.
    pub flops_executed: u64,
    /// Format arrays streamed once per product (bytes).
    pub matrix_bytes: u64,
    /// Output writes (bytes).
    pub y_bytes: u64,
    /// x gather count (== stored entries walked).
    pub x_accesses: u64,
    /// Reuse curve of the x-access stream.
    pub reuse: ReuseCurve,
    /// Warp-granularity load imbalance factor (>= 1); 1 for fixed-width
    /// formats whose padding is already counted in `flops_executed`.
    pub imbalance: f64,
    /// SIMT divergence factor (>= 1) on the compute pipe.
    pub divergence: f64,
    /// Natural register demand of the kernel (regs/thread before capping).
    pub regs_needed: u32,
    /// Shared-memory staging per thread (bytes) when the kernel tiles x
    /// through shared memory (0 = kernel relies on L1 only).
    pub shared_per_thread: u32,
    /// Rows processed per thread-launch (grid sizing basis).
    pub threads_of_work: u64,
    /// Structural locality bonus for x gathers (block formats touch
    /// contiguous x segments): fraction of misses converted to hits.
    pub gather_bonus: f64,
}

impl KernelProfile {
    /// The workload of the same kernel executing a `k`-vector batch
    /// (SpMM): the matrix arrays stream ONCE for the whole batch —
    /// that is the entire point of batched dispatch — while x gathers,
    /// y writes, FLOPs and grid work scale with `k`. Feeding this
    /// through [`super::simulate`] models one batched launch; dividing
    /// its energy/latency by `k` gives the per-request share the
    /// serving telemetry and the online observations charge.
    pub fn batched(&self, k: u64) -> KernelProfile {
        let k = k.max(1);
        KernelProfile {
            flops_useful: self.flops_useful * k,
            flops_executed: self.flops_executed * k,
            y_bytes: self.y_bytes * k,
            x_accesses: self.x_accesses * k,
            threads_of_work: self.threads_of_work * k,
            ..self.clone()
        }
    }
}

/// Natural per-thread register demand of each kernel implementation.
/// Values follow nvcc's typical allocation for scalar CSR / ELL kernels
/// and the heavier blocked kernels (accumulator tiles).
pub fn regs_needed(format: Format) -> u32 {
    match format {
        Format::Csr => 48,
        Format::Ell => 36,
        Format::Bell => 72,
        Format::Sell => 44,
    }
}

/// Shared staging bytes per thread (used when the carve-out prefers
/// shared memory and the kernel tiles x).
pub fn shared_per_thread(format: Format) -> u32 {
    match format {
        Format::Csr => 0,  // pure L1 gathers
        Format::Ell => 4,  // stages one x word per lane
        Format::Sell => 4,
        Format::Bell => 16, // stages x blocks + accumulators
    }
}

/// Warp-level imbalance of scalar CSR: each warp's runtime is its longest
/// row; efficiency = total work / (32 * sum of per-warp maxima).
fn csr_imbalance(a: &Csr, warp: usize) -> f64 {
    if a.n_rows == 0 {
        return 1.0;
    }
    let mut padded: u64 = 0;
    let mut total: u64 = 0;
    let mut r = 0;
    while r < a.n_rows {
        let end = (r + warp).min(a.n_rows);
        let mut mx = 0u64;
        for i in r..end {
            let l = a.row_len(i) as u64;
            mx = mx.max(l);
            total += l;
        }
        padded += mx * warp as u64;
        r = end;
    }
    if total == 0 {
        1.0
    } else {
        padded as f64 / total as f64
    }
}

/// Build the profile of one (matrix, format) pair.
pub fn profile(a: &Csr, format: Format, p: ConvertParams) -> KernelProfile {
    profile_with_reuse(a, format, p, reuse_curve(a))
}

/// [`profile`] with a precomputed reuse curve — the curve is a property
/// of the matrix, not the format, so sweeping all four formats should
/// measure it once (EXPERIMENTS.md §Perf iteration 1).
pub fn profile_with_reuse(
    a: &Csr,
    format: Format,
    p: ConvertParams,
    reuse: ReuseCurve,
) -> KernelProfile {
    let nnz = a.vals.len() as u64;
    let y_bytes = (a.n_rows * 4) as u64;

    let (flops_executed, matrix_bytes, x_accesses, imbalance, divergence, gather_bonus, threads) =
        match format {
            Format::Csr => {
                let imb = csr_imbalance(a, 32);
                // row_ptr + cols + vals; gathers = nnz; divergence from
                // per-row loop trip-count variance folded into imbalance.
                (
                    2 * nnz,
                    a.storage_bytes() as u64,
                    nnz,
                    imb,
                    1.15, // loop/branch overhead of the scalar kernel
                    0.0,
                    a.n_rows as u64,
                )
            }
            Format::Ell => {
                let ell = convert::csr_to_ell(a);
                let stored = ell.stored_entries() as u64;
                (
                    2 * stored,
                    ell.storage_bytes() as u64,
                    stored,
                    1.0, // width-uniform: no warp imbalance
                    1.0,
                    0.0,
                    a.n_rows as u64,
                )
            }
            Format::Bell => {
                let bell = convert::csr_to_bell(a, p.bell_bh, p.bell_bw);
                let stored = bell.stored_entries() as u64;
                // One gather per block column serves bh*bw MACs; the
                // contiguous x block converts most misses to streaming.
                (
                    2 * stored,
                    bell.storage_bytes() as u64,
                    (bell.bcols.len() as u64) * p.bell_bw as u64,
                    1.0,
                    1.0,
                    0.55,
                    a.n_rows as u64,
                )
            }
            Format::Sell => {
                let sell = convert::csr_to_sell(a, p.sell_h);
                let stored = sell.stored_entries() as u64;
                // imbalance confined to slice granularity; approximate
                // with CSR imbalance at slice-height warps, bounded by
                // the padding already counted in `stored`.
                let imb = csr_imbalance(a, p.sell_h).min(
                    stored as f64 / nnz.max(1) as f64,
                );
                (
                    2 * stored,
                    sell.storage_bytes() as u64,
                    stored,
                    imb.max(1.0),
                    1.05,
                    0.0,
                    a.n_rows as u64,
                )
            }
        };

    KernelProfile {
        format,
        flops_useful: 2 * nnz,
        flops_executed,
        matrix_bytes,
        y_bytes,
        x_accesses,
        reuse,
        imbalance,
        divergence,
        regs_needed: regs_needed(format),
        shared_per_thread: shared_per_thread(format),
        threads_of_work: threads,
        gather_bonus,
    }
}

/// Profiles for all four formats of one matrix (shares the reuse curve).
pub fn profile_all(a: &Csr, p: ConvertParams) -> Vec<KernelProfile> {
    let reuse = reuse_curve(a);
    Format::ALL.iter().map(|&f| profile_with_reuse(a, f, p, reuse)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{patterns, Rng};
    use crate::sparse::convert::coo_to_csr;

    fn skewed() -> Csr {
        let mut rng = Rng::new(11);
        coo_to_csr(&patterns::powerlaw(&mut rng, 1024, 1024, 2.0, 8.0, 256))
    }

    fn regular() -> Csr {
        let mut rng = Rng::new(12);
        coo_to_csr(&patterns::diagonals(&mut rng, 1024, &[-8, 0, 8], 1.0))
    }

    #[test]
    fn csr_imbalance_high_on_powerlaw_low_on_regular() {
        let p = ConvertParams::default();
        let imb_skew = profile(&skewed(), Format::Csr, p).imbalance;
        let imb_reg = profile(&regular(), Format::Csr, p).imbalance;
        assert!(imb_skew > 2.0, "powerlaw imbalance {imb_skew}");
        assert!(imb_reg < 1.2, "regular imbalance {imb_reg}");
    }

    #[test]
    fn ell_explodes_on_powerlaw() {
        let p = ConvertParams::default();
        let a = skewed();
        let ell = profile(&a, Format::Ell, p);
        let csr = profile(&a, Format::Csr, p);
        assert!(ell.flops_executed > 5 * csr.flops_executed,
            "ELL padding waste should explode on powerlaw: {} vs {}",
            ell.flops_executed, csr.flops_executed);
    }

    #[test]
    fn ell_tight_on_regular() {
        let p = ConvertParams::default();
        let a = regular();
        let ell = profile(&a, Format::Ell, p);
        assert!(ell.flops_executed as f64 <= 1.5 * ell.flops_useful as f64);
    }

    #[test]
    fn sell_pads_less_than_ell_on_skewed() {
        let p = ConvertParams { sell_h: 8, ..Default::default() };
        let a = skewed();
        let sell = profile(&a, Format::Sell, p);
        let ell = profile(&a, Format::Ell, p);
        assert!(sell.flops_executed < ell.flops_executed);
        assert!(sell.matrix_bytes < ell.matrix_bytes);
    }

    #[test]
    fn useful_flops_format_invariant() {
        let p = ConvertParams::default();
        let a = skewed();
        let profs = profile_all(&a, p);
        assert!(profs.windows(2).all(|w| w[0].flops_useful == w[1].flops_useful));
        assert_eq!(profs.len(), 4);
    }

    #[test]
    fn bell_fewer_gathers_with_bonus() {
        let mut rng = Rng::new(13);
        let a = coo_to_csr(&patterns::blocks(&mut rng, 512, 8, 8, 3.0, 6, 0.95));
        let p = ConvertParams::default();
        let bell = profile(&a, Format::Bell, p);
        let csr = profile(&a, Format::Csr, p);
        assert!(bell.gather_bonus > 0.0);
        assert!(bell.x_accesses < csr.x_accesses,
            "BELL gathers whole blocks: {} < {}", bell.x_accesses, csr.x_accesses);
    }

    #[test]
    fn batched_profile_charges_matrix_stream_once() {
        let p = ConvertParams::default();
        let a = skewed();
        let one = profile(&a, Format::Ell, p);
        assert_eq!(one.batched(1), one, "k = 1 is the identity");
        let k = 8u64;
        let b = one.batched(k);
        assert_eq!(b.matrix_bytes, one.matrix_bytes, "matrix streamed once per batch");
        assert_eq!(b.flops_executed, k * one.flops_executed);
        assert_eq!(b.x_accesses, k * one.x_accesses);
        assert_eq!(b.y_bytes, k * one.y_bytes);
        assert_eq!(b.threads_of_work, k * one.threads_of_work);
    }

    #[test]
    fn batched_dispatch_is_cheaper_per_request_than_k_launches() {
        use crate::gpusim::{simulate, turing_gtx1650m, KernelConfig, MemConfig};
        let p = ConvertParams::default();
        let a = regular();
        let arch = turing_gtx1650m();
        for fmt in Format::ALL {
            let prof = profile(&a, fmt, p);
            let cfg = KernelConfig {
                format: fmt,
                tb_size: 256,
                maxrregcount: 64,
                mem: MemConfig::Default,
            };
            let (single, _) = simulate(&arch, &prof, &cfg);
            let k = 8u64;
            let (batch, _) = simulate(&arch, &prof.batched(k), &cfg);
            assert!(
                batch.energy_j < k as f64 * single.energy_j,
                "{fmt}: batched energy {} must beat {} x single {}",
                batch.energy_j,
                k,
                single.energy_j
            );
            assert!(
                batch.latency_s < k as f64 * single.latency_s,
                "{fmt}: batched latency must amortize the matrix stream + launch"
            );
        }
    }

    #[test]
    fn imbalance_exactly_one_on_uniform_rows() {
        let mut csr_rows = vec![0u32];
        let mut cols = Vec::new();
        for r in 0..64u32 {
            for k in 0..4u32 {
                cols.push((r + k) % 64);
            }
            csr_rows.push(cols.len() as u32);
        }
        let vals = vec![1.0; cols.len()];
        let a = Csr::new(64, 64, csr_rows, cols, vals);
        assert_eq!(profile(&a, Format::Csr, ConvertParams::default()).imbalance, 1.0);
    }
}
