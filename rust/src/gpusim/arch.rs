//! GPU architecture profiles — the paper's two testbeds (Table 3):
//! NVIDIA GTX 1650-mobile (Turing) and GTX 1080 (Pascal).
//!
//! Parameters come from the paper's Table 3 where given (core counts,
//! clocks, memory sizes) and from NVIDIA's published architecture specs
//! for the rest (SM resources, bandwidths, power envelopes).

/// Static description of a GPU architecture + board.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuArch {
    pub name: &'static str,
    /// Streaming multiprocessor count.
    pub sm_count: u32,
    pub cores_per_sm: u32,
    /// Boost/base clock used for peak-rate math (GHz). Table 3: 1.6 GHz.
    pub clock_ghz: f64,
    /// DRAM bandwidth (GB/s).
    pub dram_bw_gbs: f64,
    /// L2 cache size (bytes, device-wide).
    pub l2_bytes: usize,
    /// Unified L1/shared capacity per SM (bytes).
    pub l1_shared_bytes: usize,
    /// Whether the L1/shared split is configurable (Turing carve-out) or
    /// fixed (Pascal's dedicated 24 KiB L1).
    pub configurable_carveout: bool,
    /// Register file per SM (32-bit registers).
    pub regs_per_sm: u32,
    pub max_threads_per_sm: u32,
    pub max_blocks_per_sm: u32,
    pub max_warps_per_sm: u32,
    pub warp_size: u32,
    /// Register allocation granularity (regs rounded up per warp).
    pub reg_alloc_unit: u32,
    /// Board power envelope (W).
    pub tdp_w: f64,
    /// Idle draw excluded from energy per §6.3 (W).
    pub idle_w: f64,
    /// Occupancy at which memory latency is fully hidden for streaming
    /// kernels (fraction of max warps) — lower on Turing (improved
    /// scheduling) than Pascal.
    pub occ_saturation: f64,
}

impl GpuArch {
    /// Peak single-precision FLOP/s (FMA = 2 flops/cycle/core).
    pub fn peak_flops(&self) -> f64 {
        self.sm_count as f64 * self.cores_per_sm as f64 * 2.0 * self.clock_ghz * 1e9
    }

    /// Peak DRAM bytes/s.
    pub fn peak_bw(&self) -> f64 {
        self.dram_bw_gbs * 1e9
    }

    pub fn total_cores(&self) -> u32 {
        self.sm_count * self.cores_per_sm
    }
}

/// NVIDIA GTX 1650-mobile — Turing TU117, the paper's primary device.
/// Table 3: 896 CUDA cores, 4 GB GDDR5, 1.6 GHz.
pub fn turing_gtx1650m() -> GpuArch {
    GpuArch {
        name: "GTX1650m-Turing",
        sm_count: 14,
        cores_per_sm: 64,
        clock_ghz: 1.6,
        dram_bw_gbs: 128.0,
        l2_bytes: 1024 * 1024,
        l1_shared_bytes: 96 * 1024,
        configurable_carveout: true,
        regs_per_sm: 65536,
        max_threads_per_sm: 1024,
        max_blocks_per_sm: 16,
        max_warps_per_sm: 32,
        warp_size: 32,
        reg_alloc_unit: 256,
        tdp_w: 50.0,
        idle_w: 7.0,
        occ_saturation: 0.70,
    }
}

/// NVIDIA GTX 1080 — Pascal GP104, the paper's cross-check device (§7.6).
/// Table 3: 2560 CUDA cores, 8 GB GDDR5X, 1.6 GHz.
pub fn pascal_gtx1080() -> GpuArch {
    GpuArch {
        name: "GTX1080-Pascal",
        sm_count: 20,
        cores_per_sm: 128,
        clock_ghz: 1.6,
        dram_bw_gbs: 320.0,
        l2_bytes: 2 * 1024 * 1024,
        l1_shared_bytes: 96 * 1024, // 96 KiB shared + dedicated L1; modelled unified
        configurable_carveout: false,
        regs_per_sm: 65536,
        max_threads_per_sm: 2048,
        max_blocks_per_sm: 32,
        max_warps_per_sm: 64,
        warp_size: 32,
        reg_alloc_unit: 256,
        tdp_w: 180.0,
        idle_w: 10.0,
        occ_saturation: 0.80,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_counts_match_table3() {
        assert_eq!(turing_gtx1650m().total_cores(), 896);
        assert_eq!(pascal_gtx1080().total_cores(), 2560);
    }

    #[test]
    fn peak_rates_sane() {
        let t = turing_gtx1650m();
        // 896 cores * 2 * 1.6 GHz = 2.87 TFLOP/s
        assert!((t.peak_flops() / 1e12 - 2.8672).abs() < 1e-3);
        assert_eq!(t.peak_bw(), 128e9);
        let p = pascal_gtx1080();
        assert!(p.peak_flops() > 2.0 * t.peak_flops());
    }

    #[test]
    fn pascal_has_more_warp_slots() {
        assert!(pascal_gtx1080().max_warps_per_sm > turing_gtx1650m().max_warps_per_sm);
    }
}
