//! CUDA occupancy calculator — reproduces the resource-limit arithmetic
//! of NVIDIA's occupancy calculator for the modelled architectures.
//!
//! Occupancy (active warps / max warps per SM) is the pivot of the
//! paper's compile-parameter trade-offs (§4 observations 1-2): raising
//! `tb_size` or lowering `maxrregcount` raises occupancy, until register
//! spilling or scheduling-slot waste pushes back.

use super::arch::GpuArch;
use super::config::MemConfig;

/// Resource usage of one kernel launch configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaunchResources {
    /// Threads per block.
    pub tb_size: u32,
    /// Registers actually allocated per thread (post maxrregcount cap).
    pub regs_per_thread: u32,
    /// Static shared memory per block (bytes).
    pub shared_per_block: u32,
}

/// Occupancy analysis result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Occupancy {
    /// Blocks concurrently resident per SM.
    pub blocks_per_sm: u32,
    /// Active warps per SM.
    pub active_warps: u32,
    /// active_warps / max_warps_per_sm in [0, 1].
    pub fraction: f64,
    /// Which resource capped residency (for diagnostics/ablation).
    pub limiter: Limiter,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Limiter {
    Blocks,
    Threads,
    Registers,
    SharedMemory,
}

/// Shared-memory capacity per SM under a carve-out choice.
///
/// On configurable (Turing) parts PreferL1 shrinks shared to 1/3 and
/// PreferShared grows it to 2/3 of the unified capacity; the default is
/// an even split. On fixed (Pascal) parts the choice only affects the
/// cache model, not shared capacity.
pub fn shared_capacity(arch: &GpuArch, mem: MemConfig) -> u32 {
    let total = arch.l1_shared_bytes as u32;
    if !arch.configurable_carveout {
        return (total * 2) / 3; // Pascal: 96 KiB shared of the modelled pool
    }
    match mem {
        MemConfig::Default => total / 2,
        MemConfig::PreferL1 => total / 3,
        MemConfig::PreferShared => (total * 2) / 3,
    }
}

/// Effective L1 cache per SM under a carve-out choice (the complement of
/// [`shared_capacity`] on configurable parts; fixed otherwise).
pub fn l1_capacity(arch: &GpuArch, mem: MemConfig) -> u32 {
    let total = arch.l1_shared_bytes as u32;
    if !arch.configurable_carveout {
        return total / 3;
    }
    total - shared_capacity(arch, mem)
}

/// Compute occupancy for a launch configuration on an architecture.
pub fn occupancy(arch: &GpuArch, res: LaunchResources, mem: MemConfig) -> Occupancy {
    let warps_per_block = res.tb_size.div_ceil(arch.warp_size);

    // Limit 1: hardware block slots.
    let by_blocks = arch.max_blocks_per_sm;

    // Limit 2: thread slots.
    let by_threads = (arch.max_threads_per_sm / res.tb_size).max(0);

    // Limit 3: register file. Registers allocate per warp in units of
    // reg_alloc_unit.
    let regs_per_warp = (res.regs_per_thread * arch.warp_size).div_ceil(arch.reg_alloc_unit)
        * arch.reg_alloc_unit;
    let regs_per_block = regs_per_warp * warps_per_block;
    let by_regs = if regs_per_block == 0 { u32::MAX } else { arch.regs_per_sm / regs_per_block };

    // Limit 4: shared memory.
    let shared_cap = shared_capacity(arch, mem);
    let by_shared = if res.shared_per_block == 0 {
        u32::MAX
    } else {
        shared_cap / res.shared_per_block
    };

    let (blocks, limiter) = [
        (by_blocks, Limiter::Blocks),
        (by_threads, Limiter::Threads),
        (by_regs, Limiter::Registers),
        (by_shared, Limiter::SharedMemory),
    ]
    .into_iter()
    .min_by_key(|&(b, _)| b)
    .unwrap();

    let blocks = blocks.max(0);
    let active_warps = (blocks * warps_per_block).min(arch.max_warps_per_sm);
    Occupancy {
        blocks_per_sm: blocks,
        active_warps,
        fraction: active_warps as f64 / arch.max_warps_per_sm as f64,
        limiter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::arch::{pascal_gtx1080, turing_gtx1650m};

    fn res(tb: u32, regs: u32, shared: u32) -> LaunchResources {
        LaunchResources { tb_size: tb, regs_per_thread: regs, shared_per_block: shared }
    }

    #[test]
    fn small_regs_full_occupancy_turing() {
        let a = turing_gtx1650m();
        // 256 threads, 32 regs: 4 blocks x 8 warps = 32 warps = max
        let o = occupancy(&a, res(256, 32, 0), MemConfig::Default);
        assert_eq!(o.active_warps, a.max_warps_per_sm);
        assert!((o.fraction - 1.0).abs() < 1e-12);
    }

    #[test]
    fn heavy_registers_limit_occupancy() {
        let a = turing_gtx1650m();
        // 128 regs/thread: per warp 4096 regs; 65536/4096 = 16 warps
        let o = occupancy(&a, res(256, 128, 0), MemConfig::Default);
        assert_eq!(o.limiter, Limiter::Registers);
        assert_eq!(o.active_warps, 16);
        assert!((o.fraction - 0.5).abs() < 1e-12);
    }

    #[test]
    fn tiny_blocks_hit_block_slot_limit() {
        let a = turing_gtx1650m();
        // 64-thread blocks, cheap: block-slot limited at 16 -> 32 warps max anyway
        let o = occupancy(&a, res(64, 16, 0), MemConfig::Default);
        assert_eq!(o.limiter, Limiter::Blocks);
        assert_eq!(o.blocks_per_sm, 16);
        assert_eq!(o.active_warps, 32);
    }

    #[test]
    fn shared_memory_limits_under_prefer_l1() {
        let a = turing_gtx1650m();
        // 16 KiB/block static shared: PreferL1 gives 32 KiB -> 2 blocks
        let o = occupancy(&a, res(256, 32, 16 * 1024), MemConfig::PreferL1);
        assert_eq!(o.limiter, Limiter::SharedMemory);
        assert_eq!(o.blocks_per_sm, 2);
        // PreferShared gives 64 KiB -> 4 blocks
        let o2 = occupancy(&a, res(256, 32, 16 * 1024), MemConfig::PreferShared);
        assert_eq!(o2.blocks_per_sm, 4);
    }

    #[test]
    fn pascal_carveout_fixed() {
        let a = pascal_gtx1080();
        assert_eq!(
            shared_capacity(&a, MemConfig::PreferL1),
            shared_capacity(&a, MemConfig::PreferShared)
        );
        assert_eq!(l1_capacity(&a, MemConfig::Default), a.l1_shared_bytes as u32 / 3);
    }

    #[test]
    fn occupancy_monotone_decreasing_in_registers() {
        let a = turing_gtx1650m();
        let mut last = f64::INFINITY;
        for regs in [16, 32, 64, 128, 255] {
            let o = occupancy(&a, res(512, regs, 0), MemConfig::Default);
            assert!(o.fraction <= last + 1e-12);
            last = o.fraction;
        }
    }

    #[test]
    fn l1_plus_shared_conserved_on_turing() {
        let a = turing_gtx1650m();
        for m in MemConfig::ALL {
            assert_eq!(
                l1_capacity(&a, m) + shared_capacity(&a, m),
                a.l1_shared_bytes as u32
            );
        }
    }
}
