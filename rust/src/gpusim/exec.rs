//! Execution model: (architecture, kernel profile, configuration) ->
//! latency / energy / average power / energy efficiency.
//!
//! The model is an analytic SM/warp/memory roofline reproducing the
//! mechanisms behind the paper's §4 observations:
//!   * occupancy rises with TB size and falls with register usage
//!     (occupancy calculator);
//!   * capping `maxrregcount` below the kernel's demand spills registers
//!     to local memory — extra DRAM traffic;
//!   * the L1/shared carve-out moves the x-gather hit rate (reuse curve)
//!     and the staging kernels' shared-memory occupancy limit;
//!   * formats differ in streamed bytes, executed FLOPs, warp imbalance
//!     and divergence (kernel profile);
//!   * partial waves (grid quantization) waste SMs at large TB sizes.

use super::arch::GpuArch;
use super::config::{KernelConfig, MemConfig};
use super::kernelmodel::KernelProfile;
use super::occupancy::{l1_capacity, occupancy, LaunchResources, Occupancy};

/// Fixed kernel-launch overhead (seconds).
const LAUNCH_OVERHEAD_S: f64 = 5e-6;
/// DRAM sector fetched per x-gather miss (bytes).
const MISS_SECTOR_BYTES: f64 = 32.0;
/// Local-memory round trips per spilled register per inner iteration.
const SPILL_BYTES_PER_REG_PER_ENTRY: f64 = 0.3;
/// Fraction of spill traffic absorbed by L2 (never reaches DRAM).
const SPILL_L2_ABSORB: f64 = 0.5;

/// The four optimization objectives (paper §6.3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    /// Kernel latency (seconds).
    pub latency_s: f64,
    /// Energy per product (joules), idle excluded.
    pub energy_j: f64,
    /// Average power draw (watts), idle excluded.
    pub avg_power_w: f64,
    /// Energy efficiency (MFLOPS/W) over *useful* flops.
    pub mflops_per_watt: f64,
}

/// The four objectives as an enum (classification target selector).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Objective {
    Latency,
    Energy,
    AvgPower,
    EnergyEff,
}

impl Objective {
    pub const ALL: [Objective; 4] =
        [Objective::Latency, Objective::Energy, Objective::AvgPower, Objective::EnergyEff];

    pub fn name(self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Energy => "energy",
            Objective::AvgPower => "avg_power",
            Objective::EnergyEff => "energy_eff",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Objective::ALL.iter().copied().find(|o| o.name() == s)
    }

    /// Extract this objective's value from a measurement.
    pub fn value(self, m: &Measurement) -> f64 {
        match self {
            Objective::Latency => m.latency_s,
            Objective::Energy => m.energy_j,
            Objective::AvgPower => m.avg_power_w,
            Objective::EnergyEff => m.mflops_per_watt,
        }
    }

    /// True when *smaller* values are better (all but MFLOPS/W).
    pub fn minimize(self) -> bool {
        !matches!(self, Objective::EnergyEff)
    }

    /// True if `a` is better than `b` under this objective.
    pub fn better(self, a: f64, b: f64) -> bool {
        if self.minimize() {
            a < b
        } else {
            a > b
        }
    }
}

/// Diagnostic breakdown (exposed for ablation benches / tests).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Breakdown {
    pub occ: Occupancy,
    pub t_mem_s: f64,
    pub t_comp_s: f64,
    pub dram_bytes: f64,
    pub x_hit_rate: f64,
    pub spill_regs: u32,
    pub tail_utilization: f64,
    pub bw_utilization: f64,
    pub flop_utilization: f64,
}

/// Run the analytic model. Returns the objectives + breakdown.
pub fn simulate(arch: &GpuArch, prof: &KernelProfile, cfg: &KernelConfig) -> (Measurement, Breakdown) {
    debug_assert_eq!(prof.format, cfg.format);

    // ---- register allocation & spill --------------------------------
    // nvcc guarantees the kernel launches: if a block's registers cannot
    // fit the SM's register file, allocation is clamped and the excess
    // demand spills (a tb1024 BELL kernel cannot keep 72 regs/thread).
    let warps_per_block = cfg.tb_size.div_ceil(arch.warp_size);
    let max_fit = (arch.regs_per_sm / (warps_per_block * arch.warp_size)).max(16);
    let regs_alloc = prof.regs_needed.min(cfg.maxrregcount).min(max_fit);
    let spill_regs = prof.regs_needed.saturating_sub(regs_alloc);

    // ---- shared usage: staging kernels use shared iff the carve-out
    // gives them room (PreferShared), mirroring nvcc's launch bounds ----
    let use_shared_staging =
        prof.shared_per_thread > 0 && cfg.mem == MemConfig::PreferShared;
    let shared_per_block = if use_shared_staging {
        prof.shared_per_thread * cfg.tb_size
    } else {
        0
    };

    // ---- occupancy ----------------------------------------------------
    let occ = occupancy(
        arch,
        LaunchResources {
            tb_size: cfg.tb_size,
            regs_per_thread: regs_alloc.max(16),
            shared_per_block,
        },
        cfg.mem,
    );

    // ---- grid fill & tail quantization -----------------------------------
    // How full the machine's block slots are across all waves. Small
    // grids (or oversized TBs) leave SMs idle; the derating below is
    // sub-linear for bandwidth (a few SMs still drive much of DRAM) and
    // linear for the ALUs.
    let blocks_total = prof.threads_of_work.div_ceil(cfg.tb_size as u64).max(1);
    let concurrent = (arch.sm_count as u64 * occ.blocks_per_sm.max(1) as u64).max(1);
    let waves = blocks_total.div_ceil(concurrent);
    let tail_utilization = blocks_total as f64 / (waves * concurrent) as f64;
    // SMs covered by the grid: with fewer blocks than SMs, part of the
    // chip idles (big TBs on small matrices). Intra-SM slot fill is
    // already captured by occupancy; multi-wave tails are second-order.
    let sm_fill = (blocks_total as f64 / arch.sm_count as f64).min(1.0);

    // ---- x-gather hit rate (capacities at model scale, see
    // memory::CACHE_MODEL_SCALE) -------------------------------------------
    let scale = super::memory::CACHE_MODEL_SCALE;
    let l1 = l1_capacity(arch, cfg.mem) as usize / scale;
    // staging through shared effectively enlarges the on-chip pool
    let effective_cache =
        l1 + if use_shared_staging { shared_per_block as usize / scale } else { 0 };
    let mut hit = prof.reuse.hit_rate(effective_cache);
    // L2 catches a share of L1 misses (device-wide, format-independent)
    let l2_catch = 0.5 * prof.reuse.hit_rate(arch.l2_bytes / arch.sm_count as usize * 4 / scale);
    hit += (1.0 - hit) * l2_catch;
    // block formats gather contiguous x segments
    hit += (1.0 - hit) * prof.gather_bonus;
    let hit = hit.clamp(0.0, 1.0);

    // ---- DRAM traffic ---------------------------------------------------
    let x_miss_bytes = prof.x_accesses as f64 * (1.0 - hit) * MISS_SECTOR_BYTES;
    let spill_bytes = spill_regs as f64
        * SPILL_BYTES_PER_REG_PER_ENTRY
        * (prof.flops_executed as f64 / 2.0)
        * (1.0 - SPILL_L2_ABSORB);
    let dram_bytes = prof.matrix_bytes as f64 + prof.y_bytes as f64 + x_miss_bytes + spill_bytes;

    // ---- memory time: bandwidth derated by occupancy-driven latency
    // hiding (memory-bound kernels need enough warps in flight) ----------
    let lat_hide = (occ.fraction / arch.occ_saturation).min(1.0);
    // per-format streaming coalescing efficiency. CSR-scalar threads walk
    // their rows sequentially, so adjacent lanes read strided addresses —
    // the classic Bell & Garland result that ELL's column-major layout
    // exists to fix. ELL/BELL stream fully coalesced; SELL nearly so.
    let coalesce = match cfg.format {
        crate::sparse::Format::Csr => 0.65,
        crate::sparse::Format::Ell => 1.0,
        crate::sparse::Format::Bell => 1.0,
        crate::sparse::Format::Sell => 0.92,
    };
    let bw_eff = arch.peak_bw() * lat_hide * coalesce * sm_fill.powf(0.35);
    let t_mem = dram_bytes / bw_eff.max(1.0);

    // ---- compute time ----------------------------------------------------
    let issue_eff = (occ.fraction / 0.25).min(1.0); // ALUs saturate early
    let flops_eff = arch.peak_flops() * issue_eff * sm_fill;
    let t_comp = prof.flops_executed as f64 * prof.imbalance * prof.divergence
        / flops_eff.max(1.0);

    // ---- latency ----------------------------------------------------------
    let t_work = t_mem.max(t_comp);
    let latency = t_work + LAUNCH_OVERHEAD_S;

    // ---- power (idle excluded per §6.3) ------------------------------------
    // Dynamic power is SUB-LINEAR in delivered bandwidth/FLOPs (DVFS floor,
    // scheduler and cache overheads are paid as soon as the part is busy):
    // sqrt saturation makes faster kernels more energy-efficient, which is
    // what the paper's MFLOPS/W orderings show (Fig. 10, discussion pt. 5).
    let bw_utilization = (dram_bytes / latency / arch.peak_bw()).min(1.0);
    let flop_utilization =
        (prof.flops_executed as f64 / latency / arch.peak_flops()).min(1.0);
    let dyn_range = arch.tdp_w - arch.idle_w;
    // Stall power: divergent / imbalanced warps keep their schedulers and
    // register banks active while waiting on the longest lane, burning
    // power without retiring work — CSR's load imbalance costs watts, not
    // just time (the mechanism behind the paper's Fig. 10 average-power
    // wins for regular formats on skewed matrices).
    let stall = (prof.imbalance.min(3.0) - 1.0) / 2.0 * prof.divergence;
    let avg_power = dyn_range
        * (0.50 * bw_utilization.sqrt() + 0.25 * flop_utilization.sqrt()
            + 0.12 * occ.fraction
            + 0.13 * (stall * occ.fraction).min(1.0))
        + 0.08 * arch.idle_w; // sensor floor above true idle
    let energy = avg_power * latency;
    let mflops = prof.flops_useful as f64 / latency / 1e6;
    let eff = mflops / avg_power.max(1e-9);

    (
        Measurement {
            latency_s: latency,
            energy_j: energy,
            avg_power_w: avg_power,
            mflops_per_watt: eff,
        },
        Breakdown {
            occ,
            t_mem_s: t_mem,
            t_comp_s: t_comp,
            dram_bytes,
            x_hit_rate: hit,
            spill_regs,
            tail_utilization,
            bw_utilization,
            flop_utilization,
        },
    )
}

/// §6.3 measurement harness emulation: the paper runs each kernel
/// 500-200000 times so the (slow) power sensor returns stable readings,
/// then reports the mean. With a deterministic analytic model the mean of
/// k identical runs is the run itself; this wrapper reproduces the
/// *protocol* (repetition count chosen from kernel latency, as the paper
/// does) and is what the dataset builder calls.
pub fn measure(arch: &GpuArch, prof: &KernelProfile, cfg: &KernelConfig) -> Measurement {
    let (m, _) = simulate(arch, prof, cfg);
    // repetitions: enough to cover >= 50 ms of sensor window, clamped to
    // the paper's 500..200000 range. (Recorded for protocol fidelity;
    // the averaged objectives are unchanged under a deterministic model.)
    let _reps = ((0.05 / m.latency_s.max(1e-9)) as u64).clamp(500, 200_000);
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{patterns, Rng};
    use crate::gpusim::arch::{pascal_gtx1080, turing_gtx1650m};
    use crate::gpusim::config::MemConfig;
    use crate::gpusim::kernelmodel::profile;
    use crate::sparse::convert::{coo_to_csr, ConvertParams};
    use crate::sparse::Format;

    fn cfg(format: Format, tb: u32, regs: u32, mem: MemConfig) -> KernelConfig {
        KernelConfig { format, tb_size: tb, maxrregcount: regs, mem }
    }

    fn test_matrix() -> crate::sparse::Csr {
        let mut rng = Rng::new(21);
        coo_to_csr(&patterns::banded(&mut rng, 4096, 32, 16.0))
    }

    #[test]
    fn objectives_positive_and_consistent() {
        let a = test_matrix();
        let p = profile(&a, Format::Csr, ConvertParams::default());
        let arch = turing_gtx1650m();
        let (m, _) = simulate(&arch, &p, &cfg(Format::Csr, 256, 64, MemConfig::Default));
        assert!(m.latency_s > 0.0 && m.energy_j > 0.0 && m.avg_power_w > 0.0);
        assert!((m.energy_j - m.avg_power_w * m.latency_s).abs() < 1e-9);
        assert!(m.mflops_per_watt > 0.0);
    }

    #[test]
    fn spill_hurts_latency() {
        let a = test_matrix();
        let p = profile(&a, Format::Csr, ConvertParams::default());
        let arch = turing_gtx1650m();
        // 16 regs forces a 32-register spill for the CSR kernel (needs 48)
        let (m_spill, b_spill) =
            simulate(&arch, &p, &cfg(Format::Csr, 256, 16, MemConfig::Default));
        let (m_ok, b_ok) = simulate(&arch, &p, &cfg(Format::Csr, 256, 64, MemConfig::Default));
        assert!(b_spill.spill_regs == 32 && b_ok.spill_regs == 0);
        assert!(m_spill.latency_s > m_ok.latency_s, "spilling must cost time");
    }

    #[test]
    fn excessive_registers_reduce_occupancy() {
        let a = test_matrix();
        let p = profile(&a, Format::Bell, ConvertParams::default());
        let arch = turing_gtx1650m();
        let (_, b128) = simulate(&arch, &p, &cfg(Format::Bell, 1024, 128, MemConfig::Default));
        let (_, b64) = simulate(&arch, &p, &cfg(Format::Bell, 1024, 64, MemConfig::Default));
        assert!(b128.occ.fraction <= b64.occ.fraction);
    }

    #[test]
    fn pascal_faster_than_turing_mobile() {
        let a = test_matrix();
        let p = profile(&a, Format::Csr, ConvertParams::default());
        let c = cfg(Format::Csr, 256, 64, MemConfig::Default);
        let (mt, _) = simulate(&turing_gtx1650m(), &p, &c);
        let (mp, _) = simulate(&pascal_gtx1080(), &p, &c);
        assert!(mp.latency_s < mt.latency_s, "GTX1080 should beat 1650m");
        assert!(mp.avg_power_w > mt.avg_power_w, "and draw more power");
    }

    #[test]
    fn prefer_l1_helps_csr_gathers() {
        // scattered matrix: x gathers miss; more L1 -> higher hit rate
        let mut rng = Rng::new(22);
        let a = coo_to_csr(&patterns::uniform(&mut rng, 8192, 8192, 12.0));
        let p = profile(&a, Format::Csr, ConvertParams::default());
        let arch = turing_gtx1650m();
        let (_, b_l1) = simulate(&arch, &p, &cfg(Format::Csr, 256, 64, MemConfig::PreferL1));
        let (_, b_sh) = simulate(&arch, &p, &cfg(Format::Csr, 256, 64, MemConfig::PreferShared));
        assert!(b_l1.x_hit_rate > b_sh.x_hit_rate);
        assert!(b_l1.dram_bytes < b_sh.dram_bytes);
    }

    #[test]
    fn oversized_tb_starves_sms_on_small_grids() {
        // n = 4096 rows: tb1024 yields only 4 blocks over 14/20 SMs -> most
        // of the chip idles; tb128 fills it.
        let a = test_matrix();
        let p = profile(&a, Format::Ell, ConvertParams::default());
        for arch in [turing_gtx1650m(), pascal_gtx1080()] {
            let (big, bb) = simulate(&arch, &p, &cfg(Format::Ell, 1024, 64, MemConfig::Default));
            let (small, bs) = simulate(&arch, &p, &cfg(Format::Ell, 128, 64, MemConfig::Default));
            assert!(
                big.latency_s > small.latency_s,
                "{}: tb1024 {} should lose to tb128 {}",
                arch.name,
                big.latency_s,
                small.latency_s
            );
            assert!(bb.tail_utilization <= 1.0 && bs.tail_utilization <= 1.0);
        }
    }

    #[test]
    fn objective_enum_helpers() {
        let m = Measurement { latency_s: 2.0, energy_j: 6.0, avg_power_w: 3.0, mflops_per_watt: 9.0 };
        assert_eq!(Objective::Latency.value(&m), 2.0);
        assert_eq!(Objective::EnergyEff.value(&m), 9.0);
        assert!(Objective::Latency.better(1.0, 2.0));
        assert!(Objective::EnergyEff.better(2.0, 1.0));
        for o in Objective::ALL {
            assert_eq!(Objective::parse(o.name()), Some(o));
        }
    }

    #[test]
    fn measure_matches_simulate() {
        let a = test_matrix();
        let p = profile(&a, Format::Sell, ConvertParams::default());
        let arch = turing_gtx1650m();
        let c = cfg(Format::Sell, 128, 32, MemConfig::Default);
        assert_eq!(measure(&arch, &p, &c), simulate(&arch, &p, &c).0);
    }
}
