//! GPU performance/energy simulator — the stand-in for the paper's two
//! NVIDIA testbeds (substitution rationale: DESIGN.md §1).
//!
//! Pipeline: [`arch`] describes the device; [`occupancy`] reproduces the
//! CUDA occupancy calculator; [`memory`] measures each matrix's x-gather
//! reuse curve; [`kernelmodel`] characterizes each (matrix, format) pair;
//! [`exec`] combines them with a [`config::KernelConfig`] into the four
//! objectives of §6.3 (latency, energy, average power, MFLOPS/W).

pub mod arch;
pub mod config;
pub mod exec;
pub mod kernelmodel;
pub mod memory;
pub mod occupancy;

pub use arch::{pascal_gtx1080, turing_gtx1650m, GpuArch};
pub use config::{KernelConfig, MemConfig, MAXRREGCOUNT, TB_SIZES};
pub use exec::{measure, simulate, Measurement, Objective};
pub use kernelmodel::{profile, profile_all, profile_with_reuse, KernelProfile};
pub use memory::{reuse_curve, ReuseCurve};
pub use occupancy::{occupancy, LaunchResources, Occupancy};
