//! Cache-behaviour model for the dense-vector gathers of SpMV.
//!
//! SpMV's irregular traffic is the `x[col]` gather; how much of it the L1
//! can serve decides whether the kernel is DRAM-bound (paper §4
//! observation 3). We measure the *actual* reuse behaviour of each matrix
//! by streaming its access trace through a set of fixed-capacity
//! pseudo-LRU caches, yielding a hit-rate curve that the execution model
//! interpolates at the effective L1 capacity implied by the carve-out.

use crate::sparse::Csr;

/// Cache line size for x accesses (bytes) — 128B lines, 32 f32 each.
pub const LINE_BYTES: usize = 128;
const LINE_FLOATS: usize = LINE_BYTES / 4;

/// Corpus matrices are scaled ~64x down from the paper's SuiteSparse
/// sizes (DESIGN.md §1); cache capacities in the model scale down by the
/// same factor so the x-vector-vs-L1 regime matches the paper's (x does
/// NOT fit in L1 for mid/large matrices).
pub const CACHE_MODEL_SCALE: usize = 64;

/// Capacities (bytes, already at model scale) at which the reuse curve is
/// sampled: 16/32/64/128 KiB of hardware cache divided by
/// [`CACHE_MODEL_SCALE`].
pub const CURVE_SIZES: [usize; 4] = [
    16 * 1024 / CACHE_MODEL_SCALE,
    32 * 1024 / CACHE_MODEL_SCALE,
    64 * 1024 / CACHE_MODEL_SCALE,
    128 * 1024 / CACHE_MODEL_SCALE,
];

/// Hit-rate curve of one matrix's x-access trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReuseCurve {
    /// Hit rate at each of [`CURVE_SIZES`].
    pub hit: [f64; 4],
    /// Total gather count (== stored entries walked).
    pub accesses: u64,
}

/// FIFO set-approximation of an LRU cache over line ids.
struct FifoCache {
    slots: Vec<u32>,
    pos: Vec<i32>, // line -> slot index or -1
    head: usize,
}

impl FifoCache {
    fn new(capacity_lines: usize, n_lines: usize) -> Self {
        FifoCache {
            slots: vec![u32::MAX; capacity_lines.max(1)],
            pos: vec![-1; n_lines],
            head: 0,
        }
    }

    #[inline]
    fn access(&mut self, line: u32) -> bool {
        if self.pos[line as usize] >= 0 {
            return true;
        }
        let evict = self.slots[self.head];
        if evict != u32::MAX {
            self.pos[evict as usize] = -1;
        }
        self.slots[self.head] = line;
        self.pos[line as usize] = self.head as i32;
        self.head = (self.head + 1) % self.slots.len();
        false
    }
}

/// Measure the x-gather reuse curve of a matrix: walk the access trace in
/// kernel execution order (row-major over stored entries) through four
/// caches at once.
pub fn reuse_curve(a: &Csr) -> ReuseCurve {
    let n_lines = a.n_cols.div_ceil(LINE_FLOATS).max(1);
    let mut caches: Vec<FifoCache> = CURVE_SIZES
        .iter()
        .map(|&b| FifoCache::new(b / LINE_BYTES, n_lines))
        .collect();
    let mut hits = [0u64; 4];
    let mut accesses = 0u64;
    for &c in &a.cols {
        let line = c / LINE_FLOATS as u32;
        accesses += 1;
        for (k, cache) in caches.iter_mut().enumerate() {
            if cache.access(line) {
                hits[k] += 1;
            }
        }
    }
    let mut hit = [0.0f64; 4];
    if accesses > 0 {
        for k in 0..4 {
            hit[k] = hits[k] as f64 / accesses as f64;
        }
    }
    ReuseCurve { hit, accesses }
}

impl ReuseCurve {
    /// Interpolate the hit rate at an arbitrary cache capacity.
    /// Below the smallest sampled size the rate scales toward zero;
    /// above the largest it saturates.
    pub fn hit_rate(&self, capacity_bytes: usize) -> f64 {
        let c = capacity_bytes as f64;
        if c <= CURVE_SIZES[0] as f64 {
            return self.hit[0] * (c / CURVE_SIZES[0] as f64).max(0.0);
        }
        for k in 1..CURVE_SIZES.len() {
            if c <= CURVE_SIZES[k] as f64 {
                let (c0, c1) = (CURVE_SIZES[k - 1] as f64, CURVE_SIZES[k] as f64);
                let t = (c - c0) / (c1 - c0);
                return self.hit[k - 1] + t * (self.hit[k] - self.hit[k - 1]);
            }
        }
        self.hit[3]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{patterns, Rng};
    use crate::sparse::convert::coo_to_csr;

    #[test]
    fn curve_monotone_in_capacity() {
        let mut rng = Rng::new(3);
        let a = coo_to_csr(&patterns::uniform(&mut rng, 2000, 2000, 12.0));
        let c = reuse_curve(&a);
        for k in 1..4 {
            assert!(c.hit[k] >= c.hit[k - 1] - 1e-12, "curve must be monotone: {:?}", c.hit);
        }
    }

    #[test]
    fn banded_has_high_locality() {
        let mut rng = Rng::new(4);
        let banded = coo_to_csr(&patterns::banded(&mut rng, 4000, 16, 10.0));
        let scattered = coo_to_csr(&patterns::uniform(&mut rng, 4000, 4000, 10.0));
        let cb = reuse_curve(&banded);
        let cs = reuse_curve(&scattered);
        assert!(
            cb.hit[0] > cs.hit[0] + 0.2,
            "banded {:.3} should beat uniform {:.3} at 16 KiB",
            cb.hit[0],
            cs.hit[0]
        );
    }

    #[test]
    fn small_x_fits_entirely_at_large_capacity() {
        let mut rng = Rng::new(5);
        // 512 cols = 2 KiB of x == the largest modelled capacity
        let a = coo_to_csr(&patterns::uniform(&mut rng, 512, 512, 8.0));
        let c = reuse_curve(&a);
        assert!(c.hit[3] > 0.9, "{:?}", c.hit);
        // ...but not in the smallest cache
        assert!(c.hit[0] < 0.6, "{:?}", c.hit);
    }

    #[test]
    fn interpolation_between_samples() {
        let c = ReuseCurve { hit: [0.2, 0.4, 0.6, 0.8], accesses: 100 };
        // midpoint between the first two sampled capacities
        let mid = (CURVE_SIZES[0] + CURVE_SIZES[1]) / 2;
        assert!((c.hit_rate(mid) - 0.3).abs() < 1e-9);
        assert_eq!(c.hit_rate(CURVE_SIZES[3] * 8), 0.8);
        assert!(c.hit_rate(CURVE_SIZES[0] / 2) <= 0.2);
        assert_eq!(c.hit_rate(0), 0.0);
    }

    #[test]
    fn empty_matrix_zero_curve() {
        let a = coo_to_csr(&crate::sparse::Coo::new(4, 4));
        let c = reuse_curve(&a);
        assert_eq!(c.accesses, 0);
        assert_eq!(c.hit, [0.0; 4]);
    }
}
