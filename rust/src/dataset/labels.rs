//! Label derivation: from raw sweep records to the paper's supervised
//! learning problems.
//!
//! Compile-time mode (Table 5): per (matrix, arch, objective), the best
//! TB-size / maxrregcount / memconfig classes **with the CSR format**
//! (§5.2 fixes CSR as the compile-mode format).
//!
//! Run-time mode: per (matrix, arch, objective), the best format **with
//! optimal compile parameters per format** (§7.2's fair-comparison rule).

use super::{Dataset, Record};
use crate::gpusim::{KernelConfig, Objective};
use crate::sparse::Format;

/// One supervised example: features + the class labels of every target.
#[derive(Debug, Clone)]
pub struct Example {
    pub matrix: String,
    pub arch: String,
    pub features: Vec<f64>,
    /// Best-config labels for this objective.
    pub tb_class: usize,
    pub reg_class: usize,
    pub mem_class: usize,
    pub format_class: usize,
    /// Objective value at the best compile config (CSR) / best format.
    pub best_compile: f64,
    pub best_format_value: f64,
    /// Objective value at the paper's default baseline config.
    pub default_value: f64,
}

/// The three compile-parameter classification targets of Table 5.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    TbSize,
    MaxRegCount,
    MemConfig,
    Format,
}

impl Target {
    pub const ALL: [Target; 4] =
        [Target::TbSize, Target::MaxRegCount, Target::MemConfig, Target::Format];

    pub fn name(self) -> &'static str {
        match self {
            Target::TbSize => "TB Size",
            Target::MaxRegCount => "maxrregcount",
            Target::MemConfig => "Memory",
            Target::Format => "Format",
        }
    }

    pub fn label(self, e: &Example) -> usize {
        match self {
            Target::TbSize => e.tb_class,
            Target::MaxRegCount => e.reg_class,
            Target::MemConfig => e.mem_class,
            Target::Format => e.format_class,
        }
    }

    pub fn n_classes(self) -> usize {
        match self {
            Target::TbSize => crate::gpusim::TB_SIZES.len(),
            Target::MaxRegCount => crate::gpusim::MAXRREGCOUNT.len(),
            Target::MemConfig => crate::gpusim::MemConfig::ALL.len(),
            Target::Format => Format::ALL.len(),
        }
    }
}

/// Architecture indicator appended as the 9th model feature: the same
/// matrix has (slightly) different optimal configurations on the two
/// GPU profiles, and without this the 80/20 split contains
/// identical-feature/different-label pairs no model can separate.
pub fn arch_feature(arch: &str) -> f64 {
    if arch.contains("Pascal") {
        1.0
    } else {
        0.0
    }
}

/// Relative tolerance within which configurations are considered tied.
/// Labels must be canonical for ties — otherwise the argmin is decided by
/// float noise and the classification task of Table 5 becomes unlearnable.
const TIE_TOL: f64 = 0.005;

/// True optimum value (no tie canonicalization) — reported as the mode's
/// achievable objective value.
fn best_value(records: &[&Record], obj: Objective) -> Option<f64> {
    records
        .iter()
        .map(|r| obj.value(&r.m))
        .reduce(|a, b| if obj.better(a, b) { a } else { b })
}

fn best_record<'a>(records: &[&'a Record], obj: Objective) -> Option<&'a Record> {
    let best = records.iter().copied().reduce(|a, b| {
        if obj.better(obj.value(&a.m), obj.value(&b.m)) {
            a
        } else {
            b
        }
    })?;
    let bv = obj.value(&best.m);
    // canonical pick among near-ties: smallest (format, tb, regs, mem) ids
    records
        .iter()
        .copied()
        .filter(|r| {
            let v = obj.value(&r.m);
            if obj.minimize() {
                v <= bv * (1.0 + TIE_TOL)
            } else {
                v >= bv * (1.0 - TIE_TOL)
            }
        })
        .min_by_key(|r| {
            (
                r.config.format.class_id(),
                r.config.tb_class(),
                r.config.reg_class(),
                r.config.mem.class_id(),
            )
        })
}

/// Derive one example per (matrix, arch) for an objective.
pub fn examples(ds: &Dataset, obj: Objective) -> Vec<Example> {
    let mut out = Vec::new();
    for matrix in ds.matrices() {
        for arch in ds.archs() {
            let slice = ds.slice(&matrix, &arch);
            if slice.is_empty() {
                continue;
            }
            // compile-time labels: CSR records only
            let csr: Vec<&Record> = slice
                .iter()
                .copied()
                .filter(|r| r.config.format == Format::Csr)
                .collect();
            let best_csr = best_record(&csr, obj).expect("csr sweep present");

            // run-time label: per-format optimum, then best format
            let mut best_per_format: Vec<&Record> = Vec::new();
            for f in Format::ALL {
                let fr: Vec<&Record> =
                    slice.iter().copied().filter(|r| r.config.format == f).collect();
                if let Some(b) = best_record(&fr, obj) {
                    best_per_format.push(b);
                }
            }
            let best_fmt = best_record(&best_per_format, obj).expect("formats present");

            // default baseline
            let default_cfg = KernelConfig::default_baseline();
            let default = slice
                .iter()
                .find(|r| r.config == default_cfg)
                .expect("default config in sweep");

            let mut feats = slice[0].features.to_scaled_vec();
            feats.push(arch_feature(&arch));
            out.push(Example {
                matrix: matrix.clone(),
                arch: arch.clone(),
                features: feats,
                tb_class: best_csr.config.tb_class(),
                reg_class: best_csr.config.reg_class(),
                mem_class: best_csr.config.mem.class_id(),
                format_class: best_fmt.config.format.class_id(),
                best_compile: best_value(&csr, obj).unwrap(),
                best_format_value: best_value(&best_per_format, obj).unwrap(),
                default_value: obj.value(&default.m),
            });
        }
    }
    out
}

/// Convert examples to an (X, y) training pair for one target.
pub fn to_xy(examples: &[Example], target: Target) -> (Vec<Vec<f64>>, Vec<usize>) {
    (
        examples.iter().map(|e| e.features.clone()).collect(),
        examples.iter().map(|e| target.label(e)).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{build, BuildOptions};

    fn small_ds() -> Dataset {
        build(&BuildOptions {
            only: Some(vec!["rim".into(), "eu-2005".into(), "crankseg_1".into()]),
            ..Default::default()
        })
    }

    #[test]
    fn one_example_per_matrix_arch() {
        let ds = small_ds();
        let ex = examples(&ds, Objective::Latency);
        assert_eq!(ex.len(), 3 * 2);
    }

    #[test]
    fn labels_within_class_ranges() {
        let ds = small_ds();
        for obj in Objective::ALL {
            for e in examples(&ds, obj) {
                assert!(e.tb_class < Target::TbSize.n_classes());
                assert!(e.reg_class < Target::MaxRegCount.n_classes());
                assert!(e.mem_class < Target::MemConfig.n_classes());
                assert!(e.format_class < Target::Format.n_classes());
            }
        }
    }

    #[test]
    fn best_never_worse_than_default() {
        let ds = small_ds();
        for obj in Objective::ALL {
            for e in examples(&ds, obj) {
                assert!(
                    !obj.better(e.default_value, e.best_compile),
                    "{} {}: default {} beats best {}",
                    e.matrix,
                    obj.name(),
                    e.default_value,
                    e.best_compile
                );
                assert!(!obj.better(e.default_value, e.best_format_value));
            }
        }
    }

    #[test]
    fn format_labels_vary_across_matrices() {
        // the corpus must produce a non-degenerate format-selection problem
        let ds = super::super::build(&BuildOptions {
            only: Some(vec![
                "rim".into(),          // banded -> ELL-friendly
                "eu-2005".into(),      // powerlaw -> SELL/CSR
                "crankseg_1".into(),   // blocks -> BELL
                "parabolic_fem".into(),
            ]),
            ..Default::default()
        });
        let ex = examples(&ds, Objective::EnergyEff);
        let labels: std::collections::HashSet<usize> =
            ex.iter().map(|e| e.format_class).collect();
        assert!(labels.len() >= 2, "format labels degenerate: {labels:?}");
    }

    #[test]
    fn to_xy_shapes() {
        let ds = small_ds();
        let ex = examples(&ds, Objective::Latency);
        let (x, y) = to_xy(&ex, Target::TbSize);
        assert_eq!(x.len(), y.len());
        assert_eq!(x[0].len(), 9);
    }
}
