//! TSV persistence for the dataset ("Dataset, code, and configuration
//! parameters will be available" — the paper's release artifact).

use super::{Dataset, Record};
use crate::features::Features;
use crate::gpusim::{KernelConfig, Measurement, MemConfig};
use crate::sparse::Format;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

const HEADER: &str = "matrix\tarch\tformat\ttb\tregs\tmem\tn\tnnz\tavg_nnz\tvar_nnz\tell_ratio\tmedian\tmode\tstd_nnz\tlatency_s\tenergy_j\tavg_power_w\tmflops_per_watt";

/// Write a dataset as TSV.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    writeln!(f, "{HEADER}")?;
    for r in &ds.records {
        writeln!(
            f,
            "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{:e}\t{:e}\t{:e}\t{:e}",
            r.matrix,
            r.arch,
            r.config.format,
            r.config.tb_size,
            r.config.maxrregcount,
            r.config.mem.name(),
            r.features.n,
            r.features.nnz,
            r.features.avg_nnz,
            r.features.var_nnz,
            r.features.ell_ratio,
            r.features.median,
            r.features.mode,
            r.features.std_nnz,
            r.m.latency_s,
            r.m.energy_j,
            r.m.avg_power_w,
            r.m.mflops_per_watt,
        )?;
    }
    Ok(())
}

/// Load a dataset from TSV.
pub fn load(path: &Path) -> Result<Dataset> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
    let mut lines = text.lines();
    let header = lines.next().context("empty dataset file")?;
    if header != HEADER {
        bail!("unexpected dataset header: {header}");
    }
    let mut records = Vec::new();
    for (ln, line) in lines.enumerate() {
        if line.is_empty() {
            continue;
        }
        let c: Vec<&str> = line.split('\t').collect();
        if c.len() != 18 {
            bail!("line {}: expected 18 columns, got {}", ln + 2, c.len());
        }
        let fmt = Format::parse(c[2]).with_context(|| format!("bad format {}", c[2]))?;
        let mem = MemConfig::parse(c[5]).with_context(|| format!("bad mem {}", c[5]))?;
        let p = |s: &str| -> Result<f64> { s.parse().with_context(|| format!("bad float {s}")) };
        records.push(Record {
            matrix: c[0].to_string(),
            arch: c[1].to_string(),
            config: KernelConfig {
                format: fmt,
                tb_size: c[3].parse()?,
                maxrregcount: c[4].parse()?,
                mem,
            },
            features: Features {
                n: p(c[6])?,
                nnz: p(c[7])?,
                avg_nnz: p(c[8])?,
                var_nnz: p(c[9])?,
                ell_ratio: p(c[10])?,
                median: p(c[11])?,
                mode: p(c[12])?,
                std_nnz: p(c[13])?,
            },
            m: Measurement {
                latency_s: p(c[14])?,
                energy_j: p(c[15])?,
                avg_power_w: p(c[16])?,
                mflops_per_watt: p(c[17])?,
            },
        });
    }
    Ok(Dataset { records })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{build, BuildOptions};

    #[test]
    fn roundtrip_preserves_records() {
        let ds = build(&BuildOptions {
            only: Some(vec!["rim".into()]),
            both_archs: false,
            ..Default::default()
        });
        let tmp = std::env::temp_dir().join("autospmv_ds_test.tsv");
        save(&ds, &tmp).unwrap();
        let back = load(&tmp).unwrap();
        assert_eq!(back.len(), ds.len());
        for (a, b) in ds.records.iter().zip(&back.records) {
            assert_eq!(a.matrix, b.matrix);
            assert_eq!(a.config, b.config);
            assert!((a.m.latency_s - b.m.latency_s).abs() < 1e-12 * a.m.latency_s.abs());
        }
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn random_datasets_roundtrip_exactly() {
        // Property: save -> load is lossless for arbitrary finite
        // records — including the synthetic "online-<key>" records the
        // online loop checkpoints through this store (tiny/huge
        // magnitudes from measured latencies and modeled energies).
        use crate::testutil::assert_prop;
        let configs = KernelConfig::sweep_all();
        assert_prop("store roundtrip", 0x57073, 12, 40, |rng, size| {
            let n_records = 1 + size % 20;
            let magnitude = |rng: &mut crate::gen::Rng| {
                // span ~1e-12 .. 1e+12, the scales measurements live at
                let exp = rng.f64() * 24.0 - 12.0;
                rng.f64().max(1e-3) * 10f64.powf(exp)
            };
            let records: Vec<Record> = (0..n_records)
                .map(|_| Record {
                    matrix: format!("online-{:016x}", rng.next_u64()),
                    arch: if rng.f64() < 0.5 { "GTX1650m-Turing" } else { "GTX1080-Pascal" }
                        .to_string(),
                    config: configs[rng.below(configs.len())],
                    features: Features {
                        n: (rng.below(1_000_000) + 1) as f64,
                        nnz: (rng.below(10_000_000) + 1) as f64,
                        avg_nnz: magnitude(rng),
                        var_nnz: magnitude(rng),
                        ell_ratio: rng.f64(),
                        median: rng.below(1000) as f64,
                        mode: rng.below(1000) as f64,
                        std_nnz: magnitude(rng),
                    },
                    m: Measurement {
                        latency_s: magnitude(rng),
                        energy_j: magnitude(rng),
                        avg_power_w: magnitude(rng),
                        mflops_per_watt: magnitude(rng),
                    },
                })
                .collect();
            let ds = Dataset { records };
            let tmp = std::env::temp_dir()
                .join(format!("autospmv_roundtrip_{}.tsv", rng.next_u64()));
            save(&ds, &tmp).map_err(|e| format!("save: {e}"))?;
            let back = load(&tmp).map_err(|e| format!("load: {e}"))?;
            std::fs::remove_file(&tmp).ok();
            if back.len() != ds.len() {
                return Err(format!("len {} != {}", back.len(), ds.len()));
            }
            for (a, b) in ds.records.iter().zip(&back.records) {
                if a.matrix != b.matrix || a.arch != b.arch || a.config != b.config {
                    return Err(format!("identity fields diverge: {} vs {}", a.matrix, b.matrix));
                }
                let pairs = [
                    (a.features.n, b.features.n),
                    (a.features.nnz, b.features.nnz),
                    (a.features.avg_nnz, b.features.avg_nnz),
                    (a.features.var_nnz, b.features.var_nnz),
                    (a.features.ell_ratio, b.features.ell_ratio),
                    (a.features.median, b.features.median),
                    (a.features.mode, b.features.mode),
                    (a.features.std_nnz, b.features.std_nnz),
                    (a.m.latency_s, b.m.latency_s),
                    (a.m.energy_j, b.m.energy_j),
                    (a.m.avg_power_w, b.m.avg_power_w),
                    (a.m.mflops_per_watt, b.m.mflops_per_watt),
                ];
                for (x, y) in pairs {
                    // Rust float formatting prints the shortest string
                    // that uniquely identifies the value, so the
                    // roundtrip must be bit-exact.
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("float not bit-exact: {x:?} vs {y:?}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn load_rejects_bad_header() {
        let tmp = std::env::temp_dir().join("autospmv_bad_header.tsv");
        std::fs::write(&tmp, "nope\n").unwrap();
        assert!(load(&tmp).is_err());
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn load_rejects_short_rows() {
        let tmp = std::env::temp_dir().join("autospmv_bad_row.tsv");
        std::fs::write(&tmp, format!("{HEADER}\na\tb\tc\n")).unwrap();
        assert!(load(&tmp).is_err());
        std::fs::remove_file(tmp).ok();
    }
}
