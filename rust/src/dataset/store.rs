//! TSV persistence for the dataset ("Dataset, code, and configuration
//! parameters will be available" — the paper's release artifact).

use super::{Dataset, Record};
use crate::features::Features;
use crate::gpusim::{KernelConfig, Measurement, MemConfig};
use crate::sparse::Format;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

const HEADER: &str = "matrix\tarch\tformat\ttb\tregs\tmem\tn\tnnz\tavg_nnz\tvar_nnz\tell_ratio\tmedian\tmode\tstd_nnz\tlatency_s\tenergy_j\tavg_power_w\tmflops_per_watt";

/// Write a dataset as TSV.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    writeln!(f, "{HEADER}")?;
    for r in &ds.records {
        writeln!(
            f,
            "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{:e}\t{:e}\t{:e}\t{:e}",
            r.matrix,
            r.arch,
            r.config.format,
            r.config.tb_size,
            r.config.maxrregcount,
            r.config.mem.name(),
            r.features.n,
            r.features.nnz,
            r.features.avg_nnz,
            r.features.var_nnz,
            r.features.ell_ratio,
            r.features.median,
            r.features.mode,
            r.features.std_nnz,
            r.m.latency_s,
            r.m.energy_j,
            r.m.avg_power_w,
            r.m.mflops_per_watt,
        )?;
    }
    Ok(())
}

/// Load a dataset from TSV.
pub fn load(path: &Path) -> Result<Dataset> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
    let mut lines = text.lines();
    let header = lines.next().context("empty dataset file")?;
    if header != HEADER {
        bail!("unexpected dataset header: {header}");
    }
    let mut records = Vec::new();
    for (ln, line) in lines.enumerate() {
        if line.is_empty() {
            continue;
        }
        let c: Vec<&str> = line.split('\t').collect();
        if c.len() != 18 {
            bail!("line {}: expected 18 columns, got {}", ln + 2, c.len());
        }
        let fmt = Format::parse(c[2]).with_context(|| format!("bad format {}", c[2]))?;
        let mem = MemConfig::parse(c[5]).with_context(|| format!("bad mem {}", c[5]))?;
        let p = |s: &str| -> Result<f64> { s.parse().with_context(|| format!("bad float {s}")) };
        records.push(Record {
            matrix: c[0].to_string(),
            arch: c[1].to_string(),
            config: KernelConfig {
                format: fmt,
                tb_size: c[3].parse()?,
                maxrregcount: c[4].parse()?,
                mem,
            },
            features: Features {
                n: p(c[6])?,
                nnz: p(c[7])?,
                avg_nnz: p(c[8])?,
                var_nnz: p(c[9])?,
                ell_ratio: p(c[10])?,
                median: p(c[11])?,
                mode: p(c[12])?,
                std_nnz: p(c[13])?,
            },
            m: Measurement {
                latency_s: p(c[14])?,
                energy_j: p(c[15])?,
                avg_power_w: p(c[16])?,
                mflops_per_watt: p(c[17])?,
            },
        });
    }
    Ok(Dataset { records })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{build, BuildOptions};

    #[test]
    fn roundtrip_preserves_records() {
        let ds = build(&BuildOptions {
            only: Some(vec!["rim".into()]),
            both_archs: false,
            ..Default::default()
        });
        let tmp = std::env::temp_dir().join("autospmv_ds_test.tsv");
        save(&ds, &tmp).unwrap();
        let back = load(&tmp).unwrap();
        assert_eq!(back.len(), ds.len());
        for (a, b) in ds.records.iter().zip(&back.records) {
            assert_eq!(a.matrix, b.matrix);
            assert_eq!(a.config, b.config);
            assert!((a.m.latency_s - b.m.latency_s).abs() < 1e-12 * a.m.latency_s.abs());
        }
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn load_rejects_bad_header() {
        let tmp = std::env::temp_dir().join("autospmv_bad_header.tsv");
        std::fs::write(&tmp, "nope\n").unwrap();
        assert!(load(&tmp).is_err());
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn load_rejects_short_rows() {
        let tmp = std::env::temp_dir().join("autospmv_bad_row.tsv");
        std::fs::write(&tmp, format!("{HEADER}\na\tb\tc\n")).unwrap();
        assert!(load(&tmp).is_err());
        std::fs::remove_file(tmp).ok();
    }
}
