//! Dataset construction — the paper's §6 protocol: sweep every corpus
//! matrix over the full configuration space on both GPU profiles, record
//! the four objectives per run, and derive the classification labels
//! (best TB size / maxrregcount / memory config / format per objective).

pub mod labels;
pub mod store;

use crate::features::{extract_csr, Features};
use crate::gen::{corpus, CorpusEntry};
use crate::gpusim::{
    measure, pascal_gtx1080, profile_all, turing_gtx1650m, GpuArch, KernelConfig, Measurement,
};
use crate::sparse::convert::ConvertParams;

/// One dataset record: a (matrix, architecture, configuration) run.
#[derive(Debug, Clone)]
pub struct Record {
    pub matrix: String,
    pub arch: String,
    pub config: KernelConfig,
    pub features: Features,
    pub m: Measurement,
}

/// The full training dataset.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub records: Vec<Record>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records of one matrix on one architecture.
    pub fn slice<'a>(&'a self, matrix: &str, arch: &str) -> Vec<&'a Record> {
        self.records
            .iter()
            .filter(|r| r.matrix == matrix && r.arch == arch)
            .collect()
    }

    pub fn matrices(&self) -> Vec<String> {
        let mut v: Vec<String> = Vec::new();
        for r in &self.records {
            if !v.contains(&r.matrix) {
                v.push(r.matrix.clone());
            }
        }
        v
    }

    pub fn archs(&self) -> Vec<String> {
        let mut v: Vec<String> = Vec::new();
        for r in &self.records {
            if !v.contains(&r.arch) {
                v.push(r.arch.clone());
            }
        }
        v
    }
}

/// Dataset build options.
#[derive(Debug, Clone)]
pub struct BuildOptions {
    /// Corpus scale multiplier (1 = CI scale, see gen::corpus).
    pub scale: usize,
    /// Architectures to sweep (paper: Turing + Pascal).
    pub both_archs: bool,
    /// Optional subset of matrix names (None = all 30).
    pub only: Option<Vec<String>>,
    pub convert: ConvertParams,
}

impl Default for BuildOptions {
    fn default() -> Self {
        BuildOptions { scale: 1, both_archs: true, only: None, convert: ConvertParams::default() }
    }
}

/// Build the dataset: every (matrix x arch x config) run (§6.1: 30
/// matrices, >15k records over two GPUs).
pub fn build(opts: &BuildOptions) -> Dataset {
    let archs: Vec<GpuArch> = if opts.both_archs {
        vec![turing_gtx1650m(), pascal_gtx1080()]
    } else {
        vec![turing_gtx1650m()]
    };
    let entries: Vec<CorpusEntry> = corpus()
        .into_iter()
        .filter(|e| {
            opts.only
                .as_ref()
                .is_none_or(|names| names.iter().any(|n| n == e.name))
        })
        .collect();

    let mut records = Vec::new();
    for entry in &entries {
        let csr = entry.generate_csr(opts.scale);
        let features = extract_csr(&csr);
        // one profile per format; the reuse curve is computed once
        let profiles = profile_all(&csr, opts.convert);
        for arch in &archs {
            for cfg in KernelConfig::sweep_all() {
                let prof = &profiles[cfg.format.class_id()];
                let m = measure(arch, prof, &cfg);
                records.push(Record {
                    matrix: entry.name.to_string(),
                    arch: arch.name.to_string(),
                    config: cfg,
                    features,
                    m,
                });
            }
        }
    }
    Dataset { records }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        build(&BuildOptions {
            only: Some(vec!["rim".into(), "consph".into()]),
            both_archs: true,
            ..Default::default()
        })
    }

    #[test]
    fn record_counts_match_sweep() {
        let d = tiny();
        // 2 matrices x 2 archs x 240 configs
        assert_eq!(d.len(), 2 * 2 * 240);
        assert_eq!(d.matrices().len(), 2);
        assert_eq!(d.archs().len(), 2);
    }

    #[test]
    fn slice_selects_matrix_arch() {
        let d = tiny();
        let s = d.slice("rim", "GTX1650m-Turing");
        assert_eq!(s.len(), 240);
        assert!(s.iter().all(|r| r.matrix == "rim"));
    }

    #[test]
    fn objectives_vary_across_configs() {
        // the learning problem must be non-trivial: different configs give
        // different objective values
        let d = tiny();
        let s = d.slice("consph", "GTX1650m-Turing");
        let lats: Vec<f64> = s.iter().map(|r| r.m.latency_s).collect();
        let min = lats.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = lats.iter().cloned().fold(0.0f64, f64::max);
        assert!(max > 1.2 * min, "config choice must matter: {min} .. {max}");
    }

    #[test]
    fn full_dataset_size_matches_paper_scale() {
        // 30 x 2 x 240 = 14400 records (paper: 15520; see DESIGN.md §1)
        let opts = BuildOptions::default();
        let n_configs = KernelConfig::sweep_all().len();
        assert_eq!(30 * 2 * n_configs, 14400);
        let _ = opts;
    }
}
