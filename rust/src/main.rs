//! `auto-spmv` — the Auto-SpMV coordinator binary (see cli module docs).

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match auto_spmv::cli::parse(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = auto_spmv::cli::run(&cli) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
