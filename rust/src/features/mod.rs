//! Sparsity-feature extraction — the paper's Table 2, all eight features.
//!
//! Features are extracted on the CPU at run time (paper §5.3 step 1); the
//! extraction wall time is `f_latency` in Table 7, so [`extract_timed`]
//! returns it alongside the features. The implementation is a single pass
//! over the row-length histogram (see EXPERIMENTS.md §Perf for the
//! optimization log).

use crate::sparse::{Coo, Csr};
use std::time::{Duration, Instant};

/// The eight sparsity features of Table 2, in the paper's order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Features {
    /// n — number of rows.
    pub n: f64,
    /// nnz — number of non-zero elements.
    pub nnz: f64,
    /// Avg_nnz — mean non-zeros per row.
    pub avg_nnz: f64,
    /// Var_nnz — variance of non-zeros per row.
    pub var_nnz: f64,
    /// ELL_ratio — nnz / (n * max_row_len): padding efficiency in ELL.
    pub ell_ratio: f64,
    /// Median of non-zeros per row.
    pub median: f64,
    /// Mode of non-zeros per row.
    pub mode: f64,
    /// Std_nnz — standard deviation of non-zeros per row.
    pub std_nnz: f64,
}

pub const FEATURE_NAMES: [&str; 8] =
    ["n", "nnz", "Avg_nnz", "Var_nnz", "ELL_ratio", "Median", "Mode", "Std_nnz"];

impl Features {
    /// Feature vector in Table 2 order (the ML input layout).
    pub fn to_vec(&self) -> Vec<f64> {
        vec![
            self.n, self.nnz, self.avg_nnz, self.var_nnz,
            self.ell_ratio, self.median, self.mode, self.std_nnz,
        ]
    }

    /// Log-scaled variant used by the learners: n/nnz/var span orders of
    /// magnitude, so models train on log1p of the unbounded features.
    pub fn to_scaled_vec(&self) -> Vec<f64> {
        vec![
            self.n.ln_1p(),
            self.nnz.ln_1p(),
            self.avg_nnz.ln_1p(),
            self.var_nnz.ln_1p(),
            self.ell_ratio,
            self.median.ln_1p(),
            self.mode.ln_1p(),
            self.std_nnz.ln_1p(),
        ]
    }
}

/// Compute all eight features from per-row non-zero counts.
fn from_row_counts(n: usize, counts: &[u32]) -> Features {
    debug_assert_eq!(counts.len(), n);
    if n == 0 {
        return Features {
            n: 0.0, nnz: 0.0, avg_nnz: 0.0, var_nnz: 0.0,
            ell_ratio: 0.0, median: 0.0, mode: 0.0, std_nnz: 0.0,
        };
    }
    let nnz: u64 = counts.iter().map(|&c| c as u64).sum();
    let avg = nnz as f64 / n as f64;

    // single pass: variance accumulator + max + histogram for mode
    let mut sum_sq = 0.0f64;
    let mut max_len = 0u32;
    for &c in counts {
        let d = c as f64 - avg;
        sum_sq += d * d;
        max_len = max_len.max(c);
    }
    let var = sum_sq / n as f64;

    // histogram over 0..=max_len (row lengths are small integers)
    let mut hist = vec![0u32; max_len as usize + 1];
    for &c in counts {
        hist[c as usize] += 1;
    }
    // mode: most frequent row length (smallest on ties, matching
    // scipy.stats.mode semantics the paper's pipeline used)
    let mode = hist
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
        .map(|(len, _)| len as f64)
        .unwrap_or(0.0);
    // median via histogram walk
    let median = {
        let half = (n as u64).div_ceil(2);
        let mut acc = 0u64;
        let mut med = 0f64;
        for (len, &cnt) in hist.iter().enumerate() {
            acc += cnt as u64;
            if acc >= half {
                med = len as f64;
                // even n and boundary exactly at half: average with next occupied bin
                if n % 2 == 0 && acc == half {
                    let next = hist[len + 1..].iter().position(|&c| c > 0);
                    if let Some(off) = next {
                        med = (len as f64 + (len + 1 + off) as f64) / 2.0;
                    }
                }
                break;
            }
        }
        med
    };

    let ell_ratio = if max_len == 0 { 0.0 } else { nnz as f64 / (n as f64 * max_len as f64) };

    Features {
        n: n as f64,
        nnz: nnz as f64,
        avg_nnz: avg,
        var_nnz: var,
        ell_ratio,
        median,
        mode,
        std_nnz: var.sqrt(),
    }
}

/// Extract features from a CSR matrix.
pub fn extract_csr(a: &Csr) -> Features {
    let counts: Vec<u32> = (0..a.n_rows).map(|i| a.row_len(i) as u32).collect();
    from_row_counts(a.n_rows, &counts)
}

/// Extract features from a COO matrix (the run-time mode's input format).
pub fn extract_coo(a: &Coo) -> Features {
    from_row_counts(a.n_rows, &a.row_counts())
}

/// Extract features and report wall time (`f_latency` of Table 7).
pub fn extract_timed(a: &Coo) -> (Features, Duration) {
    let t0 = Instant::now();
    let f = extract_coo(a);
    (f, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coo_with_rows(rows: &[usize]) -> Coo {
        // build a matrix whose row i has rows[i] entries
        let n = rows.len();
        let m = rows.iter().copied().max().unwrap_or(1).max(1);
        let mut a = Coo::new(n, m);
        for (r, &k) in rows.iter().enumerate() {
            for c in 0..k {
                a.push(r, c, 1.0);
            }
        }
        a
    }

    #[test]
    fn features_hand_computed() {
        // rows: 2, 0, 4 -> n=3 nnz=6 avg=2 var=((0)+(4)+(4))/3=8/3
        let f = extract_coo(&coo_with_rows(&[2, 0, 4]));
        assert_eq!(f.n, 3.0);
        assert_eq!(f.nnz, 6.0);
        assert_eq!(f.avg_nnz, 2.0);
        assert!((f.var_nnz - 8.0 / 3.0).abs() < 1e-12);
        assert!((f.std_nnz - (8.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(f.ell_ratio, 6.0 / 12.0);
        assert_eq!(f.median, 2.0);
    }

    #[test]
    fn median_odd_and_even() {
        assert_eq!(extract_coo(&coo_with_rows(&[1, 2, 3])).median, 2.0);
        assert_eq!(extract_coo(&coo_with_rows(&[1, 1, 3, 3])).median, 2.0);
        assert_eq!(extract_coo(&coo_with_rows(&[1, 1, 1, 3])).median, 1.0);
    }

    #[test]
    fn mode_most_frequent_smallest_tie() {
        assert_eq!(extract_coo(&coo_with_rows(&[2, 2, 5, 5, 5])).mode, 5.0);
        // tie between 2 and 5 -> smallest
        assert_eq!(extract_coo(&coo_with_rows(&[2, 2, 5, 5])).mode, 2.0);
    }

    #[test]
    fn csr_and_coo_agree() {
        let coo = coo_with_rows(&[3, 1, 4, 1, 5]);
        let csr = crate::sparse::convert::coo_to_csr(&coo);
        assert_eq!(extract_coo(&coo), extract_csr(&csr));
    }

    #[test]
    fn empty_matrix_is_all_zero() {
        let f = extract_coo(&Coo::new(0, 0));
        assert_eq!(f.to_vec(), vec![0.0; 8]);
    }

    #[test]
    fn uniform_rows_have_zero_variance_and_ratio_one() {
        let f = extract_coo(&coo_with_rows(&[4, 4, 4, 4]));
        assert_eq!(f.var_nnz, 0.0);
        assert_eq!(f.ell_ratio, 1.0);
        assert_eq!(f.mode, 4.0);
    }

    #[test]
    fn vec_layouts() {
        let f = extract_coo(&coo_with_rows(&[2, 4]));
        assert_eq!(f.to_vec().len(), 8);
        assert_eq!(f.to_scaled_vec().len(), 8);
        assert_eq!(FEATURE_NAMES.len(), 8);
        // scaled: ell_ratio passes through unscaled
        assert_eq!(f.to_scaled_vec()[4], f.ell_ratio);
    }

    #[test]
    fn timed_extraction_returns_features() {
        let coo = coo_with_rows(&[1, 2, 3, 4, 5]);
        let (f, d) = extract_timed(&coo);
        assert_eq!(f, extract_coo(&coo));
        assert!(d.as_nanos() > 0);
    }
}
