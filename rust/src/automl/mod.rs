//! AutoML — the Optuna stand-in (DESIGN.md §1): Tree-structured Parzen
//! Estimator (TPE) search over the discrete hyperparameter spaces of the
//! paper's Table 1, plus a random-search baseline.
//!
//! All Table 1 spaces are categorical, so the TPE density model reduces
//! to Laplace-smoothed categorical likelihoods over the good/bad trial
//! split — the same decision rule as Optuna's categorical TPE sampler.


pub mod tuner;

use crate::gen::Rng;

/// A discrete search space: named parameters, each with a list of choices.
#[derive(Debug, Clone)]
pub struct Space {
    pub params: Vec<(&'static str, usize)>, // (name, n_choices)
}

impl Space {
    pub fn new(params: Vec<(&'static str, usize)>) -> Self {
        assert!(params.iter().all(|(_, n)| *n > 0));
        Space { params }
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn random_trial(&self, rng: &mut Rng) -> Vec<usize> {
        self.params.iter().map(|&(_, n)| rng.below(n)).collect()
    }

    /// Total number of configurations.
    pub fn cardinality(&self) -> usize {
        self.params.iter().map(|&(_, n)| n).product()
    }

    /// Enumerate every configuration (for exhaustive validation in tests).
    pub fn enumerate(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new()];
        for &(_, n) in &self.params {
            let mut next = Vec::with_capacity(out.len() * n);
            for t in &out {
                for c in 0..n {
                    let mut t2 = t.clone();
                    t2.push(c);
                    next.push(t2);
                }
            }
            out = next;
        }
        out
    }
}

/// One evaluated trial.
#[derive(Debug, Clone)]
pub struct Trial {
    pub choices: Vec<usize>,
    pub score: f64, // higher is better
}

/// TPE optimizer over a discrete [`Space`].
pub struct Tpe {
    pub space: Space,
    pub gamma: f64,       // top fraction considered "good"
    pub n_candidates: usize,
    pub n_startup: usize, // random trials before the model kicks in
    pub history: Vec<Trial>,
    rng: Rng,
}

impl Tpe {
    pub fn new(space: Space, seed: u64) -> Self {
        Tpe {
            space,
            gamma: 0.25,
            n_candidates: 24,
            n_startup: 8,
            history: Vec::new(),
            rng: Rng::new(seed ^ 0x79E),
        }
    }

    /// Propose the next trial.
    pub fn suggest(&mut self) -> Vec<usize> {
        if self.history.len() < self.n_startup {
            return self.space.random_trial(&mut self.rng);
        }
        // split history into good / bad by score quantile
        let mut sorted: Vec<&Trial> = self.history.iter().collect();
        sorted.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        let n_good = ((sorted.len() as f64 * self.gamma).ceil() as usize).clamp(1, sorted.len() - 1);
        let (good, bad) = sorted.split_at(n_good);

        // categorical densities with Laplace smoothing
        let mut best: Option<(f64, Vec<usize>)> = None;
        for _ in 0..self.n_candidates {
            let cand = self.space.random_trial(&mut self.rng);
            let mut log_ratio = 0.0;
            for (p, &(_, n)) in self.space.params.iter().enumerate() {
                let cg = good.iter().filter(|t| t.choices[p] == cand[p]).count();
                let cb = bad.iter().filter(|t| t.choices[p] == cand[p]).count();
                let pg = (cg as f64 + 1.0) / (good.len() as f64 + n as f64);
                let pb = (cb as f64 + 1.0) / (bad.len() as f64 + n as f64);
                log_ratio += pg.ln() - pb.ln();
            }
            if best.as_ref().is_none_or(|(s, _)| log_ratio > *s) {
                best = Some((log_ratio, cand));
            }
        }
        best.unwrap().1
    }

    /// Record a completed trial.
    pub fn observe(&mut self, choices: Vec<usize>, score: f64) {
        self.history.push(Trial { choices, score });
    }

    /// Run `n_trials` of suggest -> evaluate -> observe; returns the best.
    pub fn optimize<F: FnMut(&[usize]) -> f64>(&mut self, n_trials: usize, mut f: F) -> Trial {
        for _ in 0..n_trials {
            let c = self.suggest();
            let s = f(&c);
            self.observe(c, s);
        }
        self.best().expect("n_trials > 0")
    }

    pub fn best(&self) -> Option<Trial> {
        self.history
            .iter()
            .max_by(|a, b| a.score.partial_cmp(&b.score).unwrap())
            .cloned()
    }
}

/// Pure random search (the baseline TPE must beat).
pub fn random_search<F: FnMut(&[usize]) -> f64>(
    space: &Space,
    n_trials: usize,
    seed: u64,
    mut f: F,
) -> Trial {
    let mut rng = Rng::new(seed ^ 0x2A4D);
    let mut best: Option<Trial> = None;
    for _ in 0..n_trials {
        let c = space.random_trial(&mut rng);
        let s = f(&c);
        if best.as_ref().is_none_or(|b| s > b.score) {
            best = Some(Trial { choices: c, score: s });
        }
    }
    best.expect("n_trials > 0")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_space() -> Space {
        Space::new(vec![("a", 5), ("b", 4), ("c", 3)])
    }

    /// Objective with a unique optimum at (3, 1, 2) and additive structure.
    fn toy_objective(c: &[usize]) -> f64 {
        let target = [3usize, 1, 2];
        -(c.iter()
            .zip(&target)
            .map(|(&x, &t)| (x as f64 - t as f64).abs())
            .sum::<f64>())
    }

    #[test]
    fn space_cardinality_and_enumeration() {
        let s = toy_space();
        assert_eq!(s.cardinality(), 60);
        assert_eq!(s.enumerate().len(), 60);
    }

    #[test]
    fn tpe_finds_optimum() {
        let mut tpe = Tpe::new(toy_space(), 5);
        let best = tpe.optimize(60, toy_objective);
        assert_eq!(best.score, 0.0, "best {:?}", best);
    }

    #[test]
    fn tpe_beats_random_on_budget() {
        // averaged over seeds, TPE should reach a better score than random
        // with the same small budget on the structured objective
        let budget = 25;
        let mut tpe_sum = 0.0;
        let mut rnd_sum = 0.0;
        for seed in 0..10 {
            let mut tpe = Tpe::new(toy_space(), seed);
            tpe_sum += tpe.optimize(budget, toy_objective).score;
            rnd_sum += random_search(&toy_space(), budget, seed, toy_objective).score;
        }
        assert!(
            tpe_sum >= rnd_sum,
            "TPE ({tpe_sum}) should not lose to random ({rnd_sum})"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed| {
            let mut t = Tpe::new(toy_space(), seed);
            t.optimize(20, toy_objective).choices
        };
        assert_eq!(run(9), run(9));
    }

    #[test]
    fn observe_best_tracks_max() {
        let mut tpe = Tpe::new(toy_space(), 1);
        tpe.observe(vec![0, 0, 0], 1.0);
        tpe.observe(vec![1, 1, 1], 3.0);
        tpe.observe(vec![2, 2, 2], 2.0);
        assert_eq!(tpe.best().unwrap().score, 3.0);
    }
}
