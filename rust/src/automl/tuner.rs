//! Model fine-tuning — wires the Table 1 hyperparameter ranges to the
//! TPE optimizer and returns the best fitted model per family (§5.4
//! step 3: "fine-tuning machine learning algorithms to provide the most
//! accurate predictions").

use super::{Space, Tpe};
use crate::ml::boosting::GradientBoostingClassifier;
use crate::ml::centroid::{Metric, NearestCentroid};
use crate::ml::forest::RandomForestClassifier;
use crate::ml::metrics::accuracy;
use crate::ml::mlp::{Activation, MlpClassifier};
use crate::ml::split::{take, take_x, train_test_indices};
use crate::ml::svm::{Kernel, SvmClassifier};
use crate::ml::tree::{Criterion, DecisionTreeClassifier, Splitter};
use crate::ml::Classifier;

/// The six model families of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    NearestCentroid,
    DecisionTree,
    Svm,
    GradientBoosting,
    RandomForest,
    Mlp,
}

impl Family {
    pub const ALL: [Family; 6] = [
        Family::NearestCentroid,
        Family::DecisionTree,
        Family::Svm,
        Family::GradientBoosting,
        Family::RandomForest,
        Family::Mlp,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Family::NearestCentroid => "nearest_centroid",
            Family::DecisionTree => "decision_tree",
            Family::Svm => "svm",
            Family::GradientBoosting => "gradient_boosting",
            Family::RandomForest => "random_forest",
            Family::Mlp => "mlp",
        }
    }

    /// Table 1 search space of this family.
    pub fn space(self) -> Space {
        match self {
            // metric: manhattan, euclidean, minkowski
            Family::NearestCentroid => Space::new(vec![("metric", 3)]),
            // criterion x splitter (+ depth, an sklearn default we expose)
            Family::DecisionTree => {
                Space::new(vec![("criterion", 3), ("splitter", 2), ("depth", 4)])
            }
            // kernel: linear poly rbf sigmoid ("precomputed" is an sklearn
            // calling convention, not a model — excluded)
            Family::Svm => Space::new(vec![("kernel", 4)]),
            // estimators {50,100,150,200} x lr {0.1, 0.01, 0.001}
            Family::GradientBoosting => Space::new(vec![("estimators", 4), ("lr", 3)]),
            // criterion {gini, entropy, log_loss}
            Family::RandomForest => Space::new(vec![("criterion", 3)]),
            // hidden {20,50,100,150,200} x layers {1,2,3,4,5,10} x act {4}
            Family::Mlp => Space::new(vec![("hidden", 5), ("layers", 6), ("act", 4)]),
        }
    }

    /// Materialize a model from a trial's choices.
    pub fn build(self, choices: &[usize], x_train: &[Vec<f64>], seed: u64) -> Box<dyn Classifier> {
        match self {
            Family::NearestCentroid => {
                let metric = [Metric::Manhattan, Metric::Euclidean, Metric::Minkowski(3.0)]
                    [choices[0]];
                Box::new(NearestCentroid { metric, ..Default::default() })
            }
            Family::DecisionTree => {
                let criterion = Criterion::ALL[choices[0]];
                let splitter = [Splitter::Best, Splitter::Random][choices[1]];
                let max_depth = [5, 9, 13, 20][choices[2]];
                Box::new(DecisionTreeClassifier {
                    criterion,
                    splitter,
                    max_depth,
                    seed,
                    ..Default::default()
                })
            }
            Family::Svm => {
                let g = SvmClassifier::gamma_scale(x_train);
                let kernel = [
                    Kernel::Linear,
                    Kernel::Poly { degree: 3, gamma: g, coef0: 1.0 },
                    Kernel::Rbf { gamma: g },
                    Kernel::Sigmoid { gamma: g, coef0: 0.0 },
                ][choices[0]];
                Box::new(SvmClassifier { kernel, seed, ..Default::default() })
            }
            Family::GradientBoosting => {
                let n_estimators = [50, 100, 150, 200][choices[0]];
                let learning_rate = [0.1, 0.01, 0.001][choices[1]];
                Box::new(GradientBoostingClassifier {
                    n_estimators,
                    learning_rate,
                    seed,
                    ..Default::default()
                })
            }
            Family::RandomForest => {
                let criterion = Criterion::ALL[choices[0]];
                Box::new(RandomForestClassifier {
                    criterion,
                    n_estimators: 100,
                    max_depth: 15,
                    seed,
                    ..Default::default()
                })
            }
            Family::Mlp => {
                let hidden = [20, 50, 100, 150, 200][choices[0]];
                let layers = [1, 2, 3, 4, 5, 10][choices[1]];
                let activation = Activation::ALL[choices[2]];
                Box::new(MlpClassifier {
                    hidden: vec![hidden; layers],
                    activation,
                    epochs: 60,
                    seed,
                    ..Default::default()
                })
            }
        }
    }
}

/// Result of tuning one family.
pub struct Tuned {
    pub family: Family,
    pub choices: Vec<usize>,
    pub valid_accuracy: f64,
    pub model: Box<dyn Classifier>,
}

/// Tune one family with TPE on an internal holdout of the training data,
/// then refit the winner on all of it.
pub fn tune_family(
    family: Family,
    x: &[Vec<f64>],
    y: &[usize],
    n_trials: usize,
    seed: u64,
) -> Tuned {
    let (tr, va) = train_test_indices(x.len(), 0.25, seed ^ 0x7u64);
    let (xt, yt) = (take_x(x, &tr), take(y, &tr));
    let (xv, yv) = (take_x(x, &va), take(y, &va));

    let space = family.space();
    let budget = n_trials.min(space.cardinality());
    let mut tpe = Tpe::new(space, seed);
    let best = tpe.optimize(budget, |choices| {
        let mut m = family.build(choices, &xt, seed);
        m.fit(&xt, &yt);
        accuracy(&yv, &m.predict(&xv))
    });

    let mut model = family.build(&best.choices, x, seed);
    model.fit(x, y);
    Tuned { family, choices: best.choices, valid_accuracy: best.score, model }
}

/// Tune every family and return them sorted by validation accuracy
/// (best first) — the "report the best classification results" step.
pub fn tune_all(x: &[Vec<f64>], y: &[usize], n_trials: usize, seed: u64) -> Vec<Tuned> {
    let mut out: Vec<Tuned> = Family::ALL
        .iter()
        .map(|&f| tune_family(f, x, y, n_trials, seed))
        .collect();
    out.sort_by(|a, b| b.valid_accuracy.partial_cmp(&a.valid_accuracy).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::testdata;

    #[test]
    fn family_spaces_match_table1() {
        assert_eq!(Family::Mlp.space().cardinality(), 5 * 6 * 4);
        assert_eq!(Family::GradientBoosting.space().cardinality(), 12);
        assert_eq!(Family::Svm.space().cardinality(), 4);
        assert_eq!(Family::ALL.len(), 6);
    }

    #[test]
    fn tuned_tree_solves_xor() {
        let (x, y) = testdata::xor(40, 51);
        let t = tune_family(Family::DecisionTree, &x, &y, 8, 1);
        assert!(t.valid_accuracy > 0.9, "{}", t.valid_accuracy);
        let preds = t.model.predict(&x);
        assert!(crate::ml::metrics::accuracy(&y, &preds) > 0.9);
    }

    #[test]
    fn all_families_build_from_any_choice() {
        let (x, _) = testdata::blobs(5, 52);
        for f in Family::ALL {
            for c in f.space().enumerate().iter().take(6) {
                let _ = f.build(c, &x, 0);
            }
        }
    }

    #[test]
    fn centroid_tuning_cheap_and_valid() {
        let (x, y) = testdata::blobs(25, 53);
        let t = tune_family(Family::NearestCentroid, &x, &y, 3, 2);
        assert!(t.valid_accuracy > 0.9);
        assert_eq!(t.family.name(), "nearest_centroid");
    }
}
