//! The sharded serving pool: the public face of `serve`.
//!
//! [`Pool::start`] spawns N shard workers, each owning a private
//! backend (PJRT clients are not `Send`). Matrix ids are partitioned
//! across shards by a splitmix hash, so one matrix's requests always
//! meet on the same worker — that is what lets the admission queue
//! coalesce them into single-launch SpMM dispatches and keeps
//! conversion/prepared-literal state shard-local with no cross-thread
//! synchronization on the execute path.
//!
//! Every pool routes through a versioned [`SwapRouter`]; a pool started
//! with [`Pool::start`] simply never swaps it (version stays 1).
//! [`Pool::start_adaptive`] attaches a [`crate::online::Online`] loop:
//! the shards then consult its exploration bandit per dispatch, feed
//! observations back, and migrate registered matrices when a retrain
//! hot-swaps the router.

use super::backend::BackendSpec;
use super::batch::{Job, JobKind};
use super::shard::{Shard, ShardCfg, ShardMsg, StepOp};
use super::telemetry::{MatrixStats, Telemetry};
use super::{Rejected, Response};
use crate::coordinator::RunTimeOptimizer;
use crate::gpusim::{turing_gtx1650m, GpuArch};
use crate::obs::{
    ArmProfile, Event, EventKind, FlightRecord, FlightRecorder, Metrics, SloConfig, SloEngine,
    SloSnapshot, SloStatus, Stage, StageStats,
};
use crate::online::{DriftStatus, Online, SwapRouter};
use crate::sparse::convert::ConvertParams;
use crate::sparse::{Coo, Format};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Pool tuning knobs.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker shards (>= 1). Each owns a backend instance.
    pub workers: usize,
    /// Admission window: how long a shard holds the first request of a
    /// batch open for concurrent clients. Zero (the default) coalesces
    /// only what is already queued, adding no latency for sequential
    /// callers.
    pub batch_window: Duration,
    /// Hard cap on requests per dispatch.
    pub max_batch: usize,
    /// Converted-matrix LRU capacity per shard.
    pub cache_capacity: usize,
    /// Structural conversion parameters (BELL block, SELL slice).
    pub convert: ConvertParams,
    /// GPU profile used for the telemetry energy/power model.
    pub arch: GpuArch,
    /// Request-lifecycle stage tracing (DESIGN.md §10). On by default:
    /// the hot-path cost is two `Instant::now` reads and a handful of
    /// relaxed atomic adds per request (benchmarked under 3% end to
    /// end). Off, responses carry `trace: None` and the stage
    /// histograms stay empty.
    pub tracing: bool,
    /// Service-level objective to evaluate traffic against (DESIGN.md
    /// §11). None (the default) disables the SLO engine AND the trace
    /// flight recorder — the hot path then pays nothing for either.
    /// The engine itself stays observational (alert + capture); it only
    /// actuates when [`PoolConfig::scaleout`] is also set, in which
    /// case the control plane consults its status to gate admission
    /// shedding and to force-replicate matrices whose override scope
    /// degrades (DESIGN.md §12).
    pub slo: Option<SloConfig>,
    /// Scale-out control plane (DESIGN.md §12): hot-matrix replication,
    /// least-loaded routing across replicas, and SLO-driven admission
    /// control. None (the default) keeps the frozen splitmix hash
    /// partition — bit-identical routing to every earlier release.
    pub scaleout: Option<ScaleOutConfig>,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            workers: 2,
            batch_window: Duration::ZERO,
            max_batch: 32,
            cache_capacity: 64,
            convert: ConvertParams::default(),
            arch: turing_gtx1650m(),
            tracing: true,
            slo: None,
            scaleout: None,
        }
    }
}

/// Scale-out control-plane knobs (DESIGN.md §12). All decisions fire at
/// admission-count boundaries (never wall-clock), so two identically
/// seeded workloads produce identical replicate/unreplicate/shed event
/// sequences.
#[derive(Debug, Clone)]
pub struct ScaleOutConfig {
    /// Traffic share (of the decayed window counts) at or above which a
    /// matrix is considered hot and replicated onto more shards.
    pub replicate_share: f64,
    /// Share at or below which a replicated matrix is considered cooled
    /// and its extra replicas are dropped. Keep well under
    /// `replicate_share` for hysteresis.
    pub unreplicate_share: f64,
    /// Admissions per control evaluation: every `window` admitted
    /// requests the pool re-evaluates replication and halves the decayed
    /// per-matrix counts.
    pub window: u64,
    /// Cap on shards a hot matrix may occupy (home included);
    /// 0 means every shard.
    pub max_replicas: usize,
    /// Outstanding-request bound for admission control: while the SLO
    /// reports Warning/Breach, requests arriving with the summed shard
    /// queue depth at or above this are shed as
    /// [`Rejected::Overloaded`]. 0 sheds everything under pressure;
    /// irrelevant while the SLO is Ok (or absent) — an unloaded pool
    /// never sheds.
    pub admission_cap: usize,
}

impl Default for ScaleOutConfig {
    fn default() -> Self {
        ScaleOutConfig {
            replicate_share: 0.5,
            unreplicate_share: 0.125,
            window: 64,
            max_replicas: 0,
            admission_cap: 1024,
        }
    }
}

/// Decayed traffic accounting + replica placement, all guarded by one
/// mutex so control decisions are serialized and deterministic in the
/// admission order.
struct ControlState {
    /// Decayed per-matrix request counts (halved every window).
    counts: HashMap<u64, u64>,
    /// Sum of `counts` (kept in step so share math is O(1)).
    total: u64,
    /// Requests admitted over the pool's lifetime (shed requests are
    /// NOT admitted) — the `at=` coordinate of every control event.
    admitted: u64,
    /// Shard indices currently holding each matrix, home first.
    owners: HashMap<u64, Vec<usize>>,
    /// Retained registration sources: replicating onto a new shard
    /// replays the original `Register` there.
    registrations: HashMap<u64, (Coo, u64)>,
    /// Open sessions per matrix: while > 0 the matrix routes to its
    /// pinned home shard regardless of replica load.
    pinned: HashMap<u64, u64>,
    /// One `shed` journal event per control window (the shed counters
    /// track volume; the journal tracks episodes).
    shed_logged: bool,
}

struct Control {
    cfg: ScaleOutConfig,
    state: Mutex<ControlState>,
}

impl Control {
    fn new(cfg: ScaleOutConfig) -> Control {
        Control {
            cfg,
            state: Mutex::new(ControlState {
                counts: HashMap::new(),
                total: 0,
                admitted: 0,
                owners: HashMap::new(),
                registrations: HashMap::new(),
                pinned: HashMap::new(),
                shed_logged: false,
            }),
        }
    }
}

/// Aggregate pool statistics (see also the per-matrix rows).
#[derive(Debug, Clone)]
pub struct PoolStats {
    pub requests: u64,
    /// Kernel dispatches; `requests - dispatches` products were served
    /// "for free" by coalescing.
    pub dispatches: u64,
    /// Kernel launches. One per batch (per bucket chunk) on the SpMM
    /// paths; one per request on the per-vector prepared fallback —
    /// see [`PoolStats::launches_per_request`].
    pub launches: u64,
    /// Dispatches executed through a true SpMM path.
    pub spmm_dispatches: u64,
    pub coalesced_batches: u64,
    pub batched_requests: u64,
    pub max_batch: u64,
    pub conversions: u64,
    pub reconversions: u64,
    pub evictions: u64,
    pub registered_matrices: usize,
    pub cached_matrices: usize,
    pub workers: usize,
    /// Backend each shard ACTUALLY built, in shard order — differs from
    /// the requested spec when PJRT init failed and a shard degraded to
    /// native.
    pub backends: Vec<&'static str>,
    /// Total modeled energy across all matrices (joules).
    pub total_energy_j: f64,
    /// Router version (1 until the first hot-swap).
    pub router_version: u64,
    /// Completed retrains of the online loop (0 when frozen).
    pub retrains: u64,
    /// Registered matrices migrated to a new format on a hot-swap.
    pub migrations: u64,
    /// Registered matrices whose compile-knob decision changed on a
    /// hot-swap (artifact re-selection / re-preparation).
    pub knob_migrations: u64,
    /// Requests the exploration bandit routed off the predicted path.
    pub explored_requests: u64,
    /// Exploration picks made through the per-arm UCB scorer (0 when
    /// frozen or below the evidence floor).
    pub ucb_routes: u64,
    /// Requests observed by the feedback loop (batch-weighted, the
    /// retrain-cadence unit; None when frozen).
    pub observed_requests: Option<u64>,
    /// Drift detector status (None when frozen).
    pub drift: Option<DriftStatus>,
    /// Iterative sessions currently open across all shards.
    pub active_sessions: usize,
    /// Sessions opened over the pool's lifetime.
    pub sessions_opened: u64,
    /// Products served as session steps (subset of `requests`).
    pub session_steps: u64,
    /// Vector bytes that crossed the dispatch boundary (x in + y out on
    /// the per-request path; explicit session writes/reads).
    pub marshalled_bytes: u64,
    /// Vector bytes session steps kept resident instead of moving.
    pub elided_bytes: u64,
    /// Host round-trips session steps elided (one per pure step).
    pub round_trips_elided: u64,
    /// Requests submitted with a client deadline tag.
    pub deadline_tagged: u64,
    /// Tagged requests whose end-to-end service time exceeded their
    /// deadline (observational — nothing is shed).
    pub deadline_misses: u64,
    /// Requests rejected at admission (never enqueued, not in
    /// `requests`) — nonzero only with the scale-out control plane
    /// under SLO pressure.
    pub sheds: u64,
    /// Sheds with reason [`Rejected::Overloaded`].
    pub sheds_overloaded: u64,
    /// Sheds with reason [`Rejected::DeadlineExceeded`].
    pub sheds_deadline: u64,
    /// Requests a replicated matrix's least-loaded routing sent off the
    /// hash-home shard.
    pub reroutes: u64,
    /// Replica registrations created by the control plane.
    pub replications: u64,
    /// Replica registrations dropped after their matrix cooled.
    pub unreplications: u64,
    /// Extra replica registrations currently live (beyond each
    /// matrix's home shard); 0 without scale-out.
    pub replicas: u64,
    /// Outstanding product jobs per shard queue at snapshot time, in
    /// shard order.
    pub queue_depths: Vec<u64>,
    /// Per-stage latency histograms (one row per [`crate::obs::Stage`],
    /// all empty when tracing is off). The stages decompose the
    /// end-to-end histograms exactly: see [`PoolStats::stage_coverage`].
    pub stage_stats: Vec<StageStats>,
    /// Control-plane events emitted over the pool's lifetime (including
    /// any that have since been dropped from the bounded journal).
    pub events_total: u64,
    /// Events dropped from the journal ring (oldest-first) at capacity.
    pub events_dropped: u64,
    /// Router generation the per-arm attribution windows are aligned to
    /// (1 until the first hot-swap).
    pub arm_generation: u64,
    /// Per-arm cost attribution: one row per joint (format, knob) arm
    /// that served at least one request, in arm-index order.
    pub arm_profiles: Vec<ArmProfile>,
    /// SLO engine snapshot for the pool scope (None when the pool was
    /// started without an SLO).
    pub slo: Option<SloSnapshot>,
    pub per_matrix: Vec<MatrixStats>,
}

impl PoolStats {
    /// Deduplicated backend label for report headers ("native",
    /// "pjrt", or e.g. "native+pjrt" for a mixed degraded pool).
    pub fn backend_summary(&self) -> String {
        let mut names = self.backends.clone();
        names.sort_unstable();
        names.dedup();
        if names.is_empty() {
            "unknown".to_string()
        } else {
            names.join("+")
        }
    }

    /// Kernel launches per served request — the batching win in one
    /// number: 1.0 means every product paid its own launch; a coalesced
    /// SpMM workload drives this below 1 (0 when nothing served yet).
    pub fn launches_per_request(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.launches as f64 / self.requests as f64
        }
    }

    /// Marshalled vector bytes per served request — the round-trip cost
    /// in one number. The per-request path pays `4*(n_cols + n_rows)`
    /// for every product; session traffic drives this toward the
    /// amortized write/read cost (0 when nothing served yet).
    pub fn marshalled_bytes_per_request(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.marshalled_bytes as f64 / self.requests as f64
        }
    }

    /// Fraction of total vector traffic the session fast path elided
    /// (0 when nothing was served or no sessions ran).
    pub fn elision_ratio(&self) -> f64 {
        let total = self.marshalled_bytes + self.elided_bytes;
        if total == 0 {
            0.0
        } else {
            self.elided_bytes as f64 / total as f64
        }
    }

    /// Summed service time across all served requests.
    pub fn total_service(&self) -> Duration {
        self.per_matrix.iter().map(|m| m.total_latency).sum()
    }

    /// Worst single-request service time.
    pub fn max_service(&self) -> Duration {
        self.per_matrix.iter().map(|m| m.max_latency).max().unwrap_or(Duration::ZERO)
    }

    /// Summed duration across every stage histogram.
    pub fn stage_total(&self) -> Duration {
        self.stage_stats.iter().map(|s| s.total()).sum()
    }

    /// Ratio of stage-decomposed time to end-to-end service time. The
    /// shard records each request's stages against the same shared
    /// boundary instants it derives `service_time` from, so with
    /// tracing on this is 1.0 exactly (stage durations are an exact
    /// partition, summed in integer nanoseconds); 0.0 when nothing was
    /// served or tracing is off.
    pub fn stage_coverage(&self) -> f64 {
        let e2e = self.total_service().as_nanos();
        if e2e == 0 {
            0.0
        } else {
            self.stage_total().as_nanos() as f64 / e2e as f64
        }
    }

    /// Export the snapshot as metric families (DESIGN.md §10.3).
    /// Render with [`Metrics::render_text`] (Prometheus text
    /// exposition) or [`Metrics::to_table`] (the `report` twin).
    pub fn metrics(&self) -> Metrics {
        let mut m = Metrics::new();
        m.counter(
            "spmv_requests_total",
            "Products served, session steps included",
            self.requests as f64,
        );
        m.counter("spmv_dispatches_total", "Kernel dispatches executed", self.dispatches as f64);
        m.counter("spmv_launches_total", "Kernel launches executed", self.launches as f64);
        m.counter(
            "spmv_spmm_dispatches_total",
            "Dispatches that rode a true SpMM path",
            self.spmm_dispatches as f64,
        );
        m.counter(
            "spmv_coalesced_batches_total",
            "Dispatches that coalesced more than one request",
            self.coalesced_batches as f64,
        );
        m.counter("spmv_conversions_total", "Format conversions", self.conversions as f64);
        m.counter(
            "spmv_reconversions_total",
            "Post-eviction re-conversions on the chosen path",
            self.reconversions as f64,
        );
        m.counter("spmv_evictions_total", "Conversion-cache evictions", self.evictions as f64);
        m.counter(
            "spmv_migrations_total",
            "Matrices migrated to a new format on a hot-swap",
            self.migrations as f64,
        );
        m.counter(
            "spmv_knob_migrations_total",
            "Matrices whose compile-knob decision changed on a hot-swap",
            self.knob_migrations as f64,
        );
        m.counter(
            "spmv_explored_requests_total",
            "Requests the exploration bandit routed off the predicted path",
            self.explored_requests as f64,
        );
        m.counter("spmv_retrains_total", "Completed online retrains", self.retrains as f64);
        m.counter(
            "spmv_sessions_opened_total",
            "Iterative sessions opened",
            self.sessions_opened as f64,
        );
        m.counter(
            "spmv_session_steps_total",
            "Products served as chained session steps",
            self.session_steps as f64,
        );
        m.counter(
            "spmv_marshalled_bytes_total",
            "Vector bytes moved across the dispatch boundary",
            self.marshalled_bytes as f64,
        );
        m.counter(
            "spmv_elided_bytes_total",
            "Vector bytes session steps kept resident",
            self.elided_bytes as f64,
        );
        m.counter(
            "spmv_round_trips_elided_total",
            "Host round-trips elided by session steps",
            self.round_trips_elided as f64,
        );
        m.counter(
            "spmv_deadline_tagged_total",
            "Requests submitted with a deadline tag",
            self.deadline_tagged as f64,
        );
        m.counter(
            "spmv_deadline_misses_total",
            "Tagged requests that exceeded their deadline",
            self.deadline_misses as f64,
        );
        m.labeled_counter(
            "spmv_sheds_total",
            "Requests rejected at admission, by reason",
            &[("reason", "overloaded".to_string())],
            self.sheds_overloaded as f64,
        );
        m.labeled_counter(
            "spmv_sheds_total",
            "Requests rejected at admission, by reason",
            &[("reason", "deadline".to_string())],
            self.sheds_deadline as f64,
        );
        m.counter(
            "spmv_reroutes_total",
            "Requests routed off their hash-home shard by replica load",
            self.reroutes as f64,
        );
        m.counter(
            "spmv_replications_total",
            "Replica registrations created by the control plane",
            self.replications as f64,
        );
        m.counter(
            "spmv_unreplications_total",
            "Replica registrations dropped after cooling",
            self.unreplications as f64,
        );
        m.gauge(
            "spmv_replicas",
            "Extra replica registrations currently live",
            self.replicas as f64,
        );
        for (i, depth) in self.queue_depths.iter().enumerate() {
            m.labeled_gauge(
                "spmv_queue_depth",
                "Outstanding product jobs per shard queue",
                &[("shard", i.to_string())],
                *depth as f64,
            );
        }
        m.counter(
            "spmv_events_total",
            "Control-plane events emitted (journaled plus dropped)",
            self.events_total as f64,
        );
        m.counter(
            "spmv_events_dropped_total",
            "Control-plane events dropped from the bounded journal",
            self.events_dropped as f64,
        );
        m.gauge(
            "spmv_router_version",
            "Policy version (1 until the first hot-swap)",
            self.router_version as f64,
        );
        m.gauge(
            "spmv_registered_matrices",
            "Matrices registered across shards",
            self.registered_matrices as f64,
        );
        m.gauge(
            "spmv_cached_matrices",
            "Converted forms resident in shard LRUs",
            self.cached_matrices as f64,
        );
        m.gauge(
            "spmv_active_sessions",
            "Iterative sessions currently open",
            self.active_sessions as f64,
        );
        m.gauge("spmv_workers", "Shard worker threads", self.workers as f64);
        m.gauge(
            "spmv_modeled_energy_joules",
            "Total modeled energy across matrices (gpusim)",
            self.total_energy_j,
        );
        m.gauge(
            "spmv_stage_coverage_ratio",
            "Stage-decomposed time over end-to-end service time (1.0 = exact)",
            self.stage_coverage(),
        );
        for s in &self.stage_stats {
            m.histogram(
                "spmv_stage_seconds",
                "Per-request latency decomposed by lifecycle stage",
                &[("stage", s.stage.name().to_string())],
                &s.hist,
            );
        }
        m.gauge(
            "spmv_arm_generation",
            "Router generation the arm-attribution windows are aligned to",
            self.arm_generation as f64,
        );
        for p in &self.arm_profiles {
            let labels = [
                ("kind", p.kind.clone()),
                ("format", p.format.clone()),
                ("knobs", p.knobs.clone()),
            ];
            m.labeled_counter(
                "spmv_arm_requests_total",
                "Requests served per (kernel kind, format, knob) arm",
                &labels,
                p.requests as f64,
            );
            m.labeled_counter(
                "spmv_arm_seconds_total",
                "Request-weighted exec time per joint arm",
                &labels,
                p.exec_s,
            );
            m.labeled_counter(
                "spmv_arm_energy_joules_total",
                "Modeled energy per joint arm (gpusim)",
                &labels,
                p.energy_j,
            );
            m.labeled_gauge(
                "spmv_arm_power_watts",
                "Request-weighted mean modeled power per joint arm",
                &labels,
                p.mean_power_w,
            );
            m.labeled_gauge(
                "spmv_arm_mflops_per_watt",
                "Request-weighted mean modeled efficiency per joint arm",
                &labels,
                p.mflops_per_watt,
            );
        }
        if let Some(slo) = &self.slo {
            m.gauge(
                "spmv_slo_status",
                "SLO status at the last evaluation (0 ok / 1 warning / 2 breach)",
                slo.status.as_f64(),
            );
            m.gauge(
                "spmv_slo_p99_target_seconds",
                "Configured p99 service-time target",
                slo.p99_target.as_secs_f64(),
            );
            m.gauge(
                "spmv_slo_miss_budget_ratio",
                "Allowed deadline-miss fraction among tagged requests",
                slo.miss_budget,
            );
            m.counter(
                "spmv_slo_evals_total",
                "SLO evaluations run (one per fast-window of requests)",
                slo.evals as f64,
            );
            m.counter("spmv_slo_alerts_total", "SLO breach episodes alerted", slo.alerts as f64);
            m.counter(
                "spmv_slo_recoveries_total",
                "SLO breach episodes recovered",
                slo.recoveries as f64,
            );
            // burn rates are +inf when the budget is zero; clamp so the
            // text exposition stays parseable
            m.gauge(
                "spmv_slo_fast_burn_ratio",
                "Deadline-miss burn rate over the fast window (1.0 = at budget)",
                slo.fast_burn.min(1e9),
            );
            m.gauge(
                "spmv_slo_slow_burn_ratio",
                "Deadline-miss burn rate over the full history",
                slo.slow_burn.min(1e9),
            );
            if let Some(p99) = slo.fast_p99_us {
                m.gauge(
                    "spmv_slo_window_p99_seconds",
                    "Fast-window p99 service time at the last evaluation",
                    p99 * 1e-6,
                );
            }
            m.gauge(
                "spmv_flight_records",
                "Trace records frozen by the last SLO breach capture",
                slo.flight_captured as f64,
            );
        }
        for mat in &self.per_matrix {
            let labels = [("matrix", mat.id.to_string())];
            m.labeled_gauge(
                "spmv_matrix_requests",
                "Requests served per registered matrix",
                &labels,
                mat.requests as f64,
            );
            if let Some(p50) = mat.p50_us {
                m.labeled_gauge(
                    "spmv_matrix_p50_seconds",
                    "Median end-to-end service time per matrix",
                    &labels,
                    p50 * 1e-6,
                );
            }
            if let Some(p99) = mat.p99_us {
                m.labeled_gauge(
                    "spmv_matrix_p99_seconds",
                    "p99 end-to-end service time per matrix",
                    &labels,
                    p99 * 1e-6,
                );
            }
            m.labeled_gauge(
                "spmv_matrix_energy_joules",
                "Modeled energy per matrix (gpusim)",
                &labels,
                mat.energy_j,
            );
        }
        m
    }
}

/// Handle to a running sharded serving pool.
pub struct Pool {
    shards: Vec<Shard>,
    telemetry: Arc<Telemetry>,
    router: Arc<SwapRouter>,
    online: Option<Arc<Online>>,
    /// Monotone session-id allocator (pool-unique, never reused).
    session_ids: AtomicU64,
    /// Per-shard outstanding-job counters (shared with the workers
    /// through [`ShardCfg`]); maintained even without scale-out so
    /// `spmv_queue_depth` always exports.
    depths: Vec<Arc<AtomicU64>>,
    /// The scale-out control plane, when configured.
    control: Option<Arc<Control>>,
}

impl Pool {
    /// Start the worker shards with a frozen router (never swapped);
    /// each shard builds its own backend from `backend`.
    pub fn start(router: Arc<RunTimeOptimizer>, backend: BackendSpec, cfg: PoolConfig) -> Pool {
        Pool::start_inner(Arc::new(SwapRouter::new(router)), None, backend, cfg)
    }

    /// Start the pool with the closed loop attached: decisions flow
    /// through `online`'s hot-swappable router, dispatches may explore,
    /// observations feed its trainer, and registered matrices re-decide
    /// (migrate) on every router upgrade.
    pub fn start_adaptive(online: Arc<Online>, backend: BackendSpec, cfg: PoolConfig) -> Pool {
        Pool::start_inner(online.router.clone(), Some(online), backend, cfg)
    }

    fn start_inner(
        router: Arc<SwapRouter>,
        online: Option<Arc<Online>>,
        backend: BackendSpec,
        cfg: PoolConfig,
    ) -> Pool {
        // The router owns the event journal (the online loop emits into
        // it before any pool exists); telemetry shares it so shard-side
        // emissions and `Pool::events` read the same ring. The SLO
        // engine (and its flight recorder) exists only when configured.
        let workers = cfg.workers.max(1);
        let telemetry = match &cfg.slo {
            Some(slo_cfg) => {
                let engine =
                    Arc::new(SloEngine::new(slo_cfg.clone(), workers, router.journal().clone()));
                Arc::new(Telemetry::with_slo(router.journal().clone(), engine))
            }
            None => Arc::new(Telemetry::with_journal(router.journal().clone())),
        };
        let depths: Vec<Arc<AtomicU64>> =
            (0..workers).map(|_| Arc::new(AtomicU64::new(0))).collect();
        let shard_cfg = ShardCfg {
            shard: 0,
            convert: cfg.convert,
            batch_window: cfg.batch_window,
            max_batch: cfg.max_batch.max(1),
            cache_capacity: cfg.cache_capacity.max(1),
            arch: cfg.arch.clone(),
            tracing: cfg.tracing,
            depth: depths[0].clone(),
        };
        let shards = (0..workers)
            .map(|i| {
                let mut shard_cfg = shard_cfg.clone();
                shard_cfg.shard = i;
                shard_cfg.depth = depths[i].clone();
                Shard::spawn(
                    i,
                    router.clone(),
                    online.clone(),
                    backend.clone(),
                    shard_cfg,
                    telemetry.clone(),
                )
            })
            .collect();
        let control = cfg.scaleout.map(|sc| Arc::new(Control::new(sc)));
        Pool { shards, telemetry, router, online, session_ids: AtomicU64::new(0), depths, control }
    }

    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// The versioned router handle (install a new optimizer through it
    /// to hot-swap; shards migrate on their next message).
    pub fn router(&self) -> &Arc<SwapRouter> {
        &self.router
    }

    /// The attached online loop, if this pool is adaptive.
    pub fn online(&self) -> Option<&Arc<Online>> {
        self.online.as_ref()
    }

    /// The home shard index for a matrix id (splitmix64-style spread so
    /// sequential ids don't pile onto one worker). Always the route
    /// without scale-out; the fallback and session pin with it.
    fn home_index(&self, matrix_id: u64) -> usize {
        let h = matrix_id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((h >> 32) as usize) % self.shards.len()
    }

    /// The shard owning a matrix id under plain hash routing.
    fn shard_of(&self, matrix_id: u64) -> &Shard {
        &self.shards[self.home_index(matrix_id)]
    }

    /// Register a matrix; returns the format the router chose for it.
    pub fn register(&self, id: u64, coo: Coo, iterations_hint: u64) -> Result<Format> {
        let home = self.home_index(id);
        if let Some(ctl) = &self.control {
            // Retain the source so the control plane can replay this
            // registration onto more shards later, and tear down any
            // stale replicas from a previous registration of the id.
            let mut st = ctl.state.lock().expect("control lock");
            if let Some(owners) = st.owners.get(&id) {
                for &s in owners.iter().filter(|&&s| s != home) {
                    let _ = self.shards[s].tx.send(ShardMsg::Deregister { id });
                }
            }
            st.owners.insert(id, vec![home]);
            st.registrations.insert(id, (coo.clone(), iterations_hint));
            if let Some(stale) = st.counts.remove(&id) {
                st.total -= stale;
            }
        }
        let (ack, rx) = channel();
        self.shards[home]
            .tx
            .send(ShardMsg::Register { id, coo, iterations_hint, ack })
            .map_err(|_| anyhow!("serving pool stopped"))?;
        rx.recv().map_err(|_| anyhow!("serving pool dropped registration"))?
    }

    /// Submit a product request and block for the response.
    pub fn product(&self, matrix_id: u64, x: impl Into<Arc<[f32]>>) -> Result<Response> {
        self.product_async(matrix_id, x)?
            .recv()
            .map_err(|_| anyhow!("serving pool dropped request"))?
    }

    /// [`Pool::product`] with a client deadline tag: the tag counts the
    /// request in `deadline_tagged` and, when its end-to-end service
    /// time exceeds `deadline`, in `deadline_misses`. Without scale-out
    /// it is purely observational (nothing is shed or reordered); with
    /// [`PoolConfig::scaleout`] AND the SLO reporting Warning/Breach, a
    /// request whose budget is already spent — or smaller than the
    /// predicted queue wait — is rejected fast with
    /// [`Rejected::DeadlineExceeded`] instead of being enqueued.
    pub fn product_with_deadline(
        &self,
        matrix_id: u64,
        x: impl Into<Arc<[f32]>>,
        deadline: Duration,
    ) -> Result<Response> {
        self.product_async_with_deadline(matrix_id, x, Some(deadline))?
            .recv()
            .map_err(|_| anyhow!("serving pool dropped request"))?
    }

    /// Submit without waiting; the receiver yields the response later.
    /// Pipelining requests this way is also what fills the admission
    /// queue enough for coalescing to kick in. The payload is a shared
    /// `Arc<[f32]>` (a `Vec<f32>` converts with one allocation move):
    /// enqueueing is a refcount bump, and the dispatch reads the
    /// client's buffer directly — no copy anywhere on the request path.
    pub fn product_async(
        &self,
        matrix_id: u64,
        x: impl Into<Arc<[f32]>>,
    ) -> Result<Receiver<Result<Response>>> {
        self.product_async_with_deadline(matrix_id, x, None)
    }

    /// [`Pool::product_async`] with an optional deadline tag (see
    /// [`Pool::product_with_deadline`]).
    pub fn product_async_with_deadline(
        &self,
        matrix_id: u64,
        x: impl Into<Arc<[f32]>>,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Result<Response>>> {
        self.submit_async(matrix_id, JobKind::Spmv, x, deadline)
    }

    /// Solve `T x = b` against the registered matrix's lower (forward,
    /// `lower = true`) or upper (backward) triangle + diagonal, HPCG
    /// style: entries strictly on the other side of the diagonal are
    /// ignored, so a full matrix solves with its triangular part
    /// without the client pre-splitting it. Errors on a non-square
    /// matrix or a structurally/numerically zero diagonal pivot. Rides
    /// the same admission queue, coalescing, exploration, and telemetry
    /// path as [`Pool::product`] — grouped and attributed under
    /// `kind=sptrsv`.
    pub fn sptrsv(
        &self,
        matrix_id: u64,
        b: impl Into<Arc<[f32]>>,
        lower: bool,
    ) -> Result<Response> {
        self.submit_async(matrix_id, JobKind::Sptrsv { lower }, b, None)?
            .recv()
            .map_err(|_| anyhow!("serving pool dropped request"))?
    }

    /// One symmetric Gauss–Seidel sweep for `A x = b` from a zero
    /// initial guess (forward then backward pass) — the smoother /
    /// preconditioner application `M⁻¹ b`. Same admission path as
    /// [`Pool::product`], attributed under `kind=symgs`.
    pub fn symgs(&self, matrix_id: u64, b: impl Into<Arc<[f32]>>) -> Result<Response> {
        self.submit_async(matrix_id, JobKind::Symgs, b, None)?
            .recv()
            .map_err(|_| anyhow!("serving pool dropped request"))?
    }

    fn submit_async(
        &self,
        matrix_id: u64,
        kind: JobKind,
        x: impl Into<Arc<[f32]>>,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Result<Response>>> {
        let shard = match &self.control {
            Some(ctl) => self.admit(ctl, matrix_id, deadline)?,
            None => self.home_index(matrix_id),
        };
        let (reply, rx) = channel();
        // Increment BEFORE the send: the worker decrements after
        // pickup, so the counter can never underflow.
        self.depths[shard].fetch_add(1, Ordering::Relaxed);
        if self.shards[shard]
            .tx
            .send(ShardMsg::Product(Job {
                matrix_id,
                kind,
                x: x.into(),
                enqueued: Instant::now(),
                deadline,
                reply,
            }))
            .is_err()
        {
            self.depths[shard].fetch_sub(1, Ordering::Relaxed);
            return Err(anyhow!("serving pool stopped"));
        }
        Ok(rx)
    }

    /// Admission control + routing (scale-out pools only): shed under
    /// SLO pressure, account the request into the decayed popularity
    /// window, run the control evaluation at window boundaries, and
    /// pick the serving shard — pinned home while a session is open,
    /// least-loaded owner for a replicated matrix, hash home otherwise.
    fn admit(&self, ctl: &Control, matrix_id: u64, deadline: Option<Duration>) -> Result<usize> {
        // Shedding engages only under SLO pressure, so an unloaded pool
        // (or one without an SLO) admits exactly like plain hashing.
        let pressured =
            self.telemetry.slo().is_some_and(|engine| engine.status() >= SloStatus::Warning);
        if pressured {
            let outstanding: u64 = self.depths.iter().map(|d| d.load(Ordering::Relaxed)).sum();
            let reason = if outstanding >= ctl.cfg.admission_cap as u64 {
                Some(Rejected::Overloaded)
            } else {
                match deadline {
                    Some(budget) if budget.is_zero() || budget < self.predicted_queue_wait() => {
                        Some(Rejected::DeadlineExceeded)
                    }
                    _ => None,
                }
            };
            if let Some(reason) = reason {
                let t = &self.telemetry.totals;
                let by_reason = match reason {
                    Rejected::Overloaded => &t.sheds_overloaded,
                    Rejected::DeadlineExceeded => &t.sheds_deadline,
                };
                t.sheds.fetch_add(1, Ordering::Relaxed);
                by_reason.fetch_add(1, Ordering::Relaxed);
                let mut st = ctl.state.lock().expect("control lock");
                if !st.shed_logged {
                    st.shed_logged = true;
                    self.telemetry.journal().emit(EventKind::Shed {
                        matrix: matrix_id,
                        reason: reason.reason(),
                        at_requests: st.admitted,
                    });
                }
                return Err(anyhow::Error::new(reason));
            }
        }
        let mut st = ctl.state.lock().expect("control lock");
        st.admitted += 1;
        *st.counts.entry(matrix_id).or_insert(0) += 1;
        st.total += 1;
        if ctl.cfg.window > 0 && st.admitted % ctl.cfg.window == 0 {
            self.control_eval(ctl, &mut st);
        }
        let home = self.home_index(matrix_id);
        if st.pinned.get(&matrix_id).copied().unwrap_or(0) > 0 {
            return Ok(home);
        }
        let shard = match st.owners.get(&matrix_id) {
            Some(owners) if owners.len() > 1 => {
                let pick = owners
                    .iter()
                    .copied()
                    .min_by_key(|&s| (self.depths[s].load(Ordering::Relaxed), s))
                    .expect("owners non-empty");
                if pick != home {
                    self.telemetry.totals.reroutes.fetch_add(1, Ordering::Relaxed);
                }
                pick
            }
            _ => home,
        };
        Ok(shard)
    }

    /// Predicted time a request will spend queued before execution:
    /// mean queue wait + mean batch-formation wait from the stage
    /// histograms (zero with tracing off or before any traffic).
    fn predicted_queue_wait(&self) -> Duration {
        let us: f64 = self
            .telemetry
            .stages
            .snapshot()
            .iter()
            .filter(|s| matches!(s.stage, Stage::QueueWait | Stage::BatchWait))
            .map(|s| s.hist.mean_us())
            .sum();
        Duration::from_nanos((us * 1000.0) as u64)
    }

    /// One control evaluation at an admission-window boundary, with the
    /// control state locked: replicate hot matrices, drop cooled
    /// replicas, then halve the decayed counts. Matrix ids iterate in
    /// sorted order so the emitted event sequence is deterministic for
    /// a deterministic admission order.
    fn control_eval(&self, ctl: &Control, st: &mut ControlState) {
        let at = st.admitted;
        let nshards = self.shards.len();
        let target = if ctl.cfg.max_replicas == 0 {
            nshards
        } else {
            ctl.cfg.max_replicas.min(nshards)
        };
        let mut ids: Vec<u64> = st.counts.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let count = st.counts[&id];
            let share = if st.total == 0 { 0.0 } else { count as f64 / st.total as f64 };
            // An SLO override scope in Warning/Breach force-replicates
            // its matrix even below the traffic threshold (and holds
            // its replicas while degraded).
            let slo_hot = self
                .telemetry
                .slo()
                .and_then(|e| e.matrix_status(id))
                .is_some_and(|s| s >= SloStatus::Warning);
            let home = self.home_index(id);
            let Some(owners) = st.owners.get_mut(&id) else {
                continue; // never registered through this pool
            };
            let hot = share >= ctl.cfg.replicate_share || slo_hot;
            if hot && owners.len() < target {
                if let Some((coo, hint)) = st.registrations.get(&id) {
                    let mut grew = false;
                    for s in 0..nshards {
                        if owners.len() >= target {
                            break;
                        }
                        if owners.contains(&s) {
                            continue;
                        }
                        // Fire-and-forget replay of the registration:
                        // the channel is FIFO, so the replica is
                        // registered before any product we route to it
                        // after this point.
                        let (ack, _drop) = channel();
                        if self.shards[s]
                            .tx
                            .send(ShardMsg::Register {
                                id,
                                coo: coo.clone(),
                                iterations_hint: *hint,
                                ack,
                            })
                            .is_err()
                        {
                            continue;
                        }
                        owners.push(s);
                        grew = true;
                        self.telemetry.totals.replications.fetch_add(1, Ordering::Relaxed);
                        self.telemetry.journal().emit(EventKind::Replicate {
                            matrix: id,
                            shard: s,
                            replicas: owners.len(),
                            at_requests: at,
                        });
                    }
                    if grew {
                        self.telemetry.journal().emit(EventKind::Reroute {
                            matrix: id,
                            owners: owners.len(),
                            at_requests: at,
                        });
                    }
                }
            } else if owners.len() > 1 && share <= ctl.cfg.unreplicate_share && !slo_hot {
                let dropped = owners.len() - 1;
                for &s in owners.iter().filter(|&&s| s != home) {
                    let _ = self.shards[s].tx.send(ShardMsg::Deregister { id });
                }
                *owners = vec![home];
                self.telemetry.totals.unreplications.fetch_add(dropped as u64, Ordering::Relaxed);
                self.telemetry.journal().emit(EventKind::Unreplicate {
                    matrix: id,
                    dropped,
                    at_requests: at,
                });
            }
        }
        st.counts.retain(|_, c| {
            *c /= 2;
            *c > 0
        });
        st.total = st.counts.values().sum();
        st.shed_logged = false;
    }

    /// Open a device-resident iterative session pinned to a registered
    /// square matrix. The session serves chained products ([`Session::step`])
    /// without any host round-trip per iteration; while it is open the
    /// matrix's conversion is pinned and policy migrations defer to the
    /// session boundary. Fails for unknown or non-square matrices.
    pub fn open_session(&self, matrix_id: u64) -> Result<Session> {
        let shard = self.shard_of(matrix_id);
        let id = self.session_ids.fetch_add(1, Ordering::Relaxed) + 1;
        let (ack, rx) = channel();
        shard
            .tx
            .send(ShardMsg::SessionOpen { session: id, matrix_id, ack })
            .map_err(|_| anyhow!("serving pool stopped"))?;
        let n = rx.recv().map_err(|_| anyhow!("serving pool dropped session open"))??;
        // Route-pin the matrix to its home shard (where the session
        // lives) for as long as any session is open on it: least-loaded
        // routing must not send its products to a replica the session's
        // pinned conversion doesn't cover.
        let pin = self.control.clone();
        if let Some(ctl) = &pin {
            let mut st = ctl.state.lock().expect("control lock");
            *st.pinned.entry(matrix_id).or_insert(0) += 1;
        }
        Ok(Session { tx: shard.tx.clone(), id, matrix_id, n, pin })
    }

    /// Snapshot pool-wide counters, per-matrix latency quantiles, the
    /// modeled energy ledger, and the online loop's state (router
    /// version, retrains, exploration, drift).
    pub fn stats(&self) -> Result<PoolStats> {
        let mut registered = 0;
        let mut cached = 0;
        let mut active_sessions = 0;
        let mut backends = Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            let (tx, rx) = channel();
            shard.tx.send(ShardMsg::Status(tx)).map_err(|_| anyhow!("serving pool stopped"))?;
            let status = rx.recv().map_err(|_| anyhow!("serving pool dropped status"))?;
            registered += status.registered;
            cached += status.cached;
            active_sessions += status.active_sessions;
            backends.push(status.backend);
        }
        let per_matrix = self.telemetry.snapshot();
        let t = &self.telemetry.totals;
        Ok(PoolStats {
            requests: t.requests.load(Ordering::Relaxed),
            dispatches: t.dispatches.load(Ordering::Relaxed),
            launches: t.launches.load(Ordering::Relaxed),
            spmm_dispatches: t.spmm_dispatches.load(Ordering::Relaxed),
            coalesced_batches: t.coalesced_batches.load(Ordering::Relaxed),
            batched_requests: t.batched_requests.load(Ordering::Relaxed),
            max_batch: t.max_batch.load(Ordering::Relaxed),
            conversions: t.conversions.load(Ordering::Relaxed),
            reconversions: t.reconversions.load(Ordering::Relaxed),
            evictions: t.evictions.load(Ordering::Relaxed),
            registered_matrices: registered,
            cached_matrices: cached,
            workers: self.shards.len(),
            backends,
            total_energy_j: per_matrix.iter().map(|m| m.energy_j).sum(),
            router_version: self.router.version(),
            retrains: self.online.as_ref().map_or(0, |o| o.retrains()),
            migrations: t.migrations.load(Ordering::Relaxed),
            knob_migrations: t.knob_migrations.load(Ordering::Relaxed),
            explored_requests: t.explored_requests.load(Ordering::Relaxed),
            ucb_routes: self.online.as_ref().map_or(0, |o| o.ucb_routes()),
            observed_requests: self.online.as_ref().map(|o| o.observed_requests()),
            drift: self.online.as_ref().map(|o| o.drift_status()),
            active_sessions,
            sessions_opened: t.sessions_opened.load(Ordering::Relaxed),
            session_steps: t.session_steps.load(Ordering::Relaxed),
            marshalled_bytes: t.marshalled_bytes.load(Ordering::Relaxed),
            elided_bytes: t.elided_bytes.load(Ordering::Relaxed),
            round_trips_elided: t.round_trips_elided.load(Ordering::Relaxed),
            deadline_tagged: t.deadline_tagged.load(Ordering::Relaxed),
            deadline_misses: t.deadline_misses.load(Ordering::Relaxed),
            sheds: t.sheds.load(Ordering::Relaxed),
            sheds_overloaded: t.sheds_overloaded.load(Ordering::Relaxed),
            sheds_deadline: t.sheds_deadline.load(Ordering::Relaxed),
            reroutes: t.reroutes.load(Ordering::Relaxed),
            replications: t.replications.load(Ordering::Relaxed),
            unreplications: t.unreplications.load(Ordering::Relaxed),
            replicas: self.control.as_ref().map_or(0, |ctl| {
                let st = ctl.state.lock().expect("control lock");
                st.owners.values().map(|o| (o.len() - 1) as u64).sum()
            }),
            queue_depths: self.depths.iter().map(|d| d.load(Ordering::Relaxed)).collect(),
            stage_stats: self.telemetry.stages.snapshot(),
            events_total: self.telemetry.journal().total(),
            events_dropped: self.telemetry.journal().dropped(),
            arm_generation: self.telemetry.arms.generation(),
            arm_profiles: self.telemetry.arms.snapshot(),
            slo: self.telemetry.slo().map(|e| e.snapshot()),
            per_matrix,
        })
    }

    /// Trace flight records (DESIGN.md §11.3): the breach capture when
    /// one fired, else the live ring of most-recent traces. Empty when
    /// the pool runs without an SLO — the recorder only exists with one.
    pub fn flight_records(&self) -> Vec<FlightRecord> {
        match self.telemetry.slo() {
            Some(engine) => {
                let rec = engine.recorder();
                if rec.captures() > 0 {
                    rec.captured()
                } else {
                    rec.ring()
                }
            }
            None => Vec::new(),
        }
    }

    /// The flight records rendered as a JSON array (the serve CLI's
    /// `--flight-out` payload).
    pub fn flight_json(&self) -> String {
        FlightRecorder::to_json(&self.flight_records())
    }

    /// Snapshot the control-plane event journal: hot-swaps, retrains,
    /// migrations (applied and deferred), explored counterfactuals,
    /// drift triggers, session open/close — in emission order, oldest
    /// first (the ring drops oldest at capacity; see
    /// [`PoolStats::events_dropped`]).
    pub fn events(&self) -> Vec<Event> {
        self.telemetry.journal().snapshot()
    }

    /// The event journal rendered as a JSON array (the serve CLI's
    /// `--events-out` payload).
    pub fn events_json(&self) -> String {
        self.telemetry.journal().to_json()
    }

    /// Current metrics in Prometheus text-exposition format
    /// (DESIGN.md §10.3).
    pub fn metrics_text(&self) -> Result<String> {
        Ok(self.stats()?.metrics().render_text())
    }

    /// The same metric families as a `report` table (the JSON/TSV twin
    /// of [`Pool::metrics_text`]).
    pub fn metrics_table(&self) -> Result<crate::report::Table> {
        Ok(self.stats()?.metrics().to_table("metrics"))
    }
}

/// A device-resident iterative session over one pinned (square)
/// matrix, created by [`Pool::open_session`].
///
/// Lifecycle: `write(x0)` installs the vector (the one paid crossing),
/// then every [`Session::step`] computes y = A x and feeds y straight
/// back as the next x without surfacing it — on the PJRT backend the
/// vector literally stays on the device (buffer-identity chaining), on
/// native it is reused host-side without crossing the pool's
/// queue/reply boundary. [`Session::read`] copies the current vector
/// out. [`Session::power_step`] runs the normalized x' = A x / ||A x||
/// step — fused in ONE kernel when a power artifact is compiled for the
/// matrix.
///
/// Dropping the handle closes the session; any policy migration that
/// was deferred while the matrix was pinned is applied then.
pub struct Session {
    tx: Sender<ShardMsg>,
    id: u64,
    matrix_id: u64,
    n: usize,
    /// Keeps the matrix route-pinned to its home shard while open (only
    /// scale-out pools hand one out).
    pin: Option<Arc<Control>>,
}

impl Session {
    pub fn matrix_id(&self) -> u64 {
        self.matrix_id
    }

    /// The pinned matrix's (square) dimension.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Install the session's vector (host -> session crossing).
    pub fn write(&self, x: impl Into<Arc<[f32]>>) -> Result<()> {
        let (ack, rx) = channel();
        self.tx
            .send(ShardMsg::SessionWrite { session: self.id, x: x.into(), ack })
            .map_err(|_| anyhow!("serving pool stopped"))?;
        rx.recv().map_err(|_| anyhow!("serving pool dropped session write"))?
    }

    /// One chained product: the previous y becomes the next x with no
    /// host round-trip.
    pub fn step(&self) -> Result<()> {
        self.step_n(1)
    }

    /// `steps` chained products in one shard message.
    pub fn step_n(&self, steps: u64) -> Result<()> {
        self.send_op(steps, StepOp::Product { normalize: false })
    }

    /// One normalized power-iteration step x' = A x / ||A x|| (fused
    /// on-device when the inventory has a power artifact for the
    /// matrix; otherwise a plain step plus a host-side scale).
    pub fn power_step(&self) -> Result<()> {
        self.power_step_n(1)
    }

    /// `steps` normalized power steps in one shard message.
    pub fn power_step_n(&self, steps: u64) -> Result<()> {
        self.send_op(steps, StepOp::Product { normalize: true })
    }

    /// One in-session triangular solve x' = T⁻¹ x against the pinned
    /// matrix's lower (`lower = true`) or upper triangle + diagonal.
    /// The result replaces the session vector without surfacing — on
    /// PJRT the sweep runs host-side, bouncing the vector through the
    /// host once (charged to `marshalled_bytes`); the chain itself
    /// never crosses the pool boundary.
    pub fn sptrsv_step(&self, lower: bool) -> Result<()> {
        self.send_op(1, StepOp::Sptrsv { lower })
    }

    /// One in-session symmetric Gauss–Seidel sweep x' = M⁻¹ x (forward
    /// + backward pass from a zero guess) — the preconditioner
    /// application of a CG-with-SymGS chain, device-/host-resident like
    /// [`Session::sptrsv_step`].
    pub fn symgs_step(&self) -> Result<()> {
        self.send_op(1, StepOp::Symgs)
    }

    fn send_op(&self, steps: u64, op: StepOp) -> Result<()> {
        if steps == 0 {
            return Ok(());
        }
        let (ack, rx) = channel();
        self.tx
            .send(ShardMsg::SessionStep { session: self.id, steps, op, ack })
            .map_err(|_| anyhow!("serving pool stopped"))?;
        rx.recv().map_err(|_| anyhow!("serving pool dropped session step"))?
    }

    /// Copy the session's current vector out (session -> host crossing).
    pub fn read(&self) -> Result<Vec<f32>> {
        let (ack, rx) = channel();
        self.tx
            .send(ShardMsg::SessionRead { session: self.id, ack })
            .map_err(|_| anyhow!("serving pool stopped"))?;
        rx.recv().map_err(|_| anyhow!("serving pool dropped session read"))?
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // fire-and-forget: a stopped pool has nothing left to close
        let _ = self.tx.send(ShardMsg::SessionClose { session: self.id });
        if let Some(ctl) = &self.pin {
            let mut st = ctl.state.lock().expect("control lock");
            if let Some(open) = st.pinned.get_mut(&self.matrix_id) {
                *open -= 1;
                if *open == 0 {
                    st.pinned.remove(&self.matrix_id);
                }
            }
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        for shard in &mut self.shards {
            shard.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::gpusim::Objective;
    use crate::sparse::convert::coo_to_csr;
    use crate::sparse::SpMv;
    use crate::testutil::toy_router;

    fn test_router() -> Arc<RunTimeOptimizer> {
        Arc::new(toy_router(&["rim", "eu-2005", "shar_te2-b3"], Objective::EnergyEff))
    }

    fn pool_with(router: Arc<RunTimeOptimizer>, workers: usize, window_us: u64) -> Pool {
        Pool::start(
            router,
            BackendSpec::Native,
            PoolConfig {
                workers,
                batch_window: Duration::from_micros(window_us),
                ..Default::default()
            },
        )
    }

    /// Deterministic input vector for (matrix, request) pairs.
    fn input(n: usize, salt: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 7 + salt * 13) % 11) as f32 * 0.25 - 1.0).collect()
    }

    #[test]
    fn concurrent_sharded_pool_matches_single_worker_bit_for_bit() {
        let router = test_router();
        let names = ["rim", "eu-2005", "shar_te2-b3"];
        let mats: Vec<Coo> = names.iter().map(|n| gen::by_name(n).unwrap().generate(1)).collect();

        let single = pool_with(router.clone(), 1, 0);
        let sharded = pool_with(router.clone(), 2, 200);
        assert_eq!(sharded.workers(), 2);
        for (id, coo) in mats.iter().enumerate() {
            let f1 = single.register(id as u64, coo.clone(), 10_000).unwrap();
            let f2 = sharded.register(id as u64, coo.clone(), 10_000).unwrap();
            assert_eq!(f1, f2, "both pools must route {} identically", names[id]);
        }

        // Reference answers from the single-worker pool, serially.
        let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
        for (id, coo) in mats.iter().enumerate() {
            want.push(
                (0..8)
                    .map(|r| single.product(id as u64, input(coo.n_cols, r)).unwrap().y)
                    .collect(),
            );
        }

        // Many concurrent clients against the sharded pool.
        std::thread::scope(|scope| {
            for (id, coo) in mats.iter().enumerate() {
                let pool = &sharded;
                let expect = &want[id];
                scope.spawn(move || {
                    for r in 0..8 {
                        let resp = pool.product(id as u64, input(coo.n_cols, r)).unwrap();
                        assert_eq!(
                            resp.y, expect[r],
                            "matrix {id} request {r}: sharded pool must be bit-identical"
                        );
                    }
                });
            }
        });

        let stats = sharded.stats().unwrap();
        assert_eq!(stats.requests, (8 * mats.len()) as u64);
        assert_eq!(stats.registered_matrices, mats.len());
        assert!(stats.dispatches > 0);
    }

    #[test]
    fn stats_report_counts_quantiles_and_energy() {
        let router = test_router();
        let pool = pool_with(router, 2, 0);
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(1, coo, 1000).unwrap();
        for r in 0..6 {
            pool.product(1, input(n, r)).unwrap();
        }
        let stats = pool.stats().unwrap();
        assert_eq!(stats.requests, 6);
        // sequential callers never coalesce: one launch per request
        assert_eq!(stats.launches, 6);
        assert!((stats.launches_per_request() - 1.0).abs() < 1e-12);
        assert_eq!(stats.per_matrix.len(), 1);
        let m = &stats.per_matrix[0];
        assert_eq!(m.id, 1);
        assert_eq!(m.requests, 6);
        assert!(m.format.is_some());
        let (p50, p90, p99) = (m.p50_us.unwrap(), m.p90_us.unwrap(), m.p99_us.unwrap());
        assert!(p50 > 0.0 && p50 <= p90 && p90 <= p99);
        assert!(m.energy_j > 0.0, "modeled energy must be non-zero: {m:?}");
        assert!(m.model_power_w > 0.0);
        assert!(stats.total_energy_j >= m.energy_j);
        assert!(stats.total_service() >= stats.max_service());
        assert_eq!(stats.backends, vec!["native", "native"]);
        assert_eq!(stats.backend_summary(), "native");
        // decision accounting: all 6 requests rode the chosen format
        // at the default knob decision
        let fmt = m.format.unwrap();
        assert_eq!(m.chosen_by_format[fmt.class_id()], 6);
        assert_eq!(m.explored(), 0);
        assert_eq!(
            m.knobs,
            Some(crate::coordinator::compile_time::CompileChoice::serving_default()),
            "a frozen pool serves at the default knobs"
        );
        assert_eq!(m.non_default_knob_requests(), 0);
    }

    #[test]
    fn frozen_pool_reports_no_online_state() {
        let pool = pool_with(test_router(), 1, 0);
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(1, coo, 10).unwrap();
        pool.product(1, input(n, 0)).unwrap();
        let stats = pool.stats().unwrap();
        assert_eq!(stats.router_version, 1, "frozen pools never swap");
        assert_eq!(stats.retrains, 0);
        assert_eq!(stats.migrations, 0);
        assert_eq!(stats.knob_migrations, 0);
        assert_eq!(stats.explored_requests, 0);
        assert_eq!(stats.ucb_routes, 0);
        assert!(stats.observed_requests.is_none());
        assert!(stats.drift.is_none());
        assert!(pool.online().is_none());
    }

    #[test]
    fn pipelined_requests_coalesce_into_batched_dispatches() {
        let router = test_router();
        // One worker + a generous window: the first request holds the
        // batch open while the rest of the burst lands in the queue.
        let pool = pool_with(router, 1, 100_000);
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(1, coo, 1000).unwrap();
        let receivers: Vec<_> =
            (0..8).map(|r| pool.product_async(1, input(n, r)).unwrap()).collect();
        let responses: Vec<Response> =
            receivers.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        let stats = pool.stats().unwrap();
        assert_eq!(stats.requests, 8);
        assert!(
            stats.max_batch >= 2,
            "burst of 8 must coalesce (max_batch {}, dispatches {})",
            stats.max_batch,
            stats.dispatches
        );
        assert!(stats.dispatches < 8, "coalescing must save dispatches");
        assert!(stats.coalesced_batches >= 1);
        assert!(responses.iter().any(|r| r.batch_size > 1));
        // SpMM launch accounting: the native backend serves each
        // coalesced group in ONE matrix walk, so launches == dispatches
        // and the per-request launch cost drops below 1.
        assert_eq!(stats.launches, stats.dispatches);
        assert_eq!(stats.spmm_dispatches, stats.dispatches);
        assert!(
            stats.launches_per_request() < 1.0,
            "coalesced batches must amortize launches: {} launches / {} requests",
            stats.launches,
            stats.requests
        );
        // batched results still correct
        let csr = coo_to_csr(&gen::by_name("rim").unwrap().generate(1));
        for (r, resp) in responses.iter().enumerate() {
            assert_eq!(resp.y, csr.spmv_alloc(&input(n, r)));
        }
    }

    #[test]
    fn eviction_and_reconversion_keep_serving_correctly() {
        let router = test_router();
        let pool = Pool::start(
            router,
            BackendSpec::Native,
            PoolConfig { workers: 1, cache_capacity: 2, ..Default::default() },
        );
        let names = ["rim", "eu-2005", "shar_te2-b3"];
        let mats: Vec<Coo> = names.iter().map(|n| gen::by_name(n).unwrap().generate(1)).collect();
        let csrs: Vec<_> = mats.iter().map(coo_to_csr).collect();
        for (id, coo) in mats.iter().enumerate() {
            pool.register(id as u64, coo.clone(), 10_000).unwrap();
        }
        // 3 registered matrices share a 2-entry cache: round-robin
        // products keep knocking the third one out.
        for round in 0..3 {
            for (id, csr) in csrs.iter().enumerate() {
                let x = input(csr.n_cols, round);
                let resp = pool.product(id as u64, x.clone()).unwrap();
                assert_eq!(resp.y, csr.spmv_alloc(&x), "round {round} matrix {id}");
            }
        }
        let stats = pool.stats().unwrap();
        assert_eq!(stats.requests, 9);
        assert!(stats.evictions > 0, "3 matrices in 2 slots must evict: {stats:?}");
        assert!(stats.reconversions > 0, "post-eviction products must re-convert: {stats:?}");
        assert_eq!(stats.cached_matrices, 2, "cache must stay at capacity");
        assert_eq!(stats.registered_matrices, 3);
    }

    #[test]
    fn manual_hot_swap_migrates_and_counts() {
        // install a router trained for a different objective: the pool
        // must keep serving bit-identically (formats may migrate).
        let pool = pool_with(test_router(), 1, 0);
        let names = ["rim", "eu-2005", "shar_te2-b3"];
        let mats: Vec<Coo> = names.iter().map(|n| gen::by_name(n).unwrap().generate(1)).collect();
        let csrs: Vec<_> = mats.iter().map(coo_to_csr).collect();
        for (id, coo) in mats.iter().enumerate() {
            pool.register(id as u64, coo.clone(), 10_000).unwrap();
        }
        let v = pool
            .router()
            .install(Arc::new(toy_router(&["rim", "eu-2005", "shar_te2-b3"], Objective::Latency)));
        assert_eq!(v, 2);
        for (id, csr) in csrs.iter().enumerate() {
            let x = input(csr.n_cols, id);
            let resp = pool.product(id as u64, x.clone()).unwrap();
            // bit-identical to a single product in whatever format the
            // (possibly migrated) matrix now serves in
            let m = crate::sparse::convert::convert(
                csr,
                resp.format_used,
                PoolConfig::default().convert,
            );
            assert_eq!(
                resp.y,
                m.as_spmv().spmv_alloc(&x),
                "post-swap product must stay correct"
            );
        }
        let stats = pool.stats().unwrap();
        assert_eq!(stats.router_version, 2);
        assert_eq!(stats.requests, 3);
        // migrations is workload-dependent (0 if both routers agree),
        // but per-matrix formats must match what responses reported.
        for m in &stats.per_matrix {
            assert!(m.format.is_some());
        }
    }

    /// Reference chain: k repeated products x <- A x on the CSR source
    /// (all formats are bit-identical per product, so this is THE
    /// expected value for any serving path).
    fn chain(csr: &crate::sparse::Csr, x0: &[f32], k: usize, normalize: bool) -> Vec<f32> {
        let mut x = x0.to_vec();
        for _ in 0..k {
            let mut y = csr.spmv_alloc(&x);
            if normalize {
                let norm = y.iter().map(|v| v * v).sum::<f32>().sqrt();
                for v in &mut y {
                    *v /= norm;
                }
            }
            x = y;
        }
        x
    }

    #[test]
    fn session_chain_is_bit_identical_and_elides_round_trips() {
        let pool = pool_with(test_router(), 1, 0);
        let coo = gen::by_name("rim").unwrap().generate(1);
        let csr = coo_to_csr(&coo);
        let n = csr.n_cols;
        assert_eq!(csr.n_rows, n, "corpus matrix must be square for a session");
        pool.register(1, coo, 10_000).unwrap();

        let session = pool.open_session(1).unwrap();
        assert_eq!(session.n(), n);
        assert_eq!(session.matrix_id(), 1);
        // stepping before the first write is an explicit error
        let err = session.step().unwrap_err();
        assert!(format!("{err}").contains("write"), "{err}");

        let x0 = input(n, 3);
        session.write(x0.clone()).unwrap();
        session.step_n(5).unwrap();
        let y = session.read().unwrap();
        assert_eq!(y, chain(&csr, &x0, 5, false), "session chain must be bit-identical");

        let stats = pool.stats().unwrap();
        assert_eq!(stats.session_steps, 5);
        assert_eq!(stats.sessions_opened, 1);
        assert_eq!(stats.active_sessions, 1);
        assert_eq!(stats.requests, 5, "each step is a request");
        assert_eq!(stats.launches, 5, "sessions save bytes, not launches");
        assert_eq!(stats.round_trips_elided, 5, "every pure step elides one round-trip");
        assert_eq!(stats.elided_bytes, 5 * 8 * n as u64);
        // one write in + one read out are the only boundary crossings
        assert_eq!(stats.marshalled_bytes, 2 * 4 * n as u64);
        assert!(stats.elision_ratio() > 0.8, "{}", stats.elision_ratio());

        // per-request path for comparison: every product pays x in + y out
        let resp = pool.product(1, input(n, 9)).unwrap();
        assert_eq!(resp.y, csr.spmv_alloc(&input(n, 9)));
        let stats = pool.stats().unwrap();
        assert_eq!(stats.marshalled_bytes, 2 * 4 * n as u64 + 8 * n as u64);

        drop(session);
        let stats = pool.stats().unwrap();
        assert_eq!(stats.active_sessions, 0, "drop closes the session");
        assert_eq!(stats.sessions_opened, 1);
    }

    #[test]
    fn session_power_steps_match_host_normalized_chain() {
        let pool = pool_with(test_router(), 1, 0);
        let coo = gen::by_name("rim").unwrap().generate(1);
        let csr = coo_to_csr(&coo);
        let n = csr.n_cols;
        pool.register(4, coo, 10_000).unwrap();
        let session = pool.open_session(4).unwrap();
        let x0 = vec![1.0f32; n];
        session.write(x0.clone()).unwrap();
        session.power_step_n(4).unwrap();
        session.power_step().unwrap();
        let y = session.read().unwrap();
        assert_eq!(y, chain(&csr, &x0, 5, true), "normalized steps must be bit-identical");
        let norm: f32 = y.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-3, "power steps keep the vector normalized: {norm}");
    }

    /// Diagonally dominant square system — every solve kind succeeds.
    fn dd_system(n: usize, seed: u64) -> Coo {
        let mut rng = gen::Rng::new(seed);
        let mut coo = Coo::new(n, n);
        let mut diag = vec![1.0f32; n];
        for i in 0..n {
            for d in 1..=2usize {
                let j = (i + d) % n;
                let v = (rng.f64() as f32) * 0.4 - 0.2;
                coo.push(i, j, v);
                diag[i] += v.abs();
            }
        }
        for (i, d) in diag.into_iter().enumerate() {
            coo.push(i, i, d);
        }
        coo
    }

    #[test]
    fn solve_kinds_serve_end_to_end_and_attribute_separately() {
        let pool = pool_with(test_router(), 1, 0);
        let coo = dd_system(48, 11);
        let csr = coo_to_csr(&coo);
        let n = csr.n_rows;
        pool.register(1, coo, 1000).unwrap();

        // per-request solves match the native trait oracles bit-for-bit
        // regardless of which format the router converted to (the solve
        // bit-identity contract in sparse_props)
        let b = input(n, 2);
        let lo = pool.sptrsv(1, b.clone(), true).unwrap();
        assert_eq!(lo.y, csr.sptrsv(&b, true).unwrap());
        let up = pool.sptrsv(1, b.clone(), false).unwrap();
        assert_eq!(up.y, csr.sptrsv(&b, false).unwrap());
        let gs = pool.symgs(1, b.clone()).unwrap();
        let mut want_gs = vec![0.0f32; n];
        csr.symgs_sweep(&b, &mut want_gs).unwrap();
        assert_eq!(gs.y, want_gs);
        // plus one product: four requests across three kernel-kind arms
        pool.product(1, b.clone()).unwrap();

        let stats = pool.stats().unwrap();
        assert_eq!(stats.requests, 4);
        assert_eq!(stats.launches, 4, "native solves run one launch per vector");
        let kinds: Vec<&str> = stats.arm_profiles.iter().map(|p| p.kind.as_str()).collect();
        for k in ["spmv", "sptrsv", "symgs"] {
            assert!(kinds.contains(&k), "missing {k} arm in {kinds:?}");
        }
        let sptrsv_reqs: u64 = stats
            .arm_profiles
            .iter()
            .filter(|p| p.kind == "sptrsv")
            .map(|p| p.requests)
            .sum();
        assert_eq!(sptrsv_reqs, 2, "both triangle sides attribute to the sptrsv cells");

        // stage accounting: solve dispatches land in solve_exec, not exec
        let text = pool.metrics_text().unwrap();
        assert!(
            text.contains("spmv_stage_seconds_bucket{stage=\"solve_exec\",le=\"+Inf\"} 3"),
            "{text}"
        );

        // session solve steps run on the pinned conversion, same bits
        let session = pool.open_session(1).unwrap();
        session.write(b.clone()).unwrap();
        session.sptrsv_step(true).unwrap();
        assert_eq!(session.read().unwrap(), lo.y);
        session.write(b.clone()).unwrap();
        session.symgs_step().unwrap();
        assert_eq!(session.read().unwrap(), want_gs);
        let stats = pool.stats().unwrap();
        assert_eq!(stats.session_steps, 2);
    }

    #[test]
    fn session_survives_cache_eviction_pressure() {
        // capacity-1 cache, three matrices: products on the others keep
        // evicting the session matrix's LRU entry, but the session's
        // pinned Rc clone must keep serving bit-identically throughout.
        let pool = Pool::start(
            test_router(),
            BackendSpec::Native,
            PoolConfig { workers: 1, cache_capacity: 1, ..Default::default() },
        );
        let names = ["rim", "eu-2005", "shar_te2-b3"];
        let mats: Vec<Coo> = names.iter().map(|n| gen::by_name(n).unwrap().generate(1)).collect();
        let csrs: Vec<_> = mats.iter().map(coo_to_csr).collect();
        for (id, coo) in mats.iter().enumerate() {
            pool.register(id as u64, coo.clone(), 10_000).unwrap();
        }
        let session = pool.open_session(0).unwrap();
        let x0 = input(csrs[0].n_cols, 1);
        session.write(x0.clone()).unwrap();
        for round in 0..3 {
            session.step().unwrap();
            // hammer the other matrices through the 1-slot cache
            for id in [1usize, 2] {
                let x = input(csrs[id].n_cols, round);
                let resp = pool.product(id as u64, x.clone()).unwrap();
                assert_eq!(resp.y, csrs[id].spmv_alloc(&x));
            }
        }
        let y = session.read().unwrap();
        assert_eq!(
            y,
            chain(&csrs[0], &x0, 3, false),
            "eviction pressure must never touch an open session's pinned conversion"
        );
        let stats = pool.stats().unwrap();
        assert!(stats.evictions > 0, "3 matrices in 1 slot must evict: {stats:?}");
    }

    #[test]
    fn session_on_unknown_or_nonsquare_matrix_is_an_error() {
        let pool = pool_with(test_router(), 1, 0);
        let err = pool.open_session(99).unwrap_err();
        assert!(format!("{err}").contains("unknown matrix"), "{err}");
        let mut rect = Coo::new(3, 4);
        rect.push(0, 1, 2.0);
        rect.push(2, 3, -1.0);
        pool.register(5, rect, 10).unwrap();
        let err = pool.open_session(5).unwrap_err();
        assert!(format!("{err}").contains("square"), "{err}");
        // a bad write length errors without killing the session
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(6, coo, 10).unwrap();
        let session = pool.open_session(6).unwrap();
        assert!(session.write(vec![1.0, 2.0]).is_err());
        session.write(vec![0.5; n]).unwrap();
        session.step().unwrap();
        assert_eq!(session.read().unwrap().len(), n);
    }

    #[test]
    fn stage_histograms_decompose_end_to_end_latency_exactly() {
        let pool = pool_with(test_router(), 1, 0);
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(1, coo, 1000).unwrap();
        for r in 0..6 {
            let resp = pool.product(1, input(n, r)).unwrap();
            // every response decomposes its own service time exactly
            let t = resp.trace.expect("tracing is on by default");
            assert_eq!(t.total(), resp.service_time);
        }
        // session steps land in their own stage and decompose too
        let session = pool.open_session(1).unwrap();
        session.write(input(n, 7)).unwrap();
        session.step_n(3).unwrap();
        let stats = pool.stats().unwrap();
        assert_eq!(stats.stage_stats.len(), crate::obs::N_STAGES);
        let count_of = |name: &str| {
            stats.stage_stats.iter().find(|s| s.stage.name() == name).unwrap().hist.count
        };
        // sequential native products ride the SpMM path (1-launch walk)
        assert_eq!(count_of("queue_wait"), 6);
        assert_eq!(count_of("batch_wait"), 6);
        assert_eq!(count_of("convert"), 6);
        assert_eq!(count_of("spmm_exec"), 6);
        assert_eq!(count_of("exec"), 0);
        assert_eq!(count_of("reply"), 6);
        assert_eq!(count_of("session_step"), 3);
        // THE invariant: the stage histograms partition the end-to-end
        // ones exactly — equal nanosecond sums, not approximately
        assert_eq!(stats.stage_total(), stats.total_service());
        assert!(
            (stats.stage_coverage() - 1.0).abs() < 1e-12,
            "coverage {}",
            stats.stage_coverage()
        );
    }

    #[test]
    fn tracing_off_disables_traces_and_stage_histograms() {
        let pool = Pool::start(
            test_router(),
            BackendSpec::Native,
            PoolConfig { workers: 1, tracing: false, ..Default::default() },
        );
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(1, coo, 100).unwrap();
        let resp = pool.product(1, input(n, 0)).unwrap();
        assert!(resp.trace.is_none());
        let stats = pool.stats().unwrap();
        assert_eq!(stats.requests, 1, "e2e accounting is unaffected");
        assert!(stats.stage_stats.iter().all(|s| s.hist.is_empty()));
        assert_eq!(stats.stage_coverage(), 0.0);
    }

    #[test]
    fn deadline_tags_count_and_misses_accumulate() {
        let pool = pool_with(test_router(), 1, 0);
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(1, coo, 100).unwrap();
        // untagged requests never touch the deadline ledger
        pool.product(1, input(n, 0)).unwrap();
        // a zero deadline always misses; a one-hour one never does
        pool.product_with_deadline(1, input(n, 1), Duration::ZERO).unwrap();
        pool.product_with_deadline(1, input(n, 2), Duration::from_secs(3600)).unwrap();
        let stats = pool.stats().unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.deadline_tagged, 2);
        assert_eq!(stats.deadline_misses, 1);
    }

    #[test]
    fn pool_journals_session_lifecycle_events_in_order() {
        let pool = pool_with(test_router(), 1, 0);
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(1, coo, 10_000).unwrap();
        assert!(pool.events().is_empty(), "registration alone emits nothing");
        let session = pool.open_session(1).unwrap();
        session.write(input(n, 0)).unwrap();
        session.step_n(2).unwrap();
        drop(session);
        // close is fire-and-forget: push another request through the
        // same shard so the close message is definitely processed
        pool.product(1, input(n, 1)).unwrap();
        let events = pool.events();
        let kinds: Vec<&str> = events.iter().map(|e| e.kind.name()).collect();
        assert_eq!(kinds, vec!["session_open", "session_close"]);
        match &events[1].kind {
            crate::obs::EventKind::SessionClose { matrix, steps, .. } => {
                assert_eq!(*matrix, 1);
                assert_eq!(*steps, 2);
            }
            other => panic!("expected session_close, got {other:?}"),
        }
        assert!(events.windows(2).all(|w| w[0].seq < w[1].seq));
        let stats = pool.stats().unwrap();
        assert_eq!(stats.events_total, 2);
        assert_eq!(stats.events_dropped, 0);
        assert!(pool.events_json().contains("\"kind\":\"session_open\""));
    }

    #[test]
    fn metrics_text_exposes_counters_stage_histograms_and_per_matrix_gauges() {
        let pool = pool_with(test_router(), 1, 0);
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(1, coo, 1000).unwrap();
        for r in 0..4 {
            pool.product(1, input(n, r)).unwrap();
        }
        let text = pool.metrics_text().unwrap();
        assert!(text.contains("# TYPE spmv_requests_total counter"), "{text}");
        assert!(text.contains("spmv_requests_total 4"), "{text}");
        assert!(text.contains("# TYPE spmv_stage_seconds histogram"), "{text}");
        assert!(
            text.contains("spmv_stage_seconds_bucket{stage=\"queue_wait\",le=\"+Inf\"} 4"),
            "{text}"
        );
        assert!(text.contains("spmv_matrix_requests{matrix=\"1\"} 4"), "{text}");
        assert!(text.contains("spmv_stage_coverage_ratio 1"), "{text}");
        let table = pool.metrics_table().unwrap();
        assert_eq!(table.header, vec!["metric", "labels", "value"]);
        assert!(table.rows.iter().any(|r| r[0] == "spmv_requests_total" && r[2] == "4"));
    }

    #[test]
    fn arm_profiles_attribute_requests_per_joint_arm() {
        let pool = pool_with(test_router(), 1, 0);
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(1, coo, 1000).unwrap();
        for r in 0..6 {
            pool.product(1, input(n, r)).unwrap();
        }
        let stats = pool.stats().unwrap();
        assert_eq!(stats.arm_generation, 1, "no hot-swap yet");
        assert_eq!(stats.arm_profiles.len(), 1, "a frozen pool serves one arm per matrix");
        let p = &stats.arm_profiles[0];
        assert_eq!(p.kind, "spmv", "product traffic attributes to the spmv cells");
        assert_eq!(p.requests, 6);
        assert!(p.exec_s > 0.0);
        assert!(p.energy_j > 0.0);
        assert!(p.mean_power_w > 0.0);
        assert!(p.mflops_per_watt > 0.0);
        let text = pool.metrics_text().unwrap();
        assert!(text.contains("spmv_arm_generation 1"), "{text}");
        let line = format!(
            "spmv_arm_requests_total{{kind=\"spmv\",format=\"{}\",knobs=\"{}\"}} 6",
            p.format, p.knobs
        );
        assert!(text.contains(&line), "{text}");
        assert!(text.contains("# TYPE spmv_arm_energy_joules_total counter"), "{text}");
        assert!(!text.contains("spmv_slo_status"), "no SLO families without an engine");
        assert!(pool.flight_records().is_empty(), "no recorder without an SLO");
        assert_eq!(pool.flight_json(), "[]\n");
    }

    #[test]
    fn slo_breach_alerts_captures_flight_context_and_recovers() {
        use crate::obs::{SloSpec, SloStatus};
        let slo = SloConfig {
            spec: SloSpec {
                p99_target: Duration::from_secs(3600), // never the breach signal here
                deadline_miss_budget: 0.25,
            },
            overrides: vec![],
            fast_window: 8,
            recovery_evals: 2,
            flight_cap: 16,
        };
        let pool = Pool::start(
            test_router(),
            BackendSpec::Native,
            PoolConfig { workers: 1, slo: Some(slo), ..Default::default() },
        );
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(1, coo, 100).unwrap();
        // one clean window, then a window of guaranteed misses: both
        // burn windows violate at request 16 -> breach + alert
        for r in 0..8 {
            pool.product_with_deadline(1, input(n, r), Duration::from_secs(3600)).unwrap();
        }
        for r in 8..16 {
            pool.product_with_deadline(1, input(n, r), Duration::ZERO).unwrap();
        }
        let stats = pool.stats().unwrap();
        let s = stats.slo.as_ref().expect("slo snapshot when configured");
        assert_eq!(s.status, SloStatus::Breach);
        assert_eq!(s.alerts, 1);
        let records = pool.flight_records();
        assert_eq!(records.len(), 16, "breach capture froze the full ring");
        assert!(records.iter().any(|r| r.deadline_missed), "{records:?}");
        assert!(pool.flight_json().contains("\"deadline_missed\":true"));
        // drain with clean traffic: two clean evaluations recover
        for r in 16..32 {
            pool.product_with_deadline(1, input(n, r), Duration::from_secs(3600)).unwrap();
        }
        let stats = pool.stats().unwrap();
        let s = stats.slo.as_ref().unwrap();
        assert_eq!(s.status, SloStatus::Ok);
        assert_eq!(s.recoveries, 1);
        assert_eq!(s.evals, 4, "one eval per fast window");
        let keys: Vec<String> = pool.events().iter().map(|e| e.kind.key()).collect();
        assert_eq!(
            keys,
            vec![
                "slo_alert scope=pool at=16 signal=miss_budget missed=8/8".to_string(),
                "slo_recovered scope=pool at=32".to_string(),
            ],
        );
        let text = pool.metrics_text().unwrap();
        assert!(text.contains("spmv_slo_status 0"), "{text}");
        assert!(text.contains("spmv_slo_alerts_total 1"), "{text}");
        assert!(text.contains("spmv_slo_recoveries_total 1"), "{text}");
        assert!(text.contains("spmv_flight_records 16"), "{text}");
    }

    #[test]
    fn unloaded_scaleout_pool_is_bit_identical_to_hash_routing() {
        let router = test_router();
        let names = ["rim", "eu-2005", "shar_te2-b3"];
        let mats: Vec<Coo> = names.iter().map(|n| gen::by_name(n).unwrap().generate(1)).collect();
        let plain = pool_with(router.clone(), 2, 0);
        // window 6 so control evaluations DO run (every 6 admissions)
        // and decide nothing: uniform 3-matrix traffic holds every
        // share at 1/3, under the 50% replication threshold.
        let scaled = Pool::start(
            router,
            BackendSpec::Native,
            PoolConfig {
                workers: 2,
                scaleout: Some(ScaleOutConfig { window: 6, ..Default::default() }),
                ..Default::default()
            },
        );
        for (id, coo) in mats.iter().enumerate() {
            plain.register(id as u64, coo.clone(), 10_000).unwrap();
            scaled.register(id as u64, coo.clone(), 10_000).unwrap();
        }
        for r in 0..8 {
            for (id, coo) in mats.iter().enumerate() {
                let x = input(coo.n_cols, r);
                let a = plain.product(id as u64, x.clone()).unwrap();
                let b = scaled.product(id as u64, x).unwrap();
                assert_eq!(a.y, b.y, "unloaded scale-out pool must serve bit-identically");
            }
        }
        let stats = scaled.stats().unwrap();
        assert_eq!(stats.requests, 24);
        assert_eq!(stats.sheds, 0, "no SLO, no pressure, no shedding");
        assert_eq!(stats.reroutes, 0, "unreplicated matrices route to their hash home");
        assert_eq!(stats.replications, 0);
        assert_eq!(stats.replicas, 0);
        assert!(scaled.events().is_empty(), "no control events: {:?}", scaled.events());
        assert_eq!(stats.queue_depths, vec![0, 0], "sequential traffic drains fully");
    }

    #[test]
    fn hot_matrix_replicates_and_replicas_serve_bit_identically() {
        // 3 workers, one matrix taking 100% of traffic: the first
        // window boundary replicates it onto both other shards.
        let pool = Pool::start(
            test_router(),
            BackendSpec::Native,
            PoolConfig {
                workers: 3,
                scaleout: Some(ScaleOutConfig { window: 8, ..Default::default() }),
                ..Default::default()
            },
        );
        let coo = gen::by_name("rim").unwrap().generate(1);
        let csr = coo_to_csr(&coo);
        let n = csr.n_cols;
        pool.register(1, coo, 10_000).unwrap();
        let burst = |salt0: usize| {
            let receivers: Vec<_> =
                (0..12).map(|r| pool.product_async(1, input(n, salt0 + r)).unwrap()).collect();
            for (r, rx) in receivers.into_iter().enumerate() {
                let resp = rx.recv().unwrap().unwrap();
                assert_eq!(
                    resp.y,
                    csr.spmv_alloc(&input(n, salt0 + r)),
                    "request {} must be bit-identical on every replica",
                    salt0 + r
                );
            }
        };
        burst(0); // replication fires at admission 8, mid-burst
        let stats = pool.stats().unwrap();
        assert_eq!(stats.replications, 2, "hot matrix must spread to all 3 shards");
        assert_eq!(stats.replicas, 2);
        // splitmix64 homes matrix 1 on shard 0 of 3; replicas fill
        // ascending. The control event sequence is deterministic: the
        // single-threaded client admits in a fixed order.
        let keys: Vec<String> = pool.events().iter().map(|e| e.kind.key()).collect();
        assert_eq!(
            keys,
            vec![
                "replicate matrix=1 shard=1 replicas=2 at=8".to_string(),
                "replicate matrix=1 shard=2 replicas=3 at=8".to_string(),
                "reroute matrix=1 owners=3 at=8".to_string(),
            ],
        );
        // Hot-swap while replicated: each replica migrates on its own
        // next message, so these bursts interleave old- and new-policy
        // replicas — responses must stay bit-identical throughout.
        let v = pool
            .router()
            .install(Arc::new(toy_router(&["rim", "eu-2005", "shar_te2-b3"], Objective::Latency)));
        assert_eq!(v, 2);
        burst(100);
        burst(200);
        let stats = pool.stats().unwrap();
        assert_eq!(stats.requests, 36);
        assert_eq!(stats.router_version, 2);
        assert_eq!(stats.replicas, 2, "a hot-swap must not tear down replicas");
    }

    #[test]
    fn cooled_matrix_unreplicates_and_reverts_to_its_home_shard() {
        let pool = Pool::start(
            test_router(),
            BackendSpec::Native,
            PoolConfig {
                workers: 2,
                scaleout: Some(ScaleOutConfig { window: 8, ..Default::default() }),
                ..Default::default()
            },
        );
        let names = ["rim", "eu-2005"];
        let mats: Vec<Coo> = names.iter().map(|n| gen::by_name(n).unwrap().generate(1)).collect();
        let csrs: Vec<_> = mats.iter().map(coo_to_csr).collect();
        pool.register(1, mats[0].clone(), 10_000).unwrap();
        pool.register(2, mats[1].clone(), 10_000).unwrap();
        // Phase 1: matrix 1 monopolizes a window -> replicated at 8.
        for r in 0..8 {
            let x = input(csrs[0].n_cols, r);
            assert_eq!(pool.product(1, x.clone()).unwrap().y, csrs[0].spmv_alloc(&x));
        }
        // Phase 2: traffic moves to matrix 2; matrix 1's decayed count
        // halves each window (4 -> 2 -> 1) until its share drops under
        // 12.5% and the replica is deregistered at admission 32.
        for r in 0..24 {
            let x = input(csrs[1].n_cols, r);
            assert_eq!(pool.product(2, x.clone()).unwrap().y, csrs[1].spmv_alloc(&x));
        }
        let stats = pool.stats().unwrap();
        assert_eq!(stats.unreplications, 1, "cooled matrix must shrink back");
        assert_eq!(stats.replications, 2, "matrix 1 at admission 8, matrix 2 at 16");
        assert_eq!(stats.replicas, 1, "only the (still hot) matrix 2 replica remains");
        let keys: Vec<String> = pool.events().iter().map(|e| e.kind.key()).collect();
        assert_eq!(
            keys,
            vec![
                "replicate matrix=1 shard=0 replicas=2 at=8".to_string(),
                "reroute matrix=1 owners=2 at=8".to_string(),
                "replicate matrix=2 shard=1 replicas=2 at=16".to_string(),
                "reroute matrix=2 owners=2 at=16".to_string(),
                "unreplicate matrix=1 dropped=1 at=32".to_string(),
            ],
        );
        // the shrunk matrix still serves correctly from its home
        let x = input(csrs[0].n_cols, 99);
        assert_eq!(pool.product(1, x.clone()).unwrap().y, csrs[0].spmv_alloc(&x));
    }

    #[test]
    fn admission_control_sheds_typed_rejections_under_slo_pressure() {
        use crate::obs::{SloSpec, SloStatus};
        let slo = SloConfig {
            spec: SloSpec {
                p99_target: Duration::from_secs(3600),
                deadline_miss_budget: 0.25,
            },
            overrides: vec![],
            fast_window: 8,
            recovery_evals: 2,
            flight_cap: 16,
        };
        let pool = Pool::start(
            test_router(),
            BackendSpec::Native,
            PoolConfig {
                workers: 1,
                slo: Some(slo.clone()),
                scaleout: Some(ScaleOutConfig::default()),
                ..Default::default()
            },
        );
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(1, coo.clone(), 100).unwrap();
        // While healthy, zero-deadline tags are admitted (and merely
        // counted as misses) — shedding stays disarmed.
        for r in 0..8 {
            pool.product_with_deadline(1, input(n, r), Duration::from_secs(3600)).unwrap();
        }
        for r in 8..16 {
            pool.product_with_deadline(1, input(n, r), Duration::ZERO).unwrap();
        }
        let stats = pool.stats().unwrap();
        assert_eq!(stats.slo.as_ref().unwrap().status, SloStatus::Breach);
        assert_eq!(stats.sheds, 0, "nothing is shed while the SLO is healthy");
        // Breached: a blown budget is now rejected fast and typed.
        let err = pool.product_with_deadline(1, input(n, 16), Duration::ZERO).unwrap_err();
        assert_eq!(err.downcast_ref::<Rejected>(), Some(&Rejected::DeadlineExceeded));
        assert_eq!(format!("{err}"), "rejected: deadline budget already spent");
        let stats = pool.stats().unwrap();
        assert_eq!(stats.sheds, 1);
        assert_eq!(stats.sheds_deadline, 1);
        assert_eq!(stats.sheds_overloaded, 0);
        assert_eq!(stats.requests, 16, "a shed request is never admitted");
        // untagged requests still serve under pressure (cap not hit)
        pool.product(1, input(n, 17)).unwrap();
        let keys: Vec<String> = pool.events().iter().map(|e| e.kind.key()).collect();
        assert!(keys.contains(&"shed matrix=1 reason=deadline at=16".to_string()), "{keys:?}");
        let text = pool.metrics_text().unwrap();
        assert!(text.contains("spmv_sheds_total{reason=\"deadline\"} 1"), "{text}");
        assert!(text.contains("spmv_sheds_total{reason=\"overloaded\"} 0"), "{text}");
        assert!(text.contains("spmv_queue_depth{shard=\"0\"} 0"), "{text}");

        // admission_cap 0 sheds EVERYTHING — even untagged — while
        // degraded.
        let pool2 = Pool::start(
            test_router(),
            BackendSpec::Native,
            PoolConfig {
                workers: 1,
                slo: Some(slo),
                scaleout: Some(ScaleOutConfig { admission_cap: 0, ..Default::default() }),
                ..Default::default()
            },
        );
        pool2.register(1, coo, 100).unwrap();
        for r in 0..8 {
            pool2.product_with_deadline(1, input(n, r), Duration::from_secs(3600)).unwrap();
        }
        for r in 8..16 {
            pool2.product_with_deadline(1, input(n, r), Duration::ZERO).unwrap();
        }
        let err = pool2.product(1, input(n, 20)).unwrap_err();
        assert_eq!(err.downcast_ref::<Rejected>(), Some(&Rejected::Overloaded));
        assert_eq!(format!("{err}"), "rejected: admission queue over capacity");
        assert_eq!(pool2.stats().unwrap().sheds_overloaded, 1);
    }

    #[test]
    fn unknown_matrix_and_bad_length_are_errors_not_poison() {
        let router = test_router();
        let pool = pool_with(router, 2, 0);
        let err = pool.product(99, vec![1.0]).unwrap_err();
        assert!(format!("{err}").contains("unknown matrix"));
        let coo = gen::by_name("rim").unwrap().generate(1);
        let n = coo.n_cols;
        pool.register(7, coo, 1).unwrap();
        assert!(pool.product(7, vec![1.0, 2.0]).is_err());
        // pool still serves after the errors
        assert!(pool.product(7, vec![0.5; n]).is_ok());
    }
}
