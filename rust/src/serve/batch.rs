//! Admission queue: request coalescing for the serving shards.
//!
//! When a shard picks up a product request it first drains everything
//! already sitting in its queue (free coalescing — pipelined clients
//! pay zero added latency), then optionally holds the batch open for a
//! short admission window so concurrent clients hitting an idle shard
//! can still coalesce. The collected batch is grouped by matrix id and
//! each group executes as ONE SpMM dispatch.
//!
//! Non-product messages observed while draining are pushed onto the
//! shard's backlog and handled right after the batch, so a registration
//! is delayed by at most one window.

use super::shard::ShardMsg;
use super::Response;
use crate::sparse::KernelKind;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::Arc;
use std::sync::mpsc::{Receiver, Sender};
use std::time::{Duration, Instant};

/// Kernel class of a queued job, with the per-class options that change
/// what one dispatch computes. Part of the coalescing group key: a
/// group executes as ONE homogeneous dispatch, so jobs of different
/// kinds (or opposite triangle sides) never share a launch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// y = A x (the batchable product path).
    Spmv,
    /// Triangular solve x = T⁻¹ b against the matrix's lower (forward)
    /// or upper (backward) triangle + diagonal.
    Sptrsv { lower: bool },
    /// One symmetric Gauss–Seidel sweep from a zero initial guess.
    Symgs,
}

impl JobKind {
    /// The request class the bandit/attribution buckets by.
    pub fn kind(self) -> KernelKind {
        match self {
            JobKind::Spmv => KernelKind::Spmv,
            JobKind::Sptrsv { .. } => KernelKind::Sptrsv,
            JobKind::Symgs => KernelKind::Symgs,
        }
    }
}

/// One queued request (product or solve; see [`JobKind`]).
pub struct Job {
    pub matrix_id: u64,
    pub kind: JobKind,
    /// Shared payload: enqueue is a refcount bump, never a vector copy
    /// — the client's buffer IS the buffer the dispatch reads.
    pub x: Arc<[f32]>,
    /// Submission time — service latency is measured end-to-end from
    /// here, so queue wait and admission-window wait are included.
    pub enqueued: Instant,
    /// Client-declared latency budget. The shard never sheds or
    /// reorders on it — it only counts misses
    /// (`Counters::deadline_misses`) against end-to-end service time.
    /// Admission-time shedding on an already-blown budget happens
    /// before the job is built, in the pool's control plane, and only
    /// under SLO pressure (DESIGN.md §12).
    pub deadline: Option<Duration>,
    pub reply: Sender<Result<Response>>,
}

/// Collect a batch starting from `first`: drain the queue, then wait up
/// to `window` for more, capping at `max_batch` jobs. Non-product
/// messages are deferred to `backlog`.
pub(crate) fn collect_batch(
    first: Job,
    rx: &Receiver<ShardMsg>,
    backlog: &mut VecDeque<ShardMsg>,
    window: Duration,
    max_batch: usize,
) -> Vec<Job> {
    let max_batch = max_batch.max(1);
    let mut batch = vec![first];
    // Opportunistic pass: whatever is already queued coalesces for free.
    while batch.len() < max_batch {
        match rx.try_recv() {
            Ok(ShardMsg::Product(job)) => batch.push(job),
            Ok(other) => backlog.push_back(other),
            Err(_) => break,
        }
    }
    // Admission window: hold the batch open briefly for concurrent
    // clients. `window == 0` (the default) skips this entirely, so
    // strictly sequential callers never pay added latency.
    if !window.is_zero() {
        let deadline = Instant::now() + window;
        while batch.len() < max_batch {
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                break;
            };
            match rx.recv_timeout(remaining) {
                Ok(ShardMsg::Product(job)) => batch.push(job),
                Ok(other) => backlog.push_back(other),
                Err(_) => break,
            }
        }
    }
    batch
}

/// Group a batch by (matrix id, job kind), preserving first-seen order
/// (and arrival order within each group). The kind is part of the key:
/// an SpMV group can ride an SpMM launch while a solve group for the
/// same matrix executes sequentially next to it.
pub(crate) fn group_by_matrix(jobs: Vec<Job>) -> Vec<((u64, JobKind), Vec<Job>)> {
    let mut groups: Vec<((u64, JobKind), Vec<Job>)> = Vec::new();
    for job in jobs {
        let key = (job.matrix_id, job.kind);
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, members)) => members.push(job),
            None => groups.push((key, vec![job])),
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn job(matrix_id: u64) -> Job {
        let (reply, _rx) = channel();
        Job {
            matrix_id,
            kind: JobKind::Spmv,
            x: vec![1.0].into(),
            enqueued: Instant::now(),
            deadline: None,
            reply,
        }
    }

    #[test]
    fn drains_queued_products_without_waiting() {
        let (tx, rx) = channel::<ShardMsg>();
        tx.send(ShardMsg::Product(job(1))).unwrap();
        tx.send(ShardMsg::Product(job(2))).unwrap();
        let mut backlog = VecDeque::new();
        let t0 = Instant::now();
        let batch = collect_batch(job(1), &rx, &mut backlog, Duration::ZERO, 32);
        assert_eq!(batch.len(), 3);
        assert!(backlog.is_empty());
        assert!(t0.elapsed() < Duration::from_millis(100), "window 0 must not wait");
    }

    #[test]
    fn defers_non_product_messages_to_backlog() {
        let (tx, rx) = channel::<ShardMsg>();
        let (status_tx, _status_rx) = channel();
        tx.send(ShardMsg::Status(status_tx)).unwrap();
        tx.send(ShardMsg::Product(job(4))).unwrap();
        let mut backlog = VecDeque::new();
        let batch = collect_batch(job(3), &rx, &mut backlog, Duration::ZERO, 32);
        assert_eq!(batch.len(), 2);
        assert_eq!(backlog.len(), 1);
        assert!(matches!(backlog[0], ShardMsg::Status(_)));
    }

    #[test]
    fn max_batch_caps_collection() {
        let (tx, rx) = channel::<ShardMsg>();
        for i in 0..10 {
            tx.send(ShardMsg::Product(job(i))).unwrap();
        }
        let mut backlog = VecDeque::new();
        let batch = collect_batch(job(99), &rx, &mut backlog, Duration::from_millis(50), 4);
        assert_eq!(batch.len(), 4);
    }

    #[test]
    fn window_collects_late_arrivals() {
        let (tx, rx) = channel::<ShardMsg>();
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            let _ = tx.send(ShardMsg::Product(job(2)));
        });
        let mut backlog = VecDeque::new();
        let batch = collect_batch(job(1), &rx, &mut backlog, Duration::from_millis(500), 32);
        sender.join().unwrap();
        assert_eq!(batch.len(), 2, "request arriving inside the window must coalesce");
    }

    #[test]
    fn groups_preserve_first_seen_and_arrival_order() {
        let jobs = vec![job(5), job(9), job(5), job(2), job(9), job(5)];
        let groups = group_by_matrix(jobs);
        let ids: Vec<u64> = groups.iter().map(|((id, _), _)| *id).collect();
        assert_eq!(ids, vec![5, 9, 2]);
        let sizes: Vec<usize> = groups.iter().map(|(_, m)| m.len()).collect();
        assert_eq!(sizes, vec![3, 2, 1]);
    }

    #[test]
    fn kinds_and_triangle_sides_split_groups() {
        let solve = |id, lower| {
            let mut j = job(id);
            j.kind = JobKind::Sptrsv { lower };
            j
        };
        let gs = |id| {
            let mut j = job(id);
            j.kind = JobKind::Symgs;
            j
        };
        let jobs = vec![job(1), solve(1, true), job(1), solve(1, false), gs(1), solve(1, true)];
        let groups = group_by_matrix(jobs);
        let keys: Vec<(u64, JobKind)> = groups.iter().map(|(k, _)| *k).collect();
        assert_eq!(
            keys,
            vec![
                (1, JobKind::Spmv),
                (1, JobKind::Sptrsv { lower: true }),
                (1, JobKind::Sptrsv { lower: false }),
                (1, JobKind::Symgs),
            ]
        );
        let sizes: Vec<usize> = groups.iter().map(|(_, m)| m.len()).collect();
        assert_eq!(sizes, vec![2, 2, 1, 1]);
    }
}
