//! A serving shard: one worker thread owning its own backend.
//!
//! The PJRT client is not `Send`, so every shard builds a private
//! [`Backend`] from the shared [`BackendSpec`]. A shard owns the
//! matrices hashed to it: the registry keeps the CSR source plus the
//! router's decision, while the (potentially much larger) converted
//! forms live in a capacity-bounded LRU keyed by `(matrix, format)` — a
//! post-eviction request re-converts from the retained source. Product
//! requests are coalesced by [`super::batch`] and dispatched through
//! the SpMM entry points: `SpMv::spmm` on the native backend, a
//! multi-vector SpMM artifact (one launch per batch) on PJRT, with the
//! per-vector prepared path as the fallback when no SpMM variant is
//! compiled for the shape.
//!
//! When the pool runs with the closed loop attached
//! ([`crate::online`]), three things happen here and nowhere else:
//! the shard polls the hot-swap router's version at the top of its
//! message loop and **re-decides** every registered matrix on an
//! upgrade (format migration); each dispatch consults the exploration
//! bandit, which may route it to a non-predicted format (converted on
//! demand into the same LRU); and every executed dispatch feeds an
//! [`Observation`] back to the trainer. All of it sits between
//! dispatches — never under a request's execution.

use super::backend::{Backend, BackendSpec};
use super::batch::{collect_batch, group_by_matrix, Job};
use super::cache::Lru;
use super::telemetry::{MatrixTelemetry, Telemetry};
use super::Response;
use crate::features::Features;
use crate::gpusim::{simulate, GpuArch, KernelProfile, Measurement};
use crate::online::{Observation, Online, RouteChoice, SwapRouter};
use crate::runtime::pjrt::{PreparedSpmm, PreparedSpmv};
use crate::sparse::convert::{self, AnyFormat, ConvertParams};
use crate::sparse::{Coo, Csr, Format, SpMv};
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Messages a shard understands.
pub(crate) enum ShardMsg {
    Register { id: u64, coo: Coo, iterations_hint: u64, ack: Sender<Result<Format>> },
    Product(Job),
    Status(Sender<ShardStatus>),
    Shutdown,
}

/// Occupancy summary a shard reports to [`super::Pool::stats`].
#[derive(Debug, Clone, Copy)]
pub struct ShardStatus {
    pub registered: usize,
    pub cached: usize,
    /// Backend actually built ("pjrt" or "native") — a shard degrades
    /// to native when PJRT init fails, and reports say so.
    pub backend: &'static str,
}

/// Per-shard immutable configuration (built by the pool).
#[derive(Clone)]
pub(crate) struct ShardCfg {
    pub convert: ConvertParams,
    pub batch_window: Duration,
    pub max_batch: usize,
    pub cache_capacity: usize,
    pub arch: GpuArch,
}

/// Handle to a running shard.
pub(crate) struct Shard {
    pub tx: Sender<ShardMsg>,
    join: Option<JoinHandle<()>>,
}

impl Shard {
    pub(crate) fn spawn(
        index: usize,
        router: Arc<SwapRouter>,
        online: Option<Arc<Online>>,
        backend: BackendSpec,
        cfg: ShardCfg,
        telemetry: Arc<Telemetry>,
    ) -> Shard {
        let (tx, rx) = channel::<ShardMsg>();
        let join = std::thread::Builder::new()
            .name(format!("serve-shard-{index}"))
            .spawn(move || {
                let backend = match backend.build() {
                    Ok(b) => b,
                    Err(e) => {
                        eprintln!(
                            "serve-shard-{index}: backend init failed, falling back to native: {e:#}"
                        );
                        Backend::Native
                    }
                };
                worker_loop(rx, router, online, backend, cfg, telemetry)
            })
            .expect("spawn serving shard");
        Shard { tx, join: Some(join) }
    }

    /// Ask the worker to exit and join it (used by the pool's Drop).
    pub(crate) fn shutdown(&mut self) {
        let _ = self.tx.send(ShardMsg::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// A registered matrix: retained CSR source + routing decision + the
/// telemetry handle resolved once so the hot path is lock-free. The
/// features and iteration hint stay around for re-decisions on router
/// hot-swaps (step 1 of §5.3 is measured once, at registration).
struct Registered {
    csr: Csr,
    features: Features,
    iterations_hint: u64,
    format: Format,
    converted: bool,
    tele: Arc<MatrixTelemetry>,
}

/// Conversion-cache key: matrix id + format class, so an explored
/// format's conversion caches alongside the chosen one.
type CacheKey = (u64, u8);

fn cache_key(id: u64, format: Format) -> CacheKey {
    (id, format.class_id() as u8)
}

/// A cache entry: the converted form, PJRT-marshalled literals when the
/// backend compiles artifacts (per-vector AND, when the inventory has
/// one, the multi-vector SpMM variant), the workload profile, and the
/// gpusim-modeled per-product measurement for THIS format (the
/// telemetry/observation energy source; batched dispatches re-model
/// from `profile` so the matrix stream is charged once per batch).
struct CachedMatrix {
    matrix: AnyFormat,
    prepared: Option<PreparedSpmv>,
    prepared_spmm: Option<PreparedSpmm>,
    profile: Option<KernelProfile>,
    model: Measurement,
}

fn worker_loop(
    rx: Receiver<ShardMsg>,
    router: Arc<SwapRouter>,
    online: Option<Arc<Online>>,
    mut backend: Backend,
    cfg: ShardCfg,
    telemetry: Arc<Telemetry>,
) {
    let mut registry: HashMap<u64, Registered> = HashMap::new();
    let mut cache: Lru<CacheKey, CachedMatrix> = Lru::new(cfg.cache_capacity);
    let mut backlog: VecDeque<ShardMsg> = VecDeque::new();
    let (mut cur_router, mut cur_version) = router.load();
    loop {
        let msg = match backlog.pop_front() {
            Some(m) => m,
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => break, // pool dropped
            },
        };
        // Hot-swap check: one atomic load per message. On an upgrade,
        // reload the router and re-decide every registered matrix so it
        // can migrate to the format the new model prefers.
        if router.version() != cur_version {
            (cur_router, cur_version) = router.load();
            re_decide_all(
                cur_router.as_ref(),
                &mut backend,
                &cfg,
                &telemetry,
                &mut registry,
                &mut cache,
            );
        }
        match msg {
            ShardMsg::Shutdown => break,
            ShardMsg::Status(reply) => {
                let _ = reply.send(ShardStatus {
                    registered: registry.len(),
                    cached: cache.len(),
                    backend: backend.name(),
                });
            }
            ShardMsg::Register { id, coo, iterations_hint, ack } => {
                let result = do_register(
                    cur_router.as_ref(),
                    &mut backend,
                    &cfg,
                    &telemetry,
                    &mut registry,
                    &mut cache,
                    id,
                    coo,
                    iterations_hint,
                );
                let _ = ack.send(result);
            }
            ShardMsg::Product(job) => {
                let batch = collect_batch(job, &rx, &mut backlog, cfg.batch_window, cfg.max_batch);
                for (id, jobs) in group_by_matrix(batch) {
                    execute_group(
                        &mut backend,
                        &online,
                        &cfg,
                        &telemetry,
                        &registry,
                        &mut cache,
                        id,
                        jobs,
                    );
                }
            }
        }
    }
}

/// Convert (and, on PJRT, marshal) a matrix for execution in `format`,
/// and model one product's cost in that format — the §6.3 power-sensor
/// stand-in the telemetry and the online observations both read.
fn build_cached(
    backend: &mut Backend,
    csr: &Csr,
    format: Format,
    cfg: &ShardCfg,
) -> Result<CachedMatrix> {
    let matrix = convert::convert(csr, format, cfg.convert);
    let (prepared, prepared_spmm) = match backend {
        Backend::Pjrt(engine) => {
            let prepared = Some(engine.prepare(&matrix, None)?);
            // a missing SpMM variant is a fallback, never an error; a
            // same-bucket variant shares the marshalled literals
            let prepared_spmm = engine.prepare_spmm_sharing(&matrix, None, prepared.as_ref())?;
            (prepared, prepared_spmm)
        }
        Backend::Native => (None, None),
    };
    let (profile, model) = if csr.vals.is_empty() {
        (
            None,
            Measurement { latency_s: 0.0, energy_j: 0.0, avg_power_w: 0.0, mflops_per_watt: 0.0 },
        )
    } else {
        let prof = crate::gpusim::profile(csr, format, cfg.convert);
        let knobs = crate::online::observer::model_config(format);
        let m = simulate(&cfg.arch, &prof, &knobs).0;
        (Some(prof), m)
    };
    Ok(CachedMatrix { matrix, prepared, prepared_spmm, profile, model })
}

/// Per-request share of one batched dispatch's modeled cost: simulate
/// the k-vector SpMM launch (matrix stream charged once) and split the
/// extensive objectives across the batch. Falls back to the cached
/// single-product model for k = 1 or an empty profile.
fn batch_model(cached: &CachedMatrix, format: Format, k: usize, arch: &GpuArch) -> Measurement {
    if k <= 1 {
        return cached.model;
    }
    let Some(prof) = &cached.profile else {
        return cached.model;
    };
    let knobs = crate::online::observer::model_config(format);
    let (m, _) = simulate(arch, &prof.batched(k as u64), &knobs);
    Measurement {
        latency_s: m.latency_s / k as f64,
        energy_j: m.energy_j / k as f64,
        // power and MFLOPS/W are already rates over the whole launch
        avg_power_w: m.avg_power_w,
        mflops_per_watt: m.mflops_per_watt,
    }
}

#[allow(clippy::too_many_arguments)] // worker-local state is deliberately split for borrow granularity
fn do_register(
    router: &crate::coordinator::RunTimeOptimizer,
    backend: &mut Backend,
    cfg: &ShardCfg,
    telemetry: &Telemetry,
    registry: &mut HashMap<u64, Registered>,
    cache: &mut Lru<CacheKey, CachedMatrix>,
    id: u64,
    coo: Coo,
    iterations_hint: u64,
) -> Result<Format> {
    let decision = router.decide(&coo, iterations_hint);
    let csr = convert::coo_to_csr(&coo);
    let (format, converted) = if decision.convert {
        (decision.predicted_format, true)
    } else {
        (Format::Csr, false)
    };

    // Build (convert + model + marshal) BEFORE any telemetry side
    // effects, so a failed registration leaves no phantom stats row or
    // counter bump.
    let entry = build_cached(backend, &csr, format, cfg)?;

    // Re-registration replaces the matrix wholesale: every per-format
    // entry of the old matrix must go, or a later explored/migrated
    // dispatch could serve the OLD matrix's converted form.
    cache.retain(|k| k.0 != id);

    let tele = telemetry.handle(id);
    tele.configure(format, entry.model.avg_power_w);
    if converted {
        telemetry.totals.conversions.fetch_add(1, Ordering::Relaxed);
    }
    if cache.insert(cache_key(id, format), entry).is_some() {
        telemetry.totals.evictions.fetch_add(1, Ordering::Relaxed);
    }
    registry.insert(
        id,
        Registered {
            csr,
            features: decision.features,
            iterations_hint,
            format,
            converted,
            tele,
        },
    );
    Ok(format)
}

/// Re-run the routing decision for every registered matrix against an
/// upgraded router (features were measured at registration, so this is
/// steps 2–4 only). A matrix whose best format changed migrates: new
/// conversion into the cache, telemetry reconfigured, counters bumped.
/// A failed conversion keeps the old format — migration must never take
/// a serving matrix down.
fn re_decide_all(
    router: &crate::coordinator::RunTimeOptimizer,
    backend: &mut Backend,
    cfg: &ShardCfg,
    telemetry: &Telemetry,
    registry: &mut HashMap<u64, Registered>,
    cache: &mut Lru<CacheKey, CachedMatrix>,
) {
    for (id, reg) in registry.iter_mut() {
        let decision =
            router.decide_with_features(reg.features, Duration::ZERO, reg.iterations_hint);
        let (format, converted) = if decision.convert {
            (decision.predicted_format, true)
        } else {
            (Format::Csr, false)
        };
        if format == reg.format {
            continue;
        }
        // The target form may already be cached (the common convergence
        // path: exploration built it before the retrain picked it) —
        // reuse it instead of re-converting and re-simulating.
        let key = cache_key(*id, format);
        let model = if cache.touch(key) {
            match cache.mru() {
                Some((k, entry)) if *k == key => Some(entry.model),
                _ => unreachable!("touch just made {key:?} the MRU entry"),
            }
        } else {
            match build_cached(backend, &reg.csr, format, cfg) {
                Ok(entry) => {
                    let model = entry.model;
                    if cache.insert(key, entry).is_some() {
                        telemetry.totals.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                    Some(model)
                }
                Err(e) => {
                    eprintln!(
                        "serve: keeping matrix {id} in {} (migration to {format} failed: {e:#})",
                        reg.format
                    );
                    None
                }
            }
        };
        if let Some(model) = model {
            reg.tele.configure(format, model.avg_power_w);
            telemetry.totals.migrations.fetch_add(1, Ordering::Relaxed);
            if converted && !reg.converted {
                telemetry.totals.conversions.fetch_add(1, Ordering::Relaxed);
            }
            reg.format = format;
            reg.converted = converted;
        }
    }
}

/// Make `(id, route.format)` the cache's MRU entry, converting from the
/// retained CSR source on a miss. Chosen-path misses are evictions
/// being repaired and count as reconversions; explored-path misses are
/// counterfactual builds and a failure is logged here (the caller falls
/// back to the chosen format instead of failing clients).
fn ensure_cached(
    backend: &mut Backend,
    cfg: &ShardCfg,
    telemetry: &Telemetry,
    cache: &mut Lru<CacheKey, CachedMatrix>,
    reg: &Registered,
    id: u64,
    route: RouteChoice,
) -> Result<()> {
    let key = cache_key(id, route.format);
    if cache.touch(key) {
        return Ok(());
    }
    if !route.explored {
        telemetry.totals.reconversions.fetch_add(1, Ordering::Relaxed);
    }
    match build_cached(backend, &reg.csr, route.format, cfg) {
        Ok(entry) => {
            if cache.insert(key, entry).is_some() {
                telemetry.totals.evictions.fetch_add(1, Ordering::Relaxed);
            }
            Ok(())
        }
        Err(e) => {
            if route.explored {
                eprintln!(
                    "serve: exploring {} for matrix {id} failed, serving chosen {}: {e:#}",
                    route.format, reg.format
                );
            }
            Err(e)
        }
    }
}

/// Execute one coalesced group of requests for a single matrix as ONE
/// SpMM dispatch.
#[allow(clippy::too_many_arguments)] // worker-local state is deliberately split for borrow granularity
fn execute_group(
    backend: &mut Backend,
    online: &Option<Arc<Online>>,
    cfg: &ShardCfg,
    telemetry: &Telemetry,
    registry: &HashMap<u64, Registered>,
    cache: &mut Lru<CacheKey, CachedMatrix>,
    id: u64,
    jobs: Vec<Job>,
) {
    let Some(reg) = registry.get(&id) else {
        for job in jobs {
            let _ = job.reply.send(Err(anyhow!("unknown matrix id {id}")));
        }
        return;
    };

    // Validate lengths up front: malformed requests error individually
    // and never poison the batch.
    let n_cols = reg.csr.n_cols;
    let mut xs: Vec<Vec<f32>> = Vec::with_capacity(jobs.len());
    let mut clients = Vec::with_capacity(jobs.len());
    for job in jobs {
        if job.x.len() != n_cols {
            let _ = job
                .reply
                .send(Err(anyhow!("x length {} != n_cols {}", job.x.len(), n_cols)));
        } else {
            xs.push(job.x);
            clients.push((job.enqueued, job.reply));
        }
    }
    if xs.is_empty() {
        return;
    }

    // Closed loop, step "explore": one bandit consult per DISPATCH (not
    // per request). A frozen pool skips this entirely.
    let mut route = match online {
        Some(o) => o.route(&reg.features, reg.format),
        None => RouteChoice::chosen(reg.format),
    };

    // Conversion cache: a miss on the chosen key means the entry was
    // evicted since registration — re-convert from the retained CSR
    // source. A miss on an explored key is the first (or re-) build of
    // that counterfactual form; it shares the same LRU budget, and a
    // FAILED counterfactual build falls back to the chosen format —
    // exploration must never cost a client its answer. touch + mru
    // (instead of two `get`s) keeps the hit path at one scan.
    if route.explored && ensure_cached(backend, cfg, telemetry, cache, reg, id, route).is_err() {
        route = RouteChoice::chosen(reg.format);
    }
    if !route.explored {
        if let Err(e) = ensure_cached(backend, cfg, telemetry, cache, reg, id, route) {
            let msg = format!("convert matrix {id} to {}: {e:#}", route.format);
            for (_, reply) in clients {
                let _ = reply.send(Err(anyhow!("{msg}")));
            }
            return;
        }
    }
    let key = cache_key(id, route.format);
    let cached = match cache.mru() {
        Some((k, entry)) if *k == key => entry,
        _ => unreachable!("ensure_cached just made {key:?} the MRU entry"),
    };

    // One dispatch for the whole group (timed: the execution seconds,
    // queue wait excluded, are the online loop's latency label). The
    // batch rides the cheapest launch schedule available: native spmm
    // walks the matrix once (1 launch); a compiled SpMM artifact
    // executes one launch per bucket chunk; the per-vector prepared
    // path is the fallback at one launch per request.
    let batch_size = xs.len();
    let exec_start = Instant::now();
    let (result, launches, spmm_path): (Result<Vec<Vec<f32>>>, u64, bool) = match backend {
        Backend::Native => (Ok(cached.matrix.as_spmv().spmm(&xs)), 1, true),
        Backend::Pjrt(engine) => {
            // a lone request rides the leaner per-vector artifact; the
            // bucket-padded SpMM launch only pays off with a batch
            let use_spmm = cached
                .prepared_spmm
                .as_ref()
                .filter(|_| batch_size > 1 || cached.prepared.is_none());
            if let Some(spmm) = use_spmm {
                (
                    engine.spmm_prepared(spmm, &xs),
                    spmm.launches_for(batch_size) as u64,
                    true,
                )
            } else if let Some(prep) = &cached.prepared {
                (engine.spmv_batch_prepared(prep, &xs), batch_size as u64, false)
            } else {
                (
                    xs.iter().map(|x| engine.spmv(&cached.matrix, x, None)).collect(),
                    batch_size as u64,
                    false,
                )
            }
        }
    };
    let exec_s = exec_start.elapsed().as_secs_f64();

    // Batched SpMM dispatches charge the matrix stream once across the
    // whole group; the per-vector fallback really does stream it per
    // request, so its labels stay at the single-product model.
    let model = if spmm_path {
        batch_model(cached, route.format, batch_size, &cfg.arch)
    } else {
        cached.model
    };
    match result {
        Ok(ys) => {
            let totals = &telemetry.totals;
            totals.dispatches.fetch_add(1, Ordering::Relaxed);
            totals.launches.fetch_add(launches, Ordering::Relaxed);
            if spmm_path {
                totals.spmm_dispatches.fetch_add(1, Ordering::Relaxed);
            }
            totals.requests.fetch_add(batch_size as u64, Ordering::Relaxed);
            totals.max_batch.fetch_max(batch_size as u64, Ordering::Relaxed);
            if batch_size > 1 {
                totals.coalesced_batches.fetch_add(1, Ordering::Relaxed);
                totals.batched_requests.fetch_add(batch_size as u64, Ordering::Relaxed);
            }
            if route.explored {
                totals.explored_requests.fetch_add(batch_size as u64, Ordering::Relaxed);
            }
            reg.tele.route(route.format, route.explored, batch_size as u64);
            for ((enqueued, reply), y) in clients.into_iter().zip(ys) {
                let service_time = enqueued.elapsed();
                reg.tele.record(service_time, model.energy_j);
                let _ = reply.send(Ok(Response {
                    y,
                    format_used: route.format,
                    converted: route.format != Format::Csr,
                    service_time,
                    batch_size,
                    energy_j: model.energy_j,
                }));
            }
            // Closed loop, step "observe": feed the executed dispatch
            // back. May trigger an inline retrain — which is why it
            // runs AFTER every client got its reply.
            if let Some(o) = online {
                o.observe(Observation {
                    matrix_id: id,
                    features: reg.features,
                    format: route.format,
                    explored: route.explored,
                    requests: batch_size as u64,
                    measured_latency_s: exec_s / batch_size as f64,
                    modeled: model,
                });
            }
        }
        Err(e) => {
            let msg = format!("execute batch for matrix {id}: {e:#}");
            for (_, reply) in clients {
                let _ = reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}
