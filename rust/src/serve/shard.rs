//! A serving shard: one worker thread owning its own backend.
//!
//! The PJRT client is not `Send`, so every shard builds a private
//! [`Backend`] from the shared [`BackendSpec`]. A shard owns the
//! matrices hashed to it: the registry keeps the CSR source plus the
//! router's decision, while the (potentially much larger) converted
//! forms live in a capacity-bounded LRU keyed by `(matrix, format)` — a
//! post-eviction request re-converts from the retained source. Product
//! requests are coalesced by [`super::batch`] and dispatched through
//! the SpMM entry points: `SpMv::spmm` on the native backend, a
//! multi-vector SpMM artifact (one launch per batch) on PJRT, with the
//! per-vector prepared path as the fallback when no SpMM variant is
//! compiled for the shape.
//!
//! When the pool runs with the closed loop attached
//! ([`crate::online`]), three things happen here and nowhere else:
//! the shard polls the hot-swap policy's version at the top of its
//! message loop and **re-decides** every registered matrix on an
//! upgrade — the format AND the compile knobs, so a swap can migrate a
//! matrix to a different conversion, a different artifact variant, or
//! both; each dispatch consults the exploration bandit, which may route
//! it to a non-predicted joint arm (converted/marshalled on demand into
//! the same LRU); and every executed dispatch feeds an [`Observation`]
//! — labeled with the knobs actually executed — back to the trainer.
//! All of it sits between dispatches — never under a request's
//! execution.

use super::backend::{Backend, BackendSpec};
use super::batch::{collect_batch, group_by_matrix, Job, JobKind};
use super::cache::Lru;
use super::telemetry::{MatrixTelemetry, Telemetry};
use super::Response;
use crate::coordinator::compile_time::CompileChoice;
use crate::features::Features;
use crate::gpusim::{simulate, GpuArch, KernelProfile, Measurement};
use crate::obs::{EventKind, Stage, Trace};
use crate::online::{JointDecision, Observation, Online, Policy, RouteChoice, SwapRouter};
use crate::runtime::pjrt::{PreparedSession, PreparedSpmm, PreparedSpmv, SessionVec};
use crate::sparse::convert::{self, AnyFormat, ConvertParams};
use crate::sparse::{Coo, Csr, Format, KernelKind, SpMv};
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Messages a shard understands. Session messages bypass the
/// coalescing window (they are handled directly by the message loop),
/// but one that arrives while a batch is draining lands in the backlog
/// and is handled right after it — the same at-most-one-window delay a
/// registration sees.
pub(crate) enum ShardMsg {
    Register { id: u64, coo: Coo, iterations_hint: u64, ack: Sender<Result<Format>> },
    /// Drop a replica registration (control plane, replica shards only
    /// — never the hash home). Fire-and-forget; ignored while a session
    /// pins the matrix on this shard.
    Deregister { id: u64 },
    Product(Job),
    /// Open iterative session `session` pinned to `matrix_id`; acks
    /// the (square) dimension n.
    SessionOpen { session: u64, matrix_id: u64, ack: Sender<Result<usize>> },
    /// Install the session's vector (host -> session boundary crossing).
    SessionWrite { session: u64, x: Arc<[f32]>, ack: Sender<Result<()>> },
    /// Run `steps` chained applications of `op`, feeding each result
    /// back as the next x without surfacing it.
    SessionStep { session: u64, steps: u64, op: StepOp, ack: Sender<Result<()>> },
    /// Copy the session's current vector out (session -> host crossing).
    SessionRead { session: u64, ack: Sender<Result<Vec<f32>>> },
    /// Fire-and-forget close (sent from the session handle's Drop).
    SessionClose { session: u64 },
    Status(Sender<ShardStatus>),
    Shutdown,
}

/// What one iterative-session step computes from the session's current
/// vector. Products chain device-resident on PJRT; the solve ops run
/// the native sweep on the pinned conversion, so on PJRT they bounce
/// the vector through the host (charged to `marshalled_bytes` like any
/// boundary crossing) — a CG-with-SymGS-preconditioner chain still
/// crosses the POOL boundary zero times between steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOp {
    /// x' = A x, normalized to x' = A x / ||A x|| when asked.
    Product { normalize: bool },
    /// x' = T⁻¹ x against the pinned matrix's triangle + diagonal.
    Sptrsv { lower: bool },
    /// x' = one symmetric Gauss–Seidel sweep for A x' = x from a zero
    /// initial guess (the preconditioner application M⁻¹ x).
    Symgs,
}

impl StepOp {
    fn kind(self) -> KernelKind {
        match self {
            StepOp::Product { .. } => KernelKind::Spmv,
            StepOp::Sptrsv { .. } => KernelKind::Sptrsv,
            StepOp::Symgs => KernelKind::Symgs,
        }
    }
}

/// Occupancy summary a shard reports to [`super::Pool::stats`].
#[derive(Debug, Clone, Copy)]
pub struct ShardStatus {
    pub registered: usize,
    pub cached: usize,
    /// Iterative sessions currently open on this shard.
    pub active_sessions: usize,
    /// Backend actually built ("pjrt" or "native") — a shard degrades
    /// to native when PJRT init fails, and reports say so.
    pub backend: &'static str,
}

/// Per-shard immutable configuration (built by the pool).
#[derive(Clone)]
pub(crate) struct ShardCfg {
    /// This shard's index (the flight recorder's lane).
    pub shard: usize,
    pub convert: ConvertParams,
    pub batch_window: Duration,
    pub max_batch: usize,
    pub cache_capacity: usize,
    pub arch: GpuArch,
    /// Record request-lifecycle stage durations. The boundary
    /// timestamps are captured either way (service time needs them);
    /// the flag gates only the per-request saturating subtractions and
    /// relaxed atomic histogram adds.
    pub tracing: bool,
    /// Outstanding product jobs on this shard's queue: the pool
    /// increments on send, the worker decrements when a batch is picked
    /// up. Relaxed on both sides — the control plane's least-loaded
    /// routing reads it as a load hint, never for correctness.
    pub depth: Arc<std::sync::atomic::AtomicU64>,
}

/// Handle to a running shard.
pub(crate) struct Shard {
    pub tx: Sender<ShardMsg>,
    join: Option<JoinHandle<()>>,
}

impl Shard {
    pub(crate) fn spawn(
        index: usize,
        router: Arc<SwapRouter>,
        online: Option<Arc<Online>>,
        backend: BackendSpec,
        cfg: ShardCfg,
        telemetry: Arc<Telemetry>,
    ) -> Shard {
        let (tx, rx) = channel::<ShardMsg>();
        let join = std::thread::Builder::new()
            .name(format!("serve-shard-{index}"))
            .spawn(move || {
                let backend = match backend.build() {
                    Ok(b) => b,
                    Err(e) => {
                        eprintln!(
                            "serve-shard-{index}: backend init failed, falling back to native: {e:#}"
                        );
                        Backend::Native
                    }
                };
                worker_loop(rx, router, online, backend, cfg, telemetry)
            })
            .expect("spawn serving shard");
        Shard { tx, join: Some(join) }
    }

    /// Ask the worker to exit and join it (used by the pool's Drop).
    pub(crate) fn shutdown(&mut self) {
        let _ = self.tx.send(ShardMsg::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// A registered matrix: retained CSR source + the joint routing
/// decision + the telemetry handle resolved once so the hot path is
/// lock-free. The features and iteration hint stay around for
/// re-decisions on policy hot-swaps (step 1 of §5.3 is measured once,
/// at registration).
struct Registered {
    csr: Csr,
    features: Features,
    iterations_hint: u64,
    format: Format,
    /// Compile-knob half of the joint decision (the serving default
    /// until a knob policy is installed).
    choice: CompileChoice,
    converted: bool,
    tele: Arc<MatrixTelemetry>,
}

impl Registered {
    fn decision(&self) -> JointDecision {
        JointDecision { format: self.format, choice: self.choice }
    }
}

/// Conversion-cache key: matrix id + format class + the QUANTIZED knob
/// arm ([`crate::online::bandit::knob_index`]), so an explored (or
/// migrated-away-from) variant caches alongside the chosen one instead
/// of displacing it — and explored inserts evict other scratch entries
/// before any registered matrix's chosen entry ([`Lru::insert_protected`]).
/// Quantizing to the 12 arm classes — the granularity
/// at which `knob_map` selects distinct Pallas variants — bounds the
/// per-(matrix, format) footprint under joint exploration; two exact
/// choices in the same class share the entry (and its builder's
/// modeled measurement, a within-class approximation).
#[derive(Debug, Clone, Copy, PartialEq)]
struct CacheKey {
    id: u64,
    format: u8,
    knob: u8,
}

fn cache_key(id: u64, d: JointDecision) -> CacheKey {
    CacheKey {
        id,
        format: d.format.class_id() as u8,
        knob: crate::online::bandit::knob_index(d.choice) as u8,
    }
}

/// A cache entry: the converted form, PJRT-marshalled literals when the
/// backend compiles artifacts (per-vector AND, when the inventory has
/// one, the multi-vector SpMM variant), the workload profile, and the
/// gpusim-modeled per-product measurement for THIS format (the
/// telemetry/observation energy source; batched dispatches re-model
/// from `profile` so the matrix stream is charged once per batch).
struct CachedMatrix {
    matrix: AnyFormat,
    prepared: Option<PreparedSpmv>,
    prepared_spmm: Option<PreparedSpmm>,
    profile: Option<KernelProfile>,
    model: Measurement,
}

/// An open iterative session (tracked shard-side; the client holds a
/// [`super::Session`] handle). The vector lives here between steps —
/// device-resident on PJRT whenever the bucket chains, host-resident on
/// native — so pure steps cross the pool boundary zero times.
struct SessionState {
    matrix_id: u64,
    /// The joint (format, knob) decision the session pinned at open.
    /// Policy hot-swaps DEFER for a pinned matrix: the migration lands
    /// when its last session closes. All formats produce bit-identical
    /// products, so deferral never changes results — it keeps the
    /// pinned conversion (and PJRT chaining state) stable.
    decision: JointDecision,
    /// Owning handle on the pinned conversion. The LRU may still evict
    /// the entry under capacity pressure (`insert_protected` falls back
    /// to LRU order when everything is protected); this clone is what
    /// actually guarantees the session keeps serving from the same
    /// converted matrix regardless.
    pinned: Rc<CachedMatrix>,
    /// PJRT chaining state (session-lifetime marshalled literals);
    /// `None` on the native backend.
    prepared: Option<PreparedSession>,
    /// Current vector, or `None` before the first `write` (and after a
    /// failed step, which consumes it).
    vec: Option<SessionVec>,
    /// Square dimension: x and y lengths alike.
    n: usize,
    /// Steps executed over the session's lifetime (reported by the
    /// `session_close` journal event).
    steps: u64,
}

fn worker_loop(
    rx: Receiver<ShardMsg>,
    router: Arc<SwapRouter>,
    online: Option<Arc<Online>>,
    mut backend: Backend,
    cfg: ShardCfg,
    telemetry: Arc<Telemetry>,
) {
    let mut registry: HashMap<u64, Registered> = HashMap::new();
    let mut cache: Lru<CacheKey, Rc<CachedMatrix>> = Lru::new(cfg.cache_capacity);
    let mut sessions: HashMap<u64, SessionState> = HashMap::new();
    let mut backlog: VecDeque<ShardMsg> = VecDeque::new();
    let (mut cur_policy, mut cur_version) = router.load();
    loop {
        let msg = match backlog.pop_front() {
            Some(m) => m,
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => break, // pool dropped
            },
        };
        // Hot-swap check: one atomic load per message. On an upgrade,
        // reload the policy and re-decide every registered matrix so it
        // can migrate to the (format, knob) pair the new model prefers.
        // Matrices pinned by an open session defer to session close.
        if router.version() != cur_version {
            (cur_policy, cur_version) = router.load();
            // Close the per-arm attribution generation BEFORE migrating,
            // so `arm_shift` events precede this version's migrations in
            // the journal (first shard to notice wins; the rest no-op).
            telemetry.arms.mark_generation(cur_version, telemetry.journal());
            re_decide_all(
                cur_policy.as_ref(),
                cur_version,
                &mut backend,
                &cfg,
                &telemetry,
                &mut registry,
                &mut cache,
                &sessions,
            );
        }
        match msg {
            ShardMsg::Shutdown => break,
            ShardMsg::Status(reply) => {
                let _ = reply.send(ShardStatus {
                    registered: registry.len(),
                    cached: cache.len(),
                    active_sessions: sessions.len(),
                    backend: backend.name(),
                });
            }
            ShardMsg::Register { id, coo, iterations_hint, ack } => {
                let result = do_register(
                    cur_policy.as_ref(),
                    &mut backend,
                    &cfg,
                    &telemetry,
                    &mut registry,
                    &mut cache,
                    id,
                    coo,
                    iterations_hint,
                );
                let _ = ack.send(result);
            }
            ShardMsg::Deregister { id } => {
                // Defensive: the control plane only replicates onto
                // non-home shards and sessions only open on the home,
                // so a pinned matrix should never see this — but if it
                // does, keeping the registration is the safe no-op.
                if !sessions.values().any(|s| s.matrix_id == id) {
                    registry.remove(&id);
                    cache.retain(|k| k.id != id);
                }
            }
            ShardMsg::Product(job) => {
                // Batch-window open: everything a request waited before
                // this instant is queue time, everything after (until
                // its group starts converting) is batch-formation time.
                let collect_start = Instant::now();
                let batch = collect_batch(job, &rx, &mut backlog, cfg.batch_window, cfg.max_batch);
                // Picked up: these jobs left the admission queue, so
                // least-loaded routing stops counting them.
                cfg.depth.fetch_sub(batch.len() as u64, Ordering::Relaxed);
                for ((id, jk), jobs) in group_by_matrix(batch) {
                    execute_group(
                        &mut backend,
                        &online,
                        &cfg,
                        &telemetry,
                        &registry,
                        &sessions,
                        &mut cache,
                        id,
                        jk,
                        jobs,
                        collect_start,
                    );
                }
            }
            ShardMsg::SessionOpen { session, matrix_id, ack } => {
                let result = do_session_open(
                    &mut backend,
                    &cfg,
                    &telemetry,
                    &registry,
                    &mut cache,
                    &mut sessions,
                    session,
                    matrix_id,
                );
                let _ = ack.send(result);
            }
            ShardMsg::SessionWrite { session, x, ack } => {
                let _ = ack.send(do_session_write(&telemetry, &mut sessions, session, x));
            }
            ShardMsg::SessionStep { session, steps, op, ack } => {
                let _ = ack.send(do_session_step(
                    &mut backend,
                    &online,
                    &cfg,
                    &telemetry,
                    &registry,
                    &mut sessions,
                    session,
                    steps,
                    op,
                ));
            }
            ShardMsg::SessionRead { session, ack } => {
                let _ = ack.send(do_session_read(&mut backend, &telemetry, &mut sessions, session));
            }
            ShardMsg::SessionClose { session } => {
                if let Some(closed) = sessions.remove(&session) {
                    telemetry.journal().emit(EventKind::SessionClose {
                        session,
                        matrix: closed.matrix_id,
                        steps: closed.steps,
                    });
                    // Last session on this matrix gone: apply whatever
                    // policy change was deferred while it was pinned
                    // (no-op when the decision is unchanged).
                    if !sessions.values().any(|s| s.matrix_id == closed.matrix_id) {
                        re_decide_all(
                            cur_policy.as_ref(),
                            cur_version,
                            &mut backend,
                            &cfg,
                            &telemetry,
                            &mut registry,
                            &mut cache,
                            &sessions,
                        );
                    }
                }
            }
        }
    }
}

/// Convert (and, on PJRT, marshal) a matrix for execution under a
/// joint (format, knob) decision, and model one product's cost at
/// exactly those knobs — the §6.3 power-sensor stand-in the telemetry
/// and the online observations both read. The knob preference also
/// biases PJRT artifact selection (SpMV and SpMM alike) through
/// `knob_map`, so a knob migration really re-selects executables.
fn build_cached(
    backend: &mut Backend,
    csr: &Csr,
    decision: JointDecision,
    cfg: &ShardCfg,
) -> Result<CachedMatrix> {
    let matrix = convert::convert(csr, decision.format, cfg.convert);
    let knob_pref = Some(decision.choice.knobs());
    let (prepared, prepared_spmm) = match backend {
        Backend::Pjrt(engine) => {
            let prepared = Some(engine.prepare(&matrix, knob_pref)?);
            // a missing SpMM variant is a fallback, never an error; a
            // same-bucket variant shares the marshalled literals
            let prepared_spmm =
                engine.prepare_spmm_sharing(&matrix, knob_pref, prepared.as_ref())?;
            (prepared, prepared_spmm)
        }
        Backend::Native => (None, None),
    };
    let (profile, model) = if csr.vals.is_empty() {
        (
            None,
            Measurement { latency_s: 0.0, energy_j: 0.0, avg_power_w: 0.0, mflops_per_watt: 0.0 },
        )
    } else {
        let prof = crate::gpusim::profile(csr, decision.format, cfg.convert);
        let knobs = decision.choice.config_for(decision.format);
        let m = simulate(&cfg.arch, &prof, &knobs).0;
        (Some(prof), m)
    };
    Ok(CachedMatrix { matrix, prepared, prepared_spmm, profile, model })
}

/// Per-request share of one batched dispatch's modeled cost: simulate
/// the k-vector SpMM launch (matrix stream charged once) and split the
/// extensive objectives across the batch. Falls back to the cached
/// single-product model for k = 1 or an empty profile.
fn batch_model(
    cached: &CachedMatrix,
    decision: JointDecision,
    k: usize,
    arch: &GpuArch,
) -> Measurement {
    if k <= 1 {
        return cached.model;
    }
    let Some(prof) = &cached.profile else {
        return cached.model;
    };
    let knobs = decision.choice.config_for(decision.format);
    let (m, _) = simulate(arch, &prof.batched(k as u64), &knobs);
    Measurement {
        latency_s: m.latency_s / k as f64,
        energy_j: m.energy_j / k as f64,
        // power and MFLOPS/W are already rates over the whole launch
        avg_power_w: m.avg_power_w,
        mflops_per_watt: m.mflops_per_watt,
    }
}

#[allow(clippy::too_many_arguments)] // worker-local state is deliberately split for borrow granularity
fn do_register(
    policy: &Policy,
    backend: &mut Backend,
    cfg: &ShardCfg,
    telemetry: &Telemetry,
    registry: &mut HashMap<u64, Registered>,
    cache: &mut Lru<CacheKey, Rc<CachedMatrix>>,
    id: u64,
    coo: Coo,
    iterations_hint: u64,
) -> Result<Format> {
    let decision = policy.router.decide(&coo, iterations_hint);
    let csr = convert::coo_to_csr(&coo);
    let (format, converted) = if decision.convert {
        (decision.predicted_format, true)
    } else {
        (Format::Csr, false)
    };
    // joint decision: the knob half comes from the installed knob
    // policy (serving default when none is installed)
    let choice = policy.knob_for(&decision.features, format);
    let joint = JointDecision { format, choice };

    // Build (convert + model + marshal) BEFORE any telemetry side
    // effects, so a failed registration leaves no phantom stats row or
    // counter bump.
    let entry = Rc::new(build_cached(backend, &csr, joint, cfg)?);

    // Re-registration replaces the matrix wholesale: every per-variant
    // entry of the old matrix must go, or a later explored/migrated
    // dispatch could serve the OLD matrix's converted form.
    cache.retain(|k| k.id != id);

    let tele = telemetry.handle(id);
    tele.configure(format, choice, entry.model.avg_power_w);
    if converted {
        telemetry.totals.conversions.fetch_add(1, Ordering::Relaxed);
    }
    if cache.insert(cache_key(id, joint), entry).is_some() {
        telemetry.totals.evictions.fetch_add(1, Ordering::Relaxed);
    }
    registry.insert(
        id,
        Registered {
            csr,
            features: decision.features,
            iterations_hint,
            format,
            choice,
            converted,
            tele,
        },
    );
    Ok(format)
}

/// Re-run the joint routing decision for every registered matrix
/// against an upgraded policy (features were measured at registration,
/// so this is steps 2–4 only). A matrix whose best format OR best
/// compile knob changed migrates: new conversion/marshalling into the
/// cache under the new key, telemetry reconfigured, counters bumped
/// (`migrations` for format changes, `knob_migrations` for knob
/// changes — a joint change counts once in each), a `migration` event
/// journaled with the policy version that decided it. A failed rebuild
/// keeps the old decision — migration must never take a serving matrix
/// down. A matrix pinned by an open session keeps its decision: the
/// migration is deferred to session close (the close handler re-runs
/// this) and journaled as `deferred_migration`, keeping the session's
/// conversion and chaining state stable — safe because every format's
/// product is bit-identical anyway.
#[allow(clippy::too_many_arguments)] // worker-local state is deliberately split for borrow granularity
fn re_decide_all(
    policy: &Policy,
    version: u64,
    backend: &mut Backend,
    cfg: &ShardCfg,
    telemetry: &Telemetry,
    registry: &mut HashMap<u64, Registered>,
    cache: &mut Lru<CacheKey, Rc<CachedMatrix>>,
    sessions: &HashMap<u64, SessionState>,
) {
    // Sorted, not HashMap order: the journal's migration events must
    // land in the same order on every seeded run.
    let mut ids: Vec<u64> = registry.keys().copied().collect();
    ids.sort_unstable();
    for id in ids {
        let reg = registry.get_mut(&id).expect("id came from registry.keys()");
        let decision =
            policy.router.decide_with_features(reg.features, Duration::ZERO, reg.iterations_hint);
        let (format, converted) = if decision.convert {
            (decision.predicted_format, true)
        } else {
            (Format::Csr, false)
        };
        let choice = policy.knob_for(&reg.features, format);
        if format == reg.format && choice == reg.choice {
            continue;
        }
        let joint = JointDecision { format, choice };
        if sessions.values().any(|s| s.matrix_id == id) {
            // pinned: defer to session boundary, but journal what the
            // new policy wanted so the deferral is observable
            telemetry.journal().emit(EventKind::DeferredMigration {
                matrix: id,
                to: joint,
                decided_by: version,
            });
            continue;
        }
        // The target variant may already be cached (the common
        // convergence path: exploration built it before the retrain
        // picked it) — reuse it instead of re-converting/re-marshalling
        // and re-simulating.
        let key = cache_key(id, joint);
        let model = if cache.touch(key) {
            match cache.mru() {
                Some((k, entry)) if *k == key => Some(entry.model),
                _ => unreachable!("touch just made {key:?} the MRU entry"),
            }
        } else {
            match build_cached(backend, &reg.csr, joint, cfg) {
                Ok(entry) => {
                    let model = entry.model;
                    if cache.insert(key, Rc::new(entry)).is_some() {
                        telemetry.totals.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                    Some(model)
                }
                Err(e) => {
                    eprintln!(
                        "serve: keeping matrix {id} at {} (migration to {joint} failed: {e:#})",
                        reg.decision()
                    );
                    None
                }
            }
        };
        if let Some(model) = model {
            let from = reg.decision();
            reg.tele.configure(format, choice, model.avg_power_w);
            if format != reg.format {
                telemetry.totals.migrations.fetch_add(1, Ordering::Relaxed);
            }
            if choice != reg.choice {
                telemetry.totals.knob_migrations.fetch_add(1, Ordering::Relaxed);
            }
            if converted && !reg.converted {
                telemetry.totals.conversions.fetch_add(1, Ordering::Relaxed);
            }
            reg.format = format;
            reg.choice = choice;
            reg.converted = converted;
            telemetry.journal().emit(EventKind::Migration {
                matrix: id,
                from,
                to: joint,
                decided_by: version,
            });
        }
    }
}

/// Make `(id, route.decision)` the cache's MRU entry, converting (and
/// marshalling) from the retained CSR source on a miss. Chosen-path
/// misses are evictions being repaired and count as reconversions;
/// explored-path misses are counterfactual builds and a failure is
/// logged here (the caller falls back to the chosen decision instead
/// of failing clients).
#[allow(clippy::too_many_arguments)] // worker-local state is deliberately split for borrow granularity
fn ensure_cached(
    backend: &mut Backend,
    cfg: &ShardCfg,
    telemetry: &Telemetry,
    registry: &HashMap<u64, Registered>,
    sessions: &HashMap<u64, SessionState>,
    cache: &mut Lru<CacheKey, Rc<CachedMatrix>>,
    reg: &Registered,
    id: u64,
    route: RouteChoice,
) -> Result<()> {
    let key = cache_key(id, route.decision);
    if cache.touch(key) {
        return Ok(());
    }
    if !route.explored {
        telemetry.totals.reconversions.fetch_add(1, Ordering::Relaxed);
    }
    match build_cached(backend, &reg.csr, route.decision, cfg) {
        Ok(entry) => {
            // Explored builds are scratch: under joint exploration the
            // arm space is ~48 keys per matrix, so letting them evict
            // by plain recency would thrash every registered matrix's
            // CHOSEN serving entry out of a default-sized cache.
            // Protect the chosen keys AND any key an open session is
            // pinned to (residency; the session's own Rc clone is what
            // guarantees correctness even if capacity forces it out) —
            // scratch evicts scratch first.
            let evicted = if route.explored {
                cache.insert_protected(key, Rc::new(entry), |k| {
                    registry.get(&k.id).is_some_and(|r| cache_key(k.id, r.decision()) == *k)
                        || sessions.values().any(|s| cache_key(s.matrix_id, s.decision) == *k)
                })
            } else {
                cache.insert(key, Rc::new(entry))
            };
            if evicted.is_some() {
                telemetry.totals.evictions.fetch_add(1, Ordering::Relaxed);
            }
            Ok(())
        }
        Err(e) => {
            if route.explored {
                eprintln!(
                    "serve: exploring {} for matrix {id} failed, serving chosen {}: {e:#}",
                    route.decision,
                    reg.decision()
                );
            }
            Err(e)
        }
    }
}

/// Execute one coalesced group of requests for a single (matrix, job
/// kind) as ONE dispatch: SpMV groups ride the SpMM entry points;
/// solve groups (SpTRSV / SymGS) run the sequential native sweep once
/// per vector — on every backend, since a level-ordered dependency
/// chain cannot ride a batched product launch.
///
/// Stage-tracing contract (`cfg.tracing`): the boundaries `enqueued ->
/// collect_start -> group_start -> exec_start -> exec_done -> reply`
/// are shared instants, so each request's recorded stages (queue_wait
/// + batch_wait + convert + exec + reply) sum EXACTLY to its
/// `service_time` — the stage histograms decompose the end-to-end one
/// rather than approximating it.
#[allow(clippy::too_many_arguments)] // worker-local state is deliberately split for borrow granularity
fn execute_group(
    backend: &mut Backend,
    online: &Option<Arc<Online>>,
    cfg: &ShardCfg,
    telemetry: &Telemetry,
    registry: &HashMap<u64, Registered>,
    sessions: &HashMap<u64, SessionState>,
    cache: &mut Lru<CacheKey, Rc<CachedMatrix>>,
    id: u64,
    jk: JobKind,
    jobs: Vec<Job>,
    collect_start: Instant,
) {
    let kind = jk.kind();
    // Group-start boundary: batch-wait ends here; everything until the
    // dispatch (routing, cache repair, conversion) is the convert stage.
    let group_start = Instant::now();
    let Some(reg) = registry.get(&id) else {
        for job in jobs {
            let _ = job.reply.send(Err(anyhow!("unknown matrix id {id}")));
        }
        return;
    };

    // Solves invert against the diagonal, so they only make sense on a
    // square system; reject the whole group up front.
    if kind != KernelKind::Spmv && reg.csr.n_rows != reg.csr.n_cols {
        let msg =
            format!("{kind} requires a square matrix ({}x{})", reg.csr.n_rows, reg.csr.n_cols);
        for job in jobs {
            let _ = job.reply.send(Err(anyhow!("{msg}")));
        }
        return;
    }

    // Validate lengths up front: malformed requests error individually
    // and never poison the batch.
    let n_cols = reg.csr.n_cols;
    let mut xs: Vec<Arc<[f32]>> = Vec::with_capacity(jobs.len());
    let mut clients = Vec::with_capacity(jobs.len());
    for job in jobs {
        if job.x.len() != n_cols {
            let _ = job
                .reply
                .send(Err(anyhow!("x length {} != n_cols {}", job.x.len(), n_cols)));
        } else {
            xs.push(job.x);
            clients.push((job.enqueued, job.deadline, job.reply));
        }
    }
    if xs.is_empty() {
        return;
    }

    // Closed loop, step "explore": one bandit consult per DISPATCH (not
    // per request), bucketed by kernel kind so solve evidence and SpMV
    // evidence never mix. A frozen pool skips this entirely.
    let mut route = match online {
        Some(o) => o.route_kind(kind, &reg.features, reg.decision()),
        None => RouteChoice::chosen(reg.decision()),
    };

    // Conversion cache: a miss on the chosen key means the entry was
    // evicted since registration — re-convert from the retained CSR
    // source. A miss on an explored key is the first (or re-) build of
    // that counterfactual variant; it shares the same LRU budget, and a
    // FAILED counterfactual build falls back to the chosen decision —
    // exploration must never cost a client its answer. touch + mru
    // (instead of two `get`s) keeps the hit path at one scan.
    if route.explored
        && ensure_cached(backend, cfg, telemetry, registry, sessions, cache, reg, id, route)
            .is_err()
    {
        route = RouteChoice::chosen(reg.decision());
    }
    if !route.explored {
        if let Err(e) =
            ensure_cached(backend, cfg, telemetry, registry, sessions, cache, reg, id, route)
        {
            let msg = format!("convert matrix {id} to {}: {e:#}", route.decision);
            for (_, _, reply) in clients {
                let _ = reply.send(Err(anyhow!("{msg}")));
            }
            return;
        }
    }
    if route.explored {
        // journal the counterfactual the bandit actually executed (a
        // failed explored build fell back above and is not journaled)
        telemetry.journal().emit(EventKind::Explored {
            matrix: id,
            from: reg.decision(),
            to: route.decision,
        });
    }
    let key = cache_key(id, route.decision);
    let cached = match cache.mru() {
        Some((k, entry)) if *k == key => entry,
        _ => unreachable!("ensure_cached just made {key:?} the MRU entry"),
    };

    // One dispatch for the whole group (timed: the execution seconds,
    // queue wait excluded, are the online loop's latency label). The
    // batch rides the cheapest launch schedule available: native spmm
    // walks the matrix once (1 launch); a compiled SpMM artifact
    // executes one launch per bucket chunk; the per-vector prepared
    // path is the fallback at one launch per request.
    let batch_size = xs.len();
    // Borrowed views over the shared payloads: the dispatch reads the
    // clients' buffers directly — no per-request copy anywhere between
    // enqueue and kernel marshalling.
    let views: Vec<&[f32]> = xs.iter().map(|x| x.as_ref()).collect();
    let exec_start = Instant::now();
    let (result, launches, spmm_path): (Result<Vec<Vec<f32>>>, u64, bool) = match jk {
        JobKind::Spmv => match backend {
            Backend::Native => (Ok(cached.matrix.as_spmv().spmm(&views)), 1, true),
            Backend::Pjrt(engine) => {
                // a lone request rides the leaner per-vector artifact; the
                // bucket-padded SpMM launch only pays off with a batch
                let use_spmm = cached
                    .prepared_spmm
                    .as_ref()
                    .filter(|_| batch_size > 1 || cached.prepared.is_none());
                if let Some(spmm) = use_spmm {
                    (
                        engine.spmm_prepared(spmm, &views),
                        spmm.launches_for(batch_size) as u64,
                        true,
                    )
                } else if let Some(prep) = &cached.prepared {
                    (engine.spmv_batch_prepared(prep, &views), batch_size as u64, false)
                } else {
                    (
                        xs.iter()
                            .map(|x| {
                                engine.spmv(&cached.matrix, x, Some(route.decision.choice.knobs()))
                            })
                            .collect(),
                        batch_size as u64,
                        false,
                    )
                }
            }
        },
        // Solves sweep the converted form sequentially, one launch per
        // vector — a singular diagonal fails the whole group (same
        // matrix, same pivots for every rhs).
        JobKind::Sptrsv { lower } => {
            let m = cached.matrix.as_spmv();
            (views.iter().map(|b| m.sptrsv(b, lower)).collect(), batch_size as u64, false)
        }
        JobKind::Symgs => {
            let m = cached.matrix.as_spmv();
            (
                views
                    .iter()
                    .map(|b| {
                        let mut y = vec![0.0f32; b.len()];
                        m.symgs_sweep(b, &mut y)?;
                        Ok(y)
                    })
                    .collect(),
                batch_size as u64,
                false,
            )
        }
    };
    let exec_done = Instant::now();
    let exec_s = exec_done.duration_since(exec_start).as_secs_f64();

    // Batched SpMM dispatches charge the matrix stream once across the
    // whole group; the per-vector fallback really does stream it per
    // request, so its labels stay at the single-product model.
    let model = if spmm_path {
        batch_model(cached, route.decision, batch_size, &cfg.arch)
    } else {
        cached.model
    };
    match result {
        Ok(ys) => {
            let totals = &telemetry.totals;
            totals.dispatches.fetch_add(1, Ordering::Relaxed);
            totals.launches.fetch_add(launches, Ordering::Relaxed);
            if spmm_path {
                totals.spmm_dispatches.fetch_add(1, Ordering::Relaxed);
            }
            totals.requests.fetch_add(batch_size as u64, Ordering::Relaxed);
            // Per-request vector traffic across the dispatch boundary:
            // x in, y out — what an iterative session elides per step.
            totals.marshalled_bytes.fetch_add(
                batch_size as u64 * 4 * (n_cols + reg.csr.n_rows) as u64,
                Ordering::Relaxed,
            );
            totals.max_batch.fetch_max(batch_size as u64, Ordering::Relaxed);
            if batch_size > 1 {
                totals.coalesced_batches.fetch_add(1, Ordering::Relaxed);
                totals.batched_requests.fetch_add(batch_size as u64, Ordering::Relaxed);
            }
            if route.explored {
                totals.explored_requests.fetch_add(batch_size as u64, Ordering::Relaxed);
            }
            reg.tele.route(route.decision, route.explored, batch_size as u64);
            // Batch-shared stages: one atomic update with multiplicity
            // batch_size — every request in the group experienced the
            // same convert/exec wall time.
            let convert_d = exec_start.duration_since(group_start);
            let exec_d = exec_done.duration_since(exec_start);
            // Per-arm attribution: the whole group rode one joint arm,
            // so one call covers it (request-weighted exec time); the
            // kind keeps solve windows out of the SpMV cells.
            telemetry.arms.record_kind(
                kind,
                route.decision,
                batch_size as u64,
                exec_d * batch_size as u32,
                &model,
            );
            if cfg.tracing {
                let k = batch_size as u64;
                telemetry.stages.record_n(Stage::Convert, convert_d, k);
                let exec_stage = match jk {
                    JobKind::Spmv if spmm_path => Stage::SpmmExec,
                    JobKind::Spmv => Stage::Exec,
                    JobKind::Sptrsv { .. } | JobKind::Symgs => Stage::SolveExec,
                };
                telemetry.stages.record_n(exec_stage, exec_d, k);
            }
            for ((enqueued, deadline, reply), y) in clients.into_iter().zip(ys) {
                let now = Instant::now();
                let service_time = now.duration_since(enqueued);
                let trace = if cfg.tracing {
                    // A request that joined mid-window has no queue
                    // time; its batch wait starts at its own enqueue.
                    let queue_wait = collect_start.saturating_duration_since(enqueued);
                    let waited_from =
                        if enqueued > collect_start { enqueued } else { collect_start };
                    let batch_wait = group_start.saturating_duration_since(waited_from);
                    let reply_wait = now.duration_since(exec_done);
                    telemetry.stages.record(Stage::QueueWait, queue_wait);
                    telemetry.stages.record(Stage::BatchWait, batch_wait);
                    telemetry.stages.record(Stage::Reply, reply_wait);
                    Some(Trace {
                        queue_wait,
                        batch_wait,
                        convert: convert_d,
                        exec: exec_d,
                        reply: reply_wait,
                    })
                } else {
                    None
                };
                let tagged = deadline.is_some();
                let missed = deadline.is_some_and(|dl| service_time > dl);
                if tagged {
                    totals.deadline_tagged.fetch_add(1, Ordering::Relaxed);
                    if missed {
                        totals.deadline_misses.fetch_add(1, Ordering::Relaxed);
                    }
                }
                if let Some(slo) = telemetry.slo() {
                    slo.observe(id, cfg.shard, service_time, tagged, missed, trace);
                }
                reg.tele.record(service_time, model.energy_j);
                let _ = reply.send(Ok(Response {
                    y,
                    format_used: route.decision.format,
                    converted: route.decision.format != Format::Csr,
                    service_time,
                    batch_size,
                    energy_j: model.energy_j,
                    trace,
                }));
            }
            // Closed loop, step "observe": feed the executed dispatch
            // back, labeled with the knobs it actually ran under. May
            // trigger an inline retrain — which is why it runs AFTER
            // every client got its reply.
            if let Some(o) = online {
                o.observe(Observation {
                    matrix_id: id,
                    kind,
                    features: reg.features,
                    format: route.decision.format,
                    choice: route.decision.choice,
                    explored: route.explored,
                    requests: batch_size as u64,
                    measured_latency_s: exec_s / batch_size as f64,
                    modeled: model,
                });
            }
        }
        Err(e) => {
            let msg = format!("execute batch for matrix {id}: {e:#}");
            for (_, _, reply) in clients {
                let _ = reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

/// Open an iterative session pinned to a registered (square) matrix:
/// make the CHOSEN conversion resident, clone its `Rc` into the session
/// (the eviction-proof handle), and on PJRT marshal the session's
/// chaining literals — per-step SpMV plus the fused power artifact when
/// one fits. Sessions always pin the chosen decision; they never
/// explore (a mid-flight arm change would invalidate the device chain).
#[allow(clippy::too_many_arguments)] // worker-local state is deliberately split for borrow granularity
fn do_session_open(
    backend: &mut Backend,
    cfg: &ShardCfg,
    telemetry: &Telemetry,
    registry: &HashMap<u64, Registered>,
    cache: &mut Lru<CacheKey, Rc<CachedMatrix>>,
    sessions: &mut HashMap<u64, SessionState>,
    session: u64,
    matrix_id: u64,
) -> Result<usize> {
    let reg =
        registry.get(&matrix_id).ok_or_else(|| anyhow!("unknown matrix id {matrix_id}"))?;
    let n = reg.csr.n_rows;
    if n != reg.csr.n_cols {
        bail!(
            "iterative session requires a square matrix ({}x{})",
            reg.csr.n_rows,
            reg.csr.n_cols
        );
    }
    let route = RouteChoice::chosen(reg.decision());
    ensure_cached(backend, cfg, telemetry, registry, sessions, cache, reg, matrix_id, route)?;
    let key = cache_key(matrix_id, route.decision);
    let pinned = match cache.mru() {
        Some((k, entry)) if *k == key => Rc::clone(entry),
        _ => unreachable!("ensure_cached just made {key:?} the MRU entry"),
    };
    let prepared = match backend {
        Backend::Pjrt(engine) => {
            Some(engine.prepare_session(&pinned.matrix, Some(route.decision.choice.knobs()))?)
        }
        Backend::Native => None,
    };
    telemetry.totals.sessions_opened.fetch_add(1, Ordering::Relaxed);
    telemetry.journal().emit(EventKind::SessionOpen { session, matrix: matrix_id });
    sessions.insert(
        session,
        SessionState {
            matrix_id,
            decision: route.decision,
            pinned,
            prepared,
            vec: None,
            n,
            steps: 0,
        },
    );
    Ok(n)
}

/// Install the session's vector: the one host->session crossing a
/// write pays for, charged to `marshalled_bytes`.
fn do_session_write(
    telemetry: &Telemetry,
    sessions: &mut HashMap<u64, SessionState>,
    session: u64,
    x: Arc<[f32]>,
) -> Result<()> {
    let state =
        sessions.get_mut(&session).ok_or_else(|| anyhow!("unknown session {session}"))?;
    if x.len() != state.n {
        bail!("x length {} != n {}", x.len(), state.n);
    }
    telemetry.totals.marshalled_bytes.fetch_add(4 * state.n as u64, Ordering::Relaxed);
    state.vec = Some(SessionVec::Host(x.to_vec()));
    Ok(())
}

/// Run `steps` chained applications of `op` on a session. Each step
/// counts exactly like a per-request dispatch in the launch ledger (+1
/// request, +1 dispatch, +1 launch) — the session's win is the VECTOR
/// ledger: a pure chained step moves zero bytes across the dispatch
/// boundary and charges `elided_bytes`/`round_trips_elided` with what
/// the per-request path would have paid; a step that had to bounce
/// through the host (non-square PJRT bucket, host-side normalize
/// without a fused artifact, or a solve op on PJRT — the sequential
/// sweep runs host-side) charges `marshalled_bytes` instead. The whole
/// run feeds ONE batch-weighted [`Observation`] tagged with the op's
/// kernel kind so retrain cadence and drift detection see session
/// traffic without solve latencies polluting SpMV training labels. A
/// failed step consumes the vector: the client must `write` again
/// before continuing.
#[allow(clippy::too_many_arguments)] // worker-local state is deliberately split for borrow granularity
fn do_session_step(
    backend: &mut Backend,
    online: &Option<Arc<Online>>,
    cfg: &ShardCfg,
    telemetry: &Telemetry,
    registry: &HashMap<u64, Registered>,
    sessions: &mut HashMap<u64, SessionState>,
    session: u64,
    steps: u64,
    op: StepOp,
) -> Result<()> {
    let state =
        sessions.get_mut(&session).ok_or_else(|| anyhow!("unknown session {session}"))?;
    if state.vec.is_none() {
        bail!("session vector unset: call write() first");
    }
    let reg = registry.get(&state.matrix_id);
    let model = state.pinned.model;
    let n = state.n as u64;
    let totals = &telemetry.totals;
    let t0 = Instant::now();
    // One host-side sweep from the session's current vector (the solve
    // ops; also every native op). Errors (singular diagonal) surface to
    // the client with the vector consumed, per the step contract.
    let apply_host = |matrix: &AnyFormat, x: &[f32]| -> Result<Vec<f32>> {
        let m = matrix.as_spmv();
        match op {
            StepOp::Product { normalize } => {
                let mut y = m.spmv_alloc(x);
                if normalize {
                    let norm = y.iter().map(|v| v * v).sum::<f32>().sqrt();
                    for v in &mut y {
                        *v /= norm;
                    }
                }
                Ok(y)
            }
            StepOp::Sptrsv { lower } => m.sptrsv(x, lower),
            StepOp::Symgs => {
                let mut y = vec![0.0f32; x.len()];
                m.symgs_sweep(x, &mut y)?;
                Ok(y)
            }
        }
    };
    for _ in 0..steps {
        let step_start = Instant::now();
        let cur = state.vec.take().expect("session vector present");
        let (next, bounced) = match (backend, op) {
            (Backend::Pjrt(engine), StepOp::Product { normalize }) => {
                let prep = state.prepared.as_ref().expect("PJRT session is prepared");
                engine.session_step(prep, cur, normalize)?
            }
            (Backend::Pjrt(engine), StepOp::Sptrsv { .. } | StepOp::Symgs) => {
                // solve step on PJRT: bounce the device vector through
                // the host, sweep natively, continue the chain host-side
                // (the next product step re-uploads it)
                let prep = state.prepared.as_ref().expect("PJRT session is prepared");
                let x = engine.session_read(prep, &cur)?;
                (SessionVec::Host(apply_host(&state.pinned.matrix, &x)?), true)
            }
            (Backend::Native, _) => {
                let x = match cur {
                    SessionVec::Host(v) => v,
                    SessionVec::Device(_) => {
                        unreachable!("native session state is host-resident")
                    }
                };
                // host-side vector REUSE: y becomes the next x without
                // ever crossing back through the pool's queue/reply
                // boundary, so the step is as boundary-free as a
                // device-chained one
                (SessionVec::Host(apply_host(&state.pinned.matrix, &x)?), false)
            }
        };
        state.vec = Some(next);
        state.steps += 1;
        totals.requests.fetch_add(1, Ordering::Relaxed);
        totals.dispatches.fetch_add(1, Ordering::Relaxed);
        totals.launches.fetch_add(1, Ordering::Relaxed);
        totals.session_steps.fetch_add(1, Ordering::Relaxed);
        if bounced {
            totals.marshalled_bytes.fetch_add(8 * n, Ordering::Relaxed);
        } else {
            totals.elided_bytes.fetch_add(8 * n, Ordering::Relaxed);
            totals.round_trips_elided.fetch_add(1, Ordering::Relaxed);
        }
        // One shared elapsed read: the session_step stage and the
        // per-matrix end-to-end histogram must see the same duration,
        // or the stage decomposition would drift from the e2e totals.
        let step_d = step_start.elapsed();
        if let Some(r) = reg {
            if cfg.tracing {
                telemetry.stages.record(Stage::SessionStep, step_d);
            }
            r.tele.record(step_d, model.energy_j);
        }
        if let Some(slo) = telemetry.slo() {
            // a session step is all execution — no queue/batch stages
            let trace = Trace { exec: step_d, ..Trace::default() };
            slo.observe(state.matrix_id, cfg.shard, step_d, false, false, Some(trace));
        }
    }
    if steps > 0 {
        if let Some(r) = reg {
            r.tele.route(state.decision, false, steps);
        }
        telemetry.arms.record_kind(op.kind(), state.decision, steps, t0.elapsed(), &model);
        if let (Some(o), Some(r)) = (online, reg) {
            o.observe(Observation {
                matrix_id: state.matrix_id,
                kind: op.kind(),
                features: r.features,
                format: state.decision.format,
                choice: state.decision.choice,
                explored: false,
                requests: steps,
                measured_latency_s: t0.elapsed().as_secs_f64() / steps as f64,
                modeled: model,
            });
        }
    }
    Ok(())
}

/// Copy the session's current vector out — the explicit escape hatch,
/// charged to `marshalled_bytes` like any boundary crossing.
fn do_session_read(
    backend: &mut Backend,
    telemetry: &Telemetry,
    sessions: &mut HashMap<u64, SessionState>,
    session: u64,
) -> Result<Vec<f32>> {
    let state =
        sessions.get_mut(&session).ok_or_else(|| anyhow!("unknown session {session}"))?;
    let Some(vec) = &state.vec else {
        bail!("session vector unset: call write() first");
    };
    let y = match (backend, vec) {
        (Backend::Pjrt(engine), v) => {
            let prep = state.prepared.as_ref().expect("PJRT session is prepared");
            engine.session_read(prep, v)?
        }
        (Backend::Native, SessionVec::Host(v)) => v.clone(),
        (Backend::Native, SessionVec::Device(_)) => {
            unreachable!("native session state is host-resident")
        }
    };
    telemetry.totals.marshalled_bytes.fetch_add(4 * state.n as u64, Ordering::Relaxed);
    Ok(y)
}
