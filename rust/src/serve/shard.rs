//! A serving shard: one worker thread owning its own backend.
//!
//! The PJRT client is not `Send`, so every shard builds a private
//! [`Backend`] from the shared [`BackendSpec`]. A shard owns the
//! matrices hashed to it: the registry keeps the CSR source plus the
//! router's decision, while the (potentially much larger) converted
//! forms live in a capacity-bounded LRU — a post-eviction request
//! re-converts from the retained source. Product requests are coalesced
//! by [`super::batch`] and dispatched through `spmv_batch`.

use super::backend::{Backend, BackendSpec};
use super::batch::{collect_batch, group_by_matrix, Job};
use super::cache::Lru;
use super::telemetry::{MatrixTelemetry, Telemetry};
use super::Response;
use crate::coordinator::RunTimeOptimizer;
use crate::gpusim::{simulate, GpuArch, KernelConfig, MemConfig};
use crate::runtime::pjrt::PreparedSpmv;
use crate::sparse::convert::{self, AnyFormat, ConvertParams};
use crate::sparse::{Coo, Csr, Format, SpMv};
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Compile knobs assumed by the telemetry energy model (the artifact
/// default: mid TB size, no register cap pressure, default carve-out).
const MODEL_TB_SIZE: u32 = 256;
const MODEL_MAXRREGCOUNT: u32 = 64;

/// Messages a shard understands.
pub(crate) enum ShardMsg {
    Register { id: u64, coo: Coo, iterations_hint: u64, ack: Sender<Result<Format>> },
    Product(Job),
    Status(Sender<ShardStatus>),
    Shutdown,
}

/// Occupancy summary a shard reports to [`super::Pool::stats`].
#[derive(Debug, Clone, Copy)]
pub struct ShardStatus {
    pub registered: usize,
    pub cached: usize,
    /// Backend actually built ("pjrt" or "native") — a shard degrades
    /// to native when PJRT init fails, and reports say so.
    pub backend: &'static str,
}

/// Per-shard immutable configuration (built by the pool).
#[derive(Clone)]
pub(crate) struct ShardCfg {
    pub convert: ConvertParams,
    pub batch_window: Duration,
    pub max_batch: usize,
    pub cache_capacity: usize,
    pub arch: GpuArch,
}

/// Handle to a running shard.
pub(crate) struct Shard {
    pub tx: Sender<ShardMsg>,
    join: Option<JoinHandle<()>>,
}

impl Shard {
    pub(crate) fn spawn(
        index: usize,
        router: Arc<RunTimeOptimizer>,
        backend: BackendSpec,
        cfg: ShardCfg,
        telemetry: Arc<Telemetry>,
    ) -> Shard {
        let (tx, rx) = channel::<ShardMsg>();
        let join = std::thread::Builder::new()
            .name(format!("serve-shard-{index}"))
            .spawn(move || {
                let backend = match backend.build() {
                    Ok(b) => b,
                    Err(e) => {
                        eprintln!(
                            "serve-shard-{index}: backend init failed, falling back to native: {e:#}"
                        );
                        Backend::Native
                    }
                };
                worker_loop(rx, router, backend, cfg, telemetry)
            })
            .expect("spawn serving shard");
        Shard { tx, join: Some(join) }
    }

    /// Ask the worker to exit and join it (used by the pool's Drop).
    pub(crate) fn shutdown(&mut self) {
        let _ = self.tx.send(ShardMsg::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// A registered matrix: retained CSR source + routing decision + the
/// telemetry handle resolved once so the hot path is lock-free.
struct Registered {
    csr: Csr,
    format: Format,
    converted: bool,
    tele: Arc<MatrixTelemetry>,
    energy_per_req_j: f64,
}

/// A cache entry: the converted form, plus PJRT-marshalled literals
/// when the backend compiles artifacts.
struct CachedMatrix {
    matrix: AnyFormat,
    prepared: Option<PreparedSpmv>,
}

fn worker_loop(
    rx: Receiver<ShardMsg>,
    router: Arc<RunTimeOptimizer>,
    mut backend: Backend,
    cfg: ShardCfg,
    telemetry: Arc<Telemetry>,
) {
    let mut registry: HashMap<u64, Registered> = HashMap::new();
    let mut cache: Lru<CachedMatrix> = Lru::new(cfg.cache_capacity);
    let mut backlog: VecDeque<ShardMsg> = VecDeque::new();
    loop {
        let msg = match backlog.pop_front() {
            Some(m) => m,
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => break, // pool dropped
            },
        };
        match msg {
            ShardMsg::Shutdown => break,
            ShardMsg::Status(reply) => {
                let _ = reply.send(ShardStatus {
                    registered: registry.len(),
                    cached: cache.len(),
                    backend: backend.name(),
                });
            }
            ShardMsg::Register { id, coo, iterations_hint, ack } => {
                let result = do_register(
                    &router,
                    &mut backend,
                    &cfg,
                    &telemetry,
                    &mut registry,
                    &mut cache,
                    id,
                    coo,
                    iterations_hint,
                );
                let _ = ack.send(result);
            }
            ShardMsg::Product(job) => {
                let batch = collect_batch(job, &rx, &mut backlog, cfg.batch_window, cfg.max_batch);
                for (id, jobs) in group_by_matrix(batch) {
                    execute_group(&mut backend, &cfg, &telemetry, &registry, &mut cache, id, jobs);
                }
            }
        }
    }
}

/// Convert (and, on PJRT, marshal) a registered matrix for execution.
fn build_cached(
    backend: &mut Backend,
    csr: &Csr,
    format: Format,
    params: ConvertParams,
) -> Result<CachedMatrix> {
    let matrix = convert::convert(csr, format, params);
    let prepared = match backend {
        Backend::Pjrt(engine) => Some(engine.prepare(&matrix, None)?),
        Backend::Native => None,
    };
    Ok(CachedMatrix { matrix, prepared })
}

#[allow(clippy::too_many_arguments)] // worker-local state is deliberately split for borrow granularity
fn do_register(
    router: &RunTimeOptimizer,
    backend: &mut Backend,
    cfg: &ShardCfg,
    telemetry: &Telemetry,
    registry: &mut HashMap<u64, Registered>,
    cache: &mut Lru<CachedMatrix>,
    id: u64,
    coo: Coo,
    iterations_hint: u64,
) -> Result<Format> {
    let decision = router.decide(&coo, iterations_hint);
    let csr = convert::coo_to_csr(&coo);
    let (format, converted) = if decision.convert {
        (decision.predicted_format, true)
    } else {
        (Format::Csr, false)
    };

    // Model the per-product power/energy once, at registration — the
    // gpusim stand-in for the paper's power sensor (§6.3), threaded
    // through the request path via telemetry.
    let (model_power_w, model_energy_j) = if csr.vals.is_empty() {
        (0.0, 0.0)
    } else {
        let prof = crate::gpusim::profile(&csr, format, cfg.convert);
        let knobs = KernelConfig {
            format,
            tb_size: MODEL_TB_SIZE,
            maxrregcount: MODEL_MAXRREGCOUNT,
            mem: MemConfig::Default,
        };
        let (m, _) = simulate(&cfg.arch, &prof, &knobs);
        (m.avg_power_w, m.energy_j)
    };
    // Build (convert + marshal) BEFORE any telemetry side effects, so a
    // failed registration leaves no phantom stats row or counter bump.
    let entry = build_cached(backend, &csr, format, cfg.convert)?;

    let tele = telemetry.handle(id);
    tele.configure(format, model_power_w, model_energy_j);
    if converted {
        telemetry.totals.conversions.fetch_add(1, Ordering::Relaxed);
    }
    if cache.insert(id, entry).is_some() {
        telemetry.totals.evictions.fetch_add(1, Ordering::Relaxed);
    }
    registry.insert(
        id,
        Registered { csr, format, converted, tele, energy_per_req_j: model_energy_j },
    );
    Ok(format)
}

/// Execute one coalesced group of requests for a single matrix as ONE
/// `spmv_batch` dispatch.
fn execute_group(
    backend: &mut Backend,
    cfg: &ShardCfg,
    telemetry: &Telemetry,
    registry: &HashMap<u64, Registered>,
    cache: &mut Lru<CachedMatrix>,
    id: u64,
    jobs: Vec<Job>,
) {
    let Some(reg) = registry.get(&id) else {
        for job in jobs {
            let _ = job.reply.send(Err(anyhow!("unknown matrix id {id}")));
        }
        return;
    };

    // Validate lengths up front: malformed requests error individually
    // and never poison the batch.
    let n_cols = reg.csr.n_cols;
    let mut xs: Vec<Vec<f32>> = Vec::with_capacity(jobs.len());
    let mut clients = Vec::with_capacity(jobs.len());
    for job in jobs {
        if job.x.len() != n_cols {
            let _ = job
                .reply
                .send(Err(anyhow!("x length {} != n_cols {}", job.x.len(), n_cols)));
        } else {
            xs.push(job.x);
            clients.push((job.enqueued, job.reply));
        }
    }
    if xs.is_empty() {
        return;
    }

    // Conversion cache: a miss here means the entry was evicted since
    // registration — re-convert from the retained CSR source. touch +
    // mru (instead of two `get`s) keeps the hit path at one scan.
    if !cache.touch(id) {
        telemetry.totals.reconversions.fetch_add(1, Ordering::Relaxed);
        match build_cached(backend, &reg.csr, reg.format, cfg.convert) {
            Ok(entry) => {
                if cache.insert(id, entry).is_some() {
                    telemetry.totals.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(e) => {
                let msg = format!("re-convert matrix {id}: {e:#}");
                for (_, reply) in clients {
                    let _ = reply.send(Err(anyhow!("{msg}")));
                }
                return;
            }
        }
    }
    let cached = match cache.mru() {
        Some((key, entry)) if *key == id => entry,
        _ => unreachable!("touch/insert just made matrix {id} the MRU entry"),
    };

    // One dispatch for the whole group.
    let result: Result<Vec<Vec<f32>>> = match backend {
        Backend::Native => Ok(cached.matrix.as_spmv().spmv_batch(&xs)),
        Backend::Pjrt(engine) => match &cached.prepared {
            Some(prep) => engine.spmv_batch_prepared(prep, &xs),
            None => xs.iter().map(|x| engine.spmv(&cached.matrix, x, None)).collect(),
        },
    };

    let batch_size = xs.len();
    match result {
        Ok(ys) => {
            let totals = &telemetry.totals;
            totals.dispatches.fetch_add(1, Ordering::Relaxed);
            totals.requests.fetch_add(batch_size as u64, Ordering::Relaxed);
            totals.max_batch.fetch_max(batch_size as u64, Ordering::Relaxed);
            if batch_size > 1 {
                totals.coalesced_batches.fetch_add(1, Ordering::Relaxed);
                totals.batched_requests.fetch_add(batch_size as u64, Ordering::Relaxed);
            }
            for ((enqueued, reply), y) in clients.into_iter().zip(ys) {
                let service_time = enqueued.elapsed();
                reg.tele.record(service_time);
                let _ = reply.send(Ok(Response {
                    y,
                    format_used: reg.format,
                    converted: reg.converted,
                    service_time,
                    batch_size,
                    energy_j: reg.energy_per_req_j,
                }));
            }
        }
        Err(e) => {
            let msg = format!("execute batch for matrix {id}: {e:#}");
            for (_, reply) in clients {
                let _ = reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}
