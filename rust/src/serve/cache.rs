//! Bounded LRU cache for converted matrices.
//!
//! Conversion targets (padded ELL/SELL/BELL forms) can be far larger
//! than the CSR source, and the original serving loop kept every one of
//! them forever in a per-worker `HashMap`. Each shard instead holds the
//! converted forms in this LRU: capacity is a hard bound, eviction
//! returns the victim so the shard can account for it, and a
//! post-eviction miss re-converts from the retained CSR source.
//!
//! Implementation note: a recency-ordered `Vec` (most recent last) —
//! O(capacity) per touch, which is exact and cache-friendly at serving
//! cache sizes (tens of entries), and has no dependency footprint.

/// A tiny exact LRU. The key is generic (`Copy + PartialEq`): the
/// serving shards key by `(matrix id, format class)` so a bandit-
/// explored conversion caches alongside the router-chosen one without
/// displacing it under the same key.
pub struct Lru<K: Copy + PartialEq, V> {
    cap: usize,
    /// Recency order: least-recently-used first, most-recent last.
    entries: Vec<(K, V)>,
}

impl<K: Copy + PartialEq, V> Lru<K, V> {
    /// Create with `cap` slots (at least 1).
    pub fn new(cap: usize) -> Self {
        Lru { cap: cap.max(1), entries: Vec::new() }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, key: K) -> bool {
        self.entries.iter().any(|(k, _)| *k == key)
    }

    /// Look up and mark as most-recently used.
    pub fn get(&mut self, key: K) -> Option<&V> {
        if self.touch(key) {
            self.mru().map(|(_, v)| v)
        } else {
            None
        }
    }

    /// Mark a key most-recently used without returning it; `true` on a
    /// hit. Paired with [`Lru::mru`], this lets a caller do a single
    /// scan for the get-or-insert pattern (a plain `get` can't span an
    /// insert under the borrow checker).
    pub fn touch(&mut self, key: K) -> bool {
        match self.entries.iter().position(|(k, _)| *k == key) {
            Some(idx) => {
                self.entries[idx..].rotate_left(1);
                true
            }
            None => false,
        }
    }

    /// The most-recently-used entry (what [`Lru::touch`] or
    /// [`Lru::insert`] just placed).
    pub fn mru(&self) -> Option<&(K, V)> {
        self.entries.last()
    }

    /// Insert (or replace) a value, marking it most-recently used.
    /// Returns the evicted least-recently-used entry, if the insert
    /// pushed the cache past capacity.
    pub fn insert(&mut self, key: K, value: V) -> Option<(K, V)> {
        self.insert_protected(key, value, |_| false)
    }

    /// Insert like [`Lru::insert`], but when eviction is needed the
    /// victim is the least-recently-used entry whose key FAILS
    /// `protect`; only when every entry is protected does it fall back
    /// to the plain LRU victim. The serving shards use this for
    /// bandit-explored counterfactual builds, which must not evict a
    /// registered matrix's chosen serving variant.
    pub fn insert_protected(
        &mut self,
        key: K,
        value: V,
        protect: impl Fn(&K) -> bool,
    ) -> Option<(K, V)> {
        if let Some(idx) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries.remove(idx);
            self.entries.push((key, value));
            return None;
        }
        let evicted = if self.entries.len() == self.cap {
            let victim = self.entries.iter().position(|(k, _)| !protect(k)).unwrap_or(0);
            Some(self.entries.remove(victim))
        } else {
            None
        };
        self.entries.push((key, value));
        evicted
    }

    /// Keys in recency order (least-recently-used first); test aid.
    pub fn keys(&self) -> Vec<K> {
        self.entries.iter().map(|(k, _)| *k).collect()
    }

    /// Drop every entry whose key fails the predicate, preserving
    /// recency order of the survivors. Used on re-registration: all of
    /// a matrix's per-format entries must go, not just the chosen one.
    pub fn retain(&mut self, mut keep: impl FnMut(&K) -> bool) {
        self.entries.retain(|(k, _)| keep(k));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_is_honored_and_lru_entry_evicted() {
        let mut lru = Lru::new(2);
        assert!(lru.insert(1, "a").is_none());
        assert!(lru.insert(2, "b").is_none());
        assert_eq!(lru.len(), 2);
        // 3 evicts 1 (the least recently used)
        let evicted = lru.insert(3, "c").expect("must evict");
        assert_eq!(evicted.0, 1);
        assert_eq!(lru.len(), 2);
        assert!(!lru.contains(1));
        assert!(lru.contains(2) && lru.contains(3));
    }

    #[test]
    fn get_refreshes_recency() {
        let mut lru = Lru::new(2);
        lru.insert(1, 10);
        lru.insert(2, 20);
        assert_eq!(lru.get(1), Some(&10)); // 1 becomes most-recent
        let evicted = lru.insert(3, 30).expect("must evict");
        assert_eq!(evicted.0, 2, "2 is now the LRU entry");
        assert_eq!(lru.keys(), vec![1, 3]);
    }

    #[test]
    fn reinsert_replaces_without_eviction() {
        let mut lru = Lru::new(2);
        lru.insert(1, 10);
        lru.insert(2, 20);
        assert!(lru.insert(1, 11).is_none());
        assert_eq!(lru.len(), 2);
        assert_eq!(lru.get(1), Some(&11));
    }

    #[test]
    fn touch_and_mru_implement_single_scan_get_or_insert() {
        let mut lru = Lru::new(2);
        assert!(!lru.touch(1), "miss on empty");
        lru.insert(1, 10);
        lru.insert(2, 20);
        assert_eq!(lru.mru(), Some(&(2, 20)));
        assert!(lru.touch(1), "hit refreshes recency");
        assert_eq!(lru.mru(), Some(&(1, 10)));
        assert_eq!(lru.keys(), vec![2, 1]);
        assert!(!lru.touch(9));
    }

    #[test]
    fn retain_drops_matching_entries_and_keeps_order() {
        let mut lru: Lru<(u64, u8), i32> = Lru::new(8);
        lru.insert((1, 0), 10);
        lru.insert((2, 0), 20);
        lru.insert((1, 1), 11);
        lru.insert((2, 3), 23);
        lru.retain(|k| k.0 != 1);
        assert_eq!(lru.keys(), vec![(2, 0), (2, 3)]);
        assert!(!lru.contains((1, 0)) && !lru.contains((1, 1)));
    }

    #[test]
    fn composite_keys_keep_per_format_entries_distinct() {
        // the shard's keying: (matrix id, format class)
        let mut lru: Lru<(u64, u8), &str> = Lru::new(3);
        lru.insert((7, 0), "csr");
        lru.insert((7, 1), "ell");
        lru.insert((9, 0), "csr");
        assert_eq!(lru.len(), 3);
        assert_eq!(lru.get((7, 0)), Some(&"csr"));
        assert_eq!(lru.get((7, 1)), Some(&"ell"));
        let evicted = lru.insert((9, 3), "sell").expect("capacity 3");
        assert_eq!(evicted.0, (9, 0), "LRU entry goes first");
    }

    #[test]
    fn insert_protected_skips_protected_victims() {
        let mut lru = Lru::new(2);
        lru.insert(1, "chosen");
        lru.insert(2, "scratch");
        // 1 is the LRU victim, but it is protected: 2 must go instead
        let evicted = lru.insert_protected(3, "scratch2", |k| *k == 1).expect("full");
        assert_eq!(evicted.0, 2);
        assert!(lru.contains(1) && lru.contains(3));
        // when EVERY entry is protected, fall back to the plain LRU victim
        let evicted = lru.insert_protected(4, "x", |_| true).expect("full");
        assert_eq!(evicted.0, 1, "all-protected falls back to LRU order");
        // replacing an existing key never evicts
        assert!(lru.insert_protected(4, "y", |_| false).is_none());
        assert_eq!(lru.get(4), Some(&"y"));
        assert_eq!(lru.len(), 2);
    }

    #[test]
    fn missing_key_is_none_and_zero_capacity_clamps_to_one() {
        let mut lru = Lru::new(0);
        assert_eq!(lru.capacity(), 1);
        assert!(lru.is_empty());
        assert_eq!(lru.get(9), None);
        lru.insert(1, 1);
        let evicted = lru.insert(2, 2).expect("single slot");
        assert_eq!(evicted, (1, 1));
    }
}
