//! Execution backends for the serving shards.
//!
//! The PJRT client (and its compiled executables) are not `Send`, so a
//! [`BackendSpec`] — which is `Send + Clone` — crosses the thread
//! boundary and each shard builds its own [`Backend`] on startup.

use crate::runtime::Engine;
use anyhow::Result;
use std::path::PathBuf;

/// How products are executed. Each shard constructs its own backend
/// from this spec.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    /// AOT-compiled kernels through PJRT (the production path); the
    /// payload is the artifact directory.
    Pjrt(PathBuf),
    /// Native Rust SpMV (testing / environments without artifacts).
    Native,
}

impl BackendSpec {
    pub fn name(&self) -> &'static str {
        match self {
            BackendSpec::Pjrt(_) => "pjrt",
            BackendSpec::Native => "native",
        }
    }

    pub(crate) fn build(&self) -> Result<Backend> {
        match self {
            BackendSpec::Pjrt(dir) => Ok(Backend::Pjrt(Box::new(Engine::new(dir)?))),
            BackendSpec::Native => Ok(Backend::Native),
        }
    }
}

/// A shard-owned executor (intentionally not `Send`: it may hold PJRT
/// handles).
pub(crate) enum Backend {
    Pjrt(Box<Engine>),
    Native,
}

impl Backend {
    /// The backend actually built — can differ from the requested
    /// [`BackendSpec`] when PJRT init fails and the shard degrades to
    /// native; pool stats report this so output is never mislabeled.
    pub(crate) fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt(_) => "pjrt",
            Backend::Native => "native",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_spec_builds() {
        assert!(matches!(BackendSpec::Native.build(), Ok(Backend::Native)));
        assert_eq!(BackendSpec::Native.name(), "native");
    }

    #[test]
    fn pjrt_spec_without_artifacts_is_an_error() {
        let spec = BackendSpec::Pjrt(PathBuf::from("/nonexistent/artifacts"));
        assert_eq!(spec.name(), "pjrt");
        assert!(spec.build().is_err());
    }
}
