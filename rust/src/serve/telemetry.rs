//! Serving telemetry: per-matrix latency histograms + modeled energy.
//!
//! The hot path must not serialize shards, so everything a worker
//! touches per request is an atomic on an `Arc<MatrixTelemetry>` handle
//! the shard resolves once at registration ("lock-free-ish": the only
//! lock is the registry `RwLock`, taken on handle lookup, never per
//! request). Latencies land in a log2-bucketed histogram, so quantiles
//! come out of 48 counters instead of an unbounded sample buffer; the
//! energy ledger accumulates the `gpusim`-modeled joules per product
//! (paper §6.3's objective, finally visible at serve time). Routing
//! decisions are counted per format class, split chosen vs. explored,
//! AND per quantized compile-knob arm, so both halves of the joint
//! (format, knob) loop's traffic — including counterfactuals — are
//! observable.

use crate::coordinator::compile_time::CompileChoice;
use crate::obs::hist::Hist;
use crate::obs::{ArmAttr, Journal, SloEngine, StageHists, DEFAULT_JOURNAL_CAP};
use crate::online::bandit::{knob_arm, knob_index};
use crate::online::JointDecision;
use crate::sparse::Format;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// Number of format classes ([`Format::ALL`]).
const N_FORMATS: usize = Format::ALL.len();

/// Number of quantized knob arms ([`crate::online::bandit::N_KNOBS`]).
const N_KNOBS: usize = crate::online::bandit::N_KNOBS;

const FORMAT_UNSET: u64 = u64::MAX;
const KNOB_UNSET: u64 = u64::MAX;

/// Compact u64 encoding of a knob choice (atomic-slot friendly).
fn encode_choice(c: CompileChoice) -> u64 {
    ((c.tb_size as u64) << 16) | ((c.maxrregcount as u64) << 4) | c.mem.class_id() as u64
}

fn decode_choice(bits: u64) -> Option<CompileChoice> {
    if bits == KNOB_UNSET {
        return None;
    }
    Some(CompileChoice {
        tb_size: (bits >> 16) as u32,
        maxrregcount: ((bits >> 4) & 0xFFF) as u32,
        mem: crate::gpusim::MemConfig::from_class_id((bits & 0xF) as usize)?,
    })
}

/// Per-matrix counters; every field is an atomic so shards record
/// without locking.
pub struct MatrixTelemetry {
    /// `Format::class_id` of the serving format, or FORMAT_UNSET.
    format_class: AtomicU64,
    /// [`encode_choice`] of the serving knob decision, or KNOB_UNSET.
    knob_bits: AtomicU64,
    /// End-to-end service latency (log2 buckets, see [`crate::obs::hist`]).
    lat: Hist,
    /// Accumulated modeled energy (nanojoules).
    energy_nj: AtomicU64,
    /// Modeled average power draw (f64 bits), set at registration.
    model_power_w_bits: AtomicU64,
    /// Requests dispatched per format class on the router's decision.
    chosen: [AtomicU64; N_FORMATS],
    /// Requests dispatched per format class by bandit exploration.
    explored: [AtomicU64; N_FORMATS],
    /// Requests dispatched per quantized knob arm (chosen + explored).
    by_knob: [AtomicU64; N_KNOBS],
}

impl MatrixTelemetry {
    fn new() -> Self {
        MatrixTelemetry {
            format_class: AtomicU64::new(FORMAT_UNSET),
            knob_bits: AtomicU64::new(KNOB_UNSET),
            lat: Hist::new(),
            energy_nj: AtomicU64::new(0),
            model_power_w_bits: AtomicU64::new(0f64.to_bits()),
            chosen: std::array::from_fn(|_| AtomicU64::new(0)),
            explored: std::array::from_fn(|_| AtomicU64::new(0)),
            by_knob: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Install the registration-time (or post-migration) model: the
    /// serving format and knob decision plus the simulated power draw
    /// of one product on the deployment profile.
    pub fn configure(&self, format: Format, choice: CompileChoice, model_power_w: f64) {
        self.format_class.store(format.class_id() as u64, Ordering::Relaxed);
        self.knob_bits.store(encode_choice(choice), Ordering::Relaxed);
        self.model_power_w_bits.store(model_power_w.to_bits(), Ordering::Relaxed);
    }

    /// Record one served product and its modeled energy. Energy is
    /// per-request so explored dispatches charge their own format's
    /// cost, not the registered one's.
    pub fn record(&self, latency: Duration, energy_j: f64) {
        self.lat.record(latency);
        self.energy_nj.fetch_add((energy_j * 1e9).round().max(0.0) as u64, Ordering::Relaxed);
    }

    /// Count a routing decision for `requests` coalesced products.
    pub fn route(&self, decision: JointDecision, explored: bool, requests: u64) {
        let side = if explored { &self.explored } else { &self.chosen };
        side[decision.format.class_id()].fetch_add(requests, Ordering::Relaxed);
        self.by_knob[knob_index(decision.choice)].fetch_add(requests, Ordering::Relaxed);
    }

    fn snapshot(&self, id: u64) -> MatrixStats {
        let lat = self.lat.snapshot();
        let class = self.format_class.load(Ordering::Relaxed);
        // Quantiles are clamped to the observed max inside the snapshot
        // (`p99 <= max` in every report), None on an empty histogram,
        // and tail quantiles are None on a single sample — one
        // observation supports a median, not a p99.
        MatrixStats {
            id,
            format: if class == FORMAT_UNSET {
                None
            } else {
                Format::from_class_id(class as usize)
            },
            knobs: decode_choice(self.knob_bits.load(Ordering::Relaxed)),
            requests: lat.count,
            mean_us: lat.mean_us(),
            p50_us: lat.quantile_us(0.50),
            p90_us: lat.tail_quantile_us(0.90),
            p99_us: lat.tail_quantile_us(0.99),
            max_us: lat.max_us(),
            total_latency: Duration::from_nanos(lat.sum_ns),
            max_latency: Duration::from_nanos(lat.max_ns),
            energy_j: self.energy_nj.load(Ordering::Relaxed) as f64 * 1e-9,
            model_power_w: f64::from_bits(self.model_power_w_bits.load(Ordering::Relaxed)),
            chosen_by_format: std::array::from_fn(|i| self.chosen[i].load(Ordering::Relaxed)),
            explored_by_format: std::array::from_fn(|i| self.explored[i].load(Ordering::Relaxed)),
            by_knob: std::array::from_fn(|i| self.by_knob[i].load(Ordering::Relaxed)),
        }
    }
}

impl Default for MatrixTelemetry {
    fn default() -> Self {
        Self::new()
    }
}

/// One matrix's serving statistics (a [`Pool::stats`] row).
///
/// [`Pool::stats`]: crate::serve::Pool::stats
#[derive(Debug, Clone)]
pub struct MatrixStats {
    pub id: u64,
    /// Serving format (None if telemetry was created but never
    /// configured by a registration).
    pub format: Option<Format>,
    /// Serving compile-knob decision (None before configuration).
    pub knobs: Option<CompileChoice>,
    pub requests: u64,
    pub mean_us: f64,
    /// Latency quantiles; `None` when the histogram cannot support the
    /// estimate (empty, or a single sample for the tail quantiles).
    pub p50_us: Option<f64>,
    pub p90_us: Option<f64>,
    pub p99_us: Option<f64>,
    pub max_us: f64,
    pub total_latency: Duration,
    pub max_latency: Duration,
    /// Total modeled energy spent serving this matrix (joules).
    pub energy_j: f64,
    /// Modeled average power of one product (watts).
    pub model_power_w: f64,
    /// Requests dispatched per format class (`Format::ALL` order) on
    /// the router's decision...
    pub chosen_by_format: [u64; N_FORMATS],
    /// ...vs. routed off-policy by the exploration bandit.
    pub explored_by_format: [u64; N_FORMATS],
    /// Requests dispatched per quantized knob arm
    /// ([`crate::online::bandit::knob_arm`] order, chosen + explored).
    pub by_knob: [u64; N_KNOBS],
}

impl MatrixStats {
    /// Requests served off the predicted path.
    pub fn explored(&self) -> u64 {
        self.explored_by_format.iter().sum()
    }

    /// Requests served under a non-default knob decision.
    pub fn non_default_knob_requests(&self) -> u64 {
        let default = knob_index(CompileChoice::serving_default());
        self.by_knob
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != default)
            .map(|(_, c)| *c)
            .sum()
    }

    /// Compact "tb/r/mem:count" rendering of the knob-decision mix
    /// (report/CLI aid). Example: `tb256/r64/default:12 tb64/r32/prefer_l1:3`.
    pub fn knob_decisions(&self) -> String {
        let parts: Vec<String> = self
            .by_knob
            .iter()
            .enumerate()
            .filter(|(_, c)| **c > 0)
            .map(|(i, c)| format!("{}:{c}", knob_arm(i)))
            .collect();
        if parts.is_empty() {
            "-".to_string()
        } else {
            parts.join(" ")
        }
    }

    /// Compact "fmt:count" rendering of the decision mix, explored arms
    /// starred (report/CLI aid). Example: `ell:120 csr*:3 sell*:2`.
    pub fn decisions(&self) -> String {
        let mut parts = Vec::new();
        for f in Format::ALL {
            let c = self.chosen_by_format[f.class_id()];
            if c > 0 {
                parts.push(format!("{f}:{c}"));
            }
        }
        for f in Format::ALL {
            let e = self.explored_by_format[f.class_id()];
            if e > 0 {
                parts.push(format!("{f}*:{e}"));
            }
        }
        if parts.is_empty() {
            "-".to_string()
        } else {
            parts.join(" ")
        }
    }
}

/// Pool-wide counters (all relaxed atomics; exact under quiescence,
/// monotone always).
#[derive(Default)]
pub struct Counters {
    pub requests: AtomicU64,
    /// Kernel dispatches (one per executed batch, coalesced or not).
    pub dispatches: AtomicU64,
    /// Kernel LAUNCHES. A native or SpMM-artifact dispatch serves its
    /// whole batch in one launch per bucket chunk; the per-vector
    /// prepared fallback pays one launch per request. `launches /
    /// requests < 1` is the direct evidence batching amortizes the
    /// matrix stream.
    pub launches: AtomicU64,
    /// Dispatches that executed through a true SpMM path (native
    /// one-matrix-walk or a multi-vector PJRT artifact).
    pub spmm_dispatches: AtomicU64,
    /// Dispatches that served more than one request.
    pub coalesced_batches: AtomicU64,
    /// Requests served by coalesced dispatches.
    pub batched_requests: AtomicU64,
    /// Largest batch executed so far.
    pub max_batch: AtomicU64,
    /// Registrations where the router converted away from CSR.
    pub conversions: AtomicU64,
    /// Conversion-cache misses on the product path (post-eviction).
    pub reconversions: AtomicU64,
    /// Conversion-cache evictions.
    pub evictions: AtomicU64,
    /// Requests the bandit routed to a non-predicted arm.
    pub explored_requests: AtomicU64,
    /// Registered matrices whose format changed on a router hot-swap.
    pub migrations: AtomicU64,
    /// Registered matrices whose compile-knob decision changed on a
    /// router hot-swap (re-selected artifacts / re-prepared literals;
    /// counted independently of format migrations).
    pub knob_migrations: AtomicU64,
    /// Vector bytes that crossed the host/device boundary at dispatch:
    /// the per-request path charges `4*(n_cols + n_rows)` per served
    /// product (x in, y out), a session charges `4*n` only on explicit
    /// `write` / `read`. Backend-uniform — on native backends this is
    /// the bytes copied into/out of the pool's dispatch layer.
    pub marshalled_bytes: AtomicU64,
    /// Vector bytes a session step did NOT move because the vector
    /// stayed resident (`4*(n_cols + n_rows)` per pure chained step —
    /// exactly what the per-request path would have charged).
    pub elided_bytes: AtomicU64,
    /// Host round-trips elided: pure session steps that fed y back as
    /// the next x without surfacing it.
    pub round_trips_elided: AtomicU64,
    /// Iterative-session products served (each also counts in
    /// `requests`/`dispatches`/`launches`).
    pub session_steps: AtomicU64,
    /// Sessions opened over the pool's lifetime.
    pub sessions_opened: AtomicU64,
    /// Requests that carried a deadline tag (SLO seed, ROADMAP
    /// scale-out item).
    pub deadline_tagged: AtomicU64,
    /// Deadline-tagged requests whose service time exceeded the tag.
    pub deadline_misses: AtomicU64,
    /// Requests rejected at admission (never enqueued; not counted in
    /// `requests`). Split by reason below.
    pub sheds: AtomicU64,
    /// Sheds because the admission queue was over capacity under SLO
    /// pressure.
    pub sheds_overloaded: AtomicU64,
    /// Sheds because the deadline budget was already gone (expired, or
    /// below the predicted queue wait).
    pub sheds_deadline: AtomicU64,
    /// Requests routed off their hash-home shard to a less-loaded
    /// replica.
    pub reroutes: AtomicU64,
    /// Hot-matrix replica registrations performed by the control plane.
    pub replications: AtomicU64,
    /// Replica deregistrations after a matrix cooled.
    pub unreplications: AtomicU64,
}

/// The shared registry: matrix id -> telemetry handle, plus the
/// pool-wide stage histograms, per-arm cost attribution, the optional
/// SLO engine, and the control-plane event journal handle shards emit
/// through.
pub struct Telemetry {
    matrices: RwLock<HashMap<u64, Arc<MatrixTelemetry>>>,
    pub totals: Counters,
    /// Per-stage latency histograms (request-lifecycle tracing).
    pub stages: StageHists,
    /// Per-(format × knob-arm) latency/energy attribution (always on —
    /// a few relaxed atomic adds per dispatch).
    pub arms: ArmAttr,
    /// SLO engine, present only when the pool was configured with one.
    slo: Option<Arc<SloEngine>>,
    journal: Arc<Journal>,
}

impl Telemetry {
    pub fn new() -> Self {
        Telemetry::with_journal(Arc::new(Journal::new(DEFAULT_JOURNAL_CAP)))
    }

    /// Share an existing journal (the pool passes the router's so
    /// shard-side events interleave with hot-swap/retrain events in
    /// one sequence).
    pub fn with_journal(journal: Arc<Journal>) -> Self {
        Telemetry {
            matrices: RwLock::new(HashMap::new()),
            totals: Counters::default(),
            stages: StageHists::new(),
            arms: ArmAttr::new(),
            slo: None,
            journal,
        }
    }

    /// Like [`Telemetry::with_journal`], plus an SLO engine shards feed
    /// per served request.
    pub fn with_slo(journal: Arc<Journal>, engine: Arc<SloEngine>) -> Self {
        let mut t = Telemetry::with_journal(journal);
        t.slo = Some(engine);
        t
    }

    /// The SLO engine, if the pool runs with one.
    pub fn slo(&self) -> Option<&Arc<SloEngine>> {
        self.slo.as_ref()
    }

    /// The control-plane event journal.
    pub fn journal(&self) -> &Arc<Journal> {
        &self.journal
    }

    /// Get-or-create the handle for a matrix. Shards call this once per
    /// registration and cache the `Arc`; the per-request path is pure
    /// atomics on the handle.
    pub fn handle(&self, id: u64) -> Arc<MatrixTelemetry> {
        if let Some(t) = self.matrices.read().expect("telemetry lock").get(&id) {
            return t.clone();
        }
        self.matrices
            .write()
            .expect("telemetry lock")
            .entry(id)
            .or_insert_with(|| Arc::new(MatrixTelemetry::new()))
            .clone()
    }

    /// Consistent-enough snapshot of every matrix's stats, by id.
    pub fn snapshot(&self) -> Vec<MatrixStats> {
        let map = self.matrices.read().expect("telemetry lock");
        let mut rows: Vec<MatrixStats> = map.iter().map(|(id, t)| t.snapshot(*id)).collect();
        rows.sort_by_key(|r| r.id);
        rows
    }
}

impl Default for Telemetry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_encoding_roundtrips() {
        use crate::gpusim::{MemConfig, MAXRREGCOUNT, TB_SIZES};
        for &tb in &TB_SIZES {
            for &regs in &MAXRREGCOUNT {
                for &mem in &MemConfig::ALL {
                    let c = CompileChoice { tb_size: tb, maxrregcount: regs, mem };
                    assert_eq!(decode_choice(encode_choice(c)), Some(c));
                }
            }
        }
        assert_eq!(decode_choice(KNOB_UNSET), None);
    }

    #[test]
    fn record_accumulates_and_quantiles_are_ordered() {
        let t = MatrixTelemetry::new();
        t.configure(Format::Ell, CompileChoice::serving_default(), 12.5);
        for us in [5u64, 10, 20, 40, 80, 160, 320, 640, 1280, 2560] {
            t.record(Duration::from_micros(us), 3e-6);
        }
        let s = t.snapshot(7);
        assert_eq!(s.id, 7);
        assert_eq!(s.format, Some(Format::Ell));
        assert_eq!(s.knobs, Some(CompileChoice::serving_default()));
        assert_eq!(s.requests, 10);
        assert!(s.mean_us > 0.0);
        let (p50, p90, p99) = (s.p50_us.unwrap(), s.p90_us.unwrap(), s.p99_us.unwrap());
        assert!(p50 <= p90 && p90 <= p99, "{s:?}");
        assert!(p99 <= s.max_us, "quantiles are clamped to the observed max: {s:?}");
        assert!((s.energy_j - 10.0 * 3e-6).abs() < 1e-9);
        assert!((s.model_power_w - 12.5).abs() < 1e-12);
        assert!(s.total_latency >= s.max_latency);
    }

    #[test]
    fn empty_telemetry_snapshot_is_zeroed_with_no_quantiles() {
        let t = MatrixTelemetry::new();
        let s = t.snapshot(0);
        assert_eq!(s.requests, 0);
        assert_eq!(s.format, None);
        assert_eq!(s.knobs, None);
        assert_eq!(s.p50_us, None);
        assert_eq!(s.p90_us, None);
        assert_eq!(s.p99_us, None);
        assert_eq!(s.energy_j, 0.0);
        assert_eq!(s.explored(), 0);
        assert_eq!(s.decisions(), "-");
        assert_eq!(s.knob_decisions(), "-");
        assert_eq!(s.non_default_knob_requests(), 0);
    }

    #[test]
    fn single_sample_supports_a_median_but_no_tail_quantiles() {
        let t = MatrixTelemetry::new();
        t.record(Duration::from_micros(100), 1e-6);
        let s = t.snapshot(1);
        assert_eq!(s.requests, 1);
        let p50 = s.p50_us.expect("one sample is a median");
        assert!(p50 > 0.0 && p50 <= s.max_us);
        assert_eq!(s.p90_us, None, "a single sample cannot support p90");
        assert_eq!(s.p99_us, None, "a single sample cannot support p99");
    }

    #[test]
    fn route_counts_split_chosen_and_explored_per_format() {
        let t = MatrixTelemetry::new();
        let d = JointDecision::format_only;
        t.route(d(Format::Ell), false, 10);
        t.route(d(Format::Ell), false, 5);
        t.route(d(Format::Csr), true, 2);
        t.route(d(Format::Sell), true, 1);
        let s = t.snapshot(3);
        assert_eq!(s.chosen_by_format[Format::Ell.class_id()], 15);
        assert_eq!(s.explored_by_format[Format::Csr.class_id()], 2);
        assert_eq!(s.explored(), 3);
        assert_eq!(s.decisions(), "ell:15 csr*:2 sell*:1");
        // all 18 requests rode the default knob arm
        assert_eq!(s.by_knob[knob_index(CompileChoice::serving_default())], 18);
        assert_eq!(s.non_default_knob_requests(), 0);
        assert_eq!(s.knob_decisions(), "tb256/r64/default:18");
    }

    #[test]
    fn route_counts_knob_arms() {
        use crate::gpusim::MemConfig;
        let t = MatrixTelemetry::new();
        let alt = CompileChoice { tb_size: 64, maxrregcount: 32, mem: MemConfig::PreferL1 };
        t.route(JointDecision::format_only(Format::Ell), false, 4);
        t.route(JointDecision { format: Format::Ell, choice: alt }, true, 3);
        let s = t.snapshot(9);
        assert_eq!(s.by_knob[knob_index(alt)], 3);
        assert_eq!(s.non_default_knob_requests(), 3);
        let rendered = s.knob_decisions();
        assert!(rendered.contains("tb256/r64/default:4"), "{rendered}");
        assert!(rendered.contains("tb64/r32/prefer_l1:3"), "{rendered}");
    }

    #[test]
    fn registry_hands_out_shared_handles() {
        let reg = Telemetry::new();
        let a = reg.handle(1);
        let b = reg.handle(1);
        assert!(Arc::ptr_eq(&a, &b));
        a.record(Duration::from_micros(3), 0.0);
        let rows = reg.snapshot();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].requests, 1);
        reg.handle(2);
        let rows = reg.snapshot();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].id, 1);
        assert_eq!(rows[1].id, 2);
    }

    #[test]
    fn telemetry_shares_its_journal_and_stage_hists() {
        use crate::obs::{EventKind, Stage};
        let journal = Arc::new(Journal::new(8));
        let t = Telemetry::with_journal(journal.clone());
        t.journal().emit(EventKind::SessionOpen { session: 1, matrix: 0 });
        assert_eq!(journal.len(), 1, "emits land in the shared ring");
        t.stages.record(Stage::Exec, Duration::from_micros(5));
        let stages = t.stages.snapshot();
        let exec = stages.iter().find(|s| s.stage == Stage::Exec).unwrap();
        assert_eq!(exec.count(), 1);
        assert!(Telemetry::new().journal().is_empty(), "private journal by default");
    }

    #[test]
    fn telemetry_with_slo_exposes_the_engine_and_arms() {
        use crate::obs::{SloConfig, SloEngine};
        let journal = Arc::new(Journal::new(8));
        let engine = Arc::new(SloEngine::new(SloConfig::default(), 1, journal.clone()));
        let t = Telemetry::with_slo(journal, engine.clone());
        assert!(Arc::ptr_eq(t.slo().expect("engine installed"), &engine));
        assert!(Telemetry::new().slo().is_none(), "no engine unless configured");
        assert_eq!(t.arms.generation(), 1, "attribution is always on");
    }
}
