//! `serve` — the sharded, batching SpMV serving engine.
//!
//! This subsystem is the deployable face of the paper's run-time mode at
//! scale, replacing the original single-worker loop behind one mpsc
//! channel (`coordinator::service`, now a thin shim over [`Pool`]):
//!
//! * **Sharding** ([`pool`]): N worker threads, matrices partitioned by
//!   id hash. Each worker owns its own backend because the PJRT client
//!   is not `Send`; requests for one matrix always land on the same
//!   shard, so converted forms and prepared literals stay hot.
//! * **Admission + coalescing** ([`batch`]): each shard drains its queue
//!   before executing, groups concurrent requests for the same matrix,
//!   and dispatches one true SpMM per group — the native
//!   [`crate::sparse::SpMv::spmm`] one-matrix-walk, or a multi-vector
//!   SpMM artifact executing the whole batch in ONE kernel launch on
//!   PJRT (per-vector prepared literals remain as the fallback when no
//!   SpMM variant is compiled). An optional admission window holds the
//!   first request briefly so concurrent clients coalesce even on an
//!   idle shard; `PoolStats::launches_per_request` reports the win.
//! * **Bounded conversion cache** ([`cache`]): converted matrices (the
//!   padded ELL/SELL/BELL forms that can dwarf the CSR source) live in a
//!   per-shard LRU with capacity eviction; the registered CSR source is
//!   retained, so a post-eviction request re-converts instead of
//!   failing. The old per-worker `HashMap` grew without bound.
//! * **Telemetry** ([`telemetry`]): a registry of per-matrix atomics —
//!   request counts, log-scale latency histograms (p50/p90/p99), routing
//!   decisions by format (chosen vs. explored), and modeled
//!   energy/power per request from the `gpusim` analytic model —
//!   snapshotted lock-free-ish through [`Pool::stats`].
//! * **Closed loop** (optional, [`crate::online`]): a pool started with
//!   [`Pool::start_adaptive`] consults an exploration bandit per
//!   dispatch, streams observations to a retraining task, and migrates
//!   registered matrices when the versioned router hot-swaps. A pool
//!   started with [`Pool::start`] routes through the same handle but
//!   never swaps it — and is bit-identical to the pre-loop engine.
//! * **Scale-out control plane** (optional, [`PoolConfig::scaleout`]):
//!   the admission path tracks per-matrix traffic in decayed counters,
//!   replicates hot matrices onto additional shards (the conversion
//!   LRU makes copies cheap), routes replicated traffic to the
//!   least-loaded owning shard by queue depth, and — only while the
//!   SLO engine reports Warning/Breach — sheds requests whose deadline
//!   budget is already gone with a typed [`Rejected`] error. An
//!   unloaded pool routes bit-identically to the plain splitmix hash
//!   (DESIGN.md §12).
//! * **Iterative sessions** ([`Pool::open_session`]): the fast path for
//!   chained solvers (CG, power iteration) where each product's output
//!   is the next input. A [`Session`] pins one matrix and keeps the
//!   vector resident across [`Session::step`] calls — device-side via
//!   buffer-identity chaining on PJRT, host-side reuse on native — so a
//!   pure step crosses the host/dispatch boundary zero times; explicit
//!   [`Session::write`]/[`Session::read`] are the escape hatches and
//!   [`Session::power_step`] rides the fused x' = A x / ||A x||
//!   artifact when one is compiled. Session traffic bypasses the
//!   coalescing window but still counts requests/dispatches/launches,
//!   still feeds the closed loop's observations, and defers policy
//!   migrations to session close (DESIGN.md §9).
//!
//! ```no_run
//! # use auto_spmv::serve::{BackendSpec, Pool, PoolConfig};
//! # use auto_spmv::coordinator::{OverheadModel, RunTimeOptimizer};
//! # use auto_spmv::dataset::{build, BuildOptions};
//! # use auto_spmv::gpusim::Objective;
//! # use std::sync::Arc;
//! let ds = build(&BuildOptions::default());
//! let router = RunTimeOptimizer::train(
//!     &ds, Objective::EnergyEff, OverheadModel::train_on_corpus(1, None));
//! let pool = Pool::start(Arc::new(router), BackendSpec::Native, PoolConfig::default());
//! ```

pub mod backend;
pub mod batch;
pub mod cache;
pub mod pool;
pub mod shard;
pub mod telemetry;

pub use backend::BackendSpec;
pub use batch::JobKind;
pub use pool::{Pool, PoolConfig, PoolStats, ScaleOutConfig, Session};
pub use shard::StepOp;
pub use telemetry::{MatrixStats, Telemetry};

use crate::sparse::Format;
use std::fmt;
use std::time::Duration;

/// Typed admission rejection. Only emitted while the pool runs with a
/// [`ScaleOutConfig`] AND its SLO engine reports Warning/Breach — an
/// unloaded pool never sheds. Clients receive it through the normal
/// error channel and can downcast:
/// `err.downcast_ref::<Rejected>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejected {
    /// The bounded admission queue was over capacity under SLO
    /// pressure; retry against another replica or back off.
    Overloaded,
    /// The request's latency budget cannot be met: the deadline already
    /// passed, or the predicted queue wait (stage-histogram estimate)
    /// exceeds the remaining budget.
    DeadlineExceeded,
}

impl Rejected {
    /// Stable snake_case reason tag (journal/metric label).
    pub fn reason(self) -> &'static str {
        match self {
            Rejected::Overloaded => "overloaded",
            Rejected::DeadlineExceeded => "deadline",
        }
    }
}

impl fmt::Display for Rejected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Rejected::Overloaded => write!(f, "rejected: admission queue over capacity"),
            Rejected::DeadlineExceeded => write!(f, "rejected: deadline budget already spent"),
        }
    }
}

impl std::error::Error for Rejected {}

/// Result of one served product.
#[derive(Debug, Clone)]
pub struct Response {
    pub y: Vec<f32>,
    /// Format the product was executed in (an explored dispatch
    /// reports the exploration arm, not the registered format).
    pub format_used: Format,
    /// Whether the product executed in a converted (non-CSR) form.
    pub converted: bool,
    /// End-to-end service time (queue wait + batch execution).
    pub service_time: Duration,
    /// Number of requests coalesced into the dispatch that served this
    /// one (1 = unbatched).
    pub batch_size: usize,
    /// Modeled energy of this product on the configured GPU profile
    /// (joules, `gpusim` analytic model; idle excluded per paper §6.3).
    pub energy_j: f64,
    /// Per-stage decomposition of `service_time` (queue wait, batch
    /// wait, convert, exec, reply marshal — the stages sum exactly to
    /// it). `None` when the pool runs with `PoolConfig::tracing` off.
    pub trace: Option<crate::obs::Trace>,
}
