//! CLI: argument parsing (clap is not in the offline mirror) and the
//! subcommand implementations behind the `auto-spmv` binary.
//!
//! Subcommands:
//!   corpus                      list the 30 corpus matrices + features
//!   gen-dataset                 run the full sweep, save TSV
//!   train [--objective O]       train + report per-target accuracy
//!   optimize --matrix M [...]   run both optimization modes on a matrix
//!   serve [--requests N] [--workers W] [--batch-window-us U]
//!         [--cache-cap C]
//!         [--explore-rate F] [--retrain-every N] [--anneal-target K]
//!         [--joint-knobs true|false]
//!         [--stats-every N] [--metrics-out FILE] [--events-out FILE]
//!         [--slo-p99-us US] [--slo-miss-budget F] [--flight-out FILE]
//!         [--scaleout] [--replicate-share F] [--admission-cap N]
//!                               serving demo over the sharded pool
//!                               (PJRT when artifacts exist, else
//!                               native). A non-zero explore rate or
//!                               retrain cadence attaches the closed
//!                               loop (`online`): bandit exploration,
//!                               drift detection, periodic retraining,
//!                               hot-swapped router. --joint-knobs
//!                               (default on) makes the loop decide
//!                               (format, compile-knob) pairs jointly —
//!                               knob arms explored, per-format knob
//!                               policy retrained, knobs re-decided on
//!                               hot-swap. --seed drives the
//!                               exploration schedule. Observability
//!                               (DESIGN.md §10): --stats-every N
//!                               prints a progress ledger line every N
//!                               completed requests — on STDERR, so
//!                               stdout stays a clean report stream; at
//!                               exit --metrics-out dumps the Prometheus
//!                               text exposition and --events-out the
//!                               control-plane event journal (JSON) —
//!                               the final ledger, journal, and dumps
//!                               are flushed even when the request
//!                               stream fails part-way. SLO engine
//!                               (DESIGN.md §11): --slo-p99-us and/or
//!                               --slo-miss-budget attach an SloConfig
//!                               (the other half defaults to 50ms /
//!                               0.01); --flight-out dumps the trace
//!                               flight recorder (breach capture if one
//!                               fired, else the live ring) as JSON.
//!                               Scale-out control plane (DESIGN.md
//!                               §12): --scaleout (or either tuning
//!                               flag) enables hot-matrix replication,
//!                               least-loaded routing, and SLO-gated
//!                               admission shedding; --replicate-share
//!                               sets the traffic share that triggers
//!                               replication, --admission-cap the
//!                               outstanding-request bound behind
//!                               Overloaded sheds.
//!
//! Global flags: --config FILE, --set key=value (repeatable), and the
//! shorthand --scale/--seed/--objective overrides.

use crate::config::AppConfig;
use crate::coordinator::{CompileTimeOptimizer, OverheadModel, RunTimeOptimizer};
use crate::dataset::{self, labels, store, BuildOptions};
use crate::features;
use crate::gen;
use crate::gpusim::Objective;
use crate::ml::metrics::{accuracy, f1_macro};
use crate::ml::split::{take, take_x, train_test_indices};
use crate::report::{fmt_g, pct_gain, pct_improvement, Table};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Cli {
    pub command: String,
    pub flags: Vec<(String, String)>,
    pub config: AppConfig,
}

/// Parse `args` (without argv[0]).
pub fn parse(args: &[String]) -> Result<Cli> {
    if args.is_empty() {
        bail!("usage: auto-spmv <corpus|gen-dataset|train|optimize|serve> [flags]");
    }
    let command = args[0].clone();
    let mut flags: Vec<(String, String)> = Vec::new();
    let mut overrides: Vec<(String, String)> = Vec::new();
    let mut config_file: Option<PathBuf> = None;
    let mut i = 1;
    while i < args.len() {
        let a = &args[i];
        let Some(key) = a.strip_prefix("--") else {
            bail!("unexpected argument {a}");
        };
        // both spellings: `--key value` and GNU-style `--key=value`
        // (without the split, `--joint-knobs=false` would register an
        // unknown flag and the lookup would fall back to the default)
        let (key, value) = if let Some((k, v)) = key.split_once('=') {
            (k, v.to_string())
        } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            i += 1;
            (key, args[i].clone())
        } else {
            (key, "true".to_string())
        };
        match key {
            "config" => config_file = Some(PathBuf::from(&value)),
            "set" => {
                let (k, v) = value
                    .split_once('=')
                    .context("--set expects key=value")?;
                overrides.push((k.to_string(), v.to_string()));
            }
            "scale" | "seed" | "both_archs" | "automl_trials" | "artifacts_dir"
            | "dataset_path" => overrides.push((key.to_string(), value)),
            _ => flags.push((key.to_string(), value)),
        }
        i += 1;
    }
    let config = AppConfig::resolve(config_file.as_deref(), &overrides)?;
    Ok(Cli { command, flags, config })
}

impl Cli {
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    fn objective(&self) -> Result<Objective> {
        let name = self.flag("objective").unwrap_or("latency");
        Objective::parse(name).with_context(|| format!("unknown objective {name}"))
    }
}

/// Dispatch a parsed CLI.
pub fn run(cli: &Cli) -> Result<()> {
    match cli.command.as_str() {
        "corpus" => cmd_corpus(cli),
        "gen-dataset" => cmd_gen_dataset(cli),
        "train" => cmd_train(cli),
        "optimize" => cmd_optimize(cli),
        "serve" => cmd_serve(cli),
        other => bail!("unknown command {other}"),
    }
}

fn cmd_corpus(cli: &Cli) -> Result<()> {
    let mut t = Table::new(
        "Corpus (SuiteSparse stand-in, Table 7 order)",
        &["matrix", "n", "nnz", "Avg_nnz", "Std_nnz", "ELL_ratio"],
    );
    for e in gen::corpus() {
        let csr = e.generate_csr(cli.config.scale);
        let f = features::extract_csr(&csr);
        t.row(vec![
            e.name.into(),
            format!("{}", f.n as u64),
            format!("{}", f.nnz as u64),
            fmt_g(f.avg_nnz),
            fmt_g(f.std_nnz),
            fmt_g(f.ell_ratio),
        ]);
    }
    t.emit("corpus");
    Ok(())
}

fn cmd_gen_dataset(cli: &Cli) -> Result<()> {
    let ds = dataset::build(&BuildOptions {
        scale: cli.config.scale,
        both_archs: cli.config.both_archs,
        ..Default::default()
    });
    if let Some(dir) = cli.config.dataset_path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    store::save(&ds, &cli.config.dataset_path)?;
    println!(
        "dataset: {} records ({} matrices x {} archs) -> {:?}",
        ds.len(),
        ds.matrices().len(),
        ds.archs().len(),
        cli.config.dataset_path
    );
    Ok(())
}

fn load_or_build(cli: &Cli) -> Result<dataset::Dataset> {
    if cli.config.dataset_path.exists() {
        store::load(&cli.config.dataset_path)
    } else {
        Ok(dataset::build(&BuildOptions {
            scale: cli.config.scale,
            both_archs: cli.config.both_archs,
            ..Default::default()
        }))
    }
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let ds = load_or_build(cli)?;
    let obj = cli.objective()?;
    let ex = labels::examples(&ds, obj);
    let mut t = Table::new(
        &format!("Classification ({}, tuned decision tree, 80/20)", obj.name()),
        &["target", "accuracy", "F1"],
    );
    for target in labels::Target::ALL {
        let (x, y) = labels::to_xy(&ex, target);
        let (tr, te) = train_test_indices(x.len(), 0.2, cli.config.seed);
        let tuned = crate::automl::tuner::tune_family(
            crate::automl::tuner::Family::DecisionTree,
            &take_x(&x, &tr),
            &take(&y, &tr),
            cli.config.automl_trials,
            cli.config.seed,
        );
        let pred = tuned.model.predict(&take_x(&x, &te));
        let truth = take(&y, &te);
        t.row(vec![
            target.name().into(),
            format!("{:.1}%", 100.0 * accuracy(&truth, &pred)),
            format!("{:.1}%", 100.0 * f1_macro(&truth, &pred, target.n_classes())),
        ]);
    }
    t.emit("train");
    Ok(())
}

fn cmd_optimize(cli: &Cli) -> Result<()> {
    let name = cli.flag("matrix").context("--matrix NAME required")?;
    let entry = gen::by_name(name).with_context(|| format!("unknown matrix {name}"))?;
    let obj = cli.objective()?;
    let ds = load_or_build(cli)?;

    let compile = CompileTimeOptimizer::train(&ds, obj);
    let overhead = OverheadModel::train_on_corpus(cli.config.scale, Some(name));
    let runtime = RunTimeOptimizer::train(&ds, obj, overhead);

    let coo = entry.generate(cli.config.scale);
    let csr = crate::sparse::convert::coo_to_csr(&coo);
    let f = features::extract_csr(&csr);

    let choice = compile.predict(&f, "GTX1650m-Turing");
    let decision = runtime.decide(&coo, cli.flag("iterations").map_or(1000, |v| v.parse().unwrap_or(1000)));

    let mut t = Table::new(&format!("Auto-SpMV plan for {name} ({})", obj.name()), &["key", "value"]);
    t.row(vec!["compile: TB size".into(), choice.tb_size.to_string()]);
    t.row(vec!["compile: maxrregcount".into(), choice.maxrregcount.to_string()]);
    t.row(vec!["compile: memory".into(), choice.mem.name().into()]);
    t.row(vec!["runtime: format".into(), decision.predicted_format.to_string()]);
    t.row(vec!["runtime: convert?".into(), decision.convert.to_string()]);
    t.row(vec!["est overhead (s)".into(), fmt_g(decision.overhead.total())]);
    t.row(vec!["est default obj".into(), fmt_g(decision.est_default)]);
    t.row(vec!["est best obj".into(), fmt_g(decision.est_best)]);
    let gain = if obj.minimize() {
        pct_improvement(decision.est_default, decision.est_best)
    } else {
        pct_gain(decision.est_default, decision.est_best)
    };
    t.row(vec!["est improvement %".into(), format!("{gain:.1}")]);
    t.emit(&format!("optimize_{name}"));
    Ok(())
}

/// `--joint-knobs` is a real tristate (absent = on): anything but
/// true/false errors instead of silently enabling the joint loop.
fn parse_joint_knobs(cli: &Cli) -> Result<bool> {
    match cli.flag("joint-knobs") {
        None | Some("true") => Ok(true),
        Some("false") => Ok(false),
        Some(other) => bail!("--joint-knobs expects true or false, got {other}"),
    }
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    use crate::gpusim::turing_gtx1650m;
    use crate::obs::{SloConfig, SloSpec};
    use crate::online::{Online, OnlineConfig, Trainer};
    use crate::serve::{BackendSpec, Pool, PoolConfig, ScaleOutConfig};
    use crate::sparse::convert::ConvertParams;
    use std::sync::Arc;
    use std::time::Duration;

    let n_requests: usize = cli.flag("requests").map_or(24, |v| v.parse().unwrap_or(24));
    let workers: usize = cli.flag("workers").map_or(2, |v| v.parse().unwrap_or(2));
    let window_us: u64 = cli.flag("batch-window-us").map_or(0, |v| v.parse().unwrap_or(0));
    let cache_cap: usize = cli.flag("cache-cap").map_or(64, |v| v.parse().unwrap_or(64));
    let explore_rate: f64 = cli.flag("explore-rate").map_or(0.0, |v| v.parse().unwrap_or(0.0));
    let retrain_every: u64 = cli.flag("retrain-every").map_or(0, |v| v.parse().unwrap_or(0));
    let anneal_target: Option<u64> =
        cli.flag("anneal-target").and_then(|v| v.parse().ok()).filter(|t| *t > 0);
    let joint_knobs = parse_joint_knobs(cli)?;
    let stats_every: usize = cli.flag("stats-every").map_or(0, |v| v.parse().unwrap_or(0));
    let metrics_out = cli.flag("metrics-out").map(PathBuf::from);
    let events_out = cli.flag("events-out").map(PathBuf::from);
    let flight_out = cli.flag("flight-out").map(PathBuf::from);
    let slo_p99_us: Option<u64> = cli.flag("slo-p99-us").and_then(|v| v.parse().ok());
    let slo_miss_budget: Option<f64> = cli.flag("slo-miss-budget").and_then(|v| v.parse().ok());
    // either SLO flag attaches the engine; the missing half keeps the
    // SloSpec default (50ms p99, 1% miss budget)
    let slo_cfg = (slo_p99_us.is_some() || slo_miss_budget.is_some()).then(|| {
        let mut spec = SloSpec::default();
        if let Some(us) = slo_p99_us {
            spec.p99_target = Duration::from_micros(us);
        }
        if let Some(budget) = slo_miss_budget {
            spec.deadline_miss_budget = budget;
        }
        SloConfig::new(spec)
    });
    // --scaleout (or either tuning flag) attaches the scale-out control
    // plane; unset fields keep the ScaleOutConfig defaults
    let scaleout_on = cli.flag("scaleout").is_some()
        || cli.flag("replicate-share").is_some()
        || cli.flag("admission-cap").is_some();
    let scaleout_cfg = scaleout_on.then(|| {
        let mut sc = ScaleOutConfig::default();
        if let Some(share) = cli.flag("replicate-share").and_then(|v| v.parse().ok()) {
            sc.replicate_share = share;
        }
        if let Some(cap) = cli.flag("admission-cap").and_then(|v| v.parse().ok()) {
            sc.admission_cap = cap;
        }
        sc
    });
    let ds = load_or_build(cli)?;
    let obj = cli.objective()?;
    let overhead = OverheadModel::train_on_corpus(cli.config.scale, None);
    let router = RunTimeOptimizer::train(&ds, obj, overhead.clone());

    let backend = if cli.config.artifacts_dir.join("manifest.tsv").exists() {
        println!("backend: PJRT over {:?}", cli.config.artifacts_dir);
        BackendSpec::Pjrt(cli.config.artifacts_dir.clone())
    } else {
        println!("backend: native (no artifacts at {:?})", cli.config.artifacts_dir);
        BackendSpec::Native
    };
    println!("pool: {workers} workers, batch window {window_us} us, cache capacity {cache_cap}");
    if let Some(slo) = &slo_cfg {
        println!(
            "slo: p99 target {} us, miss budget {:.3}, eval window {} requests",
            slo.spec.p99_target.as_micros(),
            slo.spec.deadline_miss_budget,
            slo.fast_window
        );
    }
    if let Some(sc) = &scaleout_cfg {
        println!(
            "scale-out: replicate over {:.0}% traffic share, unreplicate under {:.0}%, \
             window {} requests, admission cap {}",
            100.0 * sc.replicate_share,
            100.0 * sc.unreplicate_share,
            sc.window,
            sc.admission_cap
        );
    }
    let pool_cfg = PoolConfig {
        workers,
        batch_window: Duration::from_micros(window_us),
        cache_capacity: cache_cap,
        convert: ConvertParams { bell_bh: 8, bell_bw: 8, sell_h: 8 },
        slo: slo_cfg,
        scaleout: scaleout_cfg,
        ..PoolConfig::default()
    };
    let adaptive = explore_rate > 0.0 || retrain_every > 0;
    let pool = if adaptive {
        println!(
            "closed loop: explore rate {explore_rate}, retrain every {retrain_every} \
             requests, joint knobs {}, seed {}",
            if joint_knobs { "on" } else { "off" },
            cli.config.seed
        );
        let trainer = (retrain_every > 0)
            .then(|| Trainer::new(ds.clone(), obj, overhead, turing_gtx1650m().name));
        let online = Online::start(
            OnlineConfig {
                explore_rate,
                retrain_every,
                seed: cli.config.seed,
                anneal_target,
                joint_knobs,
                // keep serving latency flat: refits run on the trainer
                // thread, never inline on a shard
                background: true,
                ..OnlineConfig::default()
            },
            Arc::new(router),
            obj,
            trainer,
        );
        Pool::start_adaptive(online, backend, pool_cfg)
    } else {
        Pool::start(Arc::new(router), backend, pool_cfg)
    };

    // serve products over a few small corpus matrices
    let names = ["shar_te2-b3", "rim", "bcsstk32"];
    let mut sizes = Vec::new();
    for (id, name) in names.iter().enumerate() {
        let coo = gen::by_name(name).unwrap().generate(1);
        sizes.push(coo.n_cols);
        let fmt = pool.register(id as u64, coo, 10_000)?;
        println!("registered {name} -> {fmt}");
    }
    // pipeline the request stream so concurrent requests for one matrix
    // can coalesce into batched dispatches
    let t0 = std::time::Instant::now();
    let mut receivers = Vec::with_capacity(n_requests);
    for r in 0..n_requests {
        let id = r % names.len();
        let x = vec![1.0f32; sizes[id]];
        receivers.push(pool.product_async(id as u64, x)?);
    }
    // A failed drain (a dropped reply, a failed product) must NOT
    // early-return past the ledger flush below — the run's telemetry
    // matters most exactly when it died half-way. Capture the first
    // error and keep going to the flush.
    let mut completed = 0usize;
    let mut served: Result<()> = Ok(());
    for rx in receivers {
        let reply = rx.recv().map_err(|_| anyhow::anyhow!("pool dropped request"));
        if let Err(e) = reply.and_then(|r| r.map(|_| ())) {
            served = Err(e);
            break;
        }
        completed += 1;
        if stats_every > 0 && completed % stats_every == 0 {
            match pool.stats() {
                Ok(s) => {
                    // the in-flight ticker goes to STDERR: stdout is
                    // the machine-readable report stream (tables,
                    // final ledger) and must stay pipeable
                    eprintln!(
                        "[{completed}/{n_requests}] {} dispatches, {} launches, router v{}, \
                         {} migrations, {} events",
                        s.dispatches, s.launches, s.router_version, s.migrations, s.events_total
                    );
                    if let Some(slo) = &s.slo {
                        eprintln!(
                            "[{completed}/{n_requests}] slo {}: {} evals, {} alerts, \
                             {} recoveries, fast burn {:.2}",
                            slo.status.name(),
                            slo.evals,
                            slo.alerts,
                            slo.recoveries,
                            slo.fast_burn
                        );
                    }
                }
                Err(e) => {
                    served = Err(e);
                    break;
                }
            }
        }
    }
    let dt = t0.elapsed();

    // Journal first: it is an in-process ring (no shard round-trip), so
    // it survives even a dead shard that would fail `stats()` below.
    let events = pool.events();
    if let Some(path) = &events_out {
        std::fs::write(path, pool.events_json())
            .with_context(|| format!("writing event journal to {}", path.display()))?;
        println!("wrote event journal ({} events) -> {}", events.len(), path.display());
    }
    if let Err(e) = &served {
        println!("serve aborted after {completed}/{n_requests} requests: {e:#}");
    }

    let stats = pool.stats()?;
    println!(
        "backend in use: {} (degrades to native if PJRT init fails)",
        stats.backend_summary()
    );
    println!(
        "{} requests in {:.3}s ({:.1} req/s), {} dispatches (max batch {}), conversions {}, \
         reconversions {}, evictions {}",
        stats.requests,
        dt.as_secs_f64(),
        stats.requests as f64 / dt.as_secs_f64(),
        stats.dispatches,
        stats.max_batch,
        stats.conversions,
        stats.reconversions,
        stats.evictions
    );
    println!(
        "{} kernel launches ({:.2} launches/request, {} SpMM dispatches) — \
         < 1 launch/request means batching amortized the matrix stream",
        stats.launches,
        stats.launches_per_request(),
        stats.spmm_dispatches
    );
    println!(
        "{} B marshalled ({:.0} B/request), {} B elided across {} session steps \
         ({} round-trips, {} sessions opened, {} open) — elision ratio {:.2}",
        stats.marshalled_bytes,
        stats.marshalled_bytes_per_request(),
        stats.elided_bytes,
        stats.session_steps,
        stats.round_trips_elided,
        stats.sessions_opened,
        stats.active_sessions,
        stats.elision_ratio()
    );
    println!(
        "router v{} ({} retrains, {} format migrations, {} knob migrations), \
         explored {} requests ({} UCB-scored), drift: {}",
        stats.router_version,
        stats.retrains,
        stats.migrations,
        stats.knob_migrations,
        stats.explored_requests,
        stats.ucb_routes,
        stats.drift.map_or("off (frozen router)".to_string(), |d| d.to_string())
    );
    println!(
        "journal: {} control-plane event(s) recorded, {} dropped (ring cap {})",
        stats.events_total,
        stats.events_dropped,
        crate::obs::DEFAULT_JOURNAL_CAP
    );
    for e in events.iter().rev().take(5).rev() {
        println!("  {e}");
    }
    if scaleout_on {
        println!(
            "control plane: {} replications ({} live replicas), {} unreplications, \
             {} reroutes, {} sheds ({} overloaded, {} deadline)",
            stats.replications,
            stats.replicas,
            stats.unreplications,
            stats.reroutes,
            stats.sheds,
            stats.sheds_overloaded,
            stats.sheds_deadline
        );
    }
    if let Some(slo) = &stats.slo {
        println!(
            "slo {}: {} evals, {} alerts, {} recoveries, {}/{} tagged requests missed, \
             {} flight records captured",
            slo.status.name(),
            slo.evals,
            slo.alerts,
            slo.recoveries,
            slo.missed,
            slo.tagged,
            slo.flight_captured
        );
    }
    if let Some(path) = &metrics_out {
        std::fs::write(path, pool.metrics_text()?)
            .with_context(|| format!("writing metrics exposition to {}", path.display()))?;
        println!("wrote metrics exposition -> {}", path.display());
    }
    if let Some(path) = &flight_out {
        let n = pool.flight_records().len();
        std::fs::write(path, pool.flight_json())
            .with_context(|| format!("writing flight records to {}", path.display()))?;
        println!("wrote flight records ({n}) -> {}", path.display());
    }
    let quant = |q: Option<f64>| q.map_or("-".to_string(), |v| format!("{v:.1}"));
    let mut t = Table::new(
        "Per-matrix serving telemetry (latency end-to-end; energy modeled, §6.3)",
        &[
            "matrix", "format", "knobs", "requests", "p50 (us)", "p99 (us)", "energy (J)",
            "power (W)", "decisions",
        ],
    );
    for m in &stats.per_matrix {
        t.row(vec![
            names.get(m.id as usize).copied().unwrap_or("?").into(),
            m.format.map_or("?".into(), |f| f.to_string()),
            m.knobs.map_or("?".into(), |k| k.to_string()),
            m.requests.to_string(),
            quant(m.p50_us),
            quant(m.p99_us),
            fmt_g(m.energy_j),
            fmt_g(m.model_power_w),
            m.decisions(),
        ]);
    }
    t.emit("serve");
    served
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let cli = parse(&args(&["optimize", "--matrix", "rim", "--objective", "energy"])).unwrap();
        assert_eq!(cli.command, "optimize");
        assert_eq!(cli.flag("matrix"), Some("rim"));
        assert_eq!(cli.flag("objective"), Some("energy"));
    }

    #[test]
    fn config_overrides_via_flags() {
        let cli = parse(&args(&["corpus", "--scale", "2", "--set", "seed=9"])).unwrap();
        assert_eq!(cli.config.scale, 2);
        assert_eq!(cli.config.seed, 9);
    }

    #[test]
    fn rejects_empty_and_garbage() {
        assert!(parse(&[]).is_err());
        assert!(parse(&args(&["corpus", "positional"])).is_err());
        assert!(run(&parse(&args(&["bogus"])).unwrap()).is_err());
    }

    #[test]
    fn boolean_flags_default_true() {
        let cli = parse(&args(&["serve", "--verbose"])).unwrap();
        assert_eq!(cli.flag("verbose"), Some("true"));
    }

    #[test]
    fn serve_online_flags_parse() {
        let cli = parse(&args(&[
            "serve",
            "--explore-rate",
            "0.2",
            "--retrain-every",
            "64",
            "--seed",
            "7",
        ]))
        .unwrap();
        assert_eq!(cli.flag("explore-rate"), Some("0.2"));
        assert_eq!(cli.flag("retrain-every"), Some("64"));
        assert_eq!(cli.config.seed, 7, "--seed drives the exploration schedule");
    }

    #[test]
    fn gnu_style_equals_flags_parse_like_space_separated() {
        let cli = parse(&args(&["serve", "--joint-knobs=false", "--set=seed=9"])).unwrap();
        assert_eq!(cli.flag("joint-knobs"), Some("false"));
        assert_eq!(cli.config.seed, 9, "--set=key=value splits on the FIRST =");
        assert!(
            !parse_joint_knobs(&cli).unwrap(),
            "--joint-knobs=false must disable the joint loop, not silently default on"
        );
    }

    #[test]
    fn serve_observability_flags_parse() {
        let cli = parse(&args(&[
            "serve",
            "--stats-every",
            "8",
            "--metrics-out",
            "/tmp/metrics.prom",
            "--events-out=/tmp/events.json",
        ]))
        .unwrap();
        assert_eq!(cli.flag("stats-every"), Some("8"));
        assert_eq!(cli.flag("metrics-out"), Some("/tmp/metrics.prom"));
        assert_eq!(cli.flag("events-out"), Some("/tmp/events.json"));
    }

    #[test]
    fn serve_slo_flags_parse() {
        let cli = parse(&args(&[
            "serve",
            "--slo-p99-us",
            "5000",
            "--slo-miss-budget",
            "0.05",
            "--flight-out=/tmp/flight.json",
        ]))
        .unwrap();
        assert_eq!(cli.flag("slo-p99-us"), Some("5000"));
        assert_eq!(cli.flag("slo-miss-budget"), Some("0.05"));
        assert_eq!(cli.flag("flight-out"), Some("/tmp/flight.json"));
    }

    #[test]
    fn serve_scaleout_flags_parse() {
        let cli = parse(&args(&[
            "serve",
            "--scaleout",
            "--replicate-share",
            "0.4",
            "--admission-cap=256",
        ]))
        .unwrap();
        assert_eq!(cli.flag("scaleout"), Some("true"), "bare --scaleout is a boolean flag");
        assert_eq!(cli.flag("replicate-share"), Some("0.4"));
        assert_eq!(cli.flag("admission-cap"), Some("256"));
    }

    #[test]
    fn joint_knobs_flag_defaults_on_and_rejects_garbage() {
        let joint = |a: &[&str]| parse_joint_knobs(&parse(&args(a)).unwrap());
        assert!(joint(&["serve"]).unwrap(), "default is on");
        assert!(!joint(&["serve", "--joint-knobs", "false"]).unwrap());
        assert!(joint(&["serve", "--joint-knobs", "true"]).unwrap());
        assert!(
            joint(&["serve", "--joint-knobs", "off"]).is_err(),
            "anything but true/false must be rejected, not silently treated as on"
        );
    }
}
