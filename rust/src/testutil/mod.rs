//! Property-testing helper — the proptest stand-in (proptest is not in
//! the offline crate mirror; see Cargo.toml). Runs a property over many
//! seeded random cases and, on failure, retries smaller sizes derived
//! from the failing case (a lightweight shrink) before reporting the
//! minimal reproducing seed.

use crate::gen::Rng;

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult {
    Ok { cases: usize },
    Failed { seed: u64, size: usize, message: String },
}

/// Run `prop(rng, size)` over `cases` random (seed, size) pairs.
///
/// `prop` returns Err(description) on a violated property. On failure we
/// re-run the same seed at smaller sizes to find a smaller witness.
pub fn forall<F>(base_seed: u64, cases: usize, max_size: usize, mut prop: F) -> PropResult
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let size = 1 + (seed as usize % max_size);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // shrink: halve the size while it still fails
            let mut fail_size = size;
            let mut fail_msg = msg;
            let mut s = size / 2;
            while s >= 1 {
                let mut rng2 = Rng::new(seed);
                match prop(&mut rng2, s) {
                    Err(m) => {
                        fail_size = s;
                        fail_msg = m;
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            return PropResult::Failed { seed, size: fail_size, message: fail_msg };
        }
    }
    PropResult::Ok { cases }
}

/// Assert a property holds; panics with the minimal witness otherwise.
#[track_caller]
pub fn assert_prop<F>(name: &str, base_seed: u64, cases: usize, max_size: usize, prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    match forall(base_seed, cases, max_size, prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { seed, size, message } => {
            panic!("property '{name}' failed (seed={seed}, size={size}): {message}");
        }
    }
}

/// Random COO matrix for property tests.
pub fn arb_coo(rng: &mut Rng, size: usize) -> crate::sparse::Coo {
    let n = (size % 64) + 1;
    let m = ((size / 2) % 64) + 1;
    let nnz = rng.below(4 * n * m / 3 + 1);
    let mut coo = crate::sparse::Coo::with_capacity(n, m, nnz);
    for _ in 0..nnz {
        coo.push(rng.below(n), rng.below(m), rng.val());
    }
    coo
}

/// Random dense vector.
pub fn arb_x(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.val()).collect()
}

/// A run-time router trained on a small corpus slice with a synthetic
/// overhead model — the shared fixture for serving tests and the e2e
/// serving bench (one definition, so the training setup cannot drift
/// between them).
pub fn toy_router(
    matrix_names: &[&str],
    objective: crate::gpusim::Objective,
) -> crate::coordinator::RunTimeOptimizer {
    toy_setup(matrix_names, objective).0
}

/// [`toy_router`] plus the dataset and overhead model it was trained
/// on — what the online-loop fixtures need (the `Trainer` retrains from
/// the same base the initial router saw).
pub fn toy_setup(
    matrix_names: &[&str],
    objective: crate::gpusim::Objective,
) -> (
    crate::coordinator::RunTimeOptimizer,
    crate::dataset::Dataset,
    crate::coordinator::OverheadModel,
) {
    use crate::coordinator::overhead::{OverheadModel, OverheadSample};
    let ds = crate::dataset::build(&crate::dataset::BuildOptions {
        only: Some(matrix_names.iter().map(|s| s.to_string()).collect()),
        both_archs: false,
        ..Default::default()
    });
    let samples: Vec<OverheadSample> = (1..10)
        .map(|k| OverheadSample {
            n: k as f64 * 1000.0,
            nnz: k as f64 * 10_000.0,
            f_latency_s: k as f64 * 1e-3,
            c_latency_s: k as f64 * 1e-3,
        })
        .collect();
    let overhead = OverheadModel::train(&samples);
    let router = crate::coordinator::RunTimeOptimizer::train(&ds, objective, overhead.clone());
    (router, ds, overhead)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_reports_cases() {
        match forall(1, 50, 100, |_, _| Ok(())) {
            PropResult::Ok { cases } => assert_eq!(cases, 50),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn failing_property_shrinks() {
        // fails whenever size >= 4; the shrinker should reach size < 8
        match forall(2, 50, 100, |_, size| {
            if size >= 4 {
                Err("too big".into())
            } else {
                Ok(())
            }
        }) {
            PropResult::Failed { size, .. } => assert!(size < 8, "shrunk to {size}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    #[should_panic(expected = "property 'demo' failed")]
    fn assert_prop_panics_with_witness() {
        assert_prop("demo", 3, 10, 50, |_, _| Err("always".into()));
    }

    #[test]
    fn arb_coo_in_bounds() {
        let mut rng = Rng::new(5);
        for s in [1, 10, 100] {
            let c = arb_coo(&mut rng, s);
            for i in 0..c.len() {
                assert!((c.rows[i] as usize) < c.n_rows);
                assert!((c.cols[i] as usize) < c.n_cols);
            }
        }
    }
}
