//! Streaming observation buffer: the feedback path of the closed loop.
//!
//! Every executed dispatch lands here as an [`Observation`]: the
//! matrix's Table-2 features, the format the dispatch actually ran in
//! (chosen or explored), the measured per-request execution latency,
//! and the gpusim-modeled `Measurement` for that (matrix, format) at
//! the serving knobs — the stand-in for the paper's §6.3 power sensor.
//! The buffer is a bounded ring (drop-oldest), so a long-running pool
//! retrains on a sliding window of recent traffic rather than its whole
//! history — which is exactly what makes retraining track drift.
//!
//! [`to_training`] turns a buffer snapshot into the two artifacts the
//! existing `train_on_examples` path consumes: per-feature-vector
//! [`Example`]s (best observed format = the classification label) and
//! synthetic [`Record`]s that teach the per-format value regressors the
//! observed objective levels of the drifted population.

use super::bandit::{knob_arm, knob_index};
use crate::coordinator::compile_time::{knob_example, CompileChoice};
use crate::dataset::labels::{arch_feature, Example};
use crate::dataset::Record;
use crate::features::Features;
use crate::gpusim::{KernelConfig, Measurement, Objective};
use crate::sparse::{Format, KernelKind};
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

const N_FORMATS: usize = Format::ALL.len();

/// The kernel configuration the serving energy model assumes for
/// `format` at the default knobs (one point of the offline sweep, so
/// synthetic records mix cleanly into the training dataset).
pub fn model_config(format: Format) -> KernelConfig {
    CompileChoice::serving_default().config_for(format)
}

/// One served dispatch, as the trainer sees it.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub matrix_id: u64,
    /// Kernel class the dispatch executed (SpMV, SpTRSV, or SymGS).
    /// Part of the request class: the bandit buckets evidence per kind,
    /// and only SpMV observations feed the format router's training.
    pub kind: KernelKind,
    pub features: Features,
    /// Format the dispatch executed in.
    pub format: Format,
    /// Compile-knob decision the dispatch executed under (the serving
    /// default unless a knob policy or the exploration bandit said
    /// otherwise).
    pub choice: CompileChoice,
    /// True when the bandit routed this dispatch off the predicted path.
    pub explored: bool,
    /// Requests coalesced into the dispatch (>= 1). Weights the label
    /// aggregation and the retrain cadence, which counts *requests*.
    pub requests: u64,
    /// Measured wall-clock execution time per request in the dispatch
    /// (seconds; excludes queue wait, so it is a kernel-cost label).
    pub measured_latency_s: f64,
    /// gpusim-modeled objectives for this (matrix, format) at the
    /// serving knobs ([`model_config`]).
    pub modeled: Measurement,
}

/// Bounded drop-oldest observation ring shared by all shards.
pub struct Observer {
    cap: usize,
    buf: Mutex<VecDeque<Observation>>,
    /// Total *requests* ever observed (drops included; a coalesced
    /// dispatch counts its batch size) — the retrain cadence counts
    /// against this, not the ring occupancy.
    total: AtomicU64,
}

impl Observer {
    pub fn new(cap: usize) -> Observer {
        let cap = cap.max(1);
        Observer {
            cap,
            buf: Mutex::new(VecDeque::with_capacity(cap.min(1024))),
            total: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn record(&self, obs: Observation) {
        let weight = obs.requests.max(1);
        let mut buf = self.buf.lock().expect("observer lock");
        if buf.len() == self.cap {
            buf.pop_front();
        }
        buf.push_back(obs);
        self.total.fetch_add(weight, Ordering::Relaxed);
    }

    /// Requests ever observed (monotone).
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Observations currently buffered.
    pub fn len(&self) -> usize {
        self.buf.lock().expect("observer lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy out the current window (the trainer works on a snapshot so
    /// shards never block on a retrain).
    pub fn snapshot(&self) -> Vec<Observation> {
        self.buf.lock().expect("observer lock").iter().copied().collect()
    }
}

/// Encode a buffer snapshot as dataset [`Record`]s so the observation
/// window checkpoints through `dataset::store` across pool restarts.
/// A `Record` has no slots for the per-dispatch bookkeeping, so the
/// matrix-name field carries it:
/// `ckpt-<matrix id>-<requests>-<explored>-<measured latency f64 bits>-<kind id>`
/// (hex fields). Features and the modeled measurement round-trip
/// bit-exactly through the store's shortest-unique float formatting;
/// the config slot carries the executed format AND knob decision
/// (`CompileChoice::config_for`), so joint (format, knob) evidence
/// survives a restart.
pub fn to_records(obs: &[Observation], arch: &str) -> Vec<Record> {
    obs.iter()
        .map(|o| Record {
            matrix: format!(
                "ckpt-{:016x}-{:016x}-{}-{:016x}-{}",
                o.matrix_id,
                o.requests,
                u8::from(o.explored),
                o.measured_latency_s.to_bits(),
                o.kind.class_id()
            ),
            arch: arch.to_string(),
            config: o.choice.config_for(o.format),
            features: o.features,
            m: o.modeled,
        })
        .collect()
}

/// Decode a checkpoint written by [`to_records`]. Rejects records whose
/// matrix name does not carry the checkpoint encoding — a checkpoint
/// file holds nothing else, so a mismatch means the wrong file. A
/// 5-field name (checkpoints written before solve kinds existed) is
/// accepted and decodes as `kind=spmv`.
pub fn from_records(records: &[Record]) -> Result<Vec<Observation>> {
    records
        .iter()
        .map(|r| {
            let fields: Vec<&str> = r.matrix.split('-').collect();
            if !(fields.len() == 5 || fields.len() == 6) || fields[0] != "ckpt" {
                bail!("not an observation checkpoint record: {}", r.matrix);
            }
            let matrix_id = u64::from_str_radix(fields[1], 16).context("ckpt matrix id")?;
            let requests = u64::from_str_radix(fields[2], 16).context("ckpt requests")?;
            let explored = match fields[3] {
                "0" => false,
                "1" => true,
                other => bail!("ckpt explored flag {other}"),
            };
            let lat_bits = u64::from_str_radix(fields[4], 16).context("ckpt latency bits")?;
            let kind = match fields.get(5) {
                None => KernelKind::Spmv,
                Some(id) => {
                    let id: usize = id.parse().context("ckpt kind id")?;
                    KernelKind::from_class_id(id)
                        .with_context(|| format!("ckpt kind id {id} out of range"))?
                }
            };
            Ok(Observation {
                matrix_id,
                kind,
                features: r.features,
                format: r.config.format,
                choice: CompileChoice::from_config(&r.config),
                explored,
                requests,
                measured_latency_s: f64::from_bits(lat_bits),
                modeled: r.m,
            })
        })
        .collect()
}

/// Stable key for "the same feature vector": grouping unit for label
/// derivation (one serving matrix = one exact feature vector, so exact
/// grouping compares formats on identical inputs).
pub fn feature_key(f: &Features) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in f.to_vec() {
        h ^= v.to_bits();
        h = h.wrapping_mul(0x100_0000_01b3);
        h ^= h >> 29;
    }
    h
}

/// What a buffer snapshot contributes to the next retrain.
pub struct TrainingDelta {
    /// One example per feature vector observed under >= 2 formats,
    /// labeled with the best observed format for the objective.
    pub examples: Vec<Example>,
    /// One synthetic record per (feature vector, format, knob arm) with
    /// the mean observed/modeled measurement — value-regressor training
    /// data.
    pub records: Vec<Record>,
    /// One example per (feature vector, format) observed under >= 2
    /// distinct knob arms, labeled with the best observed arm — the
    /// per-format `CompileTimeOptimizer` refit data (DESIGN.md §8).
    pub knob_examples: Vec<(Format, Example)>,
}

#[derive(Clone, Copy)]
struct ArmAgg {
    count: u64,
    latency_s: f64,
    energy_j: f64,
    avg_power_w: f64,
    mflops_per_watt: f64,
}

impl ArmAgg {
    const ZERO: ArmAgg =
        ArmAgg { count: 0, latency_s: 0.0, energy_j: 0.0, avg_power_w: 0.0, mflops_per_watt: 0.0 };

    fn add(&mut self, o: &Observation) {
        let w = o.requests.max(1);
        self.count += w;
        let wf = w as f64;
        self.latency_s += o.measured_latency_s * wf;
        self.energy_j += o.modeled.energy_j * wf;
        self.avg_power_w += o.modeled.avg_power_w * wf;
        self.mflops_per_watt += o.modeled.mflops_per_watt * wf;
    }

    fn merge(&mut self, other: &ArmAgg) {
        self.count += other.count;
        self.latency_s += other.latency_s;
        self.energy_j += other.energy_j;
        self.avg_power_w += other.avg_power_w;
        self.mflops_per_watt += other.mflops_per_watt;
    }

    fn mean(&self) -> Measurement {
        let k = self.count.max(1) as f64;
        Measurement {
            latency_s: self.latency_s / k,
            energy_j: self.energy_j / k,
            avg_power_w: self.avg_power_w / k,
            mflops_per_watt: self.mflops_per_watt / k,
        }
    }
}

/// Aggregate a snapshot into retraining artifacts.
///
/// Observations group by exact feature vector, then by (format, knob
/// arm): the knob dimension quantizes through [`knob_index`] so finer
/// CUDA knob points that alias to the same Pallas variant pool their
/// evidence. The objective value per cell is taken from the mean
/// measurement: measured wall latency for `Objective::Latency` (the
/// serving truth), the gpusim model for the energy-family objectives
/// (the paper's sensor stand-in).
///
/// Only `kind=spmv` observations contribute: the format router and the
/// knob optimizer predict SpMV cost, and a solve's sequential sweep has
/// a different cost surface — letting SpTRSV/SymGS latencies label
/// "best format for SpMV" would poison the models. Solve evidence
/// stays in the bandit's kind-qualified buckets instead.
pub fn to_training(obs: &[Observation], objective: Objective, arch: &str) -> TrainingDelta {
    // (feature_key) -> (features, per-(format, knob-arm) aggregates);
    // insertion order kept so retraining is deterministic.
    type Cells = Vec<(Format, usize, ArmAgg)>;
    let mut groups: Vec<(u64, Features, Cells)> = Vec::new();
    for o in obs {
        if o.kind != KernelKind::Spmv {
            continue;
        }
        let key = feature_key(&o.features);
        let idx = match groups.iter().position(|(k, _, _)| *k == key) {
            Some(i) => i,
            None => {
                groups.push((key, o.features, Vec::new()));
                groups.len() - 1
            }
        };
        let cells = &mut groups[idx].2;
        let arm = knob_index(o.choice);
        let cell = match cells.iter().position(|(f, a, _)| *f == o.format && *a == arm) {
            Some(i) => &mut cells[i].2,
            None => {
                cells.push((o.format, arm, ArmAgg::ZERO));
                &mut cells.last_mut().expect("just pushed").2
            }
        };
        cell.add(o);
    }

    let mut examples = Vec::new();
    let mut records = Vec::new();
    let mut knob_examples = Vec::new();
    for (key, feats, cells) in &groups {
        let name = format!("online-{key:016x}");
        let mut fv = feats.to_scaled_vec();
        fv.push(arch_feature(arch));

        // Per-(format, arm) records for the value regressors, tagged
        // with the arm's canonical config, plus per-format knob labels.
        let mut format_aggs: [Option<ArmAgg>; N_FORMATS] = [None; N_FORMATS];
        for fmt in Format::ALL {
            let mut best_arm: Option<(usize, f64)> = None;
            let mut arms_seen = 0usize;
            for (f, arm, agg) in cells.iter().filter(|(f, _, _)| *f == fmt) {
                arms_seen += 1;
                let mean = agg.mean();
                records.push(Record {
                    matrix: name.clone(),
                    arch: arch.to_string(),
                    config: knob_arm(*arm).config_for(*f),
                    features: *feats,
                    m: mean,
                });
                let value = objective.value(&mean);
                if best_arm.is_none_or(|(_, bv)| objective.better(value, bv)) {
                    best_arm = Some((*arm, value));
                }
                format_aggs[fmt.class_id()].get_or_insert(ArmAgg::ZERO).merge(agg);
            }
            // A single-arm format feeds the value models above but
            // carries no comparative knob label.
            if arms_seen >= 2 {
                let (arm, value) = best_arm.expect("arms_seen >= 2");
                knob_examples.push((
                    fmt,
                    knob_example(
                        &name,
                        arch,
                        fv.clone(),
                        &knob_arm(arm).config_for(fmt),
                        value,
                    ),
                ));
            }
        }

        // The format label compares per-format means (knob arms pooled).
        let mut best: Option<(Format, f64)> = None;
        let mut csr_value: Option<f64> = None;
        let mut n_formats = 0usize;
        for fmt in Format::ALL {
            let Some(agg) = &format_aggs[fmt.class_id()] else { continue };
            n_formats += 1;
            let value = objective.value(&agg.mean());
            if fmt == Format::Csr {
                csr_value = Some(value);
            }
            if best.is_none_or(|(_, bv)| objective.better(value, bv)) {
                best = Some((fmt, value));
            }
        }
        // A single-format group still feeds the value models (records
        // above) but carries no comparative label: skip the example.
        if n_formats < 2 {
            continue;
        }
        let (best_fmt, best_value) = best.expect("n_formats >= 2");
        let baseline = KernelConfig::default_baseline();
        examples.push(Example {
            matrix: name,
            arch: arch.to_string(),
            features: fv,
            tb_class: baseline.tb_class(),
            reg_class: baseline.reg_class(),
            mem_class: baseline.mem.class_id(),
            format_class: best_fmt.class_id(),
            best_compile: csr_value.unwrap_or(best_value),
            best_format_value: best_value,
            default_value: csr_value.unwrap_or(best_value),
        });
    }
    TrainingDelta { examples, records, knob_examples }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feats(n: f64) -> Features {
        Features {
            n,
            nnz: n * 4.0,
            avg_nnz: 4.0,
            var_nnz: 1.0,
            ell_ratio: 0.8,
            median: 4.0,
            mode: 4.0,
            std_nnz: 1.0,
        }
    }

    fn obs(n: f64, format: Format, energy: f64, lat: f64) -> Observation {
        Observation {
            matrix_id: n as u64,
            kind: KernelKind::Spmv,
            features: feats(n),
            format,
            choice: CompileChoice::serving_default(),
            explored: format != Format::Csr,
            requests: 1,
            measured_latency_s: lat,
            modeled: Measurement {
                latency_s: lat,
                energy_j: energy,
                avg_power_w: 10.0,
                mflops_per_watt: 1.0 / energy,
            },
        }
    }

    #[test]
    fn coalesced_dispatches_weight_the_total_and_the_means() {
        let o = Observer::new(16);
        let mut batched = obs(1.0, Format::Csr, 2.0, 2e-6);
        batched.requests = 7;
        o.record(batched);
        o.record(obs(1.0, Format::Csr, 9.0, 9e-6));
        assert_eq!(o.total(), 8, "a 7-request dispatch counts 7 toward the cadence");
        let delta = to_training(&o.snapshot(), Objective::Energy, "GTX1650m-Turing");
        assert_eq!(delta.records.len(), 1);
        // weighted mean: (7*2 + 1*9) / 8
        assert!((delta.records[0].m.energy_j - 23.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn ring_is_bounded_and_total_is_monotone() {
        let o = Observer::new(4);
        for i in 0..10 {
            o.record(obs(i as f64 + 1.0, Format::Csr, 1.0, 1e-6));
        }
        assert_eq!(o.len(), 4);
        assert_eq!(o.total(), 10);
        let snap = o.snapshot();
        assert_eq!(snap.len(), 4);
        assert_eq!(snap[0].matrix_id, 7, "oldest entries dropped first");
        assert!(!o.is_empty());
        assert_eq!(o.capacity(), 4);
    }

    #[test]
    fn checkpoint_records_roundtrip_bit_exactly() {
        let mut a = obs(123.0, Format::Ell, 3.25e-4, 7.5e-7);
        a.matrix_id = 0xDEAD_BEEF;
        a.requests = 17;
        a.explored = true;
        a.choice = CompileChoice {
            tb_size: 64,
            maxrregcount: 32,
            mem: crate::gpusim::MemConfig::PreferL1,
        };
        let b = obs(9.0, Format::Csr, 1e-12, 4.2e-3);
        let records = to_records(&[a, b], "GTX1650m-Turing");
        assert_eq!(records.len(), 2);
        assert!(records[0].matrix.starts_with("ckpt-"));
        assert_eq!(records[0].arch, "GTX1650m-Turing");
        let back = from_records(&records).unwrap();
        assert_eq!(back.len(), 2);
        for (orig, got) in [a, b].iter().zip(&back) {
            assert_eq!(got.matrix_id, orig.matrix_id);
            assert_eq!(got.format, orig.format);
            assert_eq!(got.choice, orig.choice, "the knob decision must survive the checkpoint");
            assert_eq!(got.explored, orig.explored);
            assert_eq!(got.requests, orig.requests);
            assert_eq!(
                got.measured_latency_s.to_bits(),
                orig.measured_latency_s.to_bits(),
                "measured latency must survive bit-exactly"
            );
            assert_eq!(got.features, orig.features);
            assert_eq!(got.modeled, orig.modeled);
        }
    }

    #[test]
    fn checkpoint_kind_roundtrips_and_legacy_records_decode_as_spmv() {
        let mut solve = obs(5.0, Format::Csr, 1.0, 2e-6);
        solve.kind = KernelKind::Sptrsv;
        let mut gs = obs(6.0, Format::Ell, 2.0, 3e-6);
        gs.kind = KernelKind::Symgs;
        let records = to_records(&[solve, gs], "a");
        assert!(records[0].matrix.ends_with("-1"));
        assert!(records[1].matrix.ends_with("-2"));
        let back = from_records(&records).unwrap();
        assert_eq!(back[0].kind, KernelKind::Sptrsv);
        assert_eq!(back[1].kind, KernelKind::Symgs);
        // pre-solve checkpoints have 5 dash-fields and no kind: Spmv
        let mut legacy = to_records(&[obs(7.0, Format::Csr, 1.0, 1e-6)], "a");
        legacy[0].matrix =
            legacy[0].matrix.rsplit_once('-').expect("6 fields").0.to_string();
        assert_eq!(legacy[0].matrix.split('-').count(), 5);
        let back = from_records(&legacy).unwrap();
        assert_eq!(back[0].kind, KernelKind::Spmv);
        // an out-of-range kind id is still a decode error, not a default
        let mut bad = to_records(&[obs(8.0, Format::Csr, 1.0, 1e-6)], "a");
        bad[0].matrix = format!("{}-9", legacy[0].matrix);
        assert!(from_records(&bad).is_err());
    }

    #[test]
    fn training_delta_ignores_solve_observations() {
        // Same matrix: SpMV says ELL wins; a flood of fast SpTRSV
        // observations under CSR must not flip the format label, and
        // solve-only matrices must produce no records at all.
        let mut buf = vec![
            obs(100.0, Format::Csr, 4.0, 4e-6),
            obs(100.0, Format::Ell, 1.0, 1e-6),
        ];
        for _ in 0..8 {
            let mut s = obs(100.0, Format::Csr, 0.01, 1e-8);
            s.kind = KernelKind::Sptrsv;
            buf.push(s);
        }
        let mut solve_only = obs(200.0, Format::Csr, 1.0, 1e-6);
        solve_only.kind = KernelKind::Symgs;
        buf.push(solve_only);
        let delta = to_training(&buf, Objective::Energy, "GTX1650m-Turing");
        assert_eq!(delta.examples.len(), 1);
        assert_eq!(delta.examples[0].format_class, Format::Ell.class_id());
        assert_eq!(delta.records.len(), 2, "solve observations feed no value records");
        assert!((delta.records[0].m.energy_j - 4.0).abs() < 1e-12, "csr mean unpolluted");
    }

    #[test]
    fn checkpoint_decode_rejects_foreign_records() {
        let mut r = to_records(&[obs(1.0, Format::Csr, 1.0, 1e-6)], "a");
        r[0].matrix = "online-0123456789abcdef".into(); // a to_training record
        assert!(from_records(&r).is_err());
        let mut r2 = to_records(&[obs(1.0, Format::Csr, 1.0, 1e-6)], "a");
        r2[0].matrix = "ckpt-xyz-0-0-0".into();
        assert!(from_records(&r2).is_err());
    }

    #[test]
    fn feature_key_distinguishes_vectors() {
        assert_eq!(feature_key(&feats(100.0)), feature_key(&feats(100.0)));
        assert_ne!(feature_key(&feats(100.0)), feature_key(&feats(101.0)));
    }

    #[test]
    fn training_delta_labels_best_format_and_skips_single_format_groups() {
        // matrix A: CSR costly, ELL cheap (two observations each);
        // matrix B: CSR only -> record but no example.
        let buf = vec![
            obs(100.0, Format::Csr, 4.0, 4e-6),
            obs(100.0, Format::Ell, 1.0, 1e-6),
            obs(100.0, Format::Csr, 6.0, 6e-6),
            obs(100.0, Format::Ell, 3.0, 3e-6),
            obs(200.0, Format::Csr, 2.0, 2e-6),
        ];
        let delta = to_training(&buf, Objective::Energy, "GTX1650m-Turing");
        assert_eq!(delta.examples.len(), 1);
        let e = &delta.examples[0];
        assert_eq!(e.format_class, Format::Ell.class_id());
        assert_eq!(e.features.len(), 9, "8 scaled features + arch indicator");
        assert!((e.default_value - 5.0).abs() < 1e-12, "CSR mean energy");
        assert!((e.best_format_value - 2.0).abs() < 1e-12, "ELL mean energy");
        // records: A/csr, A/ell, B/csr
        assert_eq!(delta.records.len(), 3);
        assert!(delta.records.iter().all(|r| r.matrix.starts_with("online-")));
        let default_tb = CompileChoice::serving_default().tb_size;
        assert!(delta.records.iter().all(|r| r.config.tb_size == default_tb));
        let a_csr = delta
            .records
            .iter()
            .find(|r| r.config.format == Format::Csr && (r.features.n - 100.0).abs() < 1e-9)
            .unwrap();
        assert!((a_csr.m.energy_j - 5.0).abs() < 1e-12);
        assert!((a_csr.m.latency_s - 5e-6).abs() < 1e-18, "latency label is the measured mean");
    }

    #[test]
    fn training_delta_labels_best_knob_arm_per_format() {
        use crate::gpusim::MemConfig;
        // same feature vector, same format (ELL), two knob arms: the
        // gather-analogue arm is cheaper -> the knob example must label
        // its tb/reg/mem classes; a single-arm CSR group contributes no
        // knob example.
        let cheap = CompileChoice { tb_size: 64, maxrregcount: 32, mem: MemConfig::PreferL1 };
        let costly = CompileChoice::serving_default();
        let mk = |choice, energy| {
            let mut o = obs(300.0, Format::Ell, energy, 1e-6);
            o.choice = choice;
            o
        };
        let buf = vec![
            mk(costly, 6.0),
            mk(cheap, 2.0),
            obs(300.0, Format::Csr, 3.0, 3e-6),
        ];
        let delta = to_training(&buf, Objective::Energy, "GTX1650m-Turing");
        // records: ELL x 2 arms + CSR x 1 arm
        assert_eq!(delta.records.len(), 3);
        assert_eq!(delta.knob_examples.len(), 1);
        let (fmt, e) = &delta.knob_examples[0];
        assert_eq!(*fmt, Format::Ell);
        assert_eq!(e.tb_class, 0, "TB 64 is class 0");
        assert_eq!(e.reg_class, 1, "regs 32 is class 1");
        assert_eq!(e.mem_class, MemConfig::PreferL1.class_id());
        assert_eq!(e.format_class, Format::Ell.class_id());
        // the format label still compares pooled per-format means:
        // ELL mean (6+2)/2 = 4 beats nothing over CSR 3 -> CSR wins
        assert_eq!(delta.examples.len(), 1);
        assert_eq!(delta.examples[0].format_class, Format::Csr.class_id());
    }

    #[test]
    fn latency_objective_uses_measured_latency_for_labels() {
        // modeled energies favor CSR, measured latencies favor SELL: the
        // latency objective must label SELL.
        let mut a = obs(50.0, Format::Csr, 1.0, 9e-6);
        a.modeled.latency_s = 1e-7; // modeled says CSR is fast; measurement disagrees
        let b = obs(50.0, Format::Sell, 5.0, 2e-6);
        let delta = to_training(&[a, b], Objective::Latency, "GTX1650m-Turing");
        assert_eq!(delta.examples.len(), 1);
        assert_eq!(delta.examples[0].format_class, Format::Sell.class_id());
    }
}
