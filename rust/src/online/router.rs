//! Versioned, atomically hot-swappable routing policy handle.
//!
//! The serving shards must never block on (or even notice) a retrain:
//! they keep a locally cached `Arc<Policy>` plus the version it came
//! from, poll [`SwapRouter::version`] (one relaxed-ish atomic load) at
//! the top of their message loop, and reload through the `RwLock` only
//! when the version moved. [`SwapRouter::install_policy`] is the single
//! writer path: swap the `Arc`, bump the version, wake waiters.
//! In-flight dispatches keep executing against the old `Arc` they
//! already cloned — a swap can never tear a decision in half.
//!
//! A [`Policy`] is the joint run-time decision surface (DESIGN.md §8):
//! the `RunTimeOptimizer` decides the *format*, the optional
//! [`KnobPolicy`] decides the *compile knobs for that format*. A policy
//! without knob models (the PR 2/3 posture, and every frozen pool)
//! keeps knobs at [`CompileChoice::serving_default`].

use crate::coordinator::compile_time::{CompileChoice, KnobPolicy};
use crate::coordinator::RunTimeOptimizer;
use crate::features::Features;
use crate::obs::{EventKind, Journal, SwapTrigger, DEFAULT_JOURNAL_CAP};
use crate::sparse::Format;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// One installable routing policy: format router + optional per-format
/// knob policy.
pub struct Policy {
    pub router: Arc<RunTimeOptimizer>,
    /// `None` = knobs stay at the serving default (format-only
    /// routing, bit-identical to the pre-§8 engine).
    pub knobs: Option<Arc<KnobPolicy>>,
}

impl Policy {
    /// Format-only policy (frozen pools, and adaptive pools with
    /// `--joint-knobs false`).
    pub fn format_only(router: Arc<RunTimeOptimizer>) -> Policy {
        Policy { router, knobs: None }
    }

    /// Joint policy: the retrained pair swaps in together.
    pub fn joint(router: Arc<RunTimeOptimizer>, knobs: Arc<KnobPolicy>) -> Policy {
        Policy { router, knobs: Some(knobs) }
    }

    /// Knob decision for a matrix already routed to `format`.
    pub fn knob_for(&self, feats: &Features, format: Format) -> CompileChoice {
        match &self.knobs {
            Some(k) => k.predict(feats, format),
            None => CompileChoice::serving_default(),
        }
    }
}

/// Shared handle to the current policy, swappable at run time.
pub struct SwapRouter {
    inner: RwLock<Arc<Policy>>,
    /// Monotone version counter; starts at 1 for the initial policy.
    version: AtomicU64,
    /// Mirror of `version` for blocking waiters ([`Self::wait_for_version`]).
    waiters: Mutex<u64>,
    cv: Condvar,
    /// Control-plane event journal. The router owns it because it is
    /// the one object shared by the online loop (created first) and
    /// the pool (which hands it to shards via `Telemetry`). Besides the
    /// swap/retrain/migration chain it now also carries the SLO
    /// engine's `slo_alert`/`slo_recovered` events and the per-arm
    /// attribution's `arm_shift` events (DESIGN.md §11), all in one
    /// causally ordered sequence.
    journal: Arc<Journal>,
}

impl SwapRouter {
    /// Wrap an initial format router (knobs at the serving default).
    pub fn new(initial: Arc<RunTimeOptimizer>) -> SwapRouter {
        SwapRouter::new_policy(Arc::new(Policy::format_only(initial)))
    }

    pub fn new_policy(initial: Arc<Policy>) -> SwapRouter {
        SwapRouter {
            inner: RwLock::new(initial),
            version: AtomicU64::new(1),
            waiters: Mutex::new(1),
            cv: Condvar::new(),
            journal: Arc::new(Journal::new(DEFAULT_JOURNAL_CAP)),
        }
    }

    /// The control-plane event journal (shared with pool + shards).
    pub fn journal(&self) -> &Arc<Journal> {
        &self.journal
    }

    /// Current policy version (1 = the initial, never-swapped policy).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Snapshot the current policy together with its version. The pair
    /// is consistent: version reads happen under the same read lock the
    /// `Arc` is cloned under, and installs bump the counter while
    /// holding the write lock.
    pub fn load(&self) -> (Arc<Policy>, u64) {
        let guard = self.inner.read().expect("router lock");
        (guard.clone(), self.version.load(Ordering::Acquire))
    }

    /// Atomically replace the format router, dropping any installed
    /// knob policy (manual-swap compatibility path); returns the new
    /// version. Shards notice on their next message and re-decide
    /// registered matrices.
    pub fn install(&self, next: Arc<RunTimeOptimizer>) -> u64 {
        self.install_policy(Arc::new(Policy::format_only(next)))
    }

    /// Atomically replace the whole policy; returns the new version.
    /// Direct calls journal as a manual swap; the online loop uses
    /// [`Self::install_policy_traced`] to record what triggered it.
    pub fn install_policy(&self, next: Arc<Policy>) -> u64 {
        self.install_policy_traced(next, SwapTrigger::Manual)
    }

    /// Replace the policy and journal the hot-swap with its trigger.
    pub fn install_policy_traced(&self, next: Arc<Policy>, trigger: SwapTrigger) -> u64 {
        let new_version = {
            let mut guard = self.inner.write().expect("router lock");
            *guard = next;
            self.version.fetch_add(1, Ordering::AcqRel) + 1
        };
        // Monotone max: concurrent installs release the write lock in
        // version order but can reach this mutex out of order, and the
        // mirror must never move backwards or waiters would miss an
        // already-installed version.
        let mut w = self.waiters.lock().expect("router waiters lock");
        *w = (*w).max(new_version);
        self.cv.notify_all();
        drop(w);
        self.journal.emit(EventKind::HotSwap { version: new_version, trigger });
        new_version
    }

    /// Block until the policy version reaches `at_least` (true) or the
    /// timeout expires (false). Deterministic test aid for asserting a
    /// background retrain landed.
    pub fn wait_for_version(&self, at_least: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut w = self.waiters.lock().expect("router waiters lock");
        while *w < at_least {
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                return false;
            };
            let (guard, res) = self.cv.wait_timeout(w, remaining).expect("router waiters lock");
            w = guard;
            if res.timed_out() && *w < at_least {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::Objective;
    use crate::testutil::toy_router;

    fn router() -> Arc<RunTimeOptimizer> {
        Arc::new(toy_router(&["rim"], Objective::Latency))
    }

    #[test]
    fn starts_at_version_one_and_install_bumps() {
        let swap = SwapRouter::new(router());
        assert_eq!(swap.version(), 1);
        let (_, v) = swap.load();
        assert_eq!(v, 1);
        assert_eq!(swap.install(router()), 2);
        assert_eq!(swap.version(), 2);
        let (_, v) = swap.load();
        assert_eq!(v, 2);
    }

    #[test]
    fn load_returns_the_installed_policy() {
        let first = router();
        let swap = SwapRouter::new(first.clone());
        let (got, _) = swap.load();
        assert!(Arc::ptr_eq(&got.router, &first));
        assert!(got.knobs.is_none(), "format-only wrapping installs no knob policy");
        let second = router();
        swap.install(second.clone());
        let (got, _) = swap.load();
        assert!(Arc::ptr_eq(&got.router, &second));
    }

    #[test]
    fn format_only_policy_decides_default_knobs() {
        let swap = SwapRouter::new(router());
        let (policy, _) = swap.load();
        let coo = crate::gen::by_name("rim").unwrap().generate(1);
        let feats = crate::features::extract_coo(&coo);
        for f in Format::ALL {
            assert_eq!(policy.knob_for(&feats, f), CompileChoice::serving_default());
        }
    }

    #[test]
    fn joint_policy_swaps_in_and_predicts_per_format_knobs() {
        use crate::gpusim::{MAXRREGCOUNT, TB_SIZES};
        let (r, ds, _) = crate::testutil::toy_setup(&["rim"], Objective::Latency);
        let knobs =
            Arc::new(KnobPolicy::train_on_dataset(&ds, Objective::Latency, "GTX1650m-Turing"));
        let swap = SwapRouter::new(router());
        let v = swap.install_policy(Arc::new(Policy::joint(Arc::new(r), knobs)));
        assert_eq!(v, 2);
        let (policy, _) = swap.load();
        assert!(policy.knobs.is_some());
        let coo = crate::gen::by_name("rim").unwrap().generate(1);
        let feats = crate::features::extract_coo(&coo);
        for f in Format::ALL {
            let c = policy.knob_for(&feats, f);
            assert!(TB_SIZES.contains(&c.tb_size), "{f}: {c}");
            assert!(MAXRREGCOUNT.contains(&c.maxrregcount), "{f}: {c}");
        }
    }

    #[test]
    fn installs_journal_hot_swap_events_with_triggers() {
        let swap = SwapRouter::new(router());
        assert!(swap.journal().is_empty(), "the initial policy is not a swap");
        swap.install(router());
        swap.install_policy_traced(
            Arc::new(Policy::format_only(router())),
            SwapTrigger::Drift,
        );
        let events = swap.journal().snapshot();
        assert_eq!(events.len(), 2);
        assert_eq!(
            events[0].kind,
            EventKind::HotSwap { version: 2, trigger: SwapTrigger::Manual }
        );
        assert_eq!(
            events[1].kind,
            EventKind::HotSwap { version: 3, trigger: SwapTrigger::Drift }
        );
    }

    #[test]
    fn wait_for_version_sees_past_and_future_installs() {
        let swap = Arc::new(SwapRouter::new(router()));
        assert!(swap.wait_for_version(1, Duration::ZERO), "already satisfied");
        assert!(!swap.wait_for_version(2, Duration::from_millis(10)), "times out");
        let bg = swap.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            bg.install(router());
        });
        assert!(swap.wait_for_version(2, Duration::from_secs(5)));
        h.join().unwrap();
    }

    #[test]
    fn concurrent_loads_during_install_never_tear() {
        let swap = Arc::new(SwapRouter::new(router()));
        // train the replacement routers up front so the install loop is
        // tight enough to actually race the readers
        let replacements: Vec<_> = (0..3).map(|_| router()).collect();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let swap = &swap;
                s.spawn(move || {
                    for _ in 0..200 {
                        let (p, v) = swap.load();
                        // the pair must be usable: version monotone, Arc live
                        assert!(v >= 1);
                        let _ = p.router.objective;
                    }
                });
            }
            let swap = &swap;
            s.spawn(move || {
                for r in replacements {
                    swap.install(r);
                }
            });
        });
        assert_eq!(swap.version(), 4);
    }
}
