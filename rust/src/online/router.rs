//! Versioned, atomically hot-swappable router handle.
//!
//! The serving shards must never block on (or even notice) a retrain:
//! they keep a locally cached `Arc<RunTimeOptimizer>` plus the version
//! it came from, poll [`SwapRouter::version`] (one relaxed-ish atomic
//! load) at the top of their message loop, and reload through the
//! `RwLock` only when the version moved. [`SwapRouter::install`] is the
//! single writer path: swap the `Arc`, bump the version, wake waiters.
//! In-flight dispatches keep executing against the old `Arc` they
//! already cloned — a swap can never tear a decision in half.

use crate::coordinator::RunTimeOptimizer;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Shared handle to the current router, swappable at run time.
pub struct SwapRouter {
    inner: RwLock<Arc<RunTimeOptimizer>>,
    /// Monotone version counter; starts at 1 for the initial router.
    version: AtomicU64,
    /// Mirror of `version` for blocking waiters ([`Self::wait_for_version`]).
    waiters: Mutex<u64>,
    cv: Condvar,
}

impl SwapRouter {
    pub fn new(initial: Arc<RunTimeOptimizer>) -> SwapRouter {
        SwapRouter {
            inner: RwLock::new(initial),
            version: AtomicU64::new(1),
            waiters: Mutex::new(1),
            cv: Condvar::new(),
        }
    }

    /// Current router version (1 = the initial, never-swapped router).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Snapshot the current router together with its version. The pair
    /// is consistent: version reads happen under the same read lock the
    /// `Arc` is cloned under, and installs bump the counter while
    /// holding the write lock.
    pub fn load(&self) -> (Arc<RunTimeOptimizer>, u64) {
        let guard = self.inner.read().expect("router lock");
        (guard.clone(), self.version.load(Ordering::Acquire))
    }

    /// Atomically replace the router; returns the new version. Shards
    /// notice on their next message and re-decide registered matrices.
    pub fn install(&self, next: Arc<RunTimeOptimizer>) -> u64 {
        let new_version = {
            let mut guard = self.inner.write().expect("router lock");
            *guard = next;
            self.version.fetch_add(1, Ordering::AcqRel) + 1
        };
        // Monotone max: concurrent installs release the write lock in
        // version order but can reach this mutex out of order, and the
        // mirror must never move backwards or waiters would miss an
        // already-installed version.
        let mut w = self.waiters.lock().expect("router waiters lock");
        *w = (*w).max(new_version);
        self.cv.notify_all();
        new_version
    }

    /// Block until the router version reaches `at_least` (true) or the
    /// timeout expires (false). Deterministic test aid for asserting a
    /// background retrain landed.
    pub fn wait_for_version(&self, at_least: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut w = self.waiters.lock().expect("router waiters lock");
        while *w < at_least {
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                return false;
            };
            let (guard, res) = self.cv.wait_timeout(w, remaining).expect("router waiters lock");
            w = guard;
            if res.timed_out() && *w < at_least {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::Objective;
    use crate::testutil::toy_router;

    fn router() -> Arc<RunTimeOptimizer> {
        Arc::new(toy_router(&["rim"], Objective::Latency))
    }

    #[test]
    fn starts_at_version_one_and_install_bumps() {
        let swap = SwapRouter::new(router());
        assert_eq!(swap.version(), 1);
        let (_, v) = swap.load();
        assert_eq!(v, 1);
        assert_eq!(swap.install(router()), 2);
        assert_eq!(swap.version(), 2);
        let (_, v) = swap.load();
        assert_eq!(v, 2);
    }

    #[test]
    fn load_returns_the_installed_router() {
        let first = router();
        let swap = SwapRouter::new(first.clone());
        let (got, _) = swap.load();
        assert!(Arc::ptr_eq(&got, &first));
        let second = router();
        swap.install(second.clone());
        let (got, _) = swap.load();
        assert!(Arc::ptr_eq(&got, &second));
    }

    #[test]
    fn wait_for_version_sees_past_and_future_installs() {
        let swap = Arc::new(SwapRouter::new(router()));
        assert!(swap.wait_for_version(1, Duration::ZERO), "already satisfied");
        assert!(!swap.wait_for_version(2, Duration::from_millis(10)), "times out");
        let bg = swap.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            bg.install(router());
        });
        assert!(swap.wait_for_version(2, Duration::from_secs(5)));
        h.join().unwrap();
    }

    #[test]
    fn concurrent_loads_during_install_never_tear() {
        let swap = Arc::new(SwapRouter::new(router()));
        // train the replacement routers up front so the install loop is
        // tight enough to actually race the readers
        let replacements: Vec<_> = (0..3).map(|_| router()).collect();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let swap = &swap;
                s.spawn(move || {
                    for _ in 0..200 {
                        let (r, v) = swap.load();
                        // the pair must be usable: version monotone, Arc live
                        assert!(v >= 1);
                        let _ = r.objective;
                    }
                });
            }
            let swap = &swap;
            s.spawn(move || {
                for r in replacements {
                    swap.install(r);
                }
            });
        });
        assert_eq!(swap.version(), 4);
    }
}
