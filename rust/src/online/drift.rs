//! Feature-distribution drift detection over the serving traffic.
//!
//! The retrain cadence alone reacts to drift only after `retrain_every`
//! more requests; this detector pulls the trigger early. It watches the
//! eight Table-2 features (log-scaled, like the models see them) of
//! every served dispatch: the first `window` observations after a
//! (re)base become the reference distribution, and a sliding window of
//! the most recent `window` observations is compared against it with a
//! standardized mean-shift test per feature. Any feature drifting more
//! than `threshold` reference standard deviations flags the whole
//! detector, which the online loop converts into an immediate retrain
//! and a `rebase` (the new traffic mix becomes the new normal).

use crate::features::{Features, FEATURE_NAMES};
use std::collections::VecDeque;
use std::sync::Mutex;

const DIMS: usize = FEATURE_NAMES.len();

/// Detector tuning.
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    /// Observations per window (reference and current).
    pub window: usize,
    /// Standardized mean-shift (in reference std-devs) that counts as
    /// drift.
    pub threshold: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig { window: 64, threshold: 4.0 }
    }
}

/// Snapshot of the detector, surfaced through `PoolStats`.
#[derive(Debug, Clone, Copy)]
pub struct DriftStatus {
    /// True while the current window sits shifted away from reference.
    pub drifted: bool,
    /// Largest standardized per-feature shift seen in the last test.
    pub max_shift: f64,
    /// Name of the feature with the largest shift (Table-2 name).
    pub feature: &'static str,
    /// False until the reference window has filled; no tests run before
    /// that.
    pub reference_full: bool,
}

impl std::fmt::Display for DriftStatus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if !self.reference_full {
            write!(f, "warming up")
        } else if self.drifted {
            write!(f, "DRIFTED ({} shifted {:.1} sigma)", self.feature, self.max_shift)
        } else {
            write!(f, "stable (max {:.1} sigma on {})", self.max_shift, self.feature)
        }
    }
}

struct DriftState {
    reference: Vec<[f64; DIMS]>,
    /// Per-feature (mean, sigma) of the reference window, computed once
    /// when it fills (and at rebase) — the serving path must not redo
    /// O(window x DIMS) passes per dispatch under this mutex.
    ref_stats: Option<[(f64, f64); DIMS]>,
    current: VecDeque<[f64; DIMS]>,
    /// Incrementally maintained per-feature sums of `current`.
    cur_sum: [f64; DIMS],
    drifted: bool,
    max_shift: f64,
    max_feature: usize,
}

/// Per-feature (mean, effective sigma) of a filled window. Constant
/// reference features (a single-matrix warmup) get a scale-relative
/// floor instead of sigma ~ 0, so any real change still registers
/// without dividing by zero.
fn window_stats(window: &[[f64; DIMS]]) -> [(f64, f64); DIMS] {
    let n = window.len() as f64;
    std::array::from_fn(|d| {
        let mean: f64 = window.iter().map(|v| v[d]).sum::<f64>() / n;
        let var: f64 = window.iter().map(|v| (v[d] - mean) * (v[d] - mean)).sum::<f64>() / n;
        let sigma = var.sqrt().max(0.05 * mean.abs()).max(1e-9);
        (mean, sigma)
    })
}

/// Windowed mean/variance shift detector.
pub struct DriftDetector {
    cfg: DriftConfig,
    state: Mutex<DriftState>,
}

fn scaled(f: &Features) -> [f64; DIMS] {
    let v = f.to_scaled_vec();
    std::array::from_fn(|i| v[i])
}

impl DriftDetector {
    pub fn new(cfg: DriftConfig) -> DriftDetector {
        let cfg = DriftConfig { window: cfg.window.max(2), ..cfg };
        DriftDetector {
            cfg,
            state: Mutex::new(DriftState {
                reference: Vec::new(),
                ref_stats: None,
                current: VecDeque::new(),
                cur_sum: [0.0; DIMS],
                drifted: false,
                max_shift: 0.0,
                max_feature: 0,
            }),
        }
    }

    pub fn config(&self) -> DriftConfig {
        self.cfg
    }

    /// Feed one served dispatch's features. Returns true exactly when
    /// this observation newly tips the detector into the drifted state
    /// (a rising edge — the early-retrain trigger).
    pub fn add(&self, f: &Features) -> bool {
        let x = scaled(f);
        let mut st = self.state.lock().expect("drift lock");
        if st.reference.len() < self.cfg.window {
            st.reference.push(x);
            if st.reference.len() == self.cfg.window {
                st.ref_stats = Some(window_stats(&st.reference));
            }
            return false;
        }
        if st.current.len() == self.cfg.window {
            let old = st.current.pop_front().expect("window full");
            for d in 0..DIMS {
                st.cur_sum[d] -= old[d];
            }
        }
        st.current.push_back(x);
        for d in 0..DIMS {
            st.cur_sum[d] += x[d];
        }
        if st.current.len() < self.cfg.window {
            return false;
        }
        // standardized mean shift per feature: O(DIMS), reference stats
        // cached and current-window sums maintained incrementally
        let n_cur = st.current.len() as f64;
        let stats = st.ref_stats.expect("reference filled before current");
        let mut max_shift = 0.0f64;
        let mut max_feature = 0usize;
        for (d, (mean_ref, sigma)) in stats.iter().enumerate() {
            let mean_cur = st.cur_sum[d] / n_cur;
            let shift = (mean_cur - mean_ref).abs() / sigma;
            if shift > max_shift {
                max_shift = shift;
                max_feature = d;
            }
        }
        st.max_shift = max_shift;
        st.max_feature = max_feature;
        let was = st.drifted;
        st.drifted = max_shift > self.cfg.threshold;
        st.drifted && !was
    }

    pub fn status(&self) -> DriftStatus {
        let st = self.state.lock().expect("drift lock");
        DriftStatus {
            drifted: st.drifted,
            max_shift: st.max_shift,
            feature: FEATURE_NAMES[st.max_feature],
            reference_full: st.reference.len() >= self.cfg.window,
        }
    }

    /// Make the current traffic mix the new reference (called after a
    /// retrain absorbed the shift). If the current window has not filled
    /// yet, only the drifted flag resets.
    pub fn rebase(&self) {
        let mut st = self.state.lock().expect("drift lock");
        if st.current.len() >= self.cfg.window {
            st.reference = st.current.iter().copied().collect();
            st.ref_stats = Some(window_stats(&st.reference));
            st.current.clear();
            st.cur_sum = [0.0; DIMS];
        }
        st.drifted = false;
        st.max_shift = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feats(n: f64, avg: f64) -> Features {
        Features {
            n,
            nnz: n * avg,
            avg_nnz: avg,
            var_nnz: avg,
            ell_ratio: 0.5,
            median: avg,
            mode: avg,
            std_nnz: avg.sqrt(),
        }
    }

    #[test]
    fn stable_traffic_never_drifts() {
        let d = DriftDetector::new(DriftConfig { window: 8, threshold: 4.0 });
        for i in 0..100 {
            // mild jitter around one population
            let newly = d.add(&feats(1000.0 + (i % 5) as f64 * 10.0, 8.0));
            assert!(!newly);
        }
        let s = d.status();
        assert!(s.reference_full);
        assert!(!s.drifted, "{s}");
    }

    #[test]
    fn population_shift_is_detected_once_then_rebases_clean() {
        let d = DriftDetector::new(DriftConfig { window: 8, threshold: 4.0 });
        for i in 0..24 {
            assert!(!d.add(&feats(1000.0 + (i % 4) as f64, 8.0)));
        }
        // traffic shifts to a very different population
        let mut edges = 0;
        for i in 0..24 {
            if d.add(&feats(64.0, 200.0 + (i % 3) as f64)) {
                edges += 1;
            }
        }
        assert_eq!(edges, 1, "rising edge fires exactly once");
        assert!(d.status().drifted);
        d.rebase();
        let s = d.status();
        assert!(!s.drifted, "rebase clears the flag: {s}");
        // the shifted population is now the reference: no re-trigger
        let mut re_edges = 0;
        for i in 0..24 {
            if d.add(&feats(64.0, 200.0 + (i % 3) as f64)) {
                re_edges += 1;
            }
        }
        assert_eq!(re_edges, 0, "new normal must not re-fire");
    }

    #[test]
    fn no_test_before_reference_fills() {
        let d = DriftDetector::new(DriftConfig { window: 16, threshold: 1.0 });
        for _ in 0..10 {
            assert!(!d.add(&feats(10.0, 2.0)));
        }
        let s = d.status();
        assert!(!s.reference_full);
        assert!(!s.drifted);
        assert_eq!(format!("{s}"), "warming up");
    }
}
