//! Background retraining: offline sweep + online observations -> a
//! fresh `RunTimeOptimizer` AND a fresh per-format [`KnobPolicy`] for
//! the hot-swap router.
//!
//! A `Trainer` owns everything a retrain needs and nothing the serving
//! hot path touches: the offline dataset, the offline examples (derived
//! once), the objective, and a clone of the overhead model. Each
//! [`Trainer::retrain`] call folds a snapshot of the observation buffer
//! into that base — online [`Example`]s re-label the format classifier
//! for the observed feature vectors, online [`Record`]s teach the
//! per-format value regressors the observed objective levels, and
//! online knob examples re-label the per-format compile-knob
//! classifiers — and fits fresh optimizers through the exact same
//! training paths the offline mode uses.

use super::observer::{self, Observation};
use crate::coordinator::compile_time::KnobPolicy;
use crate::coordinator::{OverheadModel, RunTimeOptimizer};
use crate::dataset::labels::{self, Example};
use crate::dataset::Dataset;
use crate::gpusim::Objective;
use crate::sparse::Format;

/// What one retrain produces: the format router and the per-format
/// compile-knob policy, fitted on the same evidence snapshot (they swap
/// in together, as one [`super::router::Policy`]).
pub struct Retrained {
    pub router: RunTimeOptimizer,
    pub knobs: KnobPolicy,
}

/// Retraining recipe: base corpus + objective + overhead estimate.
pub struct Trainer {
    base: Dataset,
    offline_examples: Vec<Example>,
    /// Derived on the first JOINT retrain only — a format-only loop
    /// (`joint_knobs: false`) never pays the per-format label scan.
    offline_knob_examples: std::sync::OnceLock<Vec<(Format, Example)>>,
    objective: Objective,
    overhead: OverheadModel,
    arch_name: String,
}

impl Trainer {
    /// `arch_name` is the deployment profile's name (it tags synthetic
    /// online records so they slot into the dataset's (matrix, arch)
    /// slicing, and selects the arch indicator feature).
    pub fn new(
        base: Dataset,
        objective: Objective,
        overhead: OverheadModel,
        arch_name: &str,
    ) -> Trainer {
        let offline_examples = labels::examples(&base, objective);
        Trainer {
            base,
            offline_examples,
            offline_knob_examples: std::sync::OnceLock::new(),
            objective,
            overhead,
            arch_name: arch_name.to_string(),
        }
    }

    pub fn objective(&self) -> Objective {
        self.objective
    }

    /// Deployment profile name (tags observation checkpoints too).
    pub fn arch(&self) -> &str {
        &self.arch_name
    }

    /// Offline examples the base dataset contributes to every retrain.
    pub fn offline_examples(&self) -> usize {
        self.offline_examples.len()
    }

    /// Fit a fresh router + knob policy on offline + online evidence.
    /// Pure function of its inputs: same buffer snapshot, same models.
    /// The deployment arch indicator is reapplied, so a Pascal-deployed
    /// pool does not hot-swap in a router that predicts for Turing.
    pub fn retrain(&self, obs: &[Observation]) -> Retrained {
        self.retrain_with(obs, true)
    }

    /// Like [`Trainer::retrain`]; `joint = false` skips the knob-policy
    /// fit entirely (the returned policy predicts the serving default
    /// for every format) — the format-only loop would discard it
    /// anyway, so it must not pay four per-format tree fits per
    /// retrain.
    pub fn retrain_with(&self, obs: &[Observation], joint: bool) -> Retrained {
        let delta = observer::to_training(obs, self.objective, &self.arch_name);
        let mut ds = self.base.clone();
        ds.records.extend(delta.records);
        let mut examples = self.offline_examples.clone();
        examples.extend(delta.examples);
        let router = RunTimeOptimizer::train_on_examples(
            &ds,
            &examples,
            self.objective,
            self.overhead.clone(),
        )
        .for_arch(&self.arch_name);
        let knobs = if joint {
            let mut knob_examples = self
                .offline_knob_examples
                .get_or_init(|| KnobPolicy::offline_examples(&self.base, self.objective))
                .clone();
            knob_examples.extend(delta.knob_examples);
            KnobPolicy::train(self.objective, &self.arch_name, &knob_examples)
        } else {
            KnobPolicy::train(self.objective, &self.arch_name, &[])
        };
        Retrained { router, knobs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::compile_time::CompileChoice;
    use crate::features;
    use crate::gen;
    use crate::gpusim::{Measurement, MemConfig};
    use crate::sparse::convert::coo_to_csr;
    use crate::sparse::Format;
    use crate::testutil::toy_setup;

    /// Observations claiming ELL beats CSR on energy for one matrix.
    fn counterfactual_obs(coo: &crate::sparse::Coo) -> Vec<Observation> {
        let feats = features::extract_csr(&coo_to_csr(coo));
        let mk = |format: Format, energy: f64| Observation {
            matrix_id: 1,
            kind: crate::sparse::KernelKind::Spmv,
            features: feats,
            format,
            choice: CompileChoice::serving_default(),
            explored: format != Format::Csr,
            requests: 1,
            measured_latency_s: 1e-6,
            modeled: Measurement {
                latency_s: 1e-6,
                energy_j: energy,
                avg_power_w: 10.0,
                mflops_per_watt: 1.0 / energy,
            },
        };
        vec![mk(Format::Csr, 8e-4), mk(Format::Ell, 1e-5), mk(Format::Csr, 8e-4)]
    }

    #[test]
    fn retrain_learns_online_labels_and_values() {
        let (_, ds, overhead) = toy_setup(&["eu-2005", "wiki-talk-temporal"], Objective::Energy);
        let trainer = Trainer::new(ds, Objective::Energy, overhead, "GTX1650m-Turing");
        assert!(trainer.offline_examples() > 0);
        assert_eq!(trainer.objective(), Objective::Energy);

        let coo = gen::by_name("rim").unwrap().generate(1);
        let obs = counterfactual_obs(&coo);
        let next = trainer.retrain(&obs).router;
        // the retrained tree memorizes the online feature vector's label
        let d = next.decide(&coo, 1_000_000_000_000);
        assert_eq!(d.predicted_format, Format::Ell, "online label must win: {d:?}");
        // ...and the value models reproduce the observed objective gap,
        // so the amortization gate opens for a long-lived matrix
        assert!(
            d.est_best < d.est_default,
            "online records must teach the value gap: {d:?}"
        );
        assert!(d.convert, "huge iteration budget + real gap must convert: {d:?}");
    }

    #[test]
    fn retrain_without_observations_reproduces_offline_decisions() {
        let (offline, ds, overhead) = toy_setup(&["rim", "eu-2005"], Objective::EnergyEff);
        let trainer = Trainer::new(ds, Objective::EnergyEff, overhead, "GTX1650m-Turing");
        let retrained = trainer.retrain(&[]).router;
        for name in ["rim", "eu-2005"] {
            let coo = gen::by_name(name).unwrap().generate(1);
            let a = offline.decide(&coo, 1000);
            let b = retrained.decide(&coo, 1000);
            assert_eq!(a.predicted_format, b.predicted_format, "{name}");
            assert_eq!(a.convert, b.convert, "{name}");
        }
    }

    #[test]
    fn retrain_learns_online_knob_labels() {
        let (_, ds, overhead) = toy_setup(&["eu-2005", "wiki-talk-temporal"], Objective::Energy);
        let trainer = Trainer::new(ds, Objective::Energy, overhead, "GTX1650m-Turing");
        let coo = gen::by_name("rim").unwrap().generate(1);
        let feats = features::extract_csr(&coo_to_csr(&coo));
        // counterfactual knob evidence on ELL: the small-TB / L1 arm is
        // far cheaper than the serving default
        let winner = CompileChoice { tb_size: 64, maxrregcount: 32, mem: MemConfig::PreferL1 };
        let mk = |choice: CompileChoice, energy: f64| Observation {
            matrix_id: 2,
            kind: crate::sparse::KernelKind::Spmv,
            features: feats,
            format: Format::Ell,
            choice,
            explored: true,
            requests: 1,
            measured_latency_s: 1e-6,
            modeled: Measurement {
                latency_s: 1e-6,
                energy_j: energy,
                avg_power_w: 10.0,
                mflops_per_watt: 1.0 / energy,
            },
        };
        let obs =
            vec![mk(CompileChoice::serving_default(), 5e-4), mk(winner, 1e-6)];
        let knobs = trainer.retrain(&obs).knobs;
        let predicted = knobs.predict(&feats, Format::Ell);
        assert_eq!(
            predicted, winner,
            "the per-format knob tree must memorize the online knob label"
        );
    }
}
